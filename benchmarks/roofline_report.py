"""Aggregate dry-run JSONs into the §Dry-run / §Roofline tables."""

from __future__ import annotations

import glob
import json
from pathlib import Path
from typing import Dict, List


def load(outdir: str = "results/dryrun") -> List[Dict]:
    rows = []
    for f in sorted(glob.glob(f"{outdir}/*.json")):
        d = json.loads(Path(f).read_text())
        rows.append(d)
    return rows


def table(outdir: str = "results/dryrun", mesh: str = "single"
          ) -> List[Dict]:
    rows = []
    for d in load(outdir):
        if d.get("mesh") != mesh:
            continue
        if not d.get("ok"):
            rows.append({"arch": d["arch"], "shape": d["shape"],
                         "ok": False, "error": d.get("error", "")[:80]})
            continue
        r = d["roofline"]
        rows.append({
            "arch": d["arch"], "shape": d["shape"], "ok": True,
            "peak_gb": d["memory"]["peak_bytes"] / 1e9,
            "residency_gb": r.get("residency_gb"),
            "t_compute": r["t_compute_s"], "t_memory": r["t_memory_s"],
            "t_collective": r["t_collective_s"],
            "bottleneck": r["bottleneck"],
            "useful": r["useful_flop_fraction"],
            "roofline_fraction": r["roofline_fraction"],
            "compile_s": d.get("compile_s"),
        })
    return rows


def markdown(outdir: str = "results/dryrun", mesh: str = "single") -> str:
    rows = table(outdir, mesh)
    out = ["| arch | shape | XLA peak GB | est GB (TPU) | t_comp s "
           "| t_mem s | t_coll s | bottleneck | useful | roofline frac |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if not r["ok"]:
            out.append(f"| {r['arch']} | {r['shape']} | FAIL: "
                       f"{r['error']} | | | | | | |")
            continue
        res = r.get("residency_gb")
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['peak_gb']:.1f} "
            f"| {res if res is not None else '-'} "
            f"| {r['t_compute']:.4f} | {r['t_memory']:.4f} "
            f"| {r['t_collective']:.4f} | {r['bottleneck']} "
            f"| {r['useful']:.2f} | {r['roofline_fraction']:.3f} |")
    return "\n".join(out)


def summary(outdir: str = "results/dryrun") -> Dict:
    singles = [r for r in table(outdir, "single") if r.get("ok")]
    multis = [r for r in table(outdir, "multi") if r.get("ok")]
    fails = [r for r in table(outdir, "single") + table(outdir, "multi")
             if not r.get("ok")]
    return {
        "cells_single_ok": len(singles),
        "cells_multi_ok": len(multis),
        "fails": len(fails),
        "worst_roofline": (min(singles, key=lambda r: r["roofline_fraction"])
                           ["arch"] if singles else ""),
        "mean_roofline_fraction": (
            sum(r["roofline_fraction"] for r in singles) / len(singles)
            if singles else 0.0),
    }
