# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV rows (plus the roofline summary when dry-run results exist).
from __future__ import annotations

import sys

from benchmarks import ckpt_zns, paper_figures, roofline_report
from benchmarks.common import Bench


def main() -> None:
    quick = "--quick" in sys.argv
    b = Bench()
    # the SA<->DLWA trade-off needs enough churn to pressure the
    # active-zone budget; 1M ops is the floor for fig7b/7c
    n_ops = 1_000_000

    b.timeit("fig4a_7a_dlwa_vs_occupancy",
             paper_figures.fig4a_7a_dlwa_vs_occupancy,
             ("reduction_at_10pct", "paper_claim"))
    b.timeit("fig4b_7d_interference",
             paper_figures.fig4b_7d_interference,
             ("worst_baseline", "worst_silentzns"))
    b.timeit("fig7b_sa_dlwa_tradeoff",
             lambda: paper_figures.fig7b_sa_dlwa_tradeoff(n_ops),
             ("dlwa_reduction_at_low_thr", "sa_increase_delaying_finish",
              "paper_sa_increase"))
    b.timeit("fig7c_wear",
             lambda: paper_figures.fig7c_wear(n_ops),
             ("baseline_erases", "silentzns_erases", "erase_reduction"))
    b.timeit("fig7c_wear_leveling", paper_figures.fig7c_wear_leveling,
             ("baseline_max_wear", "silentzns_max_wear",
              "baseline_std", "silentzns_std"))
    b.timeit("fig8_geometry_sweep", paper_figures.fig8_geometry_sweep,
             ("fixed_over_vchunk2_P8S128", "paper_claim"))
    b.timeit("fig9_throughput", paper_figures.fig9_throughput,
             ("peak_P16_1job", "P8_1job", "P8_2jobs"))
    b.timeit("table3_interference", paper_figures.table3_interference,
             ("fixed_minus_vchunk2_multiseg",))
    b.timeit("table4_alloc_latency", paper_figures.table4_alloc_latency,
             ("fixed_us", "superblock_us", "block_us"))
    b.timeit("ckpt_zns_all_archs", ckpt_zns.run_all,
             ("mean_dlwa_reduction", "worst_baseline_dlwa"))

    try:
        s = roofline_report.summary()
        b.add("roofline_dryrun_summary", 0.0,
              ";".join(f"{k}={v}" for k, v in s.items()))
    except Exception as e:  # noqa: BLE001 -- dry-run results may be absent
        b.add("roofline_dryrun_summary", 0.0, f"skipped={e}")

    b.emit()


if __name__ == "__main__":
    main()
