# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV rows (plus the roofline summary when dry-run results exist).
from __future__ import annotations

import sys

import numpy as np

from benchmarks import ckpt_zns, paper_figures, roofline_report
from benchmarks.common import Bench
from repro.core import workloads, zn540
from repro.core.elements import SUPERBLOCK


def engine_batched_drivers() -> dict:
    """The fig4a/fig4b workloads through the scan-compiled engine: the
    dlwa occupancy sweep as one batched scan and interference as fused
    finish+host-write programs, with the measured speedup over the
    legacy per-op loop (tools/bench.py archives the same numbers)."""
    rep = workloads.engine_vs_legacy_speedup(
        occupancies=tuple(float(o) for o in np.linspace(0.05, 0.95, 16)),
        n_zones=8, concurrencies=(1, 2, 4, 7), repeats=2)
    flash, zone = zn540()
    eng = workloads.make_engine(flash, zone, SUPERBLOCK, max_active=28)
    sweep = workloads.dlwa_sweep_engine(
        eng, (0.1, 0.3, 0.5, 0.7, 0.9), n_zones=4)
    rep["dlwa_at_10pct"] = sweep[0]["dlwa"]
    return rep


def main() -> None:
    quick = "--quick" in sys.argv
    b = Bench()
    # the SA<->DLWA trade-off needs enough churn to pressure the
    # active-zone budget; 1M ops is the floor for fig7b/7c
    n_ops = 1_000_000

    b.timeit("fig4a_7a_dlwa_vs_occupancy",
             paper_figures.fig4a_7a_dlwa_vs_occupancy,
             ("reduction_at_10pct", "paper_claim"))
    b.timeit("fig4b_7d_interference",
             paper_figures.fig4b_7d_interference,
             ("worst_baseline", "worst_silentzns"))
    b.timeit("fig7b_sa_dlwa_tradeoff",
             lambda: paper_figures.fig7b_sa_dlwa_tradeoff(n_ops),
             ("dlwa_reduction_at_low_thr", "sa_increase_delaying_finish",
              "paper_sa_increase"))
    b.timeit("fig7c_wear",
             lambda: paper_figures.fig7c_wear(n_ops),
             ("baseline_erases", "silentzns_erases", "erase_reduction"))
    b.timeit("fig7c_wear_leveling", paper_figures.fig7c_wear_leveling,
             ("baseline_max_wear", "silentzns_max_wear",
              "baseline_std", "silentzns_std"))
    b.timeit("fig8_geometry_sweep", paper_figures.fig8_geometry_sweep,
             ("fixed_over_vchunk2_P8S128", "paper_claim"))
    b.timeit("fig9_throughput", paper_figures.fig9_throughput,
             ("peak_P16_1job", "P8_1job", "P8_2jobs"))
    b.timeit("table3_interference", paper_figures.table3_interference,
             ("fixed_minus_vchunk2_multiseg",))
    b.timeit("table4_alloc_latency", paper_figures.table4_alloc_latency,
             ("fixed_us", "superblock_us", "block_us"))
    b.timeit("ckpt_zns_all_archs", ckpt_zns.run_all,
             ("mean_dlwa_reduction", "worst_baseline_dlwa"))
    b.timeit("engine_batched_drivers", engine_batched_drivers,
             ("dlwa_speedup", "interference_speedup",
              "dlwa_engine_ops_s", "dlwa_legacy_ops_s", "dlwa_at_10pct"))

    try:
        s = roofline_report.summary()
        b.add("roofline_dryrun_summary", 0.0,
              ";".join(f"{k}={v}" for k, v in s.items()))
    except Exception as e:  # noqa: BLE001 -- dry-run results may be absent
        b.add("roofline_dryrun_summary", 0.0, f"skipped={e}")

    b.emit()


if __name__ == "__main__":
    main()
