"""The paper's headline numbers, one batched dispatch per figure.

Reproduces the three summary claims -- ~92% lower DLWA at 10%
occupancy, up to 12% less wear, up to 3.7x faster workload execution --
as SilentZNS-policy vs traditional-mapping lane pairs over ONE shared
union engine (see :mod:`repro.core.headline`):

* DLWA vs occupancy (fill + FINISH at each occupancy point);
* total block erases under RESET churn;
* workload execution time via the op-granular fleet timing model.

Usage::

    PYTHONPATH=src python benchmarks/paper_headline.py \
        [--occupancies 0.1,0.3,0.5] [--zones 4] [--wear-zones 8] \
        [--wear-cycles 8] [--exec-cycles 4] [--wear-bound N] \
        [--quick] [--out paper_headline.json]

The gated artifact (``BENCH_paper.json``) is written by
``tools/bench.py``, which wraps :func:`repro.core.headline.paper_report`
with the acceptance gates (DLWA reduction at 10% >= 80%, wear reduction
> 0, execution speedup > 1x, zero recompiles across repeated
dispatches).
"""

from __future__ import annotations

import argparse
import json
import sys

from repro.core import headline


def _occ_list(text: str):
    try:
        occs = [float(t) for t in text.split(",") if t.strip()]
    except ValueError as exc:
        raise argparse.ArgumentTypeError(
            f"--occupancies expects comma-separated floats, got "
            f"{text!r}") from exc
    if not occs or not all(0.0 < o <= 1.0 for o in occs):
        raise argparse.ArgumentTypeError(
            f"--occupancies values must be in (0, 1], got {text!r}")
    return occs


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__, allow_abbrev=False)
    ap.add_argument("--occupancies", type=_occ_list,
                    default=list(headline.DEFAULT_OCCUPANCIES),
                    help="DLWA sweep points (comma-separated, in (0,1])")
    ap.add_argument("--zones", type=int, default=4,
                    help="zones per DLWA lane")
    ap.add_argument("--wear-zones", type=int, default=8,
                    help="zones churned by the wear/exec figures")
    ap.add_argument("--wear-cycles", type=int, default=8,
                    help="RESET churn cycles of the wear figure")
    ap.add_argument("--exec-cycles", type=int, default=4,
                    help="churn cycles of the execution-time figure")
    ap.add_argument("--wear-bound", type=int, default=None,
                    help="silent-policy wear-leveling bound in erases "
                         "(default: unbounded)")
    ap.add_argument("--quick", action="store_true",
                    help="small sweep (3 occupancies, 4 zones, "
                         "2 cycles)")
    ap.add_argument("--out", type=str, default="paper_headline.json",
                    help="JSON output path ('' = stdout only)")
    args = ap.parse_args(argv)
    if args.quick:
        args.occupancies = [0.1, 0.3, 0.7]
        args.wear_zones = min(args.wear_zones, 4)
        args.wear_cycles = min(args.wear_cycles, 4)
        args.exec_cycles = min(args.exec_cycles, 2)

    report = headline.paper_report(
        occupancies=args.occupancies, dlwa_zones=args.zones,
        wear_zones=args.wear_zones, wear_cycles=args.wear_cycles,
        exec_cycles=args.exec_cycles, wear_bound=args.wear_bound)

    d, w, x = report["dlwa"], report["wear"], report["exec"]
    print("DLWA vs occupancy (traditional -> silent):")
    for o, t, s, r in zip(d["occupancies"], d["traditional_dlwa"],
                          d["silent_dlwa"], d["dlwa_reduction"]):
        print(f"  occ {o:4.0%}: {t:7.3f} -> {s:6.3f}  (-{r:.1%})")
    print(f"DLWA reduction at 10% occupancy: "
          f"{d['reduction_at_10pct']:.1%} (paper: 92%)")
    print(f"wear: {w['traditional_erases']:.0f} -> "
          f"{w['silent_erases']:.0f} block erases "
          f"(-{w['wear_reduction']:.1%}; paper: up to 12%)")
    print(f"execution: {x['traditional_s']:.3f}s -> "
          f"{x['silent_s']:.3f}s  ({x['speedup']:.2f}x; "
          f"paper: up to 3.7x)")
    print(f"recompiles on repeat: "
          f"{report['recompiles']['delta_total']:.0f}")

    if args.out:
        with open(args.out, "w") as fh:
            json.dump(report, fh, indent=2, sort_keys=True)
        print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
