"""ZNS-RAID fleet benchmark: device count x chunk x parity x allocator.

Engine-native by default: the sweep and the rebuild mode compile their
array workloads into encoded op programs and execute each cell as ONE
batched ``run_programs`` dispatch (``repro.array.ArrayEngine``), with
op-granular fleet timing.  ``--legacy`` runs the original object
``ZNSArray`` pipeline instead -- the bit-exactness oracle -- for
cross-checks.

Modes (same ``name,us_per_call,derived`` CSV schema as
``benchmarks/run.py`` via :class:`benchmarks.common.Bench`):

* sweep (default)::

      PYTHONPATH=src python benchmarks/raid_zns.py [--quick] [--legacy]

  crosses device count x stripe-chunk size x parity on/off x allocator
  spec and emits one row per cell.

* single end-to-end run::

      PYTHONPATH=src python benchmarks/raid_zns.py --devices 8 --parity

  fills superzones through ``ZoneFS``, FINISHes them, simulates the
  whole fleet in one vmapped scan, and prints per-device DLWA/wear plus
  the fleet makespan.  ZoneFS mounts ``ArrayEngine`` (the compiler
  path: per-op commands validate eagerly, execute as ONE batched
  dispatch); ``--legacy`` mounts the per-op object ``ZNSArray``
  oracle.

* rebuild-after-failure::

      PYTHONPATH=src python benchmarks/raid_zns.py --rebuild --devices 4

  fails a member, reconstructs its chunks onto a replacement (survivor
  degraded reads + sequential re-append), and reports the rebuild
  traffic's fleet makespan and its interference with concurrent host
  writes.  Engine-native this is one :func:`repro.array.rebuild_storm`
  scenario -- all three variants (host / rebuild / contended) in one
  dispatch; ``--legacy`` replays the PR 2 object pipeline.
"""

from __future__ import annotations

import argparse
import pathlib
import sys
from typing import Dict, Optional

_ROOT = pathlib.Path(__file__).resolve().parent.parent
for _p in (str(_ROOT), str(_ROOT / "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

from benchmarks.common import Bench
from repro.array import (ArrayEngine, StormScenario, ZNSArray,
                         rebuild_storm)
from repro.core import (BLOCK, FIXED, SUPERBLOCK, timing, vchunk, zn540)
from repro.core.elements import ElementSpec
from repro.core.engine import ZoneEngine
from repro.storage import ZoneFS

SPECS: Dict[str, ElementSpec] = {
    "fixed": FIXED, "superblock": SUPERBLOCK, "block": BLOCK,
    "vchunk2": vchunk(2),
}


def build_array(n_devices: int, chunk_pages: Optional[int], parity: bool,
                spec: ElementSpec) -> ZNSArray:
    flash, zone = zn540()
    return ZNSArray.build(flash, zone, spec, n_devices=n_devices,
                          chunk_pages=chunk_pages, parity=parity,
                          max_active=14)


def raid_benchmark(*, n_devices: int, chunk_pages: Optional[int] = None,
                   parity: bool = False, spec: ElementSpec = SUPERBLOCK,
                   occupancy: float = 0.5, n_zones: int = 4,
                   legacy: bool = False) -> Dict:
    """Fill ``n_zones`` superzones to ``occupancy``, FINISH each, and
    fleet-time the resulting traffic (data + parity + FINISH padding).

    Engine-native (default): the workload compiles to member op
    programs, one batched scan executes them, and op-granular
    ``simulate_fleet_ops`` times the fleet.  ``legacy``: the object
    array + page-granular ``run_fleet_trace`` (the PR 1 pipeline)."""
    if legacy:
        arr = build_array(n_devices, chunk_pages, parity, spec)
        pages = max(1, int(round(arr.zone_pages * occupancy)))
        tagged = []
        for z in range(min(n_zones, arr.max_active, arr.n_zones)):
            tagged += arr.zone_write(z, pages, trace=True) or []
            tagged += arr.zone_finish(z, trace=True) or []
        fleet = timing.run_fleet_trace(
            arr.flash, timing.group_tagged(tagged, n_devices))
        rep = arr.report()
        rep["fleet_makespan_s"] = fleet["fleet_makespan_s"]
        rep["fleet_pages"] = float(fleet["n"])
        for i in range(n_devices):
            rep[f"dev{i}_makespan_s"] = fleet[f"dev{i}_makespan_s"]
        per = arr.device_reports()
        rep["mean_device_dlwa"] = sum(r["dlwa"] for r in per) / len(per)
        return rep

    flash, zone = zn540()
    arr = ArrayEngine.build(flash, zone, spec, n_devices=n_devices,
                            chunk_pages=chunk_pages, parity=parity,
                            max_active=14)
    pages = max(1, int(round(arr.zone_pages * occupancy)))
    for z in range(min(n_zones, arr.max_active, arr.n_zones)):
        arr.zone_write(z, pages)
        arr.zone_finish(z)
    # one op-axis quantum across all sweep cells -> a handful of
    # compiled shapes for the whole sweep instead of one per cell
    arr.run(pad_quantum=256)
    rep = arr.report()
    rep.update(arr.fleet_timing())
    per = arr.device_reports()
    rep["mean_device_dlwa"] = sum(r["dlwa"] for r in per) / len(per)
    return rep


class TracingArray(ZNSArray):
    """ZNSArray that records every member IOTrace it emits, so hosts
    that never ask for traces (ZoneFS) can still be fleet-timed."""

    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self.tagged: list = []

    def zone_write(self, zone_id, n_pages, *, host=True, trace=False):
        out = super().zone_write(zone_id, n_pages, host=host, trace=True)
        self.tagged += out
        return out if trace else None

    def zone_finish(self, zone_id, *, trace=False):
        out = super().zone_finish(zone_id, trace=True)
        self.tagged += out or []
        return out if trace else None


def fleet_run(args: argparse.Namespace) -> Dict:
    """End-to-end: KV-style ZoneFS traffic over the array, then fleet
    timing of that same traffic; prints per-device DLWA/wear and the
    fleet makespan.

    Engine-native by default: ZoneFS mounts :class:`ArrayEngine`
    directly (the compiler path -- commands validate against the
    superzone mirror and accumulate as member op programs), then ONE
    batched dispatch executes the whole mount and one op-granular
    timing dispatch scores it.  ``--legacy`` mounts the per-op object
    ``ZNSArray`` (the test oracle) and times its recorded IO traces."""
    spec = SPECS[args.spec]
    flash, zone = zn540()
    cls = TracingArray if args.legacy else ArrayEngine
    arr = cls.build(flash, zone, spec, n_devices=args.devices,
                    chunk_pages=args.chunk_pages,
                    parity=args.parity, max_active=14)
    fs = ZoneFS(arr, finish_threshold=args.finish_threshold)
    # rotating create/delete traffic: files of ~1/3 superzone, lifetimes
    # cycling so zones mix and FINISH/RESET both fire
    file_pages = max(1, arr.zone_pages // 3)
    live = []
    for fid in range(args.files):
        if not fs.create(fid, file_pages, lifetime=fid % 3):
            break
        live.append(fid)
        if len(live) > 6:
            fs.delete(live.pop(0))
    for z, info in arr.zones.items():
        if info.state.name == "OPEN":
            fs.dev.zone_finish(z)

    if args.legacy:
        fleet = timing.run_fleet_trace(
            arr.flash, timing.group_tagged(arr.tagged, args.devices))
        makespan = fleet["fleet_makespan_s"]
    else:
        arr.run(pad_quantum=256)
        makespan = arr.fleet_timing()["fleet_makespan_s"]

    rep = arr.report()
    rep.update(fs.report())
    rep["fleet_makespan_s"] = makespan
    print(f"# array {arr.geom.describe()} spec={args.spec} "
          f"finish_threshold={args.finish_threshold} "
          f"({'legacy object array' if args.legacy else 'engine'})")
    print("device,dlwa,host_pages,dummy_pages,total_block_erases,"
          "max_wear,cv_wear,failed")
    for r in arr.device_reports():
        print(f"{int(r['device'])},{r['dlwa']:.4f},{int(r['host_pages'])},"
              f"{int(r['dummy_pages'])},{int(r['total_block_erases'])},"
              f"{int(r['max_wear'])},{r['cv_wear']:.4f},"
              f"{int(r['failed'])}")
    print(f"array_dlwa,{rep['dlwa']:.4f}")
    print(f"parity_overhead,{rep['parity_overhead']:.4f}")
    print(f"sa,{rep['sa']:.4f}")
    print(f"fleet_makespan_s,{rep['fleet_makespan_s']:.6f}")
    return rep


def rebuild_run_legacy(args: argparse.Namespace) -> Dict:
    """The object-pipeline rebuild mode (PR 2): fill, fail, rebuild via
    tagged traces, three per-scenario ``run_fleet_trace`` calls."""
    spec = SPECS[args.spec]
    flash, zone = zn540()
    n_dev = max(2, args.devices or 4)
    arr = ZNSArray.build(flash, zone, spec, n_devices=n_dev,
                         chunk_pages=args.chunk_pages, parity=True,
                         max_active=14)
    fill = max(1, int(round(arr.zone_pages * 0.6)))
    n_filled = min(4, arr.n_zones // 2, arr.max_active)
    for z in range(n_filled):
        arr.zone_write(z, fill)
        arr.zone_finish(z)

    failed = n_dev - 1
    arr.fail_device(failed)
    rebuild_tagged = arr.rebuild_device(failed)

    # concurrent host I/O: fresh superzones written while the rebuild runs
    host_tagged = []
    for z in range(n_filled, min(2 * n_filled, arr.n_zones)):
        host_tagged += arr.zone_write(z, fill, trace=True) or []

    base = timing.run_fleet_trace(
        arr.flash, timing.group_tagged(host_tagged, n_dev))
    reb = timing.run_fleet_trace(
        arr.flash, timing.group_tagged(rebuild_tagged, n_dev))
    cont = timing.run_fleet_trace(
        arr.flash, timing.group_tagged(host_tagged + rebuild_tagged, n_dev))
    interference = (cont["fleet_makespan_s"] / base["fleet_makespan_s"]
                    if base["fleet_makespan_s"] else float("inf"))
    rebuilt = sum(len(t.luns) for i, t in rebuild_tagged
                  if i == failed and t.op == "write")
    rep = {
        "n_devices": float(n_dev),
        "failed_device": float(failed),
        # pages re-appended to the replacement (incl. its FINISH padding)
        "rebuild_pages": float(rebuilt),
        # every page the rebuild moves, survivor degraded reads included
        "rebuild_traffic_pages": float(
            sum(len(t.luns) for _, t in rebuild_tagged)),
        "rebuild_makespan_s": reb["fleet_makespan_s"],
        "host_makespan_s": base["fleet_makespan_s"],
        "contended_makespan_s": cont["fleet_makespan_s"],
        "rebuild_interference": interference,
        "replacement_host_pages": float(arr.devices[failed].host_pages),
        "replacement_dummy_pages": float(arr.devices[failed].dummy_pages),
    }
    print(f"# rebuild {arr.geom.describe()} spec={args.spec} "
          f"failed={failed} (legacy)")
    for k, v in rep.items():
        print(f"{k},{v:.6g}")
    return rep


def rebuild_run(args: argparse.Namespace) -> Dict:
    """Engine-native rebuild-after-failure: one
    :func:`repro.array.rebuild_storm` scenario -- the host / rebuild /
    contended variants compile onto a shared engine and execute in ONE
    batched dispatch, then one op-granular timing dispatch reports the
    interference ratio."""
    if args.legacy:
        return rebuild_run_legacy(args)
    spec = SPECS[args.spec]
    flash, zone = zn540()
    n_dev = max(2, args.devices or 4)
    eng = ZoneEngine(flash, zone, spec, max_active=14)
    sc = StormScenario(n_devices=n_dev, chunk_pages=args.chunk_pages,
                       n_zones_filled=4, occupancy=0.6)
    out = rebuild_storm(eng, [sc])
    rep = dict(out["scenarios"][0])
    label = rep.pop("scenario")
    print(f"# rebuild {label} spec={args.spec} "
          f"failed={int(rep['failed_device'])} (engine)")
    for k, v in rep.items():
        print(f"{k},{v:.6g}")
    return rep


def sweep(quick: bool, legacy: bool = False) -> None:
    b = Bench()
    flash, zone = zn540()
    seg = zone.segment_pages(flash)
    devices = (1, 2, 4) if quick else (1, 2, 4, 8)
    chunks = (seg,) if quick else (seg, 2 * seg)
    specs = ("fixed", "superblock") if quick else (
        "fixed", "superblock", "vchunk2")
    for n_dev in devices:
        for chunk in chunks:
            for parity in (False, True):
                if parity and n_dev < 2:
                    continue
                for spec_name in specs:
                    name = (f"raid_d{n_dev}_c{chunk}_"
                            f"{'p1' if parity else 'p0'}_{spec_name}")
                    b.timeit(name, lambda n=n_dev, c=chunk, p=parity,
                             s=spec_name: raid_benchmark(
                                 n_devices=n, chunk_pages=c, parity=p,
                                 spec=SPECS[s], legacy=legacy),
                             ("dlwa", "parity_overhead", "max_device_dlwa",
                              "fleet_makespan_s", "total_block_erases"))
    b.emit()


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--devices", type=int, default=0,
                    help="single-run mode with this many member devices")
    ap.add_argument("--parity", action="store_true")
    ap.add_argument("--chunk-pages", type=int, default=None)
    ap.add_argument("--spec", choices=sorted(SPECS), default="superblock")
    ap.add_argument("--finish-threshold", type=float, default=0.1)
    ap.add_argument("--files", type=int, default=24)
    ap.add_argument("--rebuild", action="store_true",
                    help="rebuild-after-failure mode: reconstruct a "
                         "replaced member and report interference with "
                         "host I/O")
    ap.add_argument("--legacy", action="store_true",
                    help="run the object ZNSArray pipeline instead of "
                         "the engine-native path (cross-check oracle)")
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    if args.rebuild:
        rebuild_run(args)
    elif args.devices:
        fleet_run(args)
    else:
        sweep(args.quick, legacy=args.legacy)


if __name__ == "__main__":
    main()
