"""Reproductions of every paper table/figure on the emulated device.

Each ``fig*/table*`` function reproduces one artifact and returns its data
(dict of rows); ``benchmarks.run`` times them and emits CSV.
"""

from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.core import (BLOCK, FIXED, PAPER_GEOMETRIES, SUPERBLOCK,
                        ZNSDevice, ZoneGeometry, custom16, hchunk,
                        is_applicable, vchunk, zn540)
from repro.core import workloads
from repro.core.metrics import wear_report
from repro.storage import KVBenchConfig, LSMSimulator, ZoneFS

ELEMENTS = (FIXED, SUPERBLOCK, BLOCK, vchunk(2), vchunk(4), hchunk(2))


# --------------------------------------------------------------------- #
def fig4a_7a_dlwa_vs_occupancy() -> Dict:
    """Fig. 4a / 7a: DLWA vs zone occupancy, baseline vs SilentZNS
    (ZN540 model).  Paper: -86.36% at 10% occupancy w/ superblock."""
    flash, zone = zn540()
    rows = []
    for occ in (0.1, 0.3, 0.5, 0.7, 0.9):
        base = ZNSDevice(flash, zone, FIXED)
        sil = ZNSDevice(flash, zone, SUPERBLOCK)
        rb = workloads.dlwa_benchmark(base, occupancy=occ, n_zones=4)
        rs = workloads.dlwa_benchmark(sil, occupancy=occ, n_zones=4)
        rows.append({"occupancy": occ, "baseline_dlwa": rb["dlwa"],
                     "silentzns_dlwa": rs["dlwa"]})
    r10 = rows[0]
    reduction = (r10["baseline_dlwa"] - r10["silentzns_dlwa"]) \
        / r10["baseline_dlwa"]
    return {"rows": rows, "reduction_at_10pct": reduction,
            "paper_claim": 0.8636}


def fig4b_7d_interference() -> Dict:
    """Fig. 4b / 7d: FINISH-vs-host interference vs concurrency."""
    flash, zone = zn540()
    rows = []
    for conc in (1, 2, 3, 4, 5, 6, 7):
        base = ZNSDevice(flash, zone, FIXED, max_active=28)
        sil = ZNSDevice(flash, zone, SUPERBLOCK, max_active=28)
        rb = workloads.interference_benchmark(base, concurrency=conc)
        rs = workloads.interference_benchmark(sil, concurrency=conc)
        rows.append({"concurrency": conc,
                     "baseline": rb["interference"],
                     "silentzns": rs["interference"]})
    worst_base = max(r["baseline"] for r in rows)
    worst_sil = max(r["silentzns"] for r in rows)
    return {"rows": rows, "worst_baseline": worst_base,
            "worst_silentzns": worst_sil}


def fig7b_sa_dlwa_tradeoff(n_ops: int = 1_000_000) -> Dict:
    """Fig. 1 / 7b: SA rises as FINISH is delayed; baseline DLWA falls;
    SilentZNS keeps DLWA ~1 at every threshold."""
    flash, zone = zn540()
    rows = []
    for thr in (0.1, 0.3, 0.5, 0.7, 0.9):
        row = {"threshold": thr}
        for name, spec in (("baseline", FIXED), ("silentzns", SUPERBLOCK)):
            dev = ZNSDevice(flash, zone, spec, max_active=14)
            fs = ZoneFS(dev, finish_threshold=thr)
            sim = LSMSimulator(fs, KVBenchConfig(
                n_ops=n_ops, max_concurrent_jobs=6))
            rep = sim.run()
            row[f"{name}_dlwa"] = rep["dlwa"]
            row["sa"] = rep["sa"]   # host metric: identical across devices
        rows.append(row)
    lo, hi = rows[0], rows[-1]
    return {
        "rows": rows,
        "dlwa_reduction_at_low_thr":
            (lo["baseline_dlwa"] - lo["silentzns_dlwa"])
            / lo["baseline_dlwa"],
        "sa_increase_delaying_finish": hi["sa"] / lo["sa"] - 1.0,
        "paper_sa_increase": 0.69,
    }


def fig7c_wear(n_ops: int = 1_000_000, repeats: int = 4) -> Dict:
    """Fig. 7c: total erase counts under repeated KVBench (the paper
    repeats the workload 8x to accumulate wear)."""
    flash, zone = zn540()
    out = {}
    for name, spec, aware in (("baseline", FIXED, False),
                              ("silentzns", SUPERBLOCK, True)):
        dev = ZNSDevice(flash, zone, spec, max_active=14, wear_aware=aware)
        fs = ZoneFS(dev, finish_threshold=0.1)
        for rep_i in range(repeats):
            sim = LSMSimulator(fs, KVBenchConfig(
                n_ops=n_ops, seed=rep_i, max_concurrent_jobs=6))
            sim.run()
        rep = wear_report(dev)
        out[name] = rep
    return {
        "baseline_erases": out["baseline"]["total_incl_pending"],
        "silentzns_erases": out["silentzns"]["total_incl_pending"],
        "erase_reduction": 1 - out["silentzns"]["total_incl_pending"]
        / max(1, out["baseline"]["total_incl_pending"]),
    }


def fig7c_wear_leveling(rounds: int = 400) -> Dict:
    """Fig. 7c (distribution): isolate the leveling effect -- identical
    partial-fill churn under wear-aware SilentZNS vs the wear-oblivious
    first-fit baseline; compare the spread of per-block erase counts."""
    flash, zone = zn540()
    out = {}
    for name, aware in (("baseline", False), ("silentzns", True)):
        dev = ZNSDevice(flash, zone, SUPERBLOCK, max_active=14,
                        wear_aware=aware)
        for i in range(rounds):
            z = i % 8
            dev.zone_write(z, max(1, dev.zone_pages // 3))
            dev.zone_finish(z)
            dev.zone_reset(z)
        w = dev.block_wear() + 0.0
        worn = w  # include pending (a=3) wear implicitly via counts
        out[name] = {"max": float(w.max()), "std": float(w.std()),
                     "total": float(w.sum())}
    return {
        "baseline_max_wear": out["baseline"]["max"],
        "silentzns_max_wear": out["silentzns"]["max"],
        "baseline_std": out["baseline"]["std"],
        "silentzns_std": out["silentzns"]["std"],
    }


def fig8_geometry_sweep() -> Dict:
    """Fig. 8: pages finished across 6 zone geometries x 6 elements x
    occupancy."""
    flash = custom16()
    rows: List[Dict] = []
    for geom in PAPER_GEOMETRIES:
        for spec in ELEMENTS:
            if not is_applicable(spec, geom, flash):
                continue
            for occ in (0.0001, 0.1, 0.5, 0.9, 0.9999):
                dev = ZNSDevice(flash, geom, spec, max_active=32)
                r = workloads.dlwa_benchmark(dev, occupancy=occ, n_zones=2)
                rows.append({
                    "geometry": geom.describe(flash),
                    "element": spec.name, "occupancy": occ,
                    "dummy_pages_per_zone": r["dummy_pages_per_zone"],
                })
    # headline: fixed vs vchunk2 at P8,S128 occ ~0
    sel = {(r["geometry"], r["element"]): r["dummy_pages_per_zone"]
           for r in rows if r["occupancy"] == 0.0001}
    ratio = sel[("P8, S128", "fixed")] / max(1, sel[("P8, S128", "vchunk2")])
    return {"rows": rows, "fixed_over_vchunk2_P8S128": ratio,
            "paper_claim": 4.0}


def fig9_throughput() -> Dict:
    """Fig. 9: intra-zone bandwidth vs request size x concurrent zones."""
    flash = custom16()
    rows = []
    for P, segs in ((16, 1), (16, 2), (8, 1), (8, 2), (4, 1), (4, 2)):
        geom = ZoneGeometry(parallelism=P, n_segments=segs)
        for req_kib in (4, 16, 64):
            for jobs in (1, 2, 4, 8, 16):
                dev = ZNSDevice(flash, geom, FIXED, max_active=64)
                if jobs > dev.n_zones:
                    continue
                r = workloads.write_benchmark(dev, request_kib=req_kib,
                                              n_jobs=jobs, mib_per_job=4)
                rows.append({"geometry": geom.describe(flash),
                             "request_kib": req_kib, "jobs": jobs,
                             "mib_s": r["bandwidth_mib_s"]})
    by = {(r["geometry"], r["jobs"]) for r in rows}
    peak16 = max(r["mib_s"] for r in rows
                 if r["geometry"] == "P16, S128" and r["jobs"] == 1)
    p8_1 = max(r["mib_s"] for r in rows
               if r["geometry"] == "P8, S64" and r["jobs"] == 1)
    p8_2 = max(r["mib_s"] for r in rows
               if r["geometry"] == "P8, S64" and r["jobs"] == 2)
    return {"rows": rows, "peak_P16_1job": peak16,
            "P8_1job": p8_1, "P8_2jobs": p8_2}


def table3_interference() -> Dict:
    """Table 3: interference factor per geometry x element (conc 8 is the
    paper's setting; ZN540-style 40% fill)."""
    flash = custom16()
    rows = []
    for geom in PAPER_GEOMETRIES:
        row = {"geometry": geom.describe(flash)}
        for spec in ELEMENTS:
            if not is_applicable(spec, geom, flash):
                row[spec.name] = float("nan")
                continue
            dev = ZNSDevice(flash, geom, spec, max_active=64)
            conc = min(8, dev.n_zones // 2)
            r = workloads.interference_benchmark(dev, concurrency=conc)
            row[spec.name] = round(r["interference"], 2)
        rows.append(row)
    multi = [r for r in rows if r["geometry"] in ("P16, S256", "P8, S128")]
    gap = np.nanmean([r["fixed"] - r["vchunk2"] for r in multi])
    return {"rows": rows, "fixed_minus_vchunk2_multiseg": float(gap)}


def table4_alloc_latency() -> Dict:
    """Table 4: median zone-allocation latency per geometry x element.

    Ours is the vectorized JAX allocator (the paper used MOSEK): absolute
    numbers differ, the *ladder* (fixed << superblock < vchunk < block) is
    the reproduced structure."""
    flash = custom16()
    rows = []
    for geom in PAPER_GEOMETRIES:
        row = {"geometry": geom.describe(flash)}
        for spec in ELEMENTS:
            if not is_applicable(spec, geom, flash):
                row[spec.name] = float("nan")
                continue
            dev = ZNSDevice(flash, geom, spec, max_active=64)
            r = workloads.alloc_latency_benchmark(dev, n_allocs=16)
            row[spec.name] = round(r["median_us"], 1)
        rows.append(row)
    med = lambda k: float(np.nanmedian([r.get(k, float("nan"))
                                        for r in rows]))
    return {"rows": rows, "fixed_us": med("fixed"),
            "block_us": med("block"), "superblock_us": med("superblock")}
