"""Fleet allocator search: tenant-mix x geometry x spec x allocator.

Three strategies over the same :class:`repro.fleet.SearchSpace` (2
tenant mixes x 2 effective zone geometries x 2 stripe-chunk sizes x
parity on/off x wear-aware/first-fit x ``--specs`` element specs x
``--policies`` allocation policies, each
config expanded to ``--devices`` member lanes), all scored through the
shared batched :class:`repro.fleet.Evaluator`.  With more than one
element spec the engine is built over the padded *union* config, so a
mixed SUPERBLOCK+BLOCK+VCHUNK fleet still runs in ONE ``run_programs``
dispatch (per-lane ``DynConfig`` spec selection):

* ``--strategy grid``   -- the full cross product (96 configs on
  zn540 with the default 3-spec axis) in ONE batched ``run_programs``
  + ONE timing dispatch;
* ``--strategy random`` -- ``--random N`` seeded samples, one dispatch;
* ``--strategy evolve`` -- the adaptive searcher
  (:mod:`repro.fleet.evolve`): evolutionary proposals with a
  successive-halving rung schedule, one dispatch per rung, stopping
  early at ``--target`` if given.

Grid/random emit per-config rows scored on the weighted (DLWA, wear
spread, p99 tenant latency) objective plus the Pareto front; evolve
emits one row per generation (best-so-far objective + budget ledger)
plus the persistent Pareto archive.  Same ``name,us_per_call,derived``
CSV schema as ``benchmarks/run.py`` (via :class:`benchmarks.common.Bench`).
The front/archive is also written as JSON (``--out``, default
``fleet_pareto.json``)::

    PYTHONPATH=src python benchmarks/fleet_search.py [--quick]
        [--strategy {grid,random,evolve}] [--devices 4] [--seed S]
        [--random N] [--population K --generations G] [--target OBJ]
        [--specs superblock,block,vchunk2]
        [--policies traditional,silent] [--out fleet_pareto.json]

With ``--obs`` the run re-dispatches the Pareto-front configs (up to
``--obs-configs``) through the flight recorder (:mod:`repro.obs`) and
writes ``<prefix>_trace.json`` -- a Perfetto-loadable Chrome trace of
the fleet (tenant classes as named tracks, zone ops as duration
events) -- plus ``<prefix>_obs.json`` (telemetry timelines, metrics,
dispatch profile, recompile table; render it with
``tools/obs_report.py``).

The batched-vs-legacy speedup and the evolve-vs-random
dispatches-to-target comparison live in ``tools/bench.py`` (artifact
``BENCH_fleet.json``), not here.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

_ROOT = pathlib.Path(__file__).resolve().parent.parent
for _p in (str(_ROOT), str(_ROOT / "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

from benchmarks.common import Bench
from repro.core import zn540
from repro.core.elements import (BLOCK, SUPERBLOCK, ElementSpec, hchunk,
                                 vchunk)
from repro.core.engine import ZoneEngine
from repro.fleet import (Evaluator, EvolveParams, SearchSpace, evolve,
                         grid_space, pareto_front, random_space,
                         score_rows)

DERIVED_KEYS = ("dlwa", "wear_cv", "p99_latency_s", "makespan_s",
                "block_erases", "score", "pareto")


def parse_spec(name: str) -> ElementSpec:
    """``superblock`` / ``block`` / ``vchunkN`` / ``hchunkN`` -> spec
    (FIXED cannot join a per-lane union and is not accepted)."""
    name = name.strip().lower()
    if name == "superblock":
        return SUPERBLOCK
    if name == "block":
        return BLOCK
    for prefix, build in (("vchunk", vchunk), ("hchunk", hchunk)):
        if name.startswith(prefix) and name[len(prefix):].isdigit():
            return build(int(name[len(prefix):]))
    raise argparse.ArgumentTypeError(
        f"unknown element spec {name!r} (want superblock, block, "
        f"vchunkN or hchunkN)")


def emit_obs_artifacts(eng, configs, *, n_devices: int,
                       out_prefix: str = "fleet", n_buckets: int = 32,
                       meta: dict | None = None) -> dict:
    """Re-dispatch ``configs`` through the flight recorder and write
    the Perfetto trace + telemetry sidecar (``<out_prefix>_trace.json``
    / ``<out_prefix>_obs.json``).  The trace is schema-validated before
    returning; lanes are labeled ``<config>/dev<d>``.  Importable so
    tests drive it directly (the --obs acceptance path)."""
    from repro.fleet import N_TENANTS, build_fleet_batch, run_fleet
    from repro.fleet.runner import assert_all_ok
    from repro.obs import (ObsConfig, Profiler, RecompileCounter,
                           emit_fleet_obs)

    programs, dyn, _ = build_fleet_batch(eng, configs,
                                         n_devices=n_devices)
    obs = ObsConfig(n_buckets=n_buckets, n_tenants=N_TENANTS + 1)
    prof = Profiler()
    res = run_fleet(eng, programs, dyn=dyn, n_tenants=N_TENANTS,
                    obs=obs, profiler=prof)
    assert_all_ok(res)
    labels = [f"{fc.describe()}/dev{d}"
              for fc in configs for d in range(n_devices)]
    return emit_fleet_obs(
        res, eng, obs=obs, out_prefix=out_prefix, lane_labels=labels,
        profiler=prof, recompiles=RecompileCounter.engine_default(),
        meta={"n_configs": len(configs), "n_devices": n_devices,
              **(meta or {})})


def run_enumerative(args, eng, axes, n_devices, b: Bench) -> dict:
    """grid / random: one batched dispatch, Pareto front of the rows."""
    configs = (random_space(args.seed, args.random, **axes)
               if args.strategy == "random" else grid_space(**axes))
    t0 = time.perf_counter()
    ev = Evaluator(eng, n_devices=n_devices, weights=tuple(args.weights))
    rows = ev.evaluate(configs)
    total_us = (time.perf_counter() - t0) * 1e6
    rows = score_rows(rows, weights=tuple(args.weights))
    front = pareto_front(rows)

    per_config_us = total_us / len(rows)
    for r in rows:
        b.add(f"fleet_{r['config']}", per_config_us,
              ";".join(f"{k}={r[k]:.4g}" for k in DERIVED_KEYS))
    b.add("fleet_search_total", total_us,
          f"n_configs={len(rows)};n_devices={n_devices};"
          f"strategy={args.strategy};"
          f"dispatches={ev.n_dispatches:.0f}")
    b.add("pareto_front", 0.0, ";".join(r["config"] for r in front))
    return {
        "strategy": args.strategy,
        "weights": list(args.weights),
        "n_configs": len(rows),
        "n_devices": n_devices,
        "ledger": ev.ledger(),
        "front": front,
        "best_by_score": rows[0],
    }


def run_evolve(args, eng, axes, n_devices, b: Bench) -> dict:
    """Adaptive search: one row per generation + the Pareto archive."""
    space = SearchSpace(**{k: tuple(v) for k, v in axes.items()})
    params = EvolveParams(population=args.population,
                          generations=args.generations)
    t0 = time.perf_counter()
    res = evolve(eng, space=space, params=params, seed=args.seed,
                 n_devices=n_devices, weights=tuple(args.weights),
                 target=args.target)
    total_us = (time.perf_counter() - t0) * 1e6
    for h in res.history:
        b.add(f"evolve_gen{h['generation']}",
              total_us / len(res.history),
              f"best_so_far={h['best_so_far']:.4g};"
              f"best_of_gen={h['best_of_gen']:.4g};"
              f"dispatches={h['n_dispatches']:.0f};"
              f"evals={h['n_evals']:.3g};lane_ops={h['lane_ops']:.0f}")
    b.add("evolve_total", total_us,
          f"generations={len(res.history)};population={params.population};"
          f"best={res.best['config']};"
          f"best_objective={res.history[-1]['best_so_far']:.4g};"
          f"reached_target={res.reached_target}")
    b.add("pareto_front", 0.0,
          ";".join(r["config"] for r in res.archive))
    return {
        "strategy": "evolve",
        "weights": list(args.weights),
        "seed": args.seed,
        "n_devices": n_devices,
        "params": {"population": params.population,
                   "generations": params.generations,
                   "rung_fidelities": list(params.rung_fidelities),
                   "eta": params.eta},
        "ledger": res.ledger,
        "history": res.history,
        "front": res.archive,
        "best_by_score": res.best,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--strategy", choices=("grid", "random", "evolve"),
                    default="grid")
    ap.add_argument("--devices", type=int, default=4)
    ap.add_argument("--random", type=int, default=0,
                    help="sample N random configs (implies --strategy "
                         "random; `--strategy random` alone samples "
                         "as many configs as the grid holds)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--population", type=int, default=8,
                    help="evolve: candidates per generation")
    ap.add_argument("--generations", type=int, default=4)
    ap.add_argument("--target", type=float, default=None,
                    help="evolve: stop once the objective reaches this")
    ap.add_argument("--weights", type=float, nargs=3,
                    default=(1.0, 1.0, 1.0),
                    metavar=("W_DLWA", "W_WEAR", "W_P99"))
    ap.add_argument("--specs", type=str,
                    default="superblock,block,vchunk2",
                    help="comma-separated element-spec axis; >1 spec "
                         "builds the padded union engine (mixed-spec "
                         "lanes, one dispatch)")
    ap.add_argument("--policies", type=str, default="traditional",
                    help="comma-separated alloc_policy axis "
                         "(traditional and/or silent); 'silent' lanes "
                         "commit zone blocks on the fly (SilentZNS)")
    ap.add_argument("--workload", choices=("lsm", "ckpt", "cache"),
                    default=None,
                    help="score configs against recorded application "
                         "traffic (trace compiler): restrict the "
                         "tenant-mix axis to this workload's compiled "
                         "programs and write the per-tenant-class p99 "
                         "predictability report "
                         "(fleet_workload_<name>.json)")
    ap.add_argument("--out", type=str, default="fleet_pareto.json",
                    help="Pareto front JSON ('' to skip)")
    ap.add_argument("--obs", action="store_true",
                    help="flight-record the Pareto front: write a "
                         "Perfetto trace + telemetry sidecar")
    ap.add_argument("--obs-prefix", type=str, default="fleet",
                    help="--obs artifact prefix (<prefix>_trace.json, "
                         "<prefix>_obs.json)")
    ap.add_argument("--obs-configs", type=int, default=8,
                    help="--obs: at most this many front configs")
    ap.add_argument("--quick", action="store_true",
                    help="smaller axes (CI smoke): 8 configs, 3 devices")
    args = ap.parse_args()
    try:
        specs = tuple(parse_spec(s) for s in args.specs.split(","))
    except argparse.ArgumentTypeError as exc:
        ap.error(str(exc))   # clean usage error, not a raw traceback
    policies = tuple(p.strip() for p in args.policies.split(",")
                     if p.strip())
    bad = [p for p in policies if p not in ("traditional", "silent")]
    if bad or not policies:
        ap.error(f"--policies must name traditional and/or silent, "
                 f"got {args.policies!r}")
    if "silent" in policies and any(s.name == "fixed" for s in specs):
        ap.error("--policies silent cannot combine with --specs fixed "
                 "(FIXED elements have no block collection to vary)")
    if args.random and args.strategy == "grid":
        args.strategy = "random"
    if args.strategy == "random" and args.random < 1:
        # the grid's size
        args.random = len(grid_space(specs=specs, policies=policies))

    flash, zone = zn540()
    if args.quick:
        specs = specs[:1]
        axes = dict(segments=(22, 11), chunks=(1536,), parities=(False,),
                    wear=(True, False), specs=specs, policies=policies)
        n_devices = 3
    else:
        axes = dict(specs=specs, policies=policies)
        n_devices = args.devices
    if args.workload:
        import repro.storage  # noqa: F401  registers the workload mixes
        axes["mixes"] = (args.workload,)
    eng = ZoneEngine(flash, zone, specs if len(specs) > 1 else specs[0],
                     max_active=14)

    b = Bench()
    if args.strategy == "evolve":
        report = run_evolve(args, eng, axes, n_devices, b)
    else:
        report = run_enumerative(args, eng, axes, n_devices, b)
    b.emit()

    if args.out:
        pathlib.Path(args.out).write_text(
            json.dumps(report, indent=2) + "\n")
        print(f"# wrote {args.out} ({len(report['front'])} Pareto "
              f"configs)", file=sys.stderr)

    if args.workload:
        # the class-tagged dispatch: the same recorded traffic the
        # search scored, re-run with per-traffic-class tenant tags so
        # p99 predictability is attributable per stream (CI artifact)
        from repro.storage import run_workload
        _, wrep = run_workload(eng, args.workload, seed=args.seed)
        wrep.update(strategy=args.strategy, seed=args.seed,
                    best_by_score=report["best_by_score"]["config"])
        wpath = pathlib.Path(f"fleet_workload_{args.workload}.json")
        wpath.write_text(json.dumps(wrep, indent=2) + "\n")
        worst = max(v["p99_over_p50"]
                    for v in wrep["tenant_classes"].values())
        print(f"# wrote {wpath} (worst class p99/p50 = {worst:.2f})",
              file=sys.stderr)

    if args.obs:
        from repro.fleet import FleetConfig  # noqa: F401  (front rows)
        front_names = [r["config"] for r in report["front"]]
        all_axes = grid_space(**axes)
        by_name = {fc.describe(): fc for fc in all_axes}
        obs_configs = [by_name[n] for n in front_names
                       if n in by_name][: args.obs_configs]
        if not obs_configs:        # e.g. an empty front: record best
            obs_configs = all_axes[:1]
        paths = emit_obs_artifacts(
            eng, obs_configs, n_devices=n_devices,
            out_prefix=args.obs_prefix,
            meta={"strategy": args.strategy, "seed": args.seed,
                  "specs": ",".join(s.name for s in specs)})
        print(f"# wrote {paths['trace']} ({paths['n_events']} events) "
              f"and {paths['obs']}", file=sys.stderr)


if __name__ == "__main__":
    main()
