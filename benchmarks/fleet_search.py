"""Fleet allocator search: tenant-mix x geometry x allocator, one dispatch.

Evaluates the full :func:`repro.fleet.search.grid_space` (32 configs on
zn540 by default: 2 tenant mixes x 2 effective zone geometries x 2
stripe-chunk sizes x parity on/off x wear-aware/first-fit allocator,
each expanded to ``--devices`` member lanes) through ONE batched
``run_programs`` dispatch + ONE batched op-granular timing dispatch,
scores the weighted (DLWA, wear spread, p99 tenant latency) objective,
and emits the Pareto front.

Same ``name,us_per_call,derived`` CSV schema as ``benchmarks/run.py``
(via :class:`benchmarks.common.Bench`): one row per config plus
``fleet_search_total`` and ``pareto_front`` summary rows.  The front is
also written as JSON (``--out``, default ``fleet_pareto.json``)::

    PYTHONPATH=src python benchmarks/fleet_search.py [--quick]
        [--devices 4] [--random N --seed S] [--out fleet_pareto.json]

``--random N`` swaps the grid for N seeded random samples (deterministic
per seed).  The batched-vs-legacy speedup lives in ``tools/bench.py``
(artifact ``BENCH_fleet.json``), not here.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

_ROOT = pathlib.Path(__file__).resolve().parent.parent
for _p in (str(_ROOT), str(_ROOT / "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

from benchmarks.common import Bench
from repro.core import zn540
from repro.core.elements import SUPERBLOCK
from repro.core.engine import ZoneEngine
from repro.fleet import (evaluate_configs, grid_space, pareto_front,
                         random_space, score_rows)

DERIVED_KEYS = ("dlwa", "wear_cv", "p99_latency_s", "makespan_s",
                "block_erases", "score", "pareto")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--devices", type=int, default=4)
    ap.add_argument("--random", type=int, default=0,
                    help="sample N random configs instead of the grid")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--weights", type=float, nargs=3,
                    default=(1.0, 1.0, 1.0),
                    metavar=("W_DLWA", "W_WEAR", "W_P99"))
    ap.add_argument("--out", type=str, default="fleet_pareto.json",
                    help="Pareto front JSON ('' to skip)")
    ap.add_argument("--quick", action="store_true",
                    help="smaller axes (CI smoke): 8 configs, 3 devices")
    args = ap.parse_args()

    flash, zone = zn540()
    eng = ZoneEngine(flash, zone, SUPERBLOCK, max_active=14)
    if args.quick:
        axes = dict(segments=(22, 11), chunks=(1536,), parities=(False,),
                    wear=(True, False))
        n_devices = 3
    else:
        axes = {}
        n_devices = args.devices
    configs = (random_space(args.seed, args.random, **axes)
               if args.random else grid_space(**axes))

    b = Bench()
    t0 = time.perf_counter()
    rows = evaluate_configs(eng, configs, n_devices=n_devices)
    total_us = (time.perf_counter() - t0) * 1e6
    rows = score_rows(rows, weights=tuple(args.weights))
    front = pareto_front(rows)

    per_config_us = total_us / len(rows)
    for r in rows:
        b.add(f"fleet_{r['config']}", per_config_us,
              ";".join(f"{k}={r[k]:.4g}" for k in DERIVED_KEYS))
    b.add("fleet_search_total", total_us,
          f"n_configs={len(rows)};n_devices={n_devices};"
          f"batched_dispatches=2")
    b.add("pareto_front", 0.0,
          ";".join(r["config"] for r in front))
    b.emit()

    if args.out:
        pathlib.Path(args.out).write_text(json.dumps({
            "weights": list(args.weights),
            "n_configs": len(rows),
            "n_devices": n_devices,
            "front": front,
            "best_by_score": rows[0],
        }, indent=2) + "\n")
        print(f"# wrote {args.out} ({len(front)} Pareto configs)",
              file=sys.stderr)


if __name__ == "__main__":
    main()
