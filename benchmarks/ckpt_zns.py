"""Framework-level benchmark: checkpoint traffic through the zoned store.

For each assigned architecture, model one checkpoint epoch: params(+opt)
shards written as files with lifetime hints, old checkpoints rotated out.
Reports DLWA and write-makespan under baseline vs SilentZNS devices --
the training-cluster version of the paper's RocksDB experiment, and the
quantity that decides checkpoint cadence on a real fleet.
"""

from __future__ import annotations

from typing import Dict

import numpy as np

from repro.configs import get_arch, list_archs
from repro.core import FIXED, SUPERBLOCK, ZNSDevice, zn540
from repro.core import timing
from repro.models import model as MDL
from repro.storage import ZoneFS

#: bytes per host: a 256-chip pod, params+opt sharded -> per-host share.
HOSTS = 64


def checkpoint_traffic(arch: str, *, keep: int = 2, epochs: int = 6
                       ) -> Dict:
    cfg = get_arch(arch)
    n_params = MDL.param_count(cfg)
    ckpt_bytes_per_host = n_params * (2 + 8) / HOSTS   # bf16 + f32 mu/nu
    out = {"arch": arch, "ckpt_gib_per_host": ckpt_bytes_per_host / 2**30}
    for name, spec in (("baseline", FIXED), ("silentzns", SUPERBLOCK)):
        flash, zone = zn540()
        dev = ZNSDevice(flash, zone, spec, max_active=14)
        fs = ZoneFS(dev, finish_threshold=0.1)
        pages = max(1, int(ckpt_bytes_per_host // flash.page_bytes))
        # shard files ~1 GiB each (object-store style)
        shard_pages = max(1, (2**30) // flash.page_bytes)
        fid = 0
        live = []
        for ep in range(epochs):
            shards = []
            rem = pages
            while rem > 0:
                fid += 1
                n = min(shard_pages, rem)
                if not fs.create(fid, n, lifetime=2):
                    break
                shards.append(fid)
                rem -= n
            live.append(shards)
            if len(live) > keep:
                for old in live.pop(0):
                    fs.delete(old)
        rep = fs.report()
        out[f"{name}_dlwa"] = rep["dlwa"]
        out[f"{name}_dummy_pages"] = rep["dummy_pages"]
    out["dlwa_reduction"] = 1 - (out["silentzns_dlwa"]
                                 / max(1e-9, out["baseline_dlwa"]))
    return out


def run_all() -> Dict:
    rows = [checkpoint_traffic(a) for a in list_archs()]
    return {
        "rows": rows,
        "mean_dlwa_reduction": float(np.mean(
            [r["dlwa_reduction"] for r in rows])),
        "worst_baseline_dlwa": max(r["baseline_dlwa"] for r in rows),
    }
