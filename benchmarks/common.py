"""Shared benchmark plumbing: timing + CSV emission."""

from __future__ import annotations

import time
from typing import Callable, Dict, Iterable, List, Tuple


class Bench:
    """Collects (name, us_per_call, derived) rows for benchmarks.run."""

    def __init__(self):
        self.rows: List[Tuple[str, float, str]] = []

    def timeit(self, name: str, fn: Callable[[], Dict], derived_keys=()):
        t0 = time.perf_counter()
        out = fn() or {}
        us = (time.perf_counter() - t0) * 1e6
        derived = ";".join(f"{k}={out[k]:.4g}" if isinstance(out[k], float)
                           else f"{k}={out[k]}"
                           for k in derived_keys if k in out)
        self.rows.append((name, us, derived))
        return out

    def add(self, name: str, us: float, derived: str = ""):
        self.rows.append((name, us, derived))

    def emit(self) -> None:
        for name, us, derived in self.rows:
            print(f"{name},{us:.1f},{derived}")
