"""ZNS-RAID in 60 lines: one workload, one device vs an 8-device fleet.

Because ``ZoneFS`` talks to the :class:`repro.core.backend.ZoneBackend`
protocol, the same LSM traffic mounts unchanged on a bare ``ZNSDevice``
or a ``ZNSArray`` with log-structured parity; the array adds degraded
reads and a vmapped fleet-timing path.

    PYTHONPATH=src python examples/raid_array.py
"""

import numpy as np

from repro.array import ZNSArray
from repro.core import SUPERBLOCK, timing, zn540, ZNSDevice
from repro.storage import KVBenchConfig, LSMSimulator, ZoneFS


def lsm_over(backend) -> dict:
    fs = ZoneFS(backend, finish_threshold=0.1)
    sim = LSMSimulator(fs, KVBenchConfig(n_ops=300_000))
    return sim.run()


def main() -> None:
    flash, zone = zn540()

    print("same LSM workload, two backends (ZoneBackend protocol):")
    dev_rep = lsm_over(ZNSDevice(flash, zone, SUPERBLOCK, max_active=14))
    arr = ZNSArray.build(flash, zone, SUPERBLOCK, n_devices=8,
                         parity=True, max_active=14)
    arr_rep = lsm_over(arr)
    print(f"  1x ZNSDevice : dlwa={dev_rep['dlwa']:.3f} "
          f"sa={dev_rep['sa']:.3f}")
    print(f"  8x ZNSArray+P: dlwa={arr_rep['dlwa']:.3f} "
          f"sa={arr_rep['sa']:.3f} "
          f"(parity overhead folded into array DLWA)")

    print("\nper-device rollup (first 4 members):")
    for r in arr.device_reports()[:4]:
        print(f"  dev{int(r['device'])}: dlwa={r['dlwa']:.3f} "
              f"erases={int(r['total_block_erases'])} "
              f"max_wear={int(r['max_wear'])}")

    print("\ndegraded read: fail device 2, reconstruct from survivors")
    arr2 = ZNSArray.build(flash, zone, SUPERBLOCK, n_devices=4, parity=True)
    arr2.zone_write(0, arr2.zone_pages)
    arr2.fail_device(2)
    reads = arr2.zone_read(0, np.arange(4 * arr2.geom.chunk_pages))
    for idx, tr in reads:
        print(f"  dev{idx}: {len(tr.luns)} page reads")

    print("\nfleet timing: 8 devices in one vmapped scan")
    arr3 = ZNSArray.build(flash, zone, SUPERBLOCK, n_devices=8, parity=True)
    tagged = arr3.zone_write(0, arr3.zone_pages // 2, trace=True)
    tagged += arr3.zone_finish(0, trace=True) or []
    fleet = timing.run_fleet_trace(arr3.flash, timing.group_tagged(tagged, 8))
    print(f"  fleet makespan: {fleet['fleet_makespan_s'] * 1e3:.2f} ms "
          f"over {fleet['n']} page ops")


if __name__ == "__main__":
    main()
