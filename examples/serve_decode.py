"""Batched serving example: prefill + decode for any assigned arch.

    PYTHONPATH=src python examples/serve_decode.py [arch]

Defaults to the xLSTM (recurrent decode path); try e.g.
``deepseek-v2-236b`` to exercise the MLA absorbed-decode path (reduced
config on CPU).
"""

import sys

from repro.launch import serve


def main() -> None:
    arch = sys.argv[1] if len(sys.argv) > 1 else "xlstm-125m"
    sys.argv = [sys.argv[0], "--arch", arch, "--reduced", "--batch", "4",
                "--prompt-len", "24", "--decode-tokens", "8"]
    serve.main()


if __name__ == "__main__":
    main()
