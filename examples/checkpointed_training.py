"""End-to-end driver: fault-tolerant training on a ZNS-backed store.

Trains a reduced assigned architecture with the full substrate --
sharded-ready params, AdamW, deterministic data, async checkpoints whose
traffic flows through the emulated zoned device -- then *kills the job*
mid-run and restarts it, proving bit-exact resumption, and prints the
storage telemetry the paper is about (DLWA of the checkpoint store under
baseline vs SilentZNS zone management).

    PYTHONPATH=src python examples/checkpointed_training.py
"""

import shutil
import tempfile

import jax
import numpy as np

from repro.configs import get_arch
from repro.core import FIXED, SUPERBLOCK
from repro.models import model as MDL
from repro.models import transformer as T
from repro.train import optimizer as OPT
from repro.train.checkpoint import CheckpointManager, ZNSTelemetry
from repro.train.data import SyntheticLM
from repro.train.loop import LoopConfig, fit


def run(arch: str = "granite-3-8b", steps: int = 30) -> None:
    cfg = get_arch(arch).reduced()
    print(f"[e2e] {cfg.name} reduced: {MDL.param_count(cfg)/1e6:.2f}M "
          f"params")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    opt_cfg = OPT.AdamWConfig(lr=1e-3, total_steps=steps, warmup_steps=3)
    train_step = jax.jit(MDL.make_train_step(cfg, opt_cfg))
    data = SyntheticLM(vocab=cfg.vocab, batch=8, seq=64, seed=0)

    workdir = tempfile.mkdtemp(prefix="zns_ckpt_")
    try:
        for elem_name, elem in (("SilentZNS/superblock", SUPERBLOCK),
                                ("baseline/fixed", FIXED)):
            shutil.rmtree(workdir, ignore_errors=True)
            zns = ZNSTelemetry(element=elem, finish_threshold=0.1)
            ckpt = CheckpointManager(workdir, keep=2, async_save=False,
                                     zns=zns)
            # phase 1: crash mid-run
            cfg1 = LoopConfig(total_steps=steps, ckpt_every=5,
                              fail_at_step=steps // 2)
            try:
                fit(train_step, params, OPT.init(params), data, ckpt, cfg1)
            except RuntimeError as e:
                print(f"[e2e] {elem_name}: simulated crash ({e})")
            # phase 2: restart -- restores from the last atomic manifest
            cfg2 = LoopConfig(total_steps=steps, ckpt_every=5)
            res = fit(train_step, params, OPT.init(params), data, ckpt,
                      cfg2)
            print(f"[e2e] {elem_name}: resumed from step "
                  f"{res.restored_from}, finished at {res.final_step}, "
                  f"loss {res.losses[-1]:.3f}")
            rep = zns.report()
            print(f"[e2e] {elem_name}: ckpt-store DLWA={rep['dlwa']:.3f} "
                  f"SA={rep['sa']:.2f} finishes={rep['finishes']:.0f} "
                  f"resets={rep['resets']:.0f} "
                  f"dummy_pages={rep['dummy_pages']:.0f}")
    finally:
        shutil.rmtree(workdir, ignore_errors=True)


if __name__ == "__main__":
    run()
