"""Quickstart: the paper's headline result in 40 lines.

Builds the emulated WD ZN540, fills zones to varying occupancy, FINISHes
them, and compares device-level write amplification between the fixed-zone
baseline (ConfZNS++) and SilentZNS superblock allocation.

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import FIXED, SUPERBLOCK, ZNSDevice, zn540
from repro.core.workloads import dlwa_benchmark


def main() -> None:
    flash, zone = zn540()
    print(f"device: {flash.n_luns} LUNs, "
          f"{zone.zone_bytes(flash) / 2**20:.0f} MiB zones\n")
    print(f"{'occupancy':>10} {'baseline DLWA':>14} {'SilentZNS DLWA':>15} "
          f"{'reduction':>10}")
    for occ in (0.1, 0.25, 0.5, 0.75, 0.9):
        base = ZNSDevice(flash, zone, FIXED)
        silent = ZNSDevice(flash, zone, SUPERBLOCK)
        rb = dlwa_benchmark(base, occupancy=occ, n_zones=4)
        rs = dlwa_benchmark(silent, occupancy=occ, n_zones=4)
        red = (rb["dlwa"] - rs["dlwa"]) / rb["dlwa"]
        print(f"{occ:>10.0%} {rb['dlwa']:>14.2f} {rs['dlwa']:>15.2f} "
              f"{red:>10.1%}")
    print("\npaper §6.2: 'reducing DLWA by up to 86.36% (10% zone "
          "occupancy with the superblock configuration)'")


if __name__ == "__main__":
    main()
