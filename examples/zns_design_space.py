"""Explore the augmented ZNS design space (paper §4/§6.3 + Table 5).

Sweeps zone geometry x storage element on the paper's custom 16-LUN SSD
and prints, per configuration: DLWA at low occupancy, interference under
concurrent FINISH, and allocation latency -- then echoes the paper's
per-use-case recommendations (Table 5).

    PYTHONPATH=src python examples/zns_design_space.py
"""

from repro.core import (BLOCK, FIXED, PAPER_GEOMETRIES, SUPERBLOCK,
                        ZNSDevice, custom16, hchunk, is_applicable, vchunk)
from repro.core.workloads import (alloc_latency_benchmark, dlwa_benchmark,
                                  interference_benchmark)

ELEMENTS = (FIXED, SUPERBLOCK, BLOCK, vchunk(2), vchunk(4), hchunk(2))

RECOMMENDATIONS = """
paper Table 5 -- how to pick a configuration:
  (A) WAL / OLTP logs           -> block/Vchunk-2, small zones, early FINISH
  (B) LSM flushes / minor comp. -> superblock/Vchunk-4, medium zones
  (C) large compactions/ingest  -> superblock/Vchunk-4, large zones
  (D) mixed-lifetime ZenFS data -> block/Vchunk-2, small zones, early FINISH
  (E) read-mostly               -> superblock/Vchunk-4, large zones
"""


def main() -> None:
    flash = custom16()
    print(f"{'geometry':>10} {'element':>11} {'DLWA@10%':>9} "
          f"{'interf.':>8} {'alloc us':>9}")
    for geom in PAPER_GEOMETRIES:
        for spec in ELEMENTS:
            if not is_applicable(spec, geom, flash):
                continue
            dev = ZNSDevice(flash, geom, spec, max_active=64)
            d = dlwa_benchmark(dev, occupancy=0.10, n_zones=2)
            dev2 = ZNSDevice(flash, geom, spec, max_active=64)
            i = interference_benchmark(
                dev2, concurrency=min(4, dev2.n_zones // 2))
            dev3 = ZNSDevice(flash, geom, spec, max_active=64)
            a = alloc_latency_benchmark(dev3, n_allocs=8)
            print(f"{geom.describe(flash):>10} {spec.name:>11} "
                  f"{d['dlwa']:>9.2f} {i['interference']:>8.2f} "
                  f"{a['median_us']:>9.1f}")
    print(RECOMMENDATIONS)


if __name__ == "__main__":
    main()
