"""Fleet search in 60 lines: find the Pareto-optimal zone allocation.

Two tenant mixes x two effective zone geometries x two stripe chunks x
parity x allocator policy = 32 fleet configurations, every one expanded
to 4 member devices and all 128 lanes executed in ONE batched
``run_programs`` dispatch (heterogeneous geometries ride per-lane
``DynConfig`` overrides on the shared padded static config).  Configs
are scored on the weighted (DLWA, wear spread, p99 tenant latency)
objective; the Pareto front is the design-space answer the paper argues
an allocator should search for.

The coda runs the adaptive searcher (:mod:`repro.fleet.evolve`) against
the same space: evolutionary proposals + successive-halving rungs,
stopping as soon as it matches the grid's best objective -- with a
fraction of the dispatched evaluator budget.

    PYTHONPATH=src python examples/fleet.py
"""

import time

from repro.core import SUPERBLOCK, zn540
from repro.core.engine import ZoneEngine
from repro.fleet import (Evaluator, EvolveParams, SearchSpace, evolve,
                         evaluate_configs, grid_space, pareto_front,
                         score_rows)


def main() -> None:
    flash, zone = zn540()
    eng = ZoneEngine(flash, zone, SUPERBLOCK, max_active=14)
    configs = grid_space()

    t0 = time.perf_counter()
    rows = evaluate_configs(eng, configs, n_devices=4)
    dt = time.perf_counter() - t0
    rows = score_rows(rows)
    front = pareto_front(rows)
    print(f"evaluated {len(rows)} configs x 4 devices in {dt:.2f}s "
          f"(2 batched dispatches)\n")

    print("best 5 by weighted score (dlwa + wear_cv + p99, lower=better):")
    for r in rows[:5]:
        mark = "*" if r["pareto"] else " "
        print(f" {mark} {r['config']:<28} dlwa={r['dlwa']:.4f} "
              f"wear_cv={r['wear_cv']:.2f} "
              f"p99={r['p99_latency_s']:.2f}s score={r['score']:.3f}")

    print(f"\nPareto front ({len(front)} non-dominated configs):")
    for r in front:
        print(f"   {r['config']:<28} dlwa={r['dlwa']:.4f} "
              f"wear_cv={r['wear_cv']:.2f} p99={r['p99_latency_s']:.2f}s")

    best_dlwa = min(rows, key=lambda r: r["dlwa"])
    best_p99 = min(rows, key=lambda r: r["p99_latency_s"])
    best_wear = min(rows, key=lambda r: r["wear_cv"])
    print(f"\nthe trade-off the paper argues an allocator must search:")
    print(f"  lowest DLWA  : {best_dlwa['config']:<28} "
          f"dlwa={best_dlwa['dlwa']:.4f} (p99={best_dlwa['p99_latency_s']:.2f}s)")
    print(f"  lowest p99   : {best_p99['config']:<28} "
          f"p99={best_p99['p99_latency_s']:.2f}s (dlwa={best_p99['dlwa']:.4f})")
    print(f"  evenest wear : {best_wear['config']:<28} "
          f"wear_cv={best_wear['wear_cv']:.2f} (dlwa={best_wear['dlwa']:.4f})")
    print(f"  equal-weight winner: {rows[0]['config']}")

    # -- adaptive search: match the grid's best with a fraction of the
    # budget (grid = 32 full-fidelity evals in 1 dispatch) ------------- #
    ref = Evaluator(eng, n_devices=4)
    target = min(ref.objective(r) for r in rows)
    t0 = time.perf_counter()
    res = evolve(eng, space=SearchSpace(), seed=0, n_devices=4,
                 params=EvolveParams(population=8, generations=4),
                 target=target)
    dt = time.perf_counter() - t0
    led = res.ledger
    print(f"\nadaptive search (evolve, pop 8, halving rungs "
          f"{EvolveParams().rung_fidelities}):")
    for h in res.history:
        print(f"   gen {h['generation']}: best_so_far="
              f"{h['best_so_far']:.4f} after {h['n_evals']:.1f} "
              f"full-fidelity-equivalent evals "
              f"({h['n_dispatches']:.0f} dispatches)")
    print(f"   {'matched' if res.reached_target else 'missed'} the "
          f"grid-best objective {target:.4f} with "
          f"{led['n_evals']:.1f}/32 evals in {dt:.2f}s; "
          f"archive={len(res.archive)} Pareto configs")


if __name__ == "__main__":
    main()
