"""Unit tests for the ``tools/bench.py`` paper-headline gate logic.

``check_paper_gates`` is a pure function of the ``BENCH_paper.json``
artifact dict, so the pass/fail semantics (and the stderr WARNING
surface CI greps) are testable without running any benchmark: a
synthetic failing section must exit non-zero, a passing one zero.
The ``tools/bench_table.py`` paper rows are checked against the same
synthetic artifacts (including the pre-schema re-run message).
"""

import importlib.util
import json
import pathlib
import sys

import pytest

_ROOT = pathlib.Path(__file__).resolve().parent.parent


def _load(name, path):
    spec = importlib.util.spec_from_file_location(name, path)
    mod = importlib.util.module_from_spec(spec)
    sys.modules.setdefault(name, mod)
    spec.loader.exec_module(mod)
    return mod


bench = _load("_bench_under_test", _ROOT / "tools" / "bench.py")
bench_table = _load("_bench_table_under_test",
                    _ROOT / "tools" / "bench_table.py")


def _artifact(**over):
    """A minimal passing BENCH_paper.json artifact; keyword overrides
    replace whole sections."""
    art = {
        "dlwa": {"reduction_at_10pct": 0.86,
                 "occupancies": [0.1, 0.3], "n_zones": 4.0,
                 "traditional_dlwa": [10.0, 3.3],
                 "silent_dlwa": [1.36, 1.06],
                 "dlwa_reduction": [0.86, 0.68]},
        "wear": {"wear_reduction": 0.68, "occupancy": 0.3,
                 "n_zones": 8.0, "cycles": 8.0,
                 "traditional_erases": 2816.0, "silent_erases": 896.0},
        "exec": {"speedup": 3.14, "occupancy": 0.3, "n_zones": 8.0,
                 "cycles": 4.0, "traditional_s": 392.0,
                 "silent_s": 124.7, "host_pages": 162201.0},
        "recompiles": {"delta_total": 0.0, "entries": {}, "delta": {}},
        "meta": {"schema_version": bench.SCHEMA_VERSION},
    }
    art.update(over)
    return art


def test_passing_artifact_exits_zero(capsys):
    assert bench.check_paper_gates(_artifact()) == 0
    assert capsys.readouterr().err == ""


@pytest.mark.parametrize("section,bad,phrase", [
    ("dlwa", {"reduction_at_10pct": 0.79}, "DLWA reduction"),
    ("wear", {"wear_reduction": 0.0}, "no wear"),
    ("wear", {"wear_reduction": -0.1}, "no wear"),
    ("exec", {"speedup": 1.0}, "execution speedup"),
    ("exec", {"speedup": 0.8}, "execution speedup"),
    ("recompiles", {"delta_total": 2.0}, "recompiled"),
])
def test_failing_section_exits_nonzero(capsys, section, bad, phrase):
    art = _artifact()
    art[section] = {**art[section], **bad}
    assert bench.check_paper_gates(art) == 1
    err = capsys.readouterr().err
    assert err.startswith("WARNING:") and phrase in err


def test_gate_floors_are_inclusive_exclusive_as_documented(capsys):
    """The DLWA floor is inclusive (>= 80%); wear and speedup floors
    are strict (> 0, > 1x)."""
    art = _artifact()
    art["dlwa"]["reduction_at_10pct"] = bench.PAPER_DLWA_REDUCTION_FLOOR
    assert bench.check_paper_gates(art) == 0
    capsys.readouterr()


def test_every_failed_gate_gets_its_own_warning(capsys):
    art = _artifact(
        dlwa={**_artifact()["dlwa"], "reduction_at_10pct": 0.1},
        wear={**_artifact()["wear"], "wear_reduction": -0.5},
        exec={**_artifact()["exec"], "speedup": 0.5},
        recompiles={"delta_total": 3.0})
    assert bench.check_paper_gates(art) == 1
    warnings = [ln for ln in capsys.readouterr().err.splitlines()
                if ln.startswith("WARNING:")]
    assert len(warnings) == 4


def test_paper_report_feeds_the_gates_end_to_end(capsys):
    """`headline.paper_report` at tiny geometry produces exactly the
    artifact surface `check_paper_gates` consumes (whether tiny
    geometry clears the zn540-calibrated floors is not the point), and
    its own recompile probe must read zero."""
    from repro.core import headline
    from repro.core.geometry import FlashGeometry, ZoneGeometry

    flash = FlashGeometry(n_channels=4, ways_per_channel=1,
                          blocks_per_lun=8, pages_per_block=4,
                          page_bytes=4096)
    rep = headline.paper_report(
        flash, ZoneGeometry(parallelism=4, n_segments=2),
        occupancies=(0.1, 0.5), dlwa_zones=2, wear_zones=2,
        wear_cycles=2, exec_cycles=1, max_active=3)
    assert rep["recompiles"]["delta_total"] == 0
    assert rep["dlwa"]["reduction_at_10pct"] \
        == rep["dlwa"]["dlwa_reduction"][0]
    assert rep["wear"]["traditional_erases"] > rep["wear"]["silent_erases"]
    assert rep["exec"]["speedup"] > 1.0
    assert bench.check_paper_gates(rep) in (0, 1)
    capsys.readouterr()


def test_build_headline_engine_rejects_half_specified_geometry():
    from repro.core import headline
    from repro.core.geometry import zn540

    flash, zone = zn540()
    with pytest.raises(ValueError, match="together"):
        headline.build_headline_engine(flash, None)
    with pytest.raises(ValueError, match="together"):
        headline.build_headline_engine(None, zone)


def test_repo_artifact_passes_the_gates(capsys):
    """The checked-in BENCH_paper.json must clear its own gates."""
    path = _ROOT / "BENCH_paper.json"
    if not path.exists():
        pytest.skip("BENCH_paper.json not generated in this checkout")
    assert bench.check_paper_gates(json.loads(path.read_text())) == 0
    capsys.readouterr()


# --------------------------------------------------------------------- #
# bench_table paper rows
# --------------------------------------------------------------------- #
def test_bench_table_renders_paper_rows(tmp_path):
    p = tmp_path / "BENCH_paper.json"
    p.write_text(json.dumps(_artifact()))
    rows = bench_table.rows_of(p)
    assert len(rows) == 3
    labels = " / ".join(r[0] for r in rows)
    assert "DLWA at 10% occupancy" in labels
    assert "block erases" in labels
    assert "execution time" in labels
    assert "recompile-free" in rows[2][0]
    assert rows[0][4] == "**-86%**"


def test_bench_table_rejects_pre_schema_paper_artifact(tmp_path):
    """An artifact from an older bench (no gated 10% point) must fail
    with the re-run message, not a KeyError."""
    art = _artifact()
    del art["dlwa"]["reduction_at_10pct"]
    p = tmp_path / "BENCH_paper.json"
    p.write_text(json.dumps(art))
    with pytest.raises(bench_table.SchemaError, match="re-run"):
        bench_table.rows_of(p)
