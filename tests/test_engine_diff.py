"""Differential tests: pytree engine vs the legacy stateful device.

Three layers of equivalence, all required to be *exact*:

1. random op sequences (hypothesis, `_hypothesis_stub` fallback) replayed
   through the legacy ``LegacyZNSDevice``, the engine-backed ``ZNSDevice``
   shim, and the raw ``run_program`` scan must leave identical
   wear/avail/pages/zone-map state, counters, and zone tables -- illegal
   ops included (legacy ``RuntimeError`` <-> engine ``ok=0`` with the same
   partial effects);
2. the paper's dlwa / interference / write benchmarks driven as op
   programs must reproduce the legacy per-op metrics exactly (DLWA, dummy
   pages, wear histogram, and even the timing-model outputs, since the
   reconstructed IO streams are bit-identical);
3. the vmapped sweep executor must equal per-program scans.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import engine as E
from repro.core import workloads
from repro.core.device import ZNSDevice
from repro.core.device_legacy import LegacyZNSDevice
from repro.core.elements import (BLOCK, FIXED, SUPERBLOCK, hchunk, vchunk)
from repro.core.geometry import FlashGeometry, ZoneGeometry, zn540

SPECS = [BLOCK, vchunk(2), hchunk(2), SUPERBLOCK, FIXED]


def tiny_flash():
    return FlashGeometry(n_channels=4, ways_per_channel=1, blocks_per_lun=8,
                         pages_per_block=4, page_bytes=4096)


def assert_same_device_state(dev, leg, ctx=""):
    assert np.array_equal(dev.elem_wear, leg.elem_wear), f"wear {ctx}"
    assert np.array_equal(dev.elem_avail, leg.elem_avail), f"avail {ctx}"
    assert np.array_equal(dev.elem_pages, leg.elem_pages), f"pages {ctx}"
    assert np.array_equal(dev.elem_zone, leg.elem_zone), f"zone {ctx}"
    assert dev.host_pages == leg.host_pages, ctx
    assert dev.dummy_pages == leg.dummy_pages, ctx
    assert dev.block_erases == leg.block_erases, ctx
    assert dev.dlwa == leg.dlwa, ctx
    assert dev.n_active == leg.n_active, ctx
    for z in range(dev.n_zones):
        a, b = dev.zones[z], leg.zones[z]
        assert (a.state.name, a.wp, a.host_wp) == \
            (b.state.name, b.wp, b.host_wp), f"zone {z} {ctx}"
        if a.elements is not None and b.elements is not None:
            assert np.array_equal(a.elements, b.elements), f"map {z} {ctx}"


def assert_scan_matches_legacy(eng, state, leg, ctx=""):
    n = eng.cfg.n_elements
    assert np.array_equal(np.asarray(state.elem_wear[:n]),
                          leg.elem_wear), f"wear {ctx}"
    assert np.array_equal(np.asarray(state.elem_avail[:n]),
                          leg.elem_avail), f"avail {ctx}"
    assert np.array_equal(np.asarray(state.elem_pages[:n]),
                          leg.elem_pages), f"pages {ctx}"
    assert np.array_equal(np.asarray(state.elem_zone[:n]),
                          leg.elem_zone), f"map {ctx}"
    assert int(state.host_pages) == leg.host_pages, ctx
    assert int(state.dummy_pages) == leg.dummy_pages, ctx
    assert int(state.block_erases) == leg.block_erases, ctx
    assert int(state.n_active) == leg.n_active, ctx
    zs = np.asarray(state.zone_state)
    wp = np.asarray(state.zone_wp)
    hwp = np.asarray(state.zone_host_wp)
    for z in range(eng.cfg.n_zones):
        info = leg.zones[z]
        assert zs[z] == info.state.value, f"zone {z} state {ctx}"
        assert wp[z] == info.wp and hwp[z] == info.host_wp, f"zone {z} {ctx}"
    assert np.array_equal(eng.block_wear(state), leg.block_wear()), ctx


# --------------------------------------------------------------------- #
# 1. random op sequences, illegal ops included
# --------------------------------------------------------------------- #
@settings(max_examples=12, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(0, len(SPECS) - 1))
def test_differential_random_op_sequences(seed, spec_i):
    spec = SPECS[spec_i]
    flash = tiny_flash()
    zone = ZoneGeometry(parallelism=4, n_segments=2)
    rng = np.random.default_rng(seed)
    dev = ZNSDevice(flash, zone, spec, max_active=3)
    leg = LegacyZNSDevice(flash, zone, spec, max_active=3)
    eng = dev.engine
    rows = []
    for i in range(30):
        op = int(rng.integers(0, 3))
        z = int(rng.integers(0, 4))
        n = int(rng.integers(1, leg.zone_pages + 2))  # may overflow the zone
        if op == 0:
            rows.append((E.OP_WRITE, z, n, E.F_HOST))
        elif op == 1:
            rows.append((E.OP_FINISH, z, 0, 0))
        else:
            rows.append((E.OP_RESET, z, 0, 0))
        outcomes = []
        for d in (dev, leg):
            try:
                if op == 0:
                    d.zone_write(z, n)
                elif op == 1:
                    d.zone_finish(z)
                else:
                    d.zone_reset(z)
                outcomes.append("ok")
            except RuntimeError:
                outcomes.append("err")
        ctx = f"seed={seed} spec={spec.name} i={i} op={op} z={z} n={n}"
        assert outcomes[0] == outcomes[1], ctx
        assert_same_device_state(dev, leg, ctx)
    # the same sequence as ONE compiled scan
    state, trace = eng.run(eng.init_state(), E.encode_program(rows))
    assert_scan_matches_legacy(eng, state, leg,
                               f"seed={seed} spec={spec.name}")
    # shim and scan agree op-by-op on the pytree too
    assert np.array_equal(np.asarray(state.elem_wear),
                          np.asarray(dev.state.elem_wear))


#: one fuzz op row: (opcode, zone, n_pages, host).  n_pages ranges past
#: the tiny geometry's 32-page zone so overflow writes (illegal) mix
#: with legal fills; dummy (host=False) writes exercise the
#: dummy-page accounting paths.
_FUZZ_ROW = st.tuples(
    st.sampled_from([E.OP_WRITE, E.OP_FINISH, E.OP_RESET]),
    st.integers(0, 3),
    st.integers(1, 34),
    st.booleans(),
)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, len(SPECS) - 1), st.integers(1, 4),
       st.lists(_FUZZ_ROW, min_size=1, max_size=40))
def test_differential_fuzz_programs(spec_i, max_active, rows):
    """Strategy-generated mixed valid/illegal programs: the legacy
    device, the engine-backed shim, and ONE ``run_program`` scan must
    leave exactly the same device state, and the scan's per-op ``ok``
    flags must line up with where the legacy device raised.  (Degrades
    to the seeded ``_hypothesis_stub`` enumeration when hypothesis is
    not installed.)"""
    spec = SPECS[spec_i]
    flash = tiny_flash()
    zone = ZoneGeometry(parallelism=4, n_segments=2)
    dev = ZNSDevice(flash, zone, spec, max_active=max_active)
    leg = LegacyZNSDevice(flash, zone, spec, max_active=max_active)
    legal = []
    for i, (op, z, n, host) in enumerate(rows):
        outcomes = []
        for d in (dev, leg):
            try:
                if op == E.OP_WRITE:
                    d.zone_write(z, n, host=host)
                elif op == E.OP_FINISH:
                    d.zone_finish(z)
                else:
                    d.zone_reset(z)
                outcomes.append(True)
            except RuntimeError:
                outcomes.append(False)
        ctx = f"spec={spec.name} ma={max_active} i={i} row={rows[i]}"
        assert outcomes[0] == outcomes[1], ctx
        legal.append(outcomes[1])
        assert_same_device_state(dev, leg, ctx)
    prog = E.encode_program(
        [(op, z, n, E.F_HOST if host else 0)
         for op, z, n, host in rows])
    eng = dev.engine
    state, trace = eng.run(eng.init_state(), prog)
    ctx = f"spec={spec.name} ma={max_active}"
    assert_scan_matches_legacy(eng, state, leg, ctx)
    # ok=0 exactly where the legacy device raised (WRITE-only; FINISH /
    # RESET never raise and always report ok)
    assert np.asarray(trace.ok).tolist() == legal, ctx
    # the scan's final pytree equals the shim's, leaf for leaf
    for mine, shim in zip(state, dev.state):
        assert np.array_equal(np.asarray(mine), np.asarray(shim)), ctx


@pytest.mark.parametrize("spec", [BLOCK, vchunk(2), SUPERBLOCK, FIXED],
                         ids=lambda s: s.name)
def test_differential_wear_oblivious_allocation(spec):
    """wear_aware=False (the ConfZNS++-style first-fit policy): selection
    is by column, but slot arrangement still ranks by wear -- must stay
    bit-identical to legacy under wear-divergent churn."""
    flash = tiny_flash()
    zone = ZoneGeometry(parallelism=4, n_segments=2)
    dev = ZNSDevice(flash, zone, spec, wear_aware=False)
    leg = LegacyZNSDevice(flash, zone, spec, wear_aware=False)
    for i in range(12):
        z = i % 3
        for d in (dev, leg):
            d.zone_write(z, 3 + i)        # partial fill: uneven wear
            d.zone_finish(z)
            d.zone_reset(z)
        assert_same_device_state(dev, leg, f"{spec.name} i={i}")


# --------------------------------------------------------------------- #
# 2. paper benchmark programs: exact metric parity
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("spec", [SUPERBLOCK, FIXED], ids=lambda s: s.name)
def test_dlwa_program_matches_legacy(spec):
    flash, zone = zn540()
    eng = workloads.make_engine(flash, zone, spec, max_active=28)
    for occ in (0.1, 0.4, 0.9):
        leg = LegacyZNSDevice(flash, zone, spec, max_active=28)
        a = workloads.dlwa_benchmark(leg, occupancy=occ, n_zones=4)
        b = workloads.dlwa_benchmark_engine(eng, occupancy=occ, n_zones=4)
        assert a == b, (spec.name, occ)
        # wear histogram parity for the final state of the program
        prog = workloads.dlwa_program(eng, occupancy=occ, n_zones=4)
        state, _ = eng.run(eng.init_state(), prog)
        assert np.array_equal(eng.block_wear(state), leg.block_wear())


@pytest.mark.parametrize("spec", [SUPERBLOCK, FIXED], ids=lambda s: s.name)
def test_interference_program_matches_legacy(spec):
    """Fused finish+host-write program: identical metrics AND identical
    timing-model outputs (the rebuilt IO streams are bit-equal)."""
    flash, zone = zn540()
    eng = workloads.make_engine(flash, zone, spec, max_active=28)
    for conc in (1, 3):
        leg = LegacyZNSDevice(flash, zone, spec, max_active=28)
        a = workloads.interference_benchmark(leg, concurrency=conc)
        b = workloads.interference_benchmark_engine(eng, concurrency=conc)
        assert a == b, (spec.name, conc)


def test_write_program_matches_legacy():
    flash, zone = zn540()
    eng = workloads.make_engine(flash, zone, SUPERBLOCK, max_active=28)
    leg = LegacyZNSDevice(flash, zone, SUPERBLOCK, max_active=28)
    a = workloads.write_benchmark(leg, request_kib=16, n_jobs=4,
                                  mib_per_job=4)
    b = workloads.write_benchmark_engine(eng, request_kib=16, n_jobs=4,
                                         mib_per_job=4)
    assert a == b


def test_shim_trace_streams_match_legacy():
    """trace=True IO streams (write + FINISH padding) are bit-identical."""
    flash, zone = zn540()
    dev = ZNSDevice(flash, zone, SUPERBLOCK, max_active=28)
    leg = LegacyZNSDevice(flash, zone, SUPERBLOCK, max_active=28)
    for z in range(4):
        fill = max(1, int(dev.zone_pages * (0.2 + 0.2 * z)))
        t1 = dev.zone_write(z, fill, trace=True)
        t2 = leg.zone_write(z, fill, trace=True)
        assert np.array_equal(t1.luns, t2.luns)
        assert np.array_equal(t1.channels, t2.channels)
        f1 = dev.zone_finish(z, trace=True)
        f2 = leg.zone_finish(z, trace=True)
        assert (f1 is None) == (f2 is None)
        if f1 is not None:
            assert np.array_equal(f1.luns, f2.luns)
            assert np.array_equal(f1.channels, f2.channels)


def test_headline_dlwa_matches_legacy_oracle():
    """The paper-headline DLWA figure (paired traditional/silent lanes
    over one union engine, ONE batched dispatch) must agree per
    occupancy point with per-op ``LegacyZNSDevice`` oracles: the
    traditional lane with a legacy device built on the whole-zone
    hchunk spec, the silent lane with a legacy BLOCK device (page
    accounting is policy-independent; see
    ``tests/test_silentzns_property.py``)."""
    from repro.core import headline

    flash = tiny_flash()
    zone = ZoneGeometry(parallelism=4, n_segments=2)
    eng = headline.build_headline_engine(flash, zone, max_active=3)
    occs = (0.1, 0.5, 0.9)
    fig = headline.dlwa_figure(eng, occs, n_zones=2)
    oracle_specs = {"traditional_dlwa": headline.traditional_spec(zone),
                    "silent_dlwa": BLOCK}
    for key, spec in oracle_specs.items():
        for i, occ in enumerate(occs):
            leg = LegacyZNSDevice(flash, zone, spec, max_active=3)
            ref = workloads.dlwa_benchmark(leg, occupancy=occ, n_zones=2)
            assert fig[key][i] == ref["dlwa"], (key, occ)
    # the gated reduction is exactly the 10%-point pairing of the two
    r = headline.dlwa_reduction_at(fig, 0.1)
    assert r == 1.0 - fig["silent_dlwa"][0] / fig["traditional_dlwa"][0]


# --------------------------------------------------------------------- #
# 3. vmapped sweep == per-program scans
# --------------------------------------------------------------------- #
def test_vmapped_sweep_equals_single_scans():
    flash, zone = zn540()
    eng = workloads.make_engine(flash, zone, SUPERBLOCK, max_active=28)
    occs = [0.1, 0.3, 0.5, 0.7, 0.9]
    sweep = workloads.dlwa_sweep_engine(eng, occs, n_zones=4)
    for row, occ in zip(sweep, occs):
        single = workloads.dlwa_benchmark_engine(eng, occupancy=occ,
                                                 n_zones=4)
        assert row == single, occ


# --------------------------------------------------------------------- #
# DynConfig override validation (regressions: silent out-of-range
# overrides indexed past the padded static tables, silent FIXED shrink
# corrupted metrics)
# --------------------------------------------------------------------- #
def test_make_dyn_rejects_out_of_range_overrides():
    """zone_pages / n_zones / max_active beyond the padded static
    EngineConfig used to be accepted silently and index past the padded
    tables (wrong metrics, no error); they must raise eagerly, naming
    the offending field."""
    flash = tiny_flash()
    eng = E.ZoneEngine(flash, ZoneGeometry(4, 2), SUPERBLOCK,
                       max_active=3)
    cfg = eng.cfg
    for field, bad in [("zone_pages", cfg.zone_pages + 1),
                       ("zone_pages", 0),
                       ("n_zones", cfg.n_zones + 1),
                       ("n_zones", 0),
                       ("max_active", cfg.max_active + 1),
                       ("max_active", 0)]:
        with pytest.raises(ValueError, match=field):
            E.make_dyn(cfg, **{field: bad})
        with pytest.raises(ValueError, match=field):
            eng.dyn(**{field: bad})
    # in-range values (the documented override surface) still pass
    d = eng.dyn(zone_pages=cfg.zone_pages // 2, n_zones=1, max_active=1)
    assert int(d.zone_pages) == cfg.zone_pages // 2


def test_make_dyn_rejects_fixed_capacity_shrink():
    """Shrinking zone_pages on a FIXED-kind lane is documented illegal
    (the element *is* the whole static zone) and was guarded only in
    ``build_fleet_batch``; direct ``make_dyn`` / ``run_batch`` callers
    silently corrupted metrics.  Both construction paths must raise."""
    flash = tiny_flash()
    eng = E.ZoneEngine(flash, ZoneGeometry(4, 2), FIXED, max_active=3)
    half = eng.cfg.zone_pages // 2
    with pytest.raises(ValueError, match="FIXED"):
        E.make_dyn(eng.cfg, zone_pages=half)
    with pytest.raises(ValueError, match="FIXED"):
        eng.dyn(zone_pages=half)   # the run/run_batch dyn entry point
    # full capacity stays legal on FIXED lanes
    assert int(eng.dyn(zone_pages=eng.cfg.zone_pages).zone_pages) \
        == eng.cfg.zone_pages
    # non-FIXED kinds keep the established shrink semantics
    blk = E.ZoneEngine(flash, ZoneGeometry(4, 2), BLOCK, max_active=3)
    assert int(blk.dyn(zone_pages=blk.cfg.zone_pages // 2).zone_pages) \
        == blk.cfg.zone_pages // 2


def test_make_dyn_rejects_bad_alloc_policy():
    """The alloc_policy axis must validate eagerly, naming the field:
    an unknown policy string/int used to be conceivable as a silently
    traced garbage branch selector; and FIXED lanes have no block
    collection to vary, so 'silent' on FIXED is a construction-time
    error, not a runtime misallocation."""
    flash = tiny_flash()
    eng = E.ZoneEngine(flash, ZoneGeometry(4, 2), BLOCK, max_active=3)
    for bad in ("silentzns", "SILENT", ""):
        with pytest.raises(ValueError, match="alloc_policy"):
            E.make_dyn(eng.cfg, alloc_policy=bad)
        with pytest.raises(ValueError, match="alloc_policy"):
            eng.dyn(alloc_policy=bad)
    with pytest.raises(ValueError, match="alloc_policy"):
        eng.dyn(alloc_policy=7)
    fixed = E.ZoneEngine(flash, ZoneGeometry(4, 2), FIXED, max_active=3)
    with pytest.raises(ValueError, match="alloc_policy"):
        fixed.dyn(alloc_policy="silent")
    with pytest.raises(ValueError, match="wear_bound"):
        eng.dyn(wear_bound=-1)
    # the documented surface still passes: names, ints, and the default
    assert int(eng.dyn(alloc_policy="silent").alloc_policy) \
        == E.POLICY_SILENT
    assert int(eng.dyn(alloc_policy=E.POLICY_SILENT).alloc_policy) \
        == E.POLICY_SILENT
    assert int(eng.dyn().alloc_policy) == E.POLICY_TRADITIONAL
    assert int(fixed.dyn(alloc_policy="traditional").alloc_policy) \
        == E.POLICY_TRADITIONAL
    assert int(eng.dyn(wear_bound=2).wear_bound) == 2


# --------------------------------------------------------------------- #
# shim-specific invariants
# --------------------------------------------------------------------- #
def test_warmup_alloc_does_not_mutate_state():
    flash = tiny_flash()
    zone = ZoneGeometry(parallelism=4, n_segments=2)
    for dev in (ZNSDevice(flash, zone, BLOCK),
                LegacyZNSDevice(flash, zone, BLOCK)):
        before = dev.elem_wear.copy(), dev.elem_avail.copy()
        dev.warmup_alloc()
        assert np.array_equal(dev.elem_wear, before[0])
        assert np.array_equal(dev.elem_avail, before[1])
        assert dev.host_pages == 0 and dev.alloc_calls == 0


def test_alloc_latency_benchmark_excludes_compile():
    """After the warmup fix, no timed sample should be compile-sized
    (>100x the median) on a freshly constructed device."""
    flash = tiny_flash()
    zone = ZoneGeometry(parallelism=4, n_segments=2)
    dev = ZNSDevice(flash, zone, BLOCK)
    r = workloads.alloc_latency_benchmark(dev, n_allocs=8)
    lat = np.asarray(dev.alloc_latencies_us)
    assert r["n_allocs"] == len(lat)
    assert lat.max() < max(100.0 * r["median_us"], 5e4)
