"""Differential tests for the trace -> op-program compiler.

The property: application traffic (fuzzed KVBench mixes, checkpoint
schedules, flash-cache streams) driven through ``ZoneFS`` / the cache
mounted on a :class:`repro.storage.RecordingBackend`, compiled to a
width-5 op program and replayed through the batched ``ZoneEngine``,
leaves *bit-identical* device state to the same traffic driven through
the legacy per-op ``LegacyZNSDevice`` path -- DLWA, wear, counters and
zone tables, across all 5 element specs and both allocation policies.
(The legacy oracle has no silent allocator, so silent lanes are
cross-checked on everything the policy is defined to preserve:
host/dummy pages, DLWA, erases, active count, and the zone tables;
traditional lanes must match the element-level wear state too.)

Plus: the recorder's control-plane mirror raises the device shim's
exact errors, the mountable ``for_engine`` recorder reports through
``ZoneFS.report()`` like the legacy mount, multi-lane replays equal
per-lane runs, and the workload tenant mixes registered in
``repro.fleet.search.MIXES`` build legal deterministic fleet batches.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import repro.storage as S
from repro.core import engine as E
from repro.core.device import ZNSDevice
from repro.core.device_legacy import LegacyZNSDevice
from repro.core.elements import BLOCK, FIXED, SUPERBLOCK, hchunk, vchunk
from repro.core.engine import ZoneEngine
from repro.core.geometry import FlashGeometry, ZoneGeometry
from repro.storage.compile import _lsm_jobs

SPECS = [BLOCK, vchunk(2), hchunk(2), SUPERBLOCK, FIXED]
#: FIXED has no block collection to commit on the fly
POLICIES = {True: ("traditional",), False: ("traditional", "silent")}

MAX_ACTIVE = 6


def mid_flash():
    # 8 zones of 32 pages: enough for the LSM mount's session churn
    return FlashGeometry(n_channels=4, ways_per_channel=1,
                         blocks_per_lun=16, pages_per_block=4,
                         page_bytes=4096)


def make_engine(spec):
    return ZoneEngine(mid_flash(), ZoneGeometry(parallelism=4,
                                                n_segments=2),
                      spec, max_active=MAX_ACTIVE)


def record_and_legacy(spec, drive):
    """Drive identical traffic through a recorder and the legacy
    device; return (eng, recorder, legacy)."""
    eng = make_engine(spec)
    rec = S.RecordingBackend(eng.flash, zone_pages=eng.cfg.zone_pages,
                             n_zones=eng.cfg.n_zones,
                             max_active=MAX_ACTIVE)
    leg = LegacyZNSDevice(eng.flash, eng.zone_geom, spec,
                          max_active=MAX_ACTIVE)
    drive(rec)
    drive(leg)
    return eng, rec, leg


def assert_replay_matches_legacy(eng, rec, leg, policy, ctx=""):
    """Replay the compiled program and compare against the legacy
    device -- fully bit-identical under ``traditional``, and on every
    policy-invariant quantity under ``silent``."""
    state, trace = eng.run(eng.init_state(), rec.program(),
                           eng.dyn(alloc_policy=policy))
    ok = np.asarray(trace.ok)
    assert ok.all(), f"illegal replayed op {ctx}"
    assert int(state.host_pages) == leg.host_pages, f"host {ctx}"
    assert int(state.dummy_pages) == leg.dummy_pages, f"dummy {ctx}"
    assert int(state.block_erases) == leg.block_erases, f"erases {ctx}"
    assert int(state.n_active) == leg.n_active, f"n_active {ctx}"
    m = eng.metrics(state)
    assert m["dlwa"] == pytest.approx(leg.dlwa, abs=1e-12), f"dlwa {ctx}"
    zs = np.asarray(state.zone_state)
    wp = np.asarray(state.zone_wp)
    hwp = np.asarray(state.zone_host_wp)
    for z in range(eng.cfg.n_zones):
        info = leg.zones[z]
        assert zs[z] == info.state.value, f"zone {z} state {ctx}"
        assert wp[z] == info.wp and hwp[z] == info.host_wp, \
            f"zone {z} wp {ctx}"
    # the recorder's own control-plane mirror agrees with both
    assert rec.host_pages == leg.host_pages, f"recorder host {ctx}"
    assert rec.n_active == leg.n_active, f"recorder n_active {ctx}"
    for z in range(rec.n_zones):
        a, b = rec.zones[z], leg.zones[z]
        assert (a.state.name, a.wp, a.host_wp) == \
            (b.state.name, b.wp, b.host_wp), f"recorder zone {z} {ctx}"
    if policy == "traditional":
        n = eng.cfg.n_elements
        assert np.array_equal(np.asarray(state.elem_wear[:n]),
                              leg.elem_wear), f"wear {ctx}"
        assert np.array_equal(np.asarray(state.elem_avail[:n]),
                              leg.elem_avail), f"avail {ctx}"
        assert np.array_equal(np.asarray(state.elem_pages[:n]),
                              leg.elem_pages), f"elem pages {ctx}"
        assert np.array_equal(np.asarray(state.elem_zone[:n]),
                              leg.elem_zone), f"elem map {ctx}"
        assert np.array_equal(eng.block_wear(state), leg.block_wear()), \
            f"block wear {ctx}"
    return state


# --------------------------------------------------------------------- #
# 1. fuzzed KVBench mixes (the paper's evaluation traffic)
# --------------------------------------------------------------------- #
@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(0, len(SPECS) - 1))
def test_lsm_compiled_matches_legacy(seed, spec_i):
    spec = SPECS[spec_i]

    def drive(dev):
        cfg = S.scaled_kv_config(dev.zone_pages, dev.flash.page_bytes,
                                 seed=seed, n_flushes=4 + seed % 5,
                                 max_jobs=_lsm_jobs(dev))
        sim = S.LSMSimulator(S.ZoneFS(dev), cfg)
        sim.run()
        assert not sim.failed

    eng, rec, leg = record_and_legacy(spec, drive)
    assert len(rec) > 0
    for policy in POLICIES[spec.kind.name == "FIXED"]:
        assert_replay_matches_legacy(
            eng, rec, leg, policy,
            f"lsm seed={seed} spec={spec.name} policy={policy}")


# --------------------------------------------------------------------- #
# 2. fuzzed checkpoint-burst schedules
# --------------------------------------------------------------------- #
@settings(max_examples=10, deadline=None)
@given(st.integers(2, 10), st.integers(0, 3), st.integers(1, 3),
       st.integers(0, 2**31 - 1), st.integers(0, len(SPECS) - 1))
def test_checkpoints_compiled_match_legacy(n_steps, shards, keep, seed,
                                           spec_i):
    spec = SPECS[spec_i]
    sched = S.CheckpointSchedule(n_steps=n_steps, shards=shards,
                                 keep=keep, log_rate=2, seed=seed)

    def drive(dev):
        S.record_checkpoints(dev, sched)

    eng, rec, leg = record_and_legacy(spec, drive)
    for policy in POLICIES[spec.kind.name == "FIXED"]:
        assert_replay_matches_legacy(
            eng, rec, leg, policy,
            f"ckpt steps={n_steps} shards={shards} keep={keep} "
            f"seed={seed} spec={spec.name} policy={policy}")


# --------------------------------------------------------------------- #
# 3. flash-cache streams (reads + zone-granular eviction)
# --------------------------------------------------------------------- #
@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1), st.floats(0.0, 1.5),
       st.integers(0, len(SPECS) - 1))
def test_cache_compiled_matches_legacy(seed, skew, spec_i):
    spec = SPECS[spec_i]

    def drive(dev):
        S.record_cache(dev, n_accesses=200, n_keys=32, skew=skew,
                       seed=seed, capacity_zones=5, obj_pages=4)

    eng, rec, leg = record_and_legacy(spec, drive)
    prog = rec.program()
    assert (prog[:, 0] == E.OP_READ).any(), "cache hits must record reads"
    for policy in POLICIES[spec.kind.name == "FIXED"]:
        assert_replay_matches_legacy(
            eng, rec, leg, policy,
            f"cache seed={seed} skew={skew:.3f} spec={spec.name} "
            f"policy={policy}")


# --------------------------------------------------------------------- #
# 4. the recorder's control-plane mirror
# --------------------------------------------------------------------- #
def _mirror_pair(spec=SUPERBLOCK, max_active=2):
    flash = mid_flash()
    zone = ZoneGeometry(parallelism=4, n_segments=2)
    dev = ZNSDevice(flash, zone, spec, max_active=max_active)
    rec = S.RecordingBackend(flash, zone_pages=dev.zone_pages,
                             n_zones=dev.n_zones, max_active=max_active)
    return dev, rec


@pytest.mark.parametrize("bad", ["full", "overflow", "limit", "read"])
def test_recorder_raises_device_errors(bad):
    dev, rec = _mirror_pair()
    for d in (dev, rec):
        if bad == "full":
            d.zone_write(0, d.zone_pages)      # auto-seals
            with pytest.raises(RuntimeError, match="write to FULL zone 0"):
                d.zone_write(0, 1)
        elif bad == "overflow":
            d.zone_write(0, 1)
            with pytest.raises(RuntimeError, match="overflow"):
                d.zone_write(0, d.zone_pages)
        elif bad == "limit":
            d.zone_write(0, 1)
            d.zone_write(1, 1)
            with pytest.raises(RuntimeError,
                               match=r"open/active zone limit \(2\)"):
                d.zone_write(2, 1)
        else:
            with pytest.raises(RuntimeError,
                               match="read from unmapped zone 3"):
                d.zone_read(3, np.arange(2))


def test_recorder_random_ops_mirror_device_shim():
    """Random legal/illegal command soup: the recorder accepts exactly
    what the engine-backed device shim accepts, with matching zone
    tables afterwards."""
    rng = np.random.default_rng(7)
    dev, rec = _mirror_pair(max_active=3)
    for i in range(200):
        op = int(rng.integers(0, 4))
        z = int(rng.integers(0, 4))
        n = int(rng.integers(1, dev.zone_pages + 2))
        outcomes = []
        for d in (dev, rec):
            try:
                if op == 0:
                    d.zone_write(z, n)
                elif op == 1:
                    d.zone_finish(z)
                elif op == 2:
                    d.zone_reset(z)
                else:
                    d.zone_read(z, np.arange(min(n, 2)))
                outcomes.append("ok")
            except RuntimeError as exc:
                outcomes.append(f"err:{exc}")
        assert outcomes[0] == outcomes[1], f"i={i} op={op} z={z} n={n}"
        assert dev.n_active == rec.n_active
        for zz in range(4):
            a, b = dev.zones[zz], rec.zones[zz]
            assert (a.state.name, a.wp) == (b.state.name, b.wp)


def test_recorder_emits_explicit_alloc_rows():
    _, rec = _mirror_pair()
    rec.zone_write(1, 3)
    prog = rec.program()
    assert prog[0].tolist() == [E.OP_ALLOC, 1, 0, 0, 0]
    assert prog[1].tolist() == [E.OP_WRITE, 1, 3, E.F_HOST, 0]


def test_recorder_zone_base_offsets_rows():
    flash = mid_flash()
    rec = S.RecordingBackend(flash, zone_pages=32, n_zones=2,
                             max_active=2, zone_base=5)
    rec.zone_write(0, 4)
    rec.zone_write(1, 4)
    assert sorted(set(rec.program()[:, 1].tolist())) == [5, 6]


def test_recorder_stream_classes_stamp_tenants():
    flash = mid_flash()
    rec = S.RecordingBackend(flash, zone_pages=32, n_zones=4,
                             max_active=4,
                             class_tenants={"wal": 0, "flush": 1})
    rec.set_stream_class("wal")
    rec.zone_write(0, 2)
    rec.set_stream_class("flush")
    rec.zone_write(1, 2)
    rec.set_stream_class("unknown-class")   # must not disturb the tag
    rec.zone_write(1, 2)
    prog = rec.program()
    writes = prog[prog[:, 0] == E.OP_WRITE]
    assert writes[:, 4].tolist() == [0, 1, 1]


# --------------------------------------------------------------------- #
# 5. the mountable compiled device (for_engine) and batched replay
# --------------------------------------------------------------------- #
def test_for_engine_mount_reports_like_legacy():
    eng = make_engine(SUPERBLOCK)
    rec = S.RecordingBackend.for_engine(eng, max_active=MAX_ACTIVE)
    leg = LegacyZNSDevice(eng.flash, eng.zone_geom, SUPERBLOCK,
                          max_active=MAX_ACTIVE)
    for dev in (rec, leg):
        fs = S.ZoneFS(dev)
        fs.create(1, 10, 0)
        fs.create(2, 40, 1)
        fs.delete(1)
        rep = fs.report()
        dev._rep = rep
    assert rec._rep["dlwa"] == pytest.approx(leg._rep["dlwa"], abs=1e-12)
    assert rec.dummy_pages == leg.dummy_pages
    # cache invalidates on new traffic
    before = rec.dummy_pages
    S.ZoneFS(rec)  # re-mounting records nothing
    rec.zone_write(rec.n_zones - 1, 1)
    rec.zone_finish(rec.n_zones - 1)
    assert rec.dummy_pages > before


def test_replay_recorders_matches_individual_runs():
    eng = make_engine((SUPERBLOCK, BLOCK))
    recs = []
    for t, spec in enumerate((SUPERBLOCK, BLOCK)):
        rec = S.RecordingBackend(eng.flash, zone_pages=eng.cfg.zone_pages,
                                 n_zones=4, max_active=3, tenant=t)
        S.record_cache(rec, n_accesses=120, n_keys=24, seed=t,
                       capacity_zones=4, obj_pages=4)
        recs.append(rec)
    dyns = [eng.dyn(spec=SUPERBLOCK), eng.dyn(spec=BLOCK)]
    res = S.replay_recorders(eng, recs, dyns=dyns, n_tenants=2,
                             pad_quantum=32)
    assert res.programs.shape[0] == 2
    assert res.programs.shape[1] % 32 == 0
    for lane, (rec, dyn) in enumerate(zip(recs, dyns)):
        solo_state, _ = eng.run(eng.init_state(), rec.program(), dyn)
        got = S.lane_metrics(eng, res, lane)
        want = eng.metrics(solo_state)
        assert got == want, f"lane {lane}"


def test_replay_recorders_checks_divergence():
    eng = make_engine(SUPERBLOCK)
    rec = S.RecordingBackend(eng.flash, zone_pages=eng.cfg.zone_pages,
                             n_zones=4, max_active=3)
    rec.zone_write(0, 4)
    # corrupt a row: this write overflows the zone
    rec._rows.append((E.OP_WRITE, 0, eng.cfg.zone_pages, E.F_HOST, 0))
    # the failure is routed through the verifier: error class + the
    # shim's exact message, not just a lane/index coordinate
    with pytest.raises(AssertionError,
                       match=r"illegal WRITE .*error class 'overflow'"):
        S.replay_recorders(eng, [rec], n_tenants=1)


# --------------------------------------------------------------------- #
# 6. workload mixes + class-tagged dispatch
# --------------------------------------------------------------------- #
def big_engine():
    flash = FlashGeometry(n_channels=4, ways_per_channel=1,
                          blocks_per_lun=32, pages_per_block=4,
                          page_bytes=4096)
    return ZoneEngine(flash, ZoneGeometry(parallelism=4, n_segments=2),
                      SUPERBLOCK, max_active=8)


def test_workload_mixes_registered():
    from repro.fleet.search import MIXES
    for name in S.WORKLOADS:
        assert name in MIXES


def test_workload_mix_deterministic_and_legal():
    from repro.fleet.search import MIXES, N_TENANTS
    eng = big_engine()
    for name in S.WORKLOADS:
        a = MIXES[name](eng, eng.cfg.zone_pages)
        b = MIXES[name](eng, eng.cfg.zone_pages)
        assert len(a) == N_TENANTS
        for pa, pb in zip(a, b):
            assert np.array_equal(pa, pb), name
        # mutating a returned program must not poison the cache
        a[0][:, 2] = -1
        c = MIXES[name](eng, eng.cfg.zone_pages)
        assert not np.array_equal(a[0], c[0]), name


def test_workload_mix_builds_legal_fleet_batch():
    from repro.fleet import (N_TENANTS, assert_all_ok, build_fleet_batch,
                             run_fleet)
    from repro.fleet.search import FleetConfig
    eng = big_engine()
    fc = FleetConfig(mix="cache", n_segments=2, chunk_pages=16,
                     parity=False, wear_aware=True)
    programs, dyn, merged = build_fleet_batch(eng, [fc], n_devices=2,
                                              pad_quantum=64)
    res = run_fleet(eng, programs, dyn=dyn, n_tenants=N_TENANTS)
    assert_all_ok(res)
    assert (merged[0][:, 0] == E.OP_READ).any()


def test_run_workload_class_report():
    eng = big_engine()
    for name, classes in S.WORKLOADS.items():
        res, rep = S.run_workload(eng, name, pad_quantum=32)
        assert rep["workload"] == name
        tc = rep["tenant_classes"]
        assert tuple(tc) == classes
        total_ops = sum(v["ops"] for v in tc.values())
        real = int((res.programs[:, :, 0] != E.OP_NOP).sum())
        assert total_ops == real, name
        for cls, v in tc.items():
            if v["ops"]:
                assert v["p99_latency_s"] >= v["p50_latency_s"] >= 0.0
                assert v["p99_over_p50"] >= 1.0 or v["p50_latency_s"] == 0
        assert rep["recorded_ops"] == real


def test_run_workload_reads_are_priced():
    """OP_READ rows must enter the timing model (pages + latency)."""
    eng = big_engine()
    res, rep = S.run_workload(eng, "cache", pad_quantum=32)
    reads = res.programs[:, :, 0] == E.OP_READ
    assert reads.any()
    assert (res.pages[reads] > 0).all()
    assert (res.latencies[reads] > 0).all()
    assert rep["tenant_classes"]["hit"]["pages"] > 0


def test_workload_window_too_small_raises():
    eng = make_engine(SUPERBLOCK)   # 8 zones < 2 lanes x 6-zone lsm
    with pytest.raises(ValueError, match="6-zone window"):
        S.run_workload(eng, "lsm")
