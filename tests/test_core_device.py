"""Zone state-machine invariants + the paper's headline numbers."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (BLOCK, FIXED, SUPERBLOCK, ZNSDevice, ZoneGeometry,
                        ZoneState, custom16, hchunk, vchunk, zn540)
from repro.core import workloads
from repro.core.alloc_exact import (AVAIL_ALLOCATED, AVAIL_FREE,
                                    AVAIL_INVALID, AVAIL_VALID)


def tiny_flash():
    from repro.core.geometry import FlashGeometry
    return FlashGeometry(n_channels=4, ways_per_channel=1, blocks_per_lun=8,
                         pages_per_block=4, page_bytes=4096)


# --------------------------------------------------------------------- #
# paper headline numbers
# --------------------------------------------------------------------- #
def test_paper_dlwa_86pct_reduction_at_10pct_occupancy():
    """§6.2: 'reducing DLWA by up to 86.36% (10% zone occupancy with the
    superblock configuration)' on the ZN540 model."""
    flash, zone = zn540()
    base = ZNSDevice(flash, zone, FIXED)
    silent = ZNSDevice(flash, zone, SUPERBLOCK)
    rb = workloads.dlwa_benchmark(base, occupancy=0.10, n_zones=4)
    rs = workloads.dlwa_benchmark(silent, occupancy=0.10, n_zones=4)
    reduction = (rb["dlwa"] - rs["dlwa"]) / rb["dlwa"]
    assert rb["dlwa"] == pytest.approx(10.0, rel=0.01)
    assert reduction == pytest.approx(0.8636, abs=0.01)


def test_paper_dlwa_1_at_50pct_multisegment():
    """§6.3: at 50% occupancy, multi-segment zones eliminate dummy writes
    entirely under SilentZNS (DLWA = 1)."""
    flash = custom16()
    zone = ZoneGeometry(parallelism=16, n_segments=2)
    for spec in (BLOCK, vchunk(2), vchunk(4), SUPERBLOCK):
        dev = ZNSDevice(flash, zone, spec)
        r = workloads.dlwa_benchmark(dev, occupancy=0.5, n_zones=2)
        assert r["dlwa"] == pytest.approx(1.0), spec.name
    base = ZNSDevice(flash, zone, FIXED)
    r = workloads.dlwa_benchmark(base, occupancy=0.5, n_zones=2)
    assert r["dlwa"] == pytest.approx(2.0)


def test_paper_fig8_small_zone_scaling():
    """Fig. 8: at ~0 occupancy, halving zone size halves fixed-allocation
    dummy writes (256 -> 128 -> 64 -> 32 MiB)."""
    flash = custom16()
    dummy = {}
    for P, segs in ((16, 2), (16, 1), (8, 1), (4, 1)):
        zone = ZoneGeometry(parallelism=P, n_segments=segs)
        dev = ZNSDevice(flash, zone, FIXED)
        r = workloads.dlwa_benchmark(dev, occupancy=0.0001, n_zones=2)
        dummy[(P, segs)] = r["dummy_pages_per_zone"]
    assert dummy[(16, 2)] / dummy[(16, 1)] == pytest.approx(2.0, rel=0.01)
    assert dummy[(16, 1)] / dummy[(8, 1)] == pytest.approx(2.0, rel=0.01)
    assert dummy[(8, 1)] / dummy[(4, 1)] == pytest.approx(2.0, rel=0.01)


def test_paper_fig8_element_granularity_ladder():
    """Fig. 8 (P8,S128 @ 0.01%): block < vchunk2/4 < hchunk2 < fixed, with
    vchunk ~4x less than fixed."""
    flash = custom16()
    zone = ZoneGeometry(parallelism=8, n_segments=2)
    res = {}
    for spec in (FIXED, BLOCK, vchunk(2), vchunk(4), hchunk(2)):
        dev = ZNSDevice(flash, zone, spec)
        r = workloads.dlwa_benchmark(dev, occupancy=0.0001, n_zones=2)
        res[spec.name] = r["dummy_pages_per_zone"]
    assert res["block"] < res["vchunk2"] <= res["vchunk4"]
    assert res["vchunk4"] < res["hchunk2"] < res["fixed"]
    assert res["fixed"] / res["vchunk2"] == pytest.approx(4.0, rel=0.05)


def test_paper_fig9_parallelism_throughput():
    """Fig. 9: P16 saturates with 1 zone; P8 needs 2; P4 needs 4."""
    flash = custom16()
    bw = {}
    for P, jobs in ((16, 1), (8, 1), (8, 2), (4, 1), (4, 4)):
        zone = ZoneGeometry(parallelism=P, n_segments=1)
        dev = ZNSDevice(flash, zone, FIXED)
        r = workloads.write_benchmark(dev, request_kib=64, n_jobs=jobs,
                                      mib_per_job=8)
        bw[(P, jobs)] = r["bandwidth_mib_s"]
    assert bw[(16, 1)] == pytest.approx(119, rel=0.1)   # ~110 MiB/s peak
    assert bw[(8, 1)] == pytest.approx(60, rel=0.1)     # ~60 MiB/s
    assert bw[(8, 2)] == pytest.approx(bw[(16, 1)], rel=0.1)
    assert bw[(4, 1)] == pytest.approx(30, rel=0.1)     # ~30 MiB/s
    assert bw[(4, 4)] == pytest.approx(bw[(16, 1)], rel=0.15)


def test_paper_interference_fine_grained_lower():
    """Table 3: fine-grained elements cut FINISH interference on
    multi-segment zones; single-segment zones behave like fixed."""
    flash = custom16()
    multi = ZoneGeometry(parallelism=16, n_segments=2)
    res = {}
    for spec in (FIXED, BLOCK, vchunk(2)):
        dev = ZNSDevice(flash, multi, spec, max_active=32)
        r = workloads.interference_benchmark(dev, concurrency=4)
        res[spec.name] = r["interference"]
    assert res["block"] < res["fixed"]
    assert res["vchunk2"] < res["fixed"]
    # single segment: all schemes must pad the whole segment -> similar
    single = ZoneGeometry(parallelism=16, n_segments=1)
    vals = []
    for spec in (FIXED, BLOCK):
        dev = ZNSDevice(flash, single, spec, max_active=32)
        r = workloads.interference_benchmark(dev, concurrency=4)
        vals.append(r["interference"])
    assert vals[0] == pytest.approx(vals[1], rel=0.05)


def test_wear_leveling_beats_baseline():
    """Fig. 7c: SilentZNS spreads erases more evenly than the wear-
    oblivious baseline under repeated partial-fill churn."""
    flash, zone = zn540()
    def churn(dev, rounds=30):
        for i in range(rounds):
            z = i % 8
            dev.zone_write(z, dev.zone_pages // 10)
            dev.zone_finish(z)
            dev.zone_reset(z)
    base = ZNSDevice(flash, zone, FIXED, wear_aware=False)
    silent = ZNSDevice(flash, zone, SUPERBLOCK)
    churn(base); churn(silent)
    base_total = base.block_erases + base.pending_erases()
    silent_total = silent.block_erases + silent.pending_erases()
    assert silent_total < base_total  # fewer erases overall (less padding)
    bw, sw = base.block_wear(), silent.block_wear()
    # SilentZNS: only-touched elements wear; baseline erases whole zones
    assert sw.sum() <= bw.sum()


# --------------------------------------------------------------------- #
# state-machine invariants
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("spec", [BLOCK, vchunk(2), SUPERBLOCK, FIXED],
                         ids=lambda s: s.name)
def test_finish_releases_untouched_elements(spec):
    flash = tiny_flash()
    zone = ZoneGeometry(parallelism=4, n_segments=2)
    dev = ZNSDevice(flash, zone, spec)
    dev.zone_write(0, 3)  # 3 pages into a 32-page zone
    n_allocated = int((dev.elem_avail == AVAIL_ALLOCATED).sum()
                      + (dev.elem_avail == AVAIL_VALID).sum())
    dev.zone_finish(0)
    mapped = dev.zones[0].elements
    kept = int((mapped >= 0).sum())
    if spec is FIXED:
        assert kept == 1  # fixed cannot release anything
    else:
        assert kept < n_allocated  # something was released
    # released elements are FREE again
    assert not (dev.elem_avail == AVAIL_ALLOCATED).any()


@pytest.mark.parametrize("spec", [BLOCK, vchunk(2), SUPERBLOCK],
                         ids=lambda s: s.name)
def test_released_elements_are_reallocated(spec):
    flash = tiny_flash()
    zone = ZoneGeometry(parallelism=4, n_segments=2)
    dev = ZNSDevice(flash, zone, spec)
    dev.zone_write(0, 3)
    dev.zone_finish(0)
    free_before = int((dev.elem_avail == AVAIL_FREE).sum())
    dev.zone_write(1, 3)  # must be able to reuse released elements
    assert int((dev.elem_avail == AVAIL_FREE).sum()) < free_before


def test_reset_defers_erase_to_reallocation():
    flash = tiny_flash()
    zone = ZoneGeometry(parallelism=4, n_segments=1)
    dev = ZNSDevice(flash, zone, BLOCK)
    dev.zone_write(0, dev.zone_pages)  # full zone, no padding
    assert dev.block_erases == 0
    dev.zone_reset(0)
    assert dev.block_erases == 0          # async: metadata only
    assert (dev.elem_avail == AVAIL_INVALID).sum() == 4
    wear_before = dev.elem_wear.sum()
    # cycle through zones until invalid elements are re-allocated
    for z in range(1, dev.n_zones):
        dev.zone_write(z, dev.zone_pages)
    dev.zone_write(0, 1)  # forces reuse of reset elements -> erase now
    assert dev.block_erases > 0
    assert dev.elem_wear.sum() > wear_before


def test_dlwa_accounting_identity():
    flash = tiny_flash()
    zone = ZoneGeometry(parallelism=4, n_segments=2)
    dev = ZNSDevice(flash, zone, vchunk(2))
    dev.zone_write(0, 5)
    dev.zone_finish(0)
    # pages in mapped elements == host + dummy
    mapped = dev.elem_zone >= 0
    assert dev.elem_pages[mapped].sum() == dev.host_pages + dev.dummy_pages


def test_full_zone_write_has_no_padding():
    flash = tiny_flash()
    zone = ZoneGeometry(parallelism=4, n_segments=2)
    for spec in (FIXED, BLOCK, SUPERBLOCK, vchunk(2), hchunk(2)):
        dev = ZNSDevice(flash, zone, spec)
        dev.zone_write(0, dev.zone_pages)
        dev.zone_finish(0)
        assert dev.dummy_pages == 0, spec.name
        assert dev.zones[0].state is ZoneState.FULL


def test_open_zone_limit_enforced():
    flash = tiny_flash()
    zone = ZoneGeometry(parallelism=4, n_segments=1)
    dev = ZNSDevice(flash, zone, BLOCK, max_active=2)
    dev.zone_write(0, 1)
    dev.zone_write(1, 1)
    with pytest.raises(RuntimeError, match="active zone limit"):
        dev.zone_write(2, 1)
    dev.zone_finish(0)  # frees a slot
    dev.zone_write(2, 1)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 31))
def test_property_random_fill_finish_reset_cycle(seed, pages):
    """Arbitrary partial fills: accounting identities always hold."""
    rng = np.random.default_rng(seed)
    flash = tiny_flash()
    zone = ZoneGeometry(parallelism=4, n_segments=2)
    spec = [BLOCK, vchunk(2), hchunk(2), SUPERBLOCK][seed % 4]
    dev = ZNSDevice(flash, zone, spec)
    for rnd in range(3):
        z = rnd
        n = min(pages + rnd, dev.zone_pages)
        dev.zone_write(z, n)
        dev.zone_finish(z)
        # every mapped element of a FULL zone is completely written
        info = dev.zones[z]
        for eid in info.elements:
            if eid >= 0:
                assert dev.elem_pages[eid] == dev.layout.pages_per_element
                assert dev.elem_avail[eid] == AVAIL_VALID
        dev.zone_reset(z)
        assert not (dev.elem_zone == z).any()
    # wear never decreases, avail codes in range
    assert (dev.elem_wear >= 0).all()
    assert np.isin(dev.elem_avail,
                   [AVAIL_FREE, AVAIL_ALLOCATED, AVAIL_VALID,
                    AVAIL_INVALID]).all()
