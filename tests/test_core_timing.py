"""Timing-model invariants: resource serialization lower bounds."""

import numpy as np
import pytest

from repro.core import IOTrace, custom16
from repro.core import timing


def test_single_lun_serializes():
    flash = custom16()
    n = 100
    tr = IOTrace(np.zeros(n, np.int64), np.zeros(n, np.int64), "write")
    stats = timing.run_trace(flash, [tr])
    expected = n * flash.t_prog  # one LUN: programs serialize
    assert stats["makespan_s"] >= expected * 0.99


def test_parallel_luns_scale():
    flash = custom16()
    n = 160
    luns = np.arange(n, dtype=np.int64) % flash.n_luns
    tr = IOTrace(luns, luns % flash.n_channels, "write")
    stats = timing.run_trace(flash, [tr])
    serial = n * flash.t_prog
    # 16 LUNs across 8 channels: ~16x speedup minus channel transfer
    assert stats["makespan_s"] < serial / 8


def test_channel_contention():
    """Two LUNs on the same channel share the transfer bus."""
    flash = custom16()
    n = 64
    # LUN 0 and LUN 8 share channel 0 (lun % n_channels)
    luns = np.where(np.arange(n) % 2 == 0, 0, 8).astype(np.int64)
    tr = IOTrace(luns, luns % flash.n_channels, "write")
    stats = timing.run_trace(flash, [tr])
    # both LUNs busy concurrently but xfers serialize on the channel
    lower = (n // 2) * flash.t_prog
    assert stats["makespan_s"] >= lower * 0.99
    assert stats["makespan_s"] <= lower + n * flash.t_xfer + flash.t_prog


def test_erase_dominates():
    flash = custom16()
    tr = IOTrace(np.zeros(4, np.int64), np.zeros(4, np.int64), "erase")
    stats = timing.run_trace(flash, [tr])
    assert stats["makespan_s"] >= 4 * flash.t_erase


def test_interleaved_streams_slower_than_solo():
    flash = custom16()
    n = 128
    luns = (np.arange(n) % flash.n_luns).astype(np.int64)
    host = IOTrace(luns, luns % flash.n_channels, "write")
    noise = IOTrace(luns.copy(), luns % flash.n_channels, "write")
    solo = timing.run_trace(flash, [host])
    both = timing.run_trace(flash, [host, noise])
    assert both["owner0_makespan_s"] > solo["owner0_makespan_s"]


def test_throughput_matches_device_limit():
    """16 LUNs x 4 KiB / 525us = ~119 MiB/s peak write bandwidth."""
    flash = custom16()
    n = 1600
    luns = (np.arange(n) % flash.n_luns).astype(np.int64)
    tr = IOTrace(luns, luns % flash.n_channels, "write")
    stats = timing.run_trace(flash, [tr])
    bw = timing.write_bandwidth_mib_s(flash, stats)
    peak = flash.n_luns * flash.page_bytes / (
        flash.t_prog + flash.t_xfer) / (1024 * 1024)
    assert bw == pytest.approx(peak, rel=0.1)
