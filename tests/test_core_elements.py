"""Element-layout invariants: every layout partitions the device's blocks."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (BLOCK, FIXED, SUPERBLOCK, ZoneGeometry, build_layout,
                        custom16, elements_per_zone, groups_per_zone,
                        hchunk, is_applicable, vchunk, zn540)
from repro.core.elements import ElementKind
from repro.core.geometry import FlashGeometry

SPECS = [BLOCK, hchunk(2), vchunk(2), vchunk(4), SUPERBLOCK]


@pytest.mark.parametrize("spec", SPECS, ids=lambda s: s.name)
def test_layout_partitions_blocks(spec):
    flash = custom16()
    lay = build_layout(flash, spec)
    blocks = lay.blocks.reshape(-1)
    assert len(blocks) == flash.n_blocks
    assert sorted(blocks.tolist()) == list(range(flash.n_blocks))
    # group-major dense: reshaping by group recovers contiguous groups
    per_group = lay.n_elements // lay.n_groups
    assert (lay.group.reshape(lay.n_groups, per_group)
            == np.arange(lay.n_groups)[:, None]).all()


@pytest.mark.parametrize("spec", SPECS, ids=lambda s: s.name)
def test_element_blocks_share_group_luns(spec):
    flash = custom16()
    lay = build_layout(flash, spec)
    B = flash.blocks_per_lun
    for e in (0, lay.n_elements // 2, lay.n_elements - 1):
        luns = np.unique(lay.blocks[e] // B)
        assert len(luns) == lay.luns_per_group


def test_fixed_layout_band_interleaved():
    """Consecutive FIXED physical zones must land on different LUN bands
    (paper Fig. 9: concurrent zones scale bandwidth)."""
    flash = custom16()
    zone = ZoneGeometry(parallelism=4, n_segments=1)
    lay = build_layout(flash, FIXED, zone)
    assert lay.n_groups == 4  # 16 LUNs / P4 = 4 bands
    assert lay.group[0] != lay.group[1]
    assert set(lay.group[:4].tolist()) == {0, 1, 2, 3}


def test_fixed_layout_partitions_blocks():
    flash = custom16()
    zone = ZoneGeometry(parallelism=8, n_segments=2)
    lay = build_layout(flash, FIXED, zone)
    blocks = lay.blocks.reshape(-1)
    assert sorted(blocks.tolist()) == list(range(flash.n_blocks))
    assert lay.blocks_per_element == zone.blocks_per_zone


@pytest.mark.parametrize("P,segs", [(16, 1), (16, 2), (8, 1), (8, 2),
                                    (4, 1), (4, 2)])
def test_paper_applicability_table(P, segs):
    """Reproduce the N/A cells of paper Tables 3-4."""
    flash = custom16()
    zone = ZoneGeometry(parallelism=P, n_segments=segs)
    assert is_applicable(SUPERBLOCK, zone, flash) == (P == 16)
    assert is_applicable(hchunk(2), zone, flash) == (segs % 2 == 0)
    assert is_applicable(vchunk(2), zone, flash)
    assert is_applicable(vchunk(4), zone, flash)
    assert is_applicable(BLOCK, zone, flash)


@settings(max_examples=20, deadline=None)
@given(st.sampled_from([1, 2, 4]), st.sampled_from([1, 2, 4]),
       st.sampled_from([4, 8, 16]))
def test_zone_element_counts(ways, segs, P):
    flash = FlashGeometry(n_channels=4, ways_per_channel=ways,
                          blocks_per_lun=8, pages_per_block=4,
                          page_bytes=4096)
    if P > flash.n_luns:
        return
    zone = ZoneGeometry(parallelism=P, n_segments=segs)
    for spec in SPECS:
        if not is_applicable(spec, zone, flash):
            continue
        try:
            lay = build_layout(flash, spec)
        except ValueError:
            continue
        n_e = elements_per_zone(lay, zone)
        n_g = groups_per_zone(lay, zone)
        assert n_e * lay.blocks_per_element == zone.blocks_per_zone
        assert n_e % n_g == 0


def test_zn540_matches_paper_numbers():
    flash, zone = zn540()
    assert flash.n_luns == 4
    assert flash.page_bytes == 16 * 1024
    assert flash.pages_per_block == 768
    # 1 GiB-class zone from 22 superblocks of 4 blocks (paper §6.1)
    assert zone.blocks_per_zone == 88
    assert zone.zone_bytes(flash) == 88 * 768 * 16 * 1024
    assert flash.n_blocks // zone.blocks_per_zone == 48  # 48 zones


def test_custom16_matches_paper_numbers():
    flash = custom16()
    assert flash.n_luns == 16
    lay = build_layout(flash, SUPERBLOCK)
    assert lay.n_elements == 128          # "128 superblocks"
    assert lay.pages_per_element * flash.page_bytes == 128 * 1024 * 1024
