"""Tests for the evolutionary + successive-halving allocator search.

Four guarantees:

1. seeded determinism: same seed => identical generation history and
   Pareto archive (and a different seed actually explores differently);
2. elitist monotonicity: the best-so-far objective never increases
   across generations;
3. the halving schedule promotes exactly the top ``ceil(n/eta)`` of
   each rung's ranking -- and only those -- to the next rung, and only
   full-fidelity rows reach the archive/best;
4. the acceptance bar: on a seeded 2-tenant x 4-device fleet, evolve
   reaches an objective <= the best of a 32-config random search using
   <= half the random baseline's batched-evaluator budget (dispatches
   AND full-fidelity-equivalent evals), as recorded in
   ``BENCH_fleet.json`` by ``tools/bench.py``.
"""

import math

import pytest

from repro.core import engine as E
from repro.core.elements import SUPERBLOCK
from repro.core.geometry import FlashGeometry, ZoneGeometry
from repro.fleet import (Evaluator, EvolveParams, SearchSpace, evolve,
                         evolve_vs_random)

SPACE = SearchSpace(segments=(4, 2), chunks=(8, 16))   # 32 configs
PARAMS = EvolveParams(population=8, generations=3)


def tiny_engine():
    flash = FlashGeometry(n_channels=4, ways_per_channel=1,
                          blocks_per_lun=16, pages_per_block=4,
                          page_bytes=4096)
    return E.ZoneEngine(flash, ZoneGeometry(4, 4), SUPERBLOCK,
                        max_active=6)


@pytest.fixture(scope="module")
def eng():
    return tiny_engine()


@pytest.fixture(scope="module")
def result(eng):
    return evolve(eng, space=SPACE, params=PARAMS, seed=1, n_devices=4)


def test_space_codec_round_trips():
    for fc in SPACE.grid():
        assert SPACE.decode(SPACE.encode(fc)) == fc
    assert len(SPACE) == 32


def test_seeded_determinism(eng, result):
    rerun = evolve(eng, space=SPACE, params=PARAMS, seed=1, n_devices=4)
    assert rerun.history == result.history
    assert [r["config"] for r in rerun.archive] == \
        [r["config"] for r in result.archive]
    assert rerun.best == result.best
    # a different seed proposes a different generation 0
    other = evolve(eng, space=SPACE,
                   params=EvolveParams(population=8, generations=1),
                   seed=2, n_devices=4)
    assert other.history[0]["rungs"][0]["candidates"] != \
        result.history[0]["rungs"][0]["candidates"]


def test_best_objective_monotone_nonincreasing(result):
    curve = [h["best_so_far"] for h in result.history]
    assert all(b <= a + 1e-12 for a, b in zip(curve, curve[1:]))
    # best-so-far is the running minimum of the per-generation bests
    for i, h in enumerate(result.history):
        assert h["best_so_far"] == pytest.approx(
            min(g["best_of_gen"] for g in result.history[: i + 1]))


def test_halving_promotes_only_rung_survivors(result):
    eta = PARAMS.eta
    for h in result.history:
        rungs = h["rungs"]
        assert [r["fidelity"] for r in rungs] == \
            list(PARAMS.rung_fidelities)
        for lo, hi in zip(rungs, rungs[1:]):
            keep = max(1, math.ceil(len(lo["candidates"]) / eta))
            # survivors are exactly the rung ranking's top-keep slice,
            # and the next rung evaluates exactly those
            assert lo["survivors"] == lo["ranked"][:keep]
            assert hi["candidates"] == lo["survivors"]
        # ranking is a permutation of the rung's candidates
        for r in rungs:
            assert sorted(r["ranked"]) == sorted(r["candidates"])
    # only full-fidelity rows feed the archive and the best row
    assert all(r["fidelity"] == 1.0 for r in result.archive)
    assert result.best["fidelity"] == 1.0
    final = {n for h in result.history
             for n in h["rungs"][-1]["candidates"]}
    assert set(result.rows) == final
    assert {r["config"] for r in result.archive} <= final


def test_archive_is_nondominated(result):
    keys = ("dlwa", "wear_cv", "p99_latency_s")
    front = result.archive
    for a in front:
        for b in front:
            if a is b:
                continue
            dominates = (all(b[k] <= a[k] for k in keys)
                         and any(b[k] < a[k] for k in keys))
            assert not dominates, (a["config"], b["config"])


def test_empty_batch_does_not_skew_ledger(eng):
    """Regressions: ``stack_dyn([])`` died inside ``tree_map`` with an
    opaque error, and ``Evaluator.evaluate([])`` dispatched an empty
    batch while still counting a dispatch -- skewing the budget ledger
    evolve's halving decisions read."""
    with pytest.raises(ValueError, match="at least one DynConfig"):
        E.stack_dyn([])
    ev = Evaluator(eng, n_devices=4)
    assert ev.evaluate([]) == []
    assert ev.evaluate([], fidelity=0.25) == []
    assert (ev.n_dispatches, ev.n_evals, ev.lane_ops) == (0, 0.0, 0)
    # a real batch afterwards counts exactly once
    ev.evaluate(SPACE.grid()[:2])
    assert (ev.n_dispatches, ev.n_evals) == (1, 2.0)


def test_evaluator_ledger_and_fidelity(eng):
    ev = Evaluator(eng, n_devices=4)
    configs = SPACE.grid()[:4]
    full = ev.evaluate(configs)
    assert ev.n_dispatches == 1 and ev.n_evals == 4.0
    cheap = ev.evaluate(configs, fidelity=0.25)
    assert ev.n_dispatches == 2 and ev.n_evals == 5.0
    assert ev.lane_ops > 0
    # truncated rungs really are cheaper: fewer real ops dispatched
    assert sum(r["host_pages"] for r in cheap) < \
        sum(r["host_pages"] for r in full)
    for r in cheap:
        assert r["fidelity"] == 0.25


def test_acceptance_evolve_beats_random_at_half_budget(eng):
    """ISSUE 4 acceptance: seeded 2-tenant x 4-device fleet -- evolve
    reaches an objective <= the best of 32-config random search with
    <= half the batched evaluator dispatches and <= half the
    full-fidelity-equivalent evals."""
    rep = evolve_vs_random(eng, space=SPACE, params=PARAMS,
                           random_n=32, seed=0, n_devices=4)
    assert rep["random"]["n_configs"] == 32.0
    assert rep["evolve"]["reached_target"]
    assert rep["evolve"]["best_objective"] <= \
        rep["random"]["best_objective"] + 1e-12
    assert rep["evolve"]["n_dispatches"] <= \
        rep["random"]["n_dispatches"] / 2
    assert rep["evolve"]["n_evals"] <= rep["random"]["n_evals"] / 2
