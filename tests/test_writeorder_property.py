"""Property tests: the closed-form striping accounting in core/zns.py
must equal a brute-force page-by-page placement simulation for every
element kind, geometry, and write pointer."""

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import zns
from repro.core.elements import ElementSpec, ElementKind, hchunk, vchunk, BLOCK, SUPERBLOCK, FIXED


def brute_force_block_pages(wp, P, segs, ppb):
    """Place pages one at a time following the paper's write order."""
    blocks = np.zeros((segs, P), dtype=np.int64)
    for p in range(wp):
        seg = p // (P * ppb)
        q = p % (P * ppb)
        col = q % P
        blocks[seg, col] += 1
    return blocks


@settings(max_examples=60, deadline=None)
@given(st.integers(1, 4).map(lambda x: 2 ** x),   # P in {2,4,8,16}
       st.integers(1, 4),                          # segments
       st.sampled_from([4, 8, 16]),                # pages per block
       st.floats(0.0, 1.0))
def test_pages_per_block_matches_bruteforce(P, segs, ppb, frac):
    cap = P * segs * ppb
    wp = int(round(frac * cap))
    fast = zns.pages_per_block(wp, P, segs, ppb)
    slow = brute_force_block_pages(wp, P, segs, ppb)
    assert (np.asarray(fast) == slow).all()


@settings(max_examples=60, deadline=None)
@given(st.sampled_from([2, 4, 8]),                 # P
       st.sampled_from([1, 2, 4]),                 # segments
       st.sampled_from([4, 8]),                    # ppb
       st.floats(0.0, 1.0),
       st.sampled_from(["block", "vchunk2", "hchunk2", "superblock",
                        "fixed"]))
def test_element_pages_partition_and_total(P, segs, ppb, frac, kind):
    spec = {"block": BLOCK, "vchunk2": vchunk(2), "hchunk2": hchunk(2),
            "superblock": SUPERBLOCK, "fixed": FIXED}[kind]
    # applicability constraints
    if spec.kind is ElementKind.VCHUNK and P % spec.chunk:
        return
    if spec.kind is ElementKind.HCHUNK and segs % spec.chunk:
        return
    if spec.kind is ElementKind.SUPERBLOCK and False:
        return
    cap = P * segs * ppb
    wp = int(round(frac * cap))
    if spec.kind is ElementKind.SUPERBLOCK:
        # superblock slots span the full parallelism of the zone
        pages = zns.element_pages(wp, spec, P, segs, ppb)
    else:
        pages = zns.element_pages(wp, spec, P, segs, ppb)
    # partition: element page counts sum to the write pointer
    assert int(np.sum(pages)) == wp
    # bound: no element exceeds its capacity
    blocks_per = {"block": 1, "vchunk2": 2, "hchunk2": 2,
                  "superblock": P, "fixed": P * segs}[kind]
    assert int(np.max(pages, initial=0)) <= blocks_per * ppb
    # slot count matches the layout math
    assert len(pages) == zns.n_slots(spec, P, segs)


@settings(max_examples=40, deadline=None)
@given(st.sampled_from([4, 8]), st.sampled_from([2, 4]),
       st.sampled_from([4, 8]), st.floats(0.05, 0.95))
def test_pad_stream_covers_exactly_the_padding(P, segs, ppb, frac):
    """pad_stream must emit exactly (capacity - written) pages for every
    partially-written element and nothing for released ones."""
    spec = vchunk(2)
    if P % 2:
        return
    cap = P * segs * ppb
    wp = max(1, int(round(frac * cap)))
    pages = zns.element_pages(wp, spec, P, segs, ppb)
    elem_cap = 2 * ppb
    padded_slots = np.nonzero((pages > 0) & (pages < elem_cap))[0]
    expected_pad = int(np.sum(elem_cap - pages[padded_slots]))
    luns, chans = zns.pad_stream(wp, cap, spec, P, ppb,
                                 np.arange(P), padded_slots, 4)
    assert len(luns) == expected_pad
    assert (chans == luns % 4).all()
