"""Shared test setup.

If the real ``hypothesis`` package is unavailable (the pinned tier-1
image does not ship it and cannot install it), register the minimal
deterministic stub from ``_hypothesis_stub.py`` under the ``hypothesis``
name so every property-test module still imports and runs.
"""

import importlib.util
import pathlib
import sys

try:
    import hypothesis  # noqa: F401  (real package wins when present)
except ModuleNotFoundError:
    _spec = importlib.util.spec_from_file_location(
        "hypothesis",
        pathlib.Path(__file__).with_name("_hypothesis_stub.py"))
    _mod = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_mod)
    sys.modules["hypothesis"] = _mod
    sys.modules["hypothesis.strategies"] = _mod.strategies
