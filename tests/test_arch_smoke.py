"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes + finiteness (no NaNs), plus a serving
prefill->decode consistency check for each family."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_arch, list_archs
from repro.models import model as MDL
from repro.models import transformer as T
from repro.train import optimizer as OPT

ARCHS = list(list_archs())

#: architectures whose tiny-config jit compiles alone take 10-50 s (long
#: layer patterns / MoE + MLA / recurrent scans); their forward/train
#: smoke cases run only with `-m slow` so tier-1 stays fast.
SLOW_ARCHS = frozenset({
    "jamba-1.5-large-398b", "deepseek-v2-236b", "xlstm-125m",
    "seamless-m4t-medium", "llama-3.2-vision-11b",
})
ARCHS_HEAVY = [pytest.param(a, marks=pytest.mark.slow)
               if a in SLOW_ARCHS else a for a in ARCHS]
RNG = np.random.default_rng(0)


def make_batch(cfg, b=2, s=8):
    tokens = jnp.asarray(RNG.integers(0, cfg.vocab, (b, s)), jnp.int32)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.family in ("vlm", "audio"):
        m = 8
        batch["memory"] = jnp.asarray(
            RNG.standard_normal((b, m, cfg.d_model)) * 0.1, jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCHS_HEAVY)
def test_train_step_finite(arch):
    cfg = get_arch(arch).reduced()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg)
    logits, aux = T.forward_train(params, cfg, batch["tokens"],
                                  memory=batch.get("memory"))
    assert logits.shape == (*batch["tokens"].shape, cfg.padded_vocab)
    # padding tail is masked to -inf
    if cfg.padded_vocab > cfg.vocab:
        assert bool((logits[..., cfg.vocab:] < -1e29).all())
    assert logits.dtype == jnp.float32
    assert bool(jnp.isfinite(logits[..., :cfg.vocab]).all())
    loss, metrics = MDL.loss_fn(params, cfg, batch)
    assert np.isfinite(float(loss))
    # one optimizer step moves parameters and keeps loss finite
    ts = MDL.make_train_step(cfg, OPT.AdamWConfig(total_steps=4))
    p2, _, m = ts(params, OPT.init(params), batch)
    assert np.isfinite(float(m["loss"]))
    moved = jax.tree.map(
        lambda a, b_: bool(jnp.any(a.astype(jnp.float32)
                                   != b_.astype(jnp.float32))), params, p2)
    assert any(jax.tree.leaves(moved))


@pytest.mark.parametrize("arch", ARCHS_HEAVY)
def test_prefill_then_decode_matches_train_logits(arch):
    """Serving path correctness: prefill over s tokens then one decode step
    must reproduce the train-forward logits of the next position."""
    cfg = get_arch(arch).reduced()
    params = T.init_params(jax.random.PRNGKey(1), cfg)
    b, s = 2, 12
    batch = make_batch(cfg, b, s + 1)
    tokens = batch["tokens"]
    memory = batch.get("memory")
    max_seq = 32

    caches = T.init_caches(cfg, b, max_seq,
                           memory_len=memory.shape[1] if memory is not None
                           else 0)
    logits_p, caches = T.forward_prefill(params, cfg, tokens[:, :s],
                                         caches, memory=memory)
    assert logits_p.shape == (b, cfg.padded_vocab)

    # full-forward reference for position s-1 (predicting token s)
    logits_full, _ = T.forward_train(params, cfg, tokens[:, :s + 1],
                                     memory=memory)
    ref = logits_full[:, s - 1]
    err = float(jnp.max(jnp.abs(logits_p - ref))
                / (jnp.max(jnp.abs(ref)) + 1e-9))
    families_with_state_prefill = ("ssm", "hybrid")
    if cfg.family not in families_with_state_prefill:
        assert err < 5e-2, f"prefill/train mismatch: {err}"

        # decode one step: feed token s, expect logits for position s
        pos = jnp.full((b,), s, jnp.int32)
        logits_d, caches = T.forward_decode(params, cfg, tokens[:, s],
                                            caches, pos)
        ref_d = logits_full[:, s]
        err_d = float(jnp.max(jnp.abs(logits_d - ref_d))
                      / (jnp.max(jnp.abs(ref_d)) + 1e-9))
        assert err_d < 5e-2, f"decode/train mismatch: {err_d}"
    else:
        # recurrent-state archs: prefill is shape-correct; step-by-step
        # decode from scratch must match the train forward
        caches2 = T.init_caches(cfg, b, max_seq,
                                memory_len=memory.shape[1]
                                if memory is not None else 0)
        for i in range(4):
            pos = jnp.full((b,), i, jnp.int32)
            logits_d, caches2 = T.forward_decode(params, cfg,
                                                 tokens[:, i], caches2, pos)
        ref_d = logits_full[:, 3]
        err_d = float(jnp.max(jnp.abs(logits_d - ref_d))
                      / (jnp.max(jnp.abs(ref_d)) + 1e-9))
        assert err_d < 5e-2, f"recurrent decode/train mismatch: {err_d}"


@pytest.mark.parametrize("arch", ARCHS)
def test_input_specs_cover_all_cells(arch):
    from repro.configs.base import SHAPES
    cfg = get_arch(arch)
    for name, cell in SHAPES.items():
        if name == "long_500k" and not cfg.sub_quadratic:
            continue
        specs = MDL.input_specs(cfg, cell)
        leaves = jax.tree.leaves(specs)
        assert leaves, (arch, name)
        for leaf in leaves:
            assert isinstance(leaf, jax.ShapeDtypeStruct)


def test_param_counts_match_scale():
    """Full-config parameter counts are in the advertised ballpark."""
    expected = {
        "codeqwen1.5-7b": (6e9, 9e9),
        "phi3-mini-3.8b": (3e9, 4.6e9),
        "minitron-8b": (6e9, 10e9),   # assignment config (GQA kv=8) gives 6.7B
        "granite-3-8b": (7e9, 10e9),
        "llama4-scout-17b-a16e": (90e9, 120e9),
        "deepseek-v2-236b": (200e9, 260e9),
        "llama-3.2-vision-11b": (8e9, 13e9),
        "xlstm-125m": (0.09e9, 0.2e9),
        "jamba-1.5-large-398b": (330e9, 420e9),
        "seamless-m4t-medium": (0.5e9, 1.6e9),  # backbone only; frontend is a stub
    }
    for arch, (lo, hi) in expected.items():
        n = MDL.param_count(get_arch(arch))
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9}, {hi/1e9}]"
