"""Distributed-runtime tests on a small multi-device mesh.

jax locks the device count at first init, so each test runs a child
python with XLA_FLAGS=--xla_force_host_platform_device_count=8.
"""

import os
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent


def run_child(code: str) -> str:
    pre = (
        "import os\n"
        "os.environ['XLA_FLAGS'] = "
        "'--xla_force_host_platform_device_count=8'\n"
    )
    proc = subprocess.run(
        [sys.executable, "-c", pre + code],
        capture_output=True, text=True, timeout=420,
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin",
             "HOME": "/tmp",
             # without this, jax probes for accelerator backends and can
             # stall for minutes per child on machines without them --
             # these children force host devices, so CPU is what we mean
             "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu")},
        cwd=str(REPO))
    assert proc.returncode == 0, f"child failed:\n{proc.stderr[-3000:]}"
    return proc.stdout


def test_sharded_train_step_matches_single_device():
    """A reduced arch trained on a 2x4 mesh with the production sharding
    rules must produce the same loss as unsharded execution."""
    out = run_child("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_arch
from repro.models import model as MDL
from repro.models import transformer as T
from repro.train import optimizer as OPT
from repro.launch import sharding as SH
from repro.launch.mesh import make_test_mesh

cfg = get_arch("granite-3-8b").reduced()
params = T.init_params(jax.random.PRNGKey(0), cfg)
rng = np.random.default_rng(0)
batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (8, 16)), jnp.int32)}
batch["labels"] = batch["tokens"]

loss_ref, _ = MDL.loss_fn(params, cfg, batch)

mesh = make_test_mesh(2, 4)
p_shard = SH.param_shardings(cfg, mesh, jax.eval_shape(lambda: params))
b_shard = SH.batch_shardings(mesh, jax.eval_shape(lambda: batch), 8)
params_s = jax.tree.map(jax.device_put, params, p_shard)
batch_s = jax.tree.map(jax.device_put, batch, b_shard)
with mesh:
    loss_s, _ = jax.jit(lambda p, b: MDL.loss_fn(p, cfg, b))(params_s, batch_s)
err = abs(float(loss_s) - float(loss_ref))
assert err < 2e-2, f"sharded loss mismatch: {err}"
print("OK", float(loss_ref), float(loss_s))
""")
    assert "OK" in out


def test_decode_with_sharded_cache_matches():
    out = run_child("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_arch
from repro.models import model as MDL
from repro.models import transformer as T
from repro.launch import sharding as SH
from repro.launch.mesh import make_test_mesh

cfg = get_arch("granite-3-8b").reduced()
params = T.init_params(jax.random.PRNGKey(0), cfg)
rng = np.random.default_rng(1)
b = 4
caches = T.init_caches(cfg, b, 16)
token = jnp.asarray(rng.integers(0, cfg.vocab, (b,)), jnp.int32)
pos = jnp.zeros((b,), jnp.int32)
logits_ref, _ = T.forward_decode(params, cfg, token, caches, pos)

mesh = make_test_mesh(2, 4)
p_shard = SH.param_shardings(cfg, mesh, jax.eval_shape(lambda: params))
c_shard = SH.cache_shardings(cfg, mesh, jax.eval_shape(lambda: caches), b)
params_s = jax.tree.map(jax.device_put, params, p_shard)
caches_s = jax.tree.map(jax.device_put, caches, c_shard)
with mesh:
    logits_s, new_c = jax.jit(
        lambda p, t, c, po: T.forward_decode(p, cfg, t, c, po)
    )(params_s, token, caches_s, pos)
err = float(jnp.max(jnp.abs(logits_s[:, :cfg.vocab]
                            - logits_ref[:, :cfg.vocab])))
rel = err / (float(jnp.max(jnp.abs(logits_ref[:, :cfg.vocab]))) + 1e-9)
assert rel < 3e-2, rel
print("OK", rel)
""")
    assert "OK" in out


def test_pipeline_apply_matches_sequential():
    out = run_child("""
import jax, jax.numpy as jnp, numpy as np
from repro.launch.mesh import make_test_mesh
from repro.train.pipeline import pipeline_apply, pipeline_utilization
import jax.sharding as shd

mesh = jax.make_mesh((4,), ("stage",))
S = 4
rng = np.random.default_rng(0)
Ws = jnp.asarray(rng.standard_normal((S, 8, 8)) * 0.3, jnp.float32)
x = jnp.asarray(rng.standard_normal((8, 16, 8)), jnp.float32)

def block(w, a):
    return jnp.tanh(a @ w)

# sequential reference
ref = x
for i in range(S):
    ref = block(Ws[i], ref)

out = pipeline_apply(block, Ws, x, mesh=mesh, axis="stage", n_micro=4)
err = float(jnp.max(jnp.abs(out - ref)))
assert err < 1e-5, err
assert abs(pipeline_utilization(4, 4) - 4/7) < 1e-9
print("OK", err)
""")
    assert "OK" in out


def test_hierarchical_psum_equals_flat_psum():
    out = run_child("""
import jax, jax.numpy as jnp, numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P
from repro.train.grad import hierarchical_psum

mesh = jax.make_mesh((2, 4), ("pod", "data"))
x = jnp.arange(128, dtype=jnp.float32).reshape(32, 4)

def flat(a):
    return jax.lax.psum(a, ("pod", "data"))

def hier(a):
    return hierarchical_psum(a, in_pod_axis="data", cross_pod_axis="pod")

f = shard_map(flat, mesh=mesh, in_specs=P("data"), out_specs=P("data"),
              check_rep=False)
h = shard_map(hier, mesh=mesh, in_specs=P("data"), out_specs=P("data"),
              check_rep=False)
np.testing.assert_allclose(np.asarray(f(x)), np.asarray(h(x)), rtol=1e-6)
print("OK")
""")
    assert "OK" in out


def test_multipod_mesh_builds():
    out = run_child("""
import jax
# 8 host devices: use a scaled-down multi-pod mesh shape directly
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
assert mesh.shape == {"pod": 2, "data": 2, "model": 2}
from repro.launch.mesh import dp_axes
assert dp_axes(mesh) == ("pod", "data")
print("OK")
""")
    assert "OK" in out


def test_dryrun_cell_compiles_on_512_devices():
    """Deliverable (e) regression: one real dry-run cell lowers+compiles
    on the 512-placeholder-device production mesh."""
    import json
    import tempfile
    out = tempfile.mkdtemp()
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "xlstm-125m", "--shape", "decode_32k",
         "--mesh", "multi", "--out", out],
        capture_output=True, text=True, timeout=420,
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin",
             "HOME": "/tmp",
             "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu")},
        cwd=str(REPO))
    assert proc.returncode == 0, proc.stdout + proc.stderr[-2000:]
    res = json.loads(
        (Path(out) / "xlstm-125m__decode_32k__multi.json").read_text())
    assert res["ok"]
    assert res["devices"] == 512
    assert res["mesh_shape"] == {"pod": 2, "data": 16, "model": 16}
    assert res["roofline"]["t_memory_s"] > 0
