"""Fleet-layer tests: tenant interleaving, heterogeneous padding, search.

Covers the three guarantees the fleet layer is built on:

1. the tenant plumbing is free: a 1-tenant x 1-device (parity-off)
   fleet program is bit-identical to the plain ``run_program`` path;
2. heterogeneous-geometry padding is exact: a lane run under a
   ``DynConfig`` effective capacity on the padded static config leaves
   the same element-level state as an engine built with the smaller
   geometry outright, and batching lanes never changes per-device
   metrics vs independent runs;
3. the allocator search is deterministic under a fixed seed, and the
   batched engine path agrees with a real per-op ``ZNSArray`` replay.
"""

import numpy as np
import pytest

from repro.core import engine as E
from repro.core import workloads
from repro.core.elements import BLOCK, SUPERBLOCK, vchunk
from repro.core.geometry import FlashGeometry, ZoneGeometry
from repro.fleet import (FleetConfig, N_TENANTS, build_fleet_batch,
                         evaluate_configs, grid_space, interleave_tenants,
                         pad_programs, pareto_front, random_space,
                         run_configs_legacy, run_fleet, score_rows,
                         stripe_program, tag_tenant)
from repro.fleet import runner


def tiny_flash():
    return FlashGeometry(n_channels=4, ways_per_channel=1,
                         blocks_per_lun=16, pages_per_block=4,
                         page_bytes=4096)


def tiny_engine(spec=SUPERBLOCK, n_segments=4, max_active=6):
    flash = tiny_flash()
    return E.ZoneEngine(flash, ZoneGeometry(4, n_segments), spec,
                        max_active=max_active)


def churn_program(n_zones=3, cycles=2, base_pages=3):
    rows = []
    for cyc in range(cycles):
        for z in range(n_zones):
            rows.append((E.OP_WRITE, z, base_pages + 2 * z + cyc,
                         E.F_HOST))
            rows.append((E.OP_FINISH, z, 0, 0))
        for z in range(n_zones):
            rows.append((E.OP_RESET, z, 0, 0))
    return E.encode_program(rows)


def assert_states_equal(a, b, n, ctx=""):
    for name in ("elem_wear", "elem_avail", "elem_pages", "elem_zone"):
        assert np.array_equal(np.asarray(getattr(a, name)[:n]),
                              np.asarray(getattr(b, name)[:n])), \
            f"{name} {ctx}"
    for name in ("host_pages", "dummy_pages", "block_erases", "n_active"):
        assert int(getattr(a, name)) == int(getattr(b, name)), \
            f"{name} {ctx}"


# --------------------------------------------------------------------- #
# 1. tenant plumbing is bit-free on the degenerate fleet
# --------------------------------------------------------------------- #
def test_single_tenant_single_device_bit_identical():
    eng = tiny_engine()
    plain = churn_program()
    tagged = tag_tenant(plain, 0)
    merged = interleave_tenants([tagged])
    assert np.array_equal(merged, tagged)
    striped = stripe_program(merged, n_devices=1, chunk_pages=4,
                             parity=False,
                             member_zone_pages=eng.cfg.zone_pages,
                             parity_tenant=1)
    assert len(striped) == 1
    # width-4 plain scan vs width-5 fleet lane: identical final state
    s_plain, _ = eng.run(eng.init_state(), plain)
    res = run_fleet(eng, pad_programs(striped), n_tenants=1)
    runner.assert_all_ok(res)
    n = eng.cfg.n_elements
    lane = type(s_plain)(*[leaf[0] for leaf in res.states])
    assert_states_equal(s_plain, lane, n, "1x1 fleet")
    # chunked writes re-concatenate to the original host page counts
    assert int(res.host_delta.sum()) == int(s_plain.host_pages)


def test_repeated_finish_emits_parity_once():
    """FINISH on a FULL superzone is a no-op in ZNSArray; the
    program-space striper must not re-emit the partial-stripe parity
    chunk on a repeated FINISH (regression: the duplicate write was
    illegal on the FULL member zone)."""
    eng = tiny_engine()
    prog = tag_tenant(E.encode_program([
        (E.OP_WRITE, 0, 6, E.F_HOST),
        (E.OP_FINISH, 0, 0, 0),
        (E.OP_FINISH, 0, 0, 0),
    ]), 0)
    striped = stripe_program(prog, n_devices=3, chunk_pages=4,
                             parity=True,
                             member_zone_pages=eng.cfg.zone_pages,
                             parity_tenant=1)
    parity_writes = sum(
        1 for dev in striped for row in dev
        if row[0] == E.OP_WRITE and row[4] == 1)
    assert parity_writes == 1
    res = run_fleet(eng, pad_programs(striped), n_tenants=1)
    runner.assert_all_ok(res)


def test_interleave_round_robin_order():
    a = tag_tenant(E.encode_program([(E.OP_WRITE, 0, 1, 1)] * 3), 0)
    b = tag_tenant(E.encode_program([(E.OP_WRITE, 1, 1, 1)] * 2), 1)
    merged = interleave_tenants([a, b])
    assert merged[:, 4].tolist() == [0, 1, 0, 1, 0]


# --------------------------------------------------------------------- #
# 2. heterogeneous-geometry padding is exact
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("spec", [SUPERBLOCK, BLOCK, vchunk(2)],
                         ids=lambda s: s.name)
def test_hetero_padding_matches_exact_geometry(spec):
    big = tiny_engine(spec, n_segments=4)
    small = tiny_engine(spec, n_segments=2)
    assert big.cfg.n_elements == small.cfg.n_elements
    prog = churn_program()
    s_exact, _ = small.run(small.init_state(), prog)
    # the smaller geometry has MORE zones (8) than the padded static
    # table holds (4); only the shared prefix is addressable, and an
    # n_zones override past the static table now raises (it used to
    # silently index past the padded zone tables)
    s_pad, _ = big.run(
        big.init_state(), prog,
        big.dyn(zone_pages=small.cfg.zone_pages,
                n_zones=min(small.cfg.n_zones, big.cfg.n_zones)))
    assert_states_equal(s_exact, s_pad, big.cfg.n_elements,
                        f"padded {spec.name}")


def test_hetero_batch_matches_independent_runs():
    """A mixed-geometry batched dispatch must leave every lane exactly
    as its independent (unbatched) run would."""
    big = tiny_engine(SUPERBLOCK, n_segments=4)
    small = tiny_engine(SUPERBLOCK, n_segments=2)
    prog = churn_program()
    dyn = E.stack_dyn([
        big.dyn(),
        big.dyn(zone_pages=small.cfg.zone_pages),
        big.dyn(wear_aware=False),
    ])
    states, _ = big.run_batch(big.init_state(),
                              np.stack([prog, prog, prog]), dyn)
    singles = [
        big.run(big.init_state(), prog)[0],
        big.run(big.init_state(), prog,
                big.dyn(zone_pages=small.cfg.zone_pages))[0],
        big.run(big.init_state(), prog, big.dyn(wear_aware=False))[0],
    ]
    n = big.cfg.n_elements
    for k, ref in enumerate(singles):
        lane = type(ref)(*[leaf[k] for leaf in states])
        assert_states_equal(ref, lane, n, f"lane {k}")


def test_shrunk_alloc_never_steals_in_use_elements():
    """A group whose free count is in [take_eff, take) is feasible for
    a capacity-shrunk lane, but the claimed prefix must be the *free*
    elements -- the non-free top_k filler must never be reordered ahead
    of them (regression: elements VALID in another zone were stolen).

    The short-group state is built surgically: legal single-device
    programs keep per-group free counts at or above ``take`` whenever
    an EMPTY zone exists (zones tile the element set), but the engine
    must stay safe for any state a batched lane can reach."""
    import jax.numpy as jnp
    from repro.core.alloc_exact import AVAIL_ALLOCATED, AVAIL_VALID

    eng = tiny_engine(SUPERBLOCK, n_segments=4, max_active=8)
    half = eng.dyn(zone_pages=eng.cfg.zone_pages // 2)  # take_eff = 2
    s = eng.init_state()
    # elements 0..13 in use by other zones; only 14, 15 free
    avail = np.full(17, AVAIL_VALID, np.int32)
    avail[1::2] = AVAIL_ALLOCATED
    avail[14:] = 0  # FREE (incl. scratch)
    zone_of = np.repeat(np.arange(4, dtype=np.int32), 4)
    s = s._replace(
        elem_avail=jnp.asarray(avail),
        elem_zone=jnp.asarray(np.r_[zone_of[:14], -1, -1, -1]))
    avail_before = avail.copy()
    s, tr = eng.apply(s, (E.OP_WRITE, 3, 1, E.F_HOST), half)
    assert bool(tr.ok)
    claimed = np.asarray(s.zone_elems[3])
    assert sorted(int(e) for e in claimed if e >= 0) == [14, 15]
    # nothing belonging to other zones was touched
    assert np.array_equal(np.asarray(s.elem_avail[:14]),
                          avail_before[:14])
    assert np.array_equal(np.asarray(s.elem_zone[:14]), zone_of[:14])


def test_dyn_wear_aware_matches_static_engine():
    eng_ff = tiny_engine(BLOCK)
    eng = E.ZoneEngine(tiny_flash(), ZoneGeometry(4, 4), BLOCK,
                       max_active=6, wear_aware=False)
    prog = churn_program()
    s_static, _ = eng.run(eng.init_state(), prog)
    s_dyn, _ = eng_ff.run(eng_ff.init_state(), prog,
                          eng_ff.dyn(wear_aware=False))
    assert_states_equal(s_static, s_dyn, eng.cfg.n_elements, "ff dyn")


# --------------------------------------------------------------------- #
# 3. search: determinism + agreement with the per-op array replay
# --------------------------------------------------------------------- #
AXES = dict(segments=(4, 2), chunks=(8, 16))


def test_random_space_deterministic():
    a = random_space(7, 8, **AXES)
    b = random_space(7, 8, **AXES)
    assert a == b
    c = random_space(8, 8, **AXES)
    assert a != c  # a different seed explores differently


def test_search_objective_deterministic():
    eng = tiny_engine(SUPERBLOCK, n_segments=4, max_active=6)
    configs = random_space(3, 6, **AXES)
    rows1 = score_rows(evaluate_configs(eng, configs, n_devices=3))
    rows2 = score_rows(evaluate_configs(eng, configs, n_devices=3))
    assert [r["config"] for r in rows1] == [r["config"] for r in rows2]
    for r1, r2 in zip(rows1, rows2):
        assert r1 == r2
    front = pareto_front(rows1)
    assert 1 <= len(front) <= len(rows1)
    # front members are flagged, non-members dominated
    for r in rows1:
        assert r["pareto"] in (0.0, 1.0)
    assert all(r["pareto"] == 1.0 for r in front)


def test_grid_space_covers_cross_product():
    configs = grid_space(**AXES)
    assert len(configs) == len(set(configs)) == 2 * 2 * 2 * 2 * 2


def test_engine_path_matches_legacy_array_replay():
    """The batched engine fleet (padded geometry, program-space parity)
    must report the same array-level traffic as a real ZNSArray over
    per-op legacy devices built with each config's true geometry."""
    flash = tiny_flash()
    eng = E.ZoneEngine(flash, ZoneGeometry(4, 4), SUPERBLOCK,
                       max_active=6)
    configs = [FleetConfig("dlwa_pair", 4, 8, True, True),
               FleetConfig("dlwa_write", 2, 16, False, True),
               FleetConfig("dlwa_pair", 2, 8, True, False)]
    programs, dyn, merged = build_fleet_batch(eng, configs, n_devices=3)
    res = run_fleet(eng, programs, dyn=dyn, n_tenants=N_TENANTS)
    runner.assert_all_ok(res)
    legacy = run_configs_legacy(flash, SUPERBLOCK, configs, merged,
                                parallelism=4, n_devices=3,
                                max_active=6)
    for k, (fc, rep) in enumerate(zip(configs, legacy)):
        lanes = np.arange(3 * k, 3 * (k + 1))
        mine = runner.config_report(res, eng, lanes)
        assert mine["host_pages"] + mine["parity_pages"] == \
            rep["host_pages"] + rep["parity_pages"], fc
        assert mine["parity_pages"] == rep["parity_pages"], fc
        assert mine["dummy_pages"] == rep["dummy_pages"], fc
        assert mine["dlwa"] == pytest.approx(rep["dlwa"]), fc
        assert mine["block_erases"] == rep["total_block_erases"], fc
        assert mine["wear_cv"] == pytest.approx(rep["wear_cv"]), fc


def test_fleet_vs_legacy_speedup_smoke():
    """The BENCH_fleet pipeline end to end on a tiny geometry: both
    paths agree on DLWA (asserted inside) and the report carries every
    field tools/bench.py archives."""
    from repro.fleet.search import fleet_vs_legacy_speedup

    configs = [FleetConfig("dlwa_pair", 4, 8, True, True),
               FleetConfig("dlwa_write", 2, 16, False, False)]
    rep = fleet_vs_legacy_speedup(
        configs=configs, repeats=1, n_devices=3,
        flash=tiny_flash(), zone_geom=ZoneGeometry(4, 4), max_active=6)
    assert rep["n_configs"] == 2.0
    for key in ("legacy_s", "legacy_replay_s", "engine_s", "speedup",
                "replay_speedup", "fleet_ops"):
        assert rep[key] > 0, key


def test_fleet_timing_sane():
    eng = tiny_engine()
    prog = tag_tenant(workloads.dlwa_program(eng, occupancy=0.5,
                                             n_zones=2), 0)
    res = run_fleet(eng, pad_programs([prog, prog]), n_tenants=1)
    active = res.pages > 0
    assert (res.completions[active] > 0).all()
    assert (res.latencies[active] > 0).all()
    # NOP / zero-page ops contribute nothing
    assert (res.completions[~active] == 0).all()
    assert np.allclose(res.makespans, res.completions.max(axis=1))
    p99 = res.tenant_p99_latency(np.arange(2))
    assert p99[0] > 0
