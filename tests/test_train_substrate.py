"""Training substrate: optimizer, data determinism, grad compression,
checkpoint/restart equivalence, straggler detection."""

import json
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.train import optimizer as OPT
from repro.train import grad as G
from repro.train.checkpoint import CheckpointManager, ZNSTelemetry
from repro.train.data import SyntheticLM, MemmapLM, write_synthetic_corpus
from repro.train.loop import LoopConfig, fit


# --------------------------------------------------------------------- #
# optimizer
# --------------------------------------------------------------------- #
def test_adamw_decreases_quadratic():
    cfg = OPT.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1,
                          total_steps=100)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = OPT.init(params)
    for _ in range(60):
        grads = {"w": 2 * params["w"]}          # d/dw w^2
        params, state, m = OPT.update(cfg, params, grads, state)
    assert float(jnp.abs(params["w"]).max()) < 0.3


def test_adamw_schedule_warmup_and_decay():
    cfg = OPT.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                          min_lr_ratio=0.1)
    lrs = [float(OPT.schedule(cfg, jnp.asarray(s))) for s in range(100)]
    assert lrs[0] < 0.2                          # warmup starts low
    assert abs(lrs[10] - 1.0) < 0.1              # peak after warmup
    assert lrs[-1] == pytest.approx(0.1, abs=0.05)  # decays to min ratio
    assert lrs[99] < lrs[50] < lrs[11]


def test_grad_clip_bounds_update():
    cfg = OPT.AdamWConfig(lr=1e-3, grad_clip=1.0)
    params = {"w": jnp.zeros(4)}
    state = OPT.init(params)
    _, _, m = OPT.update(cfg, params, {"w": jnp.full(4, 1e6)}, state)
    assert float(m["grad_norm"]) > 1e5           # reported pre-clip


# --------------------------------------------------------------------- #
# data pipeline
# --------------------------------------------------------------------- #
def test_synthetic_data_deterministic_and_skippable():
    d1 = SyntheticLM(vocab=1000, batch=4, seq=16, seed=3)
    d2 = SyntheticLM(vocab=1000, batch=4, seq=16, seed=3)
    b5a = d1.batch_at(5)
    for _ in range(5):
        pass
    b5b = d2.batch_at(5)
    assert (b5a["tokens"] == b5b["tokens"]).all()
    assert (d1.batch_at(6)["tokens"] != b5a["tokens"]).any()
    assert b5a["tokens"].max() < 1000


def test_synthetic_data_host_sharding():
    full = SyntheticLM(vocab=100, batch=8, seq=4, seed=0)
    h0 = SyntheticLM(vocab=100, batch=8, seq=4, seed=0, host_id=0,
                     n_hosts=2)
    h1 = SyntheticLM(vocab=100, batch=8, seq=4, seed=0, host_id=1,
                     n_hosts=2)
    assert h0.batch_at(0)["tokens"].shape == (4, 4)
    assert (h0.batch_at(0)["tokens"] != h1.batch_at(0)["tokens"]).any()


def test_memmap_dataset(tmp_path):
    path = write_synthetic_corpus(tmp_path / "corpus.bin", 10_000, 500)
    d = MemmapLM(path=str(path), vocab=500, batch=4, seq=32, seed=1)
    b = d.batch_at(0)
    assert b["tokens"].shape == (4, 32)
    assert (b["labels"][:, :-1] == b["tokens"][:, 1:]).all()
    b2 = MemmapLM(path=str(path), vocab=500, batch=4, seq=32,
                  seed=1).batch_at(0)
    assert (b["tokens"] == b2["tokens"]).all()


# --------------------------------------------------------------------- #
# gradient machinery
# --------------------------------------------------------------------- #
def test_accumulate_grads_matches_full_batch():
    def loss_fn(p, batch):
        pred = batch["x"] @ p["w"]
        loss = jnp.mean((pred - batch["y"]) ** 2)
        return loss, {"loss": loss}

    rng = np.random.default_rng(0)
    p = {"w": jnp.asarray(rng.standard_normal((8, 1)), jnp.float32)}
    batch = {"x": jnp.asarray(rng.standard_normal((16, 8)), jnp.float32),
             "y": jnp.asarray(rng.standard_normal((16, 1)), jnp.float32)}
    l1, g1, _ = G.accumulate_grads(loss_fn, p, batch, 1)
    l4, g4, _ = G.accumulate_grads(loss_fn, p, batch, 4)
    assert float(jnp.abs(l1 - l4)) < 1e-5
    np.testing.assert_allclose(np.asarray(g1["w"]), np.asarray(g4["w"]),
                               rtol=1e-5, atol=1e-6)


def test_int8_compression_error_feedback_converges():
    """With EF, the *accumulated* quantization error stays bounded and the
    mean compressed gradient tracks the true mean."""
    rng = np.random.default_rng(1)
    g_true = jnp.asarray(rng.standard_normal(256) * 1e-3, jnp.float32)
    ef = {"g": jnp.zeros(256, jnp.float32)}
    total = jnp.zeros(256, jnp.float32)
    for _ in range(50):
        deq, ef_new = G.compress_grads_ef({"g": g_true}, ef)
        ef = ef_new
        total = total + deq["g"]
    mean = total / 50
    rel = float(jnp.linalg.norm(mean - g_true) / jnp.linalg.norm(g_true))
    assert rel < 0.05
    assert float(jnp.abs(ef["g"]).max()) < float(jnp.abs(g_true).max()) * 2


def test_compress_roundtrip_error_bounded():
    rng = np.random.default_rng(2)
    g = jnp.asarray(rng.standard_normal(1000), jnp.float32)
    q, scale = G.compress_int8(g)
    deq = G.decompress_int8(q, scale)
    assert q.dtype == jnp.int8
    assert float(jnp.abs(deq - g).max()) <= float(scale) * 0.5 + 1e-7


# --------------------------------------------------------------------- #
# checkpoint / restart
# --------------------------------------------------------------------- #
def _tiny_setup():
    def loss_fn(p, batch):
        pred = batch["tokens"].astype(jnp.float32) @ p["w"]
        loss = jnp.mean((pred - batch["labels"]) ** 2)
        return loss, {"loss": loss}

    def train_step(params, opt_state, batch):
        cfg = OPT.AdamWConfig(lr=1e-2, total_steps=100)
        (loss, m), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch)
        params, opt_state, om = OPT.update(cfg, params, grads, opt_state)
        return params, opt_state, dict(m, loss=loss, **om)

    class Data:
        def batch_at(self, step):
            rng = np.random.default_rng(step)
            return {"tokens": rng.standard_normal((4, 8)).astype(np.float32),
                    "labels": rng.standard_normal((4, 1)).astype(np.float32)}

    params = {"w": jnp.zeros((8, 1), jnp.float32)}
    return train_step, params, OPT.init(params), Data()


def test_checkpoint_save_restore_roundtrip(tmp_path):
    ckpt = CheckpointManager(tmp_path, keep=2, async_save=False)
    tree = {"a": jnp.arange(6).reshape(2, 3),
            "b": {"c": jnp.ones(4, jnp.bfloat16)}}
    ckpt.save(7, tree, meta={"step": 7})
    out, meta = ckpt.restore(tree)
    assert meta["step"] == 7
    assert (np.asarray(out["a"]) == np.arange(6).reshape(2, 3)).all()
    assert out["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_rotation_keeps_k(tmp_path):
    ckpt = CheckpointManager(tmp_path, keep=2, async_save=False)
    for s in (1, 2, 3, 4):
        ckpt.save(s, {"x": jnp.asarray([s])})
    assert ckpt.all_steps() == [3, 4]


def test_restart_equivalence(tmp_path):
    """Crash at step 7, restart, finish: final params must equal an
    uninterrupted run (deterministic data + atomic manifests)."""
    train_step, params, opt, data = _tiny_setup()

    # uninterrupted
    p_ref, o_ref = params, opt
    for s in range(10):
        b = jax.tree.map(jnp.asarray, data.batch_at(s))
        p_ref, o_ref, _ = train_step(p_ref, o_ref, b)

    # interrupted at 7 + restart
    ck = CheckpointManager(tmp_path, keep=3, async_save=False)
    cfg = LoopConfig(total_steps=10, ckpt_every=2, fail_at_step=7)
    with pytest.raises(RuntimeError, match="injected failure"):
        fit(train_step, params, opt, data, ck, cfg)
    cfg2 = LoopConfig(total_steps=10, ckpt_every=2)
    res = fit(train_step, params, opt, data, ck, cfg2)
    assert res.restored_from is not None
    final, _ = ck.restore({"params": params, "opt": opt})
    np.testing.assert_allclose(np.asarray(final["params"]["w"]),
                               np.asarray(p_ref["w"]), rtol=1e-6)


def test_zns_telemetry_tracks_checkpoint_traffic(tmp_path):
    zns = ZNSTelemetry()
    ckpt = CheckpointManager(tmp_path, keep=1, async_save=False, zns=zns)
    big = {"w": jnp.zeros((1024, 1024), jnp.float32)}  # 4 MiB
    for s in range(3):
        ckpt.save(s, big)
    rep = zns.report()
    assert rep["host_pages"] > 0
    assert rep["dlwa"] >= 1.0
    # rotated-out checkpoints were deleted: either their zones reclaimed
    # or the garbage is tracked as invalid (SA pressure)
    assert rep["resets"] >= 1 or zns.fs.sa.invalid_bytes > 0


def test_straggler_detection():
    import time as _t
    train_step, params, opt, data = _tiny_setup()
    calls = []

    def slow_step(p, o, b):
        if len(calls) == 8:
            _t.sleep(0.3)
        calls.append(1)
        return train_step(p, o, b)

    hits = []
    cfg = LoopConfig(total_steps=12, ckpt_every=100)
    res = fit(slow_step, params, opt, data, None, cfg,
              on_straggler=lambda s, dt: hits.append(s))
    assert res.stragglers and hits


def test_compressed_train_step_converges():
    """int8+EF gradient compression integrated into the train step still
    reduces the loss (the distributed-optimization lever for cross-pod
    DCI traffic)."""
    import jax.numpy as jnp
    from repro.configs import get_arch
    from repro.models import model as MDL
    from repro.models import transformer as T
    from repro.train import grad as G

    cfg = get_arch("phi3-mini-3.8b").reduced()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (4, 16)),
                                   jnp.int32)}
    batch["labels"] = batch["tokens"]
    opt_cfg = OPT.AdamWConfig(lr=3e-3, total_steps=12, warmup_steps=1)
    step = jax.jit(MDL.make_train_step(cfg, opt_cfg, compress_grads=True))
    state = (params, G.init_error_feedback(params))
    opt = OPT.init(params)
    losses = []
    for _ in range(8):
        state, opt, m = step(state, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]
