"""Tests for the synthetic traffic generators and the flash cache.

Generators: pure functions of their seed (bit-identical streams),
correct distribution shapes (empirical Zipf frequencies vs
:func:`zipf_weights`, diurnal bounds and crest/trough placement, burst
means at the 0/1 extremes), and loud validation errors.

Flash cache: the recorded zone-command stream never reads an evicted
(reset-and-not-rewritten) zone, the hit rate is monotone non-decreasing
in the zone budget, the stats ledger is self-consistent, and the
admission filter actually filters.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import repro.storage as S
from repro.core import engine as E
from repro.core.geometry import FlashGeometry
from repro.storage import (burst_arrivals, diurnal_load, zipf_weights,
                           zipfian_keys, zipfian_tenants)


# --------------------------------------------------------------------- #
# zipf
# --------------------------------------------------------------------- #
def test_zipf_weights_shape():
    w = zipf_weights(16, 1.1)
    assert w.shape == (16,)
    assert w.sum() == pytest.approx(1.0)
    assert (np.diff(w) <= 0).all(), "rank 0 must be hottest"


def test_zipf_weights_zero_skew_is_uniform():
    w = zipf_weights(8, 0.0)
    assert np.allclose(w, 1 / 8)


@pytest.mark.parametrize("bad", [dict(n_keys=0, skew=1.0),
                                 dict(n_keys=4, skew=-0.1)])
def test_zipf_weights_validates(bad):
    with pytest.raises(ValueError):
        zipf_weights(**bad)


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2**31 - 1), st.floats(0.0, 2.0))
def test_zipfian_keys_deterministic(seed, skew):
    a = zipfian_keys(500, 32, skew=skew, seed=seed)
    b = zipfian_keys(500, 32, skew=skew, seed=seed)
    assert np.array_equal(a, b)
    assert a.min() >= 0 and a.max() < 32
    c = zipfian_keys(500, 32, skew=skew, seed=seed + 1)
    assert not np.array_equal(a, c), "seed must matter"


def test_zipfian_keys_match_weights():
    n, n_keys, skew = 20000, 16, 1.2
    keys = zipfian_keys(n, n_keys, skew=skew, seed=3)
    freq = np.bincount(keys, minlength=n_keys) / n
    want = zipf_weights(n_keys, skew)
    assert np.abs(freq - want).max() < 0.02
    assert freq.argmax() == 0, "key id 0 must be the hottest"


def test_zipfian_tenants_skewed_to_tenant_zero():
    t = zipfian_tenants(5000, 4, skew=1.0, seed=1)
    counts = np.bincount(t, minlength=4)
    assert counts.argmax() == 0
    assert (counts > 0).all(), "every tenant sees some load"


# --------------------------------------------------------------------- #
# diurnal + burst
# --------------------------------------------------------------------- #
def test_diurnal_load_bounds_and_cycle():
    lvl = diurnal_load(48, base=10, peak=100, period=24)
    assert lvl.dtype == np.int64
    assert lvl.min() == 10 and lvl.max() == 100
    assert lvl[0] == 10 and lvl[12] == 100 and lvl[24] == 10
    # periodic up to the +-1 wobble of rounding near half-integers
    assert np.abs(lvl[:24] - lvl[24:]).max() <= 1


def test_diurnal_load_jitter_seeded():
    a = diurnal_load(48, base=10, peak=100, seed=7, jitter=0.2)
    b = diurnal_load(48, base=10, peak=100, seed=7, jitter=0.2)
    assert np.array_equal(a, b)
    assert (a >= 0).all()
    assert not np.array_equal(
        a, diurnal_load(48, base=10, peak=100, seed=8, jitter=0.2))


def test_diurnal_load_validates():
    with pytest.raises(ValueError, match="peak"):
        diurnal_load(10, base=5, peak=4)
    with pytest.raises(ValueError, match="seed"):
        diurnal_load(10, base=5, peak=9, jitter=0.1)


def test_burst_arrivals_deterministic_and_bursty():
    a = burst_arrivals(200, rate=4, seed=5)
    assert np.array_equal(a, burst_arrivals(200, rate=4, seed=5))
    assert a.dtype == np.int64 and (a >= 0).all()
    quiet = burst_arrivals(2000, rate=4, burst_prob=0.0, seed=0)
    assert quiet.mean() == pytest.approx(4.0, rel=0.1)
    loud = burst_arrivals(2000, rate=4, burst_prob=1.0, burst_mult=8,
                          seed=0)
    assert loud.mean() == pytest.approx(32.0, rel=0.1)
    assert loud.mean() > 4 * quiet.mean()


def test_burst_arrivals_validates():
    with pytest.raises(ValueError, match="burst_prob"):
        burst_arrivals(10, rate=2, burst_prob=1.5)


# --------------------------------------------------------------------- #
# flash cache invariants (on the recording backend)
# --------------------------------------------------------------------- #
def cache_flash():
    return FlashGeometry(n_channels=2, ways_per_channel=1,
                         blocks_per_lun=8, pages_per_block=4,
                         page_bytes=4096)


def cache_recorder(n_zones=10, zone_pages=32, max_active=6, **kw):
    return S.RecordingBackend(cache_flash(), zone_pages=zone_pages,
                              n_zones=n_zones, max_active=max_active,
                              **kw)


def run_cache(seed, capacity, *, n_accesses=400, admission_misses=1):
    rec = cache_recorder()
    cache = S.record_cache(rec, n_accesses=n_accesses, n_keys=48,
                           skew=1.1, seed=seed, capacity_zones=capacity,
                           obj_pages=4, admission_misses=admission_misses)
    return rec, cache


def test_cache_never_reads_evicted_zones():
    """Every recorded READ targets a zone holding live data (written
    since its last RESET) -- eviction must invalidate residents."""
    for seed in range(4):
        rec, _ = run_cache(seed, capacity=4)
        live = {}
        for op, zone, n, _flags, _tenant in rec.program().tolist():
            if op == E.OP_WRITE:
                live[zone] = live.get(zone, 0) + n
            elif op == E.OP_RESET:
                live[zone] = 0
            elif op == E.OP_READ:
                assert live.get(zone, 0) > 0, \
                    f"seed {seed}: read from evicted zone {zone}"


def test_cache_hit_rate_monotone_in_capacity():
    for seed in range(6):
        rates = [run_cache(seed, c)[1].stats.hit_rate
                 for c in (3, 4, 5, 6, 8)]
        assert rates == sorted(rates), f"seed {seed}: {rates}"
        assert rates[-1] > 0.5, f"seed {seed}: skewed stream must hit"


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 2**31 - 1), st.sampled_from([3, 5, 8]))
def test_cache_stats_ledger(seed, capacity):
    rec, cache = run_cache(seed, capacity)
    s = cache.stats
    assert s.hits + s.misses == 400
    assert s.admitted + s.rejected <= s.misses
    assert s.read_pages == s.hits * 4, "uniform 4-page objects"
    assert s.write_pages == s.admitted * 4
    assert s.evicted_objects >= s.evicted_zones
    prog = rec.program()
    resets = int((prog[:, 0] == E.OP_RESET).sum())
    assert resets == s.evicted_zones
    reads = prog[prog[:, 0] == E.OP_READ]
    assert int(reads[:, 2].sum()) == s.read_pages


def test_cache_admission_filter():
    # a stream of all-distinct keys never sees a second miss per key,
    # so admission_misses=2 admits nothing
    rec = cache_recorder()
    cache = S.FlashCache(rec, S.CacheConfig(
        capacity_zones=5, obj_pages=4, admission_misses=2))
    cache.run(np.arange(100))
    assert cache.stats.admitted == 0
    assert cache.stats.rejected == 100
    assert cache.stats.hit_rate == 0.0
    assert len(rec) == 0, "nothing admitted -> nothing recorded"


def test_cache_config_validates():
    with pytest.raises(ValueError, match="capacity_zones"):
        S.CacheConfig(capacity_zones=2, n_bins=2)
    with pytest.raises(ValueError, match="admission_misses"):
        S.CacheConfig(capacity_zones=4, admission_misses=0)


def test_cache_tags_hit_and_admit_classes():
    rec = cache_recorder(class_tenants={"admit": 0, "hit": 1})
    S.record_cache(rec, n_accesses=200, n_keys=24, seed=0,
                   capacity_zones=5, obj_pages=4)
    prog = rec.program()
    reads = prog[prog[:, 0] == E.OP_READ]
    writes = prog[prog[:, 0] == E.OP_WRITE]
    assert len(reads) and (reads[:, 4] == 1).all(), "hits tagged 'hit'"
    assert len(writes) and (writes[:, 4] == 0).all(), \
        "admissions tagged 'admit'"


def test_cache_report_keys():
    _, cache = run_cache(0, capacity=5)
    rep = cache.report()
    for key in ("hit_rate", "hits", "misses", "evicted_zones"):
        assert key in rep
    assert rep["hit_rate"] == pytest.approx(cache.stats.hit_rate)
