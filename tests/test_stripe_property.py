"""Property tests for the shared RAID stripe math.

``parity_device_of`` / ``data_device_of`` / ``locate_page``
(:mod:`repro.array.raid`) are the single source of truth for both the
real ``ZNSArray`` and the fleet layer's program-space striper
(:func:`repro.fleet.tenants.stripe_program`).  These tests pin the
algebra for arbitrary (n_devices, chunk, page):

* address round-trip: ``locate_page`` decomposes a logical page into
  (stripe, slot, page-in-chunk, device) and the decomposition
  reconstructs the page exactly;
* parity rotation: a stripe's parity device cycles RAID-5 style through
  all members, and no data slot ever lands on it;
* striper agreement: the per-device WRITE page counts emitted by
  ``stripe_program`` match what ``locate_page`` predicts page by page.

Runs under real hypothesis or the seeded ``_hypothesis_stub``.
"""

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.array.raid import data_device_of, locate_page, parity_device_of
from repro.core import engine as E
from repro.fleet import TENANT_COL, stripe_program, tag_tenant

#: (n_devices, parity) with n_data >= 1; chunk; zone id; logical page
_GEOM = st.tuples(st.integers(1, 8), st.booleans()).map(
    lambda t: (max(t[0], 2) if t[1] else t[0], t[1]))


@settings(max_examples=60, deadline=None)
@given(_GEOM, st.integers(1, 64), st.integers(0, 16),
       st.integers(0, 4096))
def test_locate_page_round_trip(geom, chunk, zone, page):
    n_devices, parity = geom
    n_data = n_devices - (1 if parity else 0)
    stripe, slot, r, dev = locate_page(zone, page, chunk, n_data,
                                       n_devices, parity)
    assert 0 <= r < chunk
    assert 0 <= slot < n_data
    assert 0 <= dev < n_devices
    # the decomposition is exact: page = (stripe * n_data + slot) * c + r
    assert (stripe * n_data + slot) * chunk + r == page
    # device is a pure function of (zone, stripe, slot)
    assert dev == data_device_of(zone, stripe, slot, n_devices, parity)
    # without parity the device IS the slot
    if not parity:
        assert dev == slot


@settings(max_examples=60, deadline=None)
@given(st.integers(2, 8), st.integers(0, 16), st.integers(0, 64))
def test_parity_rotation_invariants(n_devices, zone, stripe):
    p = parity_device_of(zone, stripe, n_devices)
    assert 0 <= p < n_devices
    # RAID-5 rotation: consecutive stripes cycle every member once
    window = {parity_device_of(zone, stripe + k, n_devices)
              for k in range(n_devices)}
    assert window == set(range(n_devices))
    # no data slot of a stripe ever lands on its parity device, and the
    # n_data data slots plus parity tile the devices exactly
    devs = {data_device_of(zone, stripe, s, n_devices, True)
            for s in range(n_devices - 1)}
    assert p not in devs
    assert devs | {p} == set(range(n_devices))


@settings(max_examples=25, deadline=None)
@given(_GEOM, st.integers(1, 8), st.integers(0, 3),
       st.lists(st.integers(1, 40), min_size=1, max_size=6))
def test_stripe_program_matches_locate_page(geom, chunk, zone, writes):
    """The program-space striper sends every host page to exactly the
    member ``locate_page`` names, in logical page order."""
    n_devices, parity = geom
    n_data = n_devices - (1 if parity else 0)
    member_zone_pages = chunk * 8
    cap = n_data * member_zone_pages
    total = 0
    rows = []
    for w in writes:
        w = min(w, cap - total)
        if w <= 0:
            break
        rows.append((E.OP_WRITE, zone, w, E.F_HOST))
        total += w
    if not rows:
        return
    prog = tag_tenant(E.encode_program(rows), 0)
    striped = stripe_program(prog, n_devices=n_devices,
                             chunk_pages=chunk, parity=parity,
                             member_zone_pages=member_zone_pages,
                             parity_tenant=1)
    assert len(striped) == n_devices
    # expected per-device host-data pages, page by logical page
    want = np.zeros(n_devices, dtype=np.int64)
    for page in range(total):
        want[locate_page(zone, page, chunk, n_data, n_devices,
                         parity)[3]] += 1
    got = np.zeros(n_devices, dtype=np.int64)
    for d, p in enumerate(striped):
        data = (p[:, 0] == E.OP_WRITE) & (p[:, TENANT_COL] == 0)
        got[d] = int(p[data, 2].sum())
        # each member sees a strictly sequential append stream: chunks
        # of at most `chunk` pages
        assert (p[data, 2] <= chunk).all()
    assert np.array_equal(got, want), (geom, chunk, zone, writes)
