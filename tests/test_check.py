"""repro.check: verifier-vs-engine differentials, sanitizer, lint.

Three surfaces, each with its own hard guarantee:

1. the static verifier's predicted ok-mask must be *bit-identical* to
   ``trace.ok`` from the batched engine on fuzzed programs, across
   every element spec and both allocation policies (dyn overrides
   included) -- the verifier is a numpy transliteration of the engine
   state machine, and any semantic drift must fail here;
2. the DeviceState sanitizer accepts every state a legal dispatch
   produces and rejects hand-corrupted pytrees, while adding zero jit
   compilations (it is numpy on fetched values);
3. the AST lint recognises each JAX-pitfall rule on minimal sources,
   honours the ``# lint: ok`` pragma, and the repo's own tree is clean
   (the CI gate, mirrored here so tier-1 catches regressions first).

``REPRO_SANITIZE=1`` (the CI sanitizer job) additionally audits every
final state the fuzz differentials produce.
"""

import os
import pathlib

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.check import (ERR_ACTIVE_LIMIT, ERR_ALLOC_INFEASIBLE, ERR_FULL,
                         ERR_OVERFLOW, ERR_UNMAPPED_READ, SanitizerError,
                         assert_state, assert_states, check_state,
                         check_states, explain_op, validate_rows,
                         verify_program, verify_programs)
from repro.check.lint import Finding, lint_source, lint_tree
from repro.core import engine as E
from repro.core.device import ZNSDevice
from repro.core.elements import BLOCK, FIXED, SUPERBLOCK, hchunk, vchunk
from repro.core.engine import ZoneEngine
from repro.core.geometry import FlashGeometry, ZoneGeometry

SANITIZE_ALL = os.environ.get("REPRO_SANITIZE") == "1"
SPECS = [BLOCK, vchunk(2), hchunk(2), SUPERBLOCK, FIXED]
N_OPS = 24  # fixed program length -> one compiled entry per engine


def tiny_flash():
    return FlashGeometry(n_channels=4, ways_per_channel=1, blocks_per_lun=8,
                         pages_per_block=4, page_bytes=4096)


_ENGINES = {}


def tiny_engine(spec) -> ZoneEngine:
    if spec.name not in _ENGINES:
        _ENGINES[spec.name] = ZoneEngine(
            tiny_flash(), ZoneGeometry(parallelism=4, n_segments=2),
            spec, max_active=3)
    return _ENGINES[spec.name]


def random_program(rng, eng) -> np.ndarray:
    """A fuzz program exercising every op, out-of-range zones (clipped
    by the engine), overflow page counts, and non-host writes."""
    zp = eng.cfg.zone_pages
    rows = np.zeros((N_OPS, 4), np.int32)
    rows[:, 0] = rng.integers(E.OP_NOP, E.OP_READ + 1, N_OPS)
    rows[:, 1] = rng.integers(-1, eng.cfg.n_zones + 2, N_OPS)
    rows[:, 2] = rng.integers(0, zp + 3, N_OPS)
    rows[:, 3] = rng.integers(0, 2, N_OPS)
    return rows


def fuzz_dyn(rng, eng, policy: str):
    """Random-but-valid dyn overrides (the axes make_dyn accepts)."""
    kw = {"alloc_policy": policy,
          "max_active": int(rng.choice([2, 3])),
          "wear_aware": bool(rng.integers(0, 2))}
    if eng.spec.kind.name != "FIXED" and rng.integers(0, 2):
        kw["zone_pages"] = eng.cfg.zone_pages // 2
    if policy == "silent" and rng.integers(0, 2):
        kw["wear_bound"] = int(rng.choice([0, 1]))
    return eng.dyn(**kw)


def run_and_compare(eng, prog, dyn, ctx=""):
    state, trace = eng.run(eng.init_state(), prog, dyn)
    rep = verify_program(eng.cfg, prog, dyn)
    got = np.asarray(trace.ok).astype(bool)
    assert np.array_equal(rep.ok, got), (
        f"ok-mask mismatch {ctx}: first diff at op "
        f"{int(np.argmax(rep.ok != got))}; predicted "
        f"{rep.ok.tolist()} engine {got.tolist()}")
    if SANITIZE_ALL:
        assert_state(eng.cfg, state, dyn, where=f"fuzz final state {ctx}",
                     metrics=eng.metrics(state))
    return state, rep


# --------------------------------------------------------------------- #
# 1. verifier ok-mask == engine trace.ok (the differential guarantee)
# --------------------------------------------------------------------- #
@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(0, len(SPECS) - 1))
def test_verifier_matches_engine_traditional(seed, spec_i):
    eng = tiny_engine(SPECS[spec_i])
    rng = np.random.default_rng(seed)
    dyn = fuzz_dyn(rng, eng, "traditional")
    run_and_compare(eng, random_program(rng, eng), dyn,
                    ctx=f"seed={seed} spec={SPECS[spec_i].name}")


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(0, len(SPECS) - 2))
def test_verifier_matches_engine_silent(seed, spec_i):
    # FIXED is excluded: make_dyn rejects silent-on-FIXED eagerly (the
    # verifier's conflict report covers the smuggled-dyn case)
    eng = tiny_engine(SPECS[spec_i])
    rng = np.random.default_rng(seed)
    dyn = fuzz_dyn(rng, eng, "silent")
    run_and_compare(eng, random_program(rng, eng), dyn,
                    ctx=f"seed={seed} spec={SPECS[spec_i].name} silent")


def test_verifier_matches_engine_stacked_lanes():
    """verify_programs over a heterogeneous stacked-dyn batch lane-for-
    lane against one run_programs dispatch (plus the lane sanitizer)."""
    eng = tiny_engine(BLOCK)
    rng = np.random.default_rng(7)
    programs = np.stack([random_program(rng, eng) for _ in range(4)])
    dyns = [eng.dyn(alloc_policy="traditional"),
            eng.dyn(alloc_policy="silent", wear_bound=1),
            eng.dyn(zone_pages=eng.cfg.zone_pages // 2, max_active=2),
            eng.dyn(alloc_policy="silent")]
    dyn = E.stack_dyn(dyns)
    states, trace = eng.run_batch(eng.init_state(), programs, dyn)
    reports = verify_programs(eng.cfg, programs, dyn)
    ok = np.asarray(trace.ok).astype(bool)
    for k, rep in enumerate(reports):
        assert np.array_equal(rep.ok, ok[k]), f"lane {k}"
    assert_states(eng.cfg, states, dyn, where="stacked fuzz states")
    assert check_states(eng.cfg, states, dyn) == [[], [], [], []]


# --------------------------------------------------------------------- #
# 2. verdict classification: error classes + the shim's exact messages
# --------------------------------------------------------------------- #
def shim_error(dev_ops):
    """Drive the ZNSDevice shim; return str of the first RuntimeError."""
    dev = ZNSDevice(tiny_flash(), ZoneGeometry(parallelism=4, n_segments=2),
                    BLOCK, max_active=3)
    try:
        for op, z, n, host in dev_ops:
            if op == E.OP_WRITE:
                dev.zone_write(z, n, host=bool(host))
            elif op == E.OP_FINISH:
                dev.zone_finish(z)
            elif op == E.OP_READ:
                dev.zone_read(z, np.arange(max(n, 1)))
    except RuntimeError as exc:
        return str(exc)
    return None


def test_verdict_full_matches_shim():
    eng = tiny_engine(BLOCK)
    zp = eng.cfg.zone_pages
    prog = np.asarray([(E.OP_WRITE, 0, zp, E.F_HOST),
                       (E.OP_WRITE, 0, 1, E.F_HOST)], np.int32)
    rep = verify_program(eng.cfg, prog)
    v = rep.first_failure()
    assert v.index == 1 and v.error == ERR_FULL
    assert v.message == shim_error(
        [(E.OP_WRITE, 0, zp, 1), (E.OP_WRITE, 0, 1, 1)])
    assert "FULL zone 0" in str(v.message)


def test_verdict_overflow_matches_shim():
    eng = tiny_engine(BLOCK)
    zp = eng.cfg.zone_pages
    prog = np.asarray([(E.OP_WRITE, 1, zp + 1, E.F_HOST)], np.int32)
    v = verify_program(eng.cfg, prog).first_failure()
    assert v.error == ERR_OVERFLOW
    assert v.message == shim_error([(E.OP_WRITE, 1, zp + 1, 1)])


def test_verdict_active_limit_matches_shim():
    eng = tiny_engine(BLOCK)
    ops = [(E.OP_WRITE, z, 1, 1) for z in range(4)]  # max_active = 3
    prog = np.asarray([(E.OP_WRITE, z, 1, E.F_HOST) for z in range(4)],
                      np.int32)
    v = verify_program(eng.cfg, prog).first_failure()
    assert v.index == 3 and v.error == ERR_ACTIVE_LIMIT
    assert v.message == shim_error(ops)


def test_verdict_unmapped_read_is_advisory():
    """Engine READs never fail; the verifier reports the control-plane
    error (what the shim would raise) as an advisory."""
    eng = tiny_engine(BLOCK)
    prog = np.asarray([(E.OP_READ, 2, 4, 0)], np.int32)
    rep = run_and_compare(eng, prog, None, ctx="unmapped read")[1]
    assert rep.all_ok and len(rep.advisories) == 1
    adv = rep.advisories[0]
    assert adv.error == ERR_UNMAPPED_READ
    assert adv.message == shim_error([(E.OP_READ, 2, 4, 0)])


def test_verdict_alloc_infeasible_wear_bound():
    """A silent lane whose only free elements sit beyond wear_bound of
    the minimum: alloc is infeasible (with the shim's message) and the
    op lands in the wear-bound-blocked report (unbounded would fit)."""
    from repro.check.verifier import _Dv, _Model
    eng = tiny_engine(BLOCK)
    dv = _Dv(E.dyn_values(eng.cfg, eng.dyn(alloc_policy="silent",
                                           wear_bound=0)))
    m = _Model(eng.cfg, dv)
    m.wear[:] = 5
    m.wear[0] = 0  # single least-worn element; the rest out of bound
    ok, err, msg = m._alloc(0, 0)
    assert not ok and err == ERR_ALLOC_INFEASIBLE
    assert msg == f"no free storage elements for zone 0 ({BLOCK.name})"
    assert m.wear_bound_blocked == [0]


def test_explain_op_walks_prefix():
    eng = tiny_engine(BLOCK)
    zp = eng.cfg.zone_pages
    prog = np.asarray([(E.OP_WRITE, 0, zp, E.F_HOST),
                       (E.OP_WRITE, 0, 1, E.F_HOST)], np.int32)
    v = explain_op(eng.cfg, prog, 1)
    assert not v.ok and v.error == ERR_FULL and v.op_name == "WRITE"
    assert explain_op(eng.cfg, prog, 0).ok


# --------------------------------------------------------------------- #
# 3. report analyses: dummy sites, DLWA bound, peak active, conflicts
# --------------------------------------------------------------------- #
def test_report_dummy_sites_and_dlwa_bound():
    eng = tiny_engine(BLOCK)
    prog = np.asarray([(E.OP_WRITE, 0, 6, E.F_HOST),
                       (E.OP_FINISH, 0, 0, 0),   # pads partial elements
                       (E.OP_WRITE, 1, 3, 0),    # non-host (dummy) write
                       (E.OP_FINISH, 1, 0, 0)], np.int32)
    state, rep = run_and_compare(eng, prog, None, ctx="dummy sites")
    assert rep.all_ok
    # every superfluous-write source is a site: the two FINISH paddings
    # plus the explicit non-host write, and the site pages sum to the
    # exact dummy-page counter the engine reports
    assert sorted(i for i, _, _ in rep.dummy_sites) == [1, 2, 3]
    assert (2, 1, 3) in rep.dummy_sites
    assert sum(p for _, _, p in rep.dummy_sites) == rep.dummy_pages
    met = eng.metrics(state)
    assert rep.host_pages == int(met["host_pages"])
    assert rep.dummy_pages == int(met["dummy_pages"])
    assert rep.dummy_pages > 3  # the FINISH pads really contributed
    assert rep.dlwa_lower_bound == pytest.approx(met["dlwa"])
    assert rep.peak_active == 1  # each zone sealed before the next opens


def test_report_peak_active_pressure():
    eng = tiny_engine(BLOCK)
    prog = np.asarray([(E.OP_WRITE, z, 1, E.F_HOST) for z in range(3)]
                      + [(E.OP_FINISH, z, 0, 0) for z in range(3)],
                      np.int32)
    rep = run_and_compare(eng, prog, None, ctx="peak active")[1]
    assert rep.all_ok and rep.peak_active == 3


def test_report_conflicts_on_smuggled_dyn():
    """make_dyn rejects these eagerly; hand-stacked DynConfigs can
    smuggle them past it -- the verifier reports without walking ops."""
    fixed = tiny_engine(FIXED)
    dyn = fixed.dyn()._replace(alloc_policy=E.POLICY_SILENT)
    rep = verify_program(fixed.cfg, np.zeros((1, 4), np.int32), dyn)
    assert any("FIXED" in c for c in rep.conflicts)
    blk = tiny_engine(BLOCK)
    dyn = blk.dyn()._replace(wear_bound=-2)
    rep = verify_program(blk.cfg, np.zeros((1, 4), np.int32), dyn)
    assert any("wear_bound" in c for c in rep.conflicts)


# --------------------------------------------------------------------- #
# 4. sanitizer: accepts engine states, rejects corrupted pytrees
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("spec_i", range(len(SPECS)))
def test_sanitizer_accepts_engine_states(spec_i):
    eng = tiny_engine(SPECS[spec_i])
    rng = np.random.default_rng(11 + spec_i)
    state, _ = eng.run(eng.init_state(), random_program(rng, eng))
    assert check_state(eng.cfg, state, metrics=eng.metrics(state)) == []


def open_zone_state(eng):
    zp = eng.cfg.zone_pages
    prog = np.asarray([(E.OP_WRITE, 0, zp // 2, E.F_HOST),
                       (E.OP_WRITE, 1, zp, E.F_HOST)], np.int32)
    state, trace = eng.run(eng.init_state(), prog)
    assert bool(np.asarray(trace.ok).all())
    return state


def test_sanitizer_rejects_corrupted_states():
    eng = tiny_engine(BLOCK)
    state = open_zone_state(eng)

    wp = np.asarray(state.zone_wp).copy()
    wp[0] = eng.cfg.zone_pages + 7
    v = check_state(eng.cfg, state._replace(zone_wp=wp))
    assert any("wp" in s and "outside" in s for s in v)

    ze = np.asarray(state.zone_elems).copy()
    ze[2] = ze[0]  # element committed to two zones
    v = check_state(eng.cfg, state._replace(zone_elems=ze))
    assert any("disjointness" in s for s in v)

    na = np.asarray(state.n_active).copy()
    v = check_state(eng.cfg, state._replace(n_active=na + 1))
    assert any("OPEN" in s for s in v)

    av = np.asarray(state.elem_avail).copy()
    av[0] = 9
    v = check_state(eng.cfg, state._replace(elem_avail=av))
    assert any("avail code 9" in s for s in v)

    v = check_state(eng.cfg, state, metrics={"dlwa": 123.0})
    assert any("metrics['dlwa']" in s for s in v)

    with pytest.raises(SanitizerError, match="corrupt demo"):
        assert_state(eng.cfg, state._replace(zone_wp=wp),
                     where="corrupt demo")
    try:
        assert_state(eng.cfg, state._replace(zone_wp=wp))
    except SanitizerError as exc:
        assert exc.violations  # the full list rides on the exception


def test_sanitizer_scratch_wear_and_negative_counters():
    eng = tiny_engine(BLOCK)
    state = open_zone_state(eng)
    w = np.asarray(state.elem_wear).copy()
    w[-1] = 3  # the masked-scatter scratch slot must stay zero
    v = check_state(eng.cfg, state._replace(elem_wear=w))
    assert any("scratch" in s for s in v)
    hp = np.asarray(state.host_pages) * 0 - 4
    v = check_state(eng.cfg, state._replace(host_pages=hp),
                    check_wear=False)
    assert any("negative page counters" in s for s in v)


# --------------------------------------------------------------------- #
# 5. malformed-row pre-checks + the pipelines that call them
# --------------------------------------------------------------------- #
def test_validate_rows_rejects_malformed():
    good = np.asarray([[E.OP_WRITE, 0, 4, 1, 0]], np.int32)
    assert validate_rows(good, n_tenants=1).dtype == np.int32

    bad = good.copy()
    bad[0, 0] = 9
    with pytest.raises(ValueError, match="op code 9"):
        validate_rows(bad)
    bad = good.copy()
    bad[0, 1] = -3
    with pytest.raises(ValueError, match="negative zone"):
        validate_rows(bad, where="wl")
    bad = good.copy()
    bad[0, 2] = -1
    with pytest.raises(ValueError, match="negative page count"):
        validate_rows(bad)
    bad = good.copy()
    bad[0, 4] = 5
    with pytest.raises(ValueError, match="tenant"):
        validate_rows(bad, n_tenants=2)
    # NOP rows are padding: exempt from the zone/page/tenant bounds
    nop = np.asarray([[E.OP_NOP, -5, -5, 0, 99]], np.int32)
    validate_rows(nop, n_tenants=2)
    with pytest.raises(ValueError, match="columns"):
        validate_rows(np.zeros((2, 3), np.int32))
    with pytest.raises(ValueError, match="shape"):
        validate_rows(np.zeros((2,), np.int32))


def test_replay_recorders_rejects_malformed_rows():
    import repro.storage as S

    class BadRecorder:
        def program(self):
            return np.asarray([[E.OP_WRITE, -1, 4, 1, 0]], np.int32)

    eng = tiny_engine(BLOCK)
    with pytest.raises(ValueError, match=r"recorder 0 .*negative zone"):
        S.replay_recorders(eng, [BadRecorder()])


# --------------------------------------------------------------------- #
# 6. sanitize= threading adds zero jit compilations
# --------------------------------------------------------------------- #
def test_sanitize_adds_zero_recompiles():
    from repro.obs.profile import RecompileCounter
    eng = tiny_engine(BLOCK)
    rng = np.random.default_rng(3)
    programs = np.stack([random_program(rng, eng) for _ in range(2)])
    dyn = E.stack_dyn([eng.dyn(), eng.dyn(alloc_policy="silent")])
    counter = RecompileCounter(run_programs=E.run_programs)
    eng.run_batch(eng.init_state(), programs, dyn)  # warm/compile
    before = counter.counts()
    states, _ = eng.run_batch(eng.init_state(), programs, dyn)
    assert_states(eng.cfg, states, dyn, where="recompile probe")
    assert sum(counter.delta(before).values()) == 0


def test_evaluator_sanitize_flag():
    """Evaluator(sanitize=True) audits every dispatch's states without
    changing results or growing the jit cache across generations."""
    from repro.fleet import Evaluator, grid_space
    flash = FlashGeometry(n_channels=4, ways_per_channel=1,
                          blocks_per_lun=16, pages_per_block=4,
                          page_bytes=4096)
    eng = ZoneEngine(flash, ZoneGeometry(4, 4), SUPERBLOCK, max_active=6)
    configs = grid_space(segments=(4, 2), chunks=(8,),
                         parities=(False,), wear=(True,))[:2]
    ev = Evaluator(eng, n_devices=2, sanitize=True)
    rows = ev.evaluate(configs)
    assert len(rows) == len(configs)
    cache1 = ev.jit_cache()["run_programs"]
    ev.evaluate(configs)
    assert ev.jit_cache()["run_programs"] == cache1


# --------------------------------------------------------------------- #
# 7. assert_all_ok: verifier-routed rich exceptions
# --------------------------------------------------------------------- #
def test_assert_all_ok_names_error_class():
    from repro.fleet.runner import run_fleet
    eng = tiny_engine(BLOCK)
    zp = eng.cfg.zone_pages
    rows = np.zeros((2, 4, 5), np.int32)
    rows[0, 0] = (E.OP_WRITE, 0, zp, E.F_HOST, 0)
    rows[1, 0] = (E.OP_WRITE, 0, zp + 1, E.F_HOST, 0)  # overflow
    res = run_fleet(eng, rows)
    with pytest.raises(AssertionError) as exc:
        from repro.fleet.runner import assert_all_ok
        assert_all_ok(res)
    msg = str(exc.value)
    assert "predicted error class" in msg
    assert ERR_OVERFLOW in msg and "WRITE" in msg


# --------------------------------------------------------------------- #
# 8. lint rules
# --------------------------------------------------------------------- #
def rules(src, **kw):
    return [f.rule for f in lint_source(src, "mod.py", **kw)]


def test_lint_dispatch_in_loop():
    src = "for p in programs:\n    run_programs(cfg, s, p)\n"
    assert rules(src) == ["dispatch-in-loop"]
    assert rules("run_programs(cfg, s, batch)\n") == []
    hoisted = ("def f(cfg, batch):\n"
               "    for p in batch:\n"
               "        rows.append(p)\n"
               "    return run_programs(cfg, s, rows)\n")
    assert rules(hoisted) == []


def test_lint_vmap_over_scan():
    assert rules("jax.vmap(run_program)(xs)\n") == ["vmap-over-scan"]
    assert rules("jax.vmap(lambda s: apply_op(cfg, s, r))(xs)\n") \
        == ["vmap-over-scan"]
    assert rules("jax.vmap(other_fn)(xs)\n") == []


def test_lint_jit_needs_static():
    src = "@jax.jit\ndef f(cfg, x):\n    return x\n"
    assert rules(src) == ["jit-needs-static"]
    src = ("@functools.partial(jax.jit, static_argnames=('cfg',))\n"
           "def f(cfg, x):\n    return x\n")
    assert rules(src) == []
    assert rules("@jax.jit\ndef g(x):\n    return x\n") == []


def test_lint_bench_schema():
    names = {"BENCH_fleet.json"}
    src = "p = root / 'BENCH_stale.json'\n"
    assert rules(src, bench_names=names) == ["bench-schema"]
    assert rules("p = root / 'BENCH_fleet.json'\n",
                 bench_names=names) == []
    # hard-coded schema_version comparisons only flagged in files that
    # reference bench artifacts (the Perfetto export's own schema with
    # no bench mention stays clean)
    versioned = "ok = artifact['schema_version'] == 5\n"
    assert rules(versioned, bench_names=names) == []
    assert rules("# BENCH_fleet.json reader\n" + versioned,
                 bench_names=names) == ["bench-schema"]


def test_lint_pragma_suppresses():
    src = "for p in ps:\n    run_programs(cfg, s, p)  # lint: ok\n"
    assert rules(src) == []


def test_lint_reports_syntax_errors():
    out = lint_source("def broken(:\n", "mod.py")
    assert out and out[0].rule == "syntax"
    assert isinstance(out[0], Finding) and "mod.py" in str(out[0])


def test_repo_tree_is_lint_clean():
    root = pathlib.Path(__file__).resolve().parents[1]
    findings = lint_tree(root)
    assert findings == [], "\n".join(str(f) for f in findings)
