"""Minimal deterministic stand-in for ``hypothesis``.

Loaded by ``conftest.py`` only when the real package is missing (the
pinned CI/tier-1 image does not ship it, and installing packages is not
an option there).  It covers exactly the surface this repo's property
tests use -- ``@settings(max_examples=..., deadline=...)``, ``@given``
over positional strategies, and ``st.integers`` / ``st.floats`` /
``st.sampled_from`` / ``st.booleans`` / ``st.tuples`` / ``st.lists``
(plus ``.map``) -- by enumerating a fixed number of seeded
pseudo-random examples.  No shrinking, no example database: a
failure reports the concrete arguments via the assertion itself.
"""

from __future__ import annotations

import functools
import inspect
import random
import types
from typing import Any, Callable, Sequence

_DEFAULT_MAX_EXAMPLES = 20
_SEED = 0xC0FFEE


class _Strategy:
    def __init__(self, draw: Callable[[random.Random], Any]):
        self._draw = draw

    def example(self, rng: random.Random) -> Any:
        return self._draw(rng)

    def map(self, fn: Callable[[Any], Any]) -> "_Strategy":
        return _Strategy(lambda rng: fn(self._draw(rng)))


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def floats(min_value: float, max_value: float) -> _Strategy:
    return _Strategy(lambda rng: rng.uniform(min_value, max_value))


def sampled_from(elements: Sequence[Any]) -> _Strategy:
    pool = list(elements)
    return _Strategy(lambda rng: pool[rng.randrange(len(pool))])


def booleans() -> _Strategy:
    return _Strategy(lambda rng: bool(rng.getrandbits(1)))


def tuples(*strats: _Strategy) -> _Strategy:
    return _Strategy(lambda rng: tuple(s.example(rng) for s in strats))


def lists(elements: _Strategy, *, min_size: int = 0,
          max_size: int = 10) -> _Strategy:
    def draw(rng: random.Random):
        n = rng.randint(min_size, max_size)
        return [elements.example(rng) for _ in range(n)]
    return _Strategy(draw)


strategies = types.ModuleType("hypothesis.strategies")
strategies.integers = integers
strategies.floats = floats
strategies.sampled_from = sampled_from
strategies.booleans = booleans
strategies.tuples = tuples
strategies.lists = lists


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None,
             **_ignored) -> Callable:
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn
    return deco


def given(*strats: _Strategy) -> Callable:
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_stub_max_examples",
                        getattr(fn, "_stub_max_examples",
                                _DEFAULT_MAX_EXAMPLES))
            rng = random.Random(_SEED)
            for _ in range(n):
                fn(*args, *(s.example(rng) for s in strats), **kwargs)

        # hide the strategy-filled trailing parameters from pytest's
        # fixture resolution (real hypothesis rewrites the signature too)
        params = list(inspect.signature(fn).parameters.values())
        kept = params[: len(params) - len(strats)]
        wrapper.__signature__ = inspect.Signature(kept)
        del wrapper.__wrapped__
        return wrapper
    return deco
