"""Allocator correctness: vectorized JAX path vs exact ILP dynamic program."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import alloc_exact, allocator


def _random_instance(rng, n_groups, per_group):
    n = n_groups * per_group
    wear = rng.integers(0, 50, size=n).astype(np.int64)
    avail = rng.choice([0, 1, 2, 3], size=n, p=[0.4, 0.15, 0.15, 0.3])
    group = np.repeat(np.arange(n_groups), per_group).astype(np.int32)
    return wear, avail, group


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(2, 6), st.integers(3, 12),
       st.integers(1, 3))
def test_even_split_matches_exact_dp(seed, n_groups, per_group, take):
    """Balanced ILP (K=take, L_min=all eligible): the vectorized per-group
    top-`take` selection must equal the exact DP optimum cost."""
    rng = np.random.default_rng(seed)
    wear, avail, group = _random_instance(rng, n_groups, per_group)
    eligible_idx = list(range(n_groups))
    z = take * n_groups

    dp = alloc_exact.solve(wear, avail, group, z=z, k_max=take,
                           l_min=n_groups, eligible_groups=eligible_idx)
    even = alloc_exact.solve_even(wear, avail, group, take_per_group=take,
                                  eligible_groups=eligible_idx)
    sel, feasible = allocator.allocate(
        wear.reshape(n_groups, per_group),
        avail.reshape(n_groups, per_group),
        np.ones(n_groups, dtype=bool), take)

    assert feasible == dp.feasible == even.feasible
    if not feasible:
        return
    fast_cost = float(wear.reshape(n_groups, per_group)[sel].sum())
    assert fast_cost == pytest.approx(dp.cost)
    assert even.cost == pytest.approx(dp.cost)
    # per-group counts respected
    assert (sel.sum(axis=1) == take).all()
    # only allocatable slots selected
    av2 = avail.reshape(n_groups, per_group)
    assert np.isin(av2[sel], alloc_exact.ALLOCATABLE).all()


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(3, 6), st.integers(4, 10))
def test_general_dp_constraints(seed, n_groups, per_group):
    """The general DP respects Z / K / L_min and never beats brute force
    on tiny instances."""
    rng = np.random.default_rng(seed)
    wear, avail, group = _random_instance(rng, n_groups, per_group)
    z, k_max, l_min = 4, 3, 2
    sol = alloc_exact.solve(wear, avail, group, z=z, k_max=k_max,
                            l_min=l_min, eligible_groups=range(n_groups))
    if not sol.feasible:
        return
    assert len(sol.selected) == z
    counts = np.bincount(group[sol.selected], minlength=n_groups)
    assert (counts <= k_max).all()
    assert (counts > 0).sum() >= l_min
    assert np.isin(avail[sol.selected], alloc_exact.ALLOCATABLE).all()
    assert sol.cost == pytest.approx(wear[sol.selected].sum())

    # brute force over all z-subsets for very small n
    n = len(wear)
    if n <= 14:
        import itertools
        best = np.inf
        ok_ids = [i for i in range(n) if avail[i] in alloc_exact.ALLOCATABLE]
        for comb in itertools.combinations(ok_ids, z):
            c = np.bincount(group[list(comb)], minlength=n_groups)
            if (c <= k_max).all() and (c > 0).sum() >= l_min:
                best = min(best, wear[list(comb)].sum())
        assert sol.cost == pytest.approx(best)


def test_round_robin_windows_disjoint():
    rr = allocator.RoundRobin(n_groups=8, span=4)
    w1, w2 = rr.next_window(), rr.next_window()
    assert not (w1 & w2).any()
    assert (w1 | w2).all()
    w3 = rr.next_window()
    assert (w3 == w1).all()  # wraps around


def test_eligibility_excludes_groups():
    wear = np.zeros((4, 4), np.int64)
    avail = np.zeros((4, 4), np.int32)
    eligible = np.array([True, False, True, False])
    sel, feasible = allocator.allocate(wear, avail, eligible, take=2)
    assert feasible
    assert sel[1].sum() == 0 and sel[3].sum() == 0
    assert sel[0].sum() == 2 and sel[2].sum() == 2


def test_prefers_low_wear():
    wear = np.array([[5, 1, 3, 2]], np.int64)
    avail = np.zeros((1, 4), np.int32)
    sel, _ = allocator.allocate(wear, avail, np.array([True]), take=2)
    assert sel[0].tolist() == [False, True, False, True]


def test_unavailable_never_selected():
    wear = np.array([[0, 0, 9, 9]], np.int64)
    avail = np.array([[2, 1, 0, 3]], np.int32)  # only codes 0/3 allocatable
    sel, feasible = allocator.allocate(wear, avail, np.array([True]), take=2)
    assert feasible
    assert sel[0].tolist() == [False, False, True, True]
