"""Smoke tests for the runnable examples (subprocess; CPU-fast paths)."""

import os
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent


def run_example(script: str, *args, timeout=420) -> str:
    proc = subprocess.run(
        [sys.executable, str(REPO / "examples" / script), *args],
        capture_output=True, text=True, timeout=timeout,
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin",
             "HOME": "/tmp",
             # avoid multi-minute accelerator-backend probing stalls
             "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu")},
        cwd=str(REPO))
    assert proc.returncode == 0, proc.stderr[-2000:]
    return proc.stdout


def test_quickstart():
    out = run_example("quickstart.py")
    assert "86.4%" in out          # the paper's headline number


def test_serve_decode():
    out = run_example("serve_decode.py", "xlstm-125m")
    assert "decode:" in out and "tok/s" in out
