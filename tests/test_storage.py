"""ZoneFS + LSM workload: the paper's host-side SA<->DLWA trade-off."""

import numpy as np
import pytest

from repro.core import FIXED, SUPERBLOCK, ZNSDevice, ZoneState, zn540
from repro.storage import KVBenchConfig, LSMSimulator, ZoneFS, kvbench_mix


def small_cfg(**kw):
    kw.setdefault("n_ops", 1_000_000)
    kw.setdefault("max_concurrent_jobs", 6)
    return KVBenchConfig(**kw)


def run(spec, thresh, **kw):
    flash, zone = zn540()
    dev = ZNSDevice(flash, zone, spec, max_active=14)
    fs = ZoneFS(dev, finish_threshold=thresh)
    sim = LSMSimulator(fs, small_cfg(**kw))
    return sim.run()


def test_kvbench_mix_proportions():
    ops = kvbench_mix(200_000, seed=1)
    frac = np.bincount(ops, minlength=4) / len(ops)
    assert frac[0] == pytest.approx(0.50, abs=0.02)  # inserts
    assert frac[1] == pytest.approx(0.10, abs=0.02)  # deletes
    assert frac[2] == pytest.approx(0.15, abs=0.02)  # point queries
    assert frac[3] == pytest.approx(0.25, abs=0.02)  # updates


def test_kvbench_deterministic():
    a = kvbench_mix(10_000, seed=7)
    b = kvbench_mix(10_000, seed=7)
    assert (a == b).all()


def test_fig1_sa_rises_with_threshold():
    """Fig. 1 / 7b: delaying FINISH (higher occupancy threshold) raises SA."""
    lo = run(SUPERBLOCK, 0.1)
    hi = run(SUPERBLOCK, 0.9)
    assert hi["sa"] > lo["sa"] * 1.2
    assert lo["finishes"] > hi["finishes"]


def test_fig1_baseline_dlwa_falls_with_threshold():
    lo = run(FIXED, 0.1)
    hi = run(FIXED, 0.9)
    assert lo["dlwa"] > hi["dlwa"] * 1.5


def test_fig7b_silentzns_dlwa_flat_and_low():
    """SilentZNS keeps DLWA ~1 at every threshold while the baseline pays
    heavily for early FINISH (paper: 92% less DLWA at 10% occupancy)."""
    for thresh in (0.1, 0.5, 0.9):
        base = run(FIXED, thresh)
        silent = run(SUPERBLOCK, thresh)
        assert silent["dlwa"] < 1.2, thresh
        if thresh == 0.1:
            assert base["dlwa"] > 3.0
            reduction = (base["dlwa"] - silent["dlwa"]) / base["dlwa"]
            assert reduction > 0.70


def test_sa_identical_across_devices():
    """Paper §6.2: SA is a host-side metric, independent of the device's
    internal mapping strategy."""
    base = run(FIXED, 0.5)
    silent = run(SUPERBLOCK, 0.5)
    assert base["sa"] == pytest.approx(silent["sa"], rel=0.02)


def test_wear_silentzns_less_total():
    """Fig. 7c: SilentZNS erases less in total under KVBench churn."""
    flash, zone = zn540()
    totals = {}
    for spec in (FIXED, SUPERBLOCK):
        dev = ZNSDevice(flash, zone, spec, max_active=14,
                        wear_aware=spec is SUPERBLOCK)
        fs = ZoneFS(dev, finish_threshold=0.1)
        for rep in range(2):  # paper repeats KVBench for cumulative wear
            sim = LSMSimulator(fs, small_cfg(seed=rep))
            sim.run()
        totals[spec.name] = dev.block_erases + dev.pending_erases()
    assert totals["superblock"] < totals["fixed"]


def test_zonefs_reclaims_fully_invalid_zones():
    flash, zone = zn540()
    dev = ZNSDevice(flash, zone, SUPERBLOCK)
    fs = ZoneFS(dev, finish_threshold=0.5)
    fs.create(1, 100, lifetime=0)
    assert len(fs._open_zones()) == 1
    fs.delete(1)
    assert fs.stats.resets == 1
    assert len(fs._open_zones()) == 0
    assert fs.sa.invalid_bytes == 0


def test_zonefs_mixing_pins_garbage():
    """A deleted file in a zone with live data stays unreclaimed (the SA
    mechanism)."""
    flash, zone = zn540()
    dev = ZNSDevice(flash, zone, SUPERBLOCK)
    fs = ZoneFS(dev, finish_threshold=0.99)
    fs.create(1, 100, lifetime=0)
    fs.create(2, 100, lifetime=0)   # same class -> same zone
    fs.delete(1)
    assert fs.stats.resets == 0     # file 2 still live in that zone
    assert fs.sa.invalid_bytes > 0
    fs.delete(2)
    assert fs.stats.resets == 1
    assert fs.sa.invalid_bytes == 0


def test_zonefs_one_writer_per_zone():
    flash, zone = zn540()
    dev = ZNSDevice(flash, zone, SUPERBLOCK)
    fs = ZoneFS(dev, finish_threshold=0.5)
    fs.begin(1, lifetime=1, expected_pages=10)
    fs.write(1, 10)
    z1 = fs.sessions[1].zone
    fs.begin(2, lifetime=1, expected_pages=10)
    fs.write(2, 10)
    z2 = fs.sessions[2].zone
    assert z1 != z2                 # concurrent writers get distinct zones
    fs.end(1), fs.end(2)


def test_lsm_compaction_cleans_up_inputs():
    flash, zone = zn540()
    dev = ZNSDevice(flash, zone, SUPERBLOCK, max_active=14)
    fs = ZoneFS(dev, finish_threshold=0.5)
    sim = LSMSimulator(fs, small_cfg(n_ops=2_000_000))
    rep = sim.run()
    assert rep["failed"] == 0.0
    assert rep["compact_pages"] > 0          # compactions happened
    assert len(sim.levels[0]) < 8            # L0 is being drained
    # every live file's extents are valid
    for f in fs.files.values():
        pass
    # page accounting: fs host pages == device host pages
    assert rep["host_pages"] == dev.host_pages
