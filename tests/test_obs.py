"""Flight-recorder tests: telemetry purity, decoding, export, profiling.

The load-bearing property is **effect-freeness**: running the same
program with and without an ``ObsConfig`` must produce bit-identical
``DeviceState`` and ``OpTrace`` (the recorder only *reads* the integer
state machine).  On top of that:

* histogram totals reconcile exactly with the end-state counters;
* tenant / zone / fleet decoders agree with brute-force re-aggregation
  of the materialized trace;
* ``op_stream`` page-stream reconstruction from the ``OpTrace`` is
  bit-identical to the legacy device's ``trace=True`` streams across
  element specs (the timing model consumes these streams, so drift here
  silently corrupts latency numbers);
* the Perfetto export validates against the checked-in JSON schema
  (subset validator always; real ``jsonschema`` when installed);
* the profiler / recompile counter read real jit caches: a new shape
  compiles, a repeat does not, and repeated same-shape ``Evaluator``
  generations keep a flat cache (the ``pad_quantum`` guarantee).
"""

import json
import importlib.util
import pathlib
import sys

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import engine as E
from repro.core.device_legacy import LegacyZNSDevice
from repro.core.elements import BLOCK, FIXED, SUPERBLOCK, hchunk, vchunk
from repro.core.geometry import FlashGeometry, ZoneGeometry
from repro.obs import (ObsConfig, Profiler, RecompileCounter,
                       device_rollup, fleet_timelines, jit_cache_size,
                       lane_timeline, profile_dispatch, tenant_timelines,
                       validate_trace, zone_timelines)

REPO = pathlib.Path(__file__).resolve().parent.parent
SPECS = [BLOCK, vchunk(2), hchunk(2), SUPERBLOCK, FIXED]


def tiny_flash():
    return FlashGeometry(n_channels=4, ways_per_channel=1,
                         blocks_per_lun=8, pages_per_block=4,
                         page_bytes=4096)


def tiny_engine(spec, max_active=3, **kw):
    return E.ZoneEngine(tiny_flash(), ZoneGeometry(4, 2), spec,
                        max_active=max_active, **kw)


#: the fuzz row mirrors test_engine_diff: overflow writes mix with
#: legal fills, FINISH exercises dummy pages, RESET exercises erases
_FUZZ_ROW = st.tuples(
    st.sampled_from([E.OP_WRITE, E.OP_FINISH, E.OP_RESET]),
    st.integers(0, 3),
    st.integers(1, 34),
    st.booleans(),
)


def _mixed_program(eng, n=24, seed=0):
    rng = np.random.default_rng(seed)
    rows = []
    for _ in range(n):
        op = [E.OP_WRITE, E.OP_FINISH, E.OP_RESET][int(rng.integers(3))]
        rows.append((op, int(rng.integers(4)),
                     int(rng.integers(1, eng.cfg.zone_pages + 3)),
                     E.F_HOST if rng.integers(2) else 0))
    return E.encode_program(rows)


# --------------------------------------------------------------------- #
# effect-freeness: telemetry-on == telemetry-off, bit for bit
# --------------------------------------------------------------------- #
@settings(max_examples=10, deadline=None)
@given(st.integers(0, len(SPECS) - 1),
       st.lists(_FUZZ_ROW, min_size=1, max_size=40))
def test_telemetry_is_effect_free(spec_i, rows):
    eng = tiny_engine(SPECS[spec_i])
    prog = E.encode_program(
        [(op, z, n, E.F_HOST if host else 0)
         for op, z, n, host in rows])
    s0 = eng.init_state()
    state_off, trace_off = eng.run(s0, prog)
    state_on, trace_on, tel = eng.run(
        s0, prog, obs=ObsConfig(n_buckets=7))
    for a, b in zip(state_off, state_on):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(trace_off, trace_on):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    assert int(tel.step) == len(prog)


def test_batched_telemetry_is_effect_free():
    eng = tiny_engine(SUPERBLOCK)
    progs = np.stack([_mixed_program(eng, seed=s) for s in range(3)])
    s0 = eng.init_state()
    state_off, trace_off = eng.run_batch(s0, progs)
    state_on, trace_on, tel = eng.run_batch(
        s0, progs, obs=ObsConfig(n_buckets=5))
    for a, b in zip(state_off, state_on):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(trace_off, trace_on):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    assert np.asarray(tel.host).shape == (3, 5)


# --------------------------------------------------------------------- #
# histogram reconciliation: bucket sums == end-state counters
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("spec", [BLOCK, SUPERBLOCK, FIXED],
                         ids=lambda s: s.name)
def test_histogram_totals_match_end_state(spec):
    eng = tiny_engine(spec)
    prog = _mixed_program(eng, n=30, seed=3)
    obs = ObsConfig(n_buckets=4)
    state, trace, tel = eng.run(eng.init_state(), prog, obs=obs)
    tl = lane_timeline(obs, tel)
    assert sum(tl["host"]) == int(state.host_pages)
    assert sum(tl["dummy"]) == int(state.dummy_pages)
    assert sum(tl["erases"]) == int(state.block_erases)
    assert sum(tl["allocs"]) == int(state.alloc_calls)
    ok = np.asarray(trace.ok)
    assert sum(tl["ok_ops"]) == int(ok.sum())
    assert sum(tl["illegal_ops"]) == len(prog) - int(ok.sum())
    # cumulative dlwa's last point is the device's end-state DLWA
    h, d = int(state.host_pages), int(state.dummy_pages)
    want = (h + d) / h if h else 1.0
    assert tl["dlwa"][-1] == pytest.approx(want)
    # gauges bound the series they track
    assert max(tl["active_max"]) <= eng.cfg.max_active
    assert max(tl["wear_max"]) <= int(np.asarray(state.elem_wear).max())


def test_bucket_binning_is_progress_ordered():
    """Op i of n lands in bucket i*B//n: an all-host-write program puts
    its pages in op order, so per-bucket host counts must follow the
    program's page schedule exactly."""
    eng = tiny_engine(SUPERBLOCK)
    rows = [(E.OP_WRITE, z, 2, E.F_HOST) for z in (0, 1, 2)] * 4
    prog = E.encode_program(rows)
    obs = ObsConfig(n_buckets=3)
    _, trace, tel = eng.run(eng.init_state(), prog, obs=obs)
    host = np.asarray(trace.host_delta, dtype=np.int64)
    want = [0, 0, 0]
    for i in range(len(prog)):
        want[min(i * 3 // len(prog), 2)] += int(host[i])
    assert np.asarray(tel.host).tolist() == want


def test_tenant_binning_width5():
    eng = tiny_engine(SUPERBLOCK)
    rows = np.array([
        [E.OP_WRITE, 0, 3, E.F_HOST, 0],
        [E.OP_WRITE, 1, 5, E.F_HOST, 1],
        [E.OP_WRITE, 0, 2, E.F_HOST, 0],
        [E.OP_FINISH, 1, 0, 0, 7],       # out-of-range tag clips to 2
    ], dtype=np.int32)
    obs = ObsConfig(n_buckets=2, n_tenants=3)
    state, trace, tel = eng.run(eng.init_state(), rows, obs=obs)
    th = np.asarray(tel.tenant_host).sum(axis=0)
    td = np.asarray(tel.tenant_dummy).sum(axis=0)
    assert th.tolist() == [5, 5, 0]
    assert td.sum() == int(state.dummy_pages)
    assert td[0] == td[1] == 0           # FINISH pad went to class 2
    tls = tenant_timelines(obs, tel)
    assert sorted(tls) == [0, 1, 2]
    assert sum(tls[1]["host"]) == 5


# --------------------------------------------------------------------- #
# decoders: lane / fleet / rollup / zone
# --------------------------------------------------------------------- #
def test_fleet_timelines_and_rollup():
    eng = tiny_engine(SUPERBLOCK)
    progs = np.stack([_mixed_program(eng, seed=s) for s in range(4)])
    obs = ObsConfig(n_buckets=6)
    states, traces, tel = eng.run_batch(eng.init_state(), progs,
                                        obs=obs)
    with pytest.raises(ValueError, match="lane"):
        lane_timeline(obs, tel)          # batched needs explicit lane
    tls = fleet_timelines(obs, tel)
    assert len(tls) == 4
    host = np.asarray(states.host_pages)
    for lane, tl in enumerate(tls):
        assert sum(tl["host"]) == int(host[lane])
    pooled = device_rollup(tls)
    assert sum(pooled["host"]) == int(host.sum())
    for i in range(6):
        assert pooled["wear_max"][i] == max(
            tl["wear_max"][i] for tl in tls)
    assert device_rollup([]) == {}


def test_zone_timelines_match_trace():
    eng = tiny_engine(SUPERBLOCK)
    prog = _mixed_program(eng, n=30, seed=5)
    _, trace = eng.run(eng.init_state(), prog)
    per_zone = zone_timelines(prog, trace, n_buckets=5)
    zone = np.asarray(trace.zone)
    host = np.asarray(trace.host_delta, dtype=np.int64)
    wp = np.asarray(trace.wp_after, dtype=np.int64)
    assert sorted(per_zone) == sorted(
        {int(z) for z in np.asarray(prog)[:, 1]})
    for z, tl in per_zone.items():
        mask = zone == z
        assert sum(tl["host"]) == int(host[mask].sum())
        # wp gauge ends at the zone's last traced write pointer
        last = np.nonzero(mask)[0][-1]
        assert tl["wp"][-1] == int(wp[last])
        assert all(v >= 0 for v in tl["wp"])  # carried, never sentinel


def test_obsconfig_rejects_degenerate_shapes():
    for kw in ({"n_buckets": 0}, {"n_tenants": 0}, {"n_buckets": -3}):
        with pytest.raises(ValueError):
            ObsConfig(**kw)


# --------------------------------------------------------------------- #
# op_stream: OpTrace -> page-stream reconstruction vs legacy trace=True
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("spec", SPECS, ids=lambda s: s.name)
def test_op_stream_reconstruction_matches_legacy(spec):
    eng = tiny_engine(spec, max_active=3)
    leg = LegacyZNSDevice(tiny_flash(), ZoneGeometry(4, 2), spec,
                          max_active=3)
    rows = []
    for z in range(3):
        rows.append((E.OP_WRITE, z, 3 + 2 * z, E.F_HOST))
        rows.append((E.OP_FINISH, z, 0, 0))
    prog = E.encode_program(rows)
    _, trace = eng.run(eng.init_state(), prog)
    ops = np.asarray(prog)
    for i, (op, z, n, _f) in enumerate(ops):
        if op == E.OP_WRITE:
            legacy = leg.zone_write(int(z), int(n), trace=True)
        else:
            legacy = leg.zone_finish(int(z), trace=True)
        mine = eng.op_stream(
            int(op), int(np.asarray(trace.wp_before)[i]),
            int(np.asarray(trace.wp_after)[i]),
            int(np.asarray(trace.dummy_delta)[i]),
            np.asarray(trace.elems)[i], np.asarray(trace.cols)[i])
        assert (mine is None) == (legacy is None), (spec.name, i)
        if mine is None:
            continue
        luns, channels, kind = mine
        assert np.array_equal(luns, legacy.luns), (spec.name, i)
        assert np.array_equal(channels, legacy.channels), (spec.name, i)
        assert kind == "write"


# --------------------------------------------------------------------- #
# Perfetto export + schema validation
# --------------------------------------------------------------------- #
def _tiny_fleet(n_configs=2, n_devices=2):
    from repro.fleet import (N_TENANTS, build_fleet_batch, grid_space,
                             run_fleet)
    flash = FlashGeometry(n_channels=4, ways_per_channel=2,
                          blocks_per_lun=64, pages_per_block=16,
                          page_bytes=4096)
    eng = E.ZoneEngine(flash, ZoneGeometry(8, 4), SUPERBLOCK,
                       max_active=6)
    configs = grid_space(segments=(4,), chunks=(64,),
                         parities=(False, True),
                         wear=(True,))[:n_configs]
    programs, dyn, _ = build_fleet_batch(eng, configs,
                                         n_devices=n_devices)
    obs = ObsConfig(n_buckets=8, n_tenants=N_TENANTS + 1)
    res = run_fleet(eng, programs, dyn=dyn, n_tenants=N_TENANTS,
                    obs=obs)
    return eng, configs, res, obs


def test_trace_export_validates_and_loads(tmp_path):
    from repro.obs import fleet_trace_events, write_trace
    eng, _configs, res, _obs = _tiny_fleet()
    events = fleet_trace_events(res, eng)
    phases = {e["ph"] for e in events}
    assert phases == {"M", "X", "C"}
    # every lane got a named process track; tenants are named threads
    names = [e for e in events if e["ph"] == "M"]
    assert {e["name"] for e in names} == {"process_name", "thread_name"}
    # durations follow the service-time model: ceil(pages/P) * t_page
    t_page = (eng.flash.t_prog + eng.flash.t_xfer) * 1e6
    for e in events:
        if e["ph"] == "X" and e["args"]["pages"]:
            pg = e["args"]["pages"]
            want = -(-pg // int(eng.cfg.parallelism)) * t_page
            assert e["dur"] == pytest.approx(want, rel=1e-6)
            assert e["ts"] >= -1e-9
    obj = write_trace(tmp_path / "t_trace.json", events,
                      meta={"run": "test"})
    validate_trace(obj)                  # mini + jsonschema when present
    back = json.loads((tmp_path / "t_trace.json").read_text())
    assert back["otherData"] == {"run": "test"}
    assert len(back["traceEvents"]) == len(events)


def test_trace_validation_rejects_malformed():
    ok = {"traceEvents": [
        {"ph": "X", "name": "WRITE z0", "pid": 0, "ts": 0.0,
         "dur": 1.0}]}
    validate_trace(ok)
    with pytest.raises(ValueError, match="traceEvents"):
        validate_trace({"displayTimeUnit": "ms"})
    with pytest.raises(ValueError, match="ph"):
        validate_trace({"traceEvents": [{"name": "x", "pid": 0}]})
    with pytest.raises(ValueError):
        validate_trace({"traceEvents": [
            {"ph": "Q", "name": "x", "pid": 0}]})   # ph outside enum
    with pytest.raises(ValueError):
        validate_trace({"traceEvents": [
            {"ph": "X", "name": "x", "pid": 0, "ts": "late"}]})


def test_fleet_metrics_registry():
    from repro.obs.export import fleet_metrics
    eng, _configs, res, _obs = _tiny_fleet()
    m = fleet_metrics(res, eng).as_dict()
    real = np.asarray(res.programs)[:, :, 0] != 0
    assert m["counters"]["ops_ok"] + m["counters"]["ops_illegal"] \
        == int(real.sum())
    host = np.asarray(res.host_delta, dtype=np.int64).sum()
    assert m["counters"]["host_pages"] + m["counters"]["parity_pages"] \
        == int(host)
    assert m["gauges"]["makespan_s"] == pytest.approx(
        float(np.asarray(res.makespans).max()))
    assert any(k.startswith("tenant") and k.endswith("_p99_latency_s")
               for k in m["gauges"])


# --------------------------------------------------------------------- #
# the --obs acceptance path: emit_fleet_obs via fleet_search
# --------------------------------------------------------------------- #
def _load_fleet_search():
    spec = importlib.util.spec_from_file_location(
        "fleet_search", REPO / "benchmarks" / "fleet_search.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_emit_obs_artifacts_end_to_end(tmp_path):
    fs = _load_fleet_search()
    eng, configs, _res, _obs = _tiny_fleet()
    out = fs.emit_obs_artifacts(
        eng, configs, n_devices=2,
        out_prefix=str(tmp_path / "t"), n_buckets=8,
        meta={"suite": "test"})
    trace = json.loads(pathlib.Path(out["trace"]).read_text())
    validate_trace(trace)
    assert out["n_events"] == len(trace["traceEvents"]) > 0
    obs = json.loads(pathlib.Path(out["obs"]).read_text())
    assert obs["schema_version"] == 1
    assert obs["meta"]["suite"] == "test"
    assert len(obs["lane_labels"]) == len(obs["timelines"]["lanes"]) \
        == len(configs) * 2
    assert set(obs["jit_cache"]) == {
        "apply_op", "run_program", "run_programs",
        "simulate_fleet_ops"}
    assert "fleet.engine" in obs["profile"]
    # the two DLWA views reconcile through the same three counters:
    # the registry gauge is the paper's (parity pages count as
    # amplification), the pooled timeline's is device-level (the
    # in-scan recorder sees parity traffic as host-flagged writes)
    c = obs["metrics"]["counters"]
    h, p, d = (c["host_pages"], c["parity_pages"],
               c["superfluous_pages"])
    assert obs["metrics"]["gauges"]["dlwa"] == pytest.approx(
        (h + p + d) / h)
    assert obs["timelines"]["fleet"]["dlwa"][-1] == pytest.approx(
        (h + p + d) / (h + p))


def test_emit_fleet_obs_requires_telemetry(tmp_path):
    from repro.fleet import N_TENANTS, build_fleet_batch, run_fleet
    from repro.obs import emit_fleet_obs
    eng, configs, res, obs = _tiny_fleet()
    bare = run_fleet(
        eng, *build_fleet_batch(eng, configs, n_devices=2)[:1],
        n_tenants=N_TENANTS)
    with pytest.raises(ValueError, match="telemetry"):
        emit_fleet_obs(bare, eng, obs=obs,
                       out_prefix=str(tmp_path / "x"))


def test_obs_report_renders_sections(tmp_path):
    fs = _load_fleet_search()
    sys.path.insert(0, str(REPO / "tools"))
    try:
        import obs_report
    finally:
        sys.path.pop(0)
    eng, configs, _res, _obs = _tiny_fleet()
    out = fs.emit_obs_artifacts(eng, configs, n_devices=2,
                                out_prefix=str(tmp_path / "r"),
                                n_buckets=8)
    obs = json.loads(pathlib.Path(out["obs"]).read_text())
    report = obs_report.render(obs, max_lanes=2)
    for section in ("# Flight-recorder report", "## DLWA vs time",
                    "## Wear frontier vs time",
                    "## p99 latency per tenant class",
                    "## Recompile table", "## Dispatch profile"):
        assert section in report, section
    assert "lanes omitted" in report     # 4 lanes, max_lanes=2
    assert obs_report.spark([0, 1, 2, 3]) == "▁▃▅█"
    assert obs_report.spark([5, 5]) == "▁▁"


# --------------------------------------------------------------------- #
# profiling: sections, recompile counting, Evaluator stability
# --------------------------------------------------------------------- #
def test_profiler_sections_accumulate():
    prof = Profiler()
    with prof.section("a"):
        pass
    with prof.section("a"):
        with prof.section("b"):
            pass
    snap = prof.snapshot()
    assert snap["a"]["calls"] == 2.0
    assert snap["b"]["calls"] == 1.0
    assert snap["a"]["wall_s"] >= snap["a"]["execute_s"] >= 0.0
    snap["a"]["calls"] = 99.0            # snapshot is a copy
    assert prof.sections["a"]["calls"] == 2.0


def test_profile_dispatch_blocks_and_counts():
    eng = tiny_engine(SUPERBLOCK)
    prog = _mixed_program(eng, n=8)
    prof = Profiler()
    (state, _trace), sec = profile_dispatch(
        eng.run, eng.init_state(), prog, profiler=prof, name="run")
    assert int(state.host_pages) >= 0
    assert sec["calls"] == 1.0 and sec["wall_s"] > 0.0
    assert prof.sections["run"] is sec


def test_recompile_counter_sees_new_shapes():
    eng = tiny_engine(SUPERBLOCK)
    rc = RecompileCounter(run_program=E.run_program)
    assert jit_cache_size(E.run_program) >= 0
    p1 = _mixed_program(eng, n=10)
    eng.run(eng.init_state(), p1)
    base = rc.counts()
    eng.run(eng.init_state(), _mixed_program(eng, n=10, seed=9))
    assert rc.delta(base)["run_program"] == 0    # same shape: cache hit
    eng.run(eng.init_state(), _mixed_program(eng, n=11))
    assert rc.delta(base)["run_program"] == 1    # new shape: one entry
    with pytest.raises(ValueError):
        RecompileCounter()


def test_evaluator_jit_cache_stable_across_generations():
    """The acceptance property: repeated same-shape Evaluator
    generations must not grow the run_programs cache (pad_quantum keeps
    the batch rectangular and shape-stable)."""
    from repro.fleet import Evaluator, grid_space
    eng, _configs, _res, _obs = _tiny_fleet()
    configs = grid_space(segments=(4,), chunks=(64,),
                         parities=(False, True),
                         wear=(True, False))[:4]
    ev = Evaluator(eng, n_devices=2, profiler=Profiler())
    counts = []
    for _ in range(3):
        rows = ev.evaluate(configs)
        assert len(rows) == len(configs)
        counts.append(ev.jit_cache()["run_programs"])
    assert counts[0] == counts[1] == counts[2]
    assert ev.profiler.sections["evaluator.build"]["calls"] == 3.0
    assert ev.profiler.sections["fleet.engine"]["calls"] == 3.0


def test_evolve_history_carries_profile_when_instrumented():
    from repro.fleet import (Evaluator, EvolveParams, SearchSpace,
                             evolve)
    eng, _c, _r, _o = _tiny_fleet()
    space = SearchSpace(segments=(4,), chunks=(64,),
                        parities=(False, True))
    params = EvolveParams(population=2, generations=2)
    plain = evolve(eng, space=space, params=params, seed=0,
                   n_devices=2)
    assert all("jit_cache" not in row for row in plain.history)
    ev = Evaluator(eng, n_devices=2, profiler=Profiler())
    inst = evolve(eng, space=space, params=params, seed=0,
                  n_devices=2, evaluator=ev)
    assert inst.history, "instrumented evolve produced no generations"
    for row in inst.history:
        assert row["jit_cache"]["run_programs"] >= 1
        assert "fleet.engine" in row["profile"]
    # instrumentation must not change what the search found
    assert [r["best_so_far"] for r in inst.history] == \
        [r["best_so_far"] for r in plain.history]
