"""Property layer for the SilentZNS on-the-fly allocation policy.

The ``alloc_policy="silent"`` axis commits a zone's block collection on
the fly instead of pinning the whole static grid at ALLOC.  Three
invariant families are fuzzed here (degrading to the seeded
``_hypothesis_stub`` enumeration when hypothesis is not installed):

1. every claim -- initial ALLOC and on-demand growth alike -- respects
   the wear-leveling bound (no claimed block more than ``wear_bound``
   erases above the freshest free block at claim time) and the
   parallelism floor (an open zone's collection spans exactly
   ``zone_groups`` distinct LUN groups, one rank at a time);
2. no block is double-claimed: the per-zone element tables stay
   disjoint and consistent with the reverse ``elem_zone`` map;
3. ``alloc_policy="traditional"`` is bit-identical to the existing
   allocator on all five element specs (the policy axis must be a pure
   extension), and fill+FINISH page accounting (host, dummy, DLWA) is
   policy-independent -- only wear/erase traffic may diverge.
"""

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import engine as E
from repro.core.device_legacy import LegacyZNSDevice
from repro.core.elements import (BLOCK, FIXED, SUPERBLOCK, hchunk, vchunk)
from repro.core.geometry import FlashGeometry, ZoneGeometry

SPECS = [BLOCK, vchunk(2), hchunk(2), SUPERBLOCK, FIXED]


def tiny_flash():
    return FlashGeometry(n_channels=4, ways_per_channel=1, blocks_per_lun=8,
                         pages_per_block=4, page_bytes=4096)


def tiny_engine(spec, max_active=3):
    return E.ZoneEngine(tiny_flash(), ZoneGeometry(4, 2), spec,
                        max_active=max_active)


#: one fuzz op row: (opcode, zone, n_pages, host).  Explicit ALLOC rows
#: exercise the hint-sized initial claim; WRITE past the commitment
#: exercises on-demand growth; n_pages past the 32-page zone mixes in
#: illegal overflow writes.
_ROW = st.tuples(
    st.sampled_from([E.OP_ALLOC, E.OP_WRITE, E.OP_FINISH, E.OP_RESET]),
    st.integers(0, 3),
    st.integers(1, 34),
    st.booleans(),
)


# --------------------------------------------------------------------- #
# 1 + 2. claim invariants under fuzzed churn, op by op
# --------------------------------------------------------------------- #
@settings(max_examples=8, deadline=None)
@given(st.lists(_ROW, min_size=1, max_size=24),
       st.sampled_from([None, 0, 1, 3]))
def test_silent_claims_respect_bounds_and_stay_disjoint(rows, wear_bound):
    """Every silent-policy claim is wear-bounded and rank-rectangular
    across the parallelism groups, and the zone element tables never
    share a block.  Checked after every op so the invariant holds at
    claim time, not just at the end."""
    eng = tiny_engine(BLOCK)
    dyn = eng.dyn(alloc_policy="silent", wear_bound=wear_bound)
    cfg, n = eng.cfg, eng.cfg.n_elements
    zg = int(dyn.zone_groups)
    bound = float("inf") if wear_bound is None else wear_bound
    groups = np.arange(n) // cfg.per_group
    state = eng.init_state()
    for i, (op, z, pages, host) in enumerate(rows):
        pre_zone = np.asarray(state.elem_zone)[:n].copy()
        pre_wear = np.asarray(state.elem_wear)[:n].copy()
        pre_avail = np.asarray(state.elem_avail)[:n].copy()
        prog = E.encode_program([(op, z, pages,
                                  E.F_HOST if host else 0)])
        state, _ = eng.run(state, prog, dyn)
        post_zone = np.asarray(state.elem_zone)[:n]
        ctx = f"i={i} row={rows[i]} wear_bound={wear_bound}"
        # wear bound: a block claimed this op was within `bound` erases
        # of the freshest free block available before the op
        new = (pre_zone < 0) & (post_zone >= 0)
        if new.any():
            free = ((pre_avail == E.AVAIL_FREE)
                    | (pre_avail == E.AVAIL_INVALID))
            assert free.any(), ctx
            slack = pre_wear[new] - pre_wear[free].min()
            assert (slack <= bound).all(), f"wear slack {slack} {ctx}"
        # parallelism floor: an OPEN zone's collection spans exactly
        # zone_groups distinct LUN groups, in whole ranks (FINISH may
        # later free untouched blocks, so FULL zones are exempt)
        zstates = np.asarray(state.zone_state)
        for zz in range(cfg.n_zones):
            mine = post_zone == zz
            if mine.any() and zstates[zz] == E.ZONE_OPEN:
                got = set(groups[mine].tolist())
                assert len(got) == zg, f"zone {zz} groups {got} {ctx}"
                assert int(mine.sum()) % zg == 0, f"zone {zz} {ctx}"
        # no double claim: zone tables disjoint + reverse-map consistent
        ze = np.asarray(state.zone_elems)
        owner = {}
        for zz in range(cfg.n_zones):
            for e in ze[zz][ze[zz] >= 0].tolist():
                assert e not in owner, \
                    f"elem {e} in zones {owner.get(e)} and {zz} {ctx}"
                owner[e] = zz
                assert post_zone[e] == zz, f"elem {e} reverse map {ctx}"


@settings(max_examples=6, deadline=None)
@given(st.lists(_ROW, min_size=1, max_size=24))
def test_silent_growth_equals_one_shot_commitment(rows):
    """Replaying the same program must be deterministic, and a zone
    grown across several WRITEs must end with the same collection shape
    (group span, rank multiple) as the claim invariants demand -- the
    growth path shares `_take_lowest` with ALLOC, so a divergence here
    is a growth-bookkeeping bug."""
    eng = tiny_engine(BLOCK)
    dyn = eng.dyn(alloc_policy="silent")
    prog = E.encode_program([(op, z, n, E.F_HOST if host else 0)
                             for op, z, n, host in rows])
    s1, t1 = eng.run(eng.init_state(), prog, dyn)
    s2, t2 = eng.run(eng.init_state(), prog, dyn)
    for a, b in zip(s1, s2):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    assert np.array_equal(np.asarray(t1.ok), np.asarray(t2.ok))


# --------------------------------------------------------------------- #
# 3. the policy axis is a pure extension
# --------------------------------------------------------------------- #
@settings(max_examples=6, deadline=None)
@given(st.integers(0, len(SPECS) - 1), st.integers(1, 4),
       st.lists(_ROW, min_size=1, max_size=30))
def test_traditional_policy_bit_identical(spec_i, max_active, rows):
    """`alloc_policy="traditional"` must leave the exact pytree the
    default dyn leaves on every element spec, and both must replay the
    legacy per-op device exactly -- the new axis cannot perturb the
    existing allocator by even one bit."""
    spec = SPECS[spec_i]
    eng = tiny_engine(spec, max_active=max_active)
    # OP_ALLOC has no legacy per-op equivalent in this oracle loop;
    # keep the op mix to the legacy surface
    rows = [(E.OP_WRITE if op == E.OP_ALLOC else op, z, n, host)
            for op, z, n, host in rows]
    prog = E.encode_program([(op, z, n, E.F_HOST if host else 0)
                             for op, z, n, host in rows])
    base_state, base_trace = eng.run(eng.init_state(), prog)
    trad_state, trad_trace = eng.run(eng.init_state(), prog,
                                     eng.dyn(alloc_policy="traditional"))
    ctx = f"spec={spec.name} ma={max_active}"
    for mine, ref in zip(trad_state, base_state):
        assert np.array_equal(np.asarray(mine), np.asarray(ref)), ctx
    assert np.array_equal(np.asarray(trad_trace.ok),
                          np.asarray(base_trace.ok)), ctx
    # and the pre-policy-axis oracle: the legacy stateful device
    leg = LegacyZNSDevice(tiny_flash(), ZoneGeometry(4, 2), spec,
                          max_active=max_active)
    for op, z, n, host in rows:
        try:
            if op == E.OP_WRITE:
                leg.zone_write(z, n, host=host)
            elif op == E.OP_FINISH:
                leg.zone_finish(z)
            else:
                leg.zone_reset(z)
        except RuntimeError:
            pass
    ne = eng.cfg.n_elements
    assert np.array_equal(np.asarray(trad_state.elem_wear[:ne]),
                          leg.elem_wear), ctx
    assert np.array_equal(np.asarray(trad_state.elem_avail[:ne]),
                          leg.elem_avail), ctx
    assert np.array_equal(np.asarray(trad_state.elem_pages[:ne]),
                          leg.elem_pages), ctx
    assert np.array_equal(np.asarray(trad_state.elem_zone[:ne]),
                          leg.elem_zone), ctx
    assert int(trad_state.host_pages) == leg.host_pages, ctx
    assert int(trad_state.dummy_pages) == leg.dummy_pages, ctx
    assert int(trad_state.block_erases) == leg.block_erases, ctx
    assert int(trad_state.n_active) == leg.n_active, ctx


@settings(max_examples=8, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 3), st.integers(1, 32)),
                min_size=1, max_size=10))
def test_fill_finish_page_accounting_is_policy_independent(fills):
    """Host/dummy page totals (hence DLWA) of fill+FINISH traffic are a
    function of the write pointers alone -- the silent policy changes
    *which* blocks hold the pages, never how many pages FINISH pads.
    This is the identity the paper-headline differential oracle relies
    on (see ``tests/test_engine_diff.py``)."""
    eng = tiny_engine(BLOCK, max_active=4)
    rows = [(E.OP_WRITE, z, n, E.F_HOST) for z, n in fills]
    rows += [(E.OP_FINISH, z, 0, 0) for z in range(4)]
    prog = E.encode_program(rows)
    out = {}
    for policy in ("traditional", "silent"):
        state, trace = eng.run(eng.init_state(), prog,
                               eng.dyn(alloc_policy=policy))
        out[policy] = (int(state.host_pages), int(state.dummy_pages),
                       np.asarray(trace.ok).tolist())
    assert out["traditional"] == out["silent"], fills
