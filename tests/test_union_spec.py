"""Union-config tests: per-lane element specs in one fleet dispatch.

The tentpole exactness oracle for the ``DynConfig`` spec axis: a mixed
SUPERBLOCK + BLOCK + VCHUNK batch through one padded union
:class:`~repro.core.engine.EngineConfig` must be *bit-identical* per
lane to independent dispatches on engines built with each spec
outright -- element wear/avail/pages, zone tables, counters, the lot.
Programs are hypothesis-fuzzed (legal and illegal ops mixed, like
``test_engine_diff.py``'s program fuzz; degrades to the seeded
``_hypothesis_stub`` enumeration when hypothesis is missing), and the
spec axis composes with the established capacity-shrink and allocator
overrides.  The dyn-derived slot map that replaces the static
per-spec ``element_pages`` reduction is property-checked against the
closed forms for every element kind.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import engine as E
from repro.core import zns
from repro.core.elements import (BLOCK, FIXED, SUPERBLOCK, hchunk, vchunk)
from repro.core.geometry import FlashGeometry, ZoneGeometry

UNION_SPECS = (SUPERBLOCK, BLOCK, vchunk(2))


def tiny_flash():
    return FlashGeometry(n_channels=4, ways_per_channel=1,
                         blocks_per_lun=16, pages_per_block=4,
                         page_bytes=4096)


ZGEOM = ZoneGeometry(4, 4)
FLASH = tiny_flash()
UNION = E.ZoneEngine(FLASH, ZGEOM, UNION_SPECS, max_active=6)
SINGLES = {s: E.ZoneEngine(FLASH, ZGEOM, s, max_active=6)
           for s in UNION_SPECS}
N_OPS = 32          # fixed padded program length (one compiled shape)
HALF = ZGEOM.zone_pages(FLASH) // 2


def pad_rows(rows):
    prog = np.zeros((N_OPS, 4), dtype=np.int32)
    enc = E.encode_program(rows)[:N_OPS]
    prog[: len(enc)] = enc
    return prog


def assert_lane_matches_single(states, trace, k, spec, ref, ref_trace,
                               ctx=""):
    """Union batch lane ``k`` == the single-spec engine's final state,
    with member element ids mapped onto the union grid."""
    single = SINGLES[spec]
    ids = UNION.member_element_ids(spec)
    for name in ("elem_wear", "elem_avail", "elem_pages", "elem_zone"):
        a = np.asarray(getattr(states, name)[k])[ids]
        b = np.asarray(getattr(ref, name))[: len(ids)]
        assert np.array_equal(a, b), f"{name} {ctx}"
    for name in ("host_pages", "dummy_pages", "block_erases",
                 "alloc_calls", "n_active", "rr_next"):
        assert int(getattr(states, name)[k]) == int(getattr(ref, name)), \
            f"{name} {ctx}"
    for name in ("zone_state", "zone_wp", "zone_host_wp", "zone_cols"):
        assert np.array_equal(np.asarray(getattr(states, name)[k]),
                              np.asarray(getattr(ref, name))), \
            f"{name} {ctx}"
    # zone slot tables: the lane's slots hold union ids (dense ids
    # mapped through the member grid); slots past the member's slot
    # count stay unmapped
    ns = single.cfg.n_slots
    lut = np.full(single.cfg.n_elements + 1, -1, np.int64)
    lut[: len(ids)] = ids
    ze_ref = np.asarray(ref.zone_elems)
    mapped = np.where(ze_ref >= 0, lut[np.clip(ze_ref, 0, len(ids))], -1)
    ze = np.asarray(states.zone_elems[k])
    assert np.array_equal(ze[:, :ns], mapped), f"zone_elems {ctx}"
    assert (ze[:, ns:] == -1).all(), f"zone_elems tail {ctx}"
    # per-op legality must line up too (same illegal ops rejected)
    assert np.array_equal(np.asarray(trace.ok[k]),
                          np.asarray(ref_trace.ok)), f"ok {ctx}"


# --------------------------------------------------------------------- #
# the dyn-derived slot map == the per-kind closed forms
# --------------------------------------------------------------------- #
@pytest.mark.parametrize(
    "spec", [BLOCK, vchunk(2), hchunk(2), SUPERBLOCK, FIXED],
    ids=lambda s: s.name)
def test_generic_slot_map_matches_element_pages(spec):
    """``_written_per_slot`` now derives the (segment, column) -> slot
    assignment from DynConfig values; for every element kind and every
    write pointer it must reproduce ``zns.element_pages`` exactly."""
    cfg, _ = E.make_config(FLASH, ZGEOM, spec, max_active=6)
    dyn = E.make_dyn(cfg)
    for wp in range(cfg.zone_pages + 1):
        want = zns.element_pages(wp, spec, cfg.parallelism,
                                 cfg.n_segments, cfg.pages_per_block)
        got = np.asarray(E._written_per_slot(cfg, dyn, wp))
        assert np.array_equal(got[: len(want)], want), (spec.name, wp)
        assert (got[len(want):] == 0).all(), (spec.name, wp)


# --------------------------------------------------------------------- #
# union construction invariants
# --------------------------------------------------------------------- #
def test_union_config_padded_to_maxima():
    cfg = UNION.cfg
    singles = [SINGLES[s].cfg for s in UNION_SPECS]
    assert cfg.n_groups == max(c.n_groups for c in singles)
    assert cfg.per_group == max(c.per_group for c in singles)
    assert cfg.n_elements == cfg.n_groups * cfg.per_group
    assert cfg.n_slots == max(c.n_slots for c in singles)
    assert set(dict(cfg.members)) == set(UNION_SPECS)
    for s in UNION_SPECS:
        v = cfg.member_values(s)
        assert v.n_elements == SINGLES[s].cfg.n_elements
        assert v.pages_per_element == SINGLES[s].cfg.pages_per_element
    # a plain config is its own single member
    single = SINGLES[BLOCK].cfg
    assert dict(single.members).keys() == {BLOCK}
    with pytest.raises(ValueError, match="not a member"):
        single.member_values(SUPERBLOCK)


def test_union_config_rejections():
    with pytest.raises(ValueError, match="at least one spec"):
        E.make_union_config(FLASH, ZGEOM, ())
    with pytest.raises(ValueError, match="duplicate"):
        E.make_union_config(FLASH, ZGEOM, (BLOCK, BLOCK))
    with pytest.raises(ValueError, match="FIXED"):
        E.make_union_config(FLASH, ZGEOM, (BLOCK, FIXED))


# --------------------------------------------------------------------- #
# the exactness oracle: mixed-spec batch == per-spec dispatches
# --------------------------------------------------------------------- #
#: one fuzz op row: n_pages ranges past the 64-page zone so overflow
#: writes (illegal) mix with legal fills; host=False exercises the
#: dummy-write accounting
_FUZZ_ROW = st.tuples(
    st.sampled_from([E.OP_WRITE, E.OP_FINISH, E.OP_RESET]),
    st.integers(0, 3),
    st.integers(1, 70),
    st.booleans(),
)

#: one lane: (spec index, halve the effective capacity?, wear-aware?)
_LANE = st.tuples(st.integers(0, len(UNION_SPECS) - 1), st.booleans(),
                  st.booleans())


@settings(max_examples=8, deadline=None)
@given(st.lists(_FUZZ_ROW, min_size=1, max_size=24),
       st.lists(_LANE, min_size=3, max_size=5))
def test_mixed_spec_batch_bit_identical_to_per_spec_dispatches(
        rows, lanes):
    """A SUPERBLOCK+BLOCK+VCHUNK fleet in ONE ``run_programs``
    dispatch, each lane under its member's DynConfig bundle (optionally
    composed with a capacity shrink and a first-fit allocator), leaves
    every lane bit-identical to an independent dispatch on an engine
    built with that spec outright."""
    prog = pad_rows([(op, z, n, E.F_HOST if host else 0)
                     for op, z, n, host in rows])
    dyns, refs = [], []
    for spec_i, shrink, wear in lanes:
        spec = UNION_SPECS[spec_i]
        kw = dict(wear_aware=wear)
        if shrink:
            kw["zone_pages"] = HALF
        dyns.append(UNION.dyn(spec=spec, **kw))
        single = SINGLES[spec]
        refs.append(single.run(single.init_state(), prog,
                               single.dyn(**kw)))
    states, trace = UNION.run_batch(UNION.init_state(), np.stack(
        [prog] * len(lanes)), E.stack_dyn(dyns))
    for k, (spec_i, shrink, wear) in enumerate(lanes):
        spec = UNION_SPECS[spec_i]
        assert_lane_matches_single(
            states, trace, k, spec, *refs[k],
            ctx=f"lane {k} {spec.name} shrink={shrink} wear={wear}")


def test_union_primary_lane_equals_plain_engine_default_dyn():
    """A dyn-less run of a union engine defaults to the *primary*
    member's spec bundle (never a cross-member mix of maxima), so it
    must equal the plain primary-spec engine exactly -- with or
    without an explicit ``dyn(spec=...)``."""
    rows = [(E.OP_WRITE, z, 9 + z, E.F_HOST) for z in range(3)]
    rows += [(E.OP_FINISH, z, 0, 0) for z in range(3)]
    prog = pad_rows(rows)
    single = SINGLES[SUPERBLOCK]
    ref = single.run(single.init_state(), prog)
    states, trace = UNION.run_batch(
        UNION.init_state(), np.stack([prog]),
        E.stack_dyn([UNION.dyn(spec=SUPERBLOCK)]))
    assert_lane_matches_single(states, trace, 0, SUPERBLOCK, *ref,
                               ctx="primary lane")
    # spec-aware wear extraction matches the plain engine's
    assert np.array_equal(
        UNION.elem_wear(E.DeviceState(*[leaf[0] for leaf in states]),
                        SUPERBLOCK),
        single.elem_wear(ref[0]))
    # the dyn-less path (run / run_batch without a DynConfig) is the
    # primary member too, not the padded grid pretending to be a spec
    s_plain, _ = UNION.run(UNION.init_state(), prog)
    for name in ("host_pages", "dummy_pages", "block_erases",
                 "n_active"):
        assert int(getattr(s_plain, name)) == int(getattr(ref[0], name)), \
            name
    ids = UNION.member_element_ids(SUPERBLOCK)
    assert np.array_equal(np.asarray(s_plain.elem_wear)[ids],
                          np.asarray(ref[0].elem_wear)[: len(ids)])


# --------------------------------------------------------------------- #
# the fleet layer over the union config
# --------------------------------------------------------------------- #
def test_mixed_spec_fleet_rows_match_homogeneous_engines():
    """Evaluator rows of mixed-spec configs through the union engine
    equal the rows the same configs produce on engines built with each
    spec outright -- including the wear statistics, which must ignore
    the union grid's padding elements."""
    from repro.fleet import FleetConfig, evaluate_configs

    configs = [FleetConfig("dlwa_pair", 4, 8, True, True, BLOCK),
               FleetConfig("dlwa_write", 2, 16, False, True, SUPERBLOCK),
               FleetConfig("dlwa_pair", 2, 8, True, False, vchunk(2))]
    union_rows = evaluate_configs(UNION, configs, n_devices=3)
    for fc, mine in zip(configs, union_rows):
        ref = evaluate_configs(SINGLES[fc.spec], [fc], n_devices=3)[0]
        assert mine == ref, fc.describe()


def test_mixed_spec_fleet_matches_legacy_array_replay():
    """The per-op ``ZNSArray`` oracle of ``test_fleet.py``, on a
    mixed-spec batch: members are built with each config's actual
    element spec."""
    from repro.fleet import (FleetConfig, N_TENANTS, build_fleet_batch,
                             run_configs_legacy, run_fleet, runner)

    configs = [FleetConfig("dlwa_pair", 4, 8, True, True, BLOCK),
               FleetConfig("dlwa_write", 2, 16, False, True, SUPERBLOCK),
               FleetConfig("dlwa_pair", 2, 8, True, False, vchunk(2))]
    programs, dyn, merged = build_fleet_batch(UNION, configs, n_devices=3)
    res = run_fleet(UNION, programs, dyn=dyn, n_tenants=N_TENANTS)
    runner.assert_all_ok(res)
    legacy = run_configs_legacy(FLASH, SUPERBLOCK, configs, merged,
                                parallelism=4, n_devices=3, max_active=6)
    for k, (fc, rep) in enumerate(zip(configs, legacy)):
        lanes = np.arange(3 * k, 3 * (k + 1))
        mine = runner.config_report(res, UNION, lanes)
        assert mine["parity_pages"] == rep["parity_pages"], fc
        assert mine["dummy_pages"] == rep["dummy_pages"], fc
        assert mine["dlwa"] == pytest.approx(rep["dlwa"]), fc
        assert mine["block_erases"] == rep["total_block_erases"], fc
        assert mine["wear_cv"] == pytest.approx(rep["wear_cv"]), fc


def test_build_fleet_batch_rejects_non_member_spec():
    from repro.fleet import FleetConfig, build_fleet_batch

    fc = FleetConfig("dlwa_pair", 4, 8, False, True, hchunk(2))
    with pytest.raises(ValueError, match="not a member"):
        build_fleet_batch(UNION, [fc], n_devices=3)


def test_search_space_spec_axis_codec():
    from repro.fleet import SearchSpace

    space = SearchSpace(segments=(4, 2), chunks=(8, 16),
                        specs=UNION_SPECS)
    assert len(space) == 2 * 2 * 2 * 2 * 2 * 3
    for fc in space.grid():
        assert space.decode(space.encode(fc)) == fc
    names = {fc.describe() for fc in space.grid()}
    assert len(names) == len(space)  # spec axis keeps names unique
