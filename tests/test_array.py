"""ZNS-RAID array: striping, parity, degraded reads, backend equality,
and the vmapped fleet-timing path."""

import numpy as np
import pytest

from repro.array import ArrayGeometry, ZNSArray
from repro.core import (FIXED, SUPERBLOCK, ZNSDevice, ZoneState, timing,
                        zn540)
from repro.core.backend import ZoneBackend, check_backend
from repro.storage import KVBenchConfig, LSMSimulator, ZoneFS


def build(n_devices, *, parity=False, chunk_pages=None, spec=SUPERBLOCK):
    flash, zone = zn540()
    return ZNSArray.build(flash, zone, spec, n_devices=n_devices,
                          chunk_pages=chunk_pages, parity=parity,
                          max_active=14)


# --------------------------------------------------------------------- #
# geometry / protocol
# --------------------------------------------------------------------- #
def test_backend_protocol():
    flash, zone = zn540()
    dev = ZNSDevice(flash, zone, SUPERBLOCK)
    arr = build(2)
    for obj in (dev, arr):
        check_backend(obj)
        assert isinstance(obj, ZoneBackend)
    with pytest.raises(TypeError, match="ZoneBackend"):
        check_backend(object())


def test_geometry_validation():
    with pytest.raises(ValueError, match="parity"):
        ArrayGeometry(n_devices=1, chunk_pages=64, parity=True)
    flash, zone = zn540()
    with pytest.raises(ValueError, match="divide"):
        ZNSArray.build(flash, zone, SUPERBLOCK, n_devices=2,
                       chunk_pages=7)


def test_capacity_scales_with_data_devices():
    assert build(4).zone_pages == 4 * build(1).zone_pages
    assert build(4, parity=True).zone_pages == 3 * build(1).zone_pages


def test_superzone_overflow_raises():
    arr = build(2)
    arr.zone_write(0, arr.zone_pages)
    assert arr.zones[0].state is ZoneState.FULL
    with pytest.raises(RuntimeError, match="FULL"):
        arr.zone_write(0, 1)
    arr2 = build(2)
    with pytest.raises(RuntimeError, match="overflow"):
        arr2.zone_write(0, arr2.zone_pages + 1)


def test_write_is_sequential_per_member():
    """Chunk striping must produce an append-only stream per member."""
    arr = build(4, parity=True)
    c = arr.geom.chunk_pages
    for step in (c // 3, c, 2 * c + 5, arr.zone_pages):  # ragged appends
        arr2 = build(4, parity=True)
        wp = 0
        while wp < arr2.zone_pages:
            n = min(step, arr2.zone_pages - wp)
            arr2.zone_write(0, n)  # raises inside the member if the
            wp += n                # per-device stream ever went backwards
        assert all(d.zones[0].wp == arr2.dev_zone_pages
                   for d in arr2.devices)


# --------------------------------------------------------------------- #
# parity accounting
# --------------------------------------------------------------------- #
def test_parity_emitted_per_completed_stripe():
    arr = build(4, parity=True)
    c, k = arr.geom.chunk_pages, arr.geom.n_data
    arr.zone_write(0, 2 * c * k + c)     # 2 full stripes + 1 chunk
    assert arr.parity_pages == 2 * c
    assert arr.zones[0].parity_emitted == 2


def test_parity_rotates_across_devices():
    arr = build(4, parity=True)
    arr.zone_write(0, arr.zone_pages)
    s = arr.stripes_per_zone
    owners = [arr._parity_device(0, i) for i in range(s)]
    counts = np.bincount(owners, minlength=4)
    assert counts.min() >= s // 4  # RAID-5 rotation: no parity hotspot
    # superzone offset shifts the rotation
    assert arr._parity_device(1, 0) != arr._parity_device(0, 0)


def test_parity_stripe_finish_padding_accounting():
    """FINISH of a partial stripe: one parity chunk is appended for the
    written prefix, member FINISH padding rolls up, and the array DLWA
    identity (host+parity+dummy)/host holds exactly."""
    arr = build(4, parity=True)
    c, k = arr.geom.chunk_pages, arr.geom.n_data
    n = c * k + c // 2                    # 1 full stripe + half a chunk
    arr.zone_write(0, n)
    assert arr.parity_pages == c          # only the full stripe so far
    arr.zone_finish(0)
    assert arr.parity_pages == 2 * c      # + partial-stripe parity chunk
    assert arr.zones[0].state is ZoneState.FULL
    # the half-written data chunk and the parity chunks were padded to
    # their element boundaries by the member FINISHes
    assert arr.dummy_pages == sum(d.dummy_pages for d in arr.devices)
    assert arr.dummy_pages > 0
    assert arr.dlwa == pytest.approx(
        (arr.host_pages + arr.parity_pages + arr.dummy_pages)
        / arr.host_pages)
    # host accounting is logical only: members saw data + parity pages
    member_host = sum(d.host_pages for d in arr.devices)
    assert member_host == arr.host_pages + arr.parity_pages


def test_finish_empty_superzone_is_noop():
    arr = build(2, parity=True)
    arr.zone_finish(0)
    assert arr.zones[0].state is ZoneState.FULL
    assert arr.parity_pages == 0 and arr.dummy_pages == 0


def test_reset_clears_members_and_state():
    arr = build(2, parity=True)
    arr.zone_write(0, arr.zone_pages // 2)
    arr.zone_finish(0)
    arr.zone_reset(0)
    assert arr.zones[0].state is ZoneState.EMPTY
    assert all(d.zones[0].state is ZoneState.EMPTY for d in arr.devices)
    arr.zone_write(0, 10)  # reusable after reset


# --------------------------------------------------------------------- #
# reads: normal + degraded
# --------------------------------------------------------------------- #
def test_read_routes_pages_to_chunk_owners():
    arr = build(4, parity=True)
    c = arr.geom.chunk_pages
    arr.zone_write(0, arr.zone_pages)
    reads = arr.zone_read(0, np.asarray([0, c, 2 * c]))  # stripe 0 slots
    by_dev = dict((i, len(t.luns)) for i, t in reads)
    # stripe 0 of superzone 0: parity on device 0, data on 1..3
    assert by_dev == {1: 1, 2: 1, 3: 1}
    assert all(t.op == "read" for _, t in reads)


def test_degraded_read_with_one_device_failed():
    arr = build(4, parity=True)
    c = arr.geom.chunk_pages
    arr.zone_write(0, arr.zone_pages)
    arr.fail_device(1)                    # holds data slot 0 of stripe 0
    lost = np.arange(10)                  # logical pages on device 1
    reads = arr.zone_read(0, lost)
    by_dev = dict((i, len(t.luns)) for i, t in reads)
    # reconstruction reads the same chunk row from every survivor
    assert by_dev == {0: 10, 2: 10, 3: 10}
    # device page offsets match the lost pages' stripe rows
    for _, tr in reads:
        assert len(tr.luns) == 10
    # a second failure is not survivable with single parity
    with pytest.raises(RuntimeError, match="second device failure"):
        arr.fail_device(2)
    arr.heal_device(1)
    by_dev = dict((i, len(t.luns)) for i, t in arr.zone_read(0, lost))
    assert by_dev == {1: 10}


def test_degraded_read_of_unparitied_stripe_raises():
    """A chunk lost from a still-open stripe is unrecoverable until its
    log-structured parity has been appended (stripe completion/FINISH)."""
    arr = build(4, parity=True)
    c = arr.geom.chunk_pages
    arr.zone_write(0, c)                  # one chunk: stripe 0 incomplete
    arr.fail_device(1)                    # ... and it lived on device 1
    with pytest.raises(RuntimeError, match="parity not yet written"):
        arr.zone_read(0, np.asarray([0]))
    arr.heal_device(1)
    arr.zone_finish(0)                    # FINISH appends stripe-0 parity
    arr.fail_device(1)
    by_dev = dict((i, len(t.luns))
                  for i, t in arr.zone_read(0, np.asarray([0])))
    # reconstruct from the stripe's parity chunk (device 0) alone: the
    # other data chunks were never written and contribute zeros
    assert by_dev == {0: 1}


def test_non_host_writes_count_as_member_dummy():
    """ZoneBackend host=False semantics: pages reach the members as
    padding traffic and stay out of the host counter."""
    arr = build(2, parity=False)
    arr.zone_write(0, 10, host=False)
    assert arr.host_pages == 0
    assert arr.dummy_pages == 10
    arr.zone_write(0, 30)
    assert arr.dlwa == pytest.approx((30 + 10) / 30)


def test_failed_read_without_parity_raises():
    arr = build(2, parity=False)
    arr.zone_write(0, arr.zone_pages)
    arr.fail_device(0)
    with pytest.raises(RuntimeError, match="lost"):
        arr.zone_read(0, np.asarray([0]))


# --------------------------------------------------------------------- #
# backend equality: 1-device array == bare device
# --------------------------------------------------------------------- #
def _zonefs_traffic(fs: ZoneFS) -> None:
    """Deterministic create/delete mix exercising FINISH + RESET."""
    pages = max(1, fs.dev.zone_pages // 3)
    live = []
    for fid in range(18):
        assert fs.create(fid, pages, lifetime=fid % 3)
        live.append(fid)
        if len(live) > 5:
            fs.delete(live.pop(0))


def test_zonefs_report_equal_one_device_array_vs_bare_device():
    flash, zone = zn540()
    fs_dev = ZoneFS(ZNSDevice(flash, zone, SUPERBLOCK, max_active=14),
                    finish_threshold=0.3)
    fs_arr = ZoneFS(build(1), finish_threshold=0.3)
    _zonefs_traffic(fs_dev)
    _zonefs_traffic(fs_arr)
    assert fs_arr.report() == fs_dev.report()


@pytest.mark.parametrize("spec", [FIXED, SUPERBLOCK],
                         ids=lambda s: s.name)
def test_zonefs_report_equal_under_lsm(spec):
    """Acceptance: ZoneFS + LSM run unmodified over device and array."""
    flash, zone = zn540()
    reports = []
    for backend in (ZNSDevice(flash, zone, spec, max_active=14),
                    ZNSArray.build(flash, zone, spec, n_devices=1,
                                   max_active=14)):
        fs = ZoneFS(backend, finish_threshold=0.1)
        sim = LSMSimulator(fs, KVBenchConfig(n_ops=200_000))
        reports.append(sim.run())
    assert reports[0] == reports[1]


def test_lsm_runs_on_parity_array():
    arr = build(4, parity=True)
    fs = ZoneFS(arr, finish_threshold=0.1)
    rep = LSMSimulator(fs, KVBenchConfig(n_ops=200_000)).run()
    assert rep["failed"] == 0.0
    assert rep["host_pages"] == arr.host_pages


# --------------------------------------------------------------------- #
# fleet timing
# --------------------------------------------------------------------- #
def test_vmapped_fleet_matches_independent_simulate():
    """Acceptance: the vmapped 8-device path reproduces 8 independent
    ``simulate`` calls' per-device makespans."""
    arr = build(8, parity=True)
    tagged = arr.zone_write(0, 3 * arr.geom.chunk_pages * arr.geom.n_data
                            + 17, trace=True)
    tagged += arr.zone_finish(0, trace=True) or []
    per_dev = timing.group_tagged(tagged, 8)
    assert sum(len(t) for t in per_dev) == len(tagged)
    fleet = timing.run_fleet_trace(arr.flash, per_dev)
    for i, traces in enumerate(per_dev):
        ref = timing.run_trace(arr.flash, traces)
        assert fleet[f"dev{i}_makespan_s"] == pytest.approx(
            ref["makespan_s"], rel=1e-6, abs=1e-9)
    assert fleet["fleet_makespan_s"] == pytest.approx(
        max(fleet[f"dev{i}_makespan_s"] for i in range(8)))


def test_fleet_trace_handles_idle_devices():
    arr = build(4, parity=False)
    tagged = arr.zone_write(0, arr.geom.chunk_pages, trace=True)  # dev 0 only
    fleet = timing.run_fleet_trace(arr.flash, timing.group_tagged(tagged, 4))
    assert fleet["dev0_makespan_s"] > 0
    assert fleet["dev1_makespan_s"] == 0.0
    assert fleet["fleet_makespan_s"] == fleet["dev0_makespan_s"]


def test_parity_traffic_lengthens_parity_member_makespan():
    """Cross-device merge: with parity on, the stripe's parity member
    programs a full extra chunk."""
    flash, zone = zn540()
    plain = ZNSArray.build(flash, zone, SUPERBLOCK, n_devices=4,
                           parity=False)
    par = ZNSArray.build(flash, zone, SUPERBLOCK, n_devices=4, parity=True)
    n = 3 * par.geom.chunk_pages           # one full parity stripe
    t_plain = timing.run_fleet_trace(
        flash, timing.group_tagged(plain.zone_write(0, n, trace=True), 4))
    t_par = timing.run_fleet_trace(
        flash, timing.group_tagged(par.zone_write(0, n, trace=True), 4))
    assert t_par["fleet_makespan_s"] >= t_plain["fleet_makespan_s"]
    assert t_par["n"] == t_plain["n"] + par.geom.chunk_pages


# --------------------------------------------------------------------- #
# rebuild after failure
# --------------------------------------------------------------------- #
def test_rebuild_restores_member_and_reads():
    arr = build(4, parity=True)
    fill = max(1, int(arr.zone_pages * 0.6))
    for z in range(2):
        arr.zone_write(z, fill)
        arr.zone_finish(z)
    member_wp = [arr.devices[2].zones[z].wp for z in range(2)]
    arr.fail_device(2)
    tagged = arr.rebuild_device(2)
    assert arr.failed == set()
    # replacement holds exactly the chunk rows the old member held
    for z in range(2):
        assert arr.devices[2].zones[z].wp == member_wp[z]
        assert arr.devices[2].zones[z].state is ZoneState.FULL
    # rebuild writes on the replacement == its re-appended pages
    wrote = sum(len(t.luns) for i, t in tagged
                if i == 2 and t.op == "write")
    assert wrote >= sum(member_wp)  # chunks + replacement FINISH padding
    # every survivor contributed degraded reads
    readers = {i for i, t in tagged if t.op == "read"}
    assert readers == {0, 1, 3}
    # post-rebuild, reads of the failed member's pages are served
    # normally again (no degraded fan-out)
    out = arr.zone_read(0, np.arange(8))
    assert all(t.op == "read" for _, t in out)


def test_rebuild_requires_parity_and_quorum():
    arr = build(2, parity=False)
    arr.zone_write(0, 16)
    with pytest.raises(RuntimeError, match="parity"):
        arr.rebuild_device(0)


def test_rebuild_traces_feed_fleet_timing():
    arr = build(3, parity=True)
    arr.zone_write(0, max(1, arr.zone_pages // 2))
    arr.zone_finish(0)
    arr.fail_device(1)
    tagged = arr.rebuild_device(1)
    fleet = timing.run_fleet_trace(arr.flash, timing.group_tagged(tagged, 3))
    assert fleet["fleet_makespan_s"] > 0
    assert fleet["n"] == sum(len(t.luns) for _, t in tagged)
