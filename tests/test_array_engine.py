"""Engine-native ZNS-RAID vs the object ``ZNSArray`` oracle.

Every test drives both surfaces through one logical command list
(:func:`repro.array.apply_commands`) and demands *bit-exact* equality
of ``report()`` / ``device_reports()`` -- the same oracle relationship
``LegacyZNSDevice`` has to ``ZoneEngine``, one layer up.  Covers the
chunk x parity x member-count x spec-mix grid (fuzzed), degraded reads
past a failed member, rebuild round-trips, the batched rebuild storm,
and the ``devices`` search axis.
"""

import random

import numpy as np
import pytest

from repro.array import (ArrayEngine, ArrayGeometry, StormScenario,
                         ZNSArray, apply_commands,
                         array_vs_legacy_speedup, fill_commands,
                         rebuild_storm, run_array_batch)
from repro.array.engine import _legacy_array
from repro.core import engine as E
from repro.core import timing
from repro.core.elements import BLOCK, SUPERBLOCK, vchunk
from repro.core.geometry import FlashGeometry, ZoneGeometry


def tiny_flash():
    return FlashGeometry(n_channels=4, ways_per_channel=1,
                         blocks_per_lun=16, pages_per_block=4,
                         page_bytes=4096)


def tiny_geoms():
    return tiny_flash(), ZoneGeometry(4, n_segments=4)


def build_pair(n_devices, *, chunk_pages=None, parity=False,
               specs=SUPERBLOCK, max_active=6, wear_aware=None):
    """(ArrayEngine, oracle ZNSArray) over the same tiny geometry."""
    flash, zone = tiny_geoms()
    eng_arr = ArrayEngine.build(flash, zone, specs, n_devices=n_devices,
                                chunk_pages=chunk_pages, parity=parity,
                                max_active=max_active,
                                wear_aware=wear_aware)
    legacy = _legacy_array(flash, zone, eng_arr.geom,
                           eng_arr.member_specs, max_active=max_active,
                           oracle=True)
    if wear_aware is not None:
        for d in legacy.devices:
            d.wear_aware = wear_aware
    return eng_arr, legacy


def assert_bit_identical(eng_arr: ArrayEngine, legacy: ZNSArray):
    er, lr = eng_arr.report(), legacy.report()
    assert er.keys() == lr.keys()
    for k in er:
        assert er[k] == lr[k], k
    for ed, ld in zip(eng_arr.device_reports(), legacy.device_reports()):
        assert ed.keys() == ld.keys()
        for k in ed:
            assert ed[k] == ld[k], k


# --------------------------------------------------------------------- #
# fuzzed differential: chunk x parity x members x spec mix
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("n_devices,chunk,parity", [
    (2, None, False), (2, 8, False),
    (3, None, True), (3, 4, True),
    (4, 16, True), (4, 8, False),
])
def test_fuzzed_differential(n_devices, chunk, parity):
    eng_arr, legacy = build_pair(n_devices, chunk_pages=chunk,
                                 parity=parity)
    rng = random.Random(1000 * n_devices + (chunk or 0) + int(parity))
    zp = eng_arr.zone_pages
    wp = {z: 0 for z in range(3)}
    cmds = []
    for _ in range(60):
        z = rng.randrange(3)
        verb = rng.choice(["write", "write", "write", "finish",
                           "reset", "read"])
        if verb == "write" and wp[z] is not None:
            n = rng.randrange(1, max(2, zp - wp[z] + 1))
            n = min(n, zp - wp[z])
            if n <= 0:
                continue
            cmds.append(("write", z, n, rng.random() < 0.9))
            wp[z] += n
            if wp[z] == zp:
                wp[z] = None        # FULL
        elif verb == "finish":
            cmds.append(("finish", z))
            wp[z] = None
        elif verb == "reset":
            cmds.append(("reset", z))
            wp[z] = 0
        elif verb == "read" and wp[z] and wp[z] > 0:
            offs = sorted(rng.sample(range(wp[z]),
                                     min(4, wp[z])))
            cmds.append(("read", z, offs))
    apply_commands(eng_arr, cmds)
    apply_commands(legacy, cmds)
    assert_bit_identical(eng_arr, legacy)


@pytest.mark.parametrize("specs", [
    (SUPERBLOCK, BLOCK, SUPERBLOCK),
    (BLOCK, vchunk(2), SUPERBLOCK),
])
def test_mixed_member_specs_differential(specs):
    """Heterogeneous member specs run per-lane through one union
    engine and still match the oracle exactly."""
    eng_arr, legacy = build_pair(3, parity=True, specs=specs)
    assert eng_arr.member_specs == tuple(specs)
    cmds = fill_commands(eng_arr.zone_pages, n_zones=2, occupancy=0.7,
                         churn=2)
    apply_commands(eng_arr, cmds)
    apply_commands(legacy, cmds)
    assert_bit_identical(eng_arr, legacy)


def test_error_message_equality():
    """The engine front-end raises the oracle's exact strings."""
    cases = [
        [("write", 0, 10_000, True)],                      # overflow
        [("finish", 0), ("write", 0, 1, True)],            # FULL write
        [("read", 1, [0])],                                # unmapped
    ]
    for cmds in cases:
        eng_arr, legacy = build_pair(2, parity=False)
        with pytest.raises(RuntimeError) as ee:
            apply_commands(eng_arr, cmds)
        with pytest.raises(RuntimeError) as le:
            apply_commands(legacy, cmds)
        assert str(ee.value) == str(le.value)

    # parity-off data loss on a failed member (40 pages span every
    # member at the default one-segment chunk, so the read must cross
    # the failed one)
    eng_arr, legacy = build_pair(3, parity=False)
    prefix = [("write", 0, 40, True), ("fail", 1)]
    apply_commands(eng_arr, prefix)
    apply_commands(legacy, prefix)
    with pytest.raises(RuntimeError) as ee:
        eng_arr.zone_read(0, np.arange(40))
    with pytest.raises(RuntimeError) as le:
        legacy.zone_read(0, np.arange(40))
    assert str(ee.value) == str(le.value)
    assert "parity is off" in str(ee.value)


# --------------------------------------------------------------------- #
# degraded reads + rebuild round-trips
# --------------------------------------------------------------------- #
def test_degraded_read_routes_around_failed_member():
    eng_arr, legacy = build_pair(3, parity=True)
    cmds = [("write", 0, 40, True), ("fail", 2),
            ("read", 0, list(range(40)))]
    apply_commands(eng_arr, cmds)
    apply_commands(legacy, cmds)
    # the engine plan never touches the failed member, and every
    # surviving offset lands inside that member's written extent
    plan = eng_arr.zone_read(0, np.arange(40))
    assert 2 not in plan
    for member, offs in plan.items():
        assert max(offs) < eng_arr.member_wp(0, member)
    assert_bit_identical(eng_arr, legacy)


@pytest.mark.parametrize("n_devices,chunk", [(3, None), (4, 8)])
def test_rebuild_round_trip(n_devices, chunk):
    eng_arr, legacy = build_pair(n_devices, chunk_pages=chunk,
                                 parity=True)
    zp = eng_arr.zone_pages
    written = max(1, int(zp * 0.8))   # reads stay in the host extent
    cmds = (fill_commands(zp, n_zones=2, occupancy=0.8)
            + [("write", 2, zp // 3, True),       # partial zone too
               ("fail", 0),
               ("read", 0, list(range(0, written, 7))),
               ("rebuild", 0),
               ("write", 2, zp // 4, True),       # post-rebuild traffic
               ("read", 2, list(range(zp // 4)))])
    apply_commands(eng_arr, cmds)
    apply_commands(legacy, cmds)
    assert not eng_arr.failed and not legacy.failed
    assert_bit_identical(eng_arr, legacy)


def test_rebuild_requires_parity_and_single_failure():
    eng_arr, _ = build_pair(3, parity=False)
    eng_arr.fail_device(0)
    with pytest.raises(RuntimeError, match="requires parity"):
        eng_arr.rebuild_device(0)
    eng_arr, _ = build_pair(3, parity=True)
    eng_arr.fail_device(0)
    with pytest.raises(RuntimeError, match="second device failure"):
        eng_arr.fail_device(1)


# --------------------------------------------------------------------- #
# batched dispatch + timing
# --------------------------------------------------------------------- #
def test_batched_arrays_match_sequential_runs():
    """K arrays in ONE dispatch report exactly what each reports when
    run alone."""
    flash, zone = tiny_geoms()
    shared = E.ZoneEngine(flash, zone, SUPERBLOCK, max_active=6)

    def make(i):
        a = ArrayEngine(shared, ArrayGeometry(2 + i % 2, 8, bool(i % 2)))
        apply_commands(a, fill_commands(
            a.zone_pages, n_zones=2, occupancy=0.4 + 0.1 * i))
        return a

    batch = [make(i) for i in range(4)]
    solo = [make(i) for i in range(4)]
    run_array_batch(batch, pad_quantum=16)
    for b, s in zip(batch, solo):
        assert b.report() == s.report()
        assert b.device_reports() == s.device_reports()


def test_fleet_timing_per_op_read_write_rates():
    """Array timing books reads at the read+xfer rate and writes at the
    program+xfer rate -- the per-op t_page path through
    simulate_fleet_ops."""
    eng_arr, _ = build_pair(2, parity=False)
    apply_commands(eng_arr, [("write", 0, 16, True),
                             ("read", 0, list(range(16)))])
    t = eng_arr.fleet_timing()
    assert t["fleet_pages"] > 0
    assert t["fleet_makespan_s"] > 0
    flash = tiny_flash()
    # scalar t_page still broadcasts (bit-compat with pre-array callers)
    cols = np.zeros((1, 2), np.int32)
    pages = np.array([[4, 4]], np.int32)
    ten = np.zeros((1, 2), np.int32)
    ops = np.zeros((1, 2), np.int32)
    _, _, scalar = timing.simulate_fleet_ops(
        cols, pages, ten, np.float32(1e-3), flash.n_luns, 1)
    _, _, perop = timing.simulate_fleet_ops(
        cols, pages, ten, np.full((1, 2), 1e-3, np.float32),
        flash.n_luns, 1)
    assert np.array_equal(np.asarray(scalar), np.asarray(perop))
    del ops


def test_speedup_comparator_smoke():
    """The BENCH array pipeline end to end on the tiny geometry --
    exactness is asserted inside over every array."""
    flash, zone = tiny_geoms()
    rep = array_vs_legacy_speedup(
        n_arrays=2, repeats=1, flash=flash, zone_geom=zone,
        max_active=6, n_zones=2, legacy_arrays=1)
    for key in ("n_arrays", "lane_ops", "engine_s", "legacy_s",
                "legacy_measured_s", "legacy_timed_arrays",
                "legacy_scale", "speedup"):
        assert key in rep, key
    assert rep["legacy_scale"] == 2.0


# --------------------------------------------------------------------- #
# rebuild storm
# --------------------------------------------------------------------- #
def test_rebuild_storm_batched_and_recompile_stable():
    from repro.obs import ObsConfig
    from repro.obs.profile import RecompileCounter

    flash, zone = tiny_geoms()
    eng = E.ZoneEngine(flash, zone, SUPERBLOCK, max_active=6)
    scenarios = [StormScenario(n_devices=3, n_zones_filled=1,
                               occupancy=0.5),
                 StormScenario(n_devices=4, n_zones_filled=1,
                               occupancy=0.6, chunk_pages=8)]
    obs = ObsConfig(n_buckets=8, n_tenants=3)
    counter = RecompileCounter(run_programs=E.run_programs,
                               simulate_fleet_ops=timing.simulate_fleet_ops)
    out = rebuild_storm(eng, scenarios, obs=obs, pad_quantum=16)
    assert len(out["scenarios"]) == 2
    assert len(out["telemetry"]) == 2
    for rep in out["scenarios"]:
        assert rep["rebuild_pages"] > 0
        assert rep["rebuild_read_pages"] > 0
        assert rep["rebuild_traffic_pages"] >= rep["rebuild_pages"]
        assert rep["host_makespan_s"] > 0
        # contention can only slow the host stream down
        assert rep["rebuild_interference"] >= 1.0
    before = counter.counts()
    again = rebuild_storm(eng, scenarios, obs=obs, pad_quantum=16)
    assert sum(counter.delta(before).values()) == 0
    assert again["scenarios"] == out["scenarios"]


def test_rebuild_storm_empty():
    flash, zone = tiny_geoms()
    eng = E.ZoneEngine(flash, zone, SUPERBLOCK, max_active=6)
    assert rebuild_storm(eng, []) == {"scenarios": [],
                                      "telemetry": None}


# --------------------------------------------------------------------- #
# the devices search axis
# --------------------------------------------------------------------- #
def test_search_space_devices_axis_codec():
    from repro.fleet import FleetConfig, SearchSpace, grid_space

    space = SearchSpace(mixes=("dlwa_pair",), segments=(4,), chunks=(8,),
                        specs=(SUPERBLOCK,), devices=(3, 4))
    assert len(space.axes) == 7
    for fc in space.grid():
        assert space.decode(space.encode(fc)) == fc
        assert fc.describe().endswith(f"_d{fc.n_devices}")
    # a default space keeps 6-gene vectors (seeded trajectories intact)
    assert len(SearchSpace().axes) == 6
    with pytest.raises(ValueError, match="no devices axis"):
        SearchSpace().encode(FleetConfig("dlwa_pair", 4, 8, True, True,
                                         n_devices=3))
    assert len(grid_space(mixes=("dlwa_pair",), segments=(4,),
                          chunks=(8,), parities=(False,), wear=(True,),
                          devices=(2, 3))) == 2


def test_evaluator_mixed_member_counts_match_legacy():
    """Configs with different n_devices in ONE padded dispatch score
    exactly like the per-config legacy array replay."""
    from repro.fleet import (FleetConfig, N_TENANTS, build_fleet_batch,
                             run_configs_legacy, run_fleet)
    from repro.fleet import runner

    flash, zone = tiny_geoms()
    eng = E.ZoneEngine(flash, zone, (SUPERBLOCK, BLOCK), max_active=6)
    configs = [FleetConfig("dlwa_pair", 4, 8, True, True, n_devices=3),
               FleetConfig("dlwa_write", 2, 16, False, True,
                           n_devices=4),
               FleetConfig("dlwa_pair", 2, 8, True, False,
                           spec=(SUPERBLOCK, BLOCK), n_devices=3)]
    programs, dyn, merged = build_fleet_batch(eng, configs, n_devices=4)
    res = run_fleet(eng, programs, dyn=dyn, n_tenants=N_TENANTS)
    runner.assert_all_ok(res)
    legacy = run_configs_legacy(flash, SUPERBLOCK, configs, merged,
                                parallelism=4, n_devices=4,
                                max_active=6)
    nd_max = 4
    for k, (fc, rep) in enumerate(zip(configs, legacy)):
        lanes = np.arange(k * nd_max, k * nd_max + fc.n_devices)
        mine = runner.config_report(res, eng, lanes)
        assert mine["parity_pages"] == rep["parity_pages"], fc
        assert mine["dummy_pages"] == rep["dummy_pages"], fc
        assert mine["dlwa"] == pytest.approx(rep["dlwa"], abs=1e-9), fc
        assert mine["block_erases"] == rep["total_block_erases"], fc
