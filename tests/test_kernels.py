"""Per-kernel shape/dtype sweeps: Pallas (interpret) vs pure-jnp oracle."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core import engine as E
from repro.core.elements import BLOCK, SUPERBLOCK, hchunk, vchunk
from repro.core.engine import ZoneEngine
from repro.core.geometry import FlashGeometry, ZoneGeometry
from repro.kernels.zns_alloc.ops import zns_alloc
from repro.kernels.zns_alloc.ref import zns_alloc_ref
from repro.kernels.flash_attention.ops import attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.ssm_scan.ops import ssm_scan, single_step
from repro.kernels.ssm_scan.ref import ssm_scan_ref


def rel_err(a, b):
    a = np.asarray(a, np.float32)
    b = np.asarray(b, np.float32)
    return float(np.max(np.abs(a - b)) / (np.max(np.abs(b)) + 1e-9))


def tol(dtype):
    return 2.5e-2 if dtype == jnp.bfloat16 else 5e-5


# --------------------------------------------------------------------- #
# zns_alloc
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("g,w,take", [(2, 8, 1), (4, 64, 4), (8, 128, 3),
                                      (16, 256, 8), (3, 33, 5)])
def test_zns_alloc_matches_ref(g, w, take):
    rng = np.random.default_rng(g * 1000 + w + take)
    wear = jnp.asarray(rng.integers(0, 99, (g, w)), jnp.int32)
    avail = jnp.asarray(rng.choice([0, 1, 2, 3], (g, w)), jnp.int32)
    elig = jnp.asarray(rng.random(g) < 0.8)
    s_pal, f_pal = zns_alloc(wear, avail, elig, take=take, impl="pallas")
    s_ref, ok = zns_alloc_ref(wear, avail, elig, take=take)
    assert (np.asarray(s_pal) == np.asarray(s_ref, bool)).all()


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_zns_alloc_matches_exact_dp(seed):
    """Kernel vs the ILP dynamic program on balanced instances."""
    from repro.core import alloc_exact
    rng = np.random.default_rng(seed)
    g, w, take = 4, 16, 3
    wear = rng.integers(0, 50, (g, w)).astype(np.int32)
    avail = rng.choice([0, 1, 2, 3], (g, w)).astype(np.int32)
    elig_idx = list(range(g))
    sel, feas = zns_alloc(jnp.asarray(wear), jnp.asarray(avail),
                          jnp.ones(g, bool), take=take, impl="pallas")
    dp = alloc_exact.solve(wear.reshape(-1), avail.reshape(-1),
                           np.repeat(np.arange(g), w), z=take * g,
                           k_max=take, l_min=g, eligible_groups=elig_idx)
    assert bool(feas) == dp.feasible
    if dp.feasible:
        assert float(wear[np.asarray(sel)].sum()) == pytest.approx(dp.cost)


_ALLOC_SPECS = [BLOCK, vchunk(2), hchunk(2), SUPERBLOCK]


@pytest.mark.parametrize("seed", range(5))
@pytest.mark.parametrize("spec", _ALLOC_SPECS, ids=lambda s: s.name)
def test_zns_alloc_matches_engine_claim(spec, seed):
    """The kernel's per-group lowest-(wear, col) selection is exactly
    the element set a wear-aware traditional ALLOC claims for a fresh
    zone, and its feasibility flag is exactly the op's ok verdict."""
    eng = ZoneEngine(FlashGeometry(4, 1, 8, 4, 4096), ZoneGeometry(4, 2),
                     spec, max_active=3, wear_aware=True)
    cfg = eng.cfg
    # with the round-robin window spanning every group, the rr pass and
    # its cheapest-groups fallback see the same eligibility, so kernel
    # feasibility on the all-groups mask is exactly the engine's; a
    # full-capacity zone also claims all `take` ranks per group
    assert cfg.zone_groups == cfg.n_groups
    assert cfg.n_slots == cfg.take * cfg.zone_groups

    rng = np.random.default_rng(1234 * (seed + 1) + cfg.n_elements)
    n = cfg.n_elements
    wear = np.zeros(n + 1, np.int32)
    wear[:n] = rng.integers(0, 50, n)
    avail = np.zeros(n + 1, np.int32)
    avail[:n] = rng.choice([0, 1, 2, 3], n)
    state = eng.init_state()._replace(
        elem_wear=jnp.asarray(wear), elem_avail=jnp.asarray(avail))

    prog = np.asarray([[E.OP_ALLOC, 0, 0, 0]], np.int32)
    after, trace = eng.run(state, prog)

    wear2d = wear[:n].reshape(cfg.n_groups, cfg.per_group)
    avail2d = avail[:n].reshape(cfg.n_groups, cfg.per_group)
    sel, feas = zns_alloc(jnp.asarray(wear2d), jnp.asarray(avail2d),
                          jnp.ones(cfg.n_groups, bool), take=cfg.take,
                          impl="pallas")
    assert bool(trace.ok[0]) == bool(feas)
    if bool(feas):
        g, c = np.nonzero(np.asarray(sel, bool))
        kernel_ids = set((g * cfg.per_group + c).tolist())
        row = np.asarray(after.zone_elems)[0]
        engine_ids = {int(e) for e in row if e >= 0}
        assert engine_ids == kernel_ids


# --------------------------------------------------------------------- #
# flash attention
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16],
                         ids=["f32", "bf16"])
@pytest.mark.parametrize("b,hq,hkv,s,d,causal", [
    (2, 4, 2, 64, 32, True),
    (1, 8, 8, 128, 64, True),    # MHA
    (2, 8, 1, 96, 16, True),     # MQA
    (1, 4, 2, 64, 128, False),   # bidirectional
])
def test_flash_attention_sweep(b, hq, hkv, s, d, causal, dtype):
    rng = np.random.default_rng(b + hq + s + d)
    q = jnp.asarray(rng.standard_normal((b, hq, s, d)), dtype)
    k = jnp.asarray(rng.standard_normal((b, hkv, s, d)), dtype)
    v = jnp.asarray(rng.standard_normal((b, hkv, s, d)), dtype)
    ref = attention_ref(q, k, v, causal=causal)
    out = attention(q, k, v, causal=causal, impl="pallas",
                    block_q=32, block_k=32)
    assert rel_err(out, ref) < tol(dtype)
    out2 = attention(q, k, v, causal=causal, impl="chunked", block_k=32)
    assert rel_err(out2, ref) < tol(dtype)


def test_flash_attention_block_shape_invariance():
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((1, 2, 128, 32)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 2, 128, 32)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 2, 128, 32)), jnp.float32)
    outs = [attention(q, k, v, impl="pallas", block_q=bq, block_k=bk)
            for bq, bk in ((128, 128), (64, 32), (32, 64), (16, 16))]
    for o in outs[1:]:
        assert rel_err(o, outs[0]) < 1e-5


# --------------------------------------------------------------------- #
# decode attention
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16],
                         ids=["f32", "bf16"])
@pytest.mark.parametrize("b,hq,hkv,s,d", [
    (2, 8, 2, 256, 32),
    (1, 4, 4, 128, 64),
    (3, 8, 1, 64, 16),
    (1, 16, 2, 512, 128),
])
def test_decode_attention_sweep(b, hq, hkv, s, d, dtype):
    rng = np.random.default_rng(b * 31 + s)
    q = jnp.asarray(rng.standard_normal((b, hq, d)), dtype)
    k = jnp.asarray(rng.standard_normal((b, s, hkv, d)), dtype)
    v = jnp.asarray(rng.standard_normal((b, s, hkv, d)), dtype)
    lengths = jnp.asarray(rng.integers(1, s + 1, b), jnp.int32)
    ref = decode_attention_ref(q, k, v, lengths)
    out = decode_attention(q, k, v, lengths, impl="pallas", block_s=64)
    assert rel_err(out, ref) < tol(dtype)
    out2 = decode_attention(q, k, v, lengths, impl="chunked")
    assert rel_err(out2, ref) < tol(dtype)


def test_decode_attention_respects_length():
    """Tokens beyond `length` must not influence the output."""
    rng = np.random.default_rng(5)
    b, hq, hkv, s, d = 1, 4, 2, 128, 32
    q = jnp.asarray(rng.standard_normal((b, hq, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
    lengths = jnp.asarray([40], jnp.int32)
    out1 = decode_attention(q, k, v, lengths, impl="pallas", block_s=32)
    k2 = k.at[:, 40:].set(999.0)
    v2 = v.at[:, 40:].set(-999.0)
    out2 = decode_attention(q, k2, v2, lengths, impl="pallas", block_s=32)
    assert rel_err(out1, out2) < 1e-6


# --------------------------------------------------------------------- #
# ssm scan
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16],
                         ids=["f32", "bf16"])
@pytest.mark.parametrize("bh,t,p,n,chunk", [
    (2, 64, 16, 8, 16),
    (1, 128, 32, 16, 64),
    (4, 32, 8, 4, 32),
])
def test_ssm_scan_sweep(bh, t, p, n, chunk, dtype):
    rng = np.random.default_rng(bh + t + p)
    x = jnp.asarray(rng.standard_normal((bh, t, p)) * 0.5, dtype)
    dt = jnp.asarray(rng.random((bh, t, p)) * 0.1 + 0.01, dtype)
    b = jnp.asarray(rng.standard_normal((bh, t, n)) * 0.5, dtype)
    c = jnp.asarray(rng.standard_normal((bh, t, n)) * 0.5, dtype)
    a = jnp.asarray(-np.abs(rng.standard_normal((p, n))) - 0.1, jnp.float32)
    d = jnp.asarray(rng.standard_normal(p) * 0.1, jnp.float32)
    ref = ssm_scan_ref(x, dt, b, c, a, d)
    out = ssm_scan(x, dt, b, c, a, d, impl="pallas", chunk=chunk)
    assert rel_err(out, ref) < tol(dtype)


def test_ssm_single_step_consistent_with_scan():
    rng = np.random.default_rng(9)
    bh, t, p, n = 2, 16, 8, 4
    x = jnp.asarray(rng.standard_normal((bh, t, p)) * 0.5, jnp.float32)
    dt = jnp.asarray(rng.random((bh, t, p)) * 0.1 + 0.01, jnp.float32)
    b = jnp.asarray(rng.standard_normal((bh, t, n)) * 0.5, jnp.float32)
    c = jnp.asarray(rng.standard_normal((bh, t, n)) * 0.5, jnp.float32)
    a = jnp.asarray(-np.abs(rng.standard_normal((p, n))) - 0.1, jnp.float32)
    d = jnp.asarray(rng.standard_normal(p) * 0.1, jnp.float32)
    ref = ssm_scan_ref(x, dt, b, c, a, d)
    h = jnp.zeros((bh, p, n), jnp.float32)
    for i in range(t):
        h, y = single_step(h, x[:, i], dt[:, i], b[:, i], c[:, i], a, d)
        assert rel_err(y, ref[:, i]) < 1e-5


def test_ssm_scan_chunk_invariance():
    rng = np.random.default_rng(11)
    bh, t, p, n = 1, 64, 8, 4
    x = jnp.asarray(rng.standard_normal((bh, t, p)) * 0.5, jnp.float32)
    dt = jnp.asarray(rng.random((bh, t, p)) * 0.1 + 0.01, jnp.float32)
    b = jnp.asarray(rng.standard_normal((bh, t, n)) * 0.5, jnp.float32)
    c = jnp.asarray(rng.standard_normal((bh, t, n)) * 0.5, jnp.float32)
    a = jnp.asarray(-np.abs(rng.standard_normal((p, n))) - 0.1, jnp.float32)
    d = jnp.asarray(rng.standard_normal(p) * 0.1, jnp.float32)
    outs = [ssm_scan(x, dt, b, c, a, d, impl="pallas", chunk=ch)
            for ch in (8, 16, 32, 64)]
    for o in outs[1:]:
        assert rel_err(o, outs[0]) < 1e-6
