"""Render a flight-recorder artifact as a markdown report.

Input is the ``<prefix>_obs.json`` sidecar ``repro.obs.export.
emit_fleet_obs`` writes (``benchmarks/fleet_search.py --obs``).  The
report shows the *temporal* shape of the run the end-of-run scalars
hide: DLWA vs program progress, wear-frontier spread vs progress,
per-tenant-class p99 latency, and the dispatch profile / recompile
table.  Timelines render as unicode sparklines (no plotting deps)::

    PYTHONPATH=src python tools/obs_report.py fleet_obs.json
        [--out obs_report.md] [--max-lanes 8]

With ``--out`` the report is written to a file (CI uploads it next to
the Perfetto trace); otherwise it prints to stdout.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys
from typing import List, Sequence

_BARS = " ▁▂▃▄▅▆▇█"


def spark(values: Sequence[float]) -> str:
    """Unicode sparkline of a series (flat series render as floors)."""
    vals = [float(v) for v in values]
    if not vals:
        return ""
    lo, hi = min(vals), max(vals)
    span = hi - lo
    if span <= 0:
        return _BARS[1] * len(vals)
    return "".join(_BARS[1 + int((v - lo) / span * 7)] for v in vals)


def _table(rows: List[Sequence], header: Sequence[str]) -> List[str]:
    out = ["| " + " | ".join(header) + " |",
           "| " + " | ".join("---" for _ in header) + " |"]
    out += ["| " + " | ".join(str(c) for c in r) + " |" for r in rows]
    return out


def render(obs: dict, max_lanes: int = 8) -> str:
    """The whole report as one markdown string."""
    lines: List[str] = ["# Flight-recorder report", ""]
    meta = obs.get("meta", {})
    if meta:
        lines += ["- " + " · ".join(f"{k}: {v}" for k, v in
                                    sorted(meta.items())), ""]
    tls = obs["timelines"]
    fleet = tls.get("fleet", {})
    n_lanes = len(tls.get("lanes", []))
    labels = obs.get("lane_labels") or [f"lane {i}"
                                        for i in range(n_lanes)]

    # ---- DLWA vs time ------------------------------------------------- #
    lines += ["## DLWA vs time", "",
              "Cumulative (host + superfluous) / host pages per time "
              "bucket (program progress).", ""]
    rows = []
    if fleet:
        rows.append(("**fleet**", spark(fleet["dlwa"]),
                     f"{fleet['dlwa'][-1]:.3f}"))
    shown = tls.get("lanes", [])[:max_lanes]
    for label, tl in zip(labels, shown):
        rows.append((label, spark(tl["dlwa"]), f"{tl['dlwa'][-1]:.3f}"))
    lines += _table(rows, ("lane", "dlwa timeline", "final"))
    if n_lanes > max_lanes:
        lines += ["", f"({n_lanes - max_lanes} more lanes omitted; "
                      f"--max-lanes to widen)"]
    lines += [""]

    # ---- wear spread vs time ------------------------------------------ #
    lines += ["## Wear frontier vs time", "",
              "Max element wear among op-touched elements (gauge per "
              "bucket) and superfluous pages per bucket.", ""]
    rows = []
    if fleet:
        rows.append(("**fleet** wear_max", spark(fleet["wear_max"]),
                     max(fleet["wear_max"])))
        rows.append(("**fleet** superfluous", spark(fleet["dummy"]),
                     sum(fleet["dummy"])))
        rows.append(("**fleet** erases", spark(fleet["erases"]),
                     sum(fleet["erases"])))
    for label, tl in zip(labels, shown):
        rows.append((label + " wear_max", spark(tl["wear_max"]),
                     max(tl["wear_max"])))
    lines += _table(rows, ("series", "timeline", "peak/total")) + [""]

    # ---- per-tenant p99 ----------------------------------------------- #
    gauges = obs.get("metrics", {}).get("gauges", {})
    parity = obs.get("parity_tenant")
    p99 = {k: v for k, v in gauges.items()
           if k.startswith("tenant") and k.endswith("_p99_latency_s")}
    if p99:
        lines += ["## p99 latency per tenant class", ""]
        rows = []
        for k in sorted(p99):
            t = int(k[len("tenant"): -len("_p99_latency_s")])
            name = "parity" if t == parity else f"tenant {t}"
            rows.append((name, f"{p99[k] * 1e6:.1f} us"))
        lines += _table(rows, ("tenant class", "p99 latency")) + [""]

    # ---- host/superfluous per tenant ---------------------------------- #
    tenants = tls.get("tenants", {})
    if tenants:
        lines += ["## Pages per tenant class", ""]
        rows = []
        for t in sorted(tenants, key=lambda s: int(s)):
            tt = tenants[t]
            name = ("parity" if parity is not None and int(t) == parity
                    else f"tenant {t}")
            rows.append((name, spark(tt["host"]), sum(tt["host"]),
                         sum(tt["dummy"])))
        lines += _table(rows, ("tenant class", "host-page timeline",
                               "host pages", "superfluous")) + [""]

    # ---- recompile / dispatch profile --------------------------------- #
    cache = obs.get("jit_cache", {})
    if cache:
        lines += ["## Recompile table", "",
                  "Jit-cache entries per dispatch surface (one per "
                  "abstract input signature; flat across repeats = "
                  "shape-stable).", ""]
        lines += _table(sorted(cache.items()),
                        ("function", "cache entries")) + [""]
    prof = obs.get("profile", {})
    if prof:
        lines += ["## Dispatch profile", ""]
        rows = []
        for name in sorted(prof):
            d = prof[name]
            compile_s = d["trace_s"] + d["lower_s"] + d["compile_s"]
            rows.append((name, int(d["calls"]), f"{d['wall_s']:.3f}",
                         f"{compile_s:.3f}", f"{d['execute_s']:.3f}",
                         int(d["n_compiles"])))
        lines += _table(rows, ("section", "calls", "wall s",
                               "trace+compile s", "execute s",
                               "compiles")) + [""]

    counters = obs.get("metrics", {}).get("counters", {})
    if counters:
        lines += ["## Counters", ""]
        lines += _table([(k, f"{v:.0f}")
                         for k, v in sorted(counters.items())],
                        ("counter", "value")) + [""]
    return "\n".join(lines)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__,
                                 allow_abbrev=False)
    ap.add_argument("obs_json", type=pathlib.Path,
                    help="the <prefix>_obs.json sidecar")
    ap.add_argument("--out", type=pathlib.Path, default=None)
    ap.add_argument("--max-lanes", type=int, default=8)
    args = ap.parse_args()
    obs = json.loads(args.obs_json.read_text())
    report = render(obs, max_lanes=args.max_lanes)
    if args.out:
        args.out.write_text(report + "\n")
        print(f"wrote {args.out}", file=sys.stderr)
    else:
        print(report)
    return 0


if __name__ == "__main__":
    sys.exit(main())
