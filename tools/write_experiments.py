"""Assemble EXPERIMENTS.md from the dry-run JSONs + bench outputs.

    PYTHONPATH=src:. python tools/write_experiments.py
"""

from __future__ import annotations

import json
import subprocess
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))
from benchmarks import roofline_report as R  # noqa: E402

REPO = Path(__file__).resolve().parent.parent
PERF_LOG = REPO / "results" / "perf_log.md"

HEADER = """# EXPERIMENTS

Reproduction + performance report for *Eliminating the Hidden Cost of
Zone Management in ZNS SSDs* (SilentZNS) as a multi-pod JAX framework.
All storage results run on the emulated devices (ConfZNS++-modeled ZN540
and the paper's custom 16-LUN SSD); all roofline numbers come from the
512-device dry-run (`python -m repro.launch.dryrun --all --mesh both`).

## §Reproduction — paper claims vs ours

Run: `PYTHONPATH=src python -m benchmarks.run` (CSV: name,us,derived).

| paper claim | ours | artifact |
|---|---|---|
| DLWA −86.36% @10% occupancy (superblock, ZN540) | **−86.4%** (exact) | fig4a_7a |
| DLWA = 1.0 at 50% occupancy (multi-segment zones) | **1.0** (exact) | tests::test_paper_dlwa_1_at_50pct |
| Fig 8: vchunk ~4x fewer dummy pages than fixed (P8,S128, ~0% occ) | **4.0x** | fig8 |
| Fig 9: P16 peak ≈110 MiB/s @1 zone; P8 needs 2 zones; P4 needs 4 | **119 / 60→119 / 30→119 MiB/s** | fig9 |
| Fig 1/7b: delaying FINISH 10%→90% ⇒ −91% baseline DLWA, +69% SA | **−85%, +46%** (same shape, see note) | fig7b |
| SilentZNS DLWA flat ≈1 at every threshold | **1.08→1.00** | fig7b |
| Fig 7c: less total wear (−12%) + better leveling | **−86% erases under our churn; isolation bench: max wear 146→3, σ 20.7→0.5** (see note) | fig7c / fig7c_leveling |
| Table 3: interference 1.6 → 1.1 with fine-grained elements (multi-segment) | **2.0 → ~1.1–1.2** | table3/fig4b |
| Table 4: alloc latency fixed ≪ superblock < vchunk < block | **30 µs ≪ 439 µs < block 795 µs** (ladder reproduced; abs. values are our vectorized allocator, not MOSEK — and ~10x faster) | table4 |

Notes: (i) our SA/DLWA trade-off magnitudes depend on the modeled
RocksDB concurrency (ours: 6 concurrent jobs, 64 MiB memtables); the
*mechanism* (proactive FINISH threshold vs lifetime-mixing relaxation)
and the monotone trade-off reproduce. (ii) the paper accumulates wear
over 8x 4M-op runs; our fig7c uses 4x1M and adds an isolation bench for
the leveling claim. (iii) interference absolute values depend on queue
arbitration; ordering and the multi-segment/fine-grained gap reproduce.

## §Methodology — roofline terms

`cost_analysis()`/HLO-text numbers on scanned (lax.scan-over-layers)
models undercount by the trip count (XLA sees a while body once), so the
table's three terms are **analytic per-device counts**
(`repro/analysis/flops.py`: matmul/attention/recurrence FLOPs; params /
activations / KV-cache HBM traffic; TP-AR + FSDP-AG + DP-grad + MoE-a2a
collective bytes), with HLO-parsed collective bytes taken as a floor
(`max(analytic, parsed)`). Hardware: 197 TF/s bf16, 819 GB/s HBM,
50 GB/s ICI per chip. `memory_analysis()` peak is XLA's buffer
assignment on the CPU backend, which materializes f32 copies of bf16
matmul operands (no bf16 CPU gemm) — TPU-true residency is lower; both
are reported. roofline_fraction = (model_flops/peak) / max(term).

"""


def main() -> None:
    parts = [HEADER]

    parts.append("## §Dry-run — multi-pod compile proof\n")
    s = R.summary()
    parts.append(
        f"- single-pod mesh (16x16, 256 chips): **{s['cells_single_ok']}"
        f"/{s['cells_single_ok']} cells lower+compile OK**\n"
        f"- multi-pod mesh (2x16x16, 512 chips): "
        f"**{s['cells_multi_ok']} cells OK** (the `pod` axis shards; "
        f"gradient sync crosses the DCI)\n"
        f"- failures: {s['fails']}\n"
        "- cells: 10 archs x {train_4k, prefill_32k, decode_32k} "
        "+ long_500k for the 2 sub-quadratic archs = 32 cells/mesh "
        "(long_500k skipped for 8 full-attention archs per "
        "DESIGN.md §Arch-applicability).\n")

    parts.append("\n## §Roofline — single-pod (16x16) baselines\n")
    parts.append(R.markdown(mesh="single"))
    parts.append(
        "\n\nuseful = MODEL_FLOPS/HLO-analytic FLOPs (catches attention/"
        "recurrence overhead vs pure 6ND); roofline frac = useful-flop "
        "time over the binding term.  Decode rows: roofline fraction is "
        "inherently tiny (one token amortizes no weights) -- the relevant "
        "number there is t_memory vs the cache-read bound.\n")

    parts.append("\n## §Roofline — multi-pod (2x16x16) check\n")
    parts.append(R.markdown(mesh="multi"))

    if PERF_LOG.exists():
        parts.append("\n\n" + PERF_LOG.read_text())

    (REPO / "EXPERIMENTS.md").write_text("\n".join(parts))
    print("wrote EXPERIMENTS.md")


if __name__ == "__main__":
    main()
