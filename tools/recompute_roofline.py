"""Recompute the analytic roofline terms in dry-run JSONs without
recompiling (the compile proof is unchanged; only the cost model moved)."""
import json, sys, glob
sys.path.insert(0, "src")
from repro.analysis import flops as FL
from repro.analysis import roofline as roof
from repro.configs import get_arch, get_shape
from repro.launch import sharding as SH

for path in glob.glob("results/dryrun/*.json"):
    d = json.load(open(path))
    if not d.get("ok"):
        continue
    cfg = get_arch(d["arch"]); cell = get_shape(d["shape"])
    r = d["roofline"]
    mesh_data = d["mesh_shape"]["data"]
    cost = FL.cell_cost(cfg, cell, d["devices"], dp=r["dp"], tp=r["tp"],
                        n_micro=r["n_micro"], fsdp=SH._needs_fsdp(cfg),
                        append_impl="scatter", param_dp=mesh_data)
    rl = roof.Roofline(flops=cost.flops, hbm_bytes=cost.hbm_bytes,
                       coll_bytes=max(cost.coll_bytes,
                                      d["collectives"].get("total", 0)),
                       model_flops=cost.model_flops)
    rep = rl.report()
    rep["n_micro"], rep["dp"], rep["tp"] = r["n_micro"], r["dp"], r["tp"]
    rep["residency_gb"] = round(cost.detail["residency_bytes"] / 1e9, 2)
    d["roofline"] = rep
    d["analytic_detail"] = {k: v for k, v in cost.detail.items()}
    json.dump(d, open(path, "w"), indent=1, default=str)
print("recomputed", len(glob.glob("results/dryrun/*.json")))
