"""Perf-trajectory tracker: per-op legacy pipelines vs batched engine.

Two tracked trajectories, each written as a JSON artifact:

* ``BENCH_zoneengine.json`` -- the DLWA occupancy sweep and the
  interference benchmark through the ``LegacyZNSDevice`` per-op loop vs
  the scan-compiled ``repro.core.engine`` op programs (PR 2's gate:
  dlwa sweep >= 5x).
* ``BENCH_fleet.json`` -- the 32-config fleet allocator sweep
  (``repro.fleet``) through one batched ``run_programs`` + one batched
  op-granular timing dispatch vs the per-config legacy pipeline
  (``ZNSArray`` over stateful-Python members + page-granular
  ``run_fleet_trace``, the ``benchmarks/raid_zns.py`` way) -- PR 3's
  gate: fleet sweep >= 5x.  Since PR 4 the artifact also carries an
  ``evolve`` section: the adaptive searcher's dispatched budget to
  reach the best objective of a 32-config random search
  (``repro.fleet.evolve.evolve_vs_random``; gate: target reached with
  <= half the random baseline's full-fidelity-equivalent evals).
  Since PR 5 a ``mixed_spec`` section times a SUPERBLOCK+BLOCK+VCHUNK2
  sweep through ONE union-config dispatch (per-lane ``DynConfig`` spec
  selection) vs the per-config legacy pipeline, whose members are
  built with each config's actual element spec -- the mixed-spec DLWA
  agreement is asserted before timing.

Both speedup comparisons assert metric agreement between the paths
before timing anything.  Usage::

    PYTHONPATH=src python tools/bench.py [--quick] [--repeats 3]
        [--out BENCH_zoneengine.json] [--fleet-out BENCH_fleet.json]
        [--skip-engine | --skip-fleet]
"""

from __future__ import annotations

import argparse
import json
import pathlib
import platform
import sys

_ROOT = pathlib.Path(__file__).resolve().parent.parent
for _p in (str(_ROOT), str(_ROOT / "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

import numpy as np  # noqa: E402

from repro.core import workloads  # noqa: E402
from repro.fleet import grid_space  # noqa: E402
from repro.fleet.search import fleet_vs_legacy_speedup  # noqa: E402


def _meta(**extra) -> dict:
    return {
        "device": "zn540/superblock",
        "python": platform.python_version(),
        "machine": platform.machine(),
        **extra,
    }


def bench_engine(args) -> int:
    occs = (np.linspace(0.1, 0.9, 5) if args.quick
            else np.linspace(0.05, 0.95, 16))
    concs = (1, 4) if args.quick else (1, 2, 4, 7)
    rep = workloads.engine_vs_legacy_speedup(
        occupancies=tuple(float(o) for o in occs),
        n_zones=4 if args.quick else 8,
        concurrencies=concs,
        repeats=args.repeats)

    artifact = {
        "dlwa": {
            "ops": rep["dlwa_ops"],
            "legacy_s": rep["dlwa_legacy_s"],
            "engine_s": rep["dlwa_engine_s"],
            "legacy_ops_s": rep["dlwa_legacy_ops_s"],
            "engine_ops_s": rep["dlwa_engine_ops_s"],
            "speedup": rep["dlwa_speedup"],
        },
        "interference": {
            "ops": rep["interference_ops"],
            "legacy_s": rep["interference_legacy_s"],
            "engine_s": rep["interference_engine_s"],
            "legacy_ops_s": rep["interference_legacy_ops_s"],
            "engine_ops_s": rep["interference_engine_ops_s"],
            "speedup": rep["interference_speedup"],
        },
        "meta": _meta(occupancies=len(occs), concurrencies=list(concs),
                      repeats=args.repeats),
    }
    args.out.write_text(json.dumps(artifact, indent=2) + "\n")
    for name in ("dlwa", "interference"):
        row = artifact[name]
        print(f"{name}: legacy {row['legacy_ops_s']:.0f} ops/s, "
              f"engine {row['engine_ops_s']:.0f} ops/s, "
              f"speedup {row['speedup']:.1f}x")
    print(f"wrote {args.out}")
    # the acceptance bar from PR 2: scan-compiled dlwa sweep >= 5x
    if artifact["dlwa"]["speedup"] < 5.0:
        print("WARNING: dlwa speedup below the 5x target", file=sys.stderr)
        return 1
    return 0


def bench_fleet(args) -> int:
    from repro.core.elements import BLOCK, SUPERBLOCK, vchunk
    from repro.core.engine import ZoneEngine
    from repro.core.geometry import zn540
    from repro.fleet import SearchSpace, evolve_vs_random

    configs = None
    space = SearchSpace()
    if args.quick:
        configs = grid_space(segments=(22, 11), chunks=(1536,),
                             parities=(False, True), wear=(True,))
        space = SearchSpace(chunks=(1536,), parities=(False, True))
    rep = fleet_vs_legacy_speedup(configs=configs, repeats=args.repeats)

    # mixed element specs in ONE union-config dispatch vs the per-spec
    # legacy pipeline (members built with each config's actual spec;
    # DLWA agreement asserted inside before timing)
    mixed_specs = (SUPERBLOCK, BLOCK, vchunk(2))
    mixed_configs = grid_space(
        segments=(22,) if args.quick else (22, 11),
        chunks=(1536,), parities=(False,), wear=(True,),
        specs=mixed_specs)
    mixed = fleet_vs_legacy_speedup(configs=mixed_configs,
                                    specs=mixed_specs,
                                    repeats=args.repeats)
    mixed["n_specs"] = float(len(mixed_specs))

    # adaptive search: dispatched budget to reach the random-32 target
    flash, zone = zn540()
    eng = ZoneEngine(flash, zone, SUPERBLOCK, max_active=14)
    evo = evolve_vs_random(eng, space=space, random_n=32, seed=0,
                           n_devices=4)

    artifact = {
        "fleet_sweep": rep,
        "mixed_spec": mixed,
        "evolve": evo,
        "meta": _meta(repeats=args.repeats, quick=bool(args.quick)),
    }
    args.fleet_out.write_text(json.dumps(artifact, indent=2) + "\n")
    print(f"fleet: {rep['n_configs']:.0f} configs x "
          f"{rep['n_devices']:.0f} devices, "
          f"legacy {rep['legacy_s']:.2f}s vs engine {rep['engine_s']:.2f}s "
          f"-> speedup {rep['speedup']:.1f}x "
          f"(replay-only {rep['replay_speedup']:.1f}x)")
    print(f"mixed-spec: {mixed['n_configs']:.0f} configs over "
          f"{len(mixed_specs)} element specs in one dispatch, "
          f"legacy {mixed['legacy_s']:.2f}s vs engine "
          f"{mixed['engine_s']:.2f}s -> speedup {mixed['speedup']:.1f}x")
    print(f"evolve: target {evo['random']['best_objective']:.4f} "
          f"({'reached' if evo['evolve']['reached_target'] else 'MISSED'}) "
          f"with {evo['evolve']['n_evals']:.1f} evals / "
          f"{evo['evolve']['n_dispatches']:.0f} dispatches vs random's "
          f"{evo['random']['n_evals']:.0f} / "
          f"{evo['random']['n_dispatches']:.0f} "
          f"-> {evo['n_evals_savings']:.1f}x eval savings")
    print(f"wrote {args.fleet_out}")
    rc = 0
    # PR 3's acceptance bar: batched fleet sweep >= 5x
    if rep["speedup"] < 5.0:
        print("WARNING: fleet speedup below the 5x target", file=sys.stderr)
        rc = 1
    # PR 4's acceptance bar: random-best matched on <= half the evals
    if (not evo["evolve"]["reached_target"]
            or evo["n_evals_savings"] < 2.0):
        print("WARNING: evolve missed the <=half-budget-to-random-best "
              "target", file=sys.stderr)
        rc = 1
    return rc


def main() -> int:
    # allow_abbrev off: a mistyped/abbreviated flag (e.g. `--skip`)
    # must exit non-zero instead of silently running everything under
    # argparse's prefix guessing
    ap = argparse.ArgumentParser(description=__doc__, allow_abbrev=False)
    ap.add_argument("--out", type=pathlib.Path,
                    default=_ROOT / "BENCH_zoneengine.json")
    ap.add_argument("--fleet-out", type=pathlib.Path,
                    default=_ROOT / "BENCH_fleet.json")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--quick", action="store_true",
                    help="smaller sweeps (CI smoke)")
    ap.add_argument("--skip-engine", action="store_true")
    ap.add_argument("--skip-fleet", action="store_true")
    args = ap.parse_args()
    if args.skip_engine and args.skip_fleet:
        ap.error("--skip-engine and --skip-fleet together leave "
                 "nothing to benchmark")

    rc = 0
    if not args.skip_engine:
        rc |= bench_engine(args)
    if not args.skip_fleet:
        rc |= bench_fleet(args)
    return rc


if __name__ == "__main__":
    sys.exit(main())
