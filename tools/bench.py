"""Perf-trajectory tracker: per-op legacy pipelines vs batched engine.

Two tracked trajectories, each written as a JSON artifact:

* ``BENCH_zoneengine.json`` -- the DLWA occupancy sweep and the
  interference benchmark through the ``LegacyZNSDevice`` per-op loop vs
  the scan-compiled ``repro.core.engine`` op programs (PR 2's gate:
  dlwa sweep >= 5x).
  Since PR 6 the sweep runs as ONE padded ``run_programs`` dispatch
  (``workloads.interference_sweep_engine``); the artifact asserts the
  dispatch/compile count is flat across repeats (the recompile leak
  that had regressed it to 0.96x) and gates >= 1x.
* ``BENCH_fleet.json`` -- the 32-config fleet allocator sweep
  (``repro.fleet``) through one batched ``run_programs`` + one batched
  op-granular timing dispatch vs the per-config legacy pipeline
  (``ZNSArray`` over stateful-Python members + page-granular
  ``run_fleet_trace``, the ``benchmarks/raid_zns.py`` way) -- PR 3's
  gate: fleet sweep >= 5x.  Since PR 4 the artifact also carries an
  ``evolve`` section: the adaptive searcher's dispatched budget to
  reach the best objective of a 32-config random search
  (``repro.fleet.evolve.evolve_vs_random``; gate: target reached with
  <= half the random baseline's full-fidelity-equivalent evals).
  Since PR 5 a ``mixed_spec`` section times a SUPERBLOCK+BLOCK+VCHUNK2
  sweep through ONE union-config dispatch (per-lane ``DynConfig`` spec
  selection) vs the per-config legacy pipeline, whose members are
  built with each config's actual element spec -- the mixed-spec DLWA
  agreement is asserted before timing.
  Since PR 7 the legacy legs of the fleet sweep are timed once at a
  reduced config count and linearly scaled (the per-op pipeline is
  per-config sequential; the exactness assert still covers every
  config, and the measured/scaled split is recorded in the section
  and in ``meta``), and an ``array`` section times the engine-native
  ZNS-RAID data plane (``repro.array.ArrayEngine``: striping + parity
  + rebuild compiled into ONE batched dispatch) vs the object
  ``ZNSArray`` replay -- gate: >= 5x, with every per-array report
  asserted bit-identical to the object oracle first -- plus a
  rebuild-storm subsection asserted recompile-stable across repeated
  same-shape dispatches.
  Since PR 9 a ``trace`` section records real application traffic
  (ZoneFS/LSM compactions, checkpoint bursts, a Zipfian flash cache)
  through the :class:`repro.storage.RecordingBackend` trace compiler
  and replays the compiled op programs through ONE batched dispatch vs
  the identical op streams through the per-op legacy device -- gate:
  >= 5x with zero recompiles across repeated same-shape dispatches,
  after asserting per-lane DLWA agreement.

* ``BENCH_paper.json`` -- the paper's three headline claims as
  SilentZNS-policy vs traditional-mapping lane pairs over one shared
  union engine (``repro.core.headline.paper_report``; PR 8's gates:
  DLWA reduction at 10% occupancy >= 80%, wear reduction > 0,
  workload execution speedup > 1x, zero jit-cache growth across
  repeated same-shape dispatches -- see ``check_paper_gates``).

Both speedup comparisons assert metric agreement between the paths
before timing anything.  Usage::

    PYTHONPATH=src python tools/bench.py [--quick] [--repeats 3]
        [--out BENCH_zoneengine.json] [--fleet-out BENCH_fleet.json]
        [--paper-out BENCH_paper.json]
        [--skip-engine] [--skip-fleet] [--skip-paper]
"""

from __future__ import annotations

import argparse
import datetime
import json
import pathlib
import platform
import subprocess
import sys
import time

_ROOT = pathlib.Path(__file__).resolve().parent.parent
for _p in (str(_ROOT), str(_ROOT / "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

import numpy as np  # noqa: E402

from repro.core import workloads  # noqa: E402
from repro.fleet import grid_space  # noqa: E402
from repro.fleet.search import fleet_vs_legacy_speedup  # noqa: E402


# bump when the artifact layout changes in a way bench_table must
# know about (2: run provenance stamped in meta; obs_overhead section;
# 3: array section + scaled legacy fleet timing; 4: BENCH_paper.json
# headline artifact; 5: trace section -- compiled app workloads vs the
# legacy per-op replay)
SCHEMA_VERSION = 5


def _git_sha() -> str:
    try:
        return subprocess.run(
            ["git", "-C", str(_ROOT), "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10,
            check=True).stdout.strip()
    except Exception:
        return "unknown"


def _meta(**extra) -> dict:
    import jax

    return {
        "schema_version": SCHEMA_VERSION,
        "device": "zn540/superblock",
        "python": platform.python_version(),
        "machine": platform.machine(),
        "platform": platform.platform(),
        "jax": jax.__version__,
        "jax_backend": jax.default_backend(),
        "git_sha": _git_sha(),
        "timestamp": datetime.datetime.now(
            datetime.timezone.utc).isoformat(timespec="seconds"),
        **extra,
    }


def _sanitize_audit(policies=("traditional", "silent")) -> dict:
    """End-state invariant audit accompanying an artifact: drive a
    canonical fill/finish/reset cycle per alloc policy on the bench
    geometry and run every final device state through the
    :mod:`repro.check` sanitizer.  Raises ``SanitizerError`` on any
    violation; returns the summary stamped into the artifact."""
    from repro.check import assert_states
    from repro.core import engine as zengine
    from repro.core.elements import SUPERBLOCK
    from repro.core.engine import ZoneEngine
    from repro.core.geometry import FlashGeometry, ZoneGeometry

    flash = FlashGeometry(n_channels=4, ways_per_channel=1,
                          blocks_per_lun=32, pages_per_block=4,
                          page_bytes=4096)
    eng = ZoneEngine(flash, ZoneGeometry(parallelism=4, n_segments=2),
                     SUPERBLOCK, max_active=8)
    zp = eng.cfg.zone_pages
    ops = []
    for z in range(3):
        ops += [(zengine.OP_WRITE, z, zp // 2, zengine.F_HOST),
                (zengine.OP_FINISH, z, 0, 0)]
    ops += [(zengine.OP_RESET, 0, 0, 0),
            (zengine.OP_WRITE, 0, zp, zengine.F_HOST)]
    program = np.asarray(ops, dtype=np.int32)
    dyns = [eng.dyn(alloc_policy=p) for p in policies]
    programs = np.broadcast_to(program, (len(dyns),) + program.shape)
    states, trace = eng.run_batch(eng.init_state(), np.ascontiguousarray(
        programs), zengine.stack_dyn(dyns))
    assert bool(np.asarray(trace.ok).all()), "audit program illegal?"
    assert_states(eng.cfg, states, zengine.stack_dyn(dyns),
                  where="bench sanitize audit")
    return {"checked": True, "lanes": float(len(dyns)),
            "policies": list(policies)}


def bench_engine(args) -> int:
    occs = (np.linspace(0.1, 0.9, 5) if args.quick
            else np.linspace(0.05, 0.95, 16))
    concs = (1, 4) if args.quick else (1, 2, 4, 7)
    rep = workloads.engine_vs_legacy_speedup(
        occupancies=tuple(float(o) for o in occs),
        n_zones=4 if args.quick else 8,
        concurrencies=concs,
        repeats=args.repeats)

    artifact = {
        "dlwa": {
            "ops": rep["dlwa_ops"],
            "legacy_s": rep["dlwa_legacy_s"],
            "engine_s": rep["dlwa_engine_s"],
            "legacy_ops_s": rep["dlwa_legacy_ops_s"],
            "engine_ops_s": rep["dlwa_engine_ops_s"],
            "speedup": rep["dlwa_speedup"],
        },
        "interference": {
            "ops": rep["interference_ops"],
            "legacy_s": rep["interference_legacy_s"],
            "engine_s": rep["interference_engine_s"],
            "legacy_ops_s": rep["interference_legacy_ops_s"],
            "engine_ops_s": rep["interference_engine_ops_s"],
            "speedup": rep["interference_speedup"],
            # PR 6 diagnosis of the 0.96x regression: each concurrency
            # point used to be its own scan shape, so the sweep paid
            # one XLA compile per point per process.  It now NOP-pads
            # to one rectangular batch -> ONE dispatch, and the jit
            # cache must not grow across timed repeats.
            "dispatches": rep["interference_dispatches"],
            "recompiles": rep["interference_recompiles"],
        },
        "meta": _meta(occupancies=len(occs), concurrencies=list(concs),
                      repeats=args.repeats),
    }
    if args.sanitize:
        artifact["sanitize"] = _sanitize_audit()
    args.out.write_text(json.dumps(artifact, indent=2) + "\n")
    for name in ("dlwa", "interference"):
        row = artifact[name]
        print(f"{name}: legacy {row['legacy_ops_s']:.0f} ops/s, "
              f"engine {row['engine_ops_s']:.0f} ops/s, "
              f"speedup {row['speedup']:.1f}x")
    intf = artifact["interference"]
    print(f"interference: {intf['dispatches']:.0f} dispatch(es), "
          f"{intf['recompiles']:.0f} recompile(s) across timed repeats")
    print(f"wrote {args.out}")
    rc = 0
    # the acceptance bar from PR 2: scan-compiled dlwa sweep >= 5x
    if artifact["dlwa"]["speedup"] < 5.0:
        print("WARNING: dlwa speedup below the 5x target", file=sys.stderr)
        rc = 1
    # PR 6: with the recompile leak fixed the batched sweep must not
    # lose to the per-op legacy loop, and the timed repeats must not
    # grow the jit cache (a regrowth here is the 0.96x bug returning)
    if intf["speedup"] < 1.0:
        print("WARNING: interference speedup below the 1x floor",
              file=sys.stderr)
        rc = 1
    if intf["recompiles"] != 0:
        print("WARNING: interference sweep recompiled during timed "
              "repeats (shape-unstable dispatch)", file=sys.stderr)
        rc = 1
    return rc


def _obs_overhead(eng, repeats: int, sanitize: bool = False) -> dict:
    """Telemetry-on vs telemetry-off wall time of the same warmed
    batched ``run_fleet`` dispatch (8 configs x 4 devices)."""
    import gc

    import jax

    from repro.fleet import (N_TENANTS, build_fleet_batch, grid_space,
                             run_fleet)
    from repro.obs import ObsConfig

    configs = grid_space(segments=(22, 11), chunks=(1536, 768),
                         parities=(False, True), wear=(True, False))[:8]
    programs, dyn, _ = build_fleet_batch(eng, configs, n_devices=4,
                                         pad_quantum=64)
    obs = ObsConfig(n_buckets=32, n_tenants=N_TENANTS + 1)

    def once(o):
        # FleetResult is decoded to numpy, which already forces the
        # device sync -- block again anyway in case decode gets lazier
        res = run_fleet(eng, programs, dyn=dyn, n_tenants=N_TENANTS,
                        parity_tenant=N_TENANTS, obs=o)
        jax.block_until_ready(res.completions)
        return res

    warm = (once(None), once(obs))  # warm both jit variants
    if sanitize:
        from repro.check import assert_states
        for res in warm:
            assert_states(eng.cfg, res.states, dyn,
                          where="obs-overhead warm states")
    # paired back-to-back measurements with GC parked, summarized as
    # the median of per-pair ratios: the dispatch is ~0.2s, where one
    # scheduler hiccup or GC pause swings a min-of-N ratio past the
    # 1.10 gate even though the true overhead is a few percent
    offs, ons = [], []
    gc.collect()
    gc.disable()
    try:
        for _ in range(3 * max(repeats, 3)):
            offs.append(_timed(once, None))
            ons.append(_timed(once, obs))
    finally:
        gc.enable()
    ratios = sorted(b / a for a, b in zip(offs, ons))
    off_s = float(np.median(offs))
    on_s = float(np.median(ons))
    return {
        "n_lanes": float(programs.shape[0]),
        "n_ops": float(programs.shape[1]),
        "off_s": off_s,
        "on_s": on_s,
        "overhead": float(ratios[len(ratios) // 2]),
    }


def _timed(fn, *fn_args) -> float:
    t0 = time.perf_counter()
    fn(*fn_args)
    return time.perf_counter() - t0


def _evaluator_recompiles(eng, generations: int = 4,
                          sanitize: bool = False) -> dict:
    """Jit-cache growth across repeated same-shape Evaluator
    generations -- flat after generation 1 means the dispatch surface
    is shape-stable (pad_quantum doing its job)."""
    from repro.fleet import Evaluator, grid_space
    from repro.obs import Profiler

    configs = grid_space(segments=(22, 11), chunks=(1536,),
                         parities=(False, True), wear=(True,))[:4]
    ev = Evaluator(eng, n_devices=2, profiler=Profiler(),
                   sanitize=sanitize)
    per_gen = []
    for _ in range(generations):
        ev.evaluate(configs)
        per_gen.append(ev.jit_cache()["run_programs"])
    return {
        "generations": float(generations),
        "run_programs_cache_per_gen": [float(c) for c in per_gen],
        "stable_after_warmup": bool(
            len(set(per_gen[1:])) <= 1 and per_gen[1] == per_gen[-1]),
    }


def _bench_array(args) -> dict:
    """The engine-native array comparator + the rebuild-storm
    recompile-stability probe (one shared engine, two identical
    same-shape storm dispatches; the second must not grow the jit
    cache)."""
    from repro.array import (StormScenario, array_vs_legacy_speedup,
                             rebuild_storm)
    from repro.core import engine as zengine
    from repro.core import timing as ctiming
    from repro.core.elements import SUPERBLOCK
    from repro.core.engine import ZoneEngine
    from repro.core.geometry import zn540
    from repro.obs import ObsConfig
    from repro.obs.profile import RecompileCounter

    rep = array_vs_legacy_speedup(
        n_arrays=4 if args.quick else 8,
        n_zones=4 if args.quick else 8,
        repeats=args.repeats,
        legacy_arrays=2)

    flash, zone = zn540()
    eng = ZoneEngine(flash, zone, SUPERBLOCK, max_active=14)
    scenarios = [
        StormScenario(n_devices=3, n_zones_filled=2, occupancy=0.5),
        StormScenario(n_devices=4, n_zones_filled=2, occupancy=0.6),
    ]
    counter = RecompileCounter(run_programs=zengine.run_programs,
                               simulate_fleet_ops=ctiming.simulate_fleet_ops)
    obs = ObsConfig(n_buckets=16, n_tenants=3)
    rebuild_storm(eng, scenarios, obs=obs)          # warm/compile
    before = counter.counts()
    t0 = time.perf_counter()
    storm = rebuild_storm(eng, scenarios, obs=obs)  # must hit the cache
    storm_s = time.perf_counter() - t0
    delta = counter.delta(before)
    rep["storm"] = {
        "n_scenarios": float(len(scenarios)),
        "dispatch_s": storm_s,
        "recompiles": float(sum(delta.values())),
        "scenarios": storm["scenarios"],
    }
    return rep


def _trace_recorders(eng, quick: bool):
    """Record the three application workloads (seed-varied instances)
    into op programs; each recorder is one independent device lane."""
    import repro.storage as S
    from repro.storage.compile import _lsm_jobs

    n_inst = 1 if quick else 2
    recs, labels = [], []
    for inst in range(n_inst):
        for name in ("lsm", "ckpt", "cache"):
            rec = S.RecordingBackend(
                eng.flash, zone_pages=eng.cfg.zone_pages,
                n_zones=eng.cfg.n_zones, max_active=eng.cfg.max_active)
            if name == "lsm":
                cfg = S.scaled_kv_config(
                    rec.zone_pages, eng.flash.page_bytes, seed=inst,
                    n_flushes=6 if quick else 10,
                    max_jobs=_lsm_jobs(rec))
                S.LSMSimulator(S.ZoneFS(rec), cfg).run()
            elif name == "ckpt":
                S.record_checkpoints(rec, S.CheckpointSchedule(
                    n_steps=10 if quick else 24, shards=3, seed=inst))
            else:
                S.record_cache(rec, n_accesses=600 if quick else 2000,
                               n_keys=64, seed=inst,
                               capacity_zones=min(6, rec.n_zones),
                               obj_pages=4)
            recs.append(rec)
            labels.append(f"{name}{inst}")
    return recs, labels


def _legacy_replay_trace(eng, rec) -> float:
    """Replay one recorder's rows through the per-op legacy device;
    return its final DLWA (the exactness oracle)."""
    from repro.core import engine as zengine
    from repro.core.device_legacy import LegacyZNSDevice

    leg = LegacyZNSDevice(eng.flash, eng.zone_geom, eng.spec,
                          max_active=eng.cfg.max_active)
    for op, zone, n, flags, _tenant in rec.program().tolist():
        if op == zengine.OP_WRITE:
            leg.zone_write(zone, n, host=bool(flags & zengine.F_HOST))
        elif op == zengine.OP_FINISH:
            leg.zone_finish(zone)
        elif op == zengine.OP_RESET:
            leg.zone_reset(zone)
        elif op == zengine.OP_READ:
            leg.zone_read(zone, np.arange(n))
    return leg.dlwa


def _bench_trace(args) -> dict:
    """PR 9's comparator: ZoneFS/LSM, checkpoint-burst, and flash-cache
    traffic compiled to op programs and replayed through ONE batched
    dispatch vs the same op streams through the per-op legacy device,
    plus a zero-recompile probe across repeated same-shape dispatches."""
    import repro.storage as S
    from repro.core import engine as zengine
    from repro.core import timing as ctiming
    from repro.core.elements import SUPERBLOCK
    from repro.core.engine import ZoneEngine
    from repro.core.geometry import FlashGeometry, ZoneGeometry
    from repro.obs.profile import RecompileCounter

    flash = FlashGeometry(n_channels=4, ways_per_channel=1,
                          blocks_per_lun=32, pages_per_block=4,
                          page_bytes=4096)
    eng = ZoneEngine(flash, ZoneGeometry(parallelism=4, n_segments=2),
                     SUPERBLOCK, max_active=8)
    recs, labels = _trace_recorders(eng, bool(args.quick))
    n_ops = float(sum(len(r) for r in recs))

    counter = RecompileCounter(run_programs=zengine.run_programs,
                               simulate_fleet_ops=ctiming.simulate_fleet_ops)
    res = S.replay_recorders(eng, recs, n_tenants=1,   # warm/compile
                             sanitize=bool(args.sanitize))
    # exactness before timing: every compiled lane's DLWA must equal
    # the legacy per-op replay of the identical op stream
    t0 = time.perf_counter()
    legacy_dlwa = [_legacy_replay_trace(eng, rec) for rec in recs]
    legacy_s = time.perf_counter() - t0
    for lane, (rec, want) in enumerate(zip(recs, legacy_dlwa)):
        got = S.lane_metrics(eng, res, lane)["dlwa"]
        assert abs(got - want) < 1e-12, \
            f"lane {labels[lane]}: engine dlwa {got} != legacy {want}"

    before = counter.counts()
    engine_s = min(_timed(S.replay_recorders, eng, recs)
                   for _ in range(args.repeats))
    recompiles = float(sum(counter.delta(before).values()))
    return {
        "n_lanes": float(len(recs)),
        "workloads": labels,
        "recorded_ops": n_ops,
        "legacy_s": legacy_s,
        "engine_s": engine_s,
        "speedup": legacy_s / engine_s if engine_s else float("inf"),
        "recompiles": recompiles,
        "lane_dlwa": [float(d) for d in legacy_dlwa],
    }


def bench_fleet(args) -> int:
    from repro.core.elements import BLOCK, SUPERBLOCK, vchunk
    from repro.core.engine import ZoneEngine
    from repro.core.geometry import zn540
    from repro.fleet import SearchSpace, evolve_vs_random

    configs = None
    space = SearchSpace()
    if args.quick:
        configs = grid_space(segments=(22, 11), chunks=(1536,),
                             parities=(False, True), wear=(True,))
        space = SearchSpace(chunks=(1536,), parities=(False, True))
    # the legacy legs are timed on an 8-config prefix and scaled (the
    # per-op pipeline is per-config sequential; the DLWA exactness
    # assert inside still covers every config)
    rep = fleet_vs_legacy_speedup(configs=configs, repeats=args.repeats,
                                  legacy_configs=8)

    # mixed element specs in ONE union-config dispatch vs the per-spec
    # legacy pipeline (members built with each config's actual spec;
    # DLWA agreement asserted inside before timing)
    mixed_specs = (SUPERBLOCK, BLOCK, vchunk(2))
    mixed_configs = grid_space(
        segments=(22,) if args.quick else (22, 11),
        chunks=(1536,), parities=(False,), wear=(True,),
        specs=mixed_specs)
    mixed = fleet_vs_legacy_speedup(configs=mixed_configs,
                                    specs=mixed_specs,
                                    repeats=args.repeats)
    mixed["n_specs"] = float(len(mixed_specs))

    # adaptive search: dispatched budget to reach the random-32 target
    flash, zone = zn540()
    eng = ZoneEngine(flash, zone, SUPERBLOCK, max_active=14)
    evo = evolve_vs_random(eng, space=space, random_n=32, seed=0,
                           n_devices=4)

    # PR 6 flight recorder: telemetry carried through the scan must
    # stay within 10% of the bare dispatch, and repeated same-shape
    # Evaluator generations must not grow the jit cache
    overhead = _obs_overhead(eng, repeats=args.repeats,
                             sanitize=bool(args.sanitize))
    recomp = _evaluator_recompiles(eng, sanitize=bool(args.sanitize))

    # PR 7: engine-native ZNS-RAID vs the object ZNSArray replay, plus
    # the rebuild-storm recompile-stability probe
    arr = _bench_array(args)

    # PR 9: application traces (LSM/checkpoint/flash-cache) compiled to
    # op programs and batch-replayed vs the per-op legacy device
    trace = _bench_trace(args)

    artifact = {
        "fleet_sweep": rep,
        "mixed_spec": mixed,
        "evolve": evo,
        "obs_overhead": overhead,
        "evaluator_recompiles": recomp,
        "array": arr,
        "trace": trace,
        "meta": _meta(repeats=args.repeats, quick=bool(args.quick),
                      legacy_timed_configs=rep["legacy_timed_configs"],
                      legacy_scale=rep["legacy_scale"],
                      array_legacy_timed=arr["legacy_timed_arrays"],
                      array_legacy_scale=arr["legacy_scale"]),
    }
    if args.sanitize:
        artifact["sanitize"] = _sanitize_audit()
    args.fleet_out.write_text(json.dumps(artifact, indent=2) + "\n")
    print(f"fleet: {rep['n_configs']:.0f} configs x "
          f"{rep['n_devices']:.0f} devices, "
          f"legacy {rep['legacy_s']:.2f}s vs engine {rep['engine_s']:.2f}s "
          f"-> speedup {rep['speedup']:.1f}x "
          f"(replay-only {rep['replay_speedup']:.1f}x)")
    print(f"mixed-spec: {mixed['n_configs']:.0f} configs over "
          f"{len(mixed_specs)} element specs in one dispatch, "
          f"legacy {mixed['legacy_s']:.2f}s vs engine "
          f"{mixed['engine_s']:.2f}s -> speedup {mixed['speedup']:.1f}x")
    print(f"evolve: target {evo['random']['best_objective']:.4f} "
          f"({'reached' if evo['evolve']['reached_target'] else 'MISSED'}) "
          f"with {evo['evolve']['n_evals']:.1f} evals / "
          f"{evo['evolve']['n_dispatches']:.0f} dispatches vs random's "
          f"{evo['random']['n_evals']:.0f} / "
          f"{evo['random']['n_dispatches']:.0f} "
          f"-> {evo['n_evals_savings']:.1f}x eval savings")
    print(f"obs: telemetry-on {overhead['on_s']:.3f}s vs off "
          f"{overhead['off_s']:.3f}s -> {overhead['overhead']:.3f}x "
          f"overhead; evaluator run_programs cache per generation "
          f"{recomp['run_programs_cache_per_gen']}")
    print(f"array: {arr['n_arrays']:.0f} arrays ({arr['lane_ops']:.0f} "
          f"lane-ops), legacy {arr['legacy_s']:.2f}s "
          f"({arr['legacy_timed_arrays']:.0f} timed, "
          f"x{arr['legacy_scale']:.1f} scaled) vs engine "
          f"{arr['engine_s']:.2f}s -> speedup {arr['speedup']:.1f}x; "
          f"storm {arr['storm']['n_scenarios']:.0f} scenarios in "
          f"{arr['storm']['dispatch_s']:.2f}s, "
          f"{arr['storm']['recompiles']:.0f} recompile(s)")
    print(f"trace: {trace['n_lanes']:.0f} workload lanes "
          f"({trace['recorded_ops']:.0f} recorded ops), legacy "
          f"{trace['legacy_s']:.2f}s vs engine {trace['engine_s']:.2f}s "
          f"-> speedup {trace['speedup']:.1f}x, "
          f"{trace['recompiles']:.0f} recompile(s)")
    print(f"wrote {args.fleet_out}")
    rc = 0
    # PR 3's acceptance bar: batched fleet sweep >= 5x
    if rep["speedup"] < 5.0:
        print("WARNING: fleet speedup below the 5x target", file=sys.stderr)
        rc = 1
    # PR 4's acceptance bar: random-best matched on <= half the evals
    if (not evo["evolve"]["reached_target"]
            or evo["n_evals_savings"] < 2.0):
        print("WARNING: evolve missed the <=half-budget-to-random-best "
              "target", file=sys.stderr)
        rc = 1
    # PR 6's acceptance bars: telemetry within 10%, flat jit cache
    if overhead["overhead"] > 1.10:
        print("WARNING: telemetry overhead above the 1.10x budget",
              file=sys.stderr)
        rc = 1
    if not recomp["stable_after_warmup"]:
        print("WARNING: Evaluator jit cache grew across same-shape "
              "generations (recompile leak)", file=sys.stderr)
        rc = 1
    # PR 7's acceptance bars: engine-native array >= 5x over the object
    # replay, rebuild-storm dispatch shape-stable
    if arr["speedup"] < 5.0:
        print("WARNING: array speedup below the 5x target", file=sys.stderr)
        rc = 1
    if arr["storm"]["recompiles"] != 0:
        print("WARNING: rebuild-storm dispatch recompiled on a repeated "
              "same-shape call", file=sys.stderr)
        rc = 1
    # PR 9's acceptance bars: compiled app traces >= 5x over the per-op
    # legacy replay, dispatch shape-stable across repeats
    if trace["speedup"] < 5.0:
        print("WARNING: trace-compile speedup below the 5x target",
              file=sys.stderr)
        rc = 1
    if trace["recompiles"] != 0:
        print("WARNING: trace replay recompiled on a repeated same-shape "
              "dispatch", file=sys.stderr)
        rc = 1
    return rc


# the paper's summary claims, as floors the artifact must clear
PAPER_DLWA_REDUCTION_FLOOR = 0.80   # paper: 92% at 10% occupancy
PAPER_WEAR_REDUCTION_FLOOR = 0.0    # paper: up to 12% less wear
PAPER_EXEC_SPEEDUP_FLOOR = 1.0      # paper: up to 3.7x faster


def check_paper_gates(artifact: dict) -> int:
    """PR 8's acceptance bars over a ``BENCH_paper.json`` artifact.

    Pure function of the artifact dict (no benchmarking) so the gate
    logic is unit-testable: returns 0 when every gate passes, 1
    otherwise, printing one stderr WARNING per failed gate."""
    rc = 0
    dlwa = artifact["dlwa"]["reduction_at_10pct"]
    if dlwa < PAPER_DLWA_REDUCTION_FLOOR:
        print(f"WARNING: DLWA reduction at 10% occupancy {dlwa:.1%} "
              f"below the {PAPER_DLWA_REDUCTION_FLOOR:.0%} floor",
              file=sys.stderr)
        rc = 1
    wear = artifact["wear"]["wear_reduction"]
    if wear <= PAPER_WEAR_REDUCTION_FLOOR:
        print(f"WARNING: silent policy saved no wear "
              f"(wear reduction {wear:.1%})", file=sys.stderr)
        rc = 1
    speedup = artifact["exec"]["speedup"]
    if speedup <= PAPER_EXEC_SPEEDUP_FLOOR:
        print(f"WARNING: workload execution speedup {speedup:.2f}x "
              f"not above the 1x floor", file=sys.stderr)
        rc = 1
    if artifact["recompiles"]["delta_total"] != 0:
        print("WARNING: paper figures recompiled on a repeated "
              "same-shape dispatch", file=sys.stderr)
        rc = 1
    return rc


def bench_paper(args) -> int:
    from repro.core import headline

    occs = ((0.1, 0.3, 0.7) if args.quick
            else headline.DEFAULT_OCCUPANCIES)
    report = headline.paper_report(
        occupancies=occs,
        wear_zones=4 if args.quick else 8,
        wear_cycles=4 if args.quick else 8,
        exec_cycles=2 if args.quick else 4)
    report["meta"] = _meta(quick=bool(args.quick),
                           occupancies=len(occs))
    if args.sanitize:
        report["sanitize"] = _sanitize_audit()
    args.paper_out.write_text(json.dumps(report, indent=2) + "\n")

    d, w, x = report["dlwa"], report["wear"], report["exec"]
    print(f"paper/dlwa: reduction at 10% occupancy "
          f"{d['reduction_at_10pct']:.1%} "
          f"({d['traditional_dlwa'][0]:.2f} -> {d['silent_dlwa'][0]:.2f};"
          f" paper claims 92%)")
    print(f"paper/wear: {w['traditional_erases']:.0f} -> "
          f"{w['silent_erases']:.0f} block erases "
          f"(-{w['wear_reduction']:.1%})")
    print(f"paper/exec: {x['traditional_s']:.2f}s -> {x['silent_s']:.2f}s "
          f"({x['speedup']:.2f}x); recompiles on repeat "
          f"{report['recompiles']['delta_total']:.0f}")
    print(f"wrote {args.paper_out}")
    return check_paper_gates(report)


def main() -> int:
    # allow_abbrev off: a mistyped/abbreviated flag (e.g. `--skip`)
    # must exit non-zero instead of silently running everything under
    # argparse's prefix guessing
    ap = argparse.ArgumentParser(description=__doc__, allow_abbrev=False)
    ap.add_argument("--out", type=pathlib.Path,
                    default=_ROOT / "BENCH_zoneengine.json")
    ap.add_argument("--fleet-out", type=pathlib.Path,
                    default=_ROOT / "BENCH_fleet.json")
    ap.add_argument("--paper-out", type=pathlib.Path,
                    default=_ROOT / "BENCH_paper.json")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--quick", action="store_true",
                    help="smaller sweeps (CI smoke)")
    ap.add_argument("--sanitize", action="store_true",
                    help="run repro.check's DeviceState sanitizer on the "
                         "warm dispatch states and stamp an end-state "
                         "invariant audit into each artifact (timed "
                         "repeats stay un-sanitized)")
    ap.add_argument("--skip-engine", action="store_true")
    ap.add_argument("--skip-fleet", action="store_true")
    ap.add_argument("--skip-paper", action="store_true")
    args = ap.parse_args()
    if args.skip_engine and args.skip_fleet and args.skip_paper:
        ap.error("--skip-engine, --skip-fleet and --skip-paper together "
                 "leave nothing to benchmark")

    rc = 0
    if not args.skip_engine:
        rc |= bench_engine(args)
    if not args.skip_fleet:
        rc |= bench_fleet(args)
    if not args.skip_paper:
        rc |= bench_paper(args)
    return rc


if __name__ == "__main__":
    sys.exit(main())
