"""Perf-trajectory tracker: legacy per-op loop vs scan-compiled engine.

Times the DLWA occupancy sweep and the interference benchmark through
both execution paths (``LegacyZNSDevice`` Python loop vs the
``repro.core.engine`` vmapped/fused op programs), asserts the metrics
agree, and writes a ``BENCH_zoneengine.json`` artifact so the speedup is
tracked from this PR onward::

    PYTHONPATH=src python tools/bench.py [--out BENCH_zoneengine.json]
                                         [--repeats 3] [--quick]

The artifact schema::

    {"dlwa": {"legacy_ops_s": ..., "engine_ops_s": ..., "speedup": ...},
     "interference": {...},
     "meta": {"device": "zn540/superblock", ...}}
"""

from __future__ import annotations

import argparse
import json
import pathlib
import platform
import sys

_ROOT = pathlib.Path(__file__).resolve().parent.parent
for _p in (str(_ROOT), str(_ROOT / "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

import numpy as np  # noqa: E402

from repro.core import workloads  # noqa: E402


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", type=pathlib.Path,
                    default=_ROOT / "BENCH_zoneengine.json")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--quick", action="store_true",
                    help="smaller sweep (CI smoke)")
    args = ap.parse_args()

    occs = (np.linspace(0.1, 0.9, 5) if args.quick
            else np.linspace(0.05, 0.95, 16))
    concs = (1, 4) if args.quick else (1, 2, 4, 7)
    rep = workloads.engine_vs_legacy_speedup(
        occupancies=tuple(float(o) for o in occs),
        n_zones=4 if args.quick else 8,
        concurrencies=concs,
        repeats=args.repeats)

    artifact = {
        "dlwa": {
            "ops": rep["dlwa_ops"],
            "legacy_s": rep["dlwa_legacy_s"],
            "engine_s": rep["dlwa_engine_s"],
            "legacy_ops_s": rep["dlwa_legacy_ops_s"],
            "engine_ops_s": rep["dlwa_engine_ops_s"],
            "speedup": rep["dlwa_speedup"],
        },
        "interference": {
            "ops": rep["interference_ops"],
            "legacy_s": rep["interference_legacy_s"],
            "engine_s": rep["interference_engine_s"],
            "legacy_ops_s": rep["interference_legacy_ops_s"],
            "engine_ops_s": rep["interference_engine_ops_s"],
            "speedup": rep["interference_speedup"],
        },
        "meta": {
            "device": "zn540/superblock",
            "occupancies": len(occs),
            "concurrencies": list(concs),
            "repeats": args.repeats,
            "python": platform.python_version(),
            "machine": platform.machine(),
        },
    }
    args.out.write_text(json.dumps(artifact, indent=2) + "\n")
    for name in ("dlwa", "interference"):
        row = artifact[name]
        print(f"{name}: legacy {row['legacy_ops_s']:.0f} ops/s, "
              f"engine {row['engine_ops_s']:.0f} ops/s, "
              f"speedup {row['speedup']:.1f}x")
    print(f"wrote {args.out}")
    # the acceptance bar for this PR: scan-compiled dlwa sweep >= 5x
    if artifact["dlwa"]["speedup"] < 5.0:
        print("WARNING: dlwa speedup below the 5x target", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
