#!/usr/bin/env python
"""Repo lint gate: run the :mod:`repro.check.lint` JAX-pitfall rules
(dispatch-in-loop, vmap-over-scan, jit-needs-static, bench-schema)
over ``src/``, ``tools/``, and ``tests/``.

::

    python tools/lint.py            # lint the whole repo, exit 1 if dirty
    python tools/lint.py src/repro/fleet/search.py tools/bench.py

Pure stdlib -- importing the lint rules does not import JAX, so this
runs in CI before any accelerator setup.  Suppress a finding with a
``# lint: ok`` comment on the flagged line.
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path

_ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(_ROOT / "src"))

from repro.check.lint import lint_paths, lint_tree  # noqa: E402


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("paths", nargs="*", type=Path,
                    help="files to lint (default: src/, tools/, tests/)")
    args = ap.parse_args(argv)
    if args.paths:
        findings = lint_paths(_ROOT, [p.resolve() for p in args.paths])
    else:
        findings = lint_tree(_ROOT)
    for f in findings:
        print(f)
    if findings:
        print(f"lint: {len(findings)} finding(s)", file=sys.stderr)
        return 1
    print("lint: clean")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
