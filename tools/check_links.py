"""Markdown link checker for the docs CI job (stdlib only).

Scans the given markdown files (default: README.md, ROADMAP.md,
PAPER.md, docs/*.md) for inline links/images and verifies that every
*relative* target exists in the repo.  External links (http/https/
mailto) and pure in-page anchors are skipped; a ``path#anchor`` target
is checked for the path only.  Exit code 1 with one line per broken
link::

    python tools/check_links.py [FILE.md ...]
"""

from __future__ import annotations

import pathlib
import re
import sys

_ROOT = pathlib.Path(__file__).resolve().parent.parent
# inline [text](target) and ![alt](target); stops at the first ')'
_LINK = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
_SKIP = ("http://", "https://", "mailto:", "#")


def default_files() -> list:
    out = [p for p in (_ROOT / "README.md", _ROOT / "ROADMAP.md",
                       _ROOT / "PAPER.md") if p.exists()]
    docs = _ROOT / "docs"
    if docs.is_dir():
        out += sorted(docs.glob("*.md"))
    return out


def check(path: pathlib.Path) -> list:
    broken = []
    text = path.read_text()
    # drop fenced code blocks -- shell snippets aren't links
    text = re.sub(r"```.*?```", "", text, flags=re.S)
    for m in _LINK.finditer(text):
        target = m.group(1)
        if target.startswith(_SKIP):
            continue
        rel = target.split("#", 1)[0]
        if not rel:
            continue
        if not (path.parent / rel).exists():
            broken.append(f"{path.relative_to(_ROOT)}: broken link "
                          f"-> {target}")
    return broken


def main() -> int:
    files = ([pathlib.Path(a) for a in sys.argv[1:]]
             or default_files())
    broken = []
    for f in files:
        broken += check(f)
    for line in broken:
        print(line, file=sys.stderr)
    print(f"checked {len(files)} files: "
          f"{'OK' if not broken else f'{len(broken)} broken link(s)'}")
    return 1 if broken else 0


if __name__ == "__main__":
    sys.exit(main())
