"""KVBench-II-style LSM traffic generator (paper §6.1).

The paper runs KVBench [44] on RocksDB+ZenFS: 50% inserts, 10% deletes,
15% point queries, 25% updates with 512 B entries.  We model the parts
that generate *storage traffic*, including RocksDB's concurrency, which is
what pressures ZenFS's active-zone budget:

* every mutation batch appends to the WAL (lifetime 0) through a
  persistent file session;
* a full memtable enqueues a *flush job* (L0 SST, lifetime 1) and the WAL
  epoch is truncated when the flush completes;
* a level over its file budget enqueues a *compaction job* that merges it
  into the next level (dropping ``dedup_fraction`` obsolete versions) and
  splits the output into target-size files;
* updates also invalidate old versions resident in deeper levels
  (``update_overlap``), creating garbage inside live files;
* up to ``max_concurrent_jobs`` flush/compaction jobs write concurrently,
  each holding its own zone open (ZenFS: one writer per zone).

Deterministic given the seed; emits traffic into :class:`ZoneFS`.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Callable, Deque, Dict, List, Optional

import numpy as np

from repro.core.backend import set_stream_class
from repro.storage.zonefs import ZoneFS


def kvbench_mix(n_ops: int, seed: int = 0) -> np.ndarray:
    """Op stream: 0=insert, 1=delete, 2=point query, 3=update (paper mix:
    50/10/15/25)."""
    rng = np.random.default_rng(seed)
    return rng.choice(4, size=n_ops, p=[0.50, 0.10, 0.15, 0.25])


@dataclasses.dataclass
class KVBenchConfig:
    n_ops: int = 4_000_000            # paper: 4M total operations
    entry_bytes: int = 512            # paper: 512 B entries
    memtable_entries: int = 131_072   # 64 MiB memtable (RocksDB default)
    size_ratio: int = 4               # level file-count growth factor
    max_levels: int = 4
    seed: int = 0
    dedup_fraction: float = 0.25      # obsolete versions dropped at merge
    update_overlap: float = 0.15      # deep-level bytes invalidated per merge
    max_concurrent_jobs: int = 4      # concurrent flush/compaction writers
    io_chunk_pages: int = 512         # pages a job writes per pump round


@dataclasses.dataclass
class _SST:
    file_id: int
    entries: int
    compacting: bool = False


@dataclasses.dataclass
class _Job:
    kind: str                                   # 'flush' | 'compact'
    outputs: List[tuple]                        # (fid, lifetime, pages, entries)
    out_idx: int = 0
    written_in_cur: int = 0
    on_complete: Optional[Callable[[], None]] = None
    started: bool = False

    def done(self) -> bool:
        return self.out_idx >= len(self.outputs)


class LSMSimulator:
    """Drives a ZoneFS with concurrent LSM-shaped file traffic."""

    def __init__(self, fs: ZoneFS, cfg: KVBenchConfig):
        self.fs = fs
        self.cfg = cfg
        self.levels: List[List[_SST]] = [[] for _ in range(cfg.max_levels)]
        self._next_file = 0
        self._memtable = 0
        self._wal_fid: Optional[int] = None
        self._epoch_wals: List[int] = []
        self.pending: Deque[_Job] = collections.deque()
        self.active: List[_Job] = []
        self.ops_run = 0
        self.failed = False
        self.wal_pages = 0
        self.flush_pages = 0
        self.compact_pages = 0

    # ------------------------------------------------------------------ #
    def _fid(self) -> int:
        self._next_file += 1
        return self._next_file

    def _pages(self, entries: int) -> int:
        page = self.fs.dev.flash.page_bytes
        return max(1, (entries * self.cfg.entry_bytes + page - 1) // page)

    # ------------------------------------------------------------------ #
    # job engine
    # ------------------------------------------------------------------ #
    def _pump(self) -> None:
        """Advance all active jobs by one IO chunk each; start pending
        jobs while slots are free."""
        while (len(self.active) < self.cfg.max_concurrent_jobs
               and self.pending):
            self.active.append(self.pending.popleft())
        still = []
        for job in self.active:
            if not self._step(job):
                self.failed = True
                continue
            if job.done():
                if job.on_complete:
                    job.on_complete()
            else:
                still.append(job)
        self.active = still

    def _step(self, job: _Job) -> bool:
        fid, lifetime, pages, _ = job.outputs[job.out_idx]
        set_stream_class(self.fs.dev, job.kind)
        if job.written_in_cur == 0:
            self.fs.begin(fid, lifetime, expected_pages=pages)
        room = pages - job.written_in_cur
        chunk = min(self.cfg.io_chunk_pages, room)
        if not self.fs.write(fid, chunk):
            self.fs.end(fid)
            return False
        if job.kind == "flush":
            self.flush_pages += chunk
        else:
            self.compact_pages += chunk
        job.written_in_cur += chunk
        if job.written_in_cur >= pages:
            self.fs.end(fid)
            job.out_idx += 1
            job.written_in_cur = 0
        return True

    def _drain(self) -> None:
        guard = 0
        while (self.active or self.pending) and not self.failed:
            self._pump()
            guard += 1
            if guard > 10_000_000:
                raise RuntimeError("LSM job engine wedged")

    # ------------------------------------------------------------------ #
    # LSM logic
    # ------------------------------------------------------------------ #
    def run(self) -> Dict[str, float]:
        cfg = self.cfg
        ops = kvbench_mix(cfg.n_ops, cfg.seed)
        mutations = int((ops != 2).sum())
        wal_batch = max(1, cfg.memtable_entries // 16)
        done = 0
        while done < mutations and not self.failed:
            batch = min(wal_batch, mutations - done)
            done += batch
            if not self._wal_append(batch):
                break
            self._memtable += batch
            if self._memtable >= cfg.memtable_entries:
                self._enqueue_flush()
            self._pump()
            self.ops_run += batch
        self._drain()
        self.fs.sa.sample()
        rep = self.fs.report()
        rep.update({
            "ops_run": float(self.ops_run),
            "wal_pages": float(self.wal_pages),
            "flush_pages": float(self.flush_pages),
            "compact_pages": float(self.compact_pages),
            "failed": float(self.failed),
        })
        return rep

    def _wal_append(self, entries: int) -> bool:
        set_stream_class(self.fs.dev, "wal")
        if self._wal_fid is None:
            self._wal_fid = self._fid()
            self._epoch_wals.append(self._wal_fid)
            self.fs.begin(self._wal_fid, lifetime=0)
        pages = self._pages(entries)
        ok = self.fs.write(self._wal_fid, pages)
        if ok:
            self.wal_pages += pages
        else:
            self.failed = True
        return ok

    def _enqueue_flush(self) -> None:
        entries = self._memtable
        self._memtable = 0
        # seal current WAL epoch
        if self._wal_fid is not None:
            self.fs.end(self._wal_fid)
            self._wal_fid = None
        epoch_wals = list(self._epoch_wals)
        self._epoch_wals = []
        fid = self._fid()
        pages = self._pages(entries)

        def complete() -> None:
            self.levels[0].append(_SST(fid, entries))
            for w in epoch_wals:
                self.fs.delete(w)
            self._maybe_compact(0)

        self.pending.append(_Job("flush", [(fid, 1, pages, entries)],
                                 on_complete=complete))

    def _maybe_compact(self, level: int) -> None:
        cfg = self.cfg
        if level >= cfg.max_levels - 1:
            return
        budget = cfg.size_ratio
        ready = [s for s in self.levels[level] if not s.compacting]
        if len(ready) < budget:
            return
        for s in ready:
            s.compacting = True
        entries = sum(s.entries for s in ready)
        merged = int(entries * (1.0 - cfg.dedup_fraction))
        # one merged output run per compaction (may span zones); deeper
        # levels therefore produce large files that pin their own zones
        outputs = [(self._fid(), 2 + level, self._pages(merged), merged)]

        def complete() -> None:
            self.levels[level] = [s for s in self.levels[level]
                                  if not s.compacting or s not in ready]
            for s in ready:
                if s in self.levels[level]:
                    self.levels[level].remove(s)
                self.fs.delete(s.file_id)
            for (fid, _, _, ents) in outputs:
                self.levels[level + 1].append(_SST(fid, ents))
            # updates invalidate old versions living deeper (garbage
            # pinned inside live files -> SA pressure)
            self._invalidate_deep(level + 1, entries)
            self._maybe_compact(level + 1)

        self.pending.append(_Job("compact", outputs, on_complete=complete))

    def _invalidate_deep(self, level: int, merged_entries: int) -> None:
        cfg = self.cfg
        victims = [s for s in self.levels[level] if not s.compacting]
        if not victims:
            return
        obsolete = int(merged_entries * cfg.update_overlap)
        per = obsolete // len(victims)
        for s in victims:
            cut = min(per, s.entries)
            if cut <= 0:
                continue
            s.entries -= cut
            self.fs.invalidate_partial(s.file_id, self._pages(cut))
