"""Synthetic production traffic generators for the storage front-ends.

The paper's evaluation replays application workloads (KVBench on
RocksDB+ZenFS, §6.1); production zone traffic is neither uniform nor
stationary, so the trace compiler's workload recorders draw their
request streams from the three shapes operators actually see:

* **Zipfian skew** (:func:`zipfian_keys` / :func:`zipfian_tenants`) --
  a small hot set absorbs most accesses (cache traffic, tenant load
  imbalance);
* **diurnal load** (:func:`diurnal_load`) -- a smooth day/night cycle
  scaling the per-step operation budget;
* **burst arrivals** (:func:`burst_arrivals`) -- checkpoint-style
  on/off traffic: quiet baseline punctuated by multiplicative bursts.

Every generator is a pure function of its ``seed`` (deterministic
streams, tested), returns plain numpy arrays, and never touches a
device -- the front-ends in :mod:`repro.storage.flashcache` /
:mod:`repro.storage.compile` turn these streams into zone commands.
"""

from __future__ import annotations

import numpy as np

__all__ = ["zipf_weights", "zipfian_keys", "zipfian_tenants",
           "diurnal_load", "burst_arrivals"]


def zipf_weights(n_keys: int, skew: float) -> np.ndarray:
    """Normalized Zipf(``skew``) probabilities over ranks ``0..n_keys-1``
    (rank 0 hottest).  ``skew = 0`` degenerates to uniform."""
    if n_keys < 1:
        raise ValueError(f"n_keys must be >= 1, got {n_keys}")
    if skew < 0:
        raise ValueError(f"skew must be >= 0, got {skew}")
    w = np.arange(1, n_keys + 1, dtype=np.float64) ** -skew
    return w / w.sum()


def zipfian_keys(n: int, n_keys: int, *, skew: float = 1.1,
                 seed: int = 0) -> np.ndarray:
    """``n`` key ids drawn i.i.d. from Zipf(``skew``) over ``n_keys``
    ranks -- the access stream cache/LSM front-ends consume.  Key id ==
    popularity rank (id 0 hottest), so distribution-shape tests can
    compare empirical frequencies against :func:`zipf_weights`
    directly."""
    rng = np.random.default_rng(seed)
    return rng.choice(n_keys, size=n, p=zipf_weights(n_keys, skew))


def zipfian_tenants(n: int, n_tenants: int, *, skew: float = 1.0,
                    seed: int = 0) -> np.ndarray:
    """Per-request tenant ids under Zipfian tenant load imbalance
    (tenant 0 the heaviest) -- who issues each request of a shared-fleet
    stream."""
    return zipfian_keys(n, n_tenants, skew=skew, seed=seed)


def diurnal_load(n_steps: int, *, base: int, peak: int,
                 period: int = 24, phase: float = 0.0,
                 seed: int | None = None, jitter: float = 0.0
                 ) -> np.ndarray:
    """Per-step operation budgets on a smooth day/night cycle.

    A raised cosine oscillates between ``base`` (trough) and ``peak``
    (crest) with the given ``period`` (steps per day).  ``jitter`` adds
    seeded multiplicative noise (fraction of the local level; requires
    a ``seed``).  Budgets are integer and never below zero."""
    if peak < base:
        raise ValueError(f"peak ({peak}) must be >= base ({base})")
    t = np.arange(n_steps, dtype=np.float64)
    level = base + (peak - base) * 0.5 * (
        1.0 - np.cos(2.0 * np.pi * (t / period + phase)))
    if jitter:
        if seed is None:
            raise ValueError("jitter needs a seed (determinism)")
        rng = np.random.default_rng(seed)
        level = level * (1.0 + jitter * rng.standard_normal(n_steps))
    return np.maximum(np.round(level), 0).astype(np.int64)


def burst_arrivals(n_steps: int, *, rate: int, burst_prob: float = 0.1,
                   burst_len: int = 3, burst_mult: int = 8,
                   seed: int = 0) -> np.ndarray:
    """Per-step arrival counts with checkpoint-style bursts.

    Baseline Poisson(``rate``) arrivals; each step starts a burst with
    probability ``burst_prob``, which multiplies the rate by
    ``burst_mult`` for the next ``burst_len`` steps (overlapping bursts
    extend, not stack).  Deterministic per ``seed``."""
    if not 0.0 <= burst_prob <= 1.0:
        raise ValueError(f"burst_prob must be in [0, 1], got {burst_prob}")
    rng = np.random.default_rng(seed)
    starts = rng.random(n_steps) < burst_prob
    noise = rng.poisson(rate, size=n_steps)
    boost = rng.poisson(rate * (burst_mult - 1), size=n_steps)
    out = np.zeros(n_steps, dtype=np.int64)
    until = -1
    for i in range(n_steps):
        if starts[i]:
            until = i + burst_len - 1
        out[i] = noise[i] + (boost[i] if i <= until else 0)
    return out
