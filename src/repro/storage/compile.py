"""Trace -> op-program compiler: record any ZoneBackend consumer, replay
the whole application run as ONE batched engine dispatch.

The storage front-ends (:class:`repro.storage.zonefs.ZoneFS`, the LSM
simulator, the checkpoint manager, the flash cache) speak the
:class:`repro.core.backend.ZoneBackend` protocol.  Mounting them on a
:class:`RecordingBackend` *records* the zone-command stream instead of
dispatching it per op: the recorder mirrors the device's control plane
exactly (zone states, write pointers, auto-seal, the active-zone
limit, with :class:`repro.core.device.ZNSDevice`'s error strings), so
the front-end takes the same decisions it would on a real device, while
every command lands as one width-5 tenant-tagged op row
(:mod:`repro.fleet.tenants` encoding).  The compiled program then
executes through ``run_programs`` -- per-lane
:class:`~repro.core.engine.DynConfig` (spec / ``alloc_policy`` /
geometry), op-granular :func:`repro.core.timing.simulate_fleet_ops`
timing, and ``repro.obs`` telemetry all ride along
(:func:`replay_recorders`).  Replay through the engine is bit-identical
to driving the legacy per-op path with the same traffic (differential
property suite, ``tests/test_trace_compile.py``).

Stream classes: front-ends announce their traffic class ("wal",
"flush", "compact", "ckpt", "log", "admit", "hit") via
:func:`repro.core.backend.set_stream_class`; a recorder built with
``class_tenants`` maps classes to tenant tags, which is how the
per-tenant-class p99 predictability rollups in
:class:`repro.fleet.runner.FleetResult` attribute latency.

Workloads: :data:`WORKLOADS` names three recorded application mixes --
``lsm`` (KVBench flush/compaction traffic), ``ckpt`` (checkpoint
bursts + log appends on :mod:`repro.storage.traffic` burst arrivals)
and ``cache`` (Zipfian flash-cache admission/eviction).  Importing
this module registers each as a tenant mix in
:data:`repro.fleet.search.MIXES`, so ``fleet_search.py --workload``
scores allocator/geometry configs against realistic application
traffic through the unchanged grid/random/evolve machinery, and
:func:`run_workload` emits the class-tagged dispatch + report that
``BENCH_fleet.json`` and the CI artifact carry.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.check import assert_states, validate_rows
from repro.core import engine as zengine
from repro.core.device import ZoneInfo, ZoneState
from repro.core.engine import DynConfig, ZoneEngine, stack_dyn
from repro.core.geometry import FlashGeometry
from repro.fleet import runner
from repro.fleet.tenants import TENANT_COL, pad_programs
from repro.storage.flashcache import CacheConfig, FlashCache
from repro.storage.lsm import KVBenchConfig, LSMSimulator
from repro.storage.traffic import burst_arrivals, zipfian_keys
from repro.storage.zonefs import ZoneFS

__all__ = [
    "RecordingBackend", "replay_recorders", "lane_state", "lane_metrics",
    "scaled_kv_config", "record_lsm", "CheckpointSchedule",
    "record_checkpoints", "record_cache", "WORKLOADS", "workload_programs",
    "run_workload",
]

#: write-lifetime hints of the checkpoint front-end (mirrors
#: repro.train.checkpoint; duplicated to keep storage free of a train
#: dependency)
LIFETIME_CKPT = 2
LIFETIME_LOG = 0


class RecordingBackend:
    """A :class:`~repro.core.backend.ZoneBackend` that records instead
    of executing.

    Control-plane state (zone state/wp/host_wp, the active-zone count,
    auto-seal at capacity) is tracked in plain Python with the exact
    transition rules -- and error strings -- of
    :class:`repro.core.device.ZNSDevice`, so any front-end mounted on
    the recorder behaves exactly as it would on the real device.  Every
    accepted command appends one width-5 op row; writes to an EMPTY
    zone are preceded by an explicit ``OP_ALLOC`` row (size hint 0),
    mirroring the shim's dispatch order so replay is bit-identical
    under *both* allocation policies.

    ``zone_base`` offsets recorded zone ids (the recorder's window
    ``0..n_zones-1`` lands on device zones ``base..base+n_zones-1``),
    which is how multi-tenant mixes record on disjoint zone ranges.
    ``tenant`` stamps the tag column of every recorded row; with
    ``class_tenants`` the :meth:`set_stream_class` hook switches it per
    traffic class.

    Metrics: ``host_pages`` is exact from the control plane.
    ``dummy_pages`` / ``dlwa`` require executing FINISH padding: on a
    recorder built with :meth:`for_engine` they replay the recorded
    program lazily through that engine (ArrayEngine-style dirty-flag
    caching); a bare recorder reports the control-plane view (0 dummy
    pages, DLWA 1.0 -- recording never executes device-side work), and
    real metrics come from :func:`replay_recorders` /
    :func:`lane_metrics`.
    """

    def __init__(self, flash: FlashGeometry, *, zone_pages: int,
                 n_zones: int, max_active: int = 14, zone_base: int = 0,
                 tenant: int = 0,
                 class_tenants: Optional[Dict[str, int]] = None):
        if zone_pages < 1 or n_zones < 1 or max_active < 1:
            raise ValueError("zone_pages, n_zones and max_active must "
                             "be positive")
        self.flash = flash
        self.max_active = max_active
        self._zone_pages = zone_pages
        self._n_zones = n_zones
        self.zone_base = zone_base
        self.tenant = tenant
        self.class_tenants = class_tenants
        self._zones: Dict[int, ZoneInfo] = {
            z: ZoneInfo() for z in range(n_zones)}
        self._rows: List[Tuple[int, int, int, int, int]] = []
        self._host_pages = 0
        self._n_active = 0
        # lazy-replay attachments (for_engine)
        self._eng: Optional[ZoneEngine] = None
        self._dyn_overrides: Dict = {}
        self._dirty = True
        self._cached: Optional[Tuple] = None

    @classmethod
    def for_engine(cls, eng: ZoneEngine, *, n_zones: Optional[int] = None,
                   max_active: Optional[int] = None, zone_base: int = 0,
                   tenant: int = 0,
                   class_tenants: Optional[Dict[str, int]] = None,
                   **dyn_overrides) -> "RecordingBackend":
        """A recorder whose window and limits come from ``eng`` (after
        ``dyn_overrides`` -- ``zone_pages`` / ``spec`` /
        ``alloc_policy`` / ... as accepted by :meth:`ZoneEngine.dyn`)
        and whose ``dlwa`` / ``dummy_pages`` realize lazily by
        replaying the recorded program through it -- a mountable
        compiled device: ``ZoneFS(RecordingBackend.for_engine(eng))``
        records the whole mount, and ``fs.report()`` is one scan."""
        dyn = eng.dyn(**dyn_overrides)    # validates overrides eagerly
        rec = cls(eng.flash,
                  zone_pages=int(dyn.zone_pages),
                  n_zones=min(int(dyn.n_zones) - zone_base,
                              n_zones or int(dyn.n_zones)),
                  max_active=(max_active if max_active is not None
                              else int(dyn.max_active)),
                  zone_base=zone_base, tenant=tenant,
                  class_tenants=class_tenants)
        rec._eng = eng
        rec._dyn_overrides = dict(dyn_overrides)
        return rec

    # ------------------------------------------------------------------ #
    # ZoneBackend surface
    # ------------------------------------------------------------------ #
    @property
    def zone_pages(self) -> int:
        return self._zone_pages

    @property
    def n_zones(self) -> int:
        return self._n_zones

    @property
    def zones(self) -> Dict[int, ZoneInfo]:
        return self._zones

    @property
    def host_pages(self) -> int:
        return self._host_pages

    @property
    def n_active(self) -> int:
        return self._n_active

    @property
    def dummy_pages(self) -> int:
        if self._eng is None:
            return 0    # recording executes no FINISH padding
        return int(self._realize()["dummy_pages"])

    @property
    def dlwa(self) -> float:
        if self._eng is None:
            return 1.0
        return float(self._realize()["dlwa"])

    def set_stream_class(self, name: str) -> None:
        """Map a front-end traffic class to this recorder's tenant tag
        (no-op for classes the recorder was not built to separate)."""
        if self.class_tenants is not None and name in self.class_tenants:
            self.tenant = self.class_tenants[name]

    # -- commands ------------------------------------------------------- #
    def _emit(self, op: int, zone: int, n_pages: int, flags: int) -> None:
        self._rows.append((op, self.zone_base + zone, n_pages, flags,
                           self.tenant))
        self._dirty = True

    def _info(self, zone_id: int) -> ZoneInfo:
        if not 0 <= zone_id < self._n_zones:
            raise IndexError(f"zone {zone_id} out of range "
                             f"(n_zones={self._n_zones})")
        return self._zones[zone_id]

    def _allocate(self, zone_id: int, info: ZoneInfo) -> None:
        if self._n_active >= self.max_active:
            raise RuntimeError(
                f"open/active zone limit ({self.max_active}) reached")
        # explicit ALLOC row (hint 0): the shim's dispatch order, and
        # what keeps replay policy-agnostic
        self._emit(zengine.OP_ALLOC, zone_id, 0, 0)
        info.state = ZoneState.OPEN
        info.wp = 0
        info.host_wp = 0
        # mapped marker: reads are legal until the next RESET
        info.column_luns = np.empty(0, dtype=np.int64)
        self._n_active += 1

    def zone_write(self, zone_id: int, n_pages: int, *, host: bool = True,
                   trace: bool = False) -> None:
        info = self._info(zone_id)
        if info.state is ZoneState.FULL:
            raise RuntimeError(f"write to FULL zone {zone_id}")
        if info.state is ZoneState.EMPTY:
            self._allocate(zone_id, info)
        if info.wp + n_pages > self._zone_pages:
            raise RuntimeError(
                f"zone {zone_id} overflow: wp={info.wp} + {n_pages} "
                f"> {self._zone_pages}")
        self._emit(zengine.OP_WRITE, zone_id, n_pages,
                   zengine.F_HOST if host else 0)
        info.wp += n_pages
        if host:
            info.host_wp += n_pages
            self._host_pages += n_pages
        if info.wp == self._zone_pages:
            info.state = ZoneState.FULL    # auto-seal, as the engine does
            self._n_active -= 1
        return None    # IO streams are rebuilt at replay time

    def zone_read(self, zone_id: int, pages) -> None:
        info = self._info(zone_id)
        if info.column_luns is None:
            raise RuntimeError(f"read from unmapped zone {zone_id}")
        n = int(pages) if np.isscalar(pages) else len(np.asarray(pages))
        if n > 0:
            self._emit(zengine.OP_READ, zone_id, n, 0)
        return None

    def zone_finish(self, zone_id: int, *, trace: bool = False) -> None:
        info = self._info(zone_id)
        if info.state is ZoneState.FULL:
            return None
        self._emit(zengine.OP_FINISH, zone_id, 0, 0)
        if info.state is ZoneState.OPEN:
            self._n_active -= 1
        info.state = ZoneState.FULL
        return None

    def zone_reset(self, zone_id: int) -> None:
        info = self._info(zone_id)
        self._emit(zengine.OP_RESET, zone_id, 0, 0)
        if info.state is ZoneState.OPEN:
            self._n_active -= 1
        self._zones[zone_id] = ZoneInfo()

    # ------------------------------------------------------------------ #
    # the compiled program
    # ------------------------------------------------------------------ #
    def __len__(self) -> int:
        return len(self._rows)

    def program(self) -> np.ndarray:
        """The recorded command stream as a ``(n_ops, 5)`` tenant-tagged
        op program (the :mod:`repro.fleet.tenants` encoding)."""
        return zengine.encode_program(self._rows, width=TENANT_COL + 1)

    def _realize(self) -> Dict[str, float]:
        if self._eng is None:
            raise RuntimeError(
                "bare RecordingBackend has no dummy_pages/dlwa: attach "
                "an engine with RecordingBackend.for_engine(...) or "
                "replay the program explicitly (replay_recorders)")
        if self._dirty or self._cached is None:
            prog = self.program()
            dyn = (self._eng.dyn(**self._dyn_overrides)
                   if self._dyn_overrides else None)
            state, trace = self._eng.run(self._eng.init_state(), prog, dyn)
            ok = np.asarray(trace.ok)
            real = prog[:, 0] != zengine.OP_NOP
            if (real & ~ok).any():
                i = int(np.argwhere(real & ~ok)[0][0])
                raise AssertionError(
                    f"recorder/engine divergence: replayed op {i} "
                    f"{prog[i].tolist()} illegal")
            self._cached = (self._eng.metrics(state), state, trace)
            self._dirty = False
        return self._cached[0]

    def result(self):
        """(state, trace) of the lazy engine replay (``for_engine``
        recorders only) -- cached until the next recorded command."""
        self._realize()
        return self._cached[1], self._cached[2]


# --------------------------------------------------------------------- #
# batched replay
# --------------------------------------------------------------------- #
def replay_recorders(eng: ZoneEngine,
                     recorders: Sequence[RecordingBackend], *,
                     dyns: Optional[Sequence[DynConfig]] = None,
                     n_tenants: int = 1,
                     parity_tenant: Optional[int] = None,
                     pad_quantum: int = 64, obs=None, profiler=None,
                     check: bool = True,
                     sanitize: bool = False) -> runner.FleetResult:
    """Execute every recorder's compiled program as ONE batched fleet
    dispatch (one lane per recorder).

    ``dyns`` supplies one per-lane :class:`DynConfig` (specs,
    ``alloc_policy``, effective geometry); default lanes run the
    engine's primary config.  ``pad_quantum`` rounds the op axis so
    repeated same-shape replays hit one compiled ``run_programs``
    entry; ``obs`` / ``profiler`` thread ``repro.obs`` telemetry and
    section timers through, exactly as in
    :func:`repro.fleet.runner.run_fleet`.  ``check`` asserts every real
    replayed op was legal -- a recorder/engine divergence fails loudly.
    ``sanitize`` additionally audits every lane's final device state
    with the :mod:`repro.check` sanitizer (host-side numpy; no extra
    compilations).

    Malformed rows (op code outside the IR, negative zone/page counts,
    tenant tags outside the class range) are rejected with a
    ``ValueError`` *before* dispatch: inside the batched scan they
    would not fail, they alias (op/zone clipping) or walk pointers
    backwards -- scan-time garbage with no error at all.
    """
    programs = [np.asarray(r.program(), dtype=np.int32)
                for r in recorders]
    for k, p in enumerate(programs):
        validate_rows(p, n_tenants=n_tenants,
                      parity_tenant=parity_tenant,
                      where=f"recorder {k} program")
    q = max(1, pad_quantum)
    n_ops = -(-max((len(p) for p in programs), default=1) // q) * q
    batch = pad_programs(programs, n_ops=max(n_ops, q))
    dyn = None
    if dyns is not None:
        if len(dyns) != len(recorders):
            raise ValueError(f"{len(dyns)} dyns for {len(recorders)} "
                             f"recorders")
        dyn = stack_dyn(list(dyns))
    res = runner.run_fleet(eng, batch, dyn=dyn, n_tenants=n_tenants,
                           parity_tenant=parity_tenant, obs=obs,
                           profiler=profiler)
    if check:
        runner.assert_all_ok(res)
    if sanitize:
        assert_states(eng.cfg, res.states, dyn, where="replay states")
    return res


def lane_state(res: runner.FleetResult, lane: int):
    """One lane's final :class:`DeviceState` out of the stacked batch."""
    import jax

    return jax.tree_util.tree_map(lambda x: x[lane], res.states)


def lane_metrics(eng: ZoneEngine, res: runner.FleetResult,
                 lane: int) -> Dict[str, float]:
    """``eng.metrics`` of one replay lane (host/dummy/DLWA/erases)."""
    return eng.metrics(lane_state(res, lane))


# --------------------------------------------------------------------- #
# workload recorders (application front-ends -> recorded traffic)
# --------------------------------------------------------------------- #
def scaled_kv_config(zone_pages: int, page_bytes: int, *, seed: int = 0,
                     n_flushes: int = 8, max_jobs: int = 2
                     ) -> KVBenchConfig:
    """A KVBench config scaled to the mounted zone capacity: flushes of
    roughly a sixth of a zone (capped), enough mutations for
    ``n_flushes`` memtable flushes (compactions follow from the size
    ratio) -- milliseconds to record at any geometry."""
    entry = 512
    flush_pages = max(2, min(zone_pages // 6, 4096))
    memtable_entries = max(16, flush_pages * page_bytes // entry)
    mutations = memtable_entries * n_flushes
    return KVBenchConfig(
        n_ops=int(mutations / 0.85) + 16,   # mix is ~85% mutations
        entry_bytes=entry,
        memtable_entries=memtable_entries,
        size_ratio=3,
        max_levels=3,
        seed=seed,
        max_concurrent_jobs=max_jobs,
        io_chunk_pages=max(1, flush_pages // 4),
    )


def _lsm_jobs(dev) -> int:
    """Concurrent LSM jobs a mount can sustain: the WAL session plus
    every job holds an open zone, so stay under both the active-zone
    limit and the zone count (placement needs slack to rotate)."""
    return max(1, min(2, dev.max_active - 1, dev.n_zones - 2))


def record_lsm(dev: RecordingBackend, cfg: Optional[KVBenchConfig] = None,
               *, finish_threshold: float = 0.1, seed: int = 0
               ) -> LSMSimulator:
    """Run the KVBench LSM simulator against ``dev`` (scaled to its
    geometry unless ``cfg`` is given) and return the simulator; with a
    recorder the whole run is now ``dev.program()``."""
    if cfg is None:
        cfg = scaled_kv_config(dev.zone_pages, dev.flash.page_bytes,
                               seed=seed, max_jobs=_lsm_jobs(dev))
    sim = LSMSimulator(ZoneFS(dev, finish_threshold=finish_threshold), cfg)
    sim.run()
    if sim.failed:
        raise RuntimeError(
            "LSM run failed to place a file (window too small for the "
            "config: raise n_zones/max_active or shrink the workload)")
    return sim


@dataclasses.dataclass
class CheckpointSchedule:
    """A checkpoint-burst schedule (what :mod:`repro.train.checkpoint`
    generates, parameterized): every step writes ``shards`` checkpoint
    shard files and a burst of log appends, keeping the last ``keep``
    steps live (older shards/logs are deleted -> RESET churn).  Log
    bursts come from :func:`repro.storage.traffic.burst_arrivals`."""

    n_steps: int = 8
    shards: int = 3
    shard_pages: int = 0      # 0 -> about a third of a zone
    log_pages: int = 1
    log_rate: int = 2         # baseline log appends per step
    burst_prob: float = 0.25
    burst_mult: int = 6
    keep: int = 2
    seed: int = 0


def record_checkpoints(dev: RecordingBackend,
                       sched: Optional[CheckpointSchedule] = None, *,
                       finish_threshold: float = 0.1) -> ZoneFS:
    """Drive a checkpoint/log workload over ``ZoneFS(dev)`` per
    ``sched`` and return the filesystem."""
    from repro.core.backend import set_stream_class

    sched = sched or CheckpointSchedule()
    fs = ZoneFS(dev, finish_threshold=finish_threshold)
    shard_pages = sched.shard_pages or max(1, dev.zone_pages // 3)
    bursts = burst_arrivals(sched.n_steps, rate=sched.log_rate,
                            burst_prob=sched.burst_prob,
                            burst_mult=sched.burst_mult, seed=sched.seed)
    fid = 0
    live: Dict[int, List[int]] = {}
    for step in range(sched.n_steps):
        files: List[int] = []
        set_stream_class(dev, "ckpt")
        for _ in range(sched.shards):
            fid += 1
            fs.create(fid, shard_pages, LIFETIME_CKPT)
            files.append(fid)
        set_stream_class(dev, "log")
        for _ in range(int(bursts[step])):
            fid += 1
            fs.create(fid, sched.log_pages, LIFETIME_LOG)
            files.append(fid)
        live[step] = files
        old = step - sched.keep
        if old in live:
            for f in live.pop(old):
                fs.delete(f)
    return fs


def record_cache(dev: RecordingBackend, *, n_accesses: int = 300,
                 n_keys: int = 48, skew: float = 1.1, seed: int = 0,
                 capacity_zones: Optional[int] = None,
                 obj_pages: Optional[int] = None,
                 admission_misses: int = 1) -> FlashCache:
    """Run a Zipfian flash-cache workload over ``dev`` and return the
    cache (hits -> ``OP_READ`` rows, admissions -> appends, zone
    evictions -> RESETs)."""
    cap_zones = capacity_zones or dev.n_zones
    n_bins = 2 if cap_zones >= 3 else 1
    cache = FlashCache(dev, CacheConfig(
        capacity_zones=cap_zones,
        obj_pages=obj_pages or max(1, dev.zone_pages // 8),
        admission_misses=admission_misses,
        n_bins=min(n_bins, dev.max_active)))
    cache.run(zipfian_keys(n_accesses, n_keys, skew=skew, seed=seed))
    return cache


# --------------------------------------------------------------------- #
# fleet tenant mixes (repro.fleet.search.MIXES entries)
# --------------------------------------------------------------------- #
#: workload name -> tenant-class names (tag column order of
#: run_workload's class-tagged dispatch)
WORKLOADS: Dict[str, Tuple[str, ...]] = {
    "lsm": ("wal", "flush", "compact"),
    "ckpt": ("ckpt", "log"),
    "cache": ("admit", "hit"),
}

#: zones one recorded instance needs (LSM rotates WAL + job sessions
#: through live zones and wedges below 6; ckpt/cache churn in place)
_MIN_WINDOW: Dict[str, int] = {"lsm": 6, "ckpt": 4, "cache": 4}


def _window(name: str, n_zones: int, n_lanes: int) -> int:
    need = _MIN_WINDOW[name]
    if n_zones // n_lanes < need:
        raise ValueError(
            f"workload {name!r} needs a {need}-zone window per instance "
            f"({n_lanes} instances -> >= {need * n_lanes} zones); the "
            f"engine exposes {n_zones}")
    return need


def _drive(name: str, dev: RecordingBackend, instance: int) -> None:
    """Record one tenant instance of a named workload (instances get
    seed-skewed traffic so the two fleet tenants are not clones)."""
    if name == "lsm":
        record_lsm(dev, seed=instance,
                   cfg=scaled_kv_config(
                       dev.zone_pages, dev.flash.page_bytes,
                       seed=instance, n_flushes=8 - 3 * instance,
                       max_jobs=_lsm_jobs(dev)))
    elif name == "ckpt":
        # instance 0: shard-heavy bursts; instance 1: log-dominated
        sched = (CheckpointSchedule(shards=3, log_rate=1, seed=0)
                 if instance == 0 else
                 CheckpointSchedule(shards=1, log_rate=5, burst_prob=0.4,
                                    seed=1))
        record_checkpoints(dev, sched)
    elif name == "cache":
        record_cache(dev, skew=1.3 if instance == 0 else 0.7,
                     seed=instance)
    else:
        raise KeyError(f"unknown workload {name!r} "
                       f"(have: {sorted(WORKLOADS)})")


@functools.lru_cache(maxsize=128)
def _recorded_mix(name: str, cap: int, page_bytes: int, n_zones: int,
                  max_active: int, n_tenants: int
                  ) -> Tuple[np.ndarray, ...]:
    """Record ``n_tenants`` instances of workload ``name`` on disjoint
    zone windows (cached: recording is pure Python and depends only on
    these scalars, and the evaluator rebuilds mixes every dispatch)."""
    window = _window(name, n_zones, n_tenants)
    ma = max_active // n_tenants
    if ma < 2:
        raise ValueError(
            f"workload mix {name!r} needs max_active >= {2 * n_tenants} "
            f"({n_tenants} tenants, >= 2 active zones each); engine has "
            f"{max_active}")
    flash = _mix_flash(page_bytes)
    progs = []
    for t in range(n_tenants):
        dev = RecordingBackend(flash, zone_pages=cap, n_zones=window,
                               max_active=ma, zone_base=t * window)
        _drive(name, dev, t)
        progs.append(dev.program())
    return tuple(progs)


@functools.lru_cache(maxsize=8)
def _mix_flash(page_bytes: int) -> FlashGeometry:
    """A minimal FlashGeometry carrying only what front-ends read off a
    recorder (``page_bytes``); the replay engine supplies the real
    geometry."""
    return FlashGeometry(n_channels=1, ways_per_channel=1,
                         blocks_per_lun=1, pages_per_block=1,
                         page_bytes=page_bytes)


def _workload_mix(name: str) -> Callable:
    def build(eng: ZoneEngine, cap: int) -> List[np.ndarray]:
        from repro.fleet.search import N_TENANTS

        progs = _recorded_mix(name, int(cap), eng.flash.page_bytes,
                              eng.cfg.n_zones, eng.cfg.max_active,
                              N_TENANTS)
        return [p.copy() for p in progs]

    build.__name__ = f"_mix_{name}"
    build.__doc__ = (f"Recorded {name!r} application traffic, one "
                     f"instance per tenant on disjoint zone windows.")
    return build


def _register_mixes() -> None:
    from repro.fleet import search

    for name in WORKLOADS:
        search.MIXES.setdefault(name, _workload_mix(name))


_register_mixes()


# --------------------------------------------------------------------- #
# class-tagged workload dispatch + report
# --------------------------------------------------------------------- #
def workload_programs(eng: ZoneEngine, name: str, *, n_lanes: int = 2,
                      seed: int = 0) -> List[RecordingBackend]:
    """``n_lanes`` recorded instances of workload ``name``, rows tagged
    by *traffic class* (:data:`WORKLOADS` order) rather than by
    instance -- the input of :func:`run_workload`."""
    classes = WORKLOADS[name]
    tags = {c: i for i, c in enumerate(classes)}
    window = _window(name, eng.cfg.n_zones, n_lanes)
    ma = eng.cfg.max_active // n_lanes
    if ma < 2:
        raise ValueError(
            f"workload {name!r} needs max_active >= {2 * n_lanes} for "
            f"{n_lanes} lanes; engine has {eng.cfg.max_active}")
    recs = []
    for lane in range(n_lanes):
        dev = RecordingBackend(eng.flash, zone_pages=eng.cfg.zone_pages,
                               n_zones=window, max_active=ma,
                               zone_base=lane * window,
                               class_tenants=tags)
        _drive(name, dev, (lane + seed) % 2)
        recs.append(dev)
    return recs


def run_workload(eng: ZoneEngine, name: str, *, n_lanes: int = 2,
                 seed: int = 0, pad_quantum: int = 64, obs=None,
                 profiler=None, sanitize: bool = False
                 ) -> Tuple[runner.FleetResult, Dict]:
    """Record workload ``name``, execute it as ONE class-tagged batched
    dispatch, and roll up per-tenant-class p99 predictability.

    Returns ``(FleetResult, report)`` where ``report`` carries one
    entry per traffic class (ops, pages, p50/p99/max latency,
    ``p99_over_p50`` predictability) plus dispatch-level totals -- the
    artifact ``fleet_search.py --workload`` writes and CI uploads.
    Rows are pre-validated and (with ``sanitize=True``) the final
    device states audited, as in :func:`replay_recorders`."""
    classes = WORKLOADS[name]
    recs = workload_programs(eng, name, n_lanes=n_lanes, seed=seed)
    res = replay_recorders(eng, recs, n_tenants=len(classes),
                           pad_quantum=pad_quantum, obs=obs,
                           profiler=profiler, sanitize=sanitize)
    report = {
        "workload": name,
        "n_lanes": float(len(recs)),
        "recorded_ops": float(sum(len(r) for r in recs)),
        "makespan_s": float(res.makespans.max()),
        "host_pages": float(sum(r.host_pages for r in recs)),
        "tenant_classes": res.tenant_class_report(names=classes),
    }
    return res, report
