"""ZenFS-like zoned filesystem (paper §6.1 "RocksDB with ZenFS").

Semantics reproduced from the paper + ZenFS:

* Files carry *write-lifetime hints*; a new file prefers an open zone with
  a matching hint.  A zone admits one concurrent writer at a time (zone
  appends are strictly sequential), so concurrent flush/compaction jobs
  each need their own zone -- this is what pressures the device's
  open/active zone limit.
* When the limit binds, ZenFS picks a FINISH victim whose occupancy is at
  least ``finish_threshold``; if none qualifies, it *relaxes lifetime
  matching* and mixes the file into a zone holding other-lifetime data,
  which delays that zone's reclamation and inflates space amplification
  (paper Fig. 1 / 7b).
* A zone is RESET (reclaimed) as soon as every byte in it is invalid.

SA is tracked per :class:`repro.core.metrics.SATracker`.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from repro.core.backend import ZoneBackend, check_backend
from repro.core.device import ZoneState
from repro.core.metrics import SATracker


@dataclasses.dataclass
class _Extent:
    zone: int
    pages: int
    valid: bool = True


@dataclasses.dataclass
class _File:
    file_id: int
    lifetime: int
    extents: List[_Extent] = dataclasses.field(default_factory=list)
    open: bool = False

    @property
    def pages(self) -> int:
        return sum(e.pages for e in self.extents)


@dataclasses.dataclass
class FSStats:
    host_pages: int = 0
    relaxed_placements: int = 0
    finishes: int = 0
    resets: int = 0
    failed_allocs: int = 0


@dataclasses.dataclass
class _Session:
    file: _File
    zone: Optional[int] = None
    expected_pages: int = 0  # remaining pages the writer still intends to write


class ZoneFS:
    """Lifetime-aware zoned filesystem over any :class:`ZoneBackend`
    (a bare :class:`repro.core.device.ZNSDevice` or a multi-device
    :class:`repro.array.ZNSArray`) with concurrent file sessions."""

    def __init__(self, dev: ZoneBackend, *, finish_threshold: float = 0.1):
        """``finish_threshold`` is expressed as *occupancy*: a victim zone
        may be FINISHed only if wp/capacity >= threshold (paper §6.2)."""
        check_backend(dev)
        self.dev = dev
        self.finish_threshold = finish_threshold
        self.max_open = dev.max_active
        self.files: Dict[int, _File] = {}
        self.sessions: Dict[int, _Session] = {}
        self.zone_lifetime: Dict[int, int] = {}
        self.zone_valid_pages: Dict[int, int] = {}
        self.zone_total_pages: Dict[int, int] = {}
        self.zone_busy: Dict[int, bool] = {}
        self.sa = SATracker()
        self.stats = FSStats()

    # ------------------------------------------------------------------ #
    def _open_zones(self) -> List[int]:
        return [z for z, info in self.dev.zones.items()
                if info.state is ZoneState.OPEN]

    def _free_zones(self) -> List[int]:
        return [z for z, info in self.dev.zones.items()
                if info.state is ZoneState.EMPTY]

    def _zone_room(self, z: int) -> int:
        return self.dev.zone_pages - self.dev.zones[z].wp

    def _fresh_zone(self, lifetime: int) -> Optional[int]:
        free = self._free_zones()
        if not free:
            return None
        z = free[0]
        self.zone_lifetime[z] = lifetime
        return z

    def _finish_victim(self) -> Optional[int]:
        best, best_occ = None, -1.0
        for z in self._open_zones():
            if self.zone_busy.get(z):
                continue
            occ = self.dev.zones[z].wp / self.dev.zone_pages
            if occ >= self.finish_threshold and occ > best_occ:
                best, best_occ = z, occ
        if best is not None:
            self.dev.zone_finish(best)
            self.stats.finishes += 1
            self._maybe_reclaim(best)
        return best

    def _pick_zone(self, lifetime: int, need_pages: int) -> Optional[int]:
        # 1. idle open zone with matching lifetime that fits the whole
        #    file (ZenFS avoids splitting files across zones)
        fit = min(need_pages, self.dev.zone_pages)
        for z in self._open_zones():
            if (not self.zone_busy.get(z)
                    and self.zone_lifetime.get(z) == lifetime
                    and self._zone_room(z) >= fit):
                return z
        # 2. fresh zone if under the active-zone limit
        if len(self._open_zones()) < self.max_open:
            z = self._fresh_zone(lifetime)
            if z is not None:
                return z
        # 3. finish a victim above the occupancy threshold, then reopen
        if self._finish_victim() is not None:
            z = self._fresh_zone(lifetime)
            if z is not None:
                return z
        # 4. relaxed match: any idle open zone with room (lifetime mixing)
        candidates = [z for z in self._open_zones()
                      if not self.zone_busy.get(z) and self._zone_room(z) > 0]
        if candidates:
            z = min(candidates,
                    key=lambda zz: abs(self.zone_lifetime.get(zz, 0)
                                       - lifetime))
            self.stats.relaxed_placements += 1
            return z
        return None

    # ------------------------------------------------------------------ #
    # session API (concurrent writers)
    # ------------------------------------------------------------------ #
    def begin(self, file_id: int, lifetime: int,
              expected_pages: int = 0) -> bool:
        f = _File(file_id, lifetime, open=True)
        self.files[file_id] = f
        self.sessions[file_id] = _Session(f, expected_pages=expected_pages)
        return True

    def write(self, file_id: int, n_pages: int) -> bool:
        """Append ``n_pages`` to an open file, acquiring zones as needed."""
        sess = self.sessions[file_id]
        remaining = n_pages
        while remaining > 0:
            if sess.zone is None or self._zone_room(sess.zone) == 0:
                if sess.zone is not None:
                    self.zone_busy[sess.zone] = False
                need = max(remaining, sess.expected_pages)
                z = self._pick_zone(sess.file.lifetime, need)
                if z is None:
                    self.stats.failed_allocs += 1
                    return False
                sess.zone = z
                self.zone_busy[z] = True
            z = sess.zone
            chunk = min(self._zone_room(z), remaining)
            self.dev.zone_write(z, chunk)
            self.zone_valid_pages[z] = self.zone_valid_pages.get(z, 0) + chunk
            self.zone_total_pages[z] = self.zone_total_pages.get(z, 0) + chunk
            sess.file.extents.append(_Extent(z, chunk))
            remaining -= chunk
            sess.expected_pages = max(0, sess.expected_pages - chunk)
            if self._zone_room(z) == 0:
                self.zone_busy[z] = False  # zone sealed itself (FULL)
        self.stats.host_pages += n_pages
        self.sa.on_host_write(n_pages * self.dev.flash.page_bytes)
        self.sa.sample()
        return True

    def end(self, file_id: int) -> None:
        sess = self.sessions.pop(file_id, None)
        if sess is None:
            return
        if sess.zone is not None:
            z = sess.zone
            self.zone_busy[z] = False
            # proactive FINISH (ZenFS): once a file closes, a zone whose
            # occupancy is already >= the threshold is finished to release
            # controller resources -- this is the paper's Fig. 1 knob:
            # finishing at low occupancy buys SA (fresh zones -> no
            # lifetime mixing) at the price of DLWA (padding).
            info = self.dev.zones[z]
            if (info.state is ZoneState.OPEN
                    and info.wp / self.dev.zone_pages
                    >= self.finish_threshold):
                self.dev.zone_finish(z)
                self.stats.finishes += 1
                self._maybe_reclaim(z)
        sess.file.open = False

    def create(self, file_id: int, n_pages: int, lifetime: int) -> bool:
        """Convenience: begin + write + end in one call."""
        self.begin(file_id, lifetime, expected_pages=n_pages)
        ok = self.write(file_id, n_pages)
        self.end(file_id)
        return ok

    # ------------------------------------------------------------------ #
    def delete(self, file_id: int) -> None:
        """Invalidate a file's extents; reclaim any zone that becomes
        fully invalid."""
        f = self.files.pop(file_id, None)
        if f is None:
            return
        page_bytes = self.dev.flash.page_bytes
        touched = set()
        for e in f.extents:
            if not e.valid:
                continue
            e.valid = False
            self.zone_valid_pages[e.zone] -= e.pages
            self.sa.on_invalidate(e.pages * page_bytes)
            touched.add(e.zone)
        for z in touched:
            self._maybe_reclaim(z)
        self.sa.sample()

    def invalidate_partial(self, file_id: int, n_pages: int) -> None:
        """Logically invalidate part of a live file (obsolete versions
        overwritten by updates); the garbage stays pinned until the whole
        zone is invalid."""
        f = self.files.get(file_id)
        if f is None:
            return
        page_bytes = self.dev.flash.page_bytes
        remaining = n_pages
        touched = set()
        for e in f.extents:
            if remaining <= 0:
                break
            if not e.valid or e.pages == 0:
                continue
            cut = min(e.pages, remaining)
            e.pages -= cut
            self.zone_valid_pages[e.zone] -= cut
            self.sa.on_invalidate(cut * page_bytes)
            remaining -= cut
            touched.add(e.zone)
        for z in touched:
            self._maybe_reclaim(z)
        self.sa.sample()

    def _maybe_reclaim(self, z: int) -> None:
        info = self.dev.zones[z]
        if info.state is ZoneState.EMPTY:
            return
        if self.zone_valid_pages.get(z, 0) > 0:
            return
        if self.zone_busy.get(z):
            return
        if info.state is ZoneState.OPEN and info.wp == 0:
            return
        written = self.zone_total_pages.get(z, 0)
        self.dev.zone_reset(z)
        self.stats.resets += 1
        self.sa.on_reclaim(written * self.dev.flash.page_bytes)
        self.zone_valid_pages.pop(z, None)
        self.zone_total_pages.pop(z, None)
        self.zone_lifetime.pop(z, None)
        self.zone_busy.pop(z, None)

    # ------------------------------------------------------------------ #
    def report(self) -> Dict[str, float]:
        return {
            "dlwa": self.dev.dlwa,
            "sa": self.sa.sa,
            "host_pages": float(self.stats.host_pages),
            "dummy_pages": float(self.dev.dummy_pages),
            "relaxed_placements": float(self.stats.relaxed_placements),
            "finishes": float(self.stats.finishes),
            "resets": float(self.stats.resets),
            "failed_allocs": float(self.stats.failed_allocs),
        }
