"""Zone-granular flash cache on ZNS (arXiv 2410.11260 style).

A flash cache in front of slow storage, mounted directly on any
:class:`repro.core.backend.ZoneBackend`:

* **admission** -- an object is admitted only after
  ``admission_misses`` misses (one-hit-wonders never pollute flash);
* **lifetime-binned placement** -- admitted objects append to the open
  zone of their *hotness bin* (access-frequency bucket), so objects
  with similar expected lifetimes share zones -- the ZNS analogue of
  ZenFS's write-lifetime hints (arXiv 2402.17963), and what makes
  whole-zone eviction cheap;
* **zone-granular eviction** -- when the cache is at its zone budget,
  the least-recently-*accessed* zone is dropped wholesale (its
  residents vanish, the zone is RESET); no page-granular GC exists, so
  cache DLWA stays at the device's own padding overhead.

Hits issue zone reads, admissions issue zone appends, evictions issue
RESETs -- all through the backend protocol, so the same cache runs on a
per-op device, an array, or the trace recorder
(:mod:`repro.storage.compile`), which lowers a whole cache run into one
batched engine dispatch.  Stream classes (``hit`` / ``admit``) are
announced via :func:`repro.core.backend.set_stream_class` so recorded
traffic carries per-class tenant tags.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Set

import numpy as np

from repro.core.backend import ZoneBackend, check_backend, set_stream_class
from repro.core.device import ZoneState

__all__ = ["CacheConfig", "CacheStats", "FlashCache"]


@dataclasses.dataclass
class CacheConfig:
    """Knobs of the zoned cache (zone budget, admission, binning)."""

    capacity_zones: int        # zones the cache may occupy (open + sealed)
    obj_pages: int = 1         # default object size (pages)
    admission_misses: int = 1  # misses before an object is admitted
    hot_hits: int = 3          # accesses per hotness-bin promotion
    n_bins: int = 2            # lifetime bins (0 = coldest)

    def __post_init__(self) -> None:
        if self.capacity_zones < self.n_bins + 1:
            raise ValueError(
                f"capacity_zones ({self.capacity_zones}) must exceed "
                f"n_bins ({self.n_bins}): one open zone per bin plus "
                f"at least one evictable zone")
        if self.admission_misses < 1 or self.hot_hits < 1:
            raise ValueError("admission_misses and hot_hits must be >= 1")


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    admitted: int = 0
    rejected: int = 0        # misses below the admission threshold
    evicted_objects: int = 0
    evicted_zones: int = 0
    read_pages: int = 0
    write_pages: int = 0

    @property
    def hit_rate(self) -> float:
        n = self.hits + self.misses
        return self.hits / n if n else 0.0


@dataclasses.dataclass
class _Resident:
    zone: int
    start: int
    pages: int


class FlashCache:
    """LRU-of-zones flash cache over a :class:`ZoneBackend`."""

    def __init__(self, dev: ZoneBackend, cfg: CacheConfig):
        check_backend(dev)
        if cfg.obj_pages > dev.zone_pages:
            raise ValueError(
                f"obj_pages ({cfg.obj_pages}) exceeds zone capacity "
                f"({dev.zone_pages})")
        if cfg.capacity_zones > dev.n_zones:
            raise ValueError(
                f"capacity_zones ({cfg.capacity_zones}) exceeds the "
                f"device's {dev.n_zones} zones")
        if cfg.n_bins > dev.max_active:
            raise ValueError(
                f"n_bins ({cfg.n_bins}) open zones exceed the device's "
                f"active-zone limit ({dev.max_active})")
        self.dev = dev
        self.cfg = cfg
        self.residents: Dict[int, _Resident] = {}
        self.freq: Dict[int, int] = {}
        self._miss_streak: Dict[int, int] = {}
        self._open: Dict[int, int] = {}          # bin -> open zone
        self._zone_objs: Dict[int, Set[int]] = {}
        self._zone_touch: Dict[int, int] = {}    # zone -> last access clock
        self._clock = 0
        self.stats = CacheStats()

    # ------------------------------------------------------------------ #
    def _owned(self) -> List[int]:
        return sorted(self._zone_objs)

    def _bin_of(self, key: int) -> int:
        return min(self.cfg.n_bins - 1,
                   (self.freq.get(key, 1) - 1) // self.cfg.hot_hits)

    def _zone_room(self, z: int) -> int:
        return self.dev.zone_pages - self.dev.zones[z].wp

    def _evict_one(self) -> None:
        """Drop the least-recently-accessed whole zone (zone-granular
        eviction: no page GC, one RESET)."""
        candidates = [z for z in self._zone_objs
                      if z not in self._open.values()]
        if not candidates:         # every owned zone is an open appendee
            candidates = list(self._zone_objs)
        victim = min(candidates,
                     key=lambda z: (self._zone_touch.get(z, 0), z))
        for key in self._zone_objs.pop(victim):
            self.residents.pop(key, None)
            self.stats.evicted_objects += 1
        self.dev.zone_reset(victim)
        self.stats.evicted_zones += 1
        self._zone_touch.pop(victim, None)
        for b, z in list(self._open.items()):
            if z == victim:
                del self._open[b]

    def _acquire_zone(self, b: int) -> int:
        """An EMPTY zone for bin ``b``, evicting down to budget first."""
        while len(self._zone_objs) >= self.cfg.capacity_zones:
            self._evict_one()
        for z in range(self.dev.n_zones):
            if (self.dev.zones[z].state is ZoneState.EMPTY
                    and z not in self._zone_objs):
                self._open[b] = z
                self._zone_objs[z] = set()
                return z
        # the device has fewer EMPTY zones than our budget assumes
        self._evict_one()
        return self._acquire_zone(b)

    # ------------------------------------------------------------------ #
    def access(self, key: int, pages: Optional[int] = None) -> bool:
        """One object access; returns True on a cache hit."""
        pages = self.cfg.obj_pages if pages is None else int(pages)
        if not 1 <= pages <= self.dev.zone_pages:
            raise ValueError(f"object of {pages} pages does not fit a "
                             f"zone ({self.dev.zone_pages} pages)")
        self._clock += 1
        self.freq[key] = self.freq.get(key, 0) + 1
        res = self.residents.get(key)
        if res is not None:
            set_stream_class(self.dev, "hit")
            self.dev.zone_read(
                res.zone, np.arange(res.start, res.start + res.pages,
                                    dtype=np.int64))
            self._zone_touch[res.zone] = self._clock
            self.stats.hits += 1
            self.stats.read_pages += res.pages
            return True
        self.stats.misses += 1
        streak = self._miss_streak.get(key, 0) + 1
        self._miss_streak[key] = streak
        if streak < self.cfg.admission_misses:
            self.stats.rejected += 1
            return False
        self._miss_streak[key] = 0
        self._admit(key, pages)
        return False

    def _admit(self, key: int, pages: int) -> None:
        b = self._bin_of(key)
        z = self._open.get(b)
        if z is not None and self._zone_room(z) < pages:
            # seal the bin's zone: lifetimes in it are spent together
            set_stream_class(self.dev, "admit")
            self.dev.zone_finish(z)
            del self._open[b]
            z = None
        if z is None:
            z = self._acquire_zone(b)
        start = self.dev.zones[z].wp
        set_stream_class(self.dev, "admit")
        self.dev.zone_write(z, pages)
        self.residents[key] = _Resident(z, start, pages)
        self._zone_objs[z].add(key)
        self._zone_touch[z] = self._clock
        self.stats.admitted += 1
        self.stats.write_pages += pages
        if self.dev.zones[z].state is not ZoneState.OPEN:
            # the append sealed the zone (wp reached capacity)
            self._open.pop(b, None)

    def run(self, keys: np.ndarray) -> CacheStats:
        """Drive a whole access stream (e.g. from
        :func:`repro.storage.traffic.zipfian_keys`)."""
        for k in np.asarray(keys).reshape(-1):
            self.access(int(k))
        return self.stats

    def report(self) -> Dict[str, float]:
        s = self.stats
        return {
            "hit_rate": s.hit_rate,
            "hits": float(s.hits),
            "misses": float(s.misses),
            "admitted": float(s.admitted),
            "rejected": float(s.rejected),
            "evicted_objects": float(s.evicted_objects),
            "evicted_zones": float(s.evicted_zones),
            "read_pages": float(s.read_pages),
            "write_pages": float(s.write_pages),
        }
