"""Zoned storage stack: ZenFS-like filesystem + LSM traffic generator.

This is the host side of the paper: data systems (RocksDB+ZenFS, or this
framework's checkpoint manager) place files with lifetime hints onto zones,
decide when to FINISH (threshold policy), and garbage-collect zones whose
data is fully invalidated.  The SA <-> DLWA trade-off of paper Fig. 1/7b
lives here.

Three newer members lower this host traffic onto the batched engine:
:mod:`repro.storage.traffic` (Zipfian/diurnal/burst request streams),
:mod:`repro.storage.flashcache` (zone-granular flash cache), and
:mod:`repro.storage.compile` (the trace -> op-program compiler: record
any ZoneBackend consumer, replay it as ONE fleet dispatch).
"""

from repro.storage.compile import (CheckpointSchedule, RecordingBackend,
                                   WORKLOADS, lane_metrics, lane_state,
                                   record_cache, record_checkpoints,
                                   record_lsm, replay_recorders,
                                   run_workload, scaled_kv_config,
                                   workload_programs)
from repro.storage.flashcache import CacheConfig, CacheStats, FlashCache
from repro.storage.lsm import KVBenchConfig, LSMSimulator, kvbench_mix
from repro.storage.traffic import (burst_arrivals, diurnal_load,
                                   zipf_weights, zipfian_keys,
                                   zipfian_tenants)
from repro.storage.zonefs import ZoneFS, FSStats

__all__ = ["ZoneFS", "FSStats", "KVBenchConfig", "LSMSimulator",
           "kvbench_mix",
           "CacheConfig", "CacheStats", "FlashCache",
           "burst_arrivals", "diurnal_load", "zipf_weights",
           "zipfian_keys", "zipfian_tenants",
           "CheckpointSchedule", "RecordingBackend", "WORKLOADS",
           "lane_metrics", "lane_state", "record_cache",
           "record_checkpoints", "record_lsm", "replay_recorders",
           "run_workload", "scaled_kv_config", "workload_programs"]
