"""Zoned storage stack: ZenFS-like filesystem + LSM traffic generator.

This is the host side of the paper: data systems (RocksDB+ZenFS, or this
framework's checkpoint manager) place files with lifetime hints onto zones,
decide when to FINISH (threshold policy), and garbage-collect zones whose
data is fully invalidated.  The SA <-> DLWA trade-off of paper Fig. 1/7b
lives here.
"""

from repro.storage.zonefs import ZoneFS, FSStats
from repro.storage.lsm import KVBenchConfig, LSMSimulator, kvbench_mix

__all__ = ["ZoneFS", "FSStats", "KVBenchConfig", "LSMSimulator",
           "kvbench_mix"]
