"""Pallas TPU kernel: chunked selective-state-space scan (Mamba-style).

Recurrence (diagonal A, per-head state, Mamba-1 "S6" form):

    h_t = exp(dt_t * A) * h_{t-1} + (dt_t * x_t) outer B_t
    y_t = <h_t, C_t> + D * x_t

with shapes per head: x (P,), h (P, N), B/C (N,), dt (P,), A (P, N)
(we carry the common diagonal parameterization A (P, N) = -softplus-ish
host-side; the kernel takes it as data).

TPU mapping
-----------
* grid = (batch*heads, n_time_chunks); time chunks are sequential so the
  state h lives in VMEM scratch across chunks -- the classic "carry
  scratch over the sequential grid axis" Pallas pattern.  HBM traffic is
  one pass over x/dt/B/C and one (P, N) state resident in VMEM.
* Inside a chunk the recurrence is a ``fori_loop`` over CT steps of pure
  VPU work (exp, multiply-add) plus rank-1 updates -- no MXU.
* P and N are padded to lane multiples by the caller (128 / 8).

The sub-quadratic decode path (long_500k) uses a single-step variant of
the same math (see ops.single_step).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, d_ref, y_ref, h_scr,
            *, chunk: int):
    ti = pl.program_id(1)

    @pl.when(ti == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    a = a_ref[...]                      # (P, N) f32
    dskip = d_ref[...]                  # (P,)  f32

    def step(t, h):
        x_t = x_ref[0, t].astype(jnp.float32)       # (P,)
        dt_t = dt_ref[0, t].astype(jnp.float32)     # (P,) per-channel step
        b_t = b_ref[0, t].astype(jnp.float32)       # (N,)
        c_t = c_ref[0, t].astype(jnp.float32)       # (N,)
        da = jnp.exp(dt_t[:, None] * a)              # (P, N)
        h = h * da + (dt_t * x_t)[:, None] * b_t[None, :]
        y_t = jnp.sum(h * c_t[None, :], axis=1) + dskip * x_t
        y_ref[0, t] = y_t.astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, step, h_scr[...])
    h_scr[...] = h


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssm_scan_pallas(x: jax.Array, dt: jax.Array, b: jax.Array,
                    c: jax.Array, a: jax.Array, d: jax.Array, *,
                    chunk: int = 256,
                    interpret: bool = False) -> jax.Array:
    """x: (BH, T, P); dt: (BH, T, P); b/c: (BH, T, N); a: (P, N); d: (P,).

    Returns y: (BH, T, P).
    """
    bh, t, p = x.shape
    n = b.shape[-1]
    ct = min(chunk, t)
    if t % ct:
        raise ValueError(f"T {t} % chunk {ct} != 0")
    grid = (bh, t // ct)

    kernel = functools.partial(_kernel, chunk=ct)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, ct, p), lambda i, ti: (i, ti, 0)),
            pl.BlockSpec((1, ct, p), lambda i, ti: (i, ti, 0)),
            pl.BlockSpec((1, ct, n), lambda i, ti: (i, ti, 0)),
            pl.BlockSpec((1, ct, n), lambda i, ti: (i, ti, 0)),
            pl.BlockSpec((p, n), lambda i, ti: (0, 0)),
            pl.BlockSpec((p,), lambda i, ti: (0,)),
        ],
        out_specs=pl.BlockSpec((1, ct, p), lambda i, ti: (i, ti, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, t, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, b, c, a.astype(jnp.float32), d.astype(jnp.float32))
