"""Public selective-scan entry point + single-step decode form."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ssm_scan.ref import ssm_scan_ref
from repro.kernels.ssm_scan.ssm_scan import ssm_scan_pallas


def ssm_scan(x, dt, b, c, a, d, *, impl: str = "ref", chunk: int = 256):
    """x: (BH, T, P); dt: (BH, T, P); b/c: (BH, T, N); a: (P, N); d: (P,)."""
    if impl == "pallas":
        interpret = jax.default_backend() != "tpu"
        return ssm_scan_pallas(x, dt, b, c, a, d, chunk=chunk,
                               interpret=interpret)
    if impl == "ref":
        return ssm_scan_ref(x, dt, b, c, a, d)
    raise ValueError(f"unknown ssm impl: {impl}")


@jax.jit
def single_step(h, x_t, dt_t, b_t, c_t, a, d):
    """One decode step: h (BH, P, N) -> (h', y) -- O(P*N) per token.

    x_t: (BH, P); dt_t: (BH, P); b_t/c_t: (BH, N).
    """
    af = a.astype(jnp.float32)
    da = jnp.exp(dt_t[..., None].astype(jnp.float32) * af[None])
    h = h * da + (dt_t * x_t).astype(jnp.float32)[..., None] \
        * b_t.astype(jnp.float32)[:, None, :]
    y = jnp.sum(h * c_t.astype(jnp.float32)[:, None, :], axis=-1) \
        + d.astype(jnp.float32)[None] * x_t.astype(jnp.float32)
    return h, y.astype(x_t.dtype)
