"""Pure-jnp oracle for the selective scan (lax.scan over time)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssm_scan_ref(x: jax.Array, dt: jax.Array, b: jax.Array, c: jax.Array,
                 a: jax.Array, d: jax.Array, *, chunk: int = 256
                 ) -> jax.Array:
    """Same contract as ssm_scan_pallas; differentiable reference.

    Uses chunked-remat over time so training at long T stores O(T/chunk)
    states instead of O(T) (see layers.chunked_remat_scan).
    """
    from repro.models.layers import chunked_remat_scan
    bh, t, p = x.shape
    n = b.shape[-1]
    af = a.astype(jnp.float32)
    df = d.astype(jnp.float32)

    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp                   # dt_t: (BH, P)
        x_t = x_t.astype(jnp.float32)
        dt_t = dt_t.astype(jnp.float32)
        b_t = b_t.astype(jnp.float32)
        c_t = c_t.astype(jnp.float32)
        da = jnp.exp(dt_t[..., None] * af[None])              # (BH, P, N)
        h = h * da + (dt_t * x_t)[..., None] * b_t[:, None, :]
        y_t = jnp.sum(h * c_t[:, None, :], axis=-1) + df[None] * x_t
        return h, y_t

    h0 = jnp.zeros((bh, p, n), jnp.float32)
    xs = (x.transpose(1, 0, 2), dt.transpose(1, 0, 2),
          b.transpose(1, 0, 2), c.transpose(1, 0, 2))
    _, ys = chunked_remat_scan(step, h0, xs, chunk)
    return ys.transpose(1, 0, 2).astype(x.dtype)
