"""Pallas TPU kernel: GQA decode attention over a long KV cache.

One new token per sequence attends to a KV cache of length S (the
decode_32k / long_500k serving shapes).  This is memory-bound: the kernel
streams the cache HBM->VMEM exactly once in (BS, D) tiles and keeps the
per-head streaming-softmax state (m, l, acc) in VMEM scratch.

* grid = (batch, n_kv_blocks); kv dimension sequential so scratch carries.
* K/V layout (B, S, Hkv, D) -- cache-native (append is a row write).
* GQA without gathers: q is reshaped to (Hkv, G, D) and each kv tile
  (BS, Hkv, D) contracts per kv-head group: scores (Hkv, G, BS) via a
  dot_general batched over Hkv.
* ``length`` masks the tail (cache may be partially filled).

Output: (B, Hq, D).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
            *, scale: float, block_s: int):
    si = pl.program_id(1)
    ns = pl.num_programs(1)

    @pl.when(si == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[0]
    # skip tiles entirely beyond the filled cache
    @pl.when(si * block_s < length)
    def _step():
        q = q_ref[0].astype(jnp.float32)             # (Hkv, G, D)
        k = k_ref[0].astype(jnp.float32)             # (BS, Hkv, D)
        v = v_ref[0].astype(jnp.float32)             # (BS, Hkv, D)
        hkv, g, d = q.shape
        # scores: contract D, batch over Hkv -> (Hkv, G, BS)
        s = jax.lax.dot_general(
            q, k.transpose(1, 2, 0),                  # (Hkv, D, BS)
            (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32) * scale
        pos = si * block_s + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 2)
        s = jnp.where(pos < length, s, NEG_INF)

        m_prev = m_scr[...]                           # (Hkv, G, 1)
        l_prev = l_scr[...]
        m_cur = jnp.max(s, axis=2, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                        # (Hkv, G, BS)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=2, keepdims=True)
        # acc += p @ v : (Hkv, G, BS) x (Hkv, BS, D) -> (Hkv, G, D)
        pv = jax.lax.dot_general(
            p, v.transpose(1, 0, 2),
            (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * alpha + pv
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(si == ns - 1)
    def _finish():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def decode_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                            lengths: jax.Array, *, block_s: int = 512,
                            interpret: bool = False) -> jax.Array:
    """q: (B, Hq, D); k/v: (B, S, Hkv, D); lengths: (B,) valid cache len.

    Returns (B, Hq, D).
    """
    b, hq, d = q.shape
    _, s, hkv, dk = k.shape
    assert dk == d and hq % hkv == 0
    g = hq // hkv
    bs = min(block_s, s)
    if s % bs:
        raise ValueError(f"cache len {s} % block {bs} != 0")
    grid = (b, s // bs)
    scale = 1.0 / (d ** 0.5)
    qg = q.reshape(b, hkv, g, d)

    kernel = functools.partial(_kernel, scale=scale, block_s=bs)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda b_, si: (b_,)),
            pl.BlockSpec((1, hkv, g, d), lambda b_, si: (b_, 0, 0, 0)),
            pl.BlockSpec((1, bs, hkv, d), lambda b_, si: (b_, si, 0, 0)),
            pl.BlockSpec((1, bs, hkv, d), lambda b_, si: (b_, si, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, hkv, g, d), lambda b_, si: (b_, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((hkv, g, 1), jnp.float32),
            pltpu.VMEM((hkv, g, 1), jnp.float32),
            pltpu.VMEM((hkv, g, d), jnp.float32),
        ],
        interpret=interpret,
    )(lengths.astype(jnp.int32), qg, k, v)
    return out.reshape(b, hq, d)
