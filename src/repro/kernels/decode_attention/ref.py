"""Pure-jnp oracle for decode attention."""

from __future__ import annotations

import jax
import jax.numpy as jnp


@jax.jit
def decode_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                         lengths: jax.Array) -> jax.Array:
    """q: (B, Hq, D); k/v: (B, S, Hkv, D); lengths: (B,). -> (B, Hq, D)."""
    b, hq, d = q.shape
    _, s, hkv, _ = k.shape
    g = hq // hkv
    # keep the cache in its storage dtype: contract with f32 accumulation
    # (preferred_element_type) instead of materializing an f32 copy of the
    # whole KV cache (2x HBM) -- §Perf iteration B0
    qg = q.reshape(b, hkv, g, d)
    scale = 1.0 / (d ** 0.5)
    logits = jnp.einsum("bhgd,bshd->bhgs", qg, k,
                        preferred_element_type=jnp.float32) * scale
    mask = jnp.arange(s)[None, :] < lengths[:, None]     # (B, S)
    logits = jnp.where(mask[:, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, hq, d).astype(q.dtype)
