"""Public decode-attention entry point with implementation switch."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.decode_attention import (
    decode_attention_pallas)
from repro.kernels.decode_attention.ref import decode_attention_ref

NEG_INF = -1e30


@functools.partial(jax.jit, static_argnames=("block_s",))
def decode_attention_chunked(q: jax.Array, k: jax.Array, v: jax.Array,
                             lengths: jax.Array, *,
                             block_s: int = 1024) -> jax.Array:
    """Streaming-softmax over kv blocks in plain jnp (XLA-compilable
    everywhere; same math as the kernel)."""
    b, hq, d = q.shape
    _, s, hkv, _ = k.shape
    g = hq // hkv
    bs = min(block_s, s)
    if s % bs:
        raise ValueError(f"cache len {s} % block {bs} != 0")
    ns = s // bs
    qf = q.reshape(b, hkv, g, d).astype(jnp.float32) / (d ** 0.5)
    kf = k.reshape(b, ns, bs, hkv, d).transpose(1, 0, 2, 3, 4)
    vf = v.reshape(b, ns, bs, hkv, d).transpose(1, 0, 2, 3, 4)

    def step(carry, blk):
        m_prev, l_prev, acc = carry
        kb, vb, si = blk
        sblk = jnp.einsum("bhgd,bkhd->bhgk", qf, kb.astype(jnp.float32))
        pos = si * bs + jnp.arange(bs)
        mask = pos[None, :] < lengths[:, None]           # (B, BS)
        sblk = jnp.where(mask[:, None, None], sblk, NEG_INF)
        m_cur = jnp.max(sblk, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(sblk - m_new[..., None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhgk,bkhd->bhgd", p, vb.astype(jnp.float32))
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, hkv, g), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, g), jnp.float32)
    acc0 = jnp.zeros((b, hkv, g, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, acc0),
                                  (kf, vf, jnp.arange(ns)))
    l = jnp.where(l == 0.0, 1.0, l)
    return (acc / l[..., None]).reshape(b, hq, d).astype(q.dtype)


def decode_attention(q, k, v, lengths, *, impl: str = "chunked",
                     block_s: int = 512):
    if impl == "pallas":
        interpret = jax.default_backend() != "tpu"
        return decode_attention_pallas(q, k, v, lengths, block_s=block_s,
                                       interpret=interpret)
    if impl == "chunked":
        return decode_attention_chunked(q, k, v, lengths,
                                        block_s=max(block_s, 1024))
    if impl == "xla":
        return decode_attention_ref(q, k, v, lengths)
    raise ValueError(f"unknown decode attention impl: {impl}")
