"""Pallas TPU kernels for the framework's compute hot-spots.

Each kernel ships <name>.py (pl.pallas_call + BlockSpec VMEM tiling),
ops.py (jit'd wrapper with impl switch: pallas on TPU / interpret on CPU /
jnp fallbacks), and ref.py (pure-jnp oracle used by the allclose sweeps in
tests/test_kernels.py).

  zns_alloc        wear-min per-LUN top-G selection (paper Table 4 hotspot)
  flash_attention  blocked causal GQA attention (train/prefill)
  decode_attention streaming GQA decode over long KV caches
  ssm_scan         chunked selective-state-space scan (Mamba/Jamba)
"""
