"""Jit'd public entry point for the zns_alloc kernel.

Selects the Pallas kernel on TPU, interpret-mode Pallas on CPU (used by
tests and by ``ZNSDevice(alloc_impl='pallas')``), with the jnp reference
always available via ``impl='ref'``.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.zns_alloc.ref import zns_alloc_ref
from repro.kernels.zns_alloc.zns_alloc import zns_alloc_pallas


def _pick_group_block(n_groups: int) -> int:
    for gb in (8, 4, 2, 1):
        if n_groups % gb == 0:
            return gb
    return 1


def zns_alloc(wear2d: jax.Array, avail2d: jax.Array, eligible: jax.Array,
              *, take: int, impl: str = "pallas"
              ) -> Tuple[jax.Array, jax.Array]:
    """Returns (sel bool mask (n_groups, per_group), feasible bool scalar).

    Feasibility = every eligible group has >= take allocatable elements.
    """
    if impl == "ref":
        sel, ok = zns_alloc_ref(wear2d, avail2d, eligible, take=take)
    else:
        interpret = jax.default_backend() != "tpu"
        sel, ok = zns_alloc_pallas(
            wear2d, avail2d, eligible, take=take,
            group_block=_pick_group_block(wear2d.shape[0]),
            interpret=interpret)
    elig = eligible.astype(bool)
    feasible = jnp.all(jnp.where(elig, ok >= take, True))
    return sel.astype(bool), feasible
