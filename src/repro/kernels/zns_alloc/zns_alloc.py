"""Pallas TPU kernel: wear-minimizing per-LUN top-G selection (paper §5).

The SilentZNS allocator solves, per zone allocation, the balanced form of
the ILP (Eqs. 1-6): for each eligible LUN-group, select the ``take``
lowest-wear *available* storage elements.  Table 4 of the paper shows this
selection is the technique's dominant overhead (up to ~9 ms with MOSEK at
block granularity) -- so we make it a kernel.

TPU mapping
-----------
* The device state is a dense ``(n_groups, per_group)`` wear/availability
  matrix (group-major, fixed per-group width -- guaranteed by
  ``repro.core.elements``).  At fleet scale (one allocator instance
  managing the simulated devices of many hosts) this matrix is far larger
  than VMEM, so the grid tiles *rows* (groups): each grid step streams a
  ``(GB, per_group)`` tile HBM->VMEM.
* Top-G selection is done with G rounds of a masked row-argmin -- an
  MXU-free, VPU-bound loop.  ``G = take`` is static, rows are processed
  vector-parallel, and each round updates the selection mask in VMEM.
  This avoids a full sort (O(W log W) and awkward on TPU) in favor of
  O(G * W) vector min-reductions, which wins for the small G (<= 32) the
  paper's geometries produce.
* Availability codes: elements with a in {0, 3} are allocatable (paper
  §5); ineligible rows produce all-zero selections.

Outputs: ``sel`` (int32 0/1 selection mask) and ``ok`` (per-group count of
allocatable elements, so the host can check feasibility: ok >= take).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BIG = 2**30  # python literal: safe to close over in the kernel


def _kernel(wear_ref, avail_ref, elig_ref, sel_ref, ok_ref, *, take: int):
    wear = wear_ref[...]          # (GB, W) int32
    avail = avail_ref[...]        # (GB, W) int32
    elig = elig_ref[...]          # (GB,) int32 (0/1)

    allocatable = (avail == 0) | (avail == 3)
    allocatable &= elig[:, None] != 0
    ok_ref[...] = jnp.sum(allocatable.astype(jnp.int32), axis=1)

    keyed = jnp.where(allocatable, wear, BIG)
    gb, w = keyed.shape
    col = jax.lax.broadcasted_iota(jnp.int32, (gb, w), 1)

    def round_body(_, carry):
        keyed, sel = carry
        # row-wise (min wear, min index) selection; ties -> lowest index
        row_min = jnp.min(keyed, axis=1, keepdims=True)          # (GB, 1)
        is_min = keyed == row_min
        min_idx = jnp.min(jnp.where(is_min, col, w), axis=1,
                          keepdims=True)                          # (GB, 1)
        pick = (col == min_idx) & (row_min < BIG)
        sel = sel | pick
        keyed = jnp.where(pick, BIG, keyed)                       # remove
        return keyed, sel

    sel = jnp.zeros((gb, w), dtype=jnp.bool_)
    _, sel = jax.lax.fori_loop(0, take, round_body, (keyed, sel))
    sel_ref[...] = sel.astype(jnp.int32)


@functools.partial(jax.jit,
                   static_argnames=("take", "group_block", "interpret"))
def zns_alloc_pallas(wear2d: jax.Array, avail2d: jax.Array,
                     eligible: jax.Array, *, take: int,
                     group_block: int = 8,
                     interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """Returns (sel int32 (n_groups, per_group), ok int32 (n_groups,))."""
    n_groups, per_group = wear2d.shape
    gb = min(group_block, n_groups)
    if n_groups % gb:
        raise ValueError(f"n_groups {n_groups} % group_block {gb} != 0")
    grid = (n_groups // gb,)

    kernel = functools.partial(_kernel, take=take)
    sel, ok = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((gb, per_group), lambda g: (g, 0)),
            pl.BlockSpec((gb, per_group), lambda g: (g, 0)),
            pl.BlockSpec((gb,), lambda g: (g,)),
        ],
        out_specs=[
            pl.BlockSpec((gb, per_group), lambda g: (g, 0)),
            pl.BlockSpec((gb,), lambda g: (g,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_groups, per_group), jnp.int32),
            jax.ShapeDtypeStruct((n_groups,), jnp.int32),
        ],
        interpret=interpret,
    )(wear2d.astype(jnp.int32), avail2d.astype(jnp.int32),
      eligible.astype(jnp.int32))
    return sel, ok
