"""Pure-jnp oracle for the zns_alloc kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

BIG = 2**30


@functools.partial(jax.jit, static_argnames=("take",))
def zns_alloc_ref(wear2d: jax.Array, avail2d: jax.Array,
                  eligible: jax.Array, *, take: int
                  ) -> tuple[jax.Array, jax.Array]:
    """Rank-based per-row lowest-wear selection (stable ties by index)."""
    wear2d = wear2d.astype(jnp.int32)
    avail2d = avail2d.astype(jnp.int32)
    allocatable = ((avail2d == 0) | (avail2d == 3))
    allocatable &= (eligible.astype(jnp.int32) != 0)[:, None]
    ok = jnp.sum(allocatable.astype(jnp.int32), axis=1)
    keyed = jnp.where(allocatable, wear2d, BIG)
    order = jnp.argsort(keyed, axis=1, stable=True)
    ranks = jnp.argsort(order, axis=1, stable=True)
    sel = (ranks < take) & allocatable
    return sel.astype(jnp.int32), ok
