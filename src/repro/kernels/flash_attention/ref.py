"""Pure-jnp oracle for flash attention (GQA, optional causal)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("causal",))
def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True) -> jax.Array:
    """q: (B, Hq, S, D); k/v: (B, Hkv, Sk, D); returns (B, Hq, S, D)."""
    b, hq, s, d = q.shape
    _, hkv, sk, _ = k.shape
    q_per_kv = hq // hkv
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    if q_per_kv > 1:
        kf = jnp.repeat(kf, q_per_kv, axis=1)
        vf = jnp.repeat(vf, q_per_kv, axis=1)
    scale = 1.0 / (d ** 0.5)
    logits = jnp.einsum("bhqd,bhkd->bhqk", qf, kf) * scale
    if causal:
        mask = jnp.tril(jnp.ones((s, sk), dtype=bool), k=sk - s)
        logits = jnp.where(mask[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, vf)
    return out.astype(q.dtype)
