"""Public attention entry point with implementation switch.

* ``pallas``  -- the TPU kernel (interpret-mode on CPU; used in tests).
* ``chunked`` -- identical streaming-softmax math written as a
  ``lax.scan`` over kv blocks in plain jnp.  This is what the dry-run and
  the model stack use on CPU: it compiles on every XLA backend, keeps the
  O(S^2) score tensor out of HBM (memory ~ S*BK per head), and reports the
  same FLOPs in cost analysis as the kernel would.
* ``xla``     -- naive full-materialization reference.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention_pallas
from repro.kernels.flash_attention.ref import attention_ref

NEG_INF = -1e30


@functools.partial(jax.jit, static_argnames=("causal", "block_k"))
def attention_chunked(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool = True, block_k: int = 512) -> jax.Array:
    """Streaming-softmax attention as a scan over KV blocks (pure jnp)."""
    b, hq, s, d = q.shape
    _, hkv, sk, _ = k.shape
    q_per_kv = hq // hkv
    bk = min(block_k, sk)
    sk_valid = sk
    if sk % bk:  # pad the kv length and mask the tail (e.g. 1601 patches)
        pad = bk - sk % bk
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        sk = sk + pad
    nk = sk // bk
    scale = 1.0 / (d ** 0.5)

    # (B, Hkv, G, S, D) grouped-query layout; K/V blocks scanned over axis 0
    qf = (q.astype(jnp.float32) * scale).reshape(b, hkv, q_per_kv, s, d)
    kf = k.reshape(b, hkv, nk, bk, d).transpose(2, 0, 1, 3, 4)
    vf = v.reshape(b, hkv, nk, bk, d).transpose(2, 0, 1, 3, 4)
    rows = jnp.arange(s)[:, None] + (sk - s)  # query absolute positions

    def step(carry, blk):
        m_prev, l_prev, acc = carry
        kb, vb, ki = blk                       # (B, Hkv, BK, D)
        kb = kb.astype(jnp.float32)
        vb = vb.astype(jnp.float32)
        sblk = jnp.einsum("bhgqd,bhkd->bhgqk", qf, kb)
        cols = ki * bk + jnp.arange(bk)[None, :]
        if causal:
            mask = (rows >= cols) & (cols < sk_valid)    # (S, BK)
            sblk = jnp.where(mask[None, None, None], sblk, NEG_INF)
        elif sk_valid != sk:
            sblk = jnp.where((cols < sk_valid)[None, None, None],
                             sblk, NEG_INF)
        m_cur = jnp.max(sblk, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(sblk - m_new[..., None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum("bhgqk,bhkd->bhgqd", p, vb)
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, hkv, q_per_kv, s), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, q_per_kv, s), jnp.float32)
    acc0 = jnp.zeros((b, hkv, q_per_kv, s, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, acc0), (kf, vf, jnp.arange(nk)))
    l = jnp.where(l == 0.0, 1.0, l)
    out = acc / l[..., None]
    return out.reshape(b, hq, s, d).astype(q.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q"))
def attention_qchunk(q: jax.Array, k: jax.Array, v: jax.Array, *,
                     causal: bool = True, block_q: int = 512) -> jax.Array:
    """Scan over *query* blocks with full K/V per block, body rematted.

    The kv-chunk scan ('chunked') carries a running softmax -- reverse-mode
    through it stores O(S^2/BK) residuals.  Query blocks are independent,
    so a scan over q blocks saves only its (small) ys, and jax.checkpoint
    on the body recomputes the (BQ, S) score tile in backward: training
    attention memory drops to O(S * BQ) transient per device.  This is the
    training-path impl; 'chunked' remains for (gradient-free) prefill.
    """
    b, hq, s, d = q.shape
    _, hkv, sk, _ = k.shape
    g = hq // hkv
    bq = min(block_q, s)
    if s % bq:
        raise ValueError(f"seq {s} % block_q {bq} != 0")
    nq = s // bq
    scale = 1.0 / (d ** 0.5)
    qf = (q.astype(jnp.float32) * scale).reshape(b, hkv, g, nq, bq, d)
    qf = qf.transpose(3, 0, 1, 2, 4, 5)              # (nq, B, Hkv, G, BQ, D)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    offset = sk - s                                   # query absolute offset

    @jax.checkpoint
    def body(_, blk):
        qb, qi = blk                                  # (B, Hkv, G, BQ, D)
        sblk = jnp.einsum("bhgqd,bhkd->bhgqk", qb, kf)
        if causal:
            rows = offset + qi * bq + jnp.arange(bq)[:, None]
            cols = jnp.arange(sk)[None, :]
            sblk = jnp.where((rows >= cols)[None, None, None], sblk,
                             NEG_INF)
        p = jax.nn.softmax(sblk, axis=-1)
        ob = jnp.einsum("bhgqk,bhkd->bhgqd", p, vf)
        return None, ob

    _, ys = jax.lax.scan(body, None, (qf, jnp.arange(nq)))
    out = ys.transpose(1, 2, 3, 0, 4, 5).reshape(b, hq, s, d)
    return out.astype(q.dtype)


def attention(q, k, v, *, causal: bool = True, impl: str = "chunked",
              block_q: int = 128, block_k: int = 128):
    if impl == "qchunk":
        return attention_qchunk(q, k, v, causal=causal,
                                block_q=max(block_q, 512))
    if impl == "pallas":
        interpret = jax.default_backend() != "tpu"
        return flash_attention_pallas(q, k, v, causal=causal,
                                      block_q=block_q, block_k=block_k,
                                      interpret=interpret)
    if impl == "chunked":
        return attention_chunked(q, k, v, causal=causal,
                                 block_k=max(block_k, 512))
    if impl == "xla":
        return attention_ref(q, k, v, causal=causal)
    raise ValueError(f"unknown attention impl: {impl}")
