"""Pallas TPU kernel: blocked causal flash-attention forward (GQA-aware).

Canonical FlashAttention-2 style streaming softmax, adapted to the TPU
memory hierarchy:

* grid = (batch, q_heads, n_q_blocks, n_kv_blocks); the innermost (kv)
  dimension is sequential ("arbitrary") so the VMEM scratch accumulators
  (running max m, normalizer l, and the output accumulator) persist across
  kv steps -- HBM traffic is exactly one pass over K/V per q block.
* BlockSpecs tile Q (BQ, D) and K/V (BK, D) with D the full head dim
  (<= 128, one MXU lane tile); BQ/BK default to 128 to keep the two
  matmuls MXU-shaped (128x128x128).
* GQA: the K/V BlockSpec index_map folds the q-head -> kv-head mapping
  (h // q_per_kv), so grouped heads reuse the same K/V tiles without a
  gather.
* Causality: kv blocks strictly above the diagonal are skipped via
  @pl.when (no compute, no write); the diagonal block applies the
  triangular mask.

Numerics follow the reference: logits scaled by 1/sqrt(D), accumulation
in f32, output cast to the input dtype.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
            *, scale: float, causal: bool, block_q: int, block_k: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # causal: skip kv blocks entirely above the diagonal
    if causal:
        run = ki * block_k <= qi * block_q + block_q - 1
    else:
        run = ki >= 0  # always true (traced)

    @pl.when(run)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)           # (BQ, D)
        k = k_ref[0, 0].astype(jnp.float32)           # (BK, D)
        v = v_ref[0, 0].astype(jnp.float32)           # (BK, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (BQ, BK)
        if causal:
            rows = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, s.shape, 0)
            cols = ki * block_k + jax.lax.broadcasted_iota(
                jnp.int32, s.shape, 1)
            s = jnp.where(rows >= cols, s, NEG_INF)

        m_prev = m_scr[...]                           # (BQ, 1)
        l_prev = l_scr[...]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                        # (BQ, BK)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)
        acc = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new
        l_scr[...] = l_new
        acc_scr[...] = acc

    @pl.when(ki == nk - 1)
    def _finish():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)               # fully-masked rows
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret"))
def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, block_q: int = 128,
                           block_k: int = 128,
                           interpret: bool = False) -> jax.Array:
    """q: (B, Hq, S, D); k/v: (B, Hkv, S, D) with Hq % Hkv == 0."""
    b, hq, s, d = q.shape
    _, hkv, sk, dk = k.shape
    assert dk == d and hq % hkv == 0
    q_per_kv = hq // hkv
    bq = min(block_q, s)
    bk = min(block_k, sk)
    if s % bq or sk % bk:
        raise ValueError(f"seq {s}/{sk} not divisible by blocks {bq}/{bk}")
    grid = (b, hq, s // bq, sk // bk)
    scale = 1.0 / (d ** 0.5)

    kernel = functools.partial(_kernel, scale=scale, causal=causal,
                               block_q=bq, block_k=bk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h, qi, ki: (b_, h, qi, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h, qi, ki: (b_, h // q_per_kv, ki, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h, qi, ki: (b_, h // q_per_kv, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d),
                               lambda b_, h, qi, ki: (b_, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, s, d), q.dtype),
        # running max / normalizer / output accumulator persist in VMEM
        # across the sequential kv grid dimension
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
