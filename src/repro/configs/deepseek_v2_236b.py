"""DeepSeek-V2 236B [arXiv:2405.04434; hf].

MoE decoder with multi-head latent attention (MLA): 60L, d_model 5120,
128 heads, kv_lora 512, q_lora 1536, rope/nope head dims 64/128; FFN:
layer 0 dense (d_ff 12288), layers 1.. MoE with 160 routed experts
(d_ff 1536, top-6) + 2 shared experts.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,
    d_ff=1536,
    dense_d_ff=12288,
    vocab=102400,
    n_experts=160,
    top_k=6,
    n_shared_experts=2,
    moe_d_ff=1536,
    moe_every=1,
    first_layer_dense=True,
    route_groups=16,     # device-limited routing (DeepSeek-V2 §: M=3)
    route_limit=3,
    int8_dispatch=True,  # beyond-paper: V3-style quantized dispatch

    mla=True,
    kv_lora=512,
    q_lora=1536,
    rope_head_dim=64,
    nope_head_dim=128,
    v_head_dim=128,
    head_dim=192,   # nope + rope
    source="arXiv:2405.04434",
))
