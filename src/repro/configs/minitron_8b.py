"""Minitron-8B (pruned Nemotron) [arXiv:2407.14679; hf].

Dense decoder: 32L, d_model 4096, 32 heads (GQA kv=8), d_ff 16384,
vocab 256000.  Pruned-Nemotron: squared-ReLU MLP in the original; we keep
the assignment's d_ff and use gelu MLP (2-matrix) to match its non-gated
FFN.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="minitron-8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=16384,
    vocab=256000,
    act="gelu",
    source="arXiv:2407.14679",
))
