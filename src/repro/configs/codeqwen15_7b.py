"""CodeQwen1.5-7B [hf:Qwen/CodeQwen1.5-7B; hf].

Dense decoder: 32L, d_model 4096, 32 heads (GQA kv=32 -> MHA), d_ff 13440,
vocab 92416.  RoPE + SwiGLU + RMSNorm (Qwen1.5 architecture).
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="codeqwen1.5-7b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=13440,
    vocab=92416,
    rope_theta=1_000_000.0,
    source="hf:Qwen/CodeQwen1.5-7B",
))
