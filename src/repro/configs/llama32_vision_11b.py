"""Llama-3.2-11B-Vision [hf:meta-llama/Llama-3.2-11B-Vision; unverified].

VLM: 40-layer text decoder, d_model 4096, 32 heads (GQA kv=8), d_ff 14336,
vocab 128256, with cross-attention image layers every 5th layer.  The
vision frontend is a STUB: input_specs() provides precomputed patch
embeddings (B, 1601, d_model)-shaped memory the cross-attn layers attend
to.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=128256,
    pattern=("attn", "attn", "attn", "attn", "cross"),
    cross_every=5,
    frontend_tokens=1601,   # 1 tile x (40x40+1) patches
    rope_theta=500_000.0,
    source="hf:meta-llama/Llama-3.2-11B-Vision",
))
