"""Jamba-1.5-Large 398B [arXiv:2403.19887; hf].

Hybrid: 72 layers, d_model 8192, 64 heads (GQA kv=8), d_ff 24576; Mamba :
attention 7:1 interleave; MoE (16 experts, top-2) every other layer.
Sub-quadratic in the Mamba layers; the 9 attention layers hold KV caches
(sequence-sharded for long_500k).
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab=65536,
    pattern=("mamba", "mamba", "mamba", "attn",
             "mamba", "mamba", "mamba", "mamba"),
    n_experts=16,
    top_k=2,
    moe_every=2,
    moe_offset=1,
    moe_d_ff=24576,
    ssm_state=16,
    sub_quadratic=True,
    source="arXiv:2403.19887",
))
