"""ArchConfig dataclass + registry + the four assigned input-shape cells.

Every assigned architecture registers itself by importing its module (see
``repro.configs.all_archs``); ``--arch <id>`` resolves through
:func:`get_arch`.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Optional, Tuple

_REGISTRY: Dict[str, "ArchConfig"] = {}


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | vlm | ssm | hybrid | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    dense_d_ff: int = 0         # FFN hidden for non-MoE layers (0 -> d_ff)
    head_dim: int = 0           # 0 -> d_model // n_heads

    # layer pattern: tuple of block kinds, tiled to n_layers.
    # kinds: 'attn', 'mamba', 'mlstm', 'slstm', 'cross' (self+cross pair)
    pattern: Tuple[str, ...] = ("attn",)

    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0           # per-expert hidden (0 -> d_ff)
    moe_every: int = 1          # MoE on layers where (i % moe_every)==offset
    moe_offset: int = 0
    first_layer_dense: bool = False      # deepseek-v2: layer 0 dense
    capacity_factor: float = 1.25
    route_groups: int = 0       # device-limited routing: expert groups
    route_limit: int = 0        # ... max groups (devices) per token (M)
    int8_dispatch: bool = False  # quantize the dispatch a2a payload

    # MLA (deepseek-v2)
    mla: bool = False
    kv_lora: int = 0
    q_lora: int = 0
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128

    # SSM / recurrent
    ssm_state: int = 16
    ssm_conv: int = 4
    ssm_expand: int = 2

    # encoder-decoder (audio) / VLM
    encoder_layers: int = 0
    cross_every: int = 0        # vlm: a cross-attn layer every k layers
    frontend_tokens: int = 0    # stub modality tokens (image patches/frames)

    # misc
    rope_theta: float = 10000.0
    norm: str = "rmsnorm"       # rmsnorm | layernorm
    act: str = "swiglu"         # swiglu | gelu
    tie_embeddings: bool = True
    sub_quadratic: bool = False  # supports the long_500k decode cell
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Megatron-style vocab padding: embedding tables are padded to a
        multiple of 256 so the vocab dim shards over any mesh axis and the
        unembed matmul stays MXU-aligned; padded logits are masked."""
        return -(-self.vocab // 256) * 256

    def layer_kinds(self) -> Tuple[str, ...]:
        reps = -(-self.n_layers // len(self.pattern))
        return (self.pattern * reps)[: self.n_layers]

    def is_moe_layer(self, i: int) -> bool:
        if not self.n_experts:
            return False
        if self.first_layer_dense and i == 0:
            return False
        return i % self.moe_every == self.moe_offset

    # ------------------------------------------------------------------ #
    def reduced(self) -> "ArchConfig":
        """Family-preserving smoke-test config: tiny widths, few layers."""
        pat_len = len(self.pattern)
        n_layers = max(pat_len, 2 if pat_len == 1 else pat_len)
        return dataclasses.replace(
            self,
            n_layers=n_layers,
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 4) or 2,
            head_dim=16,
            d_ff=128,
            vocab=512,
            n_experts=min(self.n_experts, 4),
            top_k=min(self.top_k, 2),
            route_groups=2 if self.route_groups else 0,
            route_limit=1 if self.route_limit else 0,
            n_shared_experts=min(self.n_shared_experts, 1),
            moe_d_ff=64 if self.moe_d_ff else 0,
            # generous capacity so tiny smoke batches never drop tokens
            # (capacity drops are shape-dependent and break prefill/train
            # logit-consistency checks)
            capacity_factor=4.0,
            kv_lora=32 if self.kv_lora else 0,
            q_lora=32 if self.q_lora else 0,
            rope_head_dim=8 if self.mla else 64,
            nope_head_dim=16 if self.mla else 128,
            v_head_dim=16 if self.mla else 128,
            ssm_state=8,
            encoder_layers=2 if self.encoder_layers else 0,
            frontend_tokens=16 if self.frontend_tokens else 0,
        )


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


#: the assigned input-shape set (same four cells for every LM arch)
SHAPES: Dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}

ARCH_MODULES = (
    "codeqwen15_7b", "phi3_mini_38b", "minitron_8b", "granite3_8b",
    "llama4_scout_17b_a16e", "deepseek_v2_236b", "llama32_vision_11b",
    "xlstm_125m", "jamba15_large_398b", "seamless_m4t_medium",
)


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def _load_all() -> None:
    for m in ARCH_MODULES:
        importlib.import_module(f"repro.configs.{m}")


def get_arch(name: str) -> ArchConfig:
    if name not in _REGISTRY:
        _load_all()
    return _REGISTRY[name]


def list_archs() -> Tuple[str, ...]:
    _load_all()
    return tuple(sorted(_REGISTRY))


def get_shape(name: str) -> ShapeCell:
    return SHAPES[name]


def applicable_cells() -> Tuple[Tuple[str, str], ...]:
    """All (arch, shape) dry-run cells.  long_500k only runs for
    sub-quadratic architectures (see DESIGN.md §Arch-applicability)."""
    cells = []
    for a in list_archs():
        cfg = get_arch(a)
        for s in SHAPES:
            if s == "long_500k" and not cfg.sub_quadratic:
                continue
            cells.append((a, s))
    return tuple(cells)
