"""SeamlessM4T-medium [arXiv:2308.11596; hf].

Encoder-decoder, multimodal: 12+12 layers, d_model 1024, 16 heads,
d_ff 4096, vocab 256206.  The speech/text frontend is a STUB:
input_specs() provides precomputed frame embeddings for the encoder; the
decoder cross-attends to encoder memory.  LayerNorm + GELU (Transformer
classic / NLLB lineage).
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="seamless-m4t-medium",
    family="audio",
    n_layers=12,           # decoder layers
    encoder_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab=256206,
    pattern=("cross",),    # every decoder layer: self-attn + cross-attn
    frontend_tokens=1024,  # speech frames after frontend (per 4k cell /4)
    norm="layernorm",
    act="gelu",
    source="arXiv:2308.11596",
))
