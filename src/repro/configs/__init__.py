"""Architecture configs: one module per assigned architecture."""

from repro.configs.base import (ArchConfig, ShapeCell, get_arch, get_shape,
                                list_archs, register, SHAPES, applicable_cells)

__all__ = ["ArchConfig", "ShapeCell", "get_arch", "get_shape", "list_archs",
           "register", "SHAPES", "applicable_cells"]
