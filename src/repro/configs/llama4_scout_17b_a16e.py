"""Llama-4-Scout-17B-16E [hf:meta-llama/Llama-4-Scout-17B-16E; unverified].

MoE decoder: 48L, d_model 5120, 40 heads (GQA kv=8), expert d_ff 8192,
vocab 202048; 16 experts, top-1 routing + 1 shared expert (early-fusion
multimodal in the original; text backbone here).
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab=202048,
    n_experts=16,
    top_k=1,
    n_shared_experts=1,
    moe_d_ff=8192,
    moe_every=1,
    rope_theta=500_000.0,
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
))
