"""xLSTM-125m [arXiv:2405.04517; unverified].

Recurrent LM: 12 blocks, d_model 768, 4 heads; mLSTM:sLSTM 3:1 interleave
(paper's mixed configuration), no FFN (d_ff=0 -> the mLSTM block carries
its own up/down projection).  Sub-quadratic: runs the long_500k cell.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    pattern=("mlstm", "mlstm", "mlstm", "slstm"),
    sub_quadratic=True,
    norm="layernorm",
    act="gelu",
    source="arXiv:2405.04517",
))
