"""Phi-3-mini 3.8B [arXiv:2404.14219; unverified].

Dense decoder: 32L, d_model 3072, 32 heads (kv=32), d_ff 8192, vocab 32064.
RoPE + SwiGLU + GQA (here kv=32 = MHA per the assignment sheet).
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="phi3-mini-3.8b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=32064,
    source="arXiv:2404.14219",
))
