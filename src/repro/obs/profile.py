"""Dispatch-level profiling: compile-phase split + recompile counting.

Two independent instruments, both cheap enough to leave on:

* :class:`CompileLog` -- a process-global accumulator of the
  ``jax.monitoring`` compile-phase duration events
  (jaxpr tracing, MLIR lowering, backend compilation).  A
  :class:`Profiler` section snapshots it around a region of host code,
  which splits the region's wall time into trace/lower/compile vs
  everything else (execute + host work) *without* AOT plumbing -- a
  warm dispatch shows zero compile seconds, a shape miss shows exactly
  where the time went.
* :class:`RecompileCounter` -- reads the jit caches of the functions it
  watches (``fn._cache_size()``, keyed on abstract input signatures:
  shapes/dtypes + static args).  A stable count across repeated
  dispatches proves shape stability (the property
  ``Evaluator.pad_quantum`` exists to buy); a growing count is the
  recompile leak the ROADMAP's interference regression turned out to
  be (see ``workloads.interference_sweep_engine``).

Both degrade gracefully: if the monitoring hook or the private cache
accessor disappears in a future jax, sections still report wall time
and counters report ``-1`` rather than raising.
"""

from __future__ import annotations

import contextlib
import copy
import time
from typing import Callable, Dict, Optional

import jax

#: jax.monitoring event -> the compile phase it times
_EVENT_KEYS = {
    "/jax/core/compile/jaxpr_trace_duration": "trace_s",
    "/jax/core/compile/jaxpr_to_mlir_module_duration": "lower_s",
    "/jax/core/compile/backend_compile_duration": "compile_s",
}
_PHASES = ("trace_s", "lower_s", "compile_s")


class CompileLog:
    """Accumulates jax compile-phase durations via ``jax.monitoring``.

    One process-global instance (:data:`COMPILE_LOG`) is installed at
    import; sections diff its :meth:`snapshot` around regions.  The
    listener registration is append-only in jax, so exactly one install
    per log instance."""

    def __init__(self) -> None:
        self.totals: Dict[str, float] = {k: 0.0 for k in _PHASES}
        self.counts: Dict[str, int] = {k: 0 for k in _PHASES}
        self.installed = False

    def _listen(self, event: str, duration: float, **kw) -> None:
        key = _EVENT_KEYS.get(event)
        if key is not None:
            self.totals[key] += float(duration)
            self.counts[key] += 1

    def install(self) -> "CompileLog":
        if not self.installed:
            try:
                jax.monitoring.register_event_duration_secs_listener(
                    self._listen)
                self.installed = True
            except Exception:       # monitoring API moved: stay inert
                pass
        return self

    def snapshot(self) -> Dict[str, Dict]:
        return {"totals": dict(self.totals), "counts": dict(self.counts)}


#: the process-global compile log every Profiler defaults to
COMPILE_LOG = CompileLog().install()


class Profiler:
    """Named per-section counters with a compile/execute wall split.

    ``with prof.section("fleet.engine"): ...`` accumulates, per name:
    ``calls``, ``wall_s``, the compile-phase seconds that elapsed
    inside (``trace_s``/``lower_s``/``compile_s`` from the
    :class:`CompileLog`), ``n_compiles`` (backend compilations
    triggered), and ``execute_s`` (wall minus compile phases -- device
    execution plus host-side work).  Sections nest; compile time then
    shows up in every enclosing section, which is the truthful reading
    (it *did* elapse there)."""

    def __init__(self, compile_log: Optional[CompileLog] = None) -> None:
        self.sections: Dict[str, Dict[str, float]] = {}
        self._log = compile_log if compile_log is not None else COMPILE_LOG

    @contextlib.contextmanager
    def section(self, name: str):
        before = self._log.snapshot()
        t0 = time.perf_counter()
        try:
            yield self
        finally:
            wall = time.perf_counter() - t0
            after = self._log.snapshot()
            d = self.sections.setdefault(name, {
                "calls": 0.0, "wall_s": 0.0, "trace_s": 0.0,
                "lower_s": 0.0, "compile_s": 0.0, "execute_s": 0.0,
                "n_compiles": 0.0})
            d["calls"] += 1.0
            d["wall_s"] += wall
            in_compile = 0.0
            for k in _PHASES:
                dt = after["totals"][k] - before["totals"][k]
                d[k] += dt
                in_compile += dt
            d["n_compiles"] += (after["counts"]["compile_s"]
                                - before["counts"]["compile_s"])
            d["execute_s"] += max(0.0, wall - in_compile)

    def snapshot(self) -> Dict[str, Dict[str, float]]:
        """JSON-ready copy of all section counters."""
        return copy.deepcopy(self.sections)


def jit_cache_size(fn) -> int:
    """Entries in a jitted function's compile cache (one per abstract
    input signature seen), or -1 if the accessor is unavailable."""
    try:
        return int(fn._cache_size())
    except Exception:
        return -1


class RecompileCounter:
    """Watches the jit caches of named functions.

    ``RecompileCounter(run_programs=engine.run_programs).counts()``
    returns ``{name: cache entries}``; :meth:`delta` diffs two readings
    (positive = that many new abstract signatures were compiled in
    between).  Counts are process-global per function, so *stability*
    across repeated calls, not the absolute value, is the signal."""

    def __init__(self, **fns: Callable) -> None:
        if not fns:
            raise ValueError("name at least one function to watch")
        self._fns = dict(fns)

    @classmethod
    def engine_default(cls) -> "RecompileCounter":
        """The engine + fleet-timing dispatch surface."""
        from repro.core import engine, timing
        return cls(apply_op=engine.apply_op,
                   run_program=engine.run_program,
                   run_programs=engine.run_programs,
                   simulate_fleet_ops=timing.simulate_fleet_ops)

    def counts(self) -> Dict[str, int]:
        return {n: jit_cache_size(f) for n, f in self._fns.items()}

    def delta(self, before: Dict[str, int]) -> Dict[str, int]:
        return {n: c - before.get(n, 0)
                for n, c in self.counts().items()}


def profile_dispatch(fn: Callable, *args,
                     profiler: Optional[Profiler] = None,
                     name: Optional[str] = None, **kwargs):
    """Call ``fn`` under a profiler section, blocking on its outputs so
    the section's wall time covers device execution.  Returns
    ``(result, section counters)``; pass ``profiler`` to accumulate
    into an existing one."""
    prof = profiler if profiler is not None else Profiler()
    label = name or getattr(fn, "__name__", "dispatch")
    with prof.section(label):
        out = fn(*args, **kwargs)
        jax.block_until_ready(out)
    return out, prof.sections[label]
