"""Chrome/Perfetto ``trace_event`` export + metrics-registry sidecar.

A fleet dispatch already computes everything a trace viewer needs: the
op-granular completion times and latencies of
:func:`repro.core.timing.simulate_fleet_ops` plus the per-op page
deltas of the :class:`OpTrace`.  :func:`fleet_trace_events` maps them
onto the Chrome ``trace_event`` JSON the Perfetto UI
(https://ui.perfetto.dev) loads directly:

* process (pid)  = fleet *lane* (one emulated member device);
* thread  (tid)  = *tenant class* (real tenants + the parity tag), so
  each tenant is its own named track;
* ``X`` duration events = executed zone ops, ``ts``/``dur`` in
  microseconds on the simulated clock (service time
  ``ceil(pages / P) * t_page``; closed-loop latency incl. queueing in
  ``args``);
* ``C`` counter events = cumulative host/superfluous pages per lane
  (the DLWA numerator/denominator as a live counter track).

:func:`validate_trace` checks an exported object against the
checked-in JSON schema (``docs/schema/perfetto_trace.schema.json``)
with a dependency-free subset validator -- and with the real
``jsonschema`` package too when it is importable (CI installs it; the
container may not have it).

:class:`MetricsRegistry` is the sidecar: monotonically accumulating
counters + last-value gauges, serialized next to the trace so a run's
scalars travel with its timeline (:func:`emit_fleet_obs` writes both).
"""

from __future__ import annotations

import json
import pathlib
from typing import Dict, List, Optional, Sequence

import numpy as np

#: opcode names (index = repro.core.engine opcode)
OP_NAMES = ("NOP", "ALLOC", "WRITE", "FINISH", "RESET", "READ")

_SCHEMA_PATH = (pathlib.Path(__file__).resolve().parents[3]
                / "docs" / "schema" / "perfetto_trace.schema.json")


# --------------------------------------------------------------------- #
# trace_event generation
# --------------------------------------------------------------------- #
def _tenant_label(t: int, res) -> str:
    return "parity" if t == res.parity_tenant else f"tenant {t}"


def fleet_trace_events(res, eng, *,
                       lane_labels: Optional[Sequence[str]] = None,
                       counters: bool = True) -> List[dict]:
    """``FleetResult`` -> Chrome ``trace_event`` list.

    ``lane_labels`` names the process tracks (default
    ``lane L``; a ``build_fleet_batch`` caller passes
    ``f"{config}/dev{d}"``).  Zero-page ops (FINISH of an exactly-full
    zone, RESET, illegal rejects) are emitted as zero-duration events
    at their completion time so legality problems stay visible on the
    timeline.
    """
    t_page = float(eng.flash.t_prog + eng.flash.t_xfer)
    par = int(eng.cfg.parallelism)
    programs = np.asarray(res.programs)
    pages = np.asarray(res.pages)
    done = np.asarray(res.completions, dtype=np.float64)
    lat = np.asarray(res.latencies, dtype=np.float64)
    ok = np.asarray(res.ok)
    host = np.asarray(res.host_delta, dtype=np.int64)
    dummy = np.asarray(res.dummy_delta, dtype=np.int64)
    n_lanes, n_ops = programs.shape[0], programs.shape[1]
    n_classes = res.parity_tenant + 1

    events: List[dict] = []
    for lane in range(n_lanes):
        label = (lane_labels[lane] if lane_labels is not None
                 else f"lane {lane}")
        events.append({"ph": "M", "name": "process_name", "pid": lane,
                       "args": {"name": label}})
        for t in range(n_classes):
            events.append({"ph": "M", "name": "thread_name",
                           "pid": lane, "tid": t,
                           "args": {"name": _tenant_label(t, res)}})
        cum_h = 0
        cum_d = 0
        for i in range(n_ops):
            op = int(programs[lane, i, 0])
            if op == 0:                       # NOP padding: invisible
                continue
            pg = int(pages[lane, i])
            dur = (-(-pg // par)) * t_page if pg > 0 else 0.0
            ts = done[lane, i] - dur
            tenant = int(programs[lane, i, -1]) if \
                programs.shape[2] > 4 else 0
            name = (OP_NAMES[op] if op < len(OP_NAMES)
                    else f"OP{op}") + f" z{int(programs[lane, i, 1])}"
            events.append({
                "ph": "X", "name": name, "cat": "zns_op",
                "pid": lane, "tid": min(tenant, n_classes - 1),
                "ts": round(ts * 1e6, 3),
                "dur": round(dur * 1e6, 3),
                "args": {
                    "zone": int(programs[lane, i, 1]),
                    "pages": pg,
                    "host_pages": int(host[lane, i]),
                    "dummy_pages": int(dummy[lane, i]),
                    "ok": bool(ok[lane, i]),
                    "latency_us": round(float(lat[lane, i]) * 1e6, 3),
                }})
            if counters and (host[lane, i] or dummy[lane, i]):
                cum_h += int(host[lane, i])
                cum_d += int(dummy[lane, i])
                events.append({
                    "ph": "C", "name": "pages", "pid": lane,
                    "ts": round(done[lane, i] * 1e6, 3),
                    "args": {"host": cum_h, "superfluous": cum_d}})
    return events


def write_trace(path, events: List[dict],
                meta: Optional[dict] = None) -> dict:
    """Wrap events in the JSON-object trace format, write, and return
    the object (Perfetto/chrome://tracing load the file as-is)."""
    obj = {"traceEvents": events, "displayTimeUnit": "ms",
           "otherData": dict(meta or {})}
    pathlib.Path(path).write_text(json.dumps(obj, indent=1) + "\n")
    return obj


# --------------------------------------------------------------------- #
# schema validation (stdlib subset + real jsonschema when importable)
# --------------------------------------------------------------------- #
def load_trace_schema(path=None) -> dict:
    """The checked-in trace_event schema (docs/schema/)."""
    return json.loads(pathlib.Path(path or _SCHEMA_PATH).read_text())


_TYPES = {"object": dict, "array": list, "string": str,
          "boolean": bool, "integer": int, "number": (int, float)}


def _check(obj, schema: dict, where: str) -> None:
    t = schema.get("type")
    if t is not None:
        want = _TYPES[t]
        if not isinstance(obj, want) or (t in ("integer", "number")
                                         and isinstance(obj, bool)):
            raise ValueError(f"{where}: expected {t}, "
                             f"got {type(obj).__name__}")
    if "enum" in schema and obj not in schema["enum"]:
        raise ValueError(f"{where}: {obj!r} not in {schema['enum']}")
    if isinstance(obj, dict):
        for req in schema.get("required", ()):
            if req not in obj:
                raise ValueError(f"{where}: missing required key "
                                 f"{req!r}")
        for key, sub in schema.get("properties", {}).items():
            if key in obj:
                _check(obj[key], sub, f"{where}.{key}")
    if isinstance(obj, list) and "items" in schema:
        for i, item in enumerate(obj):
            _check(item, schema["items"], f"{where}[{i}]")


def validate_trace(obj: dict, schema: Optional[dict] = None) -> None:
    """Raise ``ValueError`` unless ``obj`` conforms to the trace
    schema.  Always runs the dependency-free subset validator; also
    runs the full ``jsonschema`` validator when the package exists."""
    schema = schema or load_trace_schema()
    _check(obj, schema, "$")
    try:
        import jsonschema
    except ImportError:
        return
    try:
        jsonschema.validate(obj, schema)
    except jsonschema.ValidationError as exc:
        raise ValueError(f"jsonschema: {exc.message}") from exc


# --------------------------------------------------------------------- #
# metrics registry sidecar
# --------------------------------------------------------------------- #
class MetricsRegistry:
    """Counters (monotonic sums) + gauges (last value), JSON-ready."""

    def __init__(self) -> None:
        self._counters: Dict[str, float] = {}
        self._gauges: Dict[str, float] = {}

    def counter(self, name: str, inc: float = 1.0) -> None:
        self._counters[name] = self._counters.get(name, 0.0) + float(inc)

    def gauge(self, name: str, value: float) -> None:
        self._gauges[name] = float(value)

    def as_dict(self) -> Dict[str, Dict[str, float]]:
        return {"counters": dict(self._counters),
                "gauges": dict(self._gauges)}


def fleet_metrics(res, eng) -> MetricsRegistry:
    """The standard fleet scalars as a registry: page counters split by
    class, legality counts, DLWA / p99 / makespan gauges."""
    reg = MetricsRegistry()
    t = np.asarray(res.tenants)
    h = np.asarray(res.host_delta, dtype=np.int64)
    par = int(h[t == res.parity_tenant].sum())
    host = int(h.sum()) - par
    dummy = int(np.asarray(res.dummy_delta, dtype=np.int64).sum())
    reg.counter("host_pages", host)
    reg.counter("parity_pages", par)
    reg.counter("superfluous_pages", dummy)
    reg.counter("block_erases",
                int(np.asarray(res.erase_delta, dtype=np.int64).sum()))
    real = np.asarray(res.programs)[:, :, 0] != 0
    okc = int((real & np.asarray(res.ok)).sum())
    reg.counter("ops_ok", okc)
    reg.counter("ops_illegal", int(real.sum()) - okc)
    reg.gauge("dlwa", (host + par + dummy) / host if host else 1.0)
    lanes = np.arange(res.programs.shape[0])
    for k, v in res.tenant_p99_latency(lanes).items():
        reg.gauge(f"tenant{k}_p99_latency_s", v)
    reg.gauge("makespan_s", float(np.asarray(res.makespans).max()))
    return reg


def emit_fleet_obs(res, eng, *, obs, out_prefix,
                   lane_labels: Optional[Sequence[str]] = None,
                   profiler=None, recompiles=None,
                   meta: Optional[dict] = None) -> dict:
    """Write the two artifacts of one observed fleet dispatch.

    * ``<out_prefix>_trace.json`` -- the Perfetto trace (validated
      against the checked-in schema before returning);
    * ``<out_prefix>_obs.json``   -- telemetry timelines (per lane +
      per tenant + pooled), the metrics registry, and (when given) the
      profiler sections and recompile-counter readings.

    ``res`` must come from ``run_fleet(..., obs=obs)`` so it carries
    the telemetry stack.  Returns ``{"trace": path, "obs": path,
    "n_events": int}``.
    """
    from repro.obs import recorder

    if res.telemetry is None:
        raise ValueError("FleetResult has no telemetry; run the fleet "
                         "with obs=ObsConfig(...)")
    events = fleet_trace_events(res, eng, lane_labels=lane_labels)
    trace_path = f"{out_prefix}_trace.json"
    validate_trace(write_trace(trace_path, events, meta=meta))

    lanes = recorder.fleet_timelines(obs, res.telemetry)
    obs_obj = {
        "schema_version": 1,
        "meta": dict(meta or {}),
        "n_tenants": int(res.n_tenants),
        "parity_tenant": int(res.parity_tenant),
        "lane_labels": (list(lane_labels) if lane_labels is not None
                        else [f"lane {i}" for i in range(len(lanes))]),
        "metrics": fleet_metrics(res, eng).as_dict(),
        "timelines": {
            "lanes": lanes,
            "tenants": recorder.tenant_timelines(obs, res.telemetry),
            "fleet": recorder.device_rollup(lanes),
        },
        "profile": profiler.snapshot() if profiler is not None else {},
        "jit_cache": (recompiles.counts()
                      if recompiles is not None else {}),
    }
    obs_path = f"{out_prefix}_obs.json"
    pathlib.Path(obs_path).write_text(
        json.dumps(obs_obj, indent=1) + "\n")
    return {"trace": trace_path, "obs": obs_path,
            "n_events": len(events)}
