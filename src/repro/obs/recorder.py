"""Pure-JAX telemetry accumulator carried through the op-program scan.

The engine's ``run_program(s)`` already emits a per-op :class:`OpTrace`;
what it cannot answer cheaply is "*when* did the superfluous writes /
wear / occupancy happen" for long programs without hauling the whole
trace to the host and re-aggregating.  :class:`TelemetryState` is a
fixed-size pytree of time-bucketed histograms updated inside the scan:
op ``i`` of an ``n_ops``-row program lands in bucket
``i * n_buckets // n_ops``, so the telemetry shape is independent of
program length and rides the batch axis of ``run_programs`` for free
(one ``(L, n_buckets, ...)`` stack per fleet dispatch).

Opt-in and effect-free: ``run_program(s)`` take an optional static
:class:`ObsConfig`; without it nothing changes, with it the scan carry
grows the telemetry pytree and the return gains a third element.  The
recorder only *reads* the device state -- telemetry-on and
telemetry-off runs produce bit-identical ``DeviceState`` / ``OpTrace``
(integer state machine, property-tested in ``tests/test_obs.py``).

Decoding is host-side and pandas-free: plain dicts of Python lists
(JSON-ready), per lane (:func:`lane_timeline`), per fleet lane stack
(:func:`fleet_timelines`), per tenant (:func:`tenant_timelines`), per
zone (:func:`zone_timelines`, rebuilt from the materialized
``OpTrace`` because per-zone in-scan histograms would scale with
``n_zones``), and pooled per device (:func:`device_rollup`).

Units: page counters count flash pages; ``wear_max`` counts erase-block
erasures; buckets index program progress (op order), not wall time --
the op program *is* the device's request clock.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

#: column a width-5 fleet op row stores its tenant tag in (kept in sync
#: with repro.fleet.tenants.TENANT_COL; obs depends only on repro.core)
_TENANT_COL = 4

#: opcodes (mirrors repro.core.engine to avoid an import cycle with the
#: engine's lazy recorder import)
_OP_NOP, _OP_FINISH = 0, 3


@dataclasses.dataclass(frozen=True)
class ObsConfig:
    """Static (hashable) recorder configuration.

    ``n_buckets`` fixes the telemetry resolution (histogram length);
    ``n_tenants`` sizes the per-tenant axes -- pass the number of
    tenant *classes* including the parity tag (``N_TENANTS + 1`` for a
    fleet batch; tags outside ``[0, n_tenants)`` clip into the last
    class).  Width-4 programs have no tenant column and bin everything
    into class 0.
    """

    n_buckets: int = 32
    n_tenants: int = 1

    def __post_init__(self) -> None:
        if self.n_buckets < 1 or self.n_tenants < 1:
            raise ValueError(
                f"n_buckets and n_tenants must be >= 1, got "
                f"{self.n_buckets}, {self.n_tenants}")


class TelemetryState(NamedTuple):
    """Time-bucketed per-lane histograms (all int32, ``B = n_buckets``,
    ``T = n_tenants``).  Sums unless marked gauge."""

    step: jax.Array         # () op index within the program
    host: jax.Array         # (B,) host pages written
    dummy: jax.Array        # (B,) superfluous (FINISH-pad / dummy) pages
    erases: jax.Array       # (B,) block erasures
    allocs: jax.Array       # (B,) allocator invocations
    ok_ops: jax.Array       # (B,) legal executed (non-NOP) ops
    illegal_ops: jax.Array  # (B,) illegal (rejected) ops
    active_max: jax.Array   # (B,) gauge: max open zones in the bucket
    wear_max: jax.Array     # (B,) gauge: max wear among touched elements
    tenant_host: jax.Array   # (B, T) host pages per tenant class
    tenant_dummy: jax.Array  # (B, T) dummy pages per tenant class


def telemetry_init(obs: ObsConfig) -> TelemetryState:
    """Zeroed accumulator for one program scan."""
    b, t = obs.n_buckets, obs.n_tenants
    z = jnp.zeros(b, jnp.int32)
    return TelemetryState(
        step=jnp.zeros((), jnp.int32),
        host=z, dummy=z, erases=z, allocs=z, ok_ops=z, illegal_ops=z,
        active_max=z, wear_max=z,
        tenant_host=jnp.zeros((b, t), jnp.int32),
        tenant_dummy=jnp.zeros((b, t), jnp.int32),
    )


def telemetry_update(obs: ObsConfig, tel: TelemetryState,
                     before, after, trace, row: jax.Array,
                     n_ops: int) -> TelemetryState:
    """Fold one op into the histograms (traced inside the scan body).

    ``before`` / ``after`` are the :class:`DeviceState` around the op,
    ``trace`` its :class:`OpTrace`, ``row`` the raw op row (tenant tag
    read from column 4 when present).  NOP padding is excluded from the
    op-legality counters but its (zero) page deltas are folded anyway.
    """
    b = jnp.minimum(tel.step * obs.n_buckets // n_ops, obs.n_buckets - 1)
    real = row[0] != _OP_NOP
    ok_i = (real & trace.ok).astype(jnp.int32)
    bad_i = real.astype(jnp.int32) - ok_i
    # max wear among the elements the op's zone maps after the op: a
    # cheap O(n_slots) gather that tracks the wear frontier without an
    # O(n_elements) reduction per op
    elems = trace.elems
    valid = elems >= 0
    wear = after.elem_wear[jnp.where(valid, elems, 0)]
    wear_touched = jnp.max(jnp.where(valid, wear, 0)).astype(jnp.int32)
    if row.shape[0] > _TENANT_COL:
        tenant = jnp.clip(row[_TENANT_COL], 0, obs.n_tenants - 1)
    else:
        tenant = jnp.zeros((), jnp.int32)
    return TelemetryState(
        step=tel.step + 1,
        host=tel.host.at[b].add(trace.host_delta),
        dummy=tel.dummy.at[b].add(trace.dummy_delta),
        erases=tel.erases.at[b].add(trace.erase_delta),
        allocs=tel.allocs.at[b].add(after.alloc_calls
                                    - before.alloc_calls),
        ok_ops=tel.ok_ops.at[b].add(ok_i),
        illegal_ops=tel.illegal_ops.at[b].add(bad_i),
        active_max=tel.active_max.at[b].max(after.n_active),
        wear_max=tel.wear_max.at[b].max(wear_touched),
        tenant_host=tel.tenant_host.at[b, tenant].add(trace.host_delta),
        tenant_dummy=tel.tenant_dummy.at[b, tenant].add(
            trace.dummy_delta),
    )


# --------------------------------------------------------------------- #
# host-side decoding (plain dicts of lists, JSON-ready)
# --------------------------------------------------------------------- #
_SUM_KEYS = ("host", "dummy", "erases", "allocs", "ok_ops",
             "illegal_ops")
_GAUGE_KEYS = ("active_max", "wear_max")


def _np(tel: TelemetryState) -> Dict[str, np.ndarray]:
    return {k: np.asarray(getattr(tel, k))
            for k in _SUM_KEYS + _GAUGE_KEYS
            + ("tenant_host", "tenant_dummy")}


def lane_timeline(obs: ObsConfig, tel: TelemetryState,
                  lane: Optional[int] = None) -> Dict[str, list]:
    """One lane's histograms as a timeline dict.

    ``lane`` selects a row of a batched (``run_programs``) telemetry
    stack; ``None`` decodes an unbatched (``run_program``) one.  Adds
    ``dlwa``: the *cumulative* device-level write amplification up to
    each bucket boundary -- (host + dummy) pages per host page, the
    paper's DLWA as a function of program progress (1.0 before any host
    page lands).
    """
    arrs = _np(tel)
    if lane is not None:
        arrs = {k: v[lane] for k, v in arrs.items()}
    if arrs["host"].ndim != 1:
        raise ValueError("batched telemetry needs an explicit lane "
                         "(leaves have a leading lane axis)")
    out: Dict[str, list] = {k: arrs[k].astype(np.int64).tolist()
                            for k in _SUM_KEYS + _GAUGE_KEYS}
    ch = np.cumsum(arrs["host"].astype(np.int64))
    cd = np.cumsum(arrs["dummy"].astype(np.int64))
    out["dlwa"] = [float((h + d) / h) if h else 1.0
                   for h, d in zip(ch, cd)]
    out["tenant_host"] = arrs["tenant_host"].astype(np.int64).tolist()
    out["tenant_dummy"] = arrs["tenant_dummy"].astype(np.int64).tolist()
    out["n_buckets"] = int(obs.n_buckets)
    out["n_tenants"] = int(obs.n_tenants)
    return out


def fleet_timelines(obs: ObsConfig, tel: TelemetryState
                    ) -> List[Dict[str, list]]:
    """Per-lane timelines of a batched telemetry stack (lane order is
    the dispatch's lane order: config-major, device-minor for a
    ``build_fleet_batch`` batch)."""
    n_lanes = int(np.asarray(tel.host).shape[0])
    return [lane_timeline(obs, tel, lane) for lane in range(n_lanes)]


def tenant_timelines(obs: ObsConfig, tel: TelemetryState
                     ) -> Dict[int, Dict[str, list]]:
    """Per-tenant-class host/dummy page timelines pooled over all lanes
    of a batched telemetry stack (class ``n_tenants - 1`` also absorbs
    clipped out-of-range tags, e.g. the parity tag when the recorder
    was sized without it)."""
    th = np.asarray(tel.tenant_host, dtype=np.int64)
    td = np.asarray(tel.tenant_dummy, dtype=np.int64)
    if th.ndim == 3:                      # (L, B, T) -> (B, T)
        th, td = th.sum(axis=0), td.sum(axis=0)
    out = {}
    for t in range(obs.n_tenants):
        out[t] = {"host": th[:, t].tolist(), "dummy": td[:, t].tolist()}
    return out


def device_rollup(timelines: List[Dict[str, list]]) -> Dict[str, list]:
    """Pool per-lane timelines into one device/fleet-level timeline
    (sums summed, gauges maxed, DLWA recomputed from the pooled
    cumulative sums)."""
    if not timelines:
        return {}
    n = len(timelines[0]["host"])
    out: Dict[str, list] = {}
    for k in _SUM_KEYS:
        out[k] = [sum(tl[k][i] for tl in timelines) for i in range(n)]
    for k in _GAUGE_KEYS:
        out[k] = [max(tl[k][i] for tl in timelines) for i in range(n)]
    ch = np.cumsum(out["host"])
    cd = np.cumsum(out["dummy"])
    out["dlwa"] = [float((h + d) / h) if h else 1.0
                   for h, d in zip(ch, cd)]
    out["n_buckets"] = n
    return out


def zone_timelines(program: np.ndarray, trace,
                   n_buckets: int) -> Dict[int, Dict[str, list]]:
    """Per-zone timelines rebuilt host-side from one lane's materialized
    :class:`OpTrace` (per-zone in-scan histograms would cost
    ``O(n_zones)`` arrays in the carry; the trace already holds the
    per-op zone, so post-hoc binning is free).

    Returns ``{zone: {host, dummy, erases, wp}}`` for every zone the
    program touched; ``wp`` is a gauge (the zone's write pointer after
    the bucket's last op on it, carried forward across empty buckets).
    """
    program = np.asarray(program)
    n_ops = len(program)
    zone = np.asarray(trace.zone)
    host = np.asarray(trace.host_delta, dtype=np.int64)
    dummy = np.asarray(trace.dummy_delta, dtype=np.int64)
    erases = np.asarray(trace.erase_delta, dtype=np.int64)
    wp = np.asarray(trace.wp_after, dtype=np.int64)
    out: Dict[int, Dict[str, list]] = {}
    for i in range(n_ops):
        if program[i, 0] == _OP_NOP:
            continue
        z = int(zone[i])
        b = min(i * n_buckets // n_ops, n_buckets - 1)
        tl = out.setdefault(z, {
            "host": [0] * n_buckets, "dummy": [0] * n_buckets,
            "erases": [0] * n_buckets, "wp": [-1] * n_buckets})
        tl["host"][b] += int(host[i])
        tl["dummy"][b] += int(dummy[i])
        tl["erases"][b] += int(erases[i])
        tl["wp"][b] = int(wp[i])
    for tl in out.values():               # carry wp across empty buckets
        last = 0
        for b in range(n_buckets):
            if tl["wp"][b] < 0:
                tl["wp"][b] = last
            last = tl["wp"][b]
    return out
