"""Flight recorder for the batched engine: telemetry, profiling, export.

The paper's thesis is that ZNS zone management imposes *hidden* costs --
DLWA, wear and interference the host cannot see until tail latency blows
up.  End-of-run scalars (``ZoneEngine.metrics``,
``runner.config_report``) reproduce the paper's aggregates but hide the
*temporal* structure: a fleet run that writes superfluously in one
occupancy band looks identical to a healthy one.  This package makes
the hidden costs visible without giving up the one-dispatch execution
model:

* :mod:`repro.obs.recorder` -- an opt-in pure-JAX telemetry accumulator
  carried through the ``run_program(s)`` scan (``ObsConfig``):
  per-op host/superfluous pages, wear, occupancy and legality binned
  into fixed-size time-bucketed histograms per lane, plus host-side
  decoding into per-tenant / per-zone / per-device timeline dicts
  (plain lists, no pandas);
* :mod:`repro.obs.profile`  -- dispatch-level profiling: wall time
  split into trace/lower/compile vs execute via the ``jax.monitoring``
  compile events, a recompile counter over the jit caches (keyed on
  abstract input signatures), and per-section counters the fleet
  runner / evaluator / evolve loop thread through;
* :mod:`repro.obs.export`   -- Chrome/Perfetto ``trace_event`` JSON
  export (tenants -> tracks, ops -> duration events on the
  ``timing.simulate_fleet_ops`` clock) plus a counters/gauges metrics
  registry sidecar, schema-validated against
  ``docs/schema/perfetto_trace.schema.json``.

Entry points: ``benchmarks/fleet_search.py --obs`` (emit trace +
telemetry for a search run), ``tools/obs_report.py`` (render the
telemetry as a markdown report), ``tools/bench.py`` (telemetry overhead
and recompile-stability sections of the BENCH artifacts).  The recorder
is effect-free on device results: telemetry-on and telemetry-off runs
produce bit-identical ``DeviceState`` / ``OpTrace`` (property-tested in
``tests/test_obs.py``).
"""

from repro.obs.export import (MetricsRegistry, emit_fleet_obs,
                              fleet_trace_events, load_trace_schema,
                              validate_trace, write_trace)
from repro.obs.profile import (COMPILE_LOG, CompileLog, Profiler,
                               RecompileCounter, jit_cache_size,
                               profile_dispatch)
from repro.obs.recorder import (ObsConfig, TelemetryState,
                                device_rollup, fleet_timelines,
                                lane_timeline, telemetry_init,
                                telemetry_update, tenant_timelines,
                                zone_timelines)

__all__ = [
    "ObsConfig", "TelemetryState", "telemetry_init", "telemetry_update",
    "lane_timeline", "fleet_timelines", "tenant_timelines",
    "zone_timelines", "device_rollup",
    "COMPILE_LOG", "CompileLog", "Profiler", "RecompileCounter",
    "jit_cache_size", "profile_dispatch",
    "MetricsRegistry", "fleet_trace_events", "write_trace",
    "validate_trace", "load_trace_schema", "emit_fleet_obs",
]
