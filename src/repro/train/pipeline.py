"""GPipe-style pipeline parallelism over a mesh axis (shard_map).

The building block for PP at pod scale: layers are split into S stages;
stage s's parameters live on mesh slice s of the ``stage`` axis; M
microbatches flow stage-to-stage with ``jax.lax.ppermute`` on the classic
fill-drain schedule (utilization M/(M+S-1)).

Faithful dataflow: microbatches enter at stage 0, activations hop one
stage per tick, finished microbatches are collected at stage S-1 and
broadcast at the end (psum of a masked buffer).  Stages run their block
every tick (idle ticks compute on zeros -- the "bubble" is explicit in
the schedule, exactly as on hardware).

Self-contained and tested over small host-device meshes; the assigned
archs use DP/TP/EP/SP as primary parallelism (DESIGN.md §5) and can wrap
their block stack with ``pipeline_apply`` to add PP.
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def pipeline_apply(block_fn: Callable[[Any, jax.Array], jax.Array],
                   stage_params: Any, x: jax.Array, *, mesh: Mesh,
                   axis: str = "stage", n_micro: int = 2) -> jax.Array:
    """Run ``x`` through the S pipeline stages living on mesh axis
    ``axis``.

    Args:
      block_fn: (stage_params_slice, acts (Bm, ...)) -> acts (same shape).
      stage_params: pytree with leading stage dim S, sharded over ``axis``.
      x: (B, ...) replicated batch; B % n_micro == 0.
      n_micro: microbatch count M.

    Returns (B, ...) activations after all S stages (replicated).
    """
    s_stages = mesh.shape[axis]

    def stage_fn(params, xs):
        params = jax.tree.map(lambda a: a[0], params)   # this stage's slice
        stage = jax.lax.axis_index(axis)
        bm = xs.shape[0] // n_micro
        micro = xs.reshape(n_micro, bm, *xs.shape[1:])
        n_ticks = s_stages + n_micro - 1
        perm = [(i, (i + 1) % s_stages) for i in range(s_stages)]

        def tick(carry, t):
            buf, out = carry
            # stage s processes microbatch (t - s) when 0 <= t - s < M
            idx = t - stage
            active = (idx >= 0) & (idx < n_micro)
            feed = micro[jnp.clip(idx, 0, n_micro - 1)]
            inp = jnp.where(stage == 0, feed, buf)
            y = block_fn(params, inp)
            y = jnp.where(active, y, jnp.zeros_like(y))
            # collect finished microbatches at the last stage
            out = jax.lax.cond(
                active & (stage == s_stages - 1),
                lambda o: o.at[jnp.clip(idx, 0, n_micro - 1)].set(y),
                lambda o: o, out)
            buf = jax.lax.ppermute(y, axis, perm)
            return (buf, out), None

        buf0 = jnp.zeros_like(micro[0])
        out0 = jnp.zeros_like(micro)
        (_, out), _ = jax.lax.scan(tick, (buf0, out0),
                                   jnp.arange(n_ticks))
        # only the last stage holds real outputs: mask + psum broadcasts
        out = jnp.where(stage == s_stages - 1, out, jnp.zeros_like(out))
        out = jax.lax.psum(out, axis)
        return out.reshape(xs.shape)

    in_specs = (jax.tree.map(lambda _: P(axis), stage_params), P())
    return shard_map(stage_fn, mesh=mesh, in_specs=in_specs,
                     out_specs=P(), check_rep=False)(stage_params, x)


def pipeline_utilization(n_micro: int, n_stages: int) -> float:
    """GPipe bubble math: M/(M + S - 1)."""
    return n_micro / (n_micro + n_stages - 1)
