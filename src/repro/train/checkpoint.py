"""Fault-tolerant checkpointing with a zoned-storage backend.

Design (DESIGN.md §2.3): on a ZNS-backed cluster, checkpoint shards are
the dominant write-heavy, lifetime-skewed storage client.  The manager

* serializes the (params, opt_state, meta) pytree to per-leaf .npy blobs
  under ``<dir>/step_<n>/`` with a manifest; the manifest is written last
  and atomically (tmp + rename) -- a crash mid-save never corrupts the
  latest restorable checkpoint;
* optionally saves asynchronously (device_get happens synchronously, disk
  I/O on a worker thread) -- double-buffered so training never blocks on
  the previous save;
* **mirrors every byte through a simulated ZNS device** (`ZoneFS` with
  lifetime hints: checkpoints medium-lived, logs short-lived) so the
  DLWA / interference cost of the checkpoint cadence is measured, which
  is exactly the paper's workload for a training cluster;
* supports *elastic restore*: leaves come back as host numpy arrays and
  are re-placed under the current mesh/sharding, which may differ from
  the mesh that saved them (topology changes across restarts).
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import ml_dtypes
import numpy as np

from repro.core import SUPERBLOCK, ZNSDevice, zn540
from repro.core.backend import ZoneBackend, set_stream_class
from repro.core.elements import ElementSpec
from repro.storage.zonefs import ZoneFS

LIFETIME_CKPT = 2      # medium-lived: deleted when rotated out
LIFETIME_LOG = 0       # short-lived: step logs / WAL-ish appends


def _key_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return ".".join(parts)


class ZNSTelemetry:
    """Mirrors checkpoint I/O into an emulated SilentZNS/baseline backend.

    ``backend`` accepts any :class:`ZoneBackend` -- e.g. a
    :class:`repro.array.ZNSArray` to model checkpointing onto a
    multi-device ZNS-RAID fleet; defaults to a single zn540 device.
    """

    def __init__(self, element: ElementSpec = SUPERBLOCK,
                 finish_threshold: float = 0.1,
                 backend: Optional[ZoneBackend] = None):
        if backend is None:
            flash, zone = zn540()
            backend = ZNSDevice(flash, zone, element, max_active=14)
        self.dev = backend
        self.fs = ZoneFS(self.dev, finish_threshold=finish_threshold)
        self._next_file = 0
        self.file_ids: Dict[str, int] = {}

    def write_file(self, name: str, nbytes: int, lifetime: int) -> None:
        set_stream_class(self.dev,
                         "ckpt" if lifetime == LIFETIME_CKPT else "log")
        self._next_file += 1
        pages = max(1, nbytes // self.dev.flash.page_bytes)
        self.fs.create(self._next_file, pages, lifetime)
        self.file_ids[name] = self._next_file

    def delete_file(self, name: str) -> None:
        fid = self.file_ids.pop(name, None)
        if fid is not None:
            self.fs.delete(fid)

    def report(self) -> Dict[str, float]:
        return self.fs.report()


class CheckpointManager:
    def __init__(self, directory: str | Path, *, keep: int = 3,
                 async_save: bool = True,
                 zns: Optional[ZNSTelemetry] = None):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_save = async_save
        self.zns = zns
        self._thread: Optional[threading.Thread] = None
        self.save_seconds = 0.0

    # ------------------------------------------------------------------ #
    def _step_dir(self, step: int) -> Path:
        return self.dir / f"step_{step:08d}"

    def all_steps(self) -> list:
        out = []
        for p in self.dir.glob("step_*"):
            if (p / "manifest.json").exists():
                out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # ------------------------------------------------------------------ #
    def save(self, step: int, tree: Any, meta: Optional[Dict] = None
             ) -> None:
        """Snapshot ``tree`` at ``step``.  Blocks only for device_get."""
        self.wait()  # double-buffer: at most one outstanding save
        flat = jax.tree_util.tree_flatten_with_path(tree)[0]
        host = [(_key_str(path), np.asarray(jax.device_get(leaf)))
                for path, leaf in flat]

        def write() -> None:
            t0 = time.time()
            sdir = self._step_dir(step)
            tmp = sdir.with_suffix(".tmp")
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            manifest = {"step": step, "meta": meta or {}, "leaves": []}
            for i, (key, arr) in enumerate(host):
                fname = f"leaf_{i:05d}.npy"
                np.save(tmp / fname, arr)
                manifest["leaves"].append({
                    "key": key, "file": fname,
                    "shape": list(arr.shape), "dtype": str(arr.dtype),
                    "bytes": int(arr.nbytes),
                })
                if self.zns:
                    self.zns.write_file(f"step{step}/{fname}", arr.nbytes,
                                        LIFETIME_CKPT)
            (tmp / "manifest.json").write_text(json.dumps(manifest))
            if self.zns:
                self.zns.write_file(f"step{step}/manifest", 4096,
                                    LIFETIME_CKPT)
            if sdir.exists():
                shutil.rmtree(sdir)
            os.replace(tmp, sdir)   # atomic publish
            self._gc()
            self.save_seconds += time.time() - t0

        if self.async_save:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        else:
            write()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            sdir = self._step_dir(s)
            if self.zns:
                man = json.loads((sdir / "manifest.json").read_text())
                for leaf in man["leaves"]:
                    self.zns.delete_file(f"step{s}/{leaf['file']}")
                self.zns.delete_file(f"step{s}/manifest")
            shutil.rmtree(sdir)

    # ------------------------------------------------------------------ #
    def restore(self, like: Any, step: Optional[int] = None,
                shardings: Any = None) -> Tuple[Any, Dict]:
        """Load into the structure of ``like`` (a pytree or of
        ShapeDtypeStructs); re-places under ``shardings`` when given --
        this is the elastic-restore path (mesh may differ from saver's).
        """
        self.wait()
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        sdir = self._step_dir(step)
        manifest = json.loads((sdir / "manifest.json").read_text())
        by_key = {l["key"]: l for l in manifest["leaves"]}

        flat = jax.tree_util.tree_flatten_with_path(like)
        leaves = []
        for path, leaf in flat[0]:
            key = _key_str(path)
            if key not in by_key:
                raise KeyError(f"checkpoint missing leaf {key}")
            arr = np.load(sdir / by_key[key]["file"])
            want_dtype = by_key[key]["dtype"]
            if str(arr.dtype) != want_dtype:
                # bf16 & friends round-trip through .npy as raw void bytes
                arr = arr.view(np.dtype(getattr(ml_dtypes, want_dtype,
                                                want_dtype)))
            expected = tuple(leaf.shape)
            if tuple(arr.shape) != expected:
                raise ValueError(
                    f"{key}: saved {arr.shape} != expected {expected}")
            leaves.append(arr)
        tree = jax.tree_util.tree_unflatten(flat[1], leaves)
        if shardings is not None:
            tree = jax.tree.map(
                lambda a, s: jax.device_put(a, s), tree, shardings)
        return tree, manifest["meta"]
