"""AdamW + schedules, pure JAX over pytrees (no external deps).

Optimizer state is kept in f32 regardless of (bf16) param dtype; update
math runs in f32 and casts back -- the standard mixed-precision recipe.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup -> cosine decay to min_lr_ratio."""
    warm = jnp.minimum(1.0, (step + 1) / max(1, cfg.warmup_steps))
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    frac = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * frac


def init(params: Any) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                      nu=jax.tree.map(jnp.copy, zeros))


def global_norm(tree: Any) -> jax.Array:
    sq = jax.tree.map(lambda g: jnp.sum(g.astype(jnp.float32) ** 2), tree)
    return jnp.sqrt(sum(jax.tree.leaves(sq)))


def update(cfg: AdamWConfig, params: Any, grads: Any, state: AdamWState
           ) -> Tuple[Any, AdamWState, dict]:
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    lr = schedule(cfg, state.step)
    b1, b2 = cfg.b1, cfg.b2
    step = state.step + 1
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * clip
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * g * g
        mhat = mu / bc1
        nhat = nu / bc2
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps)
        pf = p.astype(jnp.float32)
        pf = pf - lr * (delta + cfg.weight_decay * pf)
        return pf.astype(p.dtype), mu, nu

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_mu = tdef.flatten_up_to(state.mu)
    flat_nu = tdef.flatten_up_to(state.nu)
    out = [upd(p, g, m, n)
           for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_mu = tdef.unflatten([o[1] for o in out])
    new_nu = tdef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step, new_mu, new_nu), {
        "grad_norm": gnorm, "lr": lr}
