"""Deterministic, sharded, skip-ahead data pipeline.

Fault-tolerance contract: a loader's state is exactly ``(seed, step)`` --
``batch_at(step)`` is a pure function, so restarting from a checkpoint at
step k replays the identical stream with zero drift, and elastic restarts
(different host count) re-shard deterministically by host id.
"""

from __future__ import annotations

import dataclasses
from pathlib import Path
from typing import Dict, Iterator, Optional

import numpy as np

import jax.numpy as jnp


@dataclasses.dataclass
class SyntheticLM:
    """Zipf-ish synthetic token stream (content-free but shaped like text)."""

    vocab: int
    batch: int
    seq: int
    seed: int = 0
    host_id: int = 0
    n_hosts: int = 1

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        if self.batch % self.n_hosts:
            raise ValueError("global batch must divide host count")
        local = self.batch // self.n_hosts
        rng = np.random.default_rng(
            (self.seed, step, self.host_id))
        # zipf-flavored marginal over the vocab
        z = rng.zipf(1.3, size=(local, self.seq + 1))
        tokens = np.minimum(z - 1, self.vocab - 1).astype(np.int32)
        return {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}

    def iterate(self, start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
        step = start_step
        while True:
            yield self.batch_at(step)
            step += 1


@dataclasses.dataclass
class MemmapLM:
    """Memory-mapped token-file dataset with deterministic skip-ahead.

    The file is a flat int32 token array; batch b at step s reads
    deterministic offsets derived from (seed, step, host) so restarts and
    elastic re-shards replay exactly.
    """

    path: str
    vocab: int
    batch: int
    seq: int
    seed: int = 0
    host_id: int = 0
    n_hosts: int = 1

    def __post_init__(self):
        self._data = np.memmap(self.path, dtype=np.int32, mode="r")
        if len(self._data) < self.seq + 2:
            raise ValueError("dataset smaller than one sequence")

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        local = self.batch // self.n_hosts
        rng = np.random.default_rng((self.seed, step, self.host_id))
        max_start = len(self._data) - self.seq - 1
        starts = rng.integers(0, max_start, size=local)
        toks = np.stack([np.asarray(self._data[s: s + self.seq + 1])
                         for s in starts])
        toks = np.clip(toks, 0, self.vocab - 1).astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def iterate(self, start_step: int = 0):
        step = start_step
        while True:
            yield self.batch_at(step)
            step += 1


def write_synthetic_corpus(path: str | Path, n_tokens: int, vocab: int,
                           seed: int = 0) -> Path:
    """Materialize a synthetic corpus for the memmap path (tests/examples)."""
    rng = np.random.default_rng(seed)
    toks = np.minimum(rng.zipf(1.3, size=n_tokens) - 1, vocab - 1)
    arr = toks.astype(np.int32)
    path = Path(path)
    arr.tofile(path)
    return path
