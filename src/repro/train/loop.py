"""Fault-tolerant training loop.

Posture for 1000+-node runs:

* **checkpoint/restart**: restore-latest on entry; periodic async save of
  (params, opt_state) + the data cursor; manifests are atomic, so a crash
  at any point resumes from the last published step.
* **deterministic replay**: the data pipeline is a pure function of
  (seed, step) -- after restart the stream continues bit-identically.
* **elastic restarts**: arrays are re-placed under the *current* mesh at
  restore; a job restarted with a different DP width keeps going (global
  batch is fixed; per-host share changes).
* **straggler mitigation**: per-step wall time is tracked against a
  rolling median; steps exceeding ``straggler_factor``x the median invoke
  ``on_straggler`` (deadline-based detection -- the hook is where a real
  deployment re-queues the slow host's shard or triggers backup workers).
* **failure injection**: ``fail_at_step`` raises mid-run (used by tests to
  prove restart-equivalence).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.train import optimizer as OPT
from repro.train.checkpoint import CheckpointManager


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_every: int = 25
    log_every: int = 10
    straggler_factor: float = 3.0
    fail_at_step: Optional[int] = None   # failure injection (tests)


@dataclasses.dataclass
class LoopResult:
    final_step: int
    losses: List[float]
    step_times: List[float]
    stragglers: List[int]
    restored_from: Optional[int]


def fit(train_step: Callable, params: Any, opt_state: Any, data,
        ckpt: Optional[CheckpointManager], cfg: LoopConfig,
        *, on_straggler: Optional[Callable[[int, float], None]] = None,
        param_shardings: Any = None, opt_shardings: Any = None
        ) -> LoopResult:
    """Run the loop; ``data.batch_at(step)`` supplies batches."""
    start = 0
    restored = None
    if ckpt is not None and ckpt.latest_step() is not None:
        state = {"params": params, "opt": opt_state}
        shard = None
        if param_shardings is not None:
            shard = {"params": param_shardings, "opt": opt_shardings}
        state, meta = ckpt.restore(state, shardings=shard)
        params, opt_state = state["params"], state["opt"]
        start = int(meta["step"]) + 1
        restored = start - 1

    losses: List[float] = []
    times: List[float] = []
    stragglers: List[int] = []
    for step in range(start, cfg.total_steps):
        if cfg.fail_at_step is not None and step == cfg.fail_at_step:
            raise RuntimeError(f"injected failure at step {step}")
        batch = jax.tree.map(lambda a: jax.numpy.asarray(a),
                             data.batch_at(step))
        t0 = time.perf_counter()
        params, opt_state, metrics = train_step(params, opt_state, batch)
        loss = float(metrics["loss"])
        dt = time.perf_counter() - t0
        losses.append(loss)
        times.append(dt)
        if len(times) >= 5:
            med = float(np.median(times[-20:]))
            if dt > cfg.straggler_factor * med:
                stragglers.append(step)
                if on_straggler:
                    on_straggler(step, dt)
        if ckpt is not None and (step + 1) % cfg.ckpt_every == 0:
            ckpt.save(step, {"params": params, "opt": opt_state},
                      meta={"step": step, "loss": loss})
    if ckpt is not None:
        ckpt.save(cfg.total_steps - 1,
                  {"params": params, "opt": opt_state},
                  meta={"step": cfg.total_steps - 1,
                        "loss": losses[-1] if losses else float("nan")})
        ckpt.wait()
    return LoopResult(cfg.total_steps - 1, losses, times, stragglers,
                      restored)
