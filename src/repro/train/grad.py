"""Distributed-gradient machinery: microbatching, compression, hierarchy.

Three building blocks used by the loop and by the §Perf hillclimbs:

* **Gradient accumulation** -- ``accumulate_grads`` scans microbatches so
  the global batch fits memory; grads are averaged in f32.
* **Int8 gradient compression with error feedback** -- per-leaf symmetric
  quantization; the quantization error is carried in an f32 residual and
  re-added next step (Seide et al. / 1-bit-SGD lineage).  Under pjit the
  all-reduce then moves int8, cutting cross-pod DCI bytes 4x vs f32.
* **Pod-hierarchical all-reduce** -- shard_map reduce-scatter over the
  in-pod axis, all-reduce over the pod axis, all-gather in-pod: the
  standard two-level schedule that keeps slow cross-pod links carrying
  1/|data| of the bytes.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


# --------------------------------------------------------------------- #
# microbatch accumulation
# --------------------------------------------------------------------- #
def accumulate_grads(loss_fn: Callable, params: Any, batch: Dict,
                     n_micro: int) -> Tuple[jax.Array, Any, Dict]:
    """Split the leading batch dim into ``n_micro`` microbatches and scan.

    loss_fn(params, batch) -> (loss, metrics_dict).
    Returns (mean loss, mean grads, last metrics).
    """
    if n_micro <= 1:
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        return loss, grads, metrics

    def reshape(x):
        if x.shape[0] == n_micro:
            return x                     # caller pre-shaped (M, Bm, ...)
        b = x.shape[0]
        if b % n_micro:
            raise ValueError(f"batch {b} % n_micro {n_micro} != 0")
        return x.reshape(n_micro, b // n_micro, *x.shape[1:])

    micro = jax.tree.map(reshape, batch)
    zero_g = jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def body(carry, mb):
        loss_acc, g_acc = carry
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, mb)
        g_acc = jax.tree.map(
            lambda a, g: a + g.astype(jnp.float32) / n_micro, g_acc, grads)
        return (loss_acc + loss / n_micro, g_acc), metrics

    (loss, grads), metrics = jax.lax.scan(body, (0.0, zero_g), micro)
    last_metrics = jax.tree.map(lambda m: m[-1], metrics)
    return loss, grads, last_metrics


# --------------------------------------------------------------------- #
# int8 compression with error feedback
# --------------------------------------------------------------------- #
def compress_int8(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization. Returns (q, scale)."""
    gf = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def init_error_feedback(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_grads_ef(grads: Any, ef: Any) -> Tuple[Any, Any]:
    """Quantize (grads + residual); new residual = input - dequantized.

    Returns (dequantized grads to feed the optimizer, new residual).
    The communication layer sees only the int8 payloads.
    """
    def one(g, e):
        target = g.astype(jnp.float32) + e
        q, scale = compress_int8(target)
        deq = decompress_int8(q, scale)
        return deq, target - deq

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(ef)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (tdef.unflatten([o[0] for o in outs]),
            tdef.unflatten([o[1] for o in outs]))


# --------------------------------------------------------------------- #
# pod-hierarchical all-reduce (shard_map)
# --------------------------------------------------------------------- #
def hierarchical_psum(x: jax.Array, *, in_pod_axis: str = "data",
                      cross_pod_axis: str = "pod") -> jax.Array:
    """reduce_scatter(in-pod) -> psum(cross-pod) -> all_gather(in-pod).

    Call inside shard_map.  Equivalent to psum over both axes but the
    cross-pod (DCI) hop carries 1/|in_pod| of the bytes.
    """
    scattered = jax.lax.psum_scatter(x, in_pod_axis, scatter_dimension=0,
                                     tiled=True)
    reduced = jax.lax.psum(scattered, cross_pod_axis)
    return jax.lax.all_gather(reduced, in_pod_axis, axis=0, tiled=True)


def make_hierarchical_grad_sync(mesh, axes=("pod", "data")):
    """shard_map'd gradient synchronizer for manual-DP training loops."""
    from jax.experimental.shard_map import shard_map

    def sync(grads):
        def inner(g):
            return jax.tree.map(
                lambda a: hierarchical_psum(
                    a, in_pod_axis=axes[1], cross_pod_axis=axes[0]) /
                (mesh.shape[axes[0]] * mesh.shape[axes[1]]), g)
        return shard_map(inner, mesh=mesh,
                         in_specs=P(), out_specs=P())(grads)
    return sync
