"""Training substrate: optimizer, grad machinery, loop, checkpointing."""
