"""Compiled-artifact analysis: collective parsing + roofline terms."""
