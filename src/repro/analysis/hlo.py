"""Collective-byte accounting over post-SPMD HLO text.

``cost_analysis()`` has no collective-byte entry, so we parse the compiled
module's text: every ``all-gather`` / ``all-reduce`` / ``reduce-scatter`` /
``all-to-all`` / ``collective-permute`` instruction contributes its result
shape's bytes (per-device).  Tuple-shaped results sum their elements.
"""

from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter",
                  "all-to-all", "collective-permute")

# e.g.  %all-reduce.5 = f32[16,128]{1,0} all-reduce(...)
#       ROOT %t = (bf16[8,16]{...}, f32[4]{...}) all-to-all(...)
_INSTR = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+([a-z\-]+)(\(|-start\()")
_SHAPE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-category result bytes (per device) of every collective op."""
    out: Dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = _INSTR.search(stripped)
        if not m:
            continue
        shape_str, op = m.group(1), m.group(2)
        if op in COLLECTIVE_OPS:
            out[op] += _shape_bytes(shape_str)
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return dict(out)


def collective_count(hlo_text: str) -> Dict[str, int]:
    out: Dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _INSTR.search(line.strip())
        if m and m.group(2) in COLLECTIVE_OPS:
            out[m.group(2)] += 1
    return dict(out)
