"""Three-term roofline from the compiled dry-run artifact (TPU v5e).

    compute    = HLO_FLOPs_per_device / peak_FLOPs
    memory     = HLO_bytes_per_device / HBM_bw
    collective = collective_bytes_per_device / ICI_link_bw

The compiled SPMD module is per-device, so per-device quantities divided
by per-chip peaks equal the task sheet's total/(chips * peak) formula.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

# TPU v5e-class hardware constants (task sheet)
PEAK_FLOPS_BF16 = 197e12        # FLOP/s per chip
HBM_BW = 819e9                  # B/s per chip
ICI_BW = 50e9                   # B/s per link (1 link assumed per transfer)


@dataclasses.dataclass
class Roofline:
    flops: float                # per-device HLO flops
    hbm_bytes: float            # per-device bytes accessed
    coll_bytes: float           # per-device collective bytes
    model_flops: float = 0.0    # 6*N*D useful flops (per device)

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS_BF16

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_fraction(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs: how much compiled compute is useful."""
        return self.model_flops / self.flops if self.flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Achievable MFU bound: useful flop-time over the bounding term."""
        if self.t_bound == 0:
            return 0.0
        return (self.model_flops / PEAK_FLOPS_BF16) / self.t_bound

    def report(self) -> Dict[str, float]:
        return {
            "flops_per_dev": self.flops,
            "hbm_bytes_per_dev": self.hbm_bytes,
            "coll_bytes_per_dev": self.coll_bytes,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "model_flops_per_dev": self.model_flops,
            "useful_flop_fraction": self.useful_fraction,
            "roofline_fraction": self.roofline_fraction,
        }


def model_flops_train(n_active_params: int, n_tokens: int) -> float:
    """6*N*D for a train step (fwd+bwd)."""
    return 6.0 * n_active_params * n_tokens


def model_flops_forward(n_active_params: int, n_tokens: int) -> float:
    """2*N*D for inference forward (prefill/decode)."""
    return 2.0 * n_active_params * n_tokens
