"""Analytic per-cell FLOP / HBM-byte / collective-byte accounting.

Why analytic: XLA's ``cost_analysis`` counts a ``while``-loop body ONCE
(trip counts are invisible to it), so any scan-over-layers model under-
reports FLOPs/bytes by ~n_layers x.  The roofline table therefore uses
closed-form counts derived from the architecture -- the same first-order
accounting every published MFU/roofline analysis uses -- and keeps the
HLO numbers as corroborating reference (they agree once scaled by trip
counts; see EXPERIMENTS.md §Methodology).

All outputs are PER DEVICE (divide global work by mesh size).

FLOPs (fwd):
  matmul params     2 * N_active * T          (N excludes embedding gather)
  attention         4 * B * H * hd * S * S_ctx   (x1/2 causal)
  cross-attention   4 * B * H * hd * S * M
  mamba scan        10 * B * S * d_inner * N_state
  mLSTM scan        ~8 * B * S * H * P^2 (matrix-memory update + read)
  sLSTM scan        ~2 * B * S * (4 d^2 / H)  (block-diag recurrence)
Train = 3x fwd (activation bwd 2x).  Decode: T = B, S = 1, S_ctx = cache.

HBM bytes:
  params traffic    train: read(bf16) x2 (fwd+bwd) + write + grads f32 r/w
                    + AdamW mu/nu f32 r/w  = 6 + 8 + 16 = 30 B/param
                    inference: 2 B/param per step
  activations       ~= c_act * T_local * d_model * bytes * n_layers
                    (c_act ~ 12 boundaries/block with remat: resid x2,
                    norms, qkv/gate projections, attention out, ffn in/out)
  KV cache          prefill: write once; decode: read whole cache + masked
                    append (read+write) => 3x cache bytes (baseline impl)
Collectives:
  TP all-reduce     2 * T_local * d * bytes per sharded matmul pair
                    (attn out + ffn out) per layer
  FSDP all-gather   param_bytes_local * (|data|-1)/|data| per microbatch
  DP grad reduce    2 * grad_bytes_local (ring, bf16 grads assumed f32)
  MoE all-to-all    2 * T_local * top_k * d * bytes per MoE layer
  SP softmax        negligible (B*H scalars)
"""

from __future__ import annotations

import dataclasses
from typing import Dict

from repro.configs.base import ArchConfig, ShapeCell
from repro.models import model as MDL

BF16 = 2
F32 = 4


@dataclasses.dataclass
class CellCost:
    flops: float          # per device
    hbm_bytes: float      # per device
    coll_bytes: float     # per device
    model_flops: float    # useful 2NT/6NT per device
    detail: Dict[str, float]


def _counts(cfg: ArchConfig):
    kinds = cfg.layer_kinds()
    n_attn = sum(k in ("attn", "cross") for k in kinds)
    n_cross = sum(k == "cross" for k in kinds)
    n_mamba = sum(k == "mamba" for k in kinds)
    n_mlstm = sum(k == "mlstm" for k in kinds)
    n_slstm = sum(k == "slstm" for k in kinds)
    n_moe = sum(cfg.is_moe_layer(i) for i in range(cfg.n_layers))
    return n_attn, n_cross, n_mamba, n_mlstm, n_slstm, n_moe


def expert_param_count(cfg: ArchConfig) -> int:
    if not cfg.n_experts:
        return 0
    e_ff = cfg.moe_d_ff or cfg.d_ff
    n_moe = sum(cfg.is_moe_layer(i) for i in range(cfg.n_layers))
    return 3 * cfg.d_model * e_ff * cfg.n_experts * n_moe


def cell_cost(cfg: ArchConfig, cell: ShapeCell, n_dev: int,
              *, dp: int, tp: int, n_micro: int = 1,
              fsdp: bool = False, append_impl: str = "scatter",
              param_dp: int = 0) -> CellCost:
    """``dp`` is the batch-sharding width (may be 1 for batch-1 decode);
    ``param_dp`` is the mesh's data-axis size, which FSDP/EP always use
    for parameter storage regardless of batch fit (defaults to dp)."""
    param_dp = param_dp or dp
    B, S = cell.global_batch, cell.seq_len
    train = cell.kind == "train"
    decode = cell.kind == "decode"
    mult = 3.0 if train else 1.0          # bwd = 2x fwd

    n_attn, n_cross, n_mamba, n_mlstm, n_slstm, n_moe = _counts(cfg)
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    H = cfg.n_heads
    N_active = MDL.active_param_count(cfg)
    mem_len = MDL.memory_len(cfg, cell)

    T = B if decode else B * S            # tokens this step
    s_q = 1 if decode else S              # query length
    s_ctx = S if decode else S            # context length

    # ---------------- FLOPs (global) ----------------
    f_matmul = 2.0 * N_active * T
    causal = 0.5 if not decode else 1.0
    f_attn = 4.0 * B * H * hd * s_q * s_ctx * causal * n_attn
    f_cross = 4.0 * B * H * hd * s_q * mem_len * n_cross
    d_inner = cfg.ssm_expand * d
    f_mamba = 10.0 * T * d_inner * cfg.ssm_state * n_mamba
    p_m = (2 * d) // max(1, H)            # mLSTM head dim (expand=2)
    f_mlstm = 8.0 * T * H * p_m * p_m * n_mlstm
    f_slstm = 2.0 * T * (4 * d * d // max(1, H)) * n_slstm
    if cfg.encoder_layers and mem_len:
        enc_T = B * mem_len
        enc_params_per_layer = (4 * d * d
                                + 2 * d * (cfg.dense_d_ff or cfg.d_ff))
        f_matmul += 2.0 * enc_params_per_layer * enc_T * cfg.encoder_layers
        f_attn += 4.0 * B * H * hd * mem_len * mem_len * cfg.encoder_layers

    flops_global = mult * (f_matmul + f_attn + f_cross + f_mamba
                           + f_mlstm + f_slstm)
    model_flops_global = (6.0 if train else 2.0) * N_active * T

    # ---------------- HBM bytes (per device) ----------------
    p_local = MDL.param_count(cfg) / (tp * (param_dp if fsdp else 1))
    if train:
        b_params = p_local * 30.0
    else:
        b_params = p_local * BF16
    t_local = T / min(dp, max(1, B)) if decode else T / dp
    c_act = 12.0
    b_acts = c_act * t_local * d * BF16 * cfg.n_layers * (mult / 3 + 2 / 3)
    # KV cache traffic
    b_cache = 0.0
    if not train:
        if cfg.mla:
            per_tok = (cfg.kv_lora + cfg.rope_head_dim) * BF16
        else:
            per_tok = 2 * cfg.n_kv_heads * hd * BF16
        cache_local = (B / min(dp, max(1, B))) * S * per_tok * n_attn / tp
        if decode:
            # attention reads the cache once; the append is in-place DUS
            # ('scatter', §Perf B1) or a full masked rewrite ('masked')
            b_cache = (1.0 if append_impl == "scatter" else 3.0) \
                * cache_local
        else:
            b_cache = cache_local            # prefill writes it once
    hbm = b_params + b_acts + b_cache

    # ---------------- collective bytes (per device) ----------------
    # EP == DP (experts sharded over 'data', §Perf A1): expert weights are
    # never gathered and expert grads reduce locally; only non-expert
    # params pay FSDP gathers / DP grad sync.
    coll = 0.0
    n_params = MDL.param_count(cfg)
    e_params = expert_param_count(cfg)
    ne_params = n_params - e_params
    n_dense_ffn = cfg.n_layers - n_moe
    ring = 2.0 * (tp - 1) / tp
    # TP activation all-reduces: attention out per attn layer + dense
    # ffn out per dense layer (fwd); bwd has matching ARs (x3 for train)
    if tp > 1:
        # Megatron: 2 ARs/layer fwd, matching 2 in bwd => x2 for train
        ar_mult = 2.0 if train else 1.0
        ar_per_layer = n_attn + n_dense_ffn
        coll += ar_mult * t_local * d * BF16 * ar_per_layer * ring
    if fsdp:
        ne_local = ne_params / (tp * param_dp)
        coll += ne_local * BF16 * n_micro * (param_dp - 1) / param_dp \
            * (2 if train else 1)
    if train:
        grad_local = ne_params / (tp * (param_dp if fsdp else 1)) * F32
        coll += 2.0 * grad_local * (dp - 1) / max(1, dp)
    if n_moe:
        # all-to-all dispatch+combine over the EP(=data) group.
        # Device-limited routing (A4) bounds per-token destinations to
        # route_limit groups; int8 dispatch (A5) halves the dispatch leg.
        fanout = cfg.top_k
        if cfg.route_groups > 1 and 0 < cfg.route_limit:
            fanout = min(cfg.top_k, cfg.route_limit)
        dispatch_b = 1.0 if cfg.int8_dispatch else BF16
        per_leg = t_local * fanout * d * n_moe * (dp - 1) / dp
        coll += mult * per_leg * (dispatch_b + BF16)  # dispatch + combine
        # EPxTP expert-ff term: SPMD picks the cheaper of (a) all-reduce
        # of the (E_local, C, d) expert outputs (ff-sharded compute) or
        # (b) all-gathering the model-sharded expert weights per
        # microbatch (FSDP-over-model) -- charge min of the two (§Perf A6)
        if tp > 1:
            ar_out = mult * t_local * cfg.top_k * cfg.capacity_factor \
                * d * BF16 * n_moe * ring
            e_local_bytes = e_params / param_dp * BF16  # per data shard
            ag_w = e_local_bytes / tp * (tp - 1) * n_micro \
                * (2 if train else 1)
            coll += min(ar_out, ag_w)
    # ------------- analytic device residency (TPU bytes) -------------
    # XLA's CPU-backend buffer assignment materializes f32 copies of
    # bf16 matmul operands (no bf16 CPU gemm), so memory_analysis() peak
    # is pessimistic; this is the TPU-true estimate the "fits in 16 GB"
    # check uses: params (+grads f32 +AdamW f32 x2 for train) + KV cache
    # + the remat activation stack + transient working set.
    res_params = p_local * (BF16 + (F32 * 3 if train else 0))
    res_cache = cache_local if not train else 0.0
    t_micro_local = t_local / max(1, n_micro)
    if train:   # remat stack saves x per layer boundary
        res_acts = cfg.n_layers * t_micro_local * d * BF16 \
            + 3.0 * t_micro_local * d * F32      # logits/CE transient
    else:       # inference: a few live boundaries, no layer stack
        res_acts = 4.0 * t_micro_local * d * BF16
    residency = res_params + res_cache + res_acts

    detail = {
        "residency_bytes": residency,
        "f_matmul": mult * f_matmul, "f_attn": mult * f_attn,
        "f_cross": mult * f_cross, "f_recurrent": mult * (
            f_mamba + f_mlstm + f_slstm),
        "b_params": b_params, "b_acts": b_acts, "b_cache": b_cache,
    }
    return CellCost(flops=flops_global / n_dev, hbm_bytes=hbm,
                    coll_bytes=coll,
                    model_flops=model_flops_global / n_dev, detail=detail)
