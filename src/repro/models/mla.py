"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

Train/prefill: the full (de-compressed) form -- per-head K_nope/V are
materialized from the kv_lora latent and attention runs through the
flash-attention impl switch with head_dim = nope + rope.

Decode: the *absorbed* form (the MLA serving trick): the cache stores only
the latent c_kv (B, S, kv_lora) and the shared roped key k_rope
(B, S, rope_dim); W_uk is absorbed into the query and W_uv into the output
so scores/values contract directly against the latent -- per-token cache
bytes are kv_lora + rope_dim (= 576) instead of 2*H*D.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.ops import attention as flash_attention
from repro.models import layers as L


def mla_init(rng, cfg, dtype=jnp.bfloat16) -> Dict:
    d = cfg.d_model
    h = cfg.n_heads
    r = jax.random.split(rng, 8)
    return {
        "w_dq": L.dense_init(r[0], d, cfg.q_lora, dtype),
        "q_norm": L.rmsnorm_init(cfg.q_lora, dtype),
        "w_uq": L.dense_init(r[1], cfg.q_lora,
                             h * (cfg.nope_head_dim + cfg.rope_head_dim),
                             dtype),
        "w_dkv": L.dense_init(r[2], d, cfg.kv_lora, dtype),
        "kv_norm": L.rmsnorm_init(cfg.kv_lora, dtype),
        "w_krope": L.dense_init(r[3], d, cfg.rope_head_dim, dtype),
        "w_uk": L.dense_init(r[4], cfg.kv_lora, h * cfg.nope_head_dim,
                             dtype),
        "w_uv": L.dense_init(r[5], cfg.kv_lora, h * cfg.v_head_dim, dtype),
        "wo": L.dense_init(r[6], h * cfg.v_head_dim, d, dtype),
    }


def _project_q(p: Dict, x: jax.Array, cfg, positions) -> Tuple[jax.Array,
                                                               jax.Array]:
    """-> q_nope (B,S,H,Dn), q_rope (B,S,H,Dr) (rope applied)."""
    b = x.shape[0]
    s = x.shape[1] if x.ndim == 3 else 1
    x2 = x if x.ndim == 3 else x[:, None]
    cq = L.rmsnorm(x2 @ p["w_dq"], p["q_norm"])
    q = (cq @ p["w_uq"]).reshape(b, s, cfg.n_heads,
                                 cfg.nope_head_dim + cfg.rope_head_dim)
    q_nope, q_rope = jnp.split(q, [cfg.nope_head_dim], axis=-1)
    q_rope = L.apply_rope(q_rope.transpose(0, 2, 1, 3), positions,
                          cfg.rope_theta).transpose(0, 2, 1, 3)
    return q_nope, q_rope


def mla_forward(p: Dict, x: jax.Array, cfg, *, causal: bool = True,
                impl: str = "chunked") -> jax.Array:
    """Full form. x: (B, S, d)."""
    b, s, _ = x.shape
    pos = jnp.arange(s)
    q_nope, q_rope = _project_q(p, x, cfg, pos)

    c_kv = L.rmsnorm(x @ p["w_dkv"], p["kv_norm"])           # (B,S,kv_lora)
    k_rope = L.apply_rope((x @ p["w_krope"])[:, None], pos,
                          cfg.rope_theta)[:, 0]              # (B,S,Dr)
    k_nope = (c_kv @ p["w_uk"]).reshape(b, s, cfg.n_heads,
                                        cfg.nope_head_dim)
    v = (c_kv @ p["w_uv"]).reshape(b, s, cfg.n_heads, cfg.v_head_dim)

    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None],
                                  (b, s, cfg.n_heads, cfg.rope_head_dim))],
        axis=-1)
    # flash kernel wants v head dim == qk head dim: zero-pad v (192 vs 128
    # for deepseek-v2) and slice the output back
    dq = cfg.nope_head_dim + cfg.rope_head_dim
    pad = dq - cfg.v_head_dim
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, pad))) if pad else v
    o = flash_attention(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                        vp.transpose(0, 2, 1, 3), causal=causal, impl=impl)
    o = o.transpose(0, 2, 1, 3)[..., :cfg.v_head_dim].reshape(b, s, -1)
    return o @ p["wo"]


def init_mla_cache(batch: int, max_seq: int, cfg,
                   dtype=jnp.bfloat16) -> Dict:
    return {
        "c_kv": jnp.zeros((batch, max_seq, cfg.kv_lora), dtype),
        "k_rope": jnp.zeros((batch, max_seq, cfg.rope_head_dim), dtype),
    }


def mla_prefill(p: Dict, x: jax.Array, cache: Dict, cfg, *,
                impl: str = "chunked") -> Tuple[jax.Array, Dict]:
    b, s, _ = x.shape
    out = mla_forward(p, x, cfg, causal=True, impl=impl)
    pos = jnp.arange(s)
    c_kv = L.rmsnorm(x @ p["w_dkv"], p["kv_norm"])
    k_rope = L.apply_rope((x @ p["w_krope"])[:, None], pos,
                          cfg.rope_theta)[:, 0]
    new_cache = {
        "c_kv": jax.lax.dynamic_update_slice(
            cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), (0, 0, 0)),
        "k_rope": jax.lax.dynamic_update_slice(
            cache["k_rope"], k_rope.astype(cache["k_rope"].dtype),
            (0, 0, 0)),
    }
    return out, new_cache


def mla_decode(p: Dict, x: jax.Array, cache: Dict, pos: jax.Array, cfg
               ) -> Tuple[jax.Array, Dict]:
    """Absorbed decode. x: (B, d); pos: (B,)."""
    b, d = x.shape
    h, dn, dr = cfg.n_heads, cfg.nope_head_dim, cfg.rope_head_dim
    q_nope, q_rope = _project_q(p, x, cfg, pos[:, None, None])
    q_nope = q_nope[:, 0]                                    # (B,H,Dn)
    q_rope = q_rope[:, 0]                                    # (B,H,Dr)

    # new latent entry
    c_new = L.rmsnorm(x @ p["w_dkv"], p["kv_norm"])          # (B, kv_lora)
    kr_new = L.apply_rope((x @ p["w_krope"])[:, None, None],
                          pos[:, None, None], cfg.rope_theta)[:, 0, 0]
    s_max = cache["c_kv"].shape[1]
    slot = jnp.arange(s_max)[None, :, None] == pos[:, None, None]
    cache = {
        "c_kv": jnp.where(slot, c_new[:, None].astype(cache["c_kv"].dtype),
                          cache["c_kv"]),
        "k_rope": jnp.where(slot,
                            kr_new[:, None].astype(cache["k_rope"].dtype),
                            cache["k_rope"]),
    }

    # absorb W_uk into q: q_c (B,H,kv_lora)
    w_uk = p["w_uk"].reshape(cfg.kv_lora, h, dn)
    q_c = jnp.einsum("bhd,lhd->bhl", q_nope.astype(jnp.float32),
                     w_uk.astype(jnp.float32))
    scale = 1.0 / jnp.sqrt(dn + dr)
    logits = (jnp.einsum("bhl,bsl->bhs", q_c.astype(cache["c_kv"].dtype),
                         cache["c_kv"],
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bhr,bsr->bhs", q_rope,
                           cache["k_rope"],
                           preferred_element_type=jnp.float32)) * scale
    mask = jnp.arange(s_max)[None, None, :] <= pos[:, None, None]
    logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    ctx = jnp.einsum("bhs,bsl->bhl", probs.astype(cache["c_kv"].dtype),
                     cache["c_kv"],
                     preferred_element_type=jnp.float32)     # (B,H,kv_lora)
    # absorb W_uv into output: v_head per head
    w_uv = p["w_uv"].reshape(cfg.kv_lora, h, cfg.v_head_dim)
    o = jnp.einsum("bhl,lhv->bhv", ctx, w_uv.astype(jnp.float32))
    o = o.reshape(b, h * cfg.v_head_dim).astype(x.dtype)
    return o @ p["wo"], cache
