"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory).

Stabilized exponential gating (xLSTM paper, arXiv:2405.04517): gates are
kept in log space with a stabilizer state m so the recurrence stays finite
over 500k-token contexts:

    m_t = max(log f_t + m_{t-1}, log i_t)
    f'  = exp(log f_t + m_{t-1} - m_t),  i' = exp(log i_t - m_t)

mLSTM: per-head matrix memory C (P x P), normalizer n (P,):
    C_t = f' C + i' v k^T ;  n_t = f' n + i' k
    h_t = C_t q / max(|n_t . q|, 1)

sLSTM: per-unit scalar memory with head-wise block-diagonal recurrence.

Both are O(1) state per token (sub-quadratic; they run long_500k).
Train/prefill paths are lax.scan over time; decode is a single step.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L


# --------------------------------------------------------------------- #
# mLSTM
# --------------------------------------------------------------------- #
def mlstm_init(rng, d_model: int, n_heads: int, *, expand: int = 2,
               dtype=jnp.bfloat16) -> Dict:
    d_inner = expand * d_model
    r = jax.random.split(rng, 7)
    return {
        "up": L.dense_init(r[0], d_model, 2 * d_inner, dtype),
        "wq": L.dense_init(r[1], d_inner, d_inner, dtype),
        "wk": L.dense_init(r[2], d_inner, d_inner, dtype),
        "wv": L.dense_init(r[3], d_inner, d_inner, dtype),
        "w_if": L.dense_init(r[4], d_inner, 2 * n_heads, dtype, scale=0.02),
        "down": L.dense_init(r[5], d_inner, d_model, dtype),
        "out_norm": L.rmsnorm_init(d_inner, dtype),
    }


def _mlstm_qkv(p: Dict, xg: jax.Array, n_heads: int):
    """xg: (..., d_inner) -> q,k,v (..., H, P) + log gates (..., H)."""
    d_inner = xg.shape[-1]
    ph = d_inner // n_heads
    def heads(y):
        return y.reshape(*y.shape[:-1], n_heads, ph)
    q = heads(xg @ p["wq"])
    k = heads(xg @ p["wk"]) / jnp.sqrt(ph).astype(xg.dtype)
    v = heads(xg @ p["wv"])
    gates = (xg @ p["w_if"]).astype(jnp.float32)
    log_i, f_pre = jnp.split(gates, 2, axis=-1)
    log_f = jax.nn.log_sigmoid(f_pre)               # forget in (0,1)
    return q, k, v, log_i, log_f


def mlstm_forward(p: Dict, x: jax.Array, n_heads: int) -> jax.Array:
    """x: (B, S, d) -> (B, S, d)."""
    b, s, d = x.shape
    xz = x @ p["up"]
    xg, z = jnp.split(xz, 2, axis=-1)               # (B, S, d_inner)
    q, k, v, log_i, log_f = _mlstm_qkv(p, xg, n_heads)
    ph = q.shape[-1]

    def step(carry, inp):
        c, n, m = carry                              # (B,H,P,P),(B,H,P),(B,H)
        q_t, k_t, v_t, li, lf = inp
        m_new = jnp.maximum(lf + m, li)
        fp = jnp.exp(lf + m - m_new)[..., None]      # (B,H,1)
        ip = jnp.exp(li - m_new)[..., None]
        kf = k_t.astype(jnp.float32)
        vf = v_t.astype(jnp.float32)
        c = c * fp[..., None] + ip[..., None] * vf[..., :, None] \
            * kf[..., None, :]                       # (B,H,P,P) v k^T
        n = n * fp + ip * kf
        qf = q_t.astype(jnp.float32)
        num = jnp.einsum("bhvk,bhk->bhv", c, qf)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, qf)),
                          1.0)[..., None]
        h = (num / den)
        return (c, n, m_new), h.astype(x.dtype)

    c0 = jnp.zeros((b, n_heads, ph, ph), jnp.float32)
    n0 = jnp.zeros((b, n_heads, ph), jnp.float32)
    m0 = jnp.full((b, n_heads), -1e30, jnp.float32)
    xs = (q.transpose(1, 0, 2, 3), k.transpose(1, 0, 2, 3),
          v.transpose(1, 0, 2, 3), log_i.transpose(1, 0, 2),
          log_f.transpose(1, 0, 2))
    _, hs = L.chunked_remat_scan(step, (c0, n0, m0), xs, chunk=128)
    h = hs.transpose(1, 0, 2, 3).reshape(b, s, -1)   # (B, S, d_inner)
    h = L.rmsnorm(h, p["out_norm"])
    h = h * jax.nn.silu(z)
    return h @ p["down"]


def init_mlstm_cache(batch: int, d_model: int, n_heads: int, *,
                     expand: int = 2) -> Dict:
    d_inner = expand * d_model
    ph = d_inner // n_heads
    return {
        "c": jnp.zeros((batch, n_heads, ph, ph), jnp.float32),
        "n": jnp.zeros((batch, n_heads, ph), jnp.float32),
        "m": jnp.full((batch, n_heads), -1e30, jnp.float32),
    }


def mlstm_decode(p: Dict, x: jax.Array, cache: Dict, n_heads: int
                 ) -> Tuple[jax.Array, Dict]:
    """x: (B, d) one token."""
    xz = x @ p["up"]
    xg, z = jnp.split(xz, 2, axis=-1)
    q, k, v, log_i, log_f = _mlstm_qkv(p, xg, n_heads)
    c, n, m = cache["c"], cache["n"], cache["m"]
    m_new = jnp.maximum(log_f + m, log_i)
    fp = jnp.exp(log_f + m - m_new)[..., None]
    ip = jnp.exp(log_i - m_new)[..., None]
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    c = c * fp[..., None] + ip[..., None] * vf[..., :, None] \
        * kf[..., None, :]
    n = n * fp + ip * kf
    qf = q.astype(jnp.float32)
    num = jnp.einsum("bhvk,bhk->bhv", c, qf)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, qf)),
                      1.0)[..., None]
    h = (num / den).reshape(x.shape[0], -1).astype(x.dtype)
    h = L.rmsnorm(h, p["out_norm"])
    h = h * jax.nn.silu(z)
    return h @ p["down"], {"c": c, "n": n, "m": m_new}


# --------------------------------------------------------------------- #
# sLSTM
# --------------------------------------------------------------------- #
def slstm_init(rng, d_model: int, n_heads: int, dtype=jnp.bfloat16) -> Dict:
    r = jax.random.split(rng, 3)
    ph = d_model // n_heads
    rec = (jax.random.normal(r[1], (n_heads, ph, 4 * ph), jnp.float32)
           / jnp.sqrt(ph)).astype(dtype)
    return {
        # input projection -> (z, i, f, o) pre-activations
        "w_in": L.dense_init(r[0], d_model, 4 * d_model, dtype),
        "r_rec": rec,                       # block-diagonal recurrence
        "out": L.dense_init(r[2], d_model, d_model, dtype),
    }


def _slstm_step(p: Dict, x_t, carry, n_heads: int):
    """x_t: (B, d); carry: (c, n, m, h_prev) with c/n/h (B, d), m (B, H)."""
    c, n, m, h_prev = carry
    b, d = x_t.shape
    ph = d // n_heads
    pre = x_t @ p["w_in"]                            # (B, 4d)
    hp = h_prev.reshape(b, n_heads, ph)
    rec = jnp.einsum("bhp,hpq->bhq", hp.astype(p["r_rec"].dtype),
                     p["r_rec"]).reshape(b, 4 * d)
    pre = (pre + rec).astype(jnp.float32)
    z, i_pre, f_pre, o_pre = jnp.split(pre, 4, axis=-1)   # (B, d) each
    zh = jnp.tanh(z)
    # stabilized exponential gating per head (shared m across the head)
    li = i_pre.reshape(b, n_heads, ph)
    lf = jax.nn.log_sigmoid(f_pre).reshape(b, n_heads, ph)
    m_new = jnp.maximum(jnp.max(lf, -1) + m, jnp.max(li, -1))   # (B, H)
    fp = jnp.exp(lf + m[..., None] - m_new[..., None])
    ip = jnp.exp(li - m_new[..., None])
    cf = c.reshape(b, n_heads, ph) * fp + ip * zh.reshape(b, n_heads, ph)
    nf = n.reshape(b, n_heads, ph) * fp + ip
    h = jax.nn.sigmoid(o_pre) * (cf / jnp.maximum(nf, 1e-6)
                                 ).reshape(b, d)
    return (cf.reshape(b, d), nf.reshape(b, d), m_new, h.astype(x_t.dtype))


def slstm_forward(p: Dict, x: jax.Array, n_heads: int) -> jax.Array:
    b, s, d = x.shape

    def step(carry, x_t):
        new = _slstm_step(p, x_t, carry, n_heads)
        return new, new[3]

    carry = init_slstm_cache(b, d, n_heads)
    carry = (carry["c"], carry["n"], carry["m"], carry["h"])
    _, hs = L.chunked_remat_scan(step, carry, x.transpose(1, 0, 2),
                                 chunk=128)
    return hs.transpose(1, 0, 2) @ p["out"]


def init_slstm_cache(batch: int, d_model: int, n_heads: int) -> Dict:
    return {
        "c": jnp.zeros((batch, d_model), jnp.float32),
        "n": jnp.zeros((batch, d_model), jnp.float32),
        "m": jnp.full((batch, n_heads), -1e30, jnp.float32),
        "h": jnp.zeros((batch, d_model), jnp.bfloat16),
    }


def slstm_decode(p: Dict, x: jax.Array, cache: Dict, n_heads: int
                 ) -> Tuple[jax.Array, Dict]:
    carry = (cache["c"], cache["n"], cache["m"], cache["h"])
    c, n, m, h = _slstm_step(p, x, carry, n_heads)
    out = h @ p["out"]
    return out, {"c": c, "n": n, "m": m, "h": h}
