"""Mixture-of-Experts layer: top-k routing, sort-based capacity dispatch.

Expert-parallel posture: expert weights are stacked (E, ...) and sharded
over the ``model`` mesh axis; tokens are sharded over ``data``.  Dispatch
is sort-based (no (T, E, C) one-hot): flatten (token, expert-choice) pairs,
argsort by expert, compute position-within-expert from cumulative counts,
scatter into an (E, C, d) buffer (capacity drop), run batched expert
matmuls, gather back with routing weights.  Under pjit this lowers to the
all-to-all-style collectives the roofline analysis attributes to EP.

Also returns the Switch-style load-balancing auxiliary loss.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers as L


def _maybe_shard(x: jax.Array, *spec):
    """with_sharding_constraint when a mesh context is active (dry-run /
    launch paths); no-op in mesh-less unit tests.  GSPMD replicates the
    data-dependent dispatch gathers/scatters without these hints (§Perf
    A7) -- pinning the token-major arrays to the data axis keeps the
    (T*k, d) combine buffers sharded and turns the token->expert scatter
    into the intended all-to-all."""
    try:
        from jax.interpreters.pxla import thread_resources
        mesh = thread_resources.env.physical_mesh
        if mesh.empty or any(ax is not None and ax not in mesh.axis_names
                             for ax in spec):
            return x
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except Exception:  # noqa: BLE001 -- sharding is best-effort here
        return x


@dataclasses.dataclass(frozen=True)
class MoEDims:
    n_experts: int
    top_k: int
    d_model: int
    d_ff: int
    n_shared: int = 0
    capacity_factor: float = 1.25
    # device-limited routing (DeepSeek-V2): tokens may route into at most
    # ``route_limit`` of ``route_groups`` expert groups (groups == EP
    # shards), bounding the all-to-all fan-out per token.
    route_groups: int = 0
    route_limit: int = 0
    # quantize the dispatch payload to int8 (per-token scale): halves the
    # dispatch leg of the a2a (DeepSeek-V3-style low-precision dispatch).
    int8_dispatch: bool = False


def moe_init(rng, dims: MoEDims, dtype=jnp.bfloat16) -> Dict:
    r = jax.random.split(rng, 5)
    e, d, f = dims.n_experts, dims.d_model, dims.d_ff
    def expert_stack(key, d_in, d_out):
        scale = 1.0 / jnp.sqrt(d_in)
        return (jax.random.normal(key, (e, d_in, d_out), jnp.float32)
                * scale).astype(dtype)
    p = {
        "router": L.dense_init(r[0], d, e, jnp.float32, scale=0.02),
        "w_gate": expert_stack(r[1], d, f),
        "w_up": expert_stack(r[2], d, f),
        "w_down": expert_stack(r[3], f, d),
    }
    if dims.n_shared:
        p["shared"] = L.swiglu_init(r[4], d, f * dims.n_shared, dtype)
    return p


def capacity(n_tokens: int, dims: MoEDims) -> int:
    per = n_tokens * dims.top_k * dims.capacity_factor / dims.n_experts
    return max(8, int(-(-per // 8) * 8))  # round up to 8


def moe_apply(p: Dict, x: jax.Array, dims: MoEDims
              ) -> Tuple[jax.Array, jax.Array]:
    """x: (T, d) flat tokens. Returns (out (T, d), aux_loss scalar)."""
    t, d = x.shape
    e, k = dims.n_experts, dims.top_k
    c = capacity(t, dims)

    router_logits = (x.astype(jnp.float32) @ p["router"])          # (T, E)
    probs = jax.nn.softmax(router_logits, axis=-1)
    if dims.route_groups > 1 and 0 < dims.route_limit < dims.route_groups:
        # device-limited routing: keep only experts in the token's top-M
        # groups (group affinity = max expert prob in the group)
        g = dims.route_groups
        per = e // g
        group_score = probs.reshape(t, g, per).max(axis=-1)        # (T, G)
        _, top_g = jax.lax.top_k(group_score, dims.route_limit)
        gmask = jnp.zeros((t, g), bool).at[
            jnp.arange(t)[:, None], top_g].set(True)
        probs = jnp.where(jnp.repeat(gmask, per, axis=1), probs, 0.0)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)                  # (T, K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # Switch aux loss: E * sum_e (fraction_e * mean_prob_e)
    one_hot = jax.nn.one_hot(gate_idx[:, 0], e, dtype=jnp.float32)
    fraction = jnp.mean(one_hot, axis=0)
    mean_prob = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(fraction * mean_prob)

    # ---- sort-based dispatch ------------------------------------------
    flat_e = gate_idx.reshape(-1)                                  # (T*K,)
    flat_t = jnp.repeat(jnp.arange(t), k)
    flat_w = gate_vals.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    sorted_t = flat_t[order]
    sorted_w = flat_w[order]
    counts = jnp.bincount(flat_e, length=e)                        # (E,)
    starts = jnp.cumsum(counts) - counts
    pos_in_e = jnp.arange(t * k) - starts[sorted_e]
    keep = pos_in_e < c

    scatter_e = jnp.where(keep, sorted_e, 0)
    scatter_p = jnp.where(keep, pos_in_e, c - 1)
    vals = jnp.where(keep[:, None], x[sorted_t], 0)
    vals = _maybe_shard(vals, "data", None)
    if dims.int8_dispatch:
        # quantize the payload that crosses the a2a; dequantize on the
        # expert's device (per-token symmetric scale)
        scale = jnp.maximum(jnp.max(jnp.abs(
            vals.astype(jnp.float32)), axis=-1, keepdims=True),
            1e-6) / 127.0
        q = jnp.clip(jnp.round(vals.astype(jnp.float32) / scale),
                     -127, 127).astype(jnp.int8)
        qbuf = jnp.zeros((e, c, d), jnp.int8).at[
            scatter_e, scatter_p].add(q, mode="drop")
        sbuf = jnp.zeros((e, c, 1), jnp.float32).at[
            scatter_e, scatter_p].add(scale, mode="drop")
        buf = (qbuf.astype(jnp.bfloat16)
               * sbuf.astype(jnp.bfloat16)).astype(x.dtype)
    else:
        buf = jnp.zeros((e, c, d), x.dtype).at[scatter_e, scatter_p].add(
            vals.astype(x.dtype), mode="drop")

    # ---- expert compute (E over 'data' (EP=DP); ff over 'model') ------
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])) \
        * jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    y = jnp.einsum("ecf,efd->ecd", h, p["w_down"])                 # (E,C,d)

    # ---- combine -------------------------------------------------------
    gathered = y[scatter_e, scatter_p]                             # (T*K, d)
    gathered = _maybe_shard(gathered, "data", None)
    gathered = jnp.where(keep[:, None], gathered, 0)
    # bf16 combine halves the (T*k, d) buffers; the residual stream and
    # gradient accumulation stay f32 upstream (§Perf A7b)
    contrib = (gathered * sorted_w[:, None].astype(gathered.dtype))
    out = jnp.zeros((t, d), contrib.dtype).at[sorted_t].add(contrib)
    out = _maybe_shard(out, "data", None)

    out = out.astype(x.dtype)
    if "shared" in p:
        out = out + L.swiglu(x, p["shared"])
    return out, aux
