"""GQA self-attention + cross-attention blocks with KV-cache serving.

Implementation notes (TPU posture):

* Full-sequence attention uses the flash-style impl switch from
  ``repro.kernels.flash_attention.ops`` -- ``chunked`` (jnp streaming
  softmax, compiles on every backend, O(S*BK) memory) by default,
  ``pallas`` on TPU.
* Decode uses ``repro.kernels.decode_attention.ops`` over the KV cache.
* KV cache layout: (B, S, Hkv, D), appended with a *masked* update
  (``where(iota == pos, new, cache)``): this keeps every dimension
  shardable (in particular S over the model axis for kv_heads < |model|)
  with zero collectives -- see DESIGN.md §5 and the §Perf log for the
  shard_map DUS variant.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.flash_attention.ops import attention as flash_attention
from repro.models import layers as L


def attn_init(rng, d_model: int, n_heads: int, n_kv_heads: int,
              head_dim: int, dtype=jnp.bfloat16) -> Dict:
    r = jax.random.split(rng, 4)
    return {
        "wq": L.dense_init(r[0], d_model, n_heads * head_dim, dtype),
        "wk": L.dense_init(r[1], d_model, n_kv_heads * head_dim, dtype),
        "wv": L.dense_init(r[2], d_model, n_kv_heads * head_dim, dtype),
        "wo": L.dense_init(r[3], n_heads * head_dim, d_model, dtype),
    }


def init_kv_cache(batch: int, max_seq: int, n_kv_heads: int, head_dim: int,
                  dtype=jnp.bfloat16) -> Dict:
    return {
        "k": jnp.zeros((batch, max_seq, n_kv_heads, head_dim), dtype),
        "v": jnp.zeros((batch, max_seq, n_kv_heads, head_dim), dtype),
    }


def cache_append(cache: Dict, k_new: jax.Array, v_new: jax.Array,
                 pos: jax.Array, *, impl: str = "scatter") -> Dict:
    """Single-position append. k_new/v_new: (B, Hkv, D); pos: (B,).

    'scatter' (§Perf iteration B1): one-row-per-batch scatter -- with
    buffer donation the cache is updated in place, so append traffic is
    O(B * Hkv * D) instead of the masked variant's full read+write of the
    cache (3x -> ~1x total decode cache bytes).  'masked' kept for A/B.
    """
    if impl == "masked":
        s = cache["k"].shape[1]
        slot = (jnp.arange(s)[None, :, None, None]
                == pos[:, None, None, None])
        return {
            "k": jnp.where(slot, k_new[:, None].astype(cache["k"].dtype),
                           cache["k"]),
            "v": jnp.where(slot, v_new[:, None].astype(cache["v"].dtype),
                           cache["v"]),
        }
    b = pos.shape[0]
    rows = jnp.arange(b)
    return {
        "k": cache["k"].at[rows, pos].set(
            k_new.astype(cache["k"].dtype), mode="drop"),
        "v": cache["v"].at[rows, pos].set(
            v_new.astype(cache["v"].dtype), mode="drop"),
    }


def attn_forward(p: Dict, x: jax.Array, *, n_heads: int, n_kv_heads: int,
                 head_dim: int, rope_theta: float, causal: bool = True,
                 positions: Optional[jax.Array] = None,
                 impl: str = "chunked", use_rope: bool = True) -> jax.Array:
    """Full-sequence self-attention. x: (B, S, d)."""
    b, s, _ = x.shape
    q = (x @ p["wq"]).reshape(b, s, n_heads, head_dim)
    k = (x @ p["wk"]).reshape(b, s, n_kv_heads, head_dim)
    v = (x @ p["wv"]).reshape(b, s, n_kv_heads, head_dim)
    if use_rope:
        pos = positions if positions is not None else jnp.arange(s)
        q = L.apply_rope(q.transpose(0, 2, 1, 3), pos, rope_theta)
        k = L.apply_rope(k.transpose(0, 2, 1, 3), pos, rope_theta)
    else:
        q = q.transpose(0, 2, 1, 3)
        k = k.transpose(0, 2, 1, 3)
    v = v.transpose(0, 2, 1, 3)
    o = flash_attention(q, k, v, causal=causal, impl=impl)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, n_heads * head_dim)
    return o @ p["wo"]


def attn_prefill(p: Dict, x: jax.Array, cache: Dict, *, n_heads: int,
                 n_kv_heads: int, head_dim: int, rope_theta: float,
                 impl: str = "chunked") -> Tuple[jax.Array, Dict]:
    """Prefill: full causal attention AND populate the cache."""
    b, s, _ = x.shape
    q = (x @ p["wq"]).reshape(b, s, n_heads, head_dim)
    k = (x @ p["wk"]).reshape(b, s, n_kv_heads, head_dim)
    v = (x @ p["wv"]).reshape(b, s, n_kv_heads, head_dim)
    pos = jnp.arange(s)
    qr = L.apply_rope(q.transpose(0, 2, 1, 3), pos, rope_theta)
    kr = L.apply_rope(k.transpose(0, 2, 1, 3), pos, rope_theta)
    vt = v.transpose(0, 2, 1, 3)
    o = flash_attention(qr, kr, vt, causal=True, impl=impl)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, n_heads * head_dim)
    new_cache = {
        "k": jax.lax.dynamic_update_slice(
            cache["k"], kr.transpose(0, 2, 1, 3).astype(cache["k"].dtype),
            (0, 0, 0, 0)),
        "v": jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0)),
    }
    return o @ p["wo"], new_cache


def attn_decode(p: Dict, x: jax.Array, cache: Dict, pos: jax.Array, *,
                n_heads: int, n_kv_heads: int, head_dim: int,
                rope_theta: float, impl: str = "chunked"
                ) -> Tuple[jax.Array, Dict]:
    """One-token decode. x: (B, d); pos: (B,) current lengths."""
    b, _ = x.shape
    q = (x @ p["wq"]).reshape(b, n_heads, head_dim)
    k = (x @ p["wk"]).reshape(b, n_kv_heads, head_dim)
    v = (x @ p["wv"]).reshape(b, n_kv_heads, head_dim)
    # rope at the current position (per batch row; explicit head axis)
    pos_b = pos[:, None, None]                       # (B, 1, 1)
    q = L.apply_rope(q[:, :, None, :], pos_b, rope_theta)[:, :, 0]
    k = L.apply_rope(k[:, :, None, :], pos_b, rope_theta)[:, :, 0]
    cache = cache_append(cache, k, v, pos)
    o = decode_attention(q, cache["k"], cache["v"], pos + 1, impl=impl)
    return o.reshape(b, n_heads * head_dim) @ p["wo"], cache


# --------------------------------------------------------------------- #
# cross-attention (VLM image layers, enc-dec decoder)
# --------------------------------------------------------------------- #
def cross_init(rng, d_model: int, n_heads: int, n_kv_heads: int,
               head_dim: int, dtype=jnp.bfloat16) -> Dict:
    return attn_init(rng, d_model, n_heads, n_kv_heads, head_dim, dtype)


def cross_forward(p: Dict, x: jax.Array, memory: jax.Array, *,
                  n_heads: int, n_kv_heads: int, head_dim: int,
                  impl: str = "chunked") -> jax.Array:
    """x: (B, S, d) queries; memory: (B, M, d). No rope, not causal."""
    b, s, _ = x.shape
    m = memory.shape[1]
    q = (x @ p["wq"]).reshape(b, s, n_heads, head_dim).transpose(0, 2, 1, 3)
    k = (memory @ p["wk"]).reshape(b, m, n_kv_heads, head_dim
                                   ).transpose(0, 2, 1, 3)
    v = (memory @ p["wv"]).reshape(b, m, n_kv_heads, head_dim
                                   ).transpose(0, 2, 1, 3)
    o = flash_attention(q, k, v, causal=False, impl=impl)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, n_heads * head_dim)
    return o @ p["wo"]


def cross_decode(p: Dict, x: jax.Array, memory_kv: Dict, *, n_heads: int,
                 n_kv_heads: int, head_dim: int,
                 impl: str = "chunked") -> jax.Array:
    """Decode-time cross-attention against precomputed memory K/V.

    x: (B, d); memory_kv: {'k','v': (B, M, Hkv, D)}.
    """
    b, _ = x.shape
    q = (x @ p["wq"]).reshape(b, n_heads, head_dim)
    m = memory_kv["k"].shape[1]
    lengths = jnp.full((b,), m, jnp.int32)
    o = decode_attention(q, memory_kv["k"], memory_kv["v"], lengths,
                         impl=impl)
    return o.reshape(b, n_heads * head_dim) @ p["wo"]


def memory_kv(p: Dict, memory: jax.Array, *, n_kv_heads: int,
              head_dim: int) -> Dict:
    """Precompute cross-attention K/V once per request (prefill)."""
    b, m, _ = memory.shape
    return {
        "k": (memory @ p["wk"]).reshape(b, m, n_kv_heads, head_dim),
        "v": (memory @ p["wv"]).reshape(b, m, n_kv_heads, head_dim),
    }
