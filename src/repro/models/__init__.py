"""Model substrate: every assigned architecture, pure JAX."""
