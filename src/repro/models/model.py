"""Model facade: build (init, loss, train_step, prefill, decode) per arch.

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for every
input of the cell's step function -- the dry-run lowers against these, so
no host memory is ever allocated for the full-size models.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeCell
from repro.models import layers as L
from repro.models import transformer as T
from repro.train import optimizer as OPT

AUX_WEIGHT = 0.01


# --------------------------------------------------------------------- #
# loss / train step
# --------------------------------------------------------------------- #
def loss_fn(params, cfg: ArchConfig, batch: Dict[str, jax.Array],
            attn_impl: str = "chunked", ssm_impl: str = "ref",
            remat: bool = False
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    logits, aux = T.forward_train(params, cfg, batch["tokens"],
                                  memory=batch.get("memory"),
                                  attn_impl=attn_impl, ssm_impl=ssm_impl,
                                  remat=remat)
    nll = L.cross_entropy(logits, batch["labels"])
    loss = nll + AUX_WEIGHT * aux
    return loss, {"nll": nll, "aux": aux}


def make_train_step(cfg: ArchConfig, opt_cfg: OPT.AdamWConfig,
                    attn_impl: str = "qchunk", ssm_impl: str = "ref",
                    n_micro: int = 1, remat: bool = True,
                    compress_grads: bool = False):
    """n_micro > 1 scans gradient-accumulation microbatches; remat wraps
    every scanned block in jax.checkpoint (activation recompute);
    compress_grads quantizes gradients to int8 with error feedback before
    the optimizer (the DP all-reduce then moves 1/4 the bytes -- the
    cross-pod DCI lever of DESIGN.md §5).  The EF residual rides in the
    returned opt_state tuple."""
    from repro.train import grad as G

    def lfn(p, b):
        return loss_fn(p, cfg, b, attn_impl, ssm_impl, remat=remat)

    def train_step(state, opt_state, batch):
        if compress_grads:
            params, ef = state
        else:
            params, ef = state, None
        loss, grads, metrics = G.accumulate_grads(lfn, params, batch,
                                                  n_micro)
        if compress_grads:
            grads, ef = G.compress_grads_ef(grads, ef)
        params, opt_state, opt_metrics = OPT.update(
            opt_cfg, params, grads, opt_state)
        metrics = dict(metrics, loss=loss, **opt_metrics)
        out_state = (params, ef) if compress_grads else params
        return out_state, opt_state, metrics
    return train_step


def make_prefill_step(cfg: ArchConfig, attn_impl: str = "chunked",
                      ssm_impl: str = "ref"):
    def prefill_step(params, tokens, caches, memory=None):
        return T.forward_prefill(params, cfg, tokens, caches,
                                 memory=memory, attn_impl=attn_impl,
                                 ssm_impl=ssm_impl)
    return prefill_step


def make_decode_step(cfg: ArchConfig, attn_impl: str = "xla"):
    # decode is a single-query attention: the full einsum + masked softmax
    # is optimal and fully shardable (no dynamic slices over the sharded
    # cache); 'chunked' only helps with a scan, which SPMD re-materializes.
    def decode_step(params, token, caches, pos):
        return T.forward_decode(params, cfg, token, caches, pos,
                                attn_impl=attn_impl)
    return decode_step


# --------------------------------------------------------------------- #
# shape-struct builders (dry-run inputs)
# --------------------------------------------------------------------- #
def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def memory_len(cfg: ArchConfig, shape: ShapeCell) -> int:
    """Stub modality-token count for VLM/audio frontends."""
    if cfg.family == "audio":
        # speech frames after the (stubbed) frontend: seq/4
        return max(16, shape.seq_len // 4)
    if cfg.family == "vlm":
        return cfg.frontend_tokens
    return 0


def param_specs(cfg: ArchConfig) -> Any:
    """ShapeDtypeStructs of the parameter pytree (no allocation)."""
    rng = jax.random.PRNGKey(0)
    return jax.eval_shape(lambda r: T.init_params(r, cfg), rng)


def cache_specs(cfg: ArchConfig, batch: int, max_seq: int,
                mem_len: int = 0) -> Any:
    return jax.eval_shape(
        lambda: T.init_caches(cfg, batch, max_seq, memory_len=mem_len))


def input_specs(cfg: ArchConfig, shape: ShapeCell) -> Dict[str, Any]:
    """All step-function inputs as ShapeDtypeStructs, per cell kind."""
    b, s = shape.global_batch, shape.seq_len
    mem = memory_len(cfg, shape)
    if shape.kind == "train":
        batch = {"tokens": _sds((b, s), jnp.int32),
                 "labels": _sds((b, s), jnp.int32)}
        if mem:
            batch["memory"] = _sds((b, mem, cfg.d_model), jnp.bfloat16)
        return {"batch": batch}
    if shape.kind == "prefill":
        out = {"tokens": _sds((b, s), jnp.int32),
               "caches": cache_specs(cfg, b, s, mem)}
        if mem:
            out["memory"] = _sds((b, mem, cfg.d_model), jnp.bfloat16)
        return out
    if shape.kind == "decode":
        return {"token": _sds((b,), jnp.int32),
                "caches": cache_specs(cfg, b, s, mem),
                "pos": _sds((b,), jnp.int32)}
    raise ValueError(shape.kind)


def opt_state_specs(cfg: ArchConfig) -> Any:
    ps = param_specs(cfg)
    return jax.eval_shape(lambda: OPT.init(jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype), ps)))


def param_count(cfg: ArchConfig) -> int:
    ps = param_specs(cfg)
    total = 0
    for s in jax.tree.leaves(ps):
        n = 1
        for d in s.shape:
            n *= int(d)   # python ints: no int32 overflow on 398B models
        total += n
    return total


def active_param_count(cfg: ArchConfig) -> int:
    """Active params per token (MoE: top_k + shared of the routed pool)."""
    total = param_count(cfg)
    if not cfg.n_experts:
        return total
    # subtract inactive experts
    e_ff = cfg.moe_d_ff or cfg.d_ff
    per_expert = 3 * cfg.d_model * e_ff
    n_moe_layers = sum(cfg.is_moe_layer(i) for i in range(cfg.n_layers))
    inactive = (cfg.n_experts - cfg.top_k) * per_expert * n_moe_layers
    return total - inactive
