"""Mamba-1 (S6) selective-SSM block -- Jamba's sequence mixer.

Block: in_proj -> (x, z); causal depthwise conv + SiLU on x; data-dependent
(dt, B, C) projections; diagonal selective scan (the ``ssm_scan`` kernel /
its jnp reference); gate by SiLU(z); out_proj.

Serving state per layer: conv tail (B, K-1, d_inner) + SSM state
(B, d_inner, N) -- O(1) per token, which is what makes the long_500k cell
tractable (DESIGN.md §Arch-applicability).
"""

from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.ssm_scan.ops import ssm_scan, single_step
from repro.models import layers as L


def mamba_init(rng, d_model: int, *, expand: int = 2, state: int = 16,
               conv: int = 4, dtype=jnp.bfloat16) -> Dict:
    d_inner = expand * d_model
    dt_rank = max(1, math.ceil(d_model / 16))
    r = jax.random.split(rng, 6)
    a = jnp.tile(jnp.arange(1, state + 1, dtype=jnp.float32)[None],
                 (d_inner, 1))
    return {
        "in_proj": L.dense_init(r[0], d_model, 2 * d_inner, dtype),
        "conv_w": (jax.random.normal(r[1], (conv, d_inner), jnp.float32)
                   * 0.1).astype(dtype),
        "x_proj": L.dense_init(r[2], d_inner, dt_rank + 2 * state, dtype),
        "dt_proj": L.dense_init(r[3], dt_rank, d_inner, dtype),
        "dt_bias": jnp.zeros((d_inner,), jnp.float32),
        "a_log": jnp.log(a),                        # (d_inner, N) f32
        "d_skip": jnp.ones((d_inner,), jnp.float32),
        "out_proj": L.dense_init(r[4], d_inner, d_model, dtype),
    }


def _causal_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv. x: (B, S, C); w: (K, C)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(k):  # K is tiny (4): unrolled taps fuse into one kernel
        out = out + xp[:, i:i + x.shape[1]].astype(jnp.float32) \
            * w[i].astype(jnp.float32)
    return out.astype(x.dtype)


def _split_xdbc(p: Dict, xc: jax.Array, state: int):
    dt_rank = p["dt_proj"].shape[0]
    xdbc = xc @ p["x_proj"]
    dt_r, b, c = jnp.split(xdbc, [dt_rank, dt_rank + state], axis=-1)
    dt = jax.nn.softplus(dt_r @ p["dt_proj"]
                         + p["dt_bias"].astype(xdbc.dtype))
    return dt, b, c


def mamba_forward(p: Dict, x: jax.Array, *, state: int = 16,
                  impl: str = "ref") -> jax.Array:
    """Train/prefill: x (B, S, d) -> (B, S, d)."""
    bsz, s, _ = x.shape
    xz = x @ p["in_proj"]
    xc, z = jnp.split(xz, 2, axis=-1)               # (B, S, d_inner)
    xc = jax.nn.silu(_causal_conv(xc, p["conv_w"]))
    dt, b, c = _split_xdbc(p, xc, state)
    a = -jnp.exp(p["a_log"])                        # (d_inner, N)
    y = ssm_scan(xc, dt, b, c, a, p["d_skip"], impl=impl)
    y = y * jax.nn.silu(z)
    return y @ p["out_proj"]


def init_mamba_cache(batch: int, d_model: int, *, expand: int = 2,
                     state: int = 16, conv: int = 4,
                     dtype=jnp.bfloat16) -> Dict:
    d_inner = expand * d_model
    return {
        "conv": jnp.zeros((batch, conv - 1, d_inner), dtype),
        "ssm": jnp.zeros((batch, d_inner, state), jnp.float32),
    }


def mamba_decode(p: Dict, x: jax.Array, cache: Dict, *, state: int = 16
                 ) -> Tuple[jax.Array, Dict]:
    """One token: x (B, d) -> (B, d); O(d_inner * N) state update."""
    xz = x @ p["in_proj"]
    xc, z = jnp.split(xz, 2, axis=-1)               # (B, d_inner)
    # conv over [cache_tail, x]
    window = jnp.concatenate([cache["conv"], xc[:, None]], axis=1)
    w = p["conv_w"].astype(jnp.float32)             # (K, d_inner)
    conv_out = jnp.sum(window.astype(jnp.float32) * w[None], axis=1)
    xc = jax.nn.silu(conv_out.astype(x.dtype))
    dt, b, c = _split_xdbc(p, xc, state)
    a = -jnp.exp(p["a_log"])
    h, y = single_step(cache["ssm"], xc, dt, b, c, a, p["d_skip"])
    y = y * jax.nn.silu(z)
    new_cache = {"conv": window[:, 1:].astype(cache["conv"].dtype),
                 "ssm": h}
    return y @ p["out_proj"], new_cache
