"""Architecture assembly: pattern-tiled blocks, scan-over-layers, serving.

Layer patterns (``ArchConfig.pattern``) tile to ``n_layers``; parameters
are *stacked per pattern slot* across repetitions and applied with
``jax.lax.scan`` so the HLO stays O(pattern) instead of O(n_layers) --
essential for 60-72-layer archs compiled for 512 devices.

Block kinds:
  'attn'   self-attention (+FFN)            -- dense/moe/hybrid layers
  'cross'  self-attention + cross-attention (+FFN)  -- VLM / enc-dec
  'mamba'  Mamba mixer (+FFN)               -- jamba
  'mlstm'  mLSTM block (self-contained, no FFN when d_ff == 0)
  'slstm'  sLSTM block (+FFN when d_ff > 0)

MoE placement: ``cfg.is_moe_layer(global_idx)``; with pattern length a
multiple of ``moe_every`` the slot's FFN kind is rep-invariant, which is
what makes the scan homogeneous.  ``first_layer_dense`` (deepseek-v2)
unrolls layer 0 outside the scan.
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as A
from repro.models import layers as L
from repro.models import mamba as M
from repro.models import mla as MLA
from repro.models import moe as MOE
from repro.models import xlstm as X


# --------------------------------------------------------------------- #
# helpers
# --------------------------------------------------------------------- #
def _norm_init(cfg: ArchConfig, d: int):
    return (L.rmsnorm_init(d) if cfg.norm == "rmsnorm"
            else L.layernorm_init(d))


def _norm(cfg: ArchConfig, x, p):
    return L.rmsnorm(x, p) if cfg.norm == "rmsnorm" else L.layernorm(x, p)


def _ffn_init(rng, cfg: ArchConfig, moe_layer: bool):
    if moe_layer:
        return MOE.moe_init(rng, _moe_dims(cfg))
    d_ff = cfg.dense_d_ff or cfg.d_ff
    if d_ff == 0:
        return None
    if cfg.act == "swiglu":
        return L.swiglu_init(rng, cfg.d_model, d_ff)
    return L.gelu_mlp_init(rng, cfg.d_model, d_ff)


def _moe_dims(cfg: ArchConfig) -> MOE.MoEDims:
    return MOE.MoEDims(cfg.n_experts, cfg.top_k, cfg.d_model,
                       cfg.moe_d_ff or cfg.d_ff, cfg.n_shared_experts,
                       cfg.capacity_factor,
                       route_groups=cfg.route_groups,
                       route_limit=cfg.route_limit,
                       int8_dispatch=cfg.int8_dispatch)


def _ffn_apply(cfg: ArchConfig, p, x2d: jax.Array, moe_layer: bool
               ) -> Tuple[jax.Array, jax.Array]:
    """x2d: (T, d). Returns (out, aux)."""
    if moe_layer:
        return MOE.moe_apply(p, x2d, _moe_dims(cfg))
    if cfg.act == "swiglu":
        return L.swiglu(x2d, p), jnp.float32(0.0)
    return L.gelu_mlp(x2d, p), jnp.float32(0.0)


def _mixer_init(rng, cfg: ArchConfig, kind: str):
    hd = cfg.resolved_head_dim
    if kind in ("attn", "cross"):
        if cfg.mla:
            p = {"self": MLA.mla_init(rng, cfg)}
        else:
            p = {"self": A.attn_init(rng, cfg.d_model, cfg.n_heads,
                                     cfg.n_kv_heads, hd)}
        if kind == "cross":
            r2 = jax.random.fold_in(rng, 1)
            p["cross"] = A.cross_init(r2, cfg.d_model, cfg.n_heads,
                                      cfg.n_kv_heads, hd)
            p["norm_c"] = _norm_init(cfg, cfg.d_model)
        return p
    if kind == "mamba":
        return {"mamba": M.mamba_init(rng, cfg.d_model,
                                      expand=cfg.ssm_expand,
                                      state=cfg.ssm_state,
                                      conv=cfg.ssm_conv)}
    if kind == "mlstm":
        return {"mlstm": X.mlstm_init(rng, cfg.d_model, cfg.n_heads)}
    if kind == "slstm":
        return {"slstm": X.slstm_init(rng, cfg.d_model, cfg.n_heads)}
    raise ValueError(kind)


def _block_init(rng, cfg: ArchConfig, kind: str, moe_layer: bool) -> Dict:
    r1, r2 = jax.random.split(rng)
    p = {"norm1": _norm_init(cfg, cfg.d_model),
         "mixer": _mixer_init(r1, cfg, kind)}
    ffn = _ffn_init(r2, cfg, moe_layer)
    if ffn is not None:
        p["norm2"] = _norm_init(cfg, cfg.d_model)
        p["ffn"] = ffn
    return p


# --------------------------------------------------------------------- #
# block apply (all modes)
# --------------------------------------------------------------------- #
def _block_apply(cfg: ArchConfig, kind: str, moe_layer: bool, p: Dict,
                 x: jax.Array, *, mode: str,
                 cache: Optional[Dict] = None,
                 pos: Optional[jax.Array] = None,
                 memory: Optional[jax.Array] = None,
                 memory_kv: Optional[Dict] = None,
                 causal: bool = True,
                 attn_impl: str = "chunked",
                 ssm_impl: str = "ref"):
    """Returns (x, new_cache, aux)."""
    hd = cfg.resolved_head_dim
    aux = jnp.float32(0.0)
    new_cache: Dict[str, Any] = {}
    h = _norm(cfg, x, p["norm1"])

    if kind in ("attn", "cross"):
        if mode == "train":
            if cfg.mla:
                o = MLA.mla_forward(p["mixer"]["self"], h, cfg,
                                    causal=causal, impl=attn_impl)
            else:
                o = A.attn_forward(p["mixer"]["self"], h,
                                   n_heads=cfg.n_heads,
                                   n_kv_heads=cfg.n_kv_heads, head_dim=hd,
                                   rope_theta=cfg.rope_theta,
                                   causal=causal, impl=attn_impl)
        elif mode == "prefill":
            if cfg.mla:
                o, kv = MLA.mla_prefill(p["mixer"]["self"], h,
                                        cache["kv"], cfg, impl=attn_impl)
            else:
                o, kv = A.attn_prefill(p["mixer"]["self"], h, cache["kv"],
                                       n_heads=cfg.n_heads,
                                       n_kv_heads=cfg.n_kv_heads,
                                       head_dim=hd,
                                       rope_theta=cfg.rope_theta,
                                       impl=attn_impl)
            new_cache["kv"] = kv
        else:  # decode
            if cfg.mla:
                o, kv = MLA.mla_decode(p["mixer"]["self"], h, cache["kv"],
                                       pos, cfg)
            else:
                o, kv = A.attn_decode(p["mixer"]["self"], h, cache["kv"],
                                      pos, n_heads=cfg.n_heads,
                                      n_kv_heads=cfg.n_kv_heads,
                                      head_dim=hd,
                                      rope_theta=cfg.rope_theta,
                                      impl=attn_impl)
            new_cache["kv"] = kv
        x = x + o
        if kind == "cross":
            hc = _norm(cfg, x, p["mixer"]["norm_c"])
            if mode == "decode":
                oc = A.cross_decode(p["mixer"]["cross"], hc,
                                    memory_kv, n_heads=cfg.n_heads,
                                    n_kv_heads=cfg.n_kv_heads, head_dim=hd,
                                    impl=attn_impl)
            else:
                oc = A.cross_forward(p["mixer"]["cross"], hc, memory,
                                     n_heads=cfg.n_heads,
                                     n_kv_heads=cfg.n_kv_heads,
                                     head_dim=hd, impl=attn_impl)
            x = x + oc

    elif kind == "mamba":
        if mode == "decode":
            o, mc = M.mamba_decode(p["mixer"]["mamba"], h, cache["mamba"],
                                   state=cfg.ssm_state)
            new_cache["mamba"] = mc
        else:
            o = M.mamba_forward(p["mixer"]["mamba"], h,
                                state=cfg.ssm_state, impl=ssm_impl)
            if mode == "prefill":
                # recompute final state cheaply is skipped: serving enters
                # decode with the scan's terminal state; for the dry-run
                # prefill cells the state is carried through new_cache
                new_cache["mamba"] = cache["mamba"]
        x = x + o

    elif kind == "mlstm":
        if mode == "decode":
            o, mc = X.mlstm_decode(p["mixer"]["mlstm"], h, cache["mlstm"],
                                   cfg.n_heads)
            new_cache["mlstm"] = mc
        else:
            o = X.mlstm_forward(p["mixer"]["mlstm"], h, cfg.n_heads)
            if mode == "prefill":
                new_cache["mlstm"] = cache["mlstm"]
        x = x + o

    elif kind == "slstm":
        if mode == "decode":
            o, sc = X.slstm_decode(p["mixer"]["slstm"], h, cache["slstm"],
                                   cfg.n_heads)
            new_cache["slstm"] = sc
        else:
            o = X.slstm_forward(p["mixer"]["slstm"], h, cfg.n_heads)
            if mode == "prefill":
                new_cache["slstm"] = cache["slstm"]
        x = x + o
    else:
        raise ValueError(kind)

    if "ffn" in p:
        h2 = _norm(cfg, x, p["norm2"])
        shp = h2.shape
        out, aux = _ffn_apply(cfg, p["ffn"], h2.reshape(-1, shp[-1]),
                              moe_layer)
        x = x + out.reshape(shp)
    return x, new_cache, aux


def _mask_padded(logits: jax.Array, cfg: ArchConfig) -> jax.Array:
    """-inf the vocab-padding tail so softmax/CE/sampling ignore it."""
    if cfg.padded_vocab == cfg.vocab:
        return logits
    keep = jnp.arange(cfg.padded_vocab) < cfg.vocab
    return jnp.where(keep, logits, -1e30)


# --------------------------------------------------------------------- #
# parameter init
# --------------------------------------------------------------------- #
def slot_kinds(cfg: ArchConfig) -> List[Tuple[str, bool]]:
    """(kind, is_moe) per pattern slot (rep-invariant by construction)."""
    pat = cfg.pattern
    n_prefix = 1 if cfg.first_layer_dense else 0
    out = []
    for j, kind in enumerate(pat):
        gidx = n_prefix + j  # any rep works; check invariance below
        out.append((kind, cfg.is_moe_layer(gidx)))
    # invariance check
    reps = (cfg.n_layers - n_prefix) // len(pat)
    for r in range(reps):
        for j, kind in enumerate(pat):
            gidx = n_prefix + r * len(pat) + j
            assert cfg.is_moe_layer(gidx) == out[j][1], (
                "pattern/moe_every mismatch: scan would be heterogeneous")
    return out


def n_scan_reps(cfg: ArchConfig) -> int:
    n_prefix = 1 if cfg.first_layer_dense else 0
    n = cfg.n_layers - n_prefix
    if n % len(cfg.pattern):
        raise ValueError(f"{cfg.name}: {n} layers not divisible by "
                         f"pattern {len(cfg.pattern)}")
    return n // len(cfg.pattern)


def init_params(rng, cfg: ArchConfig) -> Dict:
    reps = n_scan_reps(cfg)
    kinds = slot_kinds(cfg)
    r_embed, r_blocks, r_first, r_enc = jax.random.split(rng, 4)

    params: Dict[str, Any] = {
        "embed": L.embedding_init(r_embed, cfg.padded_vocab, cfg.d_model),
        "final_norm": _norm_init(cfg, cfg.d_model),
    }
    if cfg.first_layer_dense:
        params["first"] = _block_init(r_first, cfg, "attn", False)

    # stacked per-slot params: vmap the per-rep init over rep rngs
    slots = []
    for j, (kind, moe_layer) in enumerate(kinds):
        rj = jax.random.fold_in(r_blocks, j)
        rep_rngs = jax.random.split(rj, reps)
        stacked = jax.vmap(
            lambda r: _block_init(r, cfg, kind, moe_layer))(rep_rngs)
        slots.append(stacked)
    params["slots"] = slots

    if cfg.encoder_layers:
        enc_rngs = jax.random.split(r_enc, cfg.encoder_layers)
        params["encoder"] = jax.vmap(
            lambda r: _block_init(r, cfg, "attn", False))(enc_rngs)
        params["enc_norm"] = _norm_init(cfg, cfg.d_model)
    return params


# --------------------------------------------------------------------- #
# forward passes
# --------------------------------------------------------------------- #
def encode(params: Dict, cfg: ArchConfig, frames: jax.Array,
           attn_impl: str = "chunked") -> jax.Array:
    """Bidirectional encoder over precomputed frontend embeddings."""
    def body(x, p):
        x, _, _ = _block_apply(cfg, "attn", False, p, x, mode="train",
                               causal=False, attn_impl=attn_impl)
        return x, None
    x, _ = jax.lax.scan(body, frames, params["encoder"])
    return _norm(cfg, x, params["enc_norm"])


def _scan_blocks(params, cfg: ArchConfig, x, *, mode, caches=None,
                 pos=None, memory=None, memory_kv=None,
                 attn_impl="chunked", ssm_impl="ref", remat=False):
    """Apply prefix + pattern-scanned blocks.  Returns (x, new_caches, aux)."""
    kinds = slot_kinds(cfg)
    aux_total = jnp.float32(0.0)
    new_caches: Dict[str, Any] = {}

    if cfg.first_layer_dense:
        c = caches.get("first") if caches else None
        x, nc, aux = _block_apply(cfg, "attn", False, params["first"], x,
                                  mode=mode, cache=c, pos=pos,
                                  attn_impl=attn_impl, ssm_impl=ssm_impl)
        new_caches["first"] = nc
        aux_total += aux

    def body(carry, xs):
        x, aux_acc = carry
        slot_params, slot_caches, mem_kv_r = xs
        new_slot_caches = []
        for j, (kind, moe_layer) in enumerate(kinds):
            c = slot_caches[j] if slot_caches is not None else None
            mkv = (mem_kv_r[j] if (mem_kv_r is not None and
                                   kind == "cross") else None)
            x, nc, aux = _block_apply(
                cfg, kind, moe_layer, slot_params[j], x, mode=mode,
                cache=c, pos=pos, memory=memory, memory_kv=mkv,
                attn_impl=attn_impl, ssm_impl=ssm_impl)
            new_slot_caches.append(nc)
            aux_acc = aux_acc + aux
        return (x, aux_acc), new_slot_caches

    slot_caches = caches.get("slots") if caches else None
    mem_kv = caches.get("memory_kv") if (caches and mode == "decode") else None
    xs = (params["slots"], slot_caches, mem_kv)
    body_fn = jax.checkpoint(body) if remat else body
    (x, aux_total), new_slots = jax.lax.scan(body_fn, (x, aux_total), xs)
    new_caches["slots"] = new_slots
    if mem_kv is not None:
        new_caches["memory_kv"] = mem_kv
    return x, new_caches, aux_total


def forward_train(params, cfg: ArchConfig, tokens: jax.Array,
                  memory: Optional[jax.Array] = None,
                  attn_impl: str = "chunked", ssm_impl: str = "ref",
                  remat: bool = False) -> Tuple[jax.Array, jax.Array]:
    """tokens (B, S) -> (logits (B, S, V) f32, aux)."""
    x = L.embed(tokens, params["embed"])
    if cfg.encoder_layers and memory is not None:
        memory = encode(params, cfg, memory, attn_impl)
    x, _, aux = _scan_blocks(params, cfg, x, mode="train", memory=memory,
                             attn_impl=attn_impl, ssm_impl=ssm_impl,
                             remat=remat)
    x = _norm(cfg, x, params["final_norm"])
    logits = _mask_padded(L.unembed(x, params["embed"]), cfg)
    return logits, aux


def forward_prefill(params, cfg: ArchConfig, tokens: jax.Array,
                    caches: Dict, memory: Optional[jax.Array] = None,
                    attn_impl: str = "chunked", ssm_impl: str = "ref"
                    ) -> Tuple[jax.Array, Dict]:
    """Prefill: returns (last-token logits (B, V), populated caches)."""
    x = L.embed(tokens, params["embed"])
    if cfg.encoder_layers and memory is not None:
        memory = encode(params, cfg, memory, attn_impl)
    x, new_caches, _ = _scan_blocks(params, cfg, x, mode="prefill",
                                    caches=caches, memory=memory,
                                    attn_impl=attn_impl, ssm_impl=ssm_impl)
    x = _norm(cfg, x[:, -1], params["final_norm"])
    logits = _mask_padded(L.unembed(x, params["embed"]), cfg)
    if memory is not None:
        new_caches["memory_kv"] = build_memory_kv(params, cfg, memory)
    return logits, new_caches


def forward_decode(params, cfg: ArchConfig, token: jax.Array,
                   caches: Dict, pos: jax.Array,
                   attn_impl: str = "xla"
                   ) -> Tuple[jax.Array, Dict]:
    """One decode step. token (B,), pos (B,) -> (logits (B, V), caches)."""
    x = L.embed(token, params["embed"])
    x, new_caches, _ = _scan_blocks(params, cfg, x, mode="decode",
                                    caches=caches, pos=pos,
                                    attn_impl=attn_impl)
    x = _norm(cfg, x, params["final_norm"])
    logits = _mask_padded(L.unembed(x, params["embed"]), cfg)
    return logits, new_caches


def build_memory_kv(params, cfg: ArchConfig, memory: jax.Array):
    """Per cross-layer K/V over the (encoded) memory, stacked for scan."""
    kinds = slot_kinds(cfg)
    hd = cfg.resolved_head_dim
    reps = n_scan_reps(cfg)

    def one_rep(slot_params):
        out = []
        for j, (kind, _) in enumerate(kinds):
            if kind == "cross":
                out.append(A.memory_kv(slot_params[j]["mixer"]["cross"],
                                       memory, n_kv_heads=cfg.n_kv_heads,
                                       head_dim=hd))
            else:
                out.append({})
        return out

    return jax.vmap(one_rep)(params["slots"])


# --------------------------------------------------------------------- #
# cache init
# --------------------------------------------------------------------- #
def init_caches(cfg: ArchConfig, batch: int, max_seq: int,
                memory_len: int = 0) -> Dict:
    kinds = slot_kinds(cfg)
    reps = n_scan_reps(cfg)
    hd = cfg.resolved_head_dim

    def one_block_cache(kind: str) -> Dict:
        if kind in ("attn", "cross"):
            if cfg.mla:
                return {"kv": MLA.init_mla_cache(batch, max_seq, cfg)}
            return {"kv": A.init_kv_cache(batch, max_seq, cfg.n_kv_heads,
                                          hd)}
        if kind == "mamba":
            return {"mamba": M.init_mamba_cache(
                batch, cfg.d_model, expand=cfg.ssm_expand,
                state=cfg.ssm_state, conv=cfg.ssm_conv)}
        if kind == "mlstm":
            return {"mlstm": X.init_mlstm_cache(batch, cfg.d_model,
                                                cfg.n_heads)}
        if kind == "slstm":
            return {"slstm": X.init_slstm_cache(batch, cfg.d_model,
                                                cfg.n_heads)}
        raise ValueError(kind)

    def stack(tree):
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a, (reps,) + a.shape).copy(), tree)

    caches: Dict[str, Any] = {
        "slots": [stack(one_block_cache(kind)) for kind, _ in kinds]}
    if cfg.first_layer_dense:
        caches["first"] = one_block_cache("attn")
    if memory_len and any(k == "cross" for k, _ in kinds):
        mkv = {"k": jnp.zeros((batch, memory_len, cfg.n_kv_heads, hd),
                              jnp.bfloat16),
               "v": jnp.zeros((batch, memory_len, cfg.n_kv_heads, hd),
                              jnp.bfloat16)}
        caches["memory_kv"] = [
            (jax.tree.map(lambda a: jnp.broadcast_to(
                a, (reps,) + a.shape).copy(), mkv)
             if kind == "cross" else {})
            for kind, _ in kinds]
    return caches
