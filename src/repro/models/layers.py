"""Shared layers: norms, RoPE, MLPs, embeddings (pure JAX, bf16-friendly).

Parameter conventions: params are nested dicts of jnp arrays; every layer
exposes ``init(rng, ...) -> params`` and a pure apply function.  Compute
dtype follows the input; normalization statistics and softmax run in f32.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def _split(rng, n):
    return jax.random.split(rng, n)


def dense_init(rng, d_in: int, d_out: int, dtype=jnp.bfloat16,
               scale: float | None = None) -> jax.Array:
    scale = scale if scale is not None else (1.0 / jnp.sqrt(d_in))
    return (jax.random.normal(rng, (d_in, d_out), jnp.float32)
            * scale).astype(dtype)


def rmsnorm_init(d: int, dtype=jnp.bfloat16) -> jax.Array:
    return jnp.ones((d,), dtype)


def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w


def layernorm_init(d: int, dtype=jnp.bfloat16):
    return {"w": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)}


def layernorm(x: jax.Array, p, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(x.dtype) * p["w"] + p["b"]


# --------------------------------------------------------------------- #
# rotary position embeddings
# --------------------------------------------------------------------- #
def rope_frequencies(head_dim: int, theta: float = 10000.0) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array,
               theta: float = 10000.0) -> jax.Array:
    """x: (..., S, D) with positions (..., S) or (S,)."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                   # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin,
                           x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------- #
# MLPs
# --------------------------------------------------------------------- #
def swiglu_init(rng, d: int, d_ff: int, dtype=jnp.bfloat16):
    r1, r2, r3 = _split(rng, 3)
    return {"w_gate": dense_init(r1, d, d_ff, dtype),
            "w_up": dense_init(r2, d, d_ff, dtype),
            "w_down": dense_init(r3, d_ff, d, dtype)}

def swiglu(x: jax.Array, p) -> jax.Array:
    h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    return h @ p["w_down"]


def gelu_mlp_init(rng, d: int, d_ff: int, dtype=jnp.bfloat16):
    r1, r2 = _split(rng, 2)
    return {"w_in": dense_init(r1, d, d_ff, dtype),
            "w_out": dense_init(r2, d_ff, d, dtype)}


def gelu_mlp(x: jax.Array, p) -> jax.Array:
    return jax.nn.gelu(x @ p["w_in"]) @ p["w_out"]


# --------------------------------------------------------------------- #
# embeddings / unembedding
# --------------------------------------------------------------------- #
def embedding_init(rng, vocab: int, d: int, dtype=jnp.bfloat16):
    return (jax.random.normal(rng, (vocab, d), jnp.float32) * 0.02
            ).astype(dtype)


def embed(tokens: jax.Array, table: jax.Array) -> jax.Array:
    return jnp.take(table, tokens, axis=0)


def unembed(x: jax.Array, table: jax.Array) -> jax.Array:
    """Tied unembedding: logits = x @ table.T (f32 accumulate)."""
    return jax.lax.dot_general(
        x, table, (((x.ndim - 1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)


def chunked_remat_scan(step, carry, xs, chunk: int):
    """lax.scan with sqrt-style activation checkpointing over time.

    Reverse-mode through a T-step scan stores the carry at every step --
    catastrophic for recurrent states (mLSTM's (H,P,P) matrix memory at
    500k tokens).  Chunking the scan and rematerializing inside each chunk
    stores carries only at the T/chunk boundaries: memory drops from
    O(T * state) to O((T/chunk + chunk) * state) for a 2x recompute cost
    in backward -- the standard linear-RNN training recipe.
    """
    t = jax.tree.leaves(xs)[0].shape[0]
    if chunk <= 1 or t % chunk or t <= chunk:
        return jax.lax.scan(step, carry, xs)
    n = t // chunk
    xs_c = jax.tree.map(lambda a: a.reshape(n, chunk, *a.shape[1:]), xs)

    @jax.checkpoint
    def body(c, xc):
        return jax.lax.scan(step, c, xc)

    carry, ys = jax.lax.scan(body, carry, xs_c)
    ys = jax.tree.map(lambda a: a.reshape(t, *a.shape[2:]), ys)
    return carry, ys


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """logits (..., V) f32; labels (...). Mean NLL."""
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None],
                               axis=-1).squeeze(-1)
    return jnp.mean(logz - gold)
