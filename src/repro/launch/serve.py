"""Batched serving driver: prefill a prompt batch, then decode tokens.

    PYTHONPATH=src python -m repro.launch.serve --arch xlstm-125m \
        --reduced --batch 4 --prompt-len 32 --decode-tokens 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.models import model as MDL
from repro.models import transformer as T


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--decode-tokens", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--greedy", action="store_true", default=True)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    max_seq = args.prompt_len + args.decode_tokens
    print(f"[serve] {cfg.name}: batch={args.batch} "
          f"prompt={args.prompt_len} decode={args.decode_tokens}")

    rng = np.random.default_rng(args.seed)
    params = T.init_params(jax.random.PRNGKey(args.seed), cfg)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)),
        jnp.int32)
    memory = None
    mem_len = 0
    if cfg.family in ("vlm", "audio"):
        mem_len = 16
        memory = jnp.asarray(
            rng.standard_normal((args.batch, mem_len, cfg.d_model)) * 0.1,
            jnp.bfloat16)

    caches = T.init_caches(cfg, args.batch, max_seq, memory_len=mem_len)
    prefill = jax.jit(MDL.make_prefill_step(cfg))
    decode = jax.jit(MDL.make_decode_step(cfg), donate_argnums=(2,))

    t0 = time.time()
    if memory is not None:
        logits, caches = prefill(params, prompts, caches, memory)
    else:
        logits, caches = prefill(params, prompts, caches)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    tokens = [jnp.argmax(logits[:, :cfg.vocab], axis=-1).astype(jnp.int32)]
    t1 = time.time()
    for i in range(args.decode_tokens - 1):
        pos = jnp.full((args.batch,), args.prompt_len + i, jnp.int32)
        logits, caches = decode(params, tokens[-1], caches, pos)
        tokens.append(jnp.argmax(logits[:, :cfg.vocab],
                                 axis=-1).astype(jnp.int32))
    jax.block_until_ready(tokens[-1])
    t_decode = time.time() - t1

    out = np.stack([np.asarray(t) for t in tokens], axis=1)
    print(f"[serve] prefill: {t_prefill*1e3:.0f} ms "
          f"({args.batch*args.prompt_len/t_prefill:.0f} tok/s)")
    if args.decode_tokens > 1:
        per_tok = t_decode / (args.decode_tokens - 1)
        print(f"[serve] decode: {per_tok*1e3:.1f} ms/token "
              f"({args.batch/per_tok:.0f} tok/s batch-aggregate)")
    print(f"[serve] sample continuations (first 3 rows):")
    for row in out[:3]:
        print("   ", row[:12].tolist())


if __name__ == "__main__":
    main()
