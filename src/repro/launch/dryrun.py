import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds the production mesh, the step function for the
cell kind (train / prefill / decode), lowers it against ShapeDtypeStruct
inputs with full sharding annotations (never allocating the model), and
compiles.  Success proves the distribution config is coherent; the
compiled artifact yields the roofline terms (§Roofline).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch granite-3-8b \
        --shape train_4k --mesh single --out results/dryrun
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""

import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.analysis import flops as FL
from repro.analysis import hlo as hlo_mod
from repro.analysis import roofline as roof
from repro.configs import applicable_cells, get_arch, get_shape
from repro.launch import sharding as SH
from repro.launch.mesh import make_production_mesh
from repro.models import model as MDL
from repro.train import optimizer as OPT


def pick_n_micro(cfg, cell, mesh) -> int:
    """Gradient-accumulation microbatches: keep per-micro local batch >= 1
    while targeting <= ~8k local tokens per microbatch for big models."""
    if cell.kind != "train":
        return 1
    dp = 1
    for ax in SH.fit_batch_axes(mesh, cell.global_batch,
                                SH.batch_includes_model(cfg)):
        dp *= mesh.shape[ax]
    local_b = max(1, cell.global_batch // dp)
    # activation-footprint target: ~4k local tokens per microbatch for
    # dense archs; ~8k for FSDP/MoE archs (every extra microbatch re-
    # gathers the FSDP'd weights -- §Perf A6)
    tgt = 8192 if SH._needs_fsdp(cfg) else 4096
    want = -(-local_b * cell.seq_len // tgt)
    return max(1, min(local_b, want))


def build_lowerable(cfg, cell, mesh, *, attn_impl="chunked",
                    ssm_impl="ref", n_micro=None):
    """Returns (jitted_fn, arg_specs) ready to .lower(*arg_specs)."""
    specs = MDL.input_specs(cfg, cell)
    pspecs = MDL.param_specs(cfg)
    p_shard = SH.param_shardings(cfg, mesh, pspecs)

    if cell.kind == "train":
        ospecs = MDL.opt_state_specs(cfg)
        o_shard = SH.opt_state_shardings(cfg, mesh, ospecs)
        nm = n_micro or pick_n_micro(cfg, cell, mesh)
        # pre-shape the batch (n_micro, B_micro, ...) with explicit
        # sharding so GSPMD never guesses through the micro reshape
        bspec = specs["batch"]
        if nm > 1:
            bspec = jax.tree.map(
                lambda s_: jax.ShapeDtypeStruct(
                    (nm, s_.shape[0] // nm) + s_.shape[1:], s_.dtype),
                bspec)
        b_shard = SH.batch_shardings(mesh, bspec, cell.global_batch // nm,
                                     SH.batch_includes_model(cfg),
                                     micro_leading=(nm > 1))
        opt_cfg = OPT.AdamWConfig()
        train_attn = "qchunk" if attn_impl == "chunked" else attn_impl
        step = MDL.make_train_step(
            cfg, opt_cfg, attn_impl=train_attn, ssm_impl=ssm_impl,
            n_micro=nm, remat=True)
        fn = jax.jit(step,
                     in_shardings=(p_shard, o_shard, b_shard),
                     out_shardings=(p_shard, o_shard, None),
                     donate_argnums=(0, 1))
        return fn, (pspecs, ospecs, bspec)

    if cell.kind == "prefill":
        c_shard = SH.cache_shardings(cfg, mesh, specs["caches"],
                                     cell.global_batch)
        t_shard = SH.batch_shardings(mesh, specs["tokens"],
                                     cell.global_batch,
                                     SH.batch_includes_model(cfg))
        step = MDL.make_prefill_step(cfg, attn_impl=attn_impl,
                                     ssm_impl=ssm_impl)
        args = [pspecs, specs["tokens"], specs["caches"]]
        shards = [p_shard, t_shard, c_shard]
        if "memory" in specs:
            m_shard = SH.batch_shardings(mesh, specs["memory"],
                                         cell.global_batch)
            args.append(specs["memory"])
            shards.append(m_shard)
        fn = jax.jit(step, in_shardings=tuple(shards),
                     out_shardings=(None, None), donate_argnums=(2,))
        return fn, tuple(args)

    if cell.kind == "decode":
        c_shard = SH.cache_shardings(cfg, mesh, specs["caches"],
                                     cell.global_batch)
        t_shard = SH.batch_shardings(mesh, specs["token"],
                                     cell.global_batch)
        pos_shard = SH.batch_shardings(mesh, specs["pos"],
                                       cell.global_batch)
        # decode always uses the einsum path (see make_decode_step);
        # 'chunked' would force SPMD re-materialization of the cache scan
        decode_impl = "xla" if attn_impl == "chunked" else attn_impl
        step = MDL.make_decode_step(cfg, attn_impl=decode_impl)
        fn = jax.jit(step,
                     in_shardings=(p_shard, t_shard, c_shard, pos_shard),
                     out_shardings=(None, c_shard),
                     donate_argnums=(2,))
        return fn, (pspecs, specs["token"], specs["caches"], specs["pos"])

    raise ValueError(cell.kind)


def run_cell(arch: str, shape: str, mesh_kind: str, *,
             attn_impl="chunked", ssm_impl="ref") -> dict:
    cfg = get_arch(arch)
    cell = get_shape(shape)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    result = {"arch": arch, "shape": shape, "mesh": mesh_kind,
              "devices": int(len(jax.devices())),
              "mesh_shape": dict(mesh.shape),
              "attn_impl": attn_impl, "ssm_impl": ssm_impl}
    t0 = time.time()
    fn, args = build_lowerable(cfg, cell, mesh, attn_impl=attn_impl,
                               ssm_impl=ssm_impl)
    with mesh:
        lowered = fn.lower(*args)
        result["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        result["compile_s"] = round(time.time() - t1, 1)

    mem = compiled.memory_analysis()
    result["memory"] = {
        "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
        "output_bytes": getattr(mem, "output_size_in_bytes", None),
        "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
        "peak_bytes": (getattr(mem, "temp_size_in_bytes", 0) or 0)
        + (getattr(mem, "argument_size_in_bytes", 0) or 0),
    }
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    bytes_accessed = float(cost.get("bytes accessed", 0.0))
    result["cost"] = {"flops": flops, "bytes_accessed": bytes_accessed}

    text = compiled.as_text()
    result["collectives"] = hlo_mod.collective_bytes(text)
    result["collective_counts"] = hlo_mod.collective_count(text)

    # roofline terms: analytic (primary -- XLA cost_analysis counts scan
    # bodies once; see analysis/flops.py) + raw HLO kept for reference
    n_dev = result["devices"]
    dp = 1
    for ax in SH.fit_batch_axes(mesh, cell.global_batch,
                                SH.batch_includes_model(cfg)):
        dp *= mesh.shape[ax]
    dp = max(1, dp)
    tp = mesh.shape["model"] if not SH.batch_includes_model(cfg) else 1
    if "pod" in mesh.axis_names and cell.kind == "train":
        pass  # dp already includes pod via fit_batch_axes
    n_micro = pick_n_micro(cfg, cell, mesh)
    cost_a = FL.cell_cost(cfg, cell, n_dev, dp=dp, tp=tp,
                          n_micro=n_micro,
                          fsdp=SH._needs_fsdp(cfg),
                          append_impl="scatter",
                          param_dp=mesh.shape["data"])
    rl = roof.Roofline(flops=cost_a.flops, hbm_bytes=cost_a.hbm_bytes,
                       coll_bytes=max(cost_a.coll_bytes,
                                      result["collectives"].get("total", 0)),
                       model_flops=cost_a.model_flops)
    result["roofline"] = rl.report()
    result["roofline"]["residency_gb"] = round(
        cost_a.detail["residency_bytes"] / 1e9, 2)
    result["roofline"]["n_micro"] = n_micro
    result["roofline"]["dp"] = dp
    result["roofline"]["tp"] = tp
    result["analytic_detail"] = cost_a.detail
    result["ok"] = True
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None)
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--attn-impl", default="chunked")
    ap.add_argument("--ssm-impl", default="ref")
    ap.add_argument("--out", type=str, default="results/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.all:
        cells = list(applicable_cells())
    else:
        cells = [(args.arch, args.shape)]

    failures = 0
    for arch, shape in cells:
        for mesh_kind in meshes:
            name = f"{arch}__{shape}__{mesh_kind}.json"
            path = outdir / name
            if args.skip_existing and path.exists():
                prev = json.loads(path.read_text())
                if prev.get("ok"):
                    print(f"[skip] {name}")
                    continue
            t0 = time.time()
            try:
                res = run_cell(arch, shape, mesh_kind,
                               attn_impl=args.attn_impl,
                               ssm_impl=args.ssm_impl)
                rl = res["roofline"]
                print(f"[ok] {arch} {shape} {mesh_kind}: "
                      f"compile={res['compile_s']}s "
                      f"bottleneck={rl['bottleneck']} "
                      f"t={max(rl['t_compute_s'], rl['t_memory_s'], rl['t_collective_s']):.4f}s "
                      f"({time.time()-t0:.0f}s)")
            except Exception as e:  # noqa: BLE001 -- record and continue
                res = {"arch": arch, "shape": shape, "mesh": mesh_kind,
                       "ok": False, "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-4000:]}
                failures += 1
                print(f"[FAIL] {arch} {shape} {mesh_kind}: {e}")
            path.write_text(json.dumps(res, indent=1, default=str))
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
