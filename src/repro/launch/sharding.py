"""Per-architecture sharding rules (DP x TP x EP x SP on the production
mesh).

Rules are path-based over the parameter/cache pytrees; specs are written
for the *base* (unstacked) layer shapes and left-padded with ``None`` for
the scan-stacked leading rep dimension.

Key decisions (rationale in DESIGN.md §5):

* params: column-sharded in-projections / row-sharded out-projections
  (Megatron TP); expert dimension over ``model`` (EP); embeddings sharded
  on vocab; norms + small vectors replicated; xLSTM blocks replicated
  (125M params -- DP-only arch).
* KV caches: heads over ``model`` when ``n_kv_heads %% |model| == 0``,
  otherwise *sequence-sharded* (SP) -- the masked append keeps SP free of
  collectives; attention pays one tiny distributed-softmax all-reduce.
* MLA latent cache: sequence-sharded (latent dim stays whole so the
  absorbed-decode contractions stay local per shard).
* batch dims over ``('pod', 'data')``.
"""

from __future__ import annotations

from typing import Any, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig


def _dp(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def fit_batch_axes(mesh: Mesh, batch: int,
                   include_model: bool = False) -> tuple:
    """Largest prefix of the DP axes (optionally + model) whose product
    divides ``batch`` -- small serving batches (or batch=1 long-context
    decode) simply use fewer DP axes."""
    axes = []
    prod = 1
    candidates = _dp(mesh) + (("model",) if include_model else ())
    for ax in candidates:
        if batch % (prod * mesh.shape[ax]) == 0:
            axes.append(ax)
            prod *= mesh.shape[ax]
        else:
            break
    return tuple(axes)


def _model_size(mesh: Mesh) -> int:
    return mesh.shape["model"]


def _path_str(path) -> str:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        else:
            out.append(str(p))
    return "/".join(out)


def _pad(spec: P, ndim: int) -> P:
    """Left-pad a spec with None up to ndim (scan-stacked leading dims)."""
    missing = ndim - len(spec)
    if missing < 0:
        raise ValueError(f"spec {spec} longer than ndim {ndim}")
    return P(*([None] * missing + list(spec)))


# --------------------------------------------------------------------- #
# parameters
# --------------------------------------------------------------------- #
def _is_stacked(path: str) -> bool:
    """Scan-stacked pytrees (slots / encoder / memory_kv) carry a leading
    repetition dim; 'first'-layer and top-level leaves do not."""
    return ("slots/" in path or path.startswith("slots")
            or "encoder" in path or "memory_kv" in path)


def _param_spec(path: str, ndim: int, cfg: ArchConfig, mesh: Mesh) -> P:
    m = "model"
    base = ndim - (1 if _is_stacked(path) else 0)
    # xLSTM mixers: tiny -- replicate (DP-only)
    if "mlstm" in path or "slstm" in path:
        return _pad(P(), ndim)
    if "embed" in path:
        return _pad(P(m, None), ndim)
    # norms / scalars / biases
    if base <= 1 or "norm" in path:
        return _pad(P(), ndim)
    if "router" in path:
        return _pad(P(), ndim)
    # MoE expert stacks: base ndim 3 (E, d_in, d_out).
    # §Perf A1 (EP=DP) + A1b (EPxTP 2D): experts shard E over *data*
    # (dispatch = token all-to-all; expert grads local; no weight
    # gathers) and the ff dim over *model* (Megatron-MoE TP) so the
    # 398B-scale expert stacks split 256-ways for storage.
    if base == 3 and any(k in path for k in ("w_gate", "w_up")):
        return _pad(P("data", None, m), ndim)
    if base == 3 and "w_down" in path:
        return _pad(P("data", m, None), ndim)
    # MLA
    if any(k in path for k in ("w_dq", "w_dkv", "w_krope")):
        return _pad(P(None, None), ndim)
    if any(k in path for k in ("w_uq", "w_uk", "w_uv")):
        return _pad(P(None, m), ndim)
    # attention projections
    if any(k in path for k in ("wq", "wk", "wv")):
        hkv = cfg.n_kv_heads * cfg.resolved_head_dim
        if ("wk" in path or "wv" in path) and hkv % _model_size(mesh):
            return _pad(P(None, None), ndim)  # kv too narrow to shard
        return _pad(P(None, m), ndim)
    if "wo" in path:
        return _pad(P(m, None), ndim)
    # dense FFN (base ndim 2)
    if any(k in path for k in ("w_in", "w_gate", "w_up", "in_proj",
                                "dt_proj", "conv_w")):
        return _pad(P(None, m), ndim)
    if any(k in path for k in ("w_out", "w_down", "x_proj", "out_proj",
                                "a_log")):
        return _pad(P(m, None), ndim)
    if path.endswith("up") or "/up" in path:
        return _pad(P(None, m), ndim)
    if path.endswith("down") or "/down" in path:
        return _pad(P(m, None), ndim)
    return _pad(P(), ndim)


FSDP_PARAM_THRESHOLD = 20e9  # params above this also shard over 'data'


def _needs_fsdp(cfg: ArchConfig) -> bool:
    from repro.models.model import param_count
    return param_count(cfg) > FSDP_PARAM_THRESHOLD


def _uses_data(spec: P) -> bool:
    for ax in spec:
        if ax == "data" or (isinstance(ax, tuple) and "data" in ax):
            return True
    return False


def _add_fsdp(spec: P, shape, mesh: Mesh) -> P:
    """ZeRO-3/FSDP: also shard big weights over 'data' for storage --
    SPMD all-gathers them at use.  Picks the first un-sharded dim whose
    size divides |data|."""
    data = mesh.shape["data"]
    dims = list(spec) + [None] * (len(shape) - len(spec))
    for i, (d, sz) in enumerate(zip(dims, shape)):
        if d is None and sz % data == 0 and sz >= data:
            dims[i] = "data"
            return P(*dims)
    return spec


def param_shardings(cfg: ArchConfig, mesh: Mesh, specs: Any) -> Any:
    fsdp = _needs_fsdp(cfg)

    def assign(path, leaf):
        ps = _path_str(path)
        spec = _param_spec(ps, len(leaf.shape), cfg, mesh)
        if fsdp and leaf.ndim >= 2 and "norm" not in ps \
                and not _uses_data(spec):  # EP-sharded weights stay put
            spec = _add_fsdp(spec, leaf.shape, mesh)
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(assign, specs)


def opt_state_shardings(cfg: ArchConfig, mesh: Mesh, specs: Any) -> Any:
    """Optimizer state mirrors parameter sharding (mu/nu); step scalar
    replicated."""
    fsdp = _needs_fsdp(cfg)

    def assign(path, leaf):
        ps = _path_str(path)
        if leaf.ndim == 0 or ps.endswith("step") or "/step" in ps:
            return NamedSharding(mesh, P())
        spec = _param_spec(ps, len(leaf.shape), cfg, mesh)
        if fsdp and leaf.ndim >= 2 and "norm" not in ps \
                and not _uses_data(spec):  # EP-sharded weights stay put
            spec = _add_fsdp(spec, leaf.shape, mesh)
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(assign, specs)


# --------------------------------------------------------------------- #
# inputs / caches
# --------------------------------------------------------------------- #
def _fit(spec: P, ndim: int, stacked: bool) -> P:
    """Right-pad a *base* (batch-leading) spec with None to the base rank,
    then left-pad for the scan-stacking rep dim."""
    base = ndim - (1 if stacked else 0)
    body = list(spec) + [None] * (base - len(spec))
    if len(body) > base:
        raise ValueError(f"spec {spec} longer than base rank {base}")
    return P(*([None] if stacked else []), *body)


def _cache_spec(path: str, ndim: int, cfg: ArchConfig, mesh: Mesh,
                dp: tuple) -> P:
    m = "model"
    head_shard = cfg.n_kv_heads % _model_size(mesh) == 0
    stacked = "slots" in path or "memory_kv" in path
    if "mlstm" in path or "slstm" in path:
        return _fit(P(dp), ndim, stacked)         # batch-only
    if "memory_kv" in path:
        # (B, M, Hkv, D): heads if divisible else replicated M
        spec = P(dp, None, m, None) if head_shard else P(dp)
        return _fit(spec, ndim, stacked)
    if "c_kv" in path or "k_rope" in path:
        # MLA latent cache (B, S, L): sequence-sharded
        return _fit(P(dp, m, None), ndim, stacked)
    if path.endswith("/k") or path.endswith("/v") or "/kv/" in path:
        # (B, S, Hkv, D)
        spec = (P(dp, None, m, None) if head_shard
                else P(dp, m, None, None))
        return _fit(spec, ndim, stacked)
    if "conv" in path:
        return _fit(P(dp, None, m), ndim, stacked)  # (B, K-1, d_inner)
    if "ssm" in path:
        return _fit(P(dp, m, None), ndim, stacked)  # (B, d_inner, N)
    return _fit(P(dp), ndim, stacked)


def cache_shardings(cfg: ArchConfig, mesh: Mesh, specs: Any,
                    batch: int) -> Any:
    dp = fit_batch_axes(mesh, batch)

    def assign(path, leaf):
        return NamedSharding(
            mesh, _cache_spec(_path_str(path), len(leaf.shape), cfg, mesh,
                              dp))
    return jax.tree_util.tree_map_with_path(assign, specs)


def batch_shardings(mesh: Mesh, specs: Any, batch: int,
                    include_model: bool = False,
                    micro_leading: bool = False) -> Any:
    """Batch-dim sharding over as many DP axes as divide ``batch``;
    ``include_model`` folds the (otherwise idle) model axis into DP
    (xLSTM); ``micro_leading`` marks batches pre-shaped
    (n_micro, B_micro, ...) -- the microbatch dim stays unsharded so
    GSPMD never has to guess through the reshape."""
    dp = fit_batch_axes(mesh, batch, include_model)

    def assign(path, leaf):
        if not dp:
            return NamedSharding(mesh, P())
        lead = [None] if micro_leading else []
        spec = P(*lead, dp,
                 *([None] * (len(leaf.shape) - 1 - len(lead))))
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(assign, specs)


def batch_includes_model(cfg: ArchConfig) -> bool:
    return cfg.family == "ssm"  # xlstm: params replicated, model axis idle


def scalar_sharding(mesh: Mesh):
    return NamedSharding(mesh, P())
