"""Production mesh builders.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state; the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import and then calls this.

Mesh semantics (DESIGN.md §5):
  * ``pod``   -- data-parallel replicas across pods (gradients cross DCI)
  * ``data``  -- in-pod data parallelism
  * ``model`` -- tensor/expert/sequence parallelism inside a pod
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(data: int = 2, model: int = 2):
    """Small mesh for unit tests (requires >= data*model host devices)."""
    return jax.make_mesh((data, model), ("data", "model"))


def dp_axes(mesh) -> tuple:
    """Axes that carry data parallelism (pod joins data when present)."""
    return (("pod", "data") if "pod" in mesh.axis_names else ("data",))
