"""End-to-end training driver.

Runs any assigned architecture (full or ``--reduced``) on the local
devices with the full substrate: sharded params, AdamW, deterministic
data, fault-tolerant checkpointing on the ZNS-backed store, straggler
tracking.

    PYTHONPATH=src python -m repro.launch.train --arch xlstm-125m \
        --reduced --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.launch import sharding as SH
from repro.models import model as MDL
from repro.models import transformer as T
from repro.train import optimizer as OPT
from repro.train.checkpoint import CheckpointManager, ZNSTelemetry
from repro.train.data import SyntheticLM
from repro.train.loop import LoopConfig, fit


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", type=str, default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--fail-at", type=int, default=None)
    ap.add_argument("--zns-element", type=str, default="superblock",
                    choices=("superblock", "fixed"))
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    print(f"[train] {cfg.name} ({'reduced' if args.reduced else 'full'}): "
          f"{MDL.param_count(cfg)/1e6:.1f}M params, "
          f"batch={args.batch} seq={args.seq}")

    params = T.init_params(jax.random.PRNGKey(args.seed), cfg)
    opt_cfg = OPT.AdamWConfig(lr=args.lr, total_steps=args.steps,
                              warmup_steps=max(1, args.steps // 10))
    opt_state = OPT.init(params)
    train_step = jax.jit(MDL.make_train_step(cfg, opt_cfg),
                         donate_argnums=(0, 1))

    data = SyntheticLM(vocab=cfg.vocab, batch=args.batch, seq=args.seq,
                       seed=args.seed)

    ckpt = None
    zns = None
    if args.ckpt_dir:
        from repro.core import SUPERBLOCK, FIXED
        elem = SUPERBLOCK if args.zns_element == "superblock" else FIXED
        zns = ZNSTelemetry(element=elem)
        ckpt = CheckpointManager(args.ckpt_dir, keep=2, zns=zns)

    loop_cfg = LoopConfig(total_steps=args.steps,
                          ckpt_every=args.ckpt_every,
                          fail_at_step=args.fail_at)
    t0 = time.time()
    res = fit(train_step, params, opt_state, data, ckpt, loop_cfg)
    dt = time.time() - t0

    print(f"[train] done: {len(res.losses)} steps in {dt:.1f}s "
          f"({np.mean(res.step_times[1:] or [0])*1e3:.0f} ms/step)")
    if res.restored_from is not None:
        print(f"[train] restored from checkpoint step {res.restored_from}")
    if res.losses:
        print(f"[train] loss {res.losses[0]:.3f} -> {res.losses[-1]:.3f}")
    if res.stragglers:
        print(f"[train] straggler steps: {res.stragglers}")
    if zns is not None:
        rep = zns.report()
        print(f"[train] ZNS ckpt-store telemetry: DLWA={rep['dlwa']:.3f} "
              f"SA={rep['sa']:.3f} finishes={rep['finishes']:.0f} "
              f"resets={rep['resets']:.0f}")


if __name__ == "__main__":
    main()
