"""Multi-device ZNS arrays: log-structured RAID over emulated devices.

``ZNSArray`` stripes logical superzones across N :class:`ZNSDevice`
members at zone-chunk granularity with optional RAID-5-style
log-structured parity, and implements the same
:class:`repro.core.backend.ZoneBackend` surface as a single device --
``ZoneFS`` and everything above it mount either interchangeably.
"""

from repro.array.raid import (ArrayGeometry, SuperZoneInfo, TaggedTrace,
                              ZNSArray, data_device_of, locate_page,
                              parity_device_of)

__all__ = ["ArrayGeometry", "SuperZoneInfo", "TaggedTrace", "ZNSArray",
           "data_device_of", "locate_page", "parity_device_of"]
