"""Multi-device ZNS arrays: log-structured RAID over emulated devices.

``ZNSArray`` stripes logical superzones across N :class:`ZNSDevice`
members at zone-chunk granularity with optional RAID-5-style
log-structured parity, and implements the same
:class:`repro.core.backend.ZoneBackend` surface as a single device --
``ZoneFS`` and everything above it mount either interchangeably.

:class:`ArrayEngine` is the engine-native port of the same state
machine: zone commands compile to encoded per-member op programs that
execute in ONE batched ``run_programs`` dispatch (K arrays with mixed
member counts / chunk sizes / parity / element specs per batch), with
the object ``ZNSArray`` kept as the bit-exactness oracle.
``repro.array.storm`` runs batched rebuild storms on top of it.
"""

from repro.array.engine import (ArrayEngine, ArrayResult,
                                array_vs_legacy_speedup, apply_commands,
                                fill_commands, run_array_batch,
                                run_array_timing)
from repro.array.raid import (ArrayGeometry, SuperZoneInfo, TaggedTrace,
                              ZNSArray, data_device_of, locate_page,
                              member_chunk_pages, parity_device_of)
from repro.array.storm import StormScenario, rebuild_storm

__all__ = ["ArrayEngine", "ArrayGeometry", "ArrayResult", "StormScenario",
           "SuperZoneInfo", "TaggedTrace", "ZNSArray", "apply_commands",
           "array_vs_legacy_speedup", "data_device_of", "fill_commands",
           "locate_page", "member_chunk_pages", "parity_device_of",
           "rebuild_storm", "run_array_batch", "run_array_timing"]
