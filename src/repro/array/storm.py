"""Batched rebuild storms: K arrays x failure schedules in ONE dispatch.

The legacy ``benchmarks/raid_zns.py --rebuild`` mode times one
scenario at a time -- three per-scenario ``run_fleet_trace`` calls over
object-array traces.  Here every scenario compiles into THREE
engine-native arrays on one shared ``ZoneEngine``:

* ``host``      -- fill, then concurrent host writes alone,
* ``rebuild``   -- fill, fail a member, rebuild it (survivor degraded
  reads + replacement re-append) alone,
* ``contended`` -- fill, fail, rebuild *and* the host writes, the two
  streams round-robin merged per member lane (concurrent submission
  queues, the same merge model as ``timing.run_trace``),

and ALL ``3K`` arrays execute in one :func:`run_array_batch` dispatch
(obs telemetry optional) followed by ONE op-granular
:func:`simulate_fleet_ops` timing dispatch -- fill-phase rows are
masked out of the clock, so makespans cover only the storm phase.
``rebuild_interference = contended / host`` makespan, per scenario.

Repeated calls at the same scenario scale hit one compiled shape
(``pad_quantum`` rounds the op axis), which ``tools/bench.py`` asserts
with a ``RecompileCounter`` like the interference sweep.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.array.engine import (ArrayEngine, run_array_batch,
                                run_array_timing)
from repro.array.raid import ArrayGeometry
from repro.core import engine as zengine
from repro.core.elements import ElementSpec
from repro.core.engine import ZoneEngine


@dataclasses.dataclass(frozen=True)
class StormScenario:
    """One rebuild-storm cell: an array shape + a failure schedule.

    ``member_specs`` (optional) mixes element specs across members --
    the shared engine must then be built over the union of every
    scenario's specs.  ``fail_device`` defaults to the last member,
    like the legacy rebuild mode.
    """

    n_devices: int = 4
    chunk_pages: Optional[int] = None      # None -> one segment
    member_specs: Optional[Tuple[ElementSpec, ...]] = None
    n_zones_filled: int = 4
    occupancy: float = 0.6
    fail_device: Optional[int] = None
    host_occupancy: Optional[float] = None  # None -> occupancy

    def describe(self) -> str:
        spec = ("mix" if self.member_specs
                and len(set(self.member_specs)) > 1 else "uniform")
        return (f"d{self.n_devices}_c{self.chunk_pages or 'seg'}_"
                f"z{self.n_zones_filled}_o{self.occupancy:g}_{spec}")


def _rr_merge(a: List[tuple], b: List[tuple]) -> List[tuple]:
    """Round-robin interleave of two op-row streams (the concurrent
    submission-queue model ``timing`` uses to merge traces)."""
    out: List[tuple] = []
    for i in range(max(len(a), len(b))):
        if i < len(a):
            out.append(a[i])
        if i < len(b):
            out.append(b[i])
    return out


def _build_variant(eng: ZoneEngine, sc: StormScenario, *,
                   host: bool, rebuild: bool
                   ) -> Tuple[ArrayEngine, List[int]]:
    """Compile one scenario variant; returns the array and the per-lane
    fill-phase row counts (the prefix the timing clock masks out)."""
    chunk = (sc.chunk_pages if sc.chunk_pages is not None
             else eng.zone_geom.segment_pages(eng.flash))
    a = ArrayEngine(eng, ArrayGeometry(sc.n_devices, chunk, True),
                    member_specs=sc.member_specs)
    n_filled = min(sc.n_zones_filled, a.n_zones // 2, a.max_active)
    fill = max(1, int(round(a.zone_pages * sc.occupancy)))
    for z in range(n_filled):
        a.zone_write(z, fill)
        a.zone_finish(z)
    marks = [len(r) for r in a._rows]

    if rebuild:
        failed = (sc.fail_device if sc.fail_device is not None
                  else sc.n_devices - 1)
        a.fail_device(failed)
        a.rebuild_device(failed)
        marks[failed] = 0   # replacement lane: all rows are storm phase
    post_rebuild = [len(r) for r in a._rows]

    if host:
        host_fill = max(1, int(round(
            a.zone_pages * (sc.host_occupancy
                            if sc.host_occupancy is not None
                            else sc.occupancy))))
        for z in range(n_filled, min(2 * n_filled, a.n_zones)):
            a.zone_write(z, host_fill)

    if host and rebuild:
        # contended: merge the rebuild tail and the host tail per lane
        # round-robin -- appended sequentially they would serialize on
        # the member's LUN clock instead of contending
        for lane in range(sc.n_devices):
            rows = a._rows[lane]
            prefix = rows[: marks[lane]]
            reb = rows[marks[lane]: post_rebuild[lane]]
            hst = rows[post_rebuild[lane]:]
            a._rows[lane] = prefix + _rr_merge(hst, reb)
    return a, marks


def rebuild_storm(eng: ZoneEngine, scenarios: Sequence[StormScenario], *,
                  obs=None, pad_quantum: int = 64) -> Dict:
    """Run K rebuild-storm scenarios as one batched dispatch.

    Returns ``{"scenarios": [per-scenario report dicts],
    "telemetry": [per-scenario contended telemetry] | None}``; each
    report carries the legacy rebuild mode's keys (rebuild pages /
    traffic, host / rebuild / contended makespans, interference ratio)
    plus the scenario label.
    """
    scenarios = list(scenarios)
    if not scenarios:
        return {"scenarios": [], "telemetry": None}
    arrays: List[ArrayEngine] = []
    skips: List[List[int]] = []
    for sc in scenarios:
        for host, rebuild in ((True, False), (False, True), (True, True)):
            a, marks = _build_variant(eng, sc, host=host, rebuild=rebuild)
            arrays.append(a)
            skips.append(marks)

    results = run_array_batch(arrays, obs=obs, pad_quantum=pad_quantum)

    # ONE op-granular timing dispatch over every lane of every variant,
    # fill-phase pages zeroed so only the storm phase books LUN time
    programs = np.concatenate([r.programs for r in results])
    cols = np.concatenate([r.cols for r in results])
    pages = np.concatenate([r.pages for r in results]).copy()
    lane = 0
    for r, marks in zip(results, skips):
        for m in marks:
            pages[lane, :m] = 0
            lane += 1
    n_tenants = max(a.rebuild_tenant for a in arrays) + 1
    _, _, makespans = run_array_timing(
        eng.flash, programs, cols, pages, n_tenants=n_tenants)

    reports: List[Dict[str, float]] = []
    telemetry = [] if obs is not None else None
    lane = 0
    for k, sc in enumerate(scenarios):
        spans = []
        for v in range(3):
            a = arrays[3 * k + v]
            spans.append(float(
                makespans[lane: lane + a.geom.n_devices].max()))
            lane += a.geom.n_devices
        host_s, rebuild_s, contended_s = spans

        reb_arr = arrays[3 * k + 1]
        reb_res = results[3 * k + 1]
        failed = (sc.fail_device if sc.fail_device is not None
                  else sc.n_devices - 1)
        reb_mask = reb_res.tenants == reb_arr.rebuild_tenant
        is_read = reb_res.programs[:, :, 0] == zengine.OP_READ
        reports.append({
            "scenario": sc.describe(),
            "n_devices": float(sc.n_devices),
            "failed_device": float(failed),
            "rebuild_pages": float(
                reb_res.pages[failed][reb_mask[failed]].sum()),
            "rebuild_traffic_pages": float(
                reb_res.pages[reb_mask].sum()),
            "rebuild_read_pages": float(
                reb_res.pages[reb_mask & is_read].sum()),
            "host_makespan_s": host_s,
            "rebuild_makespan_s": rebuild_s,
            "contended_makespan_s": contended_s,
            "rebuild_interference": (contended_s / host_s if host_s
                                     else float("inf")),
        })
        if telemetry is not None:
            telemetry.append(results[3 * k + 2].telemetry)
    return {"scenarios": reports, "telemetry": telemetry}
