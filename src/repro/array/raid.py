"""Log-structured ZNS-RAID: superzones striped over N emulated devices.

Design follows Log-RAIZN-style zone-granular RAID (Li et al.,
arXiv:2402.17963) composed with the paper's SilentZNS allocation:

* A logical **superzone** ``z`` maps to physical zone ``z`` on *every*
  member device.  Its host-visible capacity is ``n_data * zone_pages``.
* Host pages are striped at **zone-chunk** granularity: ``chunk_pages``
  consecutive pages go to one device before the stripe rotates to the
  next.  Chunk row ``s`` of every member zone belongs to **stripe** ``s``,
  so each device sees a strictly sequential append stream -- exactly what
  a ZNS zone requires, and what lets SilentZNS allocate elements lazily
  underneath.
* With ``parity=True`` each stripe carries one parity chunk, rotated
  RAID-5 style across devices (``(superzone + stripe) % n_devices``).
  Parity is *log-structured*: it is appended when its stripe completes
  (or at FINISH for the final partial stripe), never updated in place.
* **Degraded reads**: with one device failed, a page on the failed device
  is reconstructed by reading the same chunk row from every surviving
  device.
* FINISH/RESET fan out to every member; member FINISH padding rolls up
  into the array's dummy-page count, so DLWA composes across layers.

The array implements :class:`repro.core.backend.ZoneBackend`, so
``ZoneFS`` (and the LSM / checkpoint workloads above it) mount it
unchanged.  A 1-device, parity-off array is bit-identical to the bare
device (tested).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.device import IOTrace, ZNSDevice, ZoneState
from repro.core.elements import ElementSpec
from repro.core.geometry import FlashGeometry, ZoneGeometry
from repro.core.metrics import wear_report


@dataclasses.dataclass(frozen=True)
class ArrayGeometry:
    """Shape of the RAID layer: member count, stripe unit, parity."""

    n_devices: int
    chunk_pages: int          # stripe unit (pages written per device turn)
    parity: bool = False

    def __post_init__(self) -> None:
        if self.n_devices < 1:
            raise ValueError("n_devices must be >= 1")
        if self.chunk_pages < 1:
            raise ValueError("chunk_pages must be >= 1")
        if self.parity and self.n_devices < 2:
            raise ValueError("parity needs >= 2 devices")

    @property
    def n_data(self) -> int:
        """Data chunks per stripe (devices minus the parity chunk)."""
        return self.n_devices - (1 if self.parity else 0)

    def describe(self) -> str:
        return (f"D{self.n_devices} x C{self.chunk_pages}"
                f"{'+P' if self.parity else ''}")


@dataclasses.dataclass
class SuperZoneInfo:
    """Host-visible state of one superzone (logical page units)."""
    state: ZoneState = ZoneState.EMPTY
    wp: int = 0                # logical data pages written (host + padding)
    host_wp: int = 0           # logical data pages written by the host
    parity_emitted: int = 0    # stripes whose parity chunk has been written


#: (device index, per-device trace) -- the array's tagged trace unit.
TaggedTrace = Tuple[int, IOTrace]


# --------------------------------------------------------------------- #
# stripe math (module-level: shared with the program-space striper in
# repro.fleet.tenants, so there is exactly one source of truth)
# --------------------------------------------------------------------- #
def parity_device_of(zone_id: int, stripe: int, n_devices: int) -> int:
    """Member holding ``stripe``'s parity chunk (RAID-5 rotation)."""
    return (zone_id + stripe) % n_devices


def data_device_of(zone_id: int, stripe: int, slot: int, n_devices: int,
                   parity: bool) -> int:
    """Member holding data slot ``slot`` of ``stripe`` (skipping the
    stripe's parity device when parity is on)."""
    if not parity:
        return slot
    p = parity_device_of(zone_id, stripe, n_devices)
    return slot if slot < p else slot + 1


def locate_page(zone_id: int, page: int, chunk_pages: int, n_data: int,
                n_devices: int, parity: bool) -> Tuple[int, int, int, int]:
    """Logical page -> (stripe, data slot, page-in-chunk, device)."""
    stripe, off = divmod(page, chunk_pages * n_data)
    slot, r = divmod(off, chunk_pages)
    return stripe, slot, r, data_device_of(zone_id, stripe, slot,
                                           n_devices, parity)


def member_chunk_pages(zone_id: int, stripe: int, idx: int, *,
                       chunk_pages: int, n_data: int, n_devices: int,
                       parity: bool, wp: int, parity_emitted: int) -> int:
    """Pages member ``idx`` physically wrote for chunk row ``stripe`` of
    superzone ``zone_id`` (its parity chunk, or its data chunk's written
    prefix), reconstructed from superzone metadata alone -- the member
    itself may be gone.  Shared by the object array's rebuild and the
    engine-native compiler's degraded-read / rebuild planners."""
    c = chunk_pages
    if parity:
        p = parity_device_of(zone_id, stripe, n_devices)
        if p == idx:
            return c if stripe < parity_emitted else 0
        slot = idx if idx < p else idx - 1
    else:
        slot = idx
    if slot >= n_data:
        return 0
    start = stripe * c * n_data + slot * c
    return max(0, min(c, wp - start))


class ZNSArray:
    """N independent :class:`ZNSDevice` members behind one zone surface."""

    def __init__(self, devices: Sequence[ZNSDevice], geom: ArrayGeometry):
        if len(devices) != geom.n_devices:
            raise ValueError(
                f"got {len(devices)} devices for geometry {geom.describe()}")
        zp = {d.zone_pages for d in devices}
        if len(zp) != 1:
            raise ValueError("member devices must share a zone geometry")
        self.devices = list(devices)
        self.geom = geom
        self.dev_zone_pages = zp.pop()
        if self.dev_zone_pages % geom.chunk_pages:
            raise ValueError(
                f"chunk_pages={geom.chunk_pages} must divide the member "
                f"zone capacity ({self.dev_zone_pages} pages)")
        self.stripes_per_zone = self.dev_zone_pages // geom.chunk_pages
        self.n_zones = min(d.n_zones for d in devices)
        self.max_active = min(d.max_active for d in devices)
        self.flash: FlashGeometry = devices[0].flash
        self.zones: Dict[int, SuperZoneInfo] = {
            z: SuperZoneInfo() for z in range(self.n_zones)}
        self.failed: set[int] = set()

        # array-level counters (logical pages)
        self.host_pages = 0
        self.parity_pages = 0

    # ------------------------------------------------------------------ #
    # construction helper
    # ------------------------------------------------------------------ #
    @classmethod
    def build(cls, flash: FlashGeometry, zone_geom: ZoneGeometry,
              spec: ElementSpec, *, n_devices: int,
              chunk_pages: Optional[int] = None, parity: bool = False,
              **device_kw) -> "ZNSArray":
        """Construct ``n_devices`` identical members and the array over
        them.  ``chunk_pages`` defaults to one segment (P erase-block
        rows), the natural stripe unit for the striped write order."""
        devices = [ZNSDevice(flash, zone_geom, spec, **device_kw)
                   for _ in range(n_devices)]
        if chunk_pages is None:
            chunk_pages = zone_geom.segment_pages(flash)
        return cls(devices, ArrayGeometry(n_devices, chunk_pages, parity))

    # ------------------------------------------------------------------ #
    # geometry / metrics (ZoneBackend surface)
    # ------------------------------------------------------------------ #
    @property
    def zone_pages(self) -> int:
        """Host-visible capacity of a superzone (data chunks only)."""
        return self.dev_zone_pages * self.geom.n_data

    @property
    def dummy_pages(self) -> int:
        return sum(d.dummy_pages for d in self.devices)

    @property
    def dlwa(self) -> float:
        """Array-level DLWA: every page the fleet programs (data + parity
        + member FINISH padding) per host data page."""
        if self.host_pages == 0:
            return 1.0
        return ((self.host_pages + self.parity_pages + self.dummy_pages)
                / self.host_pages)

    @property
    def n_active(self) -> int:
        return sum(1 for z in self.zones.values()
                   if z.state is ZoneState.OPEN)

    # ------------------------------------------------------------------ #
    # stripe math
    # ------------------------------------------------------------------ #
    def _parity_device(self, zone_id: int, stripe: int) -> int:
        return parity_device_of(zone_id, stripe, self.geom.n_devices)

    def _data_device(self, zone_id: int, stripe: int, slot: int) -> int:
        return data_device_of(zone_id, stripe, slot, self.geom.n_devices,
                              self.geom.parity)

    def _locate(self, zone_id: int, page: int) -> Tuple[int, int, int, int]:
        """Logical page -> (stripe, data slot, page-in-chunk, device)."""
        return locate_page(zone_id, page, self.geom.chunk_pages,
                           self.geom.n_data, self.geom.n_devices,
                           self.geom.parity)

    # ------------------------------------------------------------------ #
    # ZNS commands (ZoneBackend surface)
    # ------------------------------------------------------------------ #
    def zone_write(self, zone_id: int, n_pages: int, *, host: bool = True,
                   trace: bool = False) -> Optional[List[TaggedTrace]]:
        info = self.zones[zone_id]
        if info.state is ZoneState.FULL:
            raise RuntimeError(f"write to FULL superzone {zone_id}")
        if info.state is ZoneState.EMPTY:
            if self.n_active >= self.max_active:
                raise RuntimeError(
                    f"open/active superzone limit ({self.max_active}) "
                    "reached")
            info.state = ZoneState.OPEN
        if info.wp + n_pages > self.zone_pages:
            raise RuntimeError(
                f"superzone {zone_id} overflow: wp={info.wp} + {n_pages} "
                f"> {self.zone_pages}")

        traces: List[TaggedTrace] = []
        c = self.geom.chunk_pages
        remaining = n_pages
        page = info.wp
        while remaining > 0:
            stripe, slot, r, dev_idx = self._locate(zone_id, page)
            # parity for every completed stripe must land before this
            # device appends its next chunk row (log-structured order)
            self._emit_parity(zone_id, info, upto_stripe=stripe,
                              trace=trace, traces=traces)
            take = min(c - r, remaining)
            tr = self.devices[dev_idx].zone_write(
                zone_id, take, host=host, trace=trace)
            if trace and tr is not None:
                traces.append((dev_idx, tr))
            page += take
            remaining -= take
        info.wp = page
        if host:
            info.host_wp += n_pages
            self.host_pages += n_pages
        # stripe that just completed exactly at wp
        self._emit_parity(zone_id, info,
                          upto_stripe=info.wp // (c * self.geom.n_data),
                          trace=trace, traces=traces)
        if info.wp == self.zone_pages:
            info.state = ZoneState.FULL
        return traces if trace else None

    def _emit_parity(self, zone_id: int, info: SuperZoneInfo, *,
                     upto_stripe: int, trace: bool,
                     traces: List[TaggedTrace]) -> None:
        """Append parity chunks for every completed stripe < upto_stripe."""
        if not self.geom.parity:
            return
        c = self.geom.chunk_pages
        while info.parity_emitted < upto_stripe:
            s = info.parity_emitted
            p = self._parity_device(zone_id, s)
            tr = self.devices[p].zone_write(zone_id, c, host=True,
                                            trace=trace)
            if trace and tr is not None:
                traces.append((p, tr))
            self.parity_pages += c
            info.parity_emitted += 1

    def zone_finish(self, zone_id: int, *, trace: bool = False
                    ) -> Optional[List[TaggedTrace]]:
        """FINISH a superzone.

        1. the final partial stripe (if any) gets its parity chunk --
           parity covers the written prefix, unwritten data reads as
           zeros (log-structured RAID semantics);
        2. every member zone is FINISHed, padding partially-written
           elements (rolls up into ``dummy_pages``).
        """
        info = self.zones[zone_id]
        if info.state is ZoneState.FULL:
            return None
        traces: List[TaggedTrace] = []
        if info.state is ZoneState.OPEN:
            c, k = self.geom.chunk_pages, self.geom.n_data
            full_stripes = info.wp // (c * k)
            self._emit_parity(zone_id, info, upto_stripe=full_stripes,
                              trace=trace, traces=traces)
            if self.geom.parity and info.wp % (c * k):
                # parity over the partial stripe: a full chunk, appended
                # to the stripe's parity device before its zone pads
                s = full_stripes
                p = self._parity_device(zone_id, s)
                tr = self.devices[p].zone_write(zone_id, c, host=True,
                                                trace=trace)
                if trace and tr is not None:
                    traces.append((p, tr))
                self.parity_pages += c
                info.parity_emitted += 1
        for i, dev in enumerate(self.devices):
            tr = dev.zone_finish(zone_id, trace=trace)
            if trace and tr is not None and len(tr.luns):
                traces.append((i, tr))
        info.state = ZoneState.FULL
        return traces if trace else None

    def zone_reset(self, zone_id: int) -> None:
        for dev in self.devices:
            dev.zone_reset(zone_id)
        self.zones[zone_id] = SuperZoneInfo()

    def zone_read(self, zone_id: int, pages: np.ndarray
                  ) -> List[TaggedTrace]:
        """Read logical pages; reconstructs pages on failed devices from
        the surviving members of their stripe (degraded read)."""
        info = self.zones[zone_id]
        if info.state is ZoneState.EMPTY:
            raise RuntimeError(f"read from unmapped superzone {zone_id}")
        c = self.geom.chunk_pages
        per_dev: List[List[int]] = [[] for _ in self.devices]
        for page in np.asarray(pages, dtype=np.int64):
            stripe, _, r, dev_idx = self._locate(zone_id, int(page))
            if dev_idx in self.failed:
                if not self.geom.parity:
                    raise RuntimeError(
                        f"device {dev_idx} failed and parity is off: "
                        f"superzone {zone_id} page {int(page)} lost")
                if stripe >= info.parity_emitted:
                    # log-structured parity is appended only once the
                    # stripe completes (or at FINISH); until then a lost
                    # chunk of the open stripe is unrecoverable
                    raise RuntimeError(
                        f"superzone {zone_id} page {int(page)}: stripe "
                        f"{stripe} parity not yet written, page lost")
                # degraded: same chunk row from every surviving member
                # that physically wrote it -- chunks a FINISHed partial
                # stripe never wrote contribute zeros to the parity and
                # need no read
                off = stripe * c + r
                for other in range(self.geom.n_devices):
                    if other == dev_idx or other in self.failed:
                        continue
                    if self.devices[other].zones[zone_id].wp <= off:
                        continue
                    per_dev[other].append(off)
            else:
                per_dev[dev_idx].append(stripe * c + r)
        out: List[TaggedTrace] = []
        for i, plist in enumerate(per_dev):
            if not plist:
                continue
            tr = self.devices[i].zone_read(
                zone_id, np.asarray(plist, dtype=np.int64))
            out.append((i, tr))
        return out

    # ------------------------------------------------------------------ #
    # failure injection + rebuild
    # ------------------------------------------------------------------ #
    def fail_device(self, idx: int) -> None:
        if self.geom.parity and len(self.failed) >= 1 and idx not in self.failed:
            raise RuntimeError("single-parity array cannot survive a "
                               "second device failure")
        self.failed.add(idx)

    def heal_device(self, idx: int) -> None:
        self.failed.discard(idx)

    def _member_chunk(self, zone_id: int, stripe: int, idx: int,
                      info: SuperZoneInfo) -> int:
        """Pages member ``idx`` physically wrote for chunk row ``stripe``
        of ``zone_id`` -- see :func:`member_chunk_pages` (module-level so
        the engine-native planner shares the same source of truth)."""
        return member_chunk_pages(
            zone_id, stripe, idx, chunk_pages=self.geom.chunk_pages,
            n_data=self.geom.n_data, n_devices=self.geom.n_devices,
            parity=self.geom.parity, wp=info.wp,
            parity_emitted=info.parity_emitted)

    def rebuild_device(self, idx: int) -> List[TaggedTrace]:
        """Replace member ``idx`` with a blank device and reconstruct its
        chunks (data *and* rotated parity) from the survivors.

        For every chunk row the lost member held, the same row is read
        from each surviving member that wrote it (stripe XOR, exactly the
        degraded-read access pattern) and the reconstructed chunk is
        appended to the replacement -- a strictly sequential per-zone
        stream, so SilentZNS allocation works unchanged underneath.
        Zones of FULL superzones are FINISHed on the replacement.

        Returns the rebuild's tagged traces (reads on survivors, writes
        on the replacement) for :func:`repro.core.timing.run_fleet_trace`
        interference studies; the replacement is installed and the member
        healed on return.
        """
        if not self.geom.parity:
            raise RuntimeError("rebuild requires parity")
        if any(f != idx for f in self.failed):
            raise RuntimeError("cannot rebuild with another member down")
        old = self.devices[idx]
        replacement = ZNSDevice(old.flash, old.zone_geom, old.spec,
                                max_active=old.max_active)
        c = self.geom.chunk_pages
        tagged: List[TaggedTrace] = []
        for z, info in self.zones.items():
            if info.wp == 0 and info.parity_emitted == 0:
                continue
            wrote = 0
            for s in range(self.stripes_per_zone):
                pages_here = self._member_chunk(z, s, idx, info)
                if pages_here <= 0:
                    continue
                off = s * c
                for other in range(self.geom.n_devices):
                    if other == idx or other in self.failed:
                        continue
                    dwp = self.devices[other].zones[z].wp
                    if dwp <= off:
                        continue
                    n_read = min(pages_here, dwp - off)
                    tr = self.devices[other].zone_read(
                        z, np.arange(off, off + n_read, dtype=np.int64))
                    tagged.append((other, tr))
                tr = replacement.zone_write(z, pages_here, trace=True)
                tagged.append((idx, tr))
                wrote += pages_here
            if info.state is ZoneState.FULL and wrote > 0:
                tr = replacement.zone_finish(z, trace=True)
                if tr is not None and len(tr.luns):
                    tagged.append((idx, tr))
        self.devices[idx] = replacement
        self.failed.discard(idx)
        return tagged

    # ------------------------------------------------------------------ #
    # rollups
    # ------------------------------------------------------------------ #
    def device_reports(self) -> List[Dict[str, float]]:
        """Per-member DLWA / wear / erase rollup (paper metrics, fleet
        edition)."""
        out = []
        for i, dev in enumerate(self.devices):
            rep = {"device": float(i),
                   "dlwa": dev.dlwa,
                   "host_pages": float(dev.host_pages),
                   "dummy_pages": float(dev.dummy_pages),
                   "failed": float(i in self.failed)}
            rep.update(wear_report(dev))
            out.append(rep)
        return out

    def report(self) -> Dict[str, float]:
        """Array-level rollup: logical traffic + fleet aggregates."""
        per = self.device_reports()
        return {
            "n_devices": float(self.geom.n_devices),
            "chunk_pages": float(self.geom.chunk_pages),
            "parity": float(self.geom.parity),
            "host_pages": float(self.host_pages),
            "parity_pages": float(self.parity_pages),
            "dummy_pages": float(self.dummy_pages),
            "dlwa": self.dlwa,
            "parity_overhead": (self.parity_pages / self.host_pages
                                if self.host_pages else 0.0),
            "max_device_dlwa": max(r["dlwa"] for r in per),
            "total_block_erases": sum(r["total_block_erases"] for r in per),
            "total_incl_pending": sum(r["total_incl_pending"] for r in per),
            "max_wear": max(r["max_wear"] for r in per),
        }
