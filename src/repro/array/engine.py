"""Engine-native ZNS-RAID: the array data plane compiled onto ``ZoneEngine``.

:class:`ArrayEngine` keeps :class:`repro.array.raid.ZNSArray`'s exact
state machine -- zone-chunk striping, rotated append-only parity,
degraded reads, ``rebuild_device`` -- but *compiles* it instead of
interpreting it: every zone command is lowered host-side into encoded
per-member op rows (using the same module-level stripe math
``fleet/tenants.py`` shares with the object array), and the whole
member fleet then executes in ONE batched ``run_programs`` dispatch --
one ``lax.scan`` per member lane, all lanes in one ``lax.map``.

The host side keeps only the superzone mirror (``SuperZoneInfo`` per
zone, the same metadata the object array keeps): enough to validate
commands eagerly with the object array's exact errors, to route
degraded reads to the surviving members that physically wrote a chunk
row, and to plan a rebuild without touching device state.  Because the
engine's ``OP_READ`` is state-neutral and a rebuilt member starts
blank, *everything* composes into the one-dispatch model: a rebuild
simply replaces the failed lane's program with the replacement's
append stream (reads land on the survivor lanes), and the next
:meth:`ArrayEngine.run` replays the array's full history from a blank
shared initial state.

The object ``ZNSArray`` stays as the bit-exactness oracle (the
``LegacyZNSDevice`` pattern): :meth:`ArrayEngine.report` and
:meth:`ArrayEngine.device_reports` reproduce its rollups exactly
(differential-tested in ``tests/test_array_engine.py``), and
:func:`array_vs_legacy_speedup` is the comparator ``tools/bench.py``
gates in ``BENCH_fleet.json``.

Batched sweeps: :func:`run_array_batch` stacks K arrays (mixed member
counts, chunk sizes, parity settings, and -- on a union-config engine
-- mixed per-member element specs via per-lane ``DynConfig``) into one
padded dispatch.  ``repro.array.storm`` builds the rebuild-storm mode
on top of it.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.array.raid import (ArrayGeometry, SuperZoneInfo, ZNSArray,
                              locate_page, member_chunk_pages,
                              parity_device_of)
from repro.core import engine as zengine
from repro.core import timing
from repro.core.alloc_exact import AVAIL_INVALID
from repro.core.device import ZoneState
from repro.core.elements import SUPERBLOCK, ElementSpec
from repro.core.engine import DeviceState, ZoneEngine, stack_dyn

#: column index of the tenant tag in a width-5 op row (same convention
#: as repro.fleet.tenants.TENANT_COL; duplicated to keep the layering
#: acyclic -- fleet builds on array, not the reverse)
TENANT_COL = 4


@dataclasses.dataclass
class ArrayResult:
    """Decoded outputs of one array dispatch (all numpy).

    Lane axis = member device index (``n_devices`` lanes); op axis is
    the padded per-member program length.  ``pages`` counts the flash
    pages an op physically moved *including reads* (the engine's
    ``OP_READ`` is state-neutral, so its page count comes from the
    program row, not the write pointer) -- the quantity the op-granular
    timing model books LUN-busy time with.
    """

    programs: np.ndarray     # (n_devices, n_ops, 5) i32
    states: DeviceState      # stacked pytree, leading axis n_devices
    ok: np.ndarray           # (n_devices, n_ops) bool
    host_delta: np.ndarray   # (n_devices, n_ops) host pages per op
    dummy_delta: np.ndarray  # (n_devices, n_ops) FINISH-pad pages
    erase_delta: np.ndarray  # (n_devices, n_ops) block erasures
    pages: np.ndarray        # (n_devices, n_ops) pages moved (R+W+pad)
    cols: np.ndarray         # (n_devices, n_ops, P) zone column -> LUN
    #: per-lane telemetry stack (repro.obs TelemetryState) when the
    #: dispatch ran with obs=ObsConfig(...), else None
    telemetry: Optional[object] = None

    @property
    def tenants(self) -> np.ndarray:
        return self.programs[:, :, TENANT_COL]

    def member_state(self, idx: int) -> DeviceState:
        """Member ``idx``'s final ``DeviceState`` (leading axis sliced)."""
        import jax
        return jax.tree_util.tree_map(lambda a: a[idx], self.states)


def _decode_result(programs: np.ndarray, states: DeviceState, trace,
                   telemetry) -> ArrayResult:
    wp_b = np.asarray(trace.wp_before)
    wp_a = np.asarray(trace.wp_after)
    dummy = np.asarray(trace.dummy_delta)
    op = programs[:, :, 0]
    # pages the op physically moved: write advance, FINISH padding, and
    # -- unlike the write-only fleet runner -- READ page counts (reads
    # are engine nops; their size rides in the program row)
    pages = (np.maximum(wp_a - wp_b, 0)
             + np.where(op == zengine.OP_FINISH, dummy, 0)
             + np.where(op == zengine.OP_READ, programs[:, :, 2], 0))
    return ArrayResult(
        programs=programs,
        states=states,
        ok=np.asarray(trace.ok),
        host_delta=np.asarray(trace.host_delta),
        dummy_delta=dummy,
        erase_delta=np.asarray(trace.erase_delta),
        pages=pages.astype(np.int32),
        cols=np.asarray(trace.cols),
        telemetry=telemetry,
    )


class ArrayEngine:
    """The engine-native :class:`ZNSArray`: same surface, compiled body.

    Commands (``zone_write`` / ``zone_finish`` / ``zone_reset`` /
    ``zone_read`` / ``fail_device`` / ``rebuild_device``) validate
    eagerly against the host-side superzone mirror -- raising the object
    array's exact errors -- and append encoded op rows to the per-member
    programs.  :meth:`run` executes the accumulated programs from a
    blank shared state in one batched dispatch; :meth:`report` /
    :meth:`device_reports` then reproduce ``ZNSArray``'s rollups
    bit-exactly from the stacked ``DeviceState``.

    ``eng`` may be shared between many arrays (it is stateless); build
    it over a spec *set* and pass ``member_specs`` to run a
    heterogeneous-member array (mixed element granularities) -- each
    member lane selects its spec through the per-lane ``DynConfig``.

    Tenant tags: data rows carry the caller's ``tenant`` (default 0),
    parity appends carry ``n_tenants``, rebuild traffic carries
    ``n_tenants + 1`` -- so the op-granular timing model can separate
    host, parity, and rebuild streams.
    """

    def __init__(self, eng: ZoneEngine, geom: ArrayGeometry, *,
                 member_specs: Optional[Sequence[ElementSpec]] = None,
                 zone_pages: Optional[int] = None,
                 max_active: Optional[int] = None,
                 wear_aware: Optional[bool] = None,
                 alloc_policy: Optional[str] = None,
                 n_tenants: int = 1):
        self.eng = eng
        self.geom = geom
        cfg = eng.cfg
        self.dev_zone_pages = int(zone_pages if zone_pages is not None
                                  else cfg.zone_pages)
        if self.dev_zone_pages % geom.chunk_pages:
            raise ValueError(
                f"chunk_pages={geom.chunk_pages} must divide the member "
                f"zone capacity ({self.dev_zone_pages} pages)")
        self.stripes_per_zone = self.dev_zone_pages // geom.chunk_pages
        self.n_zones = int(cfg.n_zones)
        self.max_active = int(max_active if max_active is not None
                              else cfg.max_active)
        self.flash = eng.flash
        if member_specs is None:
            member_specs = (eng.spec,) * geom.n_devices
        member_specs = tuple(member_specs)
        if len(member_specs) != geom.n_devices:
            raise ValueError(
                f"got {len(member_specs)} member specs for geometry "
                f"{geom.describe()}")
        for s in member_specs:
            if s not in eng.members:
                raise ValueError(
                    f"member spec {s.name} is not a member of the "
                    f"engine's config; build the engine over the spec "
                    f"set")
        self.member_specs = member_specs
        # per-member wear_aware / alloc_policy: a rebuilt member is a
        # stock blank device (the object array's replacement drops the
        # overrides).  Note the bit-exactness oracle (the object
        # ZNSArray) has no silent allocator, so wear rollups are only
        # cross-checked against it when alloc_policy is unset.
        self._member_wear_aware: List[Optional[bool]] = (
            [wear_aware] * geom.n_devices)
        self._member_alloc_policy: List[Optional[str]] = (
            [alloc_policy] * geom.n_devices)
        self.n_tenants = int(n_tenants)
        self.parity_tenant = self.n_tenants
        self.rebuild_tenant = self.n_tenants + 1

        self.zones: Dict[int, SuperZoneInfo] = {
            z: SuperZoneInfo() for z in range(self.n_zones)}
        self.failed: set[int] = set()
        self.host_pages = 0
        self.parity_pages = 0
        self._rows: List[List[tuple]] = [[] for _ in range(geom.n_devices)]
        self._result: Optional[ArrayResult] = None
        self._dirty = True

    # ------------------------------------------------------------------ #
    # construction helper (mirrors ZNSArray.build)
    # ------------------------------------------------------------------ #
    @classmethod
    def build(cls, flash, zone_geom, spec, *, n_devices: int,
              chunk_pages: Optional[int] = None, parity: bool = False,
              max_active: int = 14, wear_aware: Optional[bool] = None,
              alloc_policy: Optional[str] = None,
              n_tenants: int = 1) -> "ArrayEngine":
        """Own-engine constructor; ``chunk_pages`` defaults to one
        segment, like :meth:`ZNSArray.build`.  ``spec`` may be a
        sequence (heterogeneous members over a union config)."""
        if chunk_pages is None:
            chunk_pages = zone_geom.segment_pages(flash)
        member_specs = None
        if not isinstance(spec, ElementSpec):
            member_specs = tuple(spec[d % len(spec)]
                                 for d in range(n_devices))
            spec = tuple(dict.fromkeys(spec))
            if len(spec) == 1:
                spec = spec[0]
        eng = ZoneEngine(flash, zone_geom, spec, max_active=max_active)
        return cls(eng, ArrayGeometry(n_devices, chunk_pages, parity),
                   member_specs=member_specs, wear_aware=wear_aware,
                   alloc_policy=alloc_policy, n_tenants=n_tenants)

    # ------------------------------------------------------------------ #
    # geometry / metrics mirror (ZoneBackend-shaped surface)
    # ------------------------------------------------------------------ #
    @property
    def zone_pages(self) -> int:
        """Host-visible capacity of a superzone (data chunks only)."""
        return self.dev_zone_pages * self.geom.n_data

    @property
    def n_active(self) -> int:
        return sum(1 for z in self.zones.values()
                   if z.state is ZoneState.OPEN)

    @property
    def dlwa(self) -> float:
        if self.host_pages == 0:
            return 1.0
        return ((self.host_pages + self.parity_pages + self.dummy_pages)
                / self.host_pages)

    @property
    def dummy_pages(self) -> int:
        res = self.result()
        return int(np.asarray(res.states.dummy_pages).sum())

    # ------------------------------------------------------------------ #
    # stripe math (the shared module-level functions)
    # ------------------------------------------------------------------ #
    def _parity_device(self, zone_id: int, stripe: int) -> int:
        return parity_device_of(zone_id, stripe, self.geom.n_devices)

    def _locate(self, zone_id: int, page: int) -> Tuple[int, int, int, int]:
        return locate_page(zone_id, page, self.geom.chunk_pages,
                           self.geom.n_data, self.geom.n_devices,
                           self.geom.parity)

    def _member_chunk(self, zone_id: int, stripe: int, idx: int,
                      info: SuperZoneInfo) -> int:
        return member_chunk_pages(
            zone_id, stripe, idx, chunk_pages=self.geom.chunk_pages,
            n_data=self.geom.n_data, n_devices=self.geom.n_devices,
            parity=self.geom.parity, wp=info.wp,
            parity_emitted=info.parity_emitted)

    def member_wp(self, zone_id: int, idx: int) -> int:
        """Member ``idx``'s physical write pointer in zone ``zone_id``,
        reconstructed from superzone metadata (sum of its chunk rows) --
        what the object array reads off ``devices[idx].zones[z].wp``."""
        info = self.zones[zone_id]
        return sum(self._member_chunk(zone_id, s, idx, info)
                   for s in range(self.stripes_per_zone))

    # ------------------------------------------------------------------ #
    # command compilers (the ZNSArray state machine, emitting op rows)
    # ------------------------------------------------------------------ #
    def zone_write(self, zone_id: int, n_pages: int, *, host: bool = True,
                   tenant: int = 0, trace: bool = False) -> None:
        """Compile a logical superzone write into striped member rows
        (parity appends land log-structured, exactly like the object
        array).  ``trace`` is accepted for surface compatibility and
        ignored -- traces come from the batched run."""
        del trace
        info = self.zones[zone_id]
        if info.state is ZoneState.FULL:
            raise RuntimeError(f"write to FULL superzone {zone_id}")
        if info.state is ZoneState.EMPTY:
            if self.n_active >= self.max_active:
                raise RuntimeError(
                    f"open/active superzone limit ({self.max_active}) "
                    "reached")
            info.state = ZoneState.OPEN
        if info.wp + n_pages > self.zone_pages:
            raise RuntimeError(
                f"superzone {zone_id} overflow: wp={info.wp} + {n_pages} "
                f"> {self.zone_pages}")
        c = self.geom.chunk_pages
        flags = zengine.F_HOST if host else 0
        remaining, page = n_pages, info.wp
        while remaining > 0:
            stripe, _, r, dev = self._locate(zone_id, page)
            # parity for every completed stripe must land before this
            # device appends its next chunk row (log-structured order)
            self._emit_parity(zone_id, info, upto_stripe=stripe)
            take = min(c - r, remaining)
            self._rows[dev].append(
                (zengine.OP_WRITE, zone_id, take, flags, tenant))
            page += take
            remaining -= take
        info.wp = page
        if host:
            info.host_wp += n_pages
            self.host_pages += n_pages
        self._emit_parity(zone_id, info,
                          upto_stripe=info.wp // (c * self.geom.n_data))
        if info.wp == self.zone_pages:
            info.state = ZoneState.FULL
        self._dirty = True

    def _emit_parity(self, zone_id: int, info: SuperZoneInfo, *,
                     upto_stripe: int) -> None:
        if not self.geom.parity:
            return
        c = self.geom.chunk_pages
        while info.parity_emitted < upto_stripe:
            s = info.parity_emitted
            p = self._parity_device(zone_id, s)
            self._rows[p].append(
                (zengine.OP_WRITE, zone_id, c, zengine.F_HOST,
                 self.parity_tenant))
            self.parity_pages += c
            info.parity_emitted += 1

    def zone_finish(self, zone_id: int, *, tenant: int = 0,
                    trace: bool = False) -> None:
        """Partial-stripe parity (once), then member FINISH fan-out."""
        del trace
        info = self.zones[zone_id]
        if info.state is ZoneState.FULL:
            return
        if info.state is ZoneState.OPEN:
            c, k = self.geom.chunk_pages, self.geom.n_data
            full_stripes = info.wp // (c * k)
            self._emit_parity(zone_id, info, upto_stripe=full_stripes)
            if self.geom.parity and info.wp % (c * k):
                s = full_stripes
                p = self._parity_device(zone_id, s)
                self._rows[p].append(
                    (zengine.OP_WRITE, zone_id, c, zengine.F_HOST,
                     self.parity_tenant))
                self.parity_pages += c
                info.parity_emitted += 1
        for dev in range(self.geom.n_devices):
            self._rows[dev].append(
                (zengine.OP_FINISH, zone_id, 0, 0, tenant))
        info.state = ZoneState.FULL
        self._dirty = True

    def zone_reset(self, zone_id: int, *, tenant: int = 0) -> None:
        for dev in range(self.geom.n_devices):
            self._rows[dev].append(
                (zengine.OP_RESET, zone_id, 0, 0, tenant))
        self.zones[zone_id] = SuperZoneInfo()
        self._dirty = True

    def zone_read(self, zone_id: int, pages, *, tenant: int = 0
                  ) -> Dict[int, np.ndarray]:
        """Route logical page reads to members (degraded reads included,
        with the object array's exact error semantics) and append one
        ``OP_READ`` row per touched member.  Returns the physical read
        plan ``{member: offsets}`` -- the routing the object array's
        tagged traces realize, exposed for differential tests."""
        info = self.zones[zone_id]
        if info.state is ZoneState.EMPTY:
            raise RuntimeError(f"read from unmapped superzone {zone_id}")
        c = self.geom.chunk_pages
        per_dev: List[List[int]] = [[] for _ in range(self.geom.n_devices)]
        member_wp = [self.member_wp(zone_id, d)
                     for d in range(self.geom.n_devices)]
        for page in np.asarray(pages, dtype=np.int64):
            stripe, _, r, dev_idx = self._locate(zone_id, int(page))
            if dev_idx in self.failed:
                if not self.geom.parity:
                    raise RuntimeError(
                        f"device {dev_idx} failed and parity is off: "
                        f"superzone {zone_id} page {int(page)} lost")
                if stripe >= info.parity_emitted:
                    raise RuntimeError(
                        f"superzone {zone_id} page {int(page)}: stripe "
                        f"{stripe} parity not yet written, page lost")
                # degraded: same chunk row from every surviving member
                # that physically wrote it
                off = stripe * c + r
                for other in range(self.geom.n_devices):
                    if other == dev_idx or other in self.failed:
                        continue
                    if member_wp[other] <= off:
                        continue
                    per_dev[other].append(off)
            else:
                per_dev[dev_idx].append(stripe * c + r)
        plan: Dict[int, np.ndarray] = {}
        for i, plist in enumerate(per_dev):
            if not plist:
                continue
            self._rows[i].append(
                (zengine.OP_READ, zone_id, len(plist), 0, tenant))
            plan[i] = np.asarray(plist, dtype=np.int64)
        self._dirty = True
        return plan

    # ------------------------------------------------------------------ #
    # failure injection + rebuild
    # ------------------------------------------------------------------ #
    def fail_device(self, idx: int) -> None:
        if (self.geom.parity and len(self.failed) >= 1
                and idx not in self.failed):
            raise RuntimeError("single-parity array cannot survive a "
                               "second device failure")
        self.failed.add(idx)

    def heal_device(self, idx: int) -> None:
        self.failed.discard(idx)

    def rebuild_device(self, idx: int) -> List[Tuple[int, int, int, int]]:
        """Compile the rebuild: survivor reads + replacement appends.

        The failed lane's program is *replaced* by the reconstructed
        append stream (the replacement starts blank, exactly like the
        object array's fresh ``ZNSDevice``); every chunk row it held is
        re-read from the surviving members that wrote it (stripe XOR --
        the degraded-read access pattern) as state-neutral ``OP_READ``
        rows on their lanes.  Nothing executes until :meth:`run`; the
        whole rebuild then rides the same single dispatch as the rest
        of the array's history.

        Returns the read plan as ``(survivor, zone, offset, n_read)``
        tuples (what the object array's tagged traces realize).
        """
        if not self.geom.parity:
            raise RuntimeError("rebuild requires parity")
        if any(f != idx for f in self.failed):
            raise RuntimeError("cannot rebuild with another member down")
        c = self.geom.chunk_pages
        new_rows: List[tuple] = []
        plan: List[Tuple[int, int, int, int]] = []
        for z, info in self.zones.items():
            if info.wp == 0 and info.parity_emitted == 0:
                continue
            dwp = {other: self.member_wp(z, other)
                   for other in range(self.geom.n_devices)}
            wrote = 0
            for s in range(self.stripes_per_zone):
                pages_here = self._member_chunk(z, s, idx, info)
                if pages_here <= 0:
                    continue
                off = s * c
                for other in range(self.geom.n_devices):
                    if other == idx or other in self.failed:
                        continue
                    if dwp[other] <= off:
                        continue
                    n_read = min(pages_here, dwp[other] - off)
                    self._rows[other].append(
                        (zengine.OP_READ, z, n_read, 0,
                         self.rebuild_tenant))
                    plan.append((other, z, off, n_read))
                new_rows.append(
                    (zengine.OP_WRITE, z, pages_here, zengine.F_HOST,
                     self.rebuild_tenant))
                wrote += pages_here
            if info.state is ZoneState.FULL and wrote > 0:
                new_rows.append(
                    (zengine.OP_FINISH, z, 0, 0, self.rebuild_tenant))
        self._rows[idx] = new_rows
        # the replacement is a stock device: the object array builds it
        # without the wear_aware / alloc_policy overrides, so the
        # oracle does too
        self._member_wear_aware[idx] = None
        self._member_alloc_policy[idx] = None
        self.failed.discard(idx)
        self._dirty = True
        return plan

    # ------------------------------------------------------------------ #
    # lowering + execution
    # ------------------------------------------------------------------ #
    def member_dyn(self, idx: int):
        """Per-lane ``DynConfig`` binding member ``idx``'s element spec
        / effective capacity / allocator on the shared engine config."""
        kw: Dict = {"spec": self.member_specs[idx]}
        if self.dev_zone_pages != int(self.eng.cfg.zone_pages):
            kw["zone_pages"] = self.dev_zone_pages
        if self.max_active != int(self.eng.cfg.max_active):
            kw["max_active"] = self.max_active
        if self._member_wear_aware[idx] is not None:
            kw["wear_aware"] = self._member_wear_aware[idx]
        if self._member_alloc_policy[idx] is not None:
            kw["alloc_policy"] = self._member_alloc_policy[idx]
        return self.eng.dyn(**kw)

    def member_programs(self) -> List[np.ndarray]:
        """The compiled per-member programs (ragged, width 5)."""
        return [zengine.encode_program(rows, width=TENANT_COL + 1)
                for rows in self._rows]

    def run(self, *, obs=None, pad_quantum: int = 1,
            sanitize: bool = False) -> ArrayResult:
        """Execute the array's full compiled history from a blank shared
        state: ONE batched ``run_programs`` dispatch over the member
        lanes (``obs`` threads the in-scan telemetry recorder through
        it).  Illegal rows cannot occur -- commands were validated at
        compile time -- and that is asserted, not assumed; ``sanitize``
        additionally audits the final member device states with the
        :mod:`repro.check` sanitizer."""
        res = run_array_batch([self], obs=obs,
                              pad_quantum=pad_quantum,
                              sanitize=sanitize)[0]
        return res

    def result(self) -> ArrayResult:
        """The latest dispatch result (re-runs if commands were compiled
        since)."""
        if self._dirty or self._result is None:
            self.run()
        return self._result

    # ------------------------------------------------------------------ #
    # rollups (bit-exact with the object ZNSArray)
    # ------------------------------------------------------------------ #
    def device_reports(self) -> List[Dict[str, float]]:
        res = self.result()
        out = []
        for i in range(self.geom.n_devices):
            st = res.member_state(i)
            spec = self.member_specs[i]
            host = int(st.host_pages)
            dummy = int(st.dummy_pages)
            erases = int(st.block_erases)
            ids = self.eng.member_element_ids(spec)
            layout = self.eng.layouts[spec]
            inv = np.asarray(st.elem_avail)[ids] == AVAIL_INVALID
            pending = int(inv.sum()) * layout.blocks_per_element
            w = self.eng.block_wear(st, spec)
            out.append({
                "device": float(i),
                "dlwa": (host + dummy) / host if host else 1.0,
                "host_pages": float(host),
                "dummy_pages": float(dummy),
                "failed": float(i in self.failed),
                "total_block_erases": float(erases),
                "pending_block_erases": float(pending),
                "total_incl_pending": float(erases + pending),
                "mean_wear": float(w.mean()),
                "max_wear": float(w.max()),
                "std_wear": float(w.std()),
                "cv_wear": (float(w.std() / w.mean())
                            if w.mean() > 0 else 0.0),
            })
        return out

    def report(self) -> Dict[str, float]:
        """Array-level rollup, key-for-key ``ZNSArray.report()``."""
        per = self.device_reports()
        dummy = sum(int(r["dummy_pages"]) for r in per)
        host = self.host_pages
        return {
            "n_devices": float(self.geom.n_devices),
            "chunk_pages": float(self.geom.chunk_pages),
            "parity": float(self.geom.parity),
            "host_pages": float(host),
            "parity_pages": float(self.parity_pages),
            "dummy_pages": float(dummy),
            "dlwa": ((host + self.parity_pages + dummy) / host
                     if host else 1.0),
            "parity_overhead": (self.parity_pages / host if host else 0.0),
            "max_device_dlwa": max(r["dlwa"] for r in per),
            "total_block_erases": sum(r["total_block_erases"]
                                      for r in per),
            "total_incl_pending": sum(r["total_incl_pending"]
                                      for r in per),
            "max_wear": max(r["max_wear"] for r in per),
        }

    def fleet_timing(self, *, skip_rows: Optional[Sequence[int]] = None
                     ) -> Dict[str, float]:
        """Op-granular fleet timing of the compiled history: one
        ``simulate_fleet_ops`` dispatch with per-op page costs (reads
        at ``t_read + t_xfer``, writes at ``t_prog + t_xfer``).

        ``skip_rows`` (per-member row counts) masks a program prefix
        out of the clock -- the rebuild-storm mode times only the storm
        phase, not the fill that established the array state.
        """
        res = self.result()
        pages = res.pages
        if skip_rows is not None:
            pages = pages.copy()
            for lane, m in enumerate(skip_rows):
                pages[lane, :m] = 0
        completions, latencies, makespans = run_array_timing(
            self.flash, res.programs, res.cols, pages,
            n_tenants=self.rebuild_tenant + 1)
        out = {"fleet_makespan_s": float(makespans.max(initial=0.0)),
               "fleet_pages": float(pages.sum())}
        for i in range(self.geom.n_devices):
            out[f"dev{i}_makespan_s"] = float(makespans[i])
        for t in range(self.rebuild_tenant + 1):
            sel = (res.tenants == t) & (pages > 0)
            out[f"tenant{t}_makespan_s"] = (
                float(completions[sel].max()) if sel.any() else 0.0)
        return out


# --------------------------------------------------------------------- #
# batched sweeps: K arrays in one dispatch
# --------------------------------------------------------------------- #
def run_array_batch(arrays: Sequence[ArrayEngine], *, obs=None,
                    pad_quantum: int = 1,
                    sanitize: bool = False) -> List[ArrayResult]:
    """Execute K arrays' member lanes in ONE ``run_programs`` dispatch.

    All arrays must share one ``ZoneEngine`` (they may still mix member
    counts, chunk sizes, parity, effective zone capacities, and -- on a
    union config -- per-member element specs: every lane carries its
    own ``DynConfig``).  ``pad_quantum`` rounds the padded op axis so
    repeated same-scale batches hit one compiled shape.  Each array's
    result is installed (so ``report()`` works) and returned in order.
    ``sanitize`` audits every member lane's final device state with the
    :mod:`repro.check` sanitizer (host-side numpy on the already-
    fetched states; no extra compilations).
    """
    if not arrays:
        return []
    eng = arrays[0].eng
    for a in arrays:
        if a.eng is not eng:
            raise ValueError("all arrays of one batch must share a "
                             "ZoneEngine")
    lane_programs: List[np.ndarray] = []
    dyns = []
    for a in arrays:
        lane_programs += a.member_programs()
        dyns += [a.member_dyn(d) for d in range(a.geom.n_devices)]
    q = max(1, pad_quantum)
    n_ops = -(-max(max((len(p) for p in lane_programs), default=0), 1)
              // q) * q
    programs = np.zeros((len(lane_programs), n_ops, TENANT_COL + 1),
                        dtype=np.int32)
    for i, p in enumerate(lane_programs):
        programs[i, : len(p)] = p
    dyn = stack_dyn(dyns)
    out = eng.run_batch(eng.init_state(), programs, dyn, obs=obs)
    states, trace = out[0], out[1]
    telemetry = out[2] if obs is not None else None

    import jax
    # one device->host transfer per leaf here; per-member report
    # slicing is then pure numpy views
    states = jax.tree_util.tree_map(np.asarray, states)
    if sanitize:
        from repro.check import assert_states
        assert_states(eng.cfg, states, dyn, where="array batch states")
    results = []
    lo = 0
    for a in arrays:
        hi = lo + a.geom.n_devices
        sl = slice(lo, hi)
        res = _decode_result(
            programs[sl],
            jax.tree_util.tree_map(lambda x: x[sl], states),
            jax.tree_util.tree_map(lambda x: np.asarray(x)[sl], trace),
            (jax.tree_util.tree_map(lambda x: x[sl], telemetry)
             if telemetry is not None else None))
        real = res.programs[:, :, 0] != zengine.OP_NOP
        bad = real & ~res.ok
        if bad.any():
            lane, idx = np.argwhere(bad)[0]
            raise AssertionError(
                f"illegal op at member {lane} index {idx}: "
                f"{res.programs[lane, idx].tolist()} -- the compiler "
                f"validated this command, so this is an engine/compiler "
                f"divergence")
        a._result = res
        a._dirty = False
        results.append(res)
        lo = hi
    return results


def run_array_timing(flash, programs: np.ndarray, cols: np.ndarray,
                     pages: np.ndarray, *, n_tenants: int
                     ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """One op-granular timing dispatch with per-op page costs: read
    rows book ``t_read + t_xfer`` per page, everything else
    ``t_prog + t_xfer`` (the per-op ``t_page`` extension of
    :func:`repro.core.timing.simulate_fleet_ops`)."""
    op = programs[:, :, 0]
    t_page = np.where(op == zengine.OP_READ,
                      np.float32(flash.t_read + flash.t_xfer),
                      np.float32(flash.t_prog + flash.t_xfer)
                      ).astype(np.float32)
    completions, latencies, makespans = timing.simulate_fleet_ops(
        cols, pages.astype(np.int32),
        programs[:, :, TENANT_COL], t_page, flash.n_luns, n_tenants)
    return (np.asarray(completions), np.asarray(latencies),
            np.asarray(makespans))


# --------------------------------------------------------------------- #
# differential replay + the bench comparator
# --------------------------------------------------------------------- #
#: command tuples: ("write", zone, n_pages, host) / ("finish", zone) /
#: ("reset", zone) / ("read", zone, offsets) / ("fail", idx) /
#: ("rebuild", idx)
Command = tuple


def apply_commands(backend, commands: Sequence[Command]):
    """Drive an :class:`ArrayEngine` or an object :class:`ZNSArray`
    through one logical command list -- the shared differential-test /
    comparator driver (both surfaces take the same verbs)."""
    for cmd in commands:
        verb = cmd[0]
        if verb == "write":
            backend.zone_write(cmd[1], cmd[2], host=cmd[3])
        elif verb == "finish":
            backend.zone_finish(cmd[1])
        elif verb == "reset":
            backend.zone_reset(cmd[1])
        elif verb == "read":
            backend.zone_read(cmd[1], np.asarray(cmd[2], dtype=np.int64))
        elif verb == "fail":
            backend.fail_device(cmd[1])
        elif verb == "rebuild":
            backend.rebuild_device(cmd[1])
        else:
            raise ValueError(f"unknown command {cmd!r}")
    return backend


def fill_commands(zone_pages: int, *, n_zones: int, occupancy: float,
                  writes_per_zone: int = 4, churn: int = 1,
                  zone_base: int = 0) -> List[Command]:
    """A fill + FINISH (+ RESET-churn refill) logical workload -- the
    DLWA benchmark traffic, array edition."""
    per_zone = max(1, int(zone_pages * occupancy))
    step = -(-per_zone // writes_per_zone)
    cmds: List[Command] = []
    for cycle in range(max(1, churn)):
        if cycle:
            cmds += [("reset", z)
                     for z in range(zone_base, zone_base + n_zones)]
        for z in range(zone_base, zone_base + n_zones):
            left = per_zone
            while left > 0:
                take = min(step, left)
                cmds.append(("write", z, take, True))
                left -= take
            cmds.append(("finish", z))
    return cmds


def _legacy_array(flash, zone_geom, geom: ArrayGeometry,
                  member_specs: Sequence[ElementSpec], *,
                  max_active: int, oracle: bool = False) -> ZNSArray:
    """The pipeline being retired: an object ``ZNSArray`` over per-op
    ``ZNSDevice`` shims (what ``ZNSArray.build`` constructs -- one
    engine dispatch per member op), each member built with its actual
    spec.  ``oracle=True`` swaps in ``LegacyZNSDevice`` members -- the
    bit-compatible pure-numpy oracle, cheap enough to differential-check
    every array."""
    if oracle:
        from repro.core.device_legacy import LegacyZNSDevice as cls
    else:
        from repro.core.device import ZNSDevice as cls
    devices = [cls(flash, zone_geom, s, max_active=max_active)
               for s in member_specs]
    return ZNSArray(devices, geom)


def array_vs_legacy_speedup(*, n_arrays: int = 8, repeats: int = 3,
                            flash=None, zone_geom=None,
                            specs: Optional[Sequence[ElementSpec]] = None,
                            max_active: int = 14, n_zones: int = 4,
                            legacy_arrays: Optional[int] = None
                            ) -> Dict[str, float]:
    """Time the engine-native array path against the object ``ZNSArray``
    replay -- the ``array`` section of ``BENCH_fleet.json``.

    Both paths run the *same* logical commands (a devices x chunk x
    parity sweep of fill/FINISH/churn workloads).  The engine leg is
    the tentpole's product: the commands are compiled ONCE into
    encoded member programs (``build_s``, reported separately -- the
    compiled program is a reusable artifact, like an XLA executable),
    then each timed repeat is one batched ``run_array_batch`` dispatch
    plus the full per-array ``report()`` decode.  The legacy leg
    replays the commands through object arrays over per-op
    ``LegacyZNSDevice`` members; with ``legacy_arrays`` < ``n_arrays``
    it is timed once on that prefix and scaled (recorded honestly in
    the returned fields: ``legacy_timed_arrays`` / ``legacy_measured_s``
    / ``legacy_scale``).  Before any timing, every per-array report is
    asserted bit-identical between the paths (the exactness oracle).
    """
    from repro.core.geometry import zn540

    if (flash is None) != (zone_geom is None):
        raise ValueError("flash and zone_geom must be given together")
    if flash is None:
        flash, zone_geom = zn540()
    specs = tuple(specs) if specs else (SUPERBLOCK,)
    eng = ZoneEngine(flash, zone_geom,
                     specs if len(specs) > 1 else specs[0],
                     max_active=max_active)
    seg = zone_geom.segment_pages(flash)
    axis = [(n_dev, chunk, parity)
            for n_dev in (4, 3)
            for chunk in (seg, seg // 2)
            for parity in (True, False)]
    arrays: List[ArrayEngine] = []
    commands: List[List[Command]] = []
    t0 = time.perf_counter()
    for i in range(n_arrays):
        n_dev, chunk, parity = axis[i % len(axis)]
        member_specs = tuple(specs[d % len(specs)] for d in range(n_dev))
        a = ArrayEngine(eng, ArrayGeometry(n_dev, chunk, parity),
                        member_specs=member_specs,
                        max_active=max_active)
        occ = 0.4 + 0.2 * (i % 3)
        cmds = fill_commands(a.zone_pages, n_zones=n_zones,
                             occupancy=occ, churn=2)
        apply_commands(a, cmds)
        arrays.append(a)
        commands.append(cmds)
    build_s = time.perf_counter() - t0

    def engine_pass():
        run_array_batch(arrays, pad_quantum=64)
        return [a.report() for a in arrays]

    def legacy_pass(subset, *, oracle=False):
        reports = []
        for a, cmds in subset:
            arr = _legacy_array(flash, zone_geom, a.geom, a.member_specs,
                                max_active=max_active, oracle=oracle)
            apply_commands(arr, cmds)
            reports.append(arr.report())
        return reports

    # exactness oracle (and engine warm-up): every report key of every
    # array bit-identical to the pure-numpy object oracle before
    # anything is timed
    engine_reports = engine_pass()
    oracle_reports = legacy_pass(list(zip(arrays, commands)), oracle=True)
    for er, lr in zip(engine_reports, oracle_reports):
        assert er.keys() == lr.keys()
        for k in er:
            assert er[k] == lr[k], (
                f"engine/legacy array mismatch on {k}: "
                f"{er[k]} vs {lr[k]}")

    t0 = time.perf_counter()
    for _ in range(repeats):
        engine_pass()
    engine_s = (time.perf_counter() - t0) / repeats

    # the timed legacy leg is the retired pipeline itself (ZNSArray over
    # per-op ZNSDevice shims); warmed on its prefix, timed once, scaled
    n_leg = min(legacy_arrays or n_arrays, n_arrays)
    scale = n_arrays / n_leg
    prefix = list(zip(arrays, commands))[:n_leg]
    shim_reports = legacy_pass(prefix)      # warm-up (jit caches)
    for er, lr in zip(engine_reports, shim_reports):
        assert er == lr, "shim-member array diverged from the engine"
    t0 = time.perf_counter()
    legacy_pass(prefix)
    legacy_measured_s = time.perf_counter() - t0
    legacy_s = legacy_measured_s * scale

    lane_ops = float(sum(len(p) for a in arrays
                         for p in a.member_programs()))
    return {
        "n_arrays": float(n_arrays),
        "lane_ops": lane_ops,
        "build_s": build_s,
        "engine_s": engine_s,
        "engine_total_s": build_s / max(1, repeats) + engine_s,
        "legacy_s": legacy_s,
        "legacy_measured_s": legacy_measured_s,
        "legacy_timed_arrays": float(n_leg),
        "legacy_scale": scale,
        "speedup": legacy_s / engine_s,
    }
