"""Static op-program verifier: a numpy abstract interpreter over the
width-5 op-program IR.

:func:`verify_program` walks a program against a *symbolic device
model* -- a host-side numpy mirror of the :mod:`repro.core.engine`
state machine (per-zone EMPTY/OPEN/FULL states, write pointers,
active-set occupancy, element commitments, and the lane's effective
:class:`~repro.core.engine.DynConfig` geometry) -- without dispatching
anything, and predicts, per op, the exact ok/illegal verdict the
engine's ``trace.ok`` would report, plus the *error class* a shim
(:class:`repro.core.device.ZNSDevice` /
:class:`repro.storage.compile.RecordingBackend`) would raise for the
same op, formatted with the shim's own message strings.

The hard guarantee (fuzzed in ``tests/test_check.py`` across all five
element specs x both allocation policies): the predicted ok-mask is
bit-identical to ``trace.ok`` from ``run_program``.  That requires the
model to reproduce the engine's semantics exactly, including the
deliberately-odd corners:

* op codes are clipped into ``[NOP, READ]`` and zones into
  ``[0, dyn.n_zones)`` -- out-of-range rows never fail, they alias;
* READ/NOP/FINISH/RESET always report ``ok`` engine-side (an unmapped
  READ is a *control-plane* error: the shims raise, the data plane is
  a no-op) -- the verifier reports those as ok-verdicts carrying an
  *advisory* error class instead;
* a failed WRITE keeps its side effects up to the failure point: the
  implicit ALLOC of a write to an EMPTY zone persists even when the
  write itself then overflows (legacy-device parity);
* a traditional ALLOC advances the round-robin window even when
  infeasible (but not past an active-limit refusal), and falls back to
  the cheapest-groups selection when the window is exhausted;
* a silent ALLOC sizes its claim to the op's page hint, draws from the
  cheapest wear-bounded groups, never consumes the round-robin window,
  and :func:`_grow` claims missing ranks on the fly mid-WRITE.

Beyond the per-op verdicts, :class:`ProgramReport` derives the static
analyses the paper's predictability claim wants provable up front:
superfluous-write (dummy-page) sites, a DLWA lower bound, peak
active-zone pressure, the ops a silent lane's wear bound (rather than
raw capacity) would block, and policy/spec incompatibilities
(silent-on-FIXED).  :func:`validate_rows` is the cheap malformed-row
pre-check the dispatch layers run before burning a batched scan.

Everything here is pure numpy on host values: verifying adds zero jit
compilations (asserted via ``RecompileCounter`` in the tests).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import engine as E
from repro.core.alloc_exact import (AVAIL_ALLOCATED, AVAIL_FREE,
                                    AVAIL_INVALID, AVAIL_VALID)
from repro.core.elements import ElementKind

_BIG = 2**30  # engine's sentinel wear for unavailable slots

OP_NAMES = {E.OP_NOP: "NOP", E.OP_ALLOC: "ALLOC", E.OP_WRITE: "WRITE",
            E.OP_FINISH: "FINISH", E.OP_RESET: "RESET", E.OP_READ: "READ"}

#: error classes (the shim RuntimeError families)
ERR_FULL = "full"
ERR_OVERFLOW = "overflow"
ERR_ACTIVE_LIMIT = "active-limit"
ERR_ALLOC_INFEASIBLE = "alloc-infeasible"
ERR_UNMAPPED_READ = "unmapped-read"  # advisory: engine READs never fail


@dataclasses.dataclass(frozen=True)
class OpVerdict:
    """One op's predicted outcome.  ``ok`` mirrors the engine's
    ``trace.ok`` bit; ``error`` is the shim error class (also set --
    advisory -- on ok READ ops touching an unmapped zone); ``message``
    is the exact string the shim would raise."""

    index: int
    op: int
    zone: int
    ok: bool
    error: Optional[str] = None
    message: Optional[str] = None

    @property
    def op_name(self) -> str:
        return OP_NAMES.get(self.op, f"op{self.op}")


@dataclasses.dataclass
class ProgramReport:
    """The verifier's verdicts + derived static analyses for one lane.

    ``ok`` is the predicted per-op legality mask (bit-identical to the
    engine's ``trace.ok``); ``advisories`` are control-plane-only
    diagnostics (unmapped READs) the engine data plane tolerates.
    ``dummy_sites`` lists ``(op index, zone, pages)`` of superfluous
    writes: FINISH padding the device emits to seal a partial zone,
    plus explicit non-host (``flags bit0 = 0``) write rows.
    ``wear_bound_blocked`` lists silent-lane ops whose allocation
    failed *only* because of the wear-leveling bound (the same claim
    with an unbounded ``wear_bound`` would have been feasible) -- the
    feasibility signal for picking a bound.  ``conflicts`` are
    policy/spec incompatibilities detected before walking a single op.
    """

    ok: np.ndarray
    verdicts: List[OpVerdict]
    advisories: List[OpVerdict]
    dummy_sites: List[Tuple[int, int, int]]
    host_pages: int
    dummy_pages: int
    peak_active: int
    wear_bound_blocked: List[int]
    conflicts: List[str]

    @property
    def dlwa_lower_bound(self) -> float:
        """Device-level write amplification implied by the program's
        legal ops alone -- a lower bound on what any dispatch of it can
        achieve (illegal ops move no pages; reads amplify nothing)."""
        if self.host_pages <= 0:
            return 1.0
        return (self.host_pages + self.dummy_pages) / self.host_pages

    def first_failure(self) -> Optional[OpVerdict]:
        for v in self.verdicts:
            if not v.ok:
                return v
        return None

    @property
    def all_ok(self) -> bool:
        return bool(self.ok.all())


class _Dv:
    """Effective per-lane dyn values as attributes (plain ints)."""

    def __init__(self, values: Dict):
        self.__dict__.update(values)


def _spec_name(cfg: E.EngineConfig, dv: _Dv) -> str:
    """The member spec name matching a lane's dyn values (for shim-
    format messages); falls back to the primary spec."""
    for spec, v in cfg.members:
        if (v.n_elements == dv.n_elements and v.per_group == dv.per_group
                and v.take == dv.take and v.zone_groups == dv.zone_groups
                and v.slot_stride == dv.slot_stride
                and v.pages_per_element == dv.pages_per_element):
            return spec.name
    return cfg.spec.name


def _dyn_conflicts(cfg: E.EngineConfig, dv: _Dv) -> List[str]:
    """Policy/spec incompatibilities of one lane's effective dyn.
    ``make_dyn`` rejects these eagerly, but hand-stacked DynConfigs
    (or deserialized ones) can smuggle them past it."""
    out = []
    if (dv.alloc_policy == E.POLICY_SILENT
            and cfg.kind is ElementKind.FIXED):
        out.append("alloc_policy 'silent' on a FIXED-kind config: FIXED "
                   "elements are the whole static zone, there is no "
                   "block collection for the policy to size")
    if not 0 < dv.zone_pages <= cfg.zone_pages:
        out.append(f"zone_pages {dv.zone_pages} outside the static "
                   f"config's (0, {cfg.zone_pages}]")
    if (cfg.kind is ElementKind.FIXED
            and dv.zone_pages < cfg.zone_pages):
        out.append(f"zone_pages {dv.zone_pages} shrinks a FIXED lane "
                   f"(static capacity {cfg.zone_pages})")
    if not 0 < dv.n_zones <= cfg.n_zones:
        out.append(f"n_zones {dv.n_zones} outside the static config's "
                   f"(0, {cfg.n_zones}]")
    if not 0 < dv.max_active <= cfg.max_active:
        out.append(f"max_active {dv.max_active} outside the static "
                   f"config's (0, {cfg.max_active}]")
    if dv.wear_bound < 0:
        out.append(f"negative wear_bound {dv.wear_bound}")
    return out


class _Model:
    """Numpy mirror of the engine state machine for ONE lane (one
    program under one effective dyn).  Method structure shadows the
    engine's ``_alloc`` / ``_grow_silent`` / ``_write`` / ``_finish``
    / ``_reset`` transitions; every formula is a transliteration, so a
    semantic change engine-side shows up as an ok-mask mismatch in the
    differential fuzz tests rather than silently here."""

    def __init__(self, cfg: E.EngineConfig, dv: _Dv):
        self.cfg = cfg
        self.dv = dv
        n = cfg.n_elements
        self.ng = max(dv.n_elements // max(dv.per_group, 1), 1)
        self.wear = np.zeros(n, np.int64)
        self.avail = np.full(n, AVAIL_FREE, np.int64)
        self.pages = np.zeros(n, np.int64)
        self.ezone = np.full(n, -1, np.int64)
        self.zone_state = np.full(cfg.n_zones, E.ZONE_EMPTY, np.int64)
        self.zone_wp = np.zeros(cfg.n_zones, np.int64)
        self.zone_host_wp = np.zeros(cfg.n_zones, np.int64)
        self.zone_elems = np.full((cfg.n_zones, cfg.n_slots), -1, np.int64)
        self.zone_cols = np.zeros((cfg.n_zones, cfg.parallelism), np.int64)
        self.rr_next = 0
        self.n_active = 0
        self.host_pages = 0
        self.dummy_pages = 0
        # derived (value-level) geometry, exactly as the engine computes
        # it from the lane's DynConfig
        self.n_slots_eff = dv.zone_pages // dv.pages_per_element
        self.take_eff = int(np.clip(
            self.n_slots_eff // max(dv.slot_stride, 1), 1, dv.take))
        self.wear_bound_blocked: List[int] = []
        self.block_erases = 0
        self._idx = 0  # current op index (for report sites)

    # -- selection helpers (numpy twins of the engine's) --------------- #
    def _grids(self):
        n = self.cfg.n_elements
        w2 = self.wear[:n].reshape(self.cfg.n_groups, self.cfg.per_group)
        a2 = self.avail[:n].reshape(self.cfg.n_groups, self.cfg.per_group)
        return w2, a2

    def _rr_mask(self, start: int) -> np.ndarray:
        elig = np.zeros(self.cfg.n_groups, bool)
        for pos in range(min(self.dv.zone_groups, self.cfg.zone_groups)):
            elig[(start + pos) % self.ng] = True
        return elig

    def _take_lowest(self, w2, a2, elig, by_wear: bool, take_eff: int):
        cfg, dv = self.cfg, self.dv
        col = np.arange(cfg.per_group, dtype=np.int64)[None, :]
        free = ((a2 == AVAIL_FREE) | (a2 == AVAIL_INVALID))
        free = free & elig[:, None] & (col < dv.per_group)
        composite = w2 * cfg.per_group + col
        key = np.where(free,
                       composite if by_wear
                       else np.broadcast_to(col, w2.shape),
                       _BIG)
        cols = np.argsort(key, axis=1, kind="stable")[:, : cfg.take]
        kth = np.take_along_axis(key, cols, axis=1)[:, take_eff - 1]
        feasible = bool(np.all((kth < _BIG) | ~elig))
        sel_free = np.take_along_axis(free, cols, axis=1)
        sel_key = np.where(
            sel_free,
            np.take_along_axis(w2, cols, axis=1) * cfg.per_group + cols,
            _BIG)
        order = np.argsort(sel_key, axis=1, kind="stable")
        cols = np.take_along_axis(cols, order, axis=1)
        return cols, feasible

    def _cheapest_groups(self, w2, a2, take_eff: int) -> np.ndarray:
        cfg, dv = self.cfg, self.dv
        grow = np.arange(cfg.n_groups, dtype=np.int64)[:, None]
        col = np.arange(cfg.per_group, dtype=np.int64)[None, :]
        ok = ((a2 == AVAIL_FREE) | (a2 == AVAIL_INVALID))
        ok = ok & (grow < self.ng) & (col < dv.per_group)
        keyed = np.where(ok, w2.astype(np.float32), np.float32(np.inf))
        part = np.sort(keyed, axis=1)[:, : cfg.take]
        rank = np.arange(cfg.take)[None, :]
        cost = np.where(rank < take_eff, part,
                        np.float32(0.0)).sum(axis=1, dtype=np.float32)
        order = np.argsort(cost, kind="stable")[: cfg.zone_groups]
        picked = np.arange(cfg.zone_groups) < dv.zone_groups
        elig = np.zeros(cfg.n_groups, bool)
        elig[order[picked]] = True
        return elig

    def _wear_bounded(self, w2, a2, bound: Optional[int] = None):
        cfg, dv = self.cfg, self.dv
        bound = dv.wear_bound if bound is None else bound
        grow = np.arange(cfg.n_groups, dtype=np.int64)[:, None]
        col = np.arange(cfg.per_group, dtype=np.int64)[None, :]
        free = ((a2 == AVAIL_FREE) | (a2 == AVAIL_INVALID))
        free = free & (grow < self.ng) & (col < dv.per_group)
        min_wear = int(w2[free].min()) if free.any() else _BIG
        in_bound = (w2 - min_wear) <= bound
        return np.where(in_bound, a2, AVAIL_VALID)

    def _win(self, elig: np.ndarray) -> np.ndarray:
        idx = np.nonzero(elig)[0]
        out = np.zeros(self.cfg.zone_groups, np.int64)
        out[: min(len(idx), self.cfg.zone_groups)] = \
            idx[: self.cfg.zone_groups]
        return out

    def _written_per_slot(self, wp: int) -> np.ndarray:
        cfg, dv = self.cfg, self.dv
        P, ppb = cfg.parallelism, cfg.pages_per_block
        seg = np.arange(cfg.n_segments, dtype=np.int64)
        seg_pages = P * ppb
        w_seg = np.clip(wp - seg * seg_pages, 0, seg_pages)
        col = np.arange(P, dtype=np.int64)
        blk = np.clip((w_seg[:, None] - col[None, :] + P - 1) // P,
                      0, ppb)
        lpg = P // dv.zone_groups
        seg_span = dv.pages_per_element // (lpg * ppb)
        slot = ((seg[:, None] // seg_span) * dv.slot_stride
                + col[None, :] // lpg)
        out = np.zeros(cfg.n_slots, np.int64)
        keep = slot.reshape(-1) < cfg.n_slots  # masked scatters drop
        np.add.at(out, slot.reshape(-1)[keep], blk.reshape(-1)[keep])
        return out

    # -- transitions ---------------------------------------------------- #
    def _alloc(self, zone: int, hint: int) -> Tuple[bool, Optional[str],
                                                    Optional[str]]:
        """Mirror of engine ``_alloc``; applies effects when ok.
        Returns (ok, error class, shim message) for the failure case."""
        cfg, dv = self.cfg, self.dv
        limit_ok = self.n_active < dv.max_active

        if cfg.kind is ElementKind.FIXED:
            free = ((self.avail == AVAIL_FREE)
                    | (self.avail == AVAIL_INVALID))
            key = np.where(
                free,
                self.wear if dv.wear_aware
                else np.arange(cfg.n_elements, dtype=np.int64),
                _BIG)
            e = int(np.argmin(key))
            feasible = bool(free.any())
            band = e % cfg.n_groups
            cols_row = (band * cfg.parallelism
                        + np.arange(cfg.parallelism, dtype=np.int64))
            claimed_ids = np.asarray([e], np.int64)
            elems_row = np.full(cfg.n_slots, e, np.int64)
            rr_next = self.rr_next
        else:
            w2, a2 = self._grids()
            if dv.alloc_policy == E.POLICY_SILENT:
                per_rank = dv.pages_per_element * dv.zone_groups
                ranks_hint = -(-hint // max(per_rank, 1))
                take_s = int(np.clip(ranks_hint if hint > 0
                                     else self.take_eff,
                                     1, self.take_eff))
                a2b = self._wear_bounded(w2, a2)
                elig = self._cheapest_groups(w2, a2b, take_s)
                cols, feasible = self._take_lowest(w2, a2b, elig, True,
                                                   take_s)
                if not feasible and dv.wear_bound < _BIG and limit_ok:
                    # would the same claim succeed unbounded?  report
                    # the op as blocked by the wear bound, not capacity
                    elig_u = self._cheapest_groups(w2, a2, take_s)
                    _, feas_u = self._take_lowest(w2, a2, elig_u, True,
                                                  take_s)
                    if feas_u:
                        self.wear_bound_blocked.append(self._idx)
                rr_next = self.rr_next
                rank_lim = take_s
            else:
                elig = self._rr_mask(self.rr_next)
                cols, f1 = self._take_lowest(w2, a2, elig,
                                             dv.wear_aware,
                                             self.take_eff)
                feasible = f1
                if not f1:
                    elig = self._cheapest_groups(w2, a2, self.take_eff)
                    cols, f2 = self._take_lowest(w2, a2, elig, True,
                                                 self.take_eff)
                    feasible = f2
                rr_next = (self.rr_next + dv.zone_groups) % self.ng
                rank_lim = dv.take

            win = self._win(elig)
            eids = win[:, None] * cfg.per_group + cols[win]
            ranks = np.arange(cfg.take, dtype=np.int64)[None, :]
            cpos = np.arange(cfg.zone_groups, dtype=np.int64)[:, None]
            valid = cpos < dv.zone_groups
            raw_slots = ranks * dv.slot_stride + cpos
            claimed = (valid & (raw_slots < self.n_slots_eff)
                       & (ranks < rank_lim))
            elems_row = np.full(cfg.n_slots, -1, np.int64)
            elems_row[raw_slots[claimed]] = eids[claimed]
            claimed_ids = eids[claimed].reshape(-1)
            lpg = cfg.parallelism // dv.zone_groups
            c = np.arange(cfg.parallelism, dtype=np.int64)
            pos = np.clip(c // lpg, 0, cfg.zone_groups - 1)
            cols_row = win[pos] * lpg + c % lpg

        ok = bool(limit_ok and feasible)
        if ok:
            inv = self.avail[claimed_ids] == AVAIL_INVALID
            self.wear[claimed_ids] += inv.astype(np.int64)
            self.erase_count(int(inv.sum()))
            self.avail[claimed_ids] = AVAIL_ALLOCATED
            self.pages[claimed_ids] = 0
            self.ezone[claimed_ids] = zone
            self.zone_state[zone] = E.ZONE_OPEN
            self.zone_wp[zone] = 0
            self.zone_host_wp[zone] = 0
            self.zone_elems[zone] = elems_row
            self.zone_cols[zone] = cols_row
            self.n_active += 1
        if limit_ok:  # rr advance survives an infeasible attempt
            self.rr_next = rr_next
        if ok:
            return True, None, None
        if not limit_ok:
            return False, ERR_ACTIVE_LIMIT, (
                f"open/active zone limit ({dv.max_active}) reached")
        return False, ERR_ALLOC_INFEASIBLE, (
            f"no free storage elements for zone {zone} "
            f"({_spec_name(cfg, dv)})")

    def erase_count(self, n_invalid: int) -> None:
        self.block_erases += n_invalid * (
            self.dv.pages_per_element // self.cfg.pages_per_block)

    def _grow(self, zone: int, wp1: int, pred: bool) -> bool:
        """Mirror of engine ``_grow_silent``."""
        cfg, dv = self.cfg, self.dv
        if cfg.kind is ElementKind.FIXED:
            return True
        per_rank = dv.pages_per_element * dv.zone_groups
        need = int(np.clip(-(-wp1 // max(per_rank, 1)), 1, self.take_eff))
        have = int((self.zone_elems[zone] >= 0).sum()
                   // max(dv.zone_groups, 1))
        if not (pred and dv.alloc_policy == E.POLICY_SILENT
                and need > have):
            return True
        w2, a2 = self._grids()
        a2b = self._wear_bounded(w2, a2)
        lpg = cfg.parallelism // dv.zone_groups
        pos = np.arange(cfg.zone_groups, dtype=np.int64)
        win_g = self.zone_cols[zone][
            np.clip(pos * lpg, 0, cfg.parallelism - 1)] // lpg
        elig = np.zeros(cfg.n_groups, bool)
        elig[win_g[pos < dv.zone_groups]] = True
        k = need - have
        cols, fg = self._take_lowest(w2, a2b, elig, True, k)
        if not fg:
            if dv.wear_bound < _BIG:
                _, fu = self._take_lowest(w2, a2, elig, True, k)
                if fu:
                    self.wear_bound_blocked.append(self._idx)
            return False
        win = self._win(elig)
        eids = win[:, None] * cfg.per_group + cols[win]
        ranks = np.arange(cfg.take, dtype=np.int64)[None, :]
        cpos = np.arange(cfg.zone_groups, dtype=np.int64)[:, None]
        raw_slots = (have + ranks) * dv.slot_stride + cpos
        claimed = ((cpos < dv.zone_groups) & (ranks < k)
                   & (raw_slots < self.n_slots_eff))
        self.zone_elems[zone][raw_slots[claimed]] = eids[claimed]
        ids = eids[claimed].reshape(-1)
        inv = self.avail[ids] == AVAIL_INVALID
        self.wear[ids] += inv.astype(np.int64)
        self.erase_count(int(inv.sum()))
        self.avail[ids] = AVAIL_ALLOCATED
        self.pages[ids] = 0
        self.ezone[ids] = zone
        return True

    def _write(self, zone: int, n_pages: int, host: bool
               ) -> Tuple[bool, Optional[str], Optional[str]]:
        dv = self.dv
        zst0 = self.zone_state[zone]
        aok, aerr, amsg = True, None, None
        if zst0 == E.ZONE_EMPTY:
            # the implicit ALLOC persists even if the write then fails
            aok, aerr, amsg = self._alloc(zone, hint=n_pages)
        wp0 = int(self.zone_wp[zone])
        wp1 = wp0 + n_pages
        fits = wp1 <= dv.zone_pages
        gok = self._grow(zone, wp1,
                         bool(zst0 != E.ZONE_FULL and aok and fits))
        ok = bool(zst0 != E.ZONE_FULL and aok and fits and gok)
        if ok:
            written = self._written_per_slot(wp1)
            elems = self.zone_elems[zone]
            valid = elems >= 0
            touched = valid & (written > 0)
            self.pages[elems[valid]] = written[valid]
            self.avail[elems[touched]] = AVAIL_VALID
            self.zone_wp[zone] = wp1
            self.zone_host_wp[zone] += n_pages if host else 0
            seal = wp1 == dv.zone_pages
            self.zone_state[zone] = (E.ZONE_FULL if seal
                                     else E.ZONE_OPEN)
            self.n_active -= int(seal)
            self.host_pages += n_pages if host else 0
            self.dummy_pages += 0 if host else n_pages
            return True, None, None
        # classification follows the shim's raise order: FULL, then the
        # implicit allocation, then overflow, then on-the-fly growth
        if zst0 == E.ZONE_FULL:
            return False, ERR_FULL, f"write to FULL zone {zone}"
        if not aok:
            return False, aerr, amsg
        if not fits:
            return False, ERR_OVERFLOW, (
                f"zone {zone} overflow: wp={wp0} + {n_pages} "
                f"> {dv.zone_pages}")
        return False, ERR_ALLOC_INFEASIBLE, (
            f"no free storage elements for zone {zone} "
            f"({_spec_name(self.cfg, dv)})")

    def _finish(self, zone: int) -> int:
        """Mirror of engine ``_finish``; returns the dummy padding the
        seal emitted (0 for FULL/EMPTY zones).  Always ok."""
        dv = self.dv
        zst0 = self.zone_state[zone]
        if zst0 == E.ZONE_FULL:
            return 0
        is_open = zst0 == E.ZONE_OPEN
        wp = int(self.zone_wp[zone])
        written = self._written_per_slot(wp)
        elems = self.zone_elems[zone]
        valid = elems >= 0
        untouched = valid & (written == 0) & is_open
        touched = valid & (written > 0) & is_open
        cap = dv.pages_per_element
        pad = int(np.where(touched, cap - written, 0).sum())
        u = elems[untouched]
        t = elems[touched]
        self.avail[u] = AVAIL_FREE
        self.pages[u] = 0
        self.ezone[u] = -1
        self.avail[t] = AVAIL_VALID
        self.pages[t] = cap
        self.zone_elems[zone][untouched] = -1
        self.zone_state[zone] = E.ZONE_FULL
        self.dummy_pages += pad
        self.n_active -= int(is_open)
        return pad

    def _reset(self, zone: int) -> None:
        zst0 = self.zone_state[zone]
        elems = self.zone_elems[zone]
        ids = elems[elems >= 0]
        cur = self.avail[ids]
        self.avail[ids] = np.where(
            cur == AVAIL_VALID, AVAIL_INVALID,
            np.where(cur == AVAIL_ALLOCATED, AVAIL_FREE, cur))
        self.ezone[ids] = -1
        self.pages[ids] = 0
        self.zone_state[zone] = E.ZONE_EMPTY
        self.zone_wp[zone] = 0
        self.zone_host_wp[zone] = 0
        self.zone_elems[zone] = -1
        self.zone_cols[zone] = 0
        self.n_active -= int(zst0 == E.ZONE_OPEN)

    # -- op dispatch ---------------------------------------------------- #
    def apply(self, index: int, row: np.ndarray
              ) -> Tuple[OpVerdict, Optional[OpVerdict], int]:
        """One op row -> (verdict, advisory or None, dummy pad pages)."""
        self._idx = index
        op = int(row[0])
        opc = min(max(op, 0), E.OP_READ)  # the engine's clip
        zone = int(np.clip(row[1], 0, self.dv.n_zones - 1))
        n_pages = int(row[2])
        host = bool(int(row[3]) & E.F_HOST)
        err = msg = None
        advisory = None
        pad = 0
        ok = True
        if opc == E.OP_ALLOC:
            if self.zone_state[zone] == E.ZONE_EMPTY:
                ok, err, msg = self._alloc(zone, hint=n_pages)
            # non-EMPTY: no-op, ok (and no round-robin consumption)
        elif opc == E.OP_WRITE:
            ok, err, msg = self._write(zone, n_pages, host)
        elif opc == E.OP_FINISH:
            pad = self._finish(zone)
        elif opc == E.OP_RESET:
            self._reset(zone)
        elif opc == E.OP_READ:
            if self.zone_state[zone] == E.ZONE_EMPTY:
                advisory = OpVerdict(
                    index, op, zone, True, ERR_UNMAPPED_READ,
                    f"read from unmapped zone {zone}")
        return (OpVerdict(index, op, zone, ok, err, msg), advisory, pad)


def verify_program(cfg: E.EngineConfig, program: np.ndarray,
                   dyn: Optional[E.DynConfig] = None,
                   lane: Optional[int] = None) -> ProgramReport:
    """Walk one ``(n_ops, >=4)`` program through the symbolic device
    model and predict every op's verdict without dispatching.

    ``dyn`` / ``lane`` select the lane's effective geometry exactly as
    the engine would (``lane`` indexes a stacked DynConfig).  The
    predicted ``report.ok`` is bit-identical to ``run_program``'s
    ``trace.ok`` -- the differential guarantee the fuzz tests enforce.
    """
    dv = _Dv(E.dyn_values(cfg, dyn, lane))
    program = np.asarray(program)
    if program.ndim != 2 or program.shape[1] < 4:
        raise ValueError(f"want an (n_ops, >=4) program, got "
                         f"{program.shape}")
    conflicts = _dyn_conflicts(cfg, dv)
    model = _Model(cfg, dv)
    verdicts: List[OpVerdict] = []
    advisories: List[OpVerdict] = []
    dummy_sites: List[Tuple[int, int, int]] = []
    peak_active = 0
    for i, row in enumerate(program):
        verdict, advisory, pad = model.apply(i, row)
        verdicts.append(verdict)
        if advisory is not None:
            advisories.append(advisory)
        if pad > 0:
            dummy_sites.append((i, verdict.zone, pad))
        if (verdict.ok and verdict.op == E.OP_WRITE
                and not (int(row[3]) & E.F_HOST)):
            dummy_sites.append((i, verdict.zone, int(row[2])))
        peak_active = max(peak_active, model.n_active)
    return ProgramReport(
        ok=np.asarray([v.ok for v in verdicts], bool),
        verdicts=verdicts,
        advisories=advisories,
        dummy_sites=dummy_sites,
        host_pages=model.host_pages,
        dummy_pages=model.dummy_pages,
        peak_active=peak_active,
        wear_bound_blocked=sorted(set(model.wear_bound_blocked)),
        conflicts=conflicts,
    )


def verify_programs(cfg: E.EngineConfig, programs: np.ndarray,
                    dyn: Optional[E.DynConfig] = None
                    ) -> List[ProgramReport]:
    """Per-lane :func:`verify_program` over an ``(L, n_ops, >=4)``
    batch (``dyn`` stacked per lane, as ``run_programs`` consumes)."""
    programs = np.asarray(programs)
    if programs.ndim != 3:
        raise ValueError(f"want (L, n_ops, >=4) programs, got "
                         f"{programs.shape}")
    stacked = dyn is not None and np.asarray(dyn.zone_pages).ndim > 0
    return [verify_program(cfg, programs[k], dyn,
                           lane=k if stacked else None)
            for k in range(programs.shape[0])]


def explain_op(cfg: E.EngineConfig, program: np.ndarray, index: int,
               dyn: Optional[E.DynConfig] = None,
               lane: Optional[int] = None) -> OpVerdict:
    """The predicted verdict of one op of a program (walks the prefix
    up to and including ``index``) -- what ``assert_all_ok`` uses to
    name the error class of the first failing op."""
    report = verify_program(cfg, np.asarray(program)[: index + 1],
                            dyn, lane)
    return report.verdicts[index]


# --------------------------------------------------------------------- #
# malformed-row pre-checks (before any dispatch)
# --------------------------------------------------------------------- #
def validate_rows(programs: np.ndarray, *,
                  n_tenants: Optional[int] = None,
                  parity_tenant: Optional[int] = None,
                  where: str = "program") -> np.ndarray:
    """Reject malformed width-5 rows with a clear ``ValueError`` before
    they reach a batched scan (where a bad op code aliases to NOP/READ,
    a negative page count walks the write pointer backwards, and an
    out-of-range tenant tag silently skews the per-class rollups).

    Accepts ``(n_ops, w)`` or ``(L, n_ops, w)`` with ``w >= 4``;
    returns the validated int32 array.  ``n_tenants`` (with the
    optional ``parity_tenant``, default ``n_tenants``) additionally
    bounds the tenant column of width-5 rows.  NOP rows are exempt from
    the page/tenant bounds -- they are padding.
    """
    arr = np.asarray(programs)
    if arr.ndim == 2:
        batch = arr[None]
    elif arr.ndim == 3:
        batch = arr
    else:
        raise ValueError(f"{where}: want (n_ops, >=4) or (L, n_ops, >=4) "
                         f"rows, got shape {arr.shape}")
    if batch.shape[-1] < 4:
        raise ValueError(f"{where}: rows need >= 4 columns "
                         f"(op, zone, n_pages, flags), got "
                         f"{batch.shape[-1]}")

    def _first(mask) -> Tuple[int, int]:
        lane, idx = np.argwhere(mask)[0]
        return int(lane), int(idx)

    op = batch[:, :, 0]
    real = op != E.OP_NOP
    bad_op = (op < E.OP_NOP) | (op > E.OP_READ)
    if bad_op.any():
        lane, idx = _first(bad_op)
        raise ValueError(
            f"{where}: lane {lane} row {idx}: op code "
            f"{int(op[lane, idx])} not in [{E.OP_NOP}, {E.OP_READ}]")
    bad_zone = real & (batch[:, :, 1] < 0)
    if bad_zone.any():
        lane, idx = _first(bad_zone)
        raise ValueError(
            f"{where}: lane {lane} row {idx}: negative zone "
            f"{int(batch[lane, idx, 1])}")
    bad_pages = real & (batch[:, :, 2] < 0)
    if bad_pages.any():
        lane, idx = _first(bad_pages)
        raise ValueError(
            f"{where}: lane {lane} row {idx}: negative page count "
            f"{int(batch[lane, idx, 2])}")
    if n_tenants is not None and batch.shape[-1] > 4:
        hi = n_tenants if parity_tenant is None else max(
            n_tenants - 1, parity_tenant)
        tenant = batch[:, :, 4]
        bad_t = real & ((tenant < 0) | (tenant > hi))
        if bad_t.any():
            lane, idx = _first(bad_t)
            raise ValueError(
                f"{where}: lane {lane} row {idx}: tenant "
                f"{int(tenant[lane, idx])} outside [0, {hi}] "
                f"({n_tenants} tenant classes"
                + (f", parity {parity_tenant})" if parity_tenant
                   is not None else ")"))
    return arr.astype(np.int32) if arr.dtype != np.int32 else arr
