"""Static analysis over the op-program IR: the :mod:`verifier` predicts
per-op legality (bit-identical to the engine's ``trace.ok``) plus
derived reports without dispatching anything; the :mod:`sanitizer`
checks :class:`~repro.core.engine.DeviceState` invariants between
dispatches; :mod:`lint` is the AST-based JAX-pitfall repo lint behind
``tools/lint.py``.  Pure numpy / stdlib on host values -- importing or
running any of it triggers zero jit compilations.
"""

from repro.check.sanitizer import (SanitizerError, assert_state,
                                   assert_states, check_state,
                                   check_states)
from repro.check.verifier import (ERR_ACTIVE_LIMIT, ERR_ALLOC_INFEASIBLE,
                                  ERR_FULL, ERR_OVERFLOW,
                                  ERR_UNMAPPED_READ, OpVerdict,
                                  ProgramReport, explain_op,
                                  validate_rows, verify_program,
                                  verify_programs)

__all__ = [
    "ERR_ACTIVE_LIMIT", "ERR_ALLOC_INFEASIBLE", "ERR_FULL",
    "ERR_OVERFLOW", "ERR_UNMAPPED_READ", "OpVerdict", "ProgramReport",
    "SanitizerError", "assert_state", "assert_states", "check_state",
    "check_states", "explain_op", "validate_rows", "verify_program",
    "verify_programs",
]
