"""Opt-in :class:`~repro.core.engine.DeviceState` invariant checker.

:func:`check_state` pulls one device state host-side and audits the
cross-array invariants the engine maintains by construction -- the
things a corrupted pytree (bad deserialization, hand-edited state, a
future engine bug) would silently violate while every individual array
still "looks" plausible:

* availability / zone-state codes are in range, scratch wear is zero,
  union-grid padding cells are untouched;
* the zone table and the element reverse map agree in both directions,
  and no element is committed to two zones (zone-element disjointness).
  One engine-legal exception is tolerated: silent allocation against a
  dyn-shrunk ``zone_pages`` can collide two claims on one slot, leaving
  the loser ALLOCATED with zero pages and a stale ``elem_zone`` entry
  (see the inline note and ``docs/CHECKING.md``);
* ``0 <= host_wp <= wp <= dyn.zone_pages`` per zone, EMPTY zones are
  fully unmapped with zeroed pointers;
* ``n_active`` equals the OPEN-zone count;
* counters reconcile: ``dlwa == (host + dummy) / host`` against an
  optional external metrics dict, and (for states driven through a
  single effective :class:`~repro.core.engine.DynConfig`, the batched
  engine's per-lane situation) ``block_erases == total element wear *
  blocks_per_element`` -- every erase the engine defers at claim time
  increments exactly one element's wear;
* the silent policy's wear bound (opt-in, ``strict_wear_bound=True``):
  the wear spread of the lane's grid is within ``dyn.wear_bound``.
  This one is *warning-grade by default* because it is not an
  invariant of legal histories: an element can legally sit VALID and
  least-worn forever while the free set churns far past the bound (the
  bound constrains each *claim* against the then-free minimum, not the
  final snapshot) -- see ``docs/CHECKING.md``.

Everything is numpy on fetched values: sanitizing between dispatches
adds zero jit compilations (asserted via ``RecompileCounter`` in
``tests/test_check.py``).  :func:`check_states` / :func:`assert_states`
run the same audit per lane over the stacked states ``run_programs``
returns.
"""

from __future__ import annotations

from typing import List, Optional, Sequence, Union

import numpy as np

from repro.core import engine as E
from repro.core.alloc_exact import (AVAIL_ALLOCATED, AVAIL_FREE,
                                    AVAIL_INVALID, AVAIL_VALID)


class SanitizerError(AssertionError):
    """A :class:`DeviceState` violated an engine invariant.  Carries
    the full violation list in ``violations``."""

    def __init__(self, violations: Sequence[str], where: str = "state"):
        self.violations = list(violations)
        lines = "\n  - ".join(self.violations)
        super().__init__(
            f"{where}: {len(self.violations)} device-state invariant "
            f"violation(s):\n  - {lines}")


def _np(leaf, lane: Optional[int] = None) -> np.ndarray:
    a = np.asarray(leaf)
    if lane is not None:
        a = a[lane]
    return a


def check_state(cfg: E.EngineConfig, state, dyn=None,
                lane: Optional[int] = None, *,
                metrics: Optional[dict] = None,
                check_wear: bool = True,
                strict_wear_bound: bool = False) -> List[str]:
    """Audit one device state; returns the violation list (empty when
    clean).  ``lane`` selects one row of a stacked state/DynConfig (as
    returned by ``run_programs``).  ``metrics`` cross-checks an external
    ``ZoneEngine.metrics`` dict against the state's own counters;
    ``check_wear=False`` skips the wear/erase reconciliation (for
    states merged across heterogeneous lanes, where blocks-per-element
    is not a single scalar); ``strict_wear_bound=True`` additionally
    flags a wear spread beyond ``dyn.wear_bound`` (advisory -- legal
    histories can exceed it, see the module docstring)."""
    dv = E.dyn_values(cfg, dyn, lane)
    v: List[str] = []
    n = cfg.n_elements

    wear = _np(state.elem_wear, lane)
    avail = _np(state.elem_avail, lane)
    pages = _np(state.elem_pages, lane)
    ezone = _np(state.elem_zone, lane)
    zstate = _np(state.zone_state, lane)
    zwp = _np(state.zone_wp, lane)
    zhwp = _np(state.zone_host_wp, lane)
    zelems = _np(state.zone_elems, lane)
    zcols = _np(state.zone_cols, lane)
    n_active = int(_np(state.n_active, lane))
    host = int(_np(state.host_pages, lane))
    dummy = int(_np(state.dummy_pages, lane))
    erases = int(_np(state.block_erases, lane))

    if wear.shape != (n + 1,):
        v.append(f"elem_wear shape {wear.shape}, want ({n + 1},) "
                 f"(n_elements + scratch)")
        return v  # nothing else is trustworthy
    if zelems.shape != (cfg.n_zones, cfg.n_slots):
        v.append(f"zone_elems shape {zelems.shape}, want "
                 f"({cfg.n_zones}, {cfg.n_slots})")
        return v

    # -- code ranges ---------------------------------------------------- #
    bad = ~np.isin(avail[:n], (AVAIL_FREE, AVAIL_ALLOCATED,
                               AVAIL_VALID, AVAIL_INVALID))
    for e in np.nonzero(bad)[0][:3]:
        v.append(f"element {e}: avail code {int(avail[e])} not in 0..3")
    bad = ~np.isin(zstate, (E.ZONE_EMPTY, E.ZONE_OPEN, E.ZONE_FULL))
    for z in np.nonzero(bad)[0][:3]:
        v.append(f"zone {z}: state code {int(zstate[z])} not in "
                 f"EMPTY/OPEN/FULL")
    if wear[n] != 0:
        v.append(f"scratch element wear {int(wear[n])} != 0 (masked "
                 f"scatters must not accumulate wear)")
    if (wear[:n] < 0).any():
        e = int(np.nonzero(wear[:n] < 0)[0][0])
        v.append(f"element {e}: negative wear {int(wear[e])}")

    # -- zone table -> element reverse map ------------------------------ #
    owner = np.full(n, -1, np.int64)   # element -> owning zone (forward)
    for z in range(cfg.n_zones):
        row = zelems[z]
        ids = row[row >= 0]
        if (row < -1).any() or (ids >= n).any():
            v.append(f"zone {z}: slot ids outside [-1, {n})")
            continue
        uniq = np.unique(ids)
        dup_other = uniq[(owner[uniq] >= 0)]
        for e in dup_other[:3]:
            v.append(f"element {int(e)} committed to zones "
                     f"{int(owner[e])} and {z} (disjointness)")
        owner[uniq] = z
        if zstate[z] == E.ZONE_EMPTY:
            if ids.size:
                v.append(f"zone {z}: EMPTY but {ids.size} slots mapped")
            if zwp[z] != 0 or zhwp[z] != 0:
                v.append(f"zone {z}: EMPTY with wp={int(zwp[z])} "
                         f"host_wp={int(zhwp[z])}")
        for e in uniq[:cfg.n_slots]:
            if ezone[e] != z:
                v.append(f"element {int(e)}: elem_zone={int(ezone[e])} "
                         f"but mapped in zone {z}'s slot row")
            if avail[e] not in (AVAIL_ALLOCATED, AVAIL_VALID):
                v.append(f"element {int(e)}: mapped in zone {z} with "
                         f"avail code {int(avail[e])} (want ALLOCATED "
                         f"or VALID)")

    unmapped = owner < 0
    # Silent-policy allocation under a dyn-shrunk zone (zone_pages below
    # the spec's static capacity) computes slot indices against the
    # static stride, so two claimed elements can collide on one slot:
    # the slot-row scatter keeps the last writer and drops the other,
    # while the elem_zone/avail scatters cover every claimed id.  The
    # dropped element stays ALLOCATED with zero live pages and a stale
    # reverse-map entry; the engine never reads elem_zone for
    # correctness, so this is a legal (if leaky) state, not corruption.
    orphan_ok = (avail[:n] == AVAIL_ALLOCATED) & (pages[:n] == 0)
    stray = unmapped & (ezone[:n] >= 0) & ~orphan_ok
    for e in np.nonzero(stray)[0][:3]:
        v.append(f"element {e}: elem_zone={int(ezone[e])} but absent "
                 f"from every zone's slot row")
    freeish = np.isin(avail[:n], (AVAIL_FREE, AVAIL_INVALID))
    bad = freeish & ~unmapped
    for e in np.nonzero(bad)[0][:3]:
        v.append(f"element {e}: avail FREE/INVALID but mapped in zone "
                 f"{int(owner[e])}")
    bad = freeish & (pages[:n] != 0)
    for e in np.nonzero(bad)[0][:3]:
        v.append(f"element {e}: avail FREE/INVALID with "
                 f"{int(pages[e])} live pages")
    bad = (pages[:n] < 0) | (pages[:n] > dv["pages_per_element"])
    for e in np.nonzero(bad)[0][:3]:
        v.append(f"element {e}: pages {int(pages[e])} outside "
                 f"[0, {dv['pages_per_element']}]")

    # -- per-zone pointers ---------------------------------------------- #
    bad = (zwp < 0) | (zwp > dv["zone_pages"])
    for z in np.nonzero(bad)[0][:3]:
        v.append(f"zone {z}: wp {int(zwp[z])} outside "
                 f"[0, {dv['zone_pages']}]")
    bad = (zhwp < 0) | (zhwp > zwp)
    for z in np.nonzero(bad)[0][:3]:
        v.append(f"zone {z}: host_wp {int(zhwp[z])} outside "
                 f"[0, wp={int(zwp[z])}]")
    bad = (zcols < 0) | (zcols >= cfg.n_groups * cfg.parallelism)
    for z in np.nonzero(bad.any(axis=1))[0][:3]:
        v.append(f"zone {z}: column map entries outside "
                 f"[0, {cfg.n_groups * cfg.parallelism})")

    # -- union-grid padding stays untouched ----------------------------- #
    ng_eff = dv["n_elements"] // max(dv["per_group"], 1)
    grid = np.arange(n)
    in_lane = ((grid // cfg.per_group < ng_eff)
               & (grid % cfg.per_group < dv["per_group"]))
    pad_dirty = ~in_lane & ((avail[:n] != AVAIL_FREE) | (wear[:n] != 0)
                            | (pages[:n] != 0) | (ezone[:n] != -1))
    for e in np.nonzero(pad_dirty)[0][:3]:
        v.append(f"element {e}: union-grid padding cell touched "
                 f"(avail={int(avail[e])} wear={int(wear[e])})")

    # -- counters ------------------------------------------------------- #
    open_count = int((zstate == E.ZONE_OPEN).sum())
    if n_active != open_count:
        v.append(f"n_active={n_active} but {open_count} zones are OPEN")
    if host < 0 or dummy < 0:
        v.append(f"negative page counters host={host} dummy={dummy}")
    if check_wear:
        bpe = dv["pages_per_element"] // cfg.pages_per_block
        want = int(wear[:n].sum()) * bpe
        if erases != want:
            v.append(
                f"block_erases={erases} but total element wear "
                f"{int(wear[:n].sum())} x {bpe} blocks/element = {want} "
                f"(every deferred erase increments one element's wear)")
    if metrics is not None:
        want_dlwa = (host + dummy) / host if host else 1.0
        for key, want in (("host_pages", float(host)),
                          ("dummy_pages", float(dummy)),
                          ("block_erases", float(erases)),
                          ("dlwa", want_dlwa)):
            got = metrics.get(key)
            if got is not None and not np.isclose(got, want):
                v.append(f"metrics[{key!r}]={got} but state implies "
                         f"{want}")

    # -- wear-bound spread (advisory) ----------------------------------- #
    if (strict_wear_bound and dv["alloc_policy"] == E.POLICY_SILENT
            and in_lane.any()):
        lane_wear = wear[:n][in_lane]
        spread = int(lane_wear.max()) - int(lane_wear.min())
        if spread > dv["wear_bound"]:
            v.append(f"wear spread {spread} exceeds wear_bound="
                     f"{dv['wear_bound']} (advisory: legal histories "
                     f"can exceed a per-claim bound in snapshot)")
    return v


def assert_state(cfg: E.EngineConfig, state, dyn=None,
                 lane: Optional[int] = None, *,
                 where: str = "state", **kw) -> None:
    """:func:`check_state`, raising :class:`SanitizerError` on any
    violation."""
    v = check_state(cfg, state, dyn, lane, **kw)
    if v:
        raise SanitizerError(v, where=where)


def check_states(cfg: E.EngineConfig, states, dyn=None, *,
                 lanes: Optional[Sequence[int]] = None,
                 **kw) -> List[List[str]]:
    """Per-lane :func:`check_state` over the stacked states (leading
    lane axis on every leaf) that ``run_programs`` returns.  ``dyn``
    may be a matching stacked DynConfig, a single one, or ``None``."""
    n_lanes = int(np.asarray(states.n_active).shape[0])
    stacked = dyn is not None and np.asarray(dyn.zone_pages).ndim > 0
    out = []
    for k in (lanes if lanes is not None else range(n_lanes)):
        out.append(check_state(cfg, states, dyn, lane=int(k),
                               **kw) if stacked else
                   check_state(cfg, _slice_lane(states, int(k)), dyn,
                               **kw))
    return out


def _slice_lane(states, k: int):
    return type(states)(*[np.asarray(leaf)[k] for leaf in states])


def assert_states(cfg: E.EngineConfig, states, dyn=None, *,
                  where: str = "states", **kw) -> None:
    for k, v in enumerate(check_states(cfg, states, dyn, **kw)):
        if v:
            raise SanitizerError(v, where=f"{where}[lane {k}]")
