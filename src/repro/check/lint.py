"""AST-based repo lint for the JAX pitfalls this codebase has actually
hit (each rule cites the PR that paid for it):

``dispatch-in-loop``
    ``run_program`` / ``run_programs`` called inside a Python ``for`` /
    ``while`` body.  Each iteration re-enters the jit boundary, and any
    shape drift (program length, lane count) recompiles per iteration
    -- the PR 6 recompile-per-shape leak.  Batch the programs and
    dispatch once (``run_programs``), or hoist the call out of the
    loop.

``vmap-over-scan``
    ``vmap`` applied over the engine's scan/op entry points
    (``run_program`` / ``run_programs`` / ``_scan_program`` /
    ``apply_op``).  The engine's batched-scatter paths are written for
    the explicit lane axis of ``run_programs``; wrapping them in
    ``vmap`` instead lowers the per-zone scatters to gather/select
    chains (measured 27x slower in the PR 4 fleet bring-up).

``jit-needs-static``
    ``jax.jit`` of a function taking a config-like argument (``cfg`` /
    ``config`` / ``spec`` / ``obs``) without ``static_argnums`` /
    ``static_argnames``.  Configs are hashable compile-time constants
    here; tracing them either fails outright (dataclasses of ints used
    in shapes) or silently retraces per call.

``bench-schema``
    A ``BENCH_<name>.json`` artifact reference that ``tools/bench.py``
    does not actually write, or a hard-coded integer bench
    ``schema_version`` (import ``SCHEMA_VERSION`` instead) -- stale
    references survived two artifact renames before this gate.  Only
    files that mention ``BENCH_*.json`` artifacts are in scope for the
    schema-version half, so unrelated schemas (e.g. the Perfetto
    export's) are not flagged.

Suppress any finding with a ``# lint: ok`` comment on the flagged
line.  Pure stdlib (``ast`` + ``tokenize``); no repro imports, so
``tools/lint.py`` can run it without touching JAX.
"""

from __future__ import annotations

import ast
import dataclasses
import io
import re
import tokenize
from pathlib import Path
from typing import Iterable, List, Optional, Sequence, Set

PRAGMA = re.compile(r"#\s*lint:\s*ok\b")
_BENCH_REF = re.compile(r"^BENCH_\w+\.json$")
_BENCH_ANY = re.compile(r"BENCH_\w+\.json")

#: callables whose per-iteration dispatch is the recompile hazard
DISPATCH_NAMES = {"run_program", "run_programs"}
#: engine entry points that must not be vmapped over
SCAN_NAMES = {"run_program", "run_programs", "_scan_program", "apply_op"}
#: parameter names that mark a function as config-taking
CONFIG_PARAMS = {"cfg", "config", "spec", "obs", "engine_config"}


@dataclasses.dataclass(frozen=True)
class Finding:
    path: str
    line: int
    rule: str
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


def _call_name(func: ast.AST) -> Optional[str]:
    if isinstance(func, ast.Name):
        return func.id
    if isinstance(func, ast.Attribute):
        return func.attr
    return None


def _names_in(node: ast.AST) -> Set[str]:
    out: Set[str] = set()
    for sub in ast.walk(node):
        if isinstance(sub, ast.Name):
            out.add(sub.id)
        elif isinstance(sub, ast.Attribute):
            out.add(sub.attr)
    return out


def _is_jit(node: ast.AST) -> bool:
    """``jax.jit`` / ``jit`` as a bare expression."""
    return _call_name(node) == "jit"


def _jit_without_static(dec: ast.AST) -> bool:
    """True for a decorator that jits WITHOUT static_argnums/names:
    bare ``@jax.jit``, ``@jax.jit(...)``, or
    ``@functools.partial(jax.jit, ...)`` missing the static keywords."""
    if _is_jit(dec):
        return True
    if isinstance(dec, ast.Call):
        statics = {kw.arg for kw in dec.keywords}
        has_static = statics & {"static_argnums", "static_argnames"}
        if _is_jit(dec.func):
            return not has_static
        if (_call_name(dec.func) == "partial" and dec.args
                and _is_jit(dec.args[0])):
            return not has_static
    return False


class _Visitor(ast.NodeVisitor):
    def __init__(self, path: str, bench_artifacts: Set[str],
                 mentions_bench: bool):
        self.path = path
        self.bench_artifacts = bench_artifacts
        self.mentions_bench = mentions_bench
        self.loop_depth = 0
        self.findings: List[Finding] = []

    def _add(self, node: ast.AST, rule: str, message: str) -> None:
        self.findings.append(
            Finding(self.path, getattr(node, "lineno", 0), rule, message))

    # -- loops ---------------------------------------------------------- #
    def _loop(self, node) -> None:
        self.loop_depth += 1
        self.generic_visit(node)
        self.loop_depth -= 1

    visit_For = visit_AsyncFor = visit_While = _loop

    def visit_Call(self, node: ast.Call) -> None:
        name = _call_name(node.func)
        if name in DISPATCH_NAMES and self.loop_depth > 0:
            self._add(node, "dispatch-in-loop",
                      f"{name}() inside a Python loop dispatches (and "
                      f"on shape drift recompiles) per iteration; "
                      f"batch the lanes into one run_programs call")
        if name == "vmap":
            hit: Set[str] = set()
            for arg in node.args:
                hit |= _names_in(arg) & SCAN_NAMES
            if hit:
                self._add(node, "vmap-over-scan",
                          f"vmap over {sorted(hit)[0]} lowers the "
                          f"engine's batched scatters to gather/select "
                          f"chains (measured 27x slower); use the "
                          f"explicit lane axis of run_programs")
        self.generic_visit(node)

    def _visit_def(self, node) -> None:
        args = node.args
        params = {a.arg for a in
                  args.posonlyargs + args.args + args.kwonlyargs}
        config_params = params & CONFIG_PARAMS
        for dec in node.decorator_list:
            if config_params and _jit_without_static(dec):
                self._add(
                    dec, "jit-needs-static",
                    f"jit of {node.name}() without static_argnums/"
                    f"static_argnames, but it takes config-like "
                    f"argument(s) {sorted(config_params)}; configs "
                    f"are hashable compile-time constants here")
        depth, self.loop_depth = self.loop_depth, 0
        self.generic_visit(node)
        self.loop_depth = depth

    visit_FunctionDef = visit_AsyncFunctionDef = _visit_def

    def visit_Constant(self, node: ast.Constant) -> None:
        if (isinstance(node.value, str)
                and _BENCH_REF.match(node.value)
                and self.bench_artifacts
                and node.value not in self.bench_artifacts):
            self._add(node, "bench-schema",
                      f"{node.value} is not an artifact tools/bench.py "
                      f"writes ({', '.join(sorted(self.bench_artifacts))})")
        self.generic_visit(node)

    def visit_Compare(self, node: ast.Compare) -> None:
        if self.mentions_bench:
            sides = [node.left, *node.comparators]
            has_key = any("schema_version" in _strs_in(s) for s in sides)
            has_int = any(isinstance(s, ast.Constant)
                          and isinstance(s.value, int) for s in sides)
            if has_key and has_int:
                self._add(node, "bench-schema",
                          "hard-coded bench schema_version comparison; "
                          "import SCHEMA_VERSION from tools/bench.py")
        self.generic_visit(node)


def _strs_in(node: ast.AST) -> Set[str]:
    return {sub.value for sub in ast.walk(node)
            if isinstance(sub, ast.Constant)
            and isinstance(sub.value, str)}


def _pragma_lines(source: str) -> Set[int]:
    out: Set[int] = set()
    try:
        for tok in tokenize.generate_tokens(io.StringIO(source).readline):
            if tok.type == tokenize.COMMENT and PRAGMA.search(tok.string):
                out.add(tok.start[0])
    except tokenize.TokenizeError:
        pass
    return out


def bench_artifacts(root: Path) -> Set[str]:
    """The ``BENCH_*.json`` artifact names ``tools/bench.py`` actually
    writes -- the canonical set every other reference is checked
    against.  Empty (disabling the artifact-name half of
    ``bench-schema``) when the file is absent."""
    bench = root / "tools" / "bench.py"
    if not bench.is_file():
        return set()
    return set(_BENCH_ANY.findall(bench.read_text()))


def lint_source(source: str, path: str, *,
                bench_names: Set[str] = frozenset()) -> List[Finding]:
    """Lint one module's source text; ``path`` labels the findings."""
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as exc:
        return [Finding(path, exc.lineno or 0, "syntax",
                        f"does not parse: {exc.msg}")]
    visitor = _Visitor(path, set(bench_names),
                       mentions_bench=bool(_BENCH_ANY.search(source)))
    visitor.visit(tree)
    suppressed = _pragma_lines(source)
    return [f for f in visitor.findings if f.line not in suppressed]


def lint_paths(root: Path, paths: Iterable[Path]) -> List[Finding]:
    names = bench_artifacts(root)
    out: List[Finding] = []
    for p in sorted(paths):
        if p.name == "bench.py" and p.parent.name == "tools":
            continue  # the canonical artifact list itself
        rel = str(p.relative_to(root)) if p.is_relative_to(root) else str(p)
        out.extend(lint_source(p.read_text(), rel, bench_names=names))
    return sorted(out, key=lambda f: (f.path, f.line))


def lint_tree(root: Path,
              subdirs: Sequence[str] = ("src", "tools", "tests")
              ) -> List[Finding]:
    """Lint every ``.py`` file under ``root``'s code directories."""
    paths: List[Path] = []
    for sub in subdirs:
        base = root / sub
        if base.is_dir():
            paths.extend(base.rglob("*.py"))
    return lint_paths(root, paths)
