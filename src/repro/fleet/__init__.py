"""Multi-tenant fleet simulation + allocator search over the ZoneEngine.

The fleet layer turns the repo from "replay the paper's sweeps" into
"search the design space the paper argues for":

* :mod:`repro.fleet.tenants` -- tenant-tagged width-5 op programs, the
  round-robin tenant interleaver, and the program-space RAID striper
  (same stripe math as :class:`repro.array.ZNSArray`);
* :mod:`repro.fleet.runner`  -- T tenants x N devices x K configs
  executed through ONE batched ``run_programs`` dispatch (heterogeneous
  per-lane geometries/allocators via ``DynConfig``) plus op-granular
  fleet timing;
* :mod:`repro.fleet.search`  -- grid/random search over (tenant mix,
  zone geometry, chunk size, parity, wear-awareness) scored on a
  weighted (DLWA, wear spread, p99 tenant latency) objective, with the
  Pareto front of non-dominated configs.

Entry points: ``benchmarks/fleet_search.py`` (the sweep),
``examples/fleet.py`` (a small demo), ``tools/bench.py`` (writes the
batched-vs-legacy speedup artifact ``BENCH_fleet.json`` by default;
``--skip-engine`` isolates the fleet comparison).
"""

from repro.fleet.runner import FleetResult, config_report, run_fleet
from repro.fleet.search import (MIXES, N_TENANTS, OBJECTIVE_KEYS,
                                FleetConfig, build_fleet_batch,
                                evaluate_configs, grid_space,
                                pareto_front, random_space,
                                run_configs_legacy, score_rows)
from repro.fleet.tenants import (TENANT_COL, interleave_tenants,
                                 pad_programs, stripe_program, tag_tenant)

__all__ = [
    "FleetResult", "config_report", "run_fleet",
    "MIXES", "N_TENANTS", "OBJECTIVE_KEYS", "FleetConfig",
    "build_fleet_batch", "evaluate_configs", "grid_space",
    "pareto_front", "random_space", "run_configs_legacy", "score_rows",
    "TENANT_COL", "interleave_tenants", "pad_programs",
    "stripe_program", "tag_tenant",
]
