"""Multi-tenant fleet simulation + allocator search over the ZoneEngine.

The fleet layer turns the repo from "replay the paper's sweeps" into
"search the design space the paper argues for":

* :mod:`repro.fleet.tenants` -- tenant-tagged width-5 op programs, the
  round-robin tenant interleaver, and the program-space RAID striper
  (same stripe math as :class:`repro.array.ZNSArray`);
* :mod:`repro.fleet.runner`  -- T tenants x N devices x K configs
  executed through ONE batched ``run_programs`` dispatch (heterogeneous
  per-lane geometries/allocators *and element specs* via ``DynConfig``
  on a padded union config) plus op-granular fleet timing;
* :mod:`repro.fleet.search`  -- the :class:`SearchSpace` candidate
  codec and the shared batched :class:`Evaluator` (one dispatch per
  candidate set, fidelity-truncated programs, budget ledger), plus
  grid/random enumeration over (tenant mix, zone geometry, chunk size,
  parity, wear-awareness, element spec) scored on a weighted (DLWA,
  wear spread, p99 tenant latency) objective, with the Pareto front of
  non-dominated configs;
* :mod:`repro.fleet.evolve`  -- the adaptive strategy: evolutionary
  proposals (mutation/crossover on the gene vector) with a
  successive-halving rung schedule, a persistent cross-generation
  Pareto archive, and seeded determinism.

Entry points: ``benchmarks/fleet_search.py --strategy {grid,random,
evolve}`` (the sweep), ``examples/fleet.py`` (a small demo),
``tools/bench.py`` (writes the batched-vs-legacy speedup and the
evolve-vs-random dispatches-to-target comparison to
``BENCH_fleet.json``; ``--skip-engine`` isolates the fleet part).
"""

from repro.fleet.evolve import (EvolveParams, EvolveResult, evolve,
                                evolve_vs_random)
from repro.fleet.runner import (FleetResult, assert_all_ok, config_report,
                                dispatch_cost, real_op_count, run_fleet)
from repro.fleet.search import (MIXES, N_TENANTS, OBJECTIVE_KEYS,
                                Evaluator, FleetConfig, SearchSpace,
                                build_fleet_batch, evaluate_configs,
                                grid_space, pareto_front, random_space,
                                run_configs_legacy, score_rows)
from repro.fleet.tenants import (TENANT_COL, interleave_tenants,
                                 pad_programs, stripe_program, tag_tenant)

__all__ = [
    "EvolveParams", "EvolveResult", "evolve", "evolve_vs_random",
    "FleetResult", "assert_all_ok", "config_report", "dispatch_cost",
    "real_op_count", "run_fleet",
    "MIXES", "N_TENANTS", "OBJECTIVE_KEYS", "Evaluator", "FleetConfig",
    "SearchSpace", "build_fleet_batch", "evaluate_configs", "grid_space",
    "pareto_front", "random_space", "run_configs_legacy", "score_rows",
    "TENANT_COL", "interleave_tenants", "pad_programs",
    "stripe_program", "tag_tenant",
]
