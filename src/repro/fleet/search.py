"""Allocator/geometry design-space search over batched fleet simulations.

The paper's core argument is that zone-allocation strategy (element
granularity, zone geometry, write order, mapping) drives DLWA, wear and
host interference; SilentZNS wins by searching a wider allocation design
space.  This module makes that search executable: a
:class:`FleetConfig` crosses

* **tenant mix**      -- which workload programs share the fleet
                         (:data:`MIXES`, built from the paper's
                         benchmarks in :mod:`repro.core.workloads`);
* **zone geometry**   -- effective segments per zone, realized as a
                         ``DynConfig`` capacity override on the padded
                         static config (heterogeneous lanes batch
                         together);
* **element spec**    -- the zone storage-element granularity (paper
                         §4, Table 1), realized as a per-lane
                         ``DynConfig`` spec selection on a padded
                         *union* config (``ZoneEngine`` built over a
                         spec set) -- mixed-spec fleets run in ONE
                         dispatch;
* **chunk size**      -- the RAID stripe unit (pages per member turn);
* **parity**          -- log-structured RAID-5 parity on/off;
* **allocator**       -- wear-aware vs first-fit element selection;

and every config expands to ``n_devices`` lanes that execute in ONE
``run_programs`` dispatch (:func:`evaluate_configs`).  Configs are
scored on a weighted (DLWA, wear spread, p99 tenant latency) objective
(:func:`score_rows`) and the non-dominated set is reported as the
Pareto front (:func:`pareto_front`).

Grid enumeration (:func:`grid_space`) and seeded random sampling
(:func:`random_space`) are both deterministic: same seed, same configs,
same scores (tested).  Every strategy -- grid, random, and the
evolutionary/successive-halving searcher in :mod:`repro.fleet.evolve`
-- scores candidates through one shared :class:`Evaluator`: a
:class:`SearchSpace` supplies the candidate codec (config <-> gene
vector), :meth:`Evaluator.evaluate` runs one batched dispatch per
candidate set (optionally at reduced *fidelity* via truncated op
programs), and :meth:`Evaluator.objective` is the fixed scalar the
adaptive strategies minimize.
"""

from __future__ import annotations

import contextlib
import dataclasses
import itertools
import math
import random as pyrandom
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import engine as zengine
from repro.core import timing, workloads
from repro.core.elements import SUPERBLOCK, ElementKind, ElementSpec
from repro.core.engine import ZoneEngine, stack_dyn
from repro.core.geometry import FlashGeometry, ZoneGeometry
from repro.fleet import runner
from repro.fleet.tenants import (interleave_tenants, pad_programs,
                                 stripe_program, tag_tenant)

#: real tenants per mix (parity appends carry the tag N_TENANTS)
N_TENANTS = 2


def _with_churn(program: np.ndarray, cycles: int = 2) -> np.ndarray:
    """Repeat a tenant program ``cycles`` times with a RESET of every
    touched zone in between -- re-allocation after RESET is what drives
    deferred erases and therefore wear (paper §5), so without churn the
    wear objective is degenerate."""
    zones = sorted({int(z) for z in program[:, 1]})
    resets = zengine.encode_program(
        [(zengine.OP_RESET, z, 0, 0) for z in zones],
        width=program.shape[1])
    parts: List[np.ndarray] = []
    for c in range(cycles):
        if c:
            parts.append(resets)
        parts.append(program)
    return np.concatenate(parts)


def _mix_dlwa_pair(eng: ZoneEngine, cap: int) -> List[np.ndarray]:
    """Two DLWA-benchmark tenants at different occupancies, disjoint
    superzones (paper Fig. 4a traffic, multi-tenant edition), cycled
    through RESET churn."""
    return [
        _with_churn(workloads.dlwa_program(
            eng, occupancy=0.35, n_zones=2, zone_base=0, zone_pages=cap)),
        _with_churn(workloads.dlwa_program(
            eng, occupancy=0.7, n_zones=2, zone_base=2, zone_pages=cap)),
    ]


def _mix_dlwa_write(eng: ZoneEngine, cap: int) -> List[np.ndarray]:
    """A DLWA (fill + FINISH) tenant next to a sequential-writer tenant
    (paper Fig. 9 jobs) -- FINISH padding interferes with host writes.
    The DLWA side churns; the writer keeps zones open."""
    return [
        _with_churn(workloads.dlwa_program(
            eng, occupancy=0.5, n_zones=2, zone_base=0, zone_pages=cap)),
        workloads.write_program(eng, request_kib=256, n_jobs=2,
                                mib_per_job=96, zone_base=2,
                                zone_pages=cap),
    ]


#: tenant-mix name -> builder(eng, logical_superzone_pages) -> programs
MIXES: Dict[str, Callable[[ZoneEngine, int], List[np.ndarray]]] = {
    "dlwa_pair": _mix_dlwa_pair,
    "dlwa_write": _mix_dlwa_write,
}

#: objective keys, all lower-is-better
OBJECTIVE_KEYS: Tuple[str, ...] = ("dlwa", "wear_cv", "p99_latency_s")


@dataclasses.dataclass(frozen=True)
class FleetConfig:
    """One point of the allocator/geometry/array design space.

    ``spec`` may be a *tuple* of specs: member device ``d`` then gets
    spec ``spec[d % len(spec)]`` (a heterogeneous-member array, per-lane
    through the union config).  ``n_devices = 0`` means "the
    evaluator's default member count" -- the backward-compatible value
    every pre-array config carries.
    """

    mix: str             # tenant mix (MIXES key)
    n_segments: int      # effective segments per member zone
    chunk_pages: int     # stripe unit (pages per member turn)
    parity: bool         # log-structured RAID-5 parity
    wear_aware: bool     # allocator element selection (wear vs first-fit)
    spec: ElementSpec = SUPERBLOCK  # element granularity (or a mix tuple)
    n_devices: int = 0   # array member count (0 = evaluator default)
    alloc_policy: str = "traditional"  # zone mapping: traditional|silent

    def specs_mix(self) -> Tuple[ElementSpec, ...]:
        """The spec tuple member ``d`` indexes with ``d % len``."""
        if isinstance(self.spec, ElementSpec):
            return (self.spec,)
        return tuple(self.spec)

    def describe(self) -> str:
        mix = self.specs_mix()
        spec_name = ("+".join(s.name for s in mix) if len(mix) > 1
                     else mix[0].name)
        base = (f"{self.mix}_s{self.n_segments}_c{self.chunk_pages}"
                f"_{'p1' if self.parity else 'p0'}"
                f"_{'wa' if self.wear_aware else 'ff'}"
                f"_{spec_name}")
        if self.n_devices:
            base += f"_d{self.n_devices}"
        if self.alloc_policy != "traditional":
            base += f"_{self.alloc_policy}"
        return base


@dataclasses.dataclass(frozen=True)
class SearchSpace:
    """The finite design-space axes plus the candidate *gene* codec.

    A candidate is a :class:`FleetConfig`; its gene vector is the tuple
    of per-axis indexes (one int per axis, in axis order).  The codec
    is what the evolutionary operators in :mod:`repro.fleet.evolve`
    mutate/cross over, so every strategy shares one source of truth for
    which configs exist.
    """

    mixes: Tuple[str, ...] = tuple(MIXES)
    segments: Tuple[int, ...] = (22, 11)
    chunks: Tuple[int, ...] = (1536, 3072)
    parities: Tuple[bool, ...] = (False, True)
    wear: Tuple[bool, ...] = (True, False)
    specs: Tuple = (SUPERBLOCK,)   # each entry: a spec, or a mix tuple
    devices: Tuple[int, ...] = (0,)  # member counts (0 = default)
    policies: Tuple[str, ...] = ("traditional",)  # alloc_policy values

    @property
    def _axes_fields(self) -> Tuple[Tuple[Tuple, str], ...]:
        # the devices / policies axes join the codec only when the
        # space declares values to search: a default space keeps its
        # 6-gene vectors, so seeded sampling/evolve trajectories from
        # before those axes stay bit-identical.  Genes map to configs
        # by *field name* (not position): with policies present but
        # devices absent, a positional FleetConfig(*vals) would land
        # the policy in n_devices.
        base = [(self.mixes, "mix"), (self.segments, "n_segments"),
                (self.chunks, "chunk_pages"), (self.parities, "parity"),
                (self.wear, "wear_aware"), (self.specs, "spec")]
        if self.devices != (0,):
            base.append((self.devices, "n_devices"))
        if self.policies != ("traditional",):
            base.append((self.policies, "alloc_policy"))
        return tuple(base)

    @property
    def axes(self) -> Tuple[Tuple, ...]:
        return tuple(a for a, _ in self._axes_fields)

    def __len__(self) -> int:
        return math.prod(len(a) for a in self.axes)

    def decode(self, genes: Sequence[int]) -> FleetConfig:
        """Per-axis index vector -> config (indexes taken modulo each
        axis length, so any int vector decodes)."""
        return FleetConfig(**{
            f: axis[g % len(axis)]
            for (axis, f), g in zip(self._axes_fields, genes)})

    def encode(self, fc: FleetConfig) -> Tuple[int, ...]:
        """Config -> per-axis index vector (raises if off the axes)."""
        if fc.n_devices and self.devices == (0,):
            raise ValueError(
                f"{fc.describe()}: config sets n_devices but this space "
                f"has no devices axis")
        if (fc.alloc_policy != "traditional"
                and self.policies == ("traditional",)):
            raise ValueError(
                f"{fc.describe()}: config sets alloc_policy "
                f"{fc.alloc_policy!r} but this space has no policies "
                f"axis")
        return tuple(axis.index(getattr(fc, f))
                     for axis, f in self._axes_fields)

    def grid(self) -> List[FleetConfig]:
        """Full cross product, axis-major order."""
        fields = [f for _, f in self._axes_fields]
        return [FleetConfig(**dict(zip(fields, vals)))
                for vals in itertools.product(*self.axes)]

    def sample_genes(self, rng: pyrandom.Random) -> Tuple[int, ...]:
        """One uniform gene vector from a seeded ``random.Random``."""
        return tuple(rng.randrange(len(a)) for a in self.axes)


def grid_space(*, mixes: Sequence[str] = tuple(MIXES),
               segments: Sequence[int] = (22, 11),
               chunks: Sequence[int] = (1536, 3072),
               parities: Sequence[bool] = (False, True),
               wear: Sequence[bool] = (True, False),
               specs: Sequence = (SUPERBLOCK,),
               devices: Sequence[int] = (0,),
               policies: Sequence[str] = ("traditional",)
               ) -> List[FleetConfig]:
    """Full cross product (defaults: 2*2*2*2*2 = 32 configs on zn540)."""
    return SearchSpace(tuple(mixes), tuple(segments), tuple(chunks),
                       tuple(parities), tuple(wear), tuple(specs),
                       tuple(devices), tuple(policies)).grid()


def random_space(seed: int, n: int, *,
                 mixes: Sequence[str] = tuple(MIXES),
                 segments: Sequence[int] = (22, 11),
                 chunks: Sequence[int] = (1536, 3072),
                 parities: Sequence[bool] = (False, True),
                 wear: Sequence[bool] = (True, False),
                 specs: Sequence = (SUPERBLOCK,),
                 devices: Sequence[int] = (0,),
                 policies: Sequence[str] = ("traditional",)
                 ) -> List[FleetConfig]:
    """``n`` distinct configs sampled without replacement from the grid
    by a seeded PRNG -- deterministic under a fixed seed (tested)."""
    grid = grid_space(mixes=mixes, segments=segments, chunks=chunks,
                      parities=parities, wear=wear, specs=specs,
                      devices=devices, policies=policies)
    rng = np.random.default_rng(seed)
    idx = rng.choice(len(grid), size=min(n, len(grid)), replace=False)
    return [grid[i] for i in idx]


def _nd_max(configs: Sequence[FleetConfig], default: int) -> int:
    """Lanes per config in the rectangular batch: the widest member
    count in the set (``n_devices = 0`` falls back to ``default``)."""
    return max((fc.n_devices or default for fc in configs),
               default=default)


def build_fleet_batch(eng: ZoneEngine, configs: Sequence[FleetConfig],
                      *, n_devices: int, fidelity: float = 1.0,
                      pad_quantum: int = 1
                      ) -> Tuple[np.ndarray, object, List[np.ndarray]]:
    """Expand configs to the rectangular lane batch of one dispatch.

    Returns ``(programs (K*nd_max, n_ops, 5), dyn with (K*nd_max,)
    leaves, merged logical programs per config)``, where ``nd_max`` is
    :func:`_nd_max` -- a config whose ``n_devices`` is below the widest
    member count in the set gets inert all-NOP pad lanes (configs with
    mixed array sizes still batch into ONE rectangular dispatch).  The
    merged logical program of config ``k`` (tenants interleaved,
    superzone-addressed, pre-striping) is what the per-op legacy
    comparator replays through a real ``ZNSArray`` -- both paths
    execute identical logical traffic.

    ``fidelity`` < 1 truncates each merged logical program to its first
    ``ceil(fidelity * n_rows)`` rows *before* striping -- the low-cost
    rung evaluation of the successive-halving searcher.  A prefix of a
    legal program is legal, so truncated lanes still pass
    ``assert_all_ok``; their metrics are comparable only within the
    same fidelity.

    ``pad_quantum`` rounds the padded op axis up to a multiple (NOP
    rows are inert), so repeated same-size batches hit one compiled
    ``run_programs`` shape -- see :class:`Evaluator`.
    """
    if not 0.0 < fidelity <= 1.0:
        raise ValueError(f"fidelity must be in (0, 1], got {fidelity}")
    if eng.cfg.kind is ElementKind.FIXED:
        raise ValueError("FIXED elements span the whole static zone and "
                         "cannot take an effective-capacity override")
    seg_pages = eng.zone_geom.parallelism * eng.flash.pages_per_block
    nd_max = _nd_max(configs, n_devices)
    lane_programs: List[np.ndarray] = []
    dyns = []
    merged_per_config: List[np.ndarray] = []
    for fc in configs:
        if fc.n_segments > eng.zone_geom.n_segments:
            raise ValueError(f"{fc}: n_segments exceeds the static "
                             f"geometry ({eng.zone_geom.n_segments})")
        specs_mix = fc.specs_mix()
        for s in specs_mix:
            if s not in eng.members:
                raise ValueError(
                    f"{fc}: spec {s.name} is not a member of the "
                    f"engine's config (members: "
                    f"{[m.name for m in eng.members]}); build the engine "
                    f"over the search space's spec set")
        nd = fc.n_devices or n_devices
        member_zp = seg_pages * fc.n_segments
        n_data = nd - (1 if fc.parity else 0)
        cap = n_data * member_zp
        tenant_progs = MIXES[fc.mix](eng, cap)
        merged = interleave_tenants(
            [tag_tenant(p, t) for t, p in enumerate(tenant_progs)])
        if fidelity < 1.0:
            merged = merged[: max(1, math.ceil(fidelity * len(merged)))]
        merged_per_config.append(merged)
        lane_programs += stripe_program(
            merged, n_devices=nd, chunk_pages=fc.chunk_pages,
            parity=fc.parity, member_zone_pages=member_zp,
            parity_tenant=N_TENANTS)
        dyns += [eng.dyn(spec=specs_mix[d % len(specs_mix)],
                         zone_pages=member_zp,
                         wear_aware=fc.wear_aware,
                         alloc_policy=fc.alloc_policy)
                 for d in range(nd)]
        # inert pad lanes square up a mixed-member-count batch
        lane_programs += [np.zeros((0, 5), dtype=np.int32)] * (nd_max - nd)
        dyns += [eng.dyn()] * (nd_max - nd)
    q = max(1, pad_quantum)
    n_ops = -(-max((len(p) for p in lane_programs), default=0) // q) * q
    return (pad_programs(lane_programs, n_ops=n_ops), stack_dyn(dyns),
            merged_per_config)


class Evaluator:
    """The one batched scorer every search strategy dispatches through.

    Grid/random enumeration, and the evolutionary/successive-halving
    searcher in :mod:`repro.fleet.evolve`, all share this object: it
    owns candidate expansion (:func:`build_fleet_batch`), the batched
    execution + per-config rollups, the fixed scalar objective, and the
    budget ledger.  One :meth:`evaluate` call is one *dispatch*: one
    batched ``run_programs`` + one batched timing invocation, whatever
    the candidate count or fidelity.

    Budget ledger (cumulative, read by benchmarks/tests):

    * ``n_dispatches`` -- :meth:`evaluate` calls issued;
    * ``n_evals``      -- full-fidelity-equivalent config evaluations
      (a config at fidelity ``f`` costs ``f``), the unit the
      dispatches-to-target comparison in ``BENCH_fleet.json`` uses;
    * ``lane_ops``     -- scanned ``(lane, op)`` cells actually
      dispatched (lanes x padded program length), the raw compute
      proxy.

    ``pad_quantum`` rounds every dispatch's op axis up to a multiple,
    so repeated same-size candidate sets (evolve generations, halving
    rungs) hit the same compiled ``run_programs`` shape instead of
    recompiling per batch.

    Observability (``repro.obs``): ``profiler`` threads per-section
    counters (``evaluator.build`` / the ``fleet.*`` sections of
    :func:`runner.run_fleet`) through every dispatch, and
    ``recompiles`` watches the jit caches of the dispatch surface --
    :meth:`jit_cache` readings staying flat across repeated
    generations is the shape-stability property ``pad_quantum`` buys
    (asserted in ``tests/test_obs.py``, recorded per generation by
    ``repro.fleet.evolve`` when a profiler is attached, and archived
    by ``tools/bench.py``).
    """

    def __init__(self, eng: ZoneEngine, *, n_devices: int = 4,
                 weights: Tuple[float, float, float] = (1.0, 1.0, 1.0),
                 check_legal: bool = True, pad_quantum: int = 64,
                 profiler=None, sanitize: bool = False):
        from repro.obs.profile import RecompileCounter
        self.eng = eng
        self.n_devices = n_devices
        self.weights = tuple(weights)
        self.check_legal = check_legal
        # opt-in repro.check device-state audit after every dispatch
        # (host-side numpy on fetched values: no extra compilations)
        self.sanitize = sanitize
        self.pad_quantum = max(1, pad_quantum)
        self.profiler = profiler
        self.recompiles = RecompileCounter(
            run_programs=zengine.run_programs,
            simulate_fleet_ops=timing.simulate_fleet_ops)
        self.n_dispatches = 0
        self.n_evals = 0.0
        self.lane_ops = 0

    def jit_cache(self) -> Dict[str, int]:
        """Compile-cache entry counts of the dispatch surface (one
        entry per abstract input signature ever compiled)."""
        return self.recompiles.counts()

    def evaluate(self, configs: Sequence[FleetConfig], *,
                 fidelity: float = 1.0) -> List[Dict]:
        """Score ``configs`` in ONE batched dispatch; one metrics row
        per config (see :func:`repro.fleet.runner.config_report`), each
        stamped with ``fidelity``.  An empty candidate set returns
        ``[]`` without dispatching anything or touching the budget
        ledger (an empty dispatch used to count, skewing the halving
        decisions adaptive strategies read off ``n_dispatches``)."""
        if not configs:
            return []
        sec = (self.profiler.section if self.profiler is not None
               else (lambda _name: contextlib.nullcontext()))
        with sec("evaluator.build"):
            programs, dyn, _ = build_fleet_batch(
                self.eng, configs, n_devices=self.n_devices,
                fidelity=fidelity, pad_quantum=self.pad_quantum)
        res = runner.run_fleet(self.eng, programs, dyn=dyn,
                               n_tenants=N_TENANTS,
                               profiler=self.profiler)
        if self.check_legal:
            runner.assert_all_ok(res)
        if self.sanitize:
            from repro.check import assert_states
            assert_states(self.eng.cfg, res.states, dyn,
                          where="Evaluator dispatch states")
        self.n_dispatches += 1
        self.n_evals += fidelity * len(configs)
        self.lane_ops += runner.dispatch_cost(res)
        nd_max = _nd_max(configs, self.n_devices)
        rows = []
        for k, fc in enumerate(configs):
            nd = fc.n_devices or self.n_devices
            # pad lanes (all-NOP) of a narrower config are excluded:
            # they would dilute the per-config rollup with empty lanes
            lanes = np.arange(k * nd_max, k * nd_max + nd)
            specs_mix = fc.specs_mix()
            row: Dict = {
                "config": fc.describe(),
                "mix": fc.mix,
                "n_segments": fc.n_segments,
                "chunk_pages": fc.chunk_pages,
                "parity": float(fc.parity),
                "wear_aware": float(fc.wear_aware),
                "spec": "+".join(s.name for s in specs_mix),
                "n_devices": float(nd),
                "alloc_policy": fc.alloc_policy,
                "fidelity": float(fidelity),
            }
            row.update(runner.config_report(res, self.eng, lanes))
            rows.append(row)
        return rows

    def objective(self, row: Dict) -> float:
        """Fixed weighted sum of the raw objectives (lower = better).

        Unlike :func:`score_rows` (which min-max-normalizes *within* a
        batch), this scalar is comparable across dispatches and
        generations -- the quantity adaptive strategies minimize and
        the monotone best-so-far curve is measured on.  Comparable only
        between rows of equal ``fidelity``.
        """
        return float(sum(w * row[k]
                         for k, w in zip(OBJECTIVE_KEYS, self.weights)))

    def ledger(self) -> Dict[str, float]:
        """The budget counters as a plain dict (for artifacts)."""
        return {"n_dispatches": float(self.n_dispatches),
                "n_evals": float(self.n_evals),
                "lane_ops": float(self.lane_ops)}


def evaluate_configs(eng: ZoneEngine, configs: Sequence[FleetConfig], *,
                     n_devices: int = 4,
                     check_legal: bool = True) -> List[Dict]:
    """Score every config in ONE batched engine dispatch + ONE batched
    timing dispatch (a single-shot :class:`Evaluator`)."""
    return Evaluator(eng, n_devices=n_devices,
                     check_legal=check_legal).evaluate(configs)


def score_rows(rows: List[Dict],
               weights: Tuple[float, float, float] = (1.0, 1.0, 1.0)
               ) -> List[Dict]:
    """Weighted sum of min-max-normalized objectives (lower = better);
    (re)sets ``score`` in place and returns the rows sorted best-first
    (re-scoring with different weights replaces, never accumulates)."""
    for r in rows:
        r["score"] = 0.0
    for key, w in zip(OBJECTIVE_KEYS, weights):
        vals = np.asarray([r[key] for r in rows], dtype=np.float64)
        span = vals.max() - vals.min()
        norm = (vals - vals.min()) / span if span > 0 else vals * 0.0
        for r, v in zip(rows, norm):
            r["score"] += float(w * v)
    return sorted(rows, key=lambda r: r["score"])


def pareto_front(rows: List[Dict],
                 keys: Sequence[str] = OBJECTIVE_KEYS) -> List[Dict]:
    """Non-dominated rows (no other row is <= on every key and < on
    one); flags every row with ``pareto`` in place and returns the
    front."""
    vals = np.asarray([[r[k] for k in keys] for r in rows],
                      dtype=np.float64)
    front = []
    for i, r in enumerate(rows):
        dominated = np.any(
            np.all(vals <= vals[i], axis=1)
            & np.any(vals < vals[i], axis=1))
        r["pareto"] = float(not dominated)
        if not dominated:
            front.append(r)
    return front


# --------------------------------------------------------------------- #
# per-op legacy comparator (the speedup baseline tools/bench.py tracks)
# --------------------------------------------------------------------- #
def run_configs_legacy(flash: FlashGeometry, spec: ElementSpec,
                       configs: Sequence[FleetConfig],
                       merged_programs: Sequence[np.ndarray], *,
                       parallelism: int, n_devices: int = 4,
                       max_active: int = 14,
                       fleet_timing: bool = False) -> List[Dict]:
    """Evaluate each config the pre-fleet way: replay its merged logical
    program through a real :class:`repro.array.ZNSArray` over per-op
    ``LegacyZNSDevice`` members.  Each config gets devices built with
    its *actual* (non-padded) zone geometry **and element spec**
    (``fc.spec``; the ``spec`` argument is only the engine's primary
    and is superseded per config), so this doubles as a semantic
    cross-check: array DLWA must match the batched engine path exactly
    (tested, and asserted by ``tools/bench.py``) -- including
    mixed-spec batches through a union config.  ``alloc_policy =
    "silent"`` configs replay here too: which blocks a zone claims
    never changes which pages FINISH pads (pads depend only on the
    write pointer and the spec's stripe map), so silent lanes are
    DLWA-identical to the legacy device at the same spec (wear totals
    are where the policies diverge, and those are not replayed).

    With ``fleet_timing`` the replay also collects the page-granular IO
    traces and runs :func:`repro.core.timing.run_fleet_trace` per
    config -- the full evaluation pipeline ``benchmarks/raid_zns.py``
    established in PR 1, and the baseline the ``BENCH_fleet.json``
    speedup is measured against."""
    from repro.array import ArrayGeometry, ZNSArray
    from repro.core import timing
    from repro.core.device_legacy import LegacyZNSDevice

    out = []
    for fc, merged in zip(configs, merged_programs):
        geom = ZoneGeometry(parallelism=parallelism,
                            n_segments=fc.n_segments)
        nd = fc.n_devices or n_devices
        specs_mix = fc.specs_mix()
        devices = [LegacyZNSDevice(flash, geom,
                                   specs_mix[d % len(specs_mix)],
                                   max_active=max_active,
                                   wear_aware=fc.wear_aware)
                   for d in range(nd)]
        arr = ZNSArray(devices, ArrayGeometry(
            nd, fc.chunk_pages, fc.parity))
        tagged: List = []
        for row in merged:
            op, zone, n_pages = int(row[0]), int(row[1]), int(row[2])
            if op == zengine.OP_WRITE:
                tr = arr.zone_write(zone, n_pages,
                                    host=bool(row[3] & zengine.F_HOST),
                                    trace=fleet_timing)
                tagged += tr or []
            elif op == zengine.OP_FINISH:
                tagged += arr.zone_finish(zone, trace=fleet_timing) or []
            elif op == zengine.OP_RESET:
                arr.zone_reset(zone)
        rep = arr.report()
        rep["config"] = fc.describe()
        # pooled over all members' blocks, the same statistic as
        # runner.config_report (block wear repeats element wear
        # blocks_per_element times, which leaves the CV unchanged)
        w = np.concatenate([d.block_wear() for d in arr.devices])
        rep["wear_cv"] = float(w.std() / w.mean()) if w.mean() > 0 else 0.0
        if fleet_timing:
            fleet = timing.run_fleet_trace(
                arr.flash, timing.group_tagged(tagged, nd))
            rep["makespan_s"] = fleet["fleet_makespan_s"]
            rep["fleet_pages"] = float(fleet["n"])
        out.append(rep)
    return out


def fleet_vs_legacy_speedup(*, n_devices: int = 4,
                            configs: Optional[Sequence[FleetConfig]] = None,
                            repeats: int = 3,
                            flash: Optional[FlashGeometry] = None,
                            zone_geom: Optional[ZoneGeometry] = None,
                            max_active: int = 14,
                            specs: Optional[Sequence[ElementSpec]] = None,
                            legacy_configs: Optional[int] = None
                            ) -> Dict[str, float]:
    """Time the batched fleet sweep against the per-op legacy pipeline.

    Both paths evaluate the *same* configs on the *same* logical
    traffic (the merged tenant programs), end to end:

    * **engine** -- :func:`evaluate_configs`: ONE ``run_programs``
      dispatch over all (config x device) lanes + ONE batched
      op-granular timing dispatch;
    * **legacy** -- :func:`run_configs_legacy` with ``fleet_timing``:
      per config, a real ``ZNSArray`` over stateful-Python members,
      page-granular trace collection, and a ``run_fleet_trace`` device
      simulation -- exactly the evaluation pipeline
      ``benchmarks/raid_zns.py`` established in PR 1.

    Steady state (compile excluded via one warm pass); array-level DLWA
    is asserted identical between the paths before anything is timed.
    Also reports the replay-only legacy time (``legacy_replay_s``, no
    trace/timing) so the artifact separates state-machine cost from the
    page-granular timing cost the legacy path is stuck with.  With
    ``specs`` (a spec set) the engine is the padded *union* config and
    the configs may mix element specs per lane -- the legacy path then
    builds each config's members with its actual spec, making the DLWA
    assert an exactness oracle for the mixed-spec dispatch.  Returns
    the numbers ``tools/bench.py`` archives in ``BENCH_fleet.json``.

    ``legacy_configs`` (< the config count) times the legacy legs on
    only that config prefix, once, and linearly scales the measurement
    -- the per-op pipeline is per-config sequential, so its cost is
    linear in the config count, and timing all K at full repeats just
    burns bench minutes.  The scaling is recorded honestly:
    ``legacy_timed_configs``, the measured times
    (``legacy_measured_s`` / ``legacy_replay_measured_s``) and
    ``legacy_scale`` all land in the returned dict (and the artifact).
    The DLWA exactness assert always covers EVERY config.
    """
    import time

    from repro.core.geometry import zn540

    if (flash is None) != (zone_geom is None):
        raise ValueError("flash and zone_geom must be given together")
    if flash is None:
        flash, zone_geom = zn540()
    specs = tuple(specs) if specs else (SUPERBLOCK,)
    eng = ZoneEngine(flash, zone_geom,
                     specs if len(specs) > 1 else specs[0],
                     max_active=max_active)
    if configs is None:
        configs = grid_space(specs=specs)
    programs, dyn, merged = build_fleet_batch(eng, configs,
                                              n_devices=n_devices)
    n_ops = int((programs[:, :, 0] != zengine.OP_NOP).sum())

    def engine_pass():
        return evaluate_configs(eng, configs, n_devices=n_devices)

    def legacy_pass(fleet_timing=True, n=None):
        return run_configs_legacy(
            flash, specs[0], configs[:n], merged[:n],
            parallelism=zone_geom.parallelism, n_devices=n_devices,
            max_active=max_active, fleet_timing=fleet_timing)

    rows = engine_pass()      # compile/warm both paths
    legacy = legacy_pass()    # EVERY config: the exactness oracle
    for r, l in zip(rows, legacy):
        assert abs(r["dlwa"] - l["dlwa"]) < 1e-9, (
            f"engine/legacy DLWA mismatch on {r['config']}: "
            f"{r['dlwa']} vs {l['dlwa']}")

    def timed(fn, reps=repeats):
        t0 = time.perf_counter()
        for _ in range(reps):
            fn()
        return (time.perf_counter() - t0) / reps

    n_leg = min(legacy_configs or len(configs), len(configs))
    scale = len(configs) / n_leg
    leg_reps = repeats if n_leg == len(configs) else 1
    t_eng = timed(engine_pass)
    t_leg_measured = timed(lambda: legacy_pass(n=n_leg), leg_reps)
    t_leg_replay_measured = timed(
        lambda: legacy_pass(fleet_timing=False, n=n_leg), leg_reps)
    t_leg = t_leg_measured * scale
    t_leg_replay = t_leg_replay_measured * scale
    return {
        "n_configs": float(len(configs)),
        "n_devices": float(n_devices),
        "fleet_ops": float(n_ops),
        "legacy_s": t_leg,
        "legacy_replay_s": t_leg_replay,
        "legacy_measured_s": t_leg_measured,
        "legacy_replay_measured_s": t_leg_replay_measured,
        "legacy_timed_configs": float(n_leg),
        "legacy_scale": scale,
        "engine_s": t_eng,
        "legacy_configs_s": len(configs) / t_leg,
        "engine_configs_s": len(configs) / t_eng,
        "speedup": t_leg / t_eng,
        "replay_speedup": t_leg_replay / t_eng,
    }
