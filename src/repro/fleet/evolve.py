"""Evolutionary + successive-halving search over the batched evaluator.

Grid/random enumeration (:mod:`repro.fleet.search`) *samples* the
allocator design space; this module *optimizes* over it, in the spirit
of SilentZNS's on-the-fly allocation search: a generation-based
evolutionary loop proposes :class:`~repro.fleet.search.FleetConfig`
candidates by mutation/crossover on the :class:`SearchSpace` gene
vector (tenant mix, effective segments, stripe chunk, parity,
wear-awareness), and every generation is scored through the shared
:class:`~repro.fleet.search.Evaluator` -- ONE batched ``run_programs``
dispatch per rung, exploiting the ~26x batched-vs-legacy pipeline
``BENCH_fleet.json`` tracks.

Cost control is a successive-halving (bandit) schedule inside each
generation: the population is first evaluated on *truncated* op
programs (``rung_fidelities[:-1]``, cheap low-fidelity rungs built by
cutting each merged tenant program to a prefix before striping), and
only the top ``1/eta`` survivors of each rung are promoted until the
final full-fidelity rung.  Only full-fidelity rows enter the
best-so-far curve and the persistent Pareto archive (merged across
generations via :func:`~repro.fleet.search.pareto_front`), because
truncated metrics are comparable only within a rung.

Everything is deterministically seeded: candidate proposal threads one
``random.Random(seed)``, the evaluator is pure, and no wall-clock or
global RNG state is read -- same seed, same generation history, same
archive (tested in ``tests/test_evolve.py``).

Budget accounting rides the evaluator's ledger: ``n_dispatches``
(batched evaluator invocations), ``n_evals`` (full-fidelity-equivalent
config evaluations -- a config at fidelity *f* costs *f*), and
``lane_ops`` (scanned lane x op cells).  :func:`evolve_vs_random` is
the comparison ``tools/bench.py`` archives: random search dispatched in
population-sized batches vs evolve stopping at the random-best target.
"""

from __future__ import annotations

import dataclasses
import math
import random as pyrandom
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.engine import ZoneEngine
from repro.fleet.search import (Evaluator, FleetConfig, SearchSpace,
                                pareto_front)


@dataclasses.dataclass(frozen=True)
class EvolveParams:
    """Knobs of the evolutionary + successive-halving loop.

    ``rung_fidelities`` must be ascending and end at 1.0 (the final,
    archive-feeding rung); each rung keeps the top ``ceil(n / eta)``
    candidates of the previous one.  ``elites`` is how many of the
    best-so-far configs are guaranteed a parent slot each generation
    (selection for the remaining slots is ``tournament``-way over all
    fully evaluated rows).
    """

    population: int = 8
    generations: int = 4
    elites: int = 2
    tournament: int = 2
    p_crossover: float = 0.6
    p_mutate: float = 0.35
    rung_fidelities: Tuple[float, ...] = (0.25, 1.0)
    eta: int = 2

    def __post_init__(self) -> None:
        if self.population < 1 or self.generations < 1:
            raise ValueError("population and generations must be >= 1")
        f = self.rung_fidelities
        if (not f or f[-1] != 1.0 or f[0] <= 0
                or any(b <= a for a, b in zip(f, f[1:]))):
            raise ValueError("rung_fidelities must strictly ascend and "
                             f"end at 1.0, got {f}")
        if self.eta < 2:
            raise ValueError("eta must be >= 2")


@dataclasses.dataclass
class EvolveResult:
    """Everything one :func:`evolve` run produced.

    ``history`` has one JSON-ready dict per generation::

        {"generation": g,
         "rungs": [{"fidelity": f, "candidates": [...],
                    "ranked": [...], "survivors": [...]}, ...],
         "best_of_gen": float, "best_so_far": float,
         "n_dispatches": float, "n_evals": float, "lane_ops": float}

    ``archive`` is the persistent Pareto set over every full-fidelity
    row of every generation; ``best`` the lowest-objective row found.
    ``reached_target`` is False when a ``target`` was given and the run
    exhausted its generations without matching it.
    """

    history: List[Dict]
    best: Dict
    archive: List[Dict]
    rows: Dict[str, Dict]          # config name -> full-fidelity row
    ledger: Dict[str, float]
    seed: int
    reached_target: bool


def mutate(genes: Sequence[int], space: SearchSpace,
           rng: pyrandom.Random, p: float) -> Tuple[int, ...]:
    """Per-gene: with probability ``p`` move to a *different* uniformly
    chosen index on that axis (single-value axes stay put)."""
    out = []
    for g, axis in zip(genes, space.axes):
        if len(axis) > 1 and rng.random() < p:
            g = (g + rng.randrange(1, len(axis))) % len(axis)
        out.append(g)
    return tuple(out)


def crossover(a: Sequence[int], b: Sequence[int],
              rng: pyrandom.Random) -> Tuple[int, ...]:
    """Uniform crossover: each gene from either parent, fair coin."""
    return tuple(x if rng.random() < 0.5 else y for x, y in zip(a, b))


def _halving_sizes(n: int, n_rungs: int, eta: int) -> List[int]:
    """Candidate count entering each rung: ``n``, then ceil(prev/eta)."""
    sizes = [n]
    for _ in range(n_rungs - 1):
        sizes.append(max(1, math.ceil(sizes[-1] / eta)))
    return sizes


def evolve(eng: ZoneEngine, *, space: Optional[SearchSpace] = None,
           params: Optional[EvolveParams] = None, seed: int = 0,
           n_devices: int = 4,
           weights: Tuple[float, float, float] = (1.0, 1.0, 1.0),
           target: Optional[float] = None,
           evaluator: Optional[Evaluator] = None) -> EvolveResult:
    """Run the seeded evolutionary + successive-halving search.

    Each generation proposes ``params.population`` *previously
    unproposed* configs (generation 0 uniformly at random; later ones
    by elite/tournament parent selection, uniform crossover, and
    per-gene mutation, falling back to fresh random samples when the
    operators keep landing on already-proposed configs), pushes them
    down the halving rungs, and merges the final rung's full-fidelity
    rows into the best-so-far curve and the Pareto archive.  A config
    eliminated at a low-fidelity rung is *not* retried later -- the
    halving gamble is that its truncated ranking was telling -- so no
    candidate is ever paid for twice.  Stops early when ``target`` (an
    :meth:`Evaluator.objective` value) is reached or the space is
    exhausted.
    """
    space = space or SearchSpace()
    params = params or EvolveParams()
    ev = evaluator or Evaluator(eng, n_devices=n_devices, weights=weights)
    rng = pyrandom.Random(seed)
    seen: Dict[str, Dict] = {}      # config name -> full-fidelity row
    proposed: set = set()           # every candidate ever dispatched
    genes_of: Dict[str, Tuple[int, ...]] = {}
    archive: List[Dict] = []
    best_row: Optional[Dict] = None
    history: List[Dict] = []
    reached = target is None

    def propose(generation: int) -> List[FleetConfig]:
        out: List[FleetConfig] = []
        parents = sorted(seen.values(), key=ev.objective)

        def admit(fc: FleetConfig) -> bool:
            name = fc.describe()
            if name in proposed:
                return False
            proposed.add(name)
            genes_of[name] = space.encode(fc)
            out.append(fc)
            return True

        def pick_parent(k: int) -> Tuple[int, ...]:
            if k < params.elites and k < len(parents):
                row = parents[k]              # elites seed the front slots
            else:
                row = min(rng.sample(parents,
                                     min(params.tournament, len(parents))),
                          key=ev.objective)
            return genes_of[row["config"]]

        tries = 0
        max_tries = 64 * params.population
        while len(out) < params.population and tries < max_tries:
            tries += 1
            if generation == 0 or not parents:
                admit(space.decode(space.sample_genes(rng)))
                continue
            g1 = pick_parent(len(out))
            if rng.random() < params.p_crossover and len(parents) > 1:
                # slot >= elites always tournament-selects the mate
                child = crossover(g1, pick_parent(params.elites), rng)
            else:
                child = g1
            child = mutate(child, space, rng, params.p_mutate)
            if not admit(space.decode(child)):
                # operators drifted onto a seen config: random restart
                admit(space.decode(space.sample_genes(rng)))
        return out

    for gen in range(params.generations):
        if len(proposed) >= len(space):
            break                              # space exhausted
        cands = propose(gen)
        if not cands:
            break
        by_name = {fc.describe(): fc for fc in cands}
        rungs: List[Dict] = []
        current = list(cands)
        rows: List[Dict] = []
        for i, f in enumerate(params.rung_fidelities):
            rows = ev.evaluate(current, fidelity=f)
            ranked = sorted(rows, key=ev.objective)
            if i == len(params.rung_fidelities) - 1:
                survivors = [r["config"] for r in ranked]
            else:
                keep = max(1, math.ceil(len(current) / params.eta))
                survivors = [r["config"] for r in ranked[:keep]]
            rungs.append({
                "fidelity": float(f),
                "candidates": [fc.describe() for fc in current],
                "ranked": [r["config"] for r in ranked],
                "survivors": list(survivors),
            })
            current = [by_name[name] for name in survivors]
        for r in rows:                         # final rung: full fidelity
            seen[r["config"]] = r
        archive = pareto_front(archive + rows)
        gen_best = min(rows, key=ev.objective)
        if best_row is None or ev.objective(gen_best) < ev.objective(best_row):
            best_row = gen_best
        row = {
            "generation": gen,
            "rungs": rungs,
            "best_of_gen": ev.objective(gen_best),
            "best_so_far": ev.objective(best_row),
            **ev.ledger(),
        }
        if ev.profiler is not None:
            # opt-in observability (repro.obs): compile-cache readings
            # per generation -- flat after warmup proves the evaluator's
            # pad_quantum kept the dispatch shapes stable.  Gated on the
            # profiler because cache sizes are process-global (recording
            # them unconditionally would break same-process seeded
            # determinism of the history).
            row["jit_cache"] = ev.jit_cache()
            row["profile"] = ev.profiler.snapshot()
        history.append(row)
        if target is not None and ev.objective(best_row) <= target:
            reached = True
            break

    assert best_row is not None, "evolve ran zero generations"
    return EvolveResult(history=history, best=best_row, archive=archive,
                        rows=seen, ledger=ev.ledger(), seed=seed,
                        reached_target=reached)


def evolve_vs_random(eng: ZoneEngine, *,
                     space: Optional[SearchSpace] = None,
                     params: Optional[EvolveParams] = None,
                     random_n: int = 32, seed: int = 0,
                     n_devices: int = 4,
                     weights: Tuple[float, float, float] = (1.0, 1.0, 1.0)
                     ) -> Dict:
    """The dispatches-to-target comparison ``BENCH_fleet.json`` records.

    Baseline: ``random_n`` configs sampled without replacement,
    evaluated at full fidelity in population-sized batches (an adaptive
    proposer can only act on completed batches, so batch sizes -- and
    therefore dispatch counts -- are protocol-matched).  Its best
    objective becomes evolve's ``target``; evolve runs until it matches
    it (or exhausts ``generations``).  Returns both ledgers plus the
    savings ratios; ``evolve.reached_target`` says whether the target
    was met -- the seeded acceptance test asserts it is, with
    ``n_evals`` at most half the random baseline's.
    """
    space = space or SearchSpace()
    params = params or EvolveParams()
    grid = space.grid()
    rng = np.random.default_rng(seed)
    idx = rng.choice(len(grid), size=min(random_n, len(grid)),
                     replace=False)
    configs = [grid[i] for i in idx]

    ev_r = Evaluator(eng, n_devices=n_devices, weights=weights)
    random_rows: List[Dict] = []
    for i in range(0, len(configs), params.population):
        random_rows += ev_r.evaluate(configs[i:i + params.population])
    random_best = min(random_rows, key=ev_r.objective)
    target = ev_r.objective(random_best)

    res = evolve(eng, space=space, params=params, seed=seed,
                 n_devices=n_devices, weights=weights, target=target)
    ev_e = res.ledger
    out = {
        "random": {"n_configs": float(len(configs)),
                   "best_objective": target,
                   "best_config": random_best["config"],
                   **{k: float(v) for k, v in ev_r.ledger().items()}},
        "evolve": {"best_objective": res.history[-1]["best_so_far"],
                   "best_config": res.best["config"],
                   "generations": float(len(res.history)),
                   "reached_target": bool(res.reached_target),
                   "archive_size": float(len(res.archive)),
                   **{k: float(v) for k, v in ev_e.items()}},
    }
    for k in ("n_dispatches", "n_evals", "lane_ops"):
        out[f"{k}_savings"] = (out["random"][k] / out["evolve"][k]
                               if out["evolve"][k] else float("inf"))
    return out
