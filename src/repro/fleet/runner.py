"""Batched fleet execution: T tenants x N devices x K configs, one dispatch.

A fleet *lane* is one (config, member-device) pair: a width-5 op program
(see :mod:`repro.fleet.tenants`) plus a per-lane
:class:`repro.core.engine.DynConfig` selecting the member's effective
zone geometry / allocator on the shared padded static
:class:`~repro.core.engine.EngineConfig`.  :func:`run_fleet` stacks all
lanes and executes them through ONE ``run_programs`` dispatch (a
``lax.map`` of scan-compiled programs), then scores latency with ONE
:func:`repro.core.timing.simulate_fleet_ops` dispatch -- no per-config
or per-device Python loops on the hot path.

Metric units: page counters count flash pages, ``erase_delta`` counts
erase-block erasures, times are seconds.
"""

from __future__ import annotations

import contextlib
import dataclasses
from typing import Dict, List, Optional

import jax
import numpy as np

from repro.core import engine as zengine
from repro.core import timing
from repro.core.elements import union_grid_mask
from repro.core.engine import DeviceState, DynConfig, ZoneEngine
from repro.fleet.tenants import TENANT_COL


@dataclasses.dataclass
class FleetResult:
    """Per-lane outputs of one batched fleet dispatch (all numpy).

    Lane axis ``L`` = flattened (config, device); op axis is the padded
    program length.  ``tenants`` holds the width-5 tenant column;
    parity appends carry ``parity_tenant``; NOP padding moves 0 pages
    and is ignored by every rollup.
    """

    programs: np.ndarray     # (L, n_ops, 5) i32
    states: DeviceState      # stacked pytree, leading axis L
    ok: np.ndarray           # (L, n_ops) bool  per-op legality
    host_delta: np.ndarray   # (L, n_ops) host pages moved by each op
    dummy_delta: np.ndarray  # (L, n_ops) dummy (FINISH-pad) pages
    erase_delta: np.ndarray  # (L, n_ops) block erasures
    pages: np.ndarray        # (L, n_ops) pages the op physically moved
                             #   (writes + FINISH padding + READ xfers)
    completions: np.ndarray  # (L, n_ops) op completion time (s)
    latencies: np.ndarray    # (L, n_ops) closed-loop op latency (s)
    makespans: np.ndarray    # (L,) lane makespan (s)
    n_tenants: int           # real tenants (parity tag excluded)
    parity_tenant: int
    elem_mask: Optional[np.ndarray] = None  # (L, n_elements) real elements
    #: per-lane telemetry stack (repro.obs TelemetryState with (L, ...)
    #: leaves) when the dispatch ran with obs=ObsConfig(...), else None
    telemetry: Optional[object] = None
    #: the dispatch's static config + per-lane DynConfig (when known):
    #: what lets assert_all_ok replay a failing lane through the
    #: repro.check verifier and name the predicted error class
    cfg: Optional[zengine.EngineConfig] = None
    dyn: Optional[DynConfig] = None

    @property
    def tenants(self) -> np.ndarray:
        return self.programs[:, :, TENANT_COL]

    def lane_wear(self, eng: ZoneEngine) -> np.ndarray:
        """(L, n_elements) element wear (erase counts) per lane, over
        the full padded static element axis (see ``elem_mask`` /
        :meth:`pooled_wear` for the per-lane real subset)."""
        n = eng.cfg.n_elements
        return np.asarray(self.states.elem_wear[:, :n], dtype=np.int64)

    def pooled_wear(self, eng: ZoneEngine, lanes: np.ndarray
                    ) -> np.ndarray:
        """1-D element wear pooled over ``lanes``, restricted to each
        lane's *real* elements.  A union-config lane only populates its
        member spec's cells of the padded element grid; ``elem_mask``
        (derived from the dispatch's per-lane ``DynConfig``) excludes
        the never-allocated padding so wear statistics match a device
        built with the member spec outright."""
        w = self.lane_wear(eng)[lanes]
        if self.elem_mask is None:
            return w.reshape(-1)
        return w[self.elem_mask[lanes]]

    def tenant_pages(self, lanes: np.ndarray) -> Dict[int, int]:
        """Host pages per tenant summed over ``lanes`` (parity under
        ``parity_tenant``)."""
        t = self.tenants[lanes].reshape(-1)
        h = self.host_delta[lanes].reshape(-1)
        return {int(k): int(h[t == k].sum())
                for k in range(self.n_tenants)} | {
                    self.parity_tenant:
                    int(h[t == self.parity_tenant].sum())}

    def tenant_p99_latency(self, lanes: np.ndarray) -> Dict[int, float]:
        """p99 closed-loop op latency per real tenant over ``lanes``
        (0.0 for a tenant with no executed ops there)."""
        t = self.tenants[lanes].reshape(-1)
        lat = self.latencies[lanes].reshape(-1)
        act = self.pages[lanes].reshape(-1) > 0
        out = {}
        for k in range(self.n_tenants):
            sel = act & (t == k)
            out[k] = float(np.percentile(lat[sel], 99)) if sel.any() else 0.0
        return out

    def tenant_class_report(self, lanes: Optional[np.ndarray] = None,
                            names: Optional[List[str]] = None
                            ) -> Dict[str, Dict[str, float]]:
        """Per-tenant-class latency predictability over ``lanes`` (all
        lanes by default).

        When the tenant column carries *traffic classes* (the trace
        compiler's class-tagged dispatches: wal/flush/compact,
        ckpt/log, admit/hit), this is the paper-style per-stream
        rollup: op and page counts, closed-loop latency p50/p99/max,
        and ``p99_over_p50`` -- the predictability ratio a
        well-isolated class keeps near 1.  ``names`` labels classes in
        tag order; unnamed tags keep their number."""
        lanes = (np.arange(len(self.programs)) if lanes is None
                 else np.asarray(lanes))
        t = self.tenants[lanes].reshape(-1)
        lat = self.latencies[lanes].reshape(-1)
        pages = self.pages[lanes].reshape(-1)
        host = self.host_delta[lanes].reshape(-1)
        act = (self.programs[lanes][:, :, 0].reshape(-1) != zengine.OP_NOP
               ) & self.ok[lanes].reshape(-1)
        out: Dict[str, Dict[str, float]] = {}
        for k in range(self.n_tenants):
            name = (names[k] if names is not None and k < len(names)
                    else str(k))
            sel = act & (t == k)
            if not sel.any():
                out[name] = {"ops": 0.0, "pages": 0.0, "host_pages": 0.0,
                             "mean_latency_s": 0.0, "p50_latency_s": 0.0,
                             "p99_latency_s": 0.0, "max_latency_s": 0.0,
                             "p99_over_p50": 0.0}
                continue
            l_k = lat[sel]
            p50 = float(np.percentile(l_k, 50))
            p99 = float(np.percentile(l_k, 99))
            out[name] = {
                "ops": float(sel.sum()),
                "pages": float(pages[sel].sum()),
                "host_pages": float(host[sel].sum()),
                "mean_latency_s": float(l_k.mean()),
                "p50_latency_s": p50,
                "p99_latency_s": p99,
                "max_latency_s": float(l_k.max()),
                "p99_over_p50": p99 / p50 if p50 > 0 else 0.0,
            }
        return out


def run_fleet(eng: ZoneEngine, programs: np.ndarray, *,
              dyn: Optional[DynConfig] = None, n_tenants: int = 1,
              parity_tenant: Optional[int] = None, obs=None,
              profiler=None) -> FleetResult:
    """Execute ``(L, n_ops, 5)`` fleet lanes in one batched dispatch.

    ``dyn`` (optional) must hold ``(L,)`` leaves (``engine.stack_dyn``)
    -- the heterogeneous-geometry / allocator axis.  Timing is the
    op-granular :func:`~repro.core.timing.simulate_fleet_ops` model:
    each executed op occupies its zone's LUN columns for
    ``ceil(pages / P) * (t_prog + t_xfer)`` seconds; deferred-erase
    latency is not modeled (it is tracked as ``erase_delta`` instead).

    ``obs`` (a ``repro.obs.ObsConfig``) threads the in-scan telemetry
    recorder through the dispatch; the result then carries per-lane
    histogram stacks in ``telemetry``.  ``profiler`` (a
    ``repro.obs.Profiler``) splits the call into ``fleet.engine`` /
    ``fleet.timing`` / ``fleet.decode`` sections (outputs are blocked
    on inside each section so the wall times are honest).
    """
    programs = np.asarray(programs, dtype=np.int32)
    if programs.ndim != 3 or programs.shape[-1] <= TENANT_COL:
        raise ValueError(f"want (L, n_ops, 5) programs, got "
                         f"{programs.shape}")
    if parity_tenant is None:
        parity_tenant = n_tenants
    sec = (profiler.section if profiler is not None
           else (lambda _name: contextlib.nullcontext()))
    with sec("fleet.engine"):
        out = eng.run_batch(eng.init_state(), programs, dyn, obs=obs)
        states, trace = out[0], out[1]
        telemetry = out[2] if obs is not None else None
        if profiler is not None:
            jax.block_until_ready(states)

    elem_mask = None
    if dyn is not None:
        # each lane's real elements on the (possibly union-padded)
        # static grid -- union lanes must exclude the padding cells
        # from the wear rollups
        elem_mask = union_grid_mask(eng.cfg.n_elements, eng.cfg.per_group,
                                    np.asarray(dyn.n_elements),
                                    np.asarray(dyn.per_group))

    with sec("fleet.timing"):
        wp_b = np.asarray(trace.wp_before)
        wp_a = np.asarray(trace.wp_after)
        dummy = np.asarray(trace.dummy_delta)
        op = programs[:, :, 0]
        # pages the op physically moved: write advance, FINISH padding
        # (RESET rewinds wp without moving pages -> clip), READ
        # transfers (the n_pages column; reads never advance wp)
        pages = (np.maximum(wp_a - wp_b, 0)
                 + np.where(op == zengine.OP_FINISH, dummy, 0)
                 + np.where(op == zengine.OP_READ, programs[:, :, 2], 0))
        # per-op page service time: reads pay t_read, everything
        # page-moving else programs flash
        t_page = np.where(
            op == zengine.OP_READ,
            np.float32(eng.flash.t_read + eng.flash.t_xfer),
            np.float32(eng.flash.t_prog + eng.flash.t_xfer))
        completions, latencies, makespans = timing.simulate_fleet_ops(
            np.asarray(trace.cols), pages.astype(np.int32),
            programs[:, :, TENANT_COL], t_page,
            eng.flash.n_luns, parity_tenant + 1)
        if profiler is not None:
            jax.block_until_ready(completions)
    with sec("fleet.decode"):
        return _decode_fleet(programs, states, trace, dummy, pages,
                             completions, latencies, makespans,
                             n_tenants, parity_tenant, elem_mask,
                             telemetry, eng.cfg, dyn)


def _decode_fleet(programs, states, trace, dummy, pages, completions,
                  latencies, makespans, n_tenants, parity_tenant,
                  elem_mask, telemetry, cfg=None, dyn=None) -> FleetResult:
    return FleetResult(
        programs=programs,
        states=states,
        ok=np.asarray(trace.ok),
        host_delta=np.asarray(trace.host_delta),
        dummy_delta=dummy,
        erase_delta=np.asarray(trace.erase_delta),
        pages=pages,
        completions=np.asarray(completions),
        latencies=np.asarray(latencies),
        makespans=np.asarray(makespans),
        n_tenants=n_tenants,
        parity_tenant=parity_tenant,
        elem_mask=elem_mask,
        telemetry=telemetry,
        cfg=cfg,
        dyn=dyn,
    )


def config_report(res: FleetResult, eng: ZoneEngine,
                  lanes: np.ndarray) -> Dict[str, float]:
    """Roll one config's member lanes up to the paper's fleet metrics.

    * ``dlwa``: array-level -- every page the fleet programs (host data
      + parity + FINISH padding) per host data page;
    * ``wear_cv`` / ``max_wear``: spread of element wear pooled over
      all members (the wear-leveling objective, paper Fig. 7c);
    * ``p99_latency_s``: worst real tenant's p99 closed-loop latency;
    * ``makespan_s``: slowest member (the fleet completes a stripe only
      when every chunk is durable).
    """
    lanes = np.asarray(lanes)
    t = res.tenants[lanes]
    host = int(res.host_delta[lanes][t != res.parity_tenant].sum())
    par = int(res.host_delta[lanes][t == res.parity_tenant].sum())
    dummy = int(res.dummy_delta[lanes].sum())
    erases = int(res.erase_delta[lanes].sum())
    wear = res.pooled_wear(eng, lanes)
    mean_w = float(wear.mean()) if wear.size else 0.0
    p99 = res.tenant_p99_latency(lanes)
    return {
        "host_pages": float(host),
        "parity_pages": float(par),
        "dummy_pages": float(dummy),
        "dlwa": (host + par + dummy) / host if host else 1.0,
        "block_erases": float(erases),
        "max_wear": float(wear.max()) if wear.size else 0.0,
        "wear_cv": float(wear.std() / mean_w) if mean_w > 0 else 0.0,
        "p99_latency_s": max(p99.values()) if p99 else 0.0,
        "makespan_s": float(res.makespans[lanes].max()),
        "ops_ok": float(res.ok[lanes].sum()),
    }


def dispatch_cost(res: FleetResult) -> int:
    """Scanned ``(lane, op)`` cells of one dispatch -- lanes times the
    padded program length, NOP padding included.  This is the raw
    compute a batched evaluator invocation paid (every lane scans the
    full padded op axis), the unit the search-budget ledger in
    :class:`repro.fleet.search.Evaluator` accumulates."""
    return int(res.programs.shape[0] * res.programs.shape[1])


def real_op_count(res: FleetResult) -> int:
    """Non-NOP ops across all lanes (the work that moved state)."""
    return int((res.programs[:, :, 0] != zengine.OP_NOP).sum())


def assert_all_ok(res: FleetResult, lanes: Optional[np.ndarray] = None
                  ) -> None:
    """Raise if any *real* op (non-NOP) was illegal -- a mis-built
    fleet program (overflow, active-zone limit) should fail loudly in
    tests and benchmarks, not skew metrics silently.

    When the result carries its dispatch config (``res.cfg`` /
    ``res.dyn``, populated by :func:`run_fleet`), the first failing op
    is replayed through the :mod:`repro.check` verifier and the
    exception names the op kind, zone, and predicted error class with
    the shim's message -- not just the raw row."""
    sel = slice(None) if lanes is None else lanes
    real = res.programs[sel, :, 0] != zengine.OP_NOP
    bad = real & ~res.ok[sel]
    if not bad.any():
        return
    lane, idx = np.argwhere(bad)[0]
    row = res.programs[sel][lane, idx]
    msg = (f"illegal op at lane {lane} index {idx}: {row.tolist()}")
    if res.cfg is not None:
        # absolute lane on the dispatch axis (``lanes`` may be a subset)
        abs_lane = int(np.arange(len(res.programs))[sel][lane])
        from repro.check import explain_op
        stacked = (res.dyn is not None
                   and np.asarray(res.dyn.zone_pages).ndim > 0)
        v = explain_op(res.cfg, res.programs[abs_lane], int(idx),
                       res.dyn, lane=abs_lane if stacked else None)
        if not v.ok:
            msg = (f"illegal {v.op_name} at lane {lane} index {idx} "
                   f"(zone {v.zone}): predicted error class "
                   f"'{v.error}' -- {v.message}; row {row.tolist()}")
    raise AssertionError(msg)
