"""Tenant-tagged op programs: encode *who* issued each zone command.

The engine's op rows are ``[opcode, zone, n_pages, flags]`` (see
:mod:`repro.core.engine`); this module appends an engine-opaque fifth
column -- the **tenant tag** -- and provides the transforms that turn
per-tenant workload programs into one executable program per device:

* :func:`tag_tenant`          -- widen a width-4 program to width 5 and
                                 stamp a tenant id on every row;
* :func:`interleave_tenants`  -- merge per-tenant programs round-robin
                                 by per-tenant position, the same
                                 concurrent-submission-queue model the
                                 timing layer uses for IO streams;
* :func:`stripe_program`      -- rewrite a *logical* (superzone-
                                 addressed) program into per-member
                                 *physical* programs at zone-chunk
                                 granularity, with optional RAID-5-style
                                 log-structured parity appends, using
                                 the exact stripe math of
                                 :class:`repro.array.ZNSArray`;
* :func:`pad_programs`        -- right-pad ragged per-device programs
                                 with NOP rows so a fleet stacks into
                                 the rectangular batch ``run_programs``
                                 consumes.

Units: ``n_pages`` counts flash pages; zones/tenants/devices are dense
int indexes.  Parity rows carry the reserved tag passed as
``parity_tenant`` (by convention ``n_tenants``, one past the real
tenants) so array-level DLWA can separate parity from host data.
"""

from __future__ import annotations

from typing import Dict, List, Sequence

import numpy as np

from repro.array.raid import locate_page, parity_device_of
from repro.core import engine as zengine

#: column index of the tenant tag in a width-5 op row
TENANT_COL = 4


def tag_tenant(program: np.ndarray, tenant: int) -> np.ndarray:
    """Widen ``(n_ops, >=4)`` to width 5 and stamp ``tenant`` on every
    row (an already-width-5 program is re-stamped)."""
    program = np.asarray(program, dtype=np.int32)
    out = np.zeros((len(program), TENANT_COL + 1), dtype=np.int32)
    out[:, :4] = program[:, :4]
    out[:, TENANT_COL] = tenant
    return out


def interleave_tenants(programs: Sequence[np.ndarray]) -> np.ndarray:
    """Merge tenant programs round-robin by per-tenant op position.

    Models concurrent per-tenant submission queues drained fairly --
    exactly the merge :func:`repro.core.timing._merge` applies to IO
    streams, lifted to op granularity.  A single program passes through
    unchanged (so 1 tenant x 1 device is bit-identical to the plain
    ``run_program`` path -- tested).
    """
    programs = [np.asarray(p, dtype=np.int32) for p in programs if len(p)]
    if not programs:
        return np.zeros((0, TENANT_COL + 1), dtype=np.int32)
    width = max(p.shape[1] for p in programs)
    programs = [p if p.shape[1] == width else
                np.pad(p, ((0, 0), (0, width - p.shape[1])))
                for p in programs]
    if len(programs) == 1:
        return programs[0]
    order_keys = np.concatenate(
        [np.arange(len(p), dtype=np.int64) * len(programs) + i
         for i, p in enumerate(programs)])
    perm = np.argsort(order_keys, kind="stable")
    return np.concatenate(programs)[perm]


def stripe_program(program: np.ndarray, *, n_devices: int,
                   chunk_pages: int, parity: bool,
                   member_zone_pages: int, parity_tenant: int
                   ) -> List[np.ndarray]:
    """Rewrite a logical superzone program into per-member programs.

    The logical address space is :class:`repro.array.ZNSArray`'s: a
    superzone ``z`` maps to physical zone ``z`` on every member, host
    pages stripe at ``chunk_pages`` granularity across the ``n_data``
    data members of each stripe, and (with ``parity``) one parity chunk
    per stripe is appended to the rotating parity member as soon as the
    stripe completes -- or, for the final partial stripe, at FINISH.
    FINISH/RESET fan out to every member.  Each member's program is a
    strictly sequential append stream per zone, which is what a ZNS
    zone requires and what keeps SilentZNS allocation valid underneath.

    ``member_zone_pages`` is the *effective* member zone capacity in
    pages (a ``DynConfig`` override under heterogeneous geometries);
    the logical superzone capacity is ``n_data * member_zone_pages``.
    Parity rows are tagged ``parity_tenant``.

    Returns ``n_devices`` programs of width 5 (ragged lengths -- see
    :func:`pad_programs`).
    """
    if n_devices < 1:
        raise ValueError("n_devices must be >= 1")
    if parity and n_devices < 2:
        raise ValueError("parity needs >= 2 devices")
    if member_zone_pages % chunk_pages:
        raise ValueError(
            f"chunk_pages={chunk_pages} must divide the member zone "
            f"capacity ({member_zone_pages} pages)")
    n_data = n_devices - (1 if parity else 0)
    cap = n_data * member_zone_pages
    c = chunk_pages
    out: List[List[tuple]] = [[] for _ in range(n_devices)]
    wp: Dict[int, int] = {}                 # superzone -> logical wp
    emitted: Dict[int, int] = {}            # superzone -> parity stripes

    def emit_parity(zone: int, upto_stripe: int) -> None:
        if not parity:
            return
        while emitted.get(zone, 0) < upto_stripe:
            s = emitted.get(zone, 0)
            p = parity_device_of(zone, s, n_devices)
            out[p].append((zengine.OP_WRITE, zone, c, zengine.F_HOST,
                           parity_tenant))
            emitted[zone] = s + 1

    program = np.asarray(program, dtype=np.int32)
    for row in program:
        op, zone, n_pages = int(row[0]), int(row[1]), int(row[2])
        flags = int(row[3])
        tenant = int(row[TENANT_COL]) if len(row) > TENANT_COL else 0
        if op == zengine.OP_WRITE:
            page = wp.get(zone, 0)
            if page + n_pages > cap:
                raise ValueError(
                    f"superzone {zone} overflow: wp={page} + {n_pages} "
                    f"> {cap}")
            remaining = n_pages
            while remaining > 0:
                stripe, _, r, dev = locate_page(
                    zone, page, c, n_data, n_devices, parity)
                # parity of every completed stripe lands before this
                # member appends its next chunk (log-structured order)
                emit_parity(zone, stripe)
                take = min(c - r, remaining)
                out[dev].append((op, zone, take, flags, tenant))
                page += take
                remaining -= take
            wp[zone] = page
            emit_parity(zone, page // (c * n_data))
        elif op == zengine.OP_FINISH:
            page = wp.get(zone, 0)
            full_stripes = page // (c * n_data)
            emit_parity(zone, full_stripes)
            # partial-stripe parity exactly once (a repeated FINISH is
            # a no-op, matching ZNSArray's FULL-zone semantics)
            if (parity and page % (c * n_data)
                    and emitted.get(zone, 0) <= full_stripes):
                # parity over the final partial stripe covers the
                # written prefix (unwritten data reads as zeros)
                p = parity_device_of(zone, full_stripes, n_devices)
                out[p].append((zengine.OP_WRITE, zone, c, zengine.F_HOST,
                               parity_tenant))
                emitted[zone] = full_stripes + 1
            for dev in range(n_devices):
                out[dev].append((op, zone, 0, 0, tenant))
        elif op == zengine.OP_RESET:
            for dev in range(n_devices):
                out[dev].append((op, zone, 0, 0, tenant))
            wp.pop(zone, None)
            emitted.pop(zone, None)
        else:  # NOP/ALLOC/READ: replicate (state-neutral or per-member)
            for dev in range(n_devices):
                out[dev].append((op, zone, n_pages, flags, tenant))
    return [zengine.encode_program(rows, width=TENANT_COL + 1)
            for rows in out]


def pad_programs(programs: Sequence[np.ndarray],
                 n_ops: int | None = None) -> np.ndarray:
    """Right-pad ragged programs with NOP rows and stack to
    ``(n_programs, n_ops, 5)`` -- the rectangular batch
    ``run_programs`` consumes.  NOP rows are all-zero (``OP_NOP``
    moves no pages and touches no state)."""
    programs = [np.asarray(p, dtype=np.int32) for p in programs]
    width = max((p.shape[1] for p in programs if p.ndim == 2),
                default=TENANT_COL + 1)
    n_max = n_ops if n_ops is not None else max(
        (len(p) for p in programs), default=0)
    out = np.zeros((len(programs), n_max, width), dtype=np.int32)
    for i, p in enumerate(programs):
        if len(p) > n_max:
            raise ValueError(f"program {i} has {len(p)} ops > {n_max}")
        out[i, : len(p), : p.shape[1]] = p
    return out
