"""The paper's benchmark workloads (§6.1) on the emulated device.

* ``dlwa_benchmark``        -- fill zones to a target occupancy, FINISH,
                               count dummy pages (Fig. 4a / 7a / 8).
* ``interference_benchmark``-- N zones being FINISHed while the host
                               writes N other zones (Fig. 4b / 7d, Table 3).
* ``write_benchmark``       -- FIO-like sequential writes, varying request
                               size and concurrent zones (Fig. 9).
* ``alloc_latency_benchmark``-- median zone-allocation latency (Table 4).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.core import timing
from repro.core.device import IOTrace, ZNSDevice, ZoneState
from repro.core.elements import ElementSpec
from repro.core.geometry import FlashGeometry, ZoneGeometry


def make_device(flash: FlashGeometry, zone: ZoneGeometry, spec: ElementSpec,
                *, max_active: int = 14, alloc_impl: str = "xla") -> ZNSDevice:
    return ZNSDevice(flash, zone, spec, max_active=max_active,
                     alloc_impl=alloc_impl)


# --------------------------------------------------------------------- #
# DLWA benchmark (paper Fig. 4a, 7a, 8)
# --------------------------------------------------------------------- #
def dlwa_benchmark(dev: ZNSDevice, *, occupancy: float,
                   n_zones: Optional[int] = None) -> Dict[str, float]:
    """Fill ``n_zones`` zones to ``occupancy`` then FINISH each; report
    dummy pages (pages 'finished') and DLWA."""
    n_zones = n_zones or min(8, dev.n_zones)
    pages = max(1, int(round(dev.zone_pages * occupancy)))
    pages = min(pages, dev.zone_pages)
    host0, dummy0 = dev.host_pages, dev.dummy_pages
    for z in range(n_zones):
        dev.zone_write(z, pages)
        dev.zone_finish(z)
    host = dev.host_pages - host0
    dummy = dev.dummy_pages - dummy0
    return {
        "occupancy": occupancy,
        "host_pages": float(host),
        "dummy_pages": float(dummy),
        "dummy_pages_per_zone": dummy / n_zones,
        "dlwa": (host + dummy) / host if host else 1.0,
    }


# --------------------------------------------------------------------- #
# Interference benchmark (paper Fig. 4b, 7d, Table 3)
# --------------------------------------------------------------------- #
def interference_benchmark(dev: ZNSDevice, *, concurrency: int,
                           fill_occupancy: float = 0.4,
                           host_pages_per_zone: Optional[int] = None
                           ) -> Dict[str, float]:
    """``concurrency`` zones are FINISHed while the host writes to
    ``concurrency`` other zones.  Interference = host-only throughput /
    host throughput under concurrent FINISH."""
    fill = max(1, int(round(dev.zone_pages * fill_occupancy)))
    hpz = host_pages_per_zone or fill

    # victims: partially filled zones that will be finished
    victims = list(range(concurrency))
    writers = list(range(concurrency, 2 * concurrency))
    for z in victims:
        dev.zone_write(z, fill)

    host_traces: List[IOTrace] = []
    for z in writers:
        tr = dev.zone_write(z, hpz, trace=True)
        host_traces.append(tr)

    finish_traces: List[IOTrace] = []
    for z in victims:
        tr = dev.zone_finish(z, trace=True)
        if tr is not None and len(tr.luns):
            finish_traces.append(tr)

    # baseline: host streams alone
    base = timing.run_trace(dev.flash, host_traces)
    base_tp = sum(base[f"owner{i}_throughput_pages_s"]
                  for i in range(len(host_traces)))
    # contended: host + finish dummy streams interleaved
    cont = timing.run_trace(dev.flash, host_traces + finish_traces)
    cont_tp = sum(cont[f"owner{i}_throughput_pages_s"]
                  for i in range(len(host_traces)))
    factor = base_tp / cont_tp if cont_tp else float("inf")
    return {
        "concurrency": float(concurrency),
        "baseline_pages_s": base_tp,
        "contended_pages_s": cont_tp,
        "interference": factor,
        "dummy_pages": float(sum(len(t.luns) for t in finish_traces)),
    }


# --------------------------------------------------------------------- #
# FIO-like raw write benchmark (paper Fig. 9)
# --------------------------------------------------------------------- #
def write_benchmark(dev: ZNSDevice, *, request_kib: int, n_jobs: int,
                    mib_per_job: int = 16) -> Dict[str, float]:
    """``n_jobs`` concurrent sequential writers, one dedicated zone each,
    fixed request size.  Reports aggregate bandwidth (MiB/s)."""
    pages_per_req = max(1, request_kib * 1024 // dev.flash.page_bytes)
    reqs_per_job = max(1, mib_per_job * 1024 * 1024
                       // (pages_per_req * dev.flash.page_bytes))
    total_pages = pages_per_req * reqs_per_job
    total_pages = min(total_pages, dev.zone_pages)

    traces: List[IOTrace] = []
    for j in range(n_jobs):
        tr = dev.zone_write(j, total_pages, trace=True)
        traces.append(tr)
    stats = timing.run_trace(dev.flash, traces)
    return {
        "request_kib": float(request_kib),
        "n_jobs": float(n_jobs),
        "pages": float(stats["n"]),
        "bandwidth_mib_s": timing.write_bandwidth_mib_s(dev.flash, stats),
        "makespan_s": stats["makespan_s"],
    }


# --------------------------------------------------------------------- #
# Zone-allocation latency (paper Table 4)
# --------------------------------------------------------------------- #
def alloc_latency_benchmark(dev: ZNSDevice, *, n_allocs: int = 32
                            ) -> Dict[str, float]:
    """Median wall-clock latency of zone allocation.  Exercises the
    allocate -> write -> finish -> reset cycle so re-allocation hits the
    deferred-erase path too."""
    n = min(n_allocs, dev.n_zones)
    # warm up jit
    dev.zone_write(0, 1)
    dev.zone_finish(0)
    dev.zone_reset(0)
    dev.alloc_latencies_us.clear()
    for i in range(n):
        z = i % max(1, dev.n_zones // 2)
        dev.zone_write(z, 1)
        dev.zone_finish(z)
        dev.zone_reset(z)
    return {
        "n_allocs": float(len(dev.alloc_latencies_us)),
        "median_us": dev.median_alloc_latency_us(),
        "mean_us": float(np.mean(dev.alloc_latencies_us)),
    }
