"""The paper's benchmark workloads (§6.1) on the emulated device.

* ``dlwa_benchmark``        -- fill zones to a target occupancy, FINISH,
                               count dummy pages (Fig. 4a / 7a / 8).
* ``interference_benchmark``-- N zones being FINISHed while the host
                               writes N other zones (Fig. 4b / 7d, Table 3).
* ``write_benchmark``       -- FIO-like sequential writes, varying request
                               size and concurrent zones (Fig. 9).
* ``alloc_latency_benchmark``-- median zone-allocation latency (Table 4).

Each benchmark also has a **batched engine driver** (``*_engine`` /
``dlwa_sweep_engine``) that encodes the workload as an op program and
executes it through :mod:`repro.core.engine` -- a whole occupancy sweep
runs as one vmapped ``lax.scan`` instead of per-op Python calls, and the
interference benchmark runs as a single fused finish+host-write program.
The engine drivers are metric-identical to the per-op paths (tested) and
are what ``tools/bench.py`` uses to track the engine-vs-legacy speedup.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence

import jax
import numpy as np

from repro.core import engine as zengine
from repro.core import timing
from repro.core.device import IOTrace, ZNSDevice, ZoneState
from repro.core.elements import ElementSpec
from repro.core.geometry import FlashGeometry, ZoneGeometry


def make_device(flash: FlashGeometry, zone: ZoneGeometry, spec: ElementSpec,
                *, max_active: int = 14, alloc_impl: str = "xla") -> ZNSDevice:
    return ZNSDevice(flash, zone, spec, max_active=max_active,
                     alloc_impl=alloc_impl)


# --------------------------------------------------------------------- #
# DLWA benchmark (paper Fig. 4a, 7a, 8)
# --------------------------------------------------------------------- #
def dlwa_benchmark(dev: ZNSDevice, *, occupancy: float,
                   n_zones: Optional[int] = None) -> Dict[str, float]:
    """Fill ``n_zones`` zones to ``occupancy`` then FINISH each; report
    dummy pages (pages 'finished') and DLWA."""
    n_zones = n_zones or min(8, dev.n_zones)
    pages = max(1, int(round(dev.zone_pages * occupancy)))
    pages = min(pages, dev.zone_pages)
    host0, dummy0 = dev.host_pages, dev.dummy_pages
    for z in range(n_zones):
        dev.zone_write(z, pages)
        dev.zone_finish(z)
    host = dev.host_pages - host0
    dummy = dev.dummy_pages - dummy0
    return {
        "occupancy": occupancy,
        "host_pages": float(host),
        "dummy_pages": float(dummy),
        "dummy_pages_per_zone": dummy / n_zones,
        "dlwa": (host + dummy) / host if host else 1.0,
    }


# --------------------------------------------------------------------- #
# Interference benchmark (paper Fig. 4b, 7d, Table 3)
# --------------------------------------------------------------------- #
def interference_benchmark(dev: ZNSDevice, *, concurrency: int,
                           fill_occupancy: float = 0.4,
                           host_pages_per_zone: Optional[int] = None
                           ) -> Dict[str, float]:
    """``concurrency`` zones are FINISHed while the host writes to
    ``concurrency`` other zones.  Interference = host-only throughput /
    host throughput under concurrent FINISH."""
    fill = max(1, int(round(dev.zone_pages * fill_occupancy)))
    hpz = host_pages_per_zone or fill

    # victims: partially filled zones that will be finished
    victims = list(range(concurrency))
    writers = list(range(concurrency, 2 * concurrency))
    for z in victims:
        dev.zone_write(z, fill)

    host_traces: List[IOTrace] = []
    for z in writers:
        tr = dev.zone_write(z, hpz, trace=True)
        host_traces.append(tr)

    finish_traces: List[IOTrace] = []
    for z in victims:
        tr = dev.zone_finish(z, trace=True)
        if tr is not None and len(tr.luns):
            finish_traces.append(tr)

    # baseline: host streams alone
    base = timing.run_trace(dev.flash, host_traces)
    base_tp = sum(base[f"owner{i}_throughput_pages_s"]
                  for i in range(len(host_traces)))
    # contended: host + finish dummy streams interleaved
    cont = timing.run_trace(dev.flash, host_traces + finish_traces)
    cont_tp = sum(cont[f"owner{i}_throughput_pages_s"]
                  for i in range(len(host_traces)))
    factor = base_tp / cont_tp if cont_tp else float("inf")
    return {
        "concurrency": float(concurrency),
        "baseline_pages_s": base_tp,
        "contended_pages_s": cont_tp,
        "interference": factor,
        "dummy_pages": float(sum(len(t.luns) for t in finish_traces)),
    }


# --------------------------------------------------------------------- #
# FIO-like raw write benchmark (paper Fig. 9)
# --------------------------------------------------------------------- #
def write_benchmark(dev: ZNSDevice, *, request_kib: int, n_jobs: int,
                    mib_per_job: int = 16) -> Dict[str, float]:
    """``n_jobs`` concurrent sequential writers, one dedicated zone each,
    fixed request size.  Reports aggregate bandwidth (MiB/s)."""
    pages_per_req = max(1, request_kib * 1024 // dev.flash.page_bytes)
    reqs_per_job = max(1, mib_per_job * 1024 * 1024
                       // (pages_per_req * dev.flash.page_bytes))
    total_pages = pages_per_req * reqs_per_job
    total_pages = min(total_pages, dev.zone_pages)

    traces: List[IOTrace] = []
    for j in range(n_jobs):
        tr = dev.zone_write(j, total_pages, trace=True)
        traces.append(tr)
    stats = timing.run_trace(dev.flash, traces)
    return {
        "request_kib": float(request_kib),
        "n_jobs": float(n_jobs),
        "pages": float(stats["n"]),
        "bandwidth_mib_s": timing.write_bandwidth_mib_s(dev.flash, stats),
        "makespan_s": stats["makespan_s"],
    }


# --------------------------------------------------------------------- #
# Zone-allocation latency (paper Table 4)
# --------------------------------------------------------------------- #
def alloc_latency_benchmark(dev: ZNSDevice, *, n_allocs: int = 32
                            ) -> Dict[str, float]:
    """Median wall-clock latency of zone allocation.  Exercises the
    allocate -> write -> finish -> reset cycle so re-allocation hits the
    deferred-erase path too."""
    n = min(n_allocs, dev.n_zones)
    # Warm up jit *before* timing: every compilable path (engine op
    # switch, or the legacy allocator's primary window + cheapest-groups
    # fallback) -- otherwise first-call compilation lands in the sample
    # set and skews small-sample medians (paper Table 4 methodology).
    warmup = getattr(dev, "warmup_alloc", None)
    if warmup is not None:
        warmup()
    dev.zone_write(0, 1)
    dev.zone_finish(0)
    dev.zone_reset(0)
    dev.alloc_latencies_us.clear()
    for i in range(n):
        z = i % max(1, dev.n_zones // 2)
        dev.zone_write(z, 1)
        dev.zone_finish(z)
        dev.zone_reset(z)
    return {
        "n_allocs": float(len(dev.alloc_latencies_us)),
        "median_us": dev.median_alloc_latency_us(),
        "mean_us": float(np.mean(dev.alloc_latencies_us)),
    }


# --------------------------------------------------------------------- #
# Batched engine drivers: workloads as op programs (one compiled scan)
# --------------------------------------------------------------------- #
def make_engine(flash: FlashGeometry, zone: ZoneGeometry,
                spec: ElementSpec, *, max_active: int = 14,
                wear_aware: Optional[bool] = None) -> zengine.ZoneEngine:
    return zengine.ZoneEngine(flash, zone, spec, max_active=max_active,
                              wear_aware=wear_aware)


def dlwa_program(eng: zengine.ZoneEngine, *, occupancy: float,
                 n_zones: Optional[int] = None, zone_base: int = 0,
                 zone_pages: Optional[int] = None) -> np.ndarray:
    """Encode :func:`dlwa_benchmark` as an op program.

    ``zone_base`` offsets the zones touched (the fleet layer namespaces
    tenants into disjoint zone ranges); ``zone_pages`` overrides the
    capacity occupancy is computed against (a fleet superzone's logical
    capacity, or a ``DynConfig`` effective geometry)."""
    cfg = eng.cfg
    n_zones = n_zones or min(8, cfg.n_zones)
    cap = zone_pages or cfg.zone_pages
    pages = max(1, int(round(cap * occupancy)))
    pages = min(pages, cap)
    rows = []
    for z in range(zone_base, zone_base + n_zones):
        rows.append((zengine.OP_WRITE, z, pages, zengine.F_HOST))
        rows.append((zengine.OP_FINISH, z, 0, 0))
    return zengine.encode_program(rows)


def _dlwa_metrics(host: int, dummy: int, occupancy: float,
                  n_zones: int) -> Dict[str, float]:
    return {
        "occupancy": occupancy,
        "host_pages": float(host),
        "dummy_pages": float(dummy),
        "dummy_pages_per_zone": dummy / n_zones,
        "dlwa": (host + dummy) / host if host else 1.0,
    }


def dlwa_benchmark_engine(eng: zengine.ZoneEngine, *, occupancy: float,
                          n_zones: Optional[int] = None) -> Dict[str, float]:
    """:func:`dlwa_benchmark` as one ``lax.scan`` (fresh device state)."""
    n_zones = n_zones or min(8, eng.cfg.n_zones)
    prog = dlwa_program(eng, occupancy=occupancy, n_zones=n_zones)
    state, _ = eng.run(eng.init_state(), prog)
    return _dlwa_metrics(int(state.host_pages), int(state.dummy_pages),
                         occupancy, n_zones)


def dlwa_sweep_engine(eng: zengine.ZoneEngine,
                      occupancies: Sequence[float],
                      *, n_zones: Optional[int] = None
                      ) -> List[Dict[str, float]]:
    """A whole occupancy sweep in ONE vmapped scan: every program has the
    same shape (pages varies per row), so the sweep batches cleanly."""
    n_zones = n_zones or min(8, eng.cfg.n_zones)
    programs = np.stack([
        dlwa_program(eng, occupancy=o, n_zones=n_zones)
        for o in occupancies])
    states, _ = eng.run_batch(eng.init_state(), programs)
    hosts = np.asarray(states.host_pages)
    dummies = np.asarray(states.dummy_pages)
    return [_dlwa_metrics(int(hosts[k]), int(dummies[k]), occ, n_zones)
            for k, occ in enumerate(occupancies)]


def _op_traces(eng: zengine.ZoneEngine, program: np.ndarray, trace
               ) -> List[Optional[IOTrace]]:
    """Per-op IOTraces of an executed program (None for no-IO ops)."""
    wp_b = np.asarray(trace.wp_before)
    wp_a = np.asarray(trace.wp_after)
    dummy = np.asarray(trace.dummy_delta)
    elems = np.asarray(trace.elems)
    cols = np.asarray(trace.cols)
    out: List[Optional[IOTrace]] = []
    for i in range(len(program)):
        s = eng.op_stream(int(program[i, 0]), int(wp_b[i]), int(wp_a[i]),
                          int(dummy[i]), elems[i], cols[i])
        out.append(None if s is None else IOTrace(s[0], s[1], s[2]))
    return out


def interference_program(eng: zengine.ZoneEngine, *, concurrency: int,
                         fill_occupancy: float = 0.4,
                         host_pages_per_zone: Optional[int] = None,
                         zone_base: int = 0,
                         zone_pages: Optional[int] = None) -> np.ndarray:
    """Fused finish+host-write program (victim fills, host writes, victim
    FINISHes) -- the exact op order of :func:`interference_benchmark`.
    ``zone_base`` / ``zone_pages`` as in :func:`dlwa_program`."""
    cfg = eng.cfg
    cap = zone_pages or cfg.zone_pages
    fill = max(1, int(round(cap * fill_occupancy)))
    hpz = host_pages_per_zone or fill
    rows = []
    b = zone_base
    for z in range(b, b + concurrency):                    # victims fill
        rows.append((zengine.OP_WRITE, z, fill, zengine.F_HOST))
    for z in range(b + concurrency, b + 2 * concurrency):  # host writers
        rows.append((zengine.OP_WRITE, z, hpz, zengine.F_HOST))
    for z in range(b, b + concurrency):                    # victims FINISH
        rows.append((zengine.OP_FINISH, z, 0, 0))
    return zengine.encode_program(rows)


def interference_benchmark_engine(eng: zengine.ZoneEngine, *,
                                  concurrency: int,
                                  fill_occupancy: float = 0.4,
                                  host_pages_per_zone: Optional[int] = None
                                  ) -> Dict[str, float]:
    """:func:`interference_benchmark` via one scan + one stream rebuild;
    timing uses the same :func:`repro.core.timing.run_trace` merge."""
    prog = interference_program(
        eng, concurrency=concurrency, fill_occupancy=fill_occupancy,
        host_pages_per_zone=host_pages_per_zone)
    state, trace = eng.run(eng.init_state(), prog)
    streams = _op_traces(eng, prog, trace)
    host_traces = [t for t in streams[concurrency: 2 * concurrency]
                   if t is not None]
    finish_traces = [t for t in streams[2 * concurrency:]
                     if t is not None and len(t.luns)]
    base = timing.run_trace(eng.flash, host_traces)
    base_tp = sum(base[f"owner{i}_throughput_pages_s"]
                  for i in range(len(host_traces)))
    cont = timing.run_trace(eng.flash, host_traces + finish_traces)
    cont_tp = sum(cont[f"owner{i}_throughput_pages_s"]
                  for i in range(len(host_traces)))
    factor = base_tp / cont_tp if cont_tp else float("inf")
    return {
        "concurrency": float(concurrency),
        "baseline_pages_s": base_tp,
        "contended_pages_s": cont_tp,
        "interference": factor,
        "dummy_pages": float(sum(len(t.luns) for t in finish_traces)),
    }


def interference_sweep_engine(eng: zengine.ZoneEngine,
                              concurrencies: Sequence[int], *,
                              fill_occupancy: float = 0.4,
                              host_pages_per_zone: Optional[int] = None
                              ) -> List[Dict[str, float]]:
    """The whole concurrency sweep of
    :func:`interference_benchmark_engine` in ONE batched dispatch.

    The per-concurrency driver rebuilt a ``3 * c``-row program per
    point, so every concurrency was its own ``run_program`` shape --
    one jaxpr trace + compile *per point* plus per-point dispatch
    overhead, which is why ``BENCH_zoneengine.json`` showed the engine
    *losing* to the legacy loop (0.96x) on this benchmark.  Here the
    per-concurrency programs are NOP-padded to one rectangular batch
    and executed through a single ``run_programs`` dispatch: one
    compiled shape for the whole sweep, verified recompile-free across
    repeats by the ``repro.obs`` recompile counter in ``tools/bench.py``
    and ``tests/test_obs.py``.

    Per-point metrics (stream rebuild + ``run_trace`` timing on the
    unpadded prefix) are exactly those of
    :func:`interference_benchmark_engine` (asserted in tests and by
    ``tools/bench.py`` against the legacy per-op loop).
    """
    concurrencies = list(concurrencies)
    progs = [interference_program(
        eng, concurrency=c, fill_occupancy=fill_occupancy,
        host_pages_per_zone=host_pages_per_zone) for c in concurrencies]
    n_max = max((len(p) for p in progs), default=0)
    batch = np.zeros((len(progs), n_max, 4), dtype=np.int32)
    for i, p in enumerate(progs):
        batch[i, : len(p)] = p                 # NOP rows pad the tail
    _, traces = eng.run_batch(eng.init_state(), batch)
    out: List[Dict[str, float]] = []
    for i, (c, prog) in enumerate(zip(concurrencies, progs)):
        lane = jax.tree_util.tree_map(lambda x: x[i], traces)
        streams = _op_traces(eng, prog, lane)
        host_traces = [t for t in streams[c: 2 * c] if t is not None]
        finish_traces = [t for t in streams[2 * c: len(prog)]
                         if t is not None and len(t.luns)]
        base = timing.run_trace(eng.flash, host_traces)
        base_tp = sum(base[f"owner{j}_throughput_pages_s"]
                      for j in range(len(host_traces)))
        cont = timing.run_trace(eng.flash, host_traces + finish_traces)
        cont_tp = sum(cont[f"owner{j}_throughput_pages_s"]
                      for j in range(len(host_traces)))
        out.append({
            "concurrency": float(c),
            "baseline_pages_s": base_tp,
            "contended_pages_s": cont_tp,
            "interference": base_tp / cont_tp if cont_tp else
            float("inf"),
            "dummy_pages": float(sum(len(t.luns)
                                     for t in finish_traces)),
        })
    return out


def write_program(eng: zengine.ZoneEngine, *, request_kib: int,
                  n_jobs: int, mib_per_job: int = 16, zone_base: int = 0,
                  zone_pages: Optional[int] = None) -> np.ndarray:
    """Encode :func:`write_benchmark`'s sequential-writer jobs (one
    dedicated zone each) as an op program.  ``zone_base`` /
    ``zone_pages`` as in :func:`dlwa_program`."""
    cfg = eng.cfg
    cap = zone_pages or cfg.zone_pages
    pages_per_req = max(1, request_kib * 1024 // eng.flash.page_bytes)
    reqs_per_job = max(1, mib_per_job * 1024 * 1024
                       // (pages_per_req * eng.flash.page_bytes))
    total_pages = min(pages_per_req * reqs_per_job, cap)
    return zengine.encode_program(
        [(zengine.OP_WRITE, zone_base + j, total_pages, zengine.F_HOST)
         for j in range(n_jobs)])


def write_benchmark_engine(eng: zengine.ZoneEngine, *, request_kib: int,
                           n_jobs: int, mib_per_job: int = 16
                           ) -> Dict[str, float]:
    """:func:`write_benchmark` as an op program + one stream rebuild."""
    prog = write_program(eng, request_kib=request_kib, n_jobs=n_jobs,
                         mib_per_job=mib_per_job)
    state, trace = eng.run(eng.init_state(), prog)
    traces = [t for t in _op_traces(eng, prog, trace) if t is not None]
    stats = timing.run_trace(eng.flash, traces)
    return {
        "request_kib": float(request_kib),
        "n_jobs": float(n_jobs),
        "pages": float(stats["n"]),
        "bandwidth_mib_s": timing.write_bandwidth_mib_s(eng.flash, stats),
        "makespan_s": stats["makespan_s"],
    }


# --------------------------------------------------------------------- #
# Engine vs legacy per-op loop: the PR's tracked perf trajectory
# --------------------------------------------------------------------- #
def engine_vs_legacy_speedup(*, occupancies: Sequence[float] = tuple(
        np.linspace(0.05, 0.95, 16)), n_zones: int = 8,
        concurrencies: Sequence[int] = (1, 2, 4, 7),
        repeats: int = 3) -> Dict[str, float]:
    """Time the DLWA occupancy sweep and the interference benchmark on
    the legacy per-op ``LegacyZNSDevice`` loop vs the scan-compiled
    engine (steady state: compile excluded via warmup).  Returns ops/sec
    for both plus the speedups ``tools/bench.py`` archives."""
    from repro.core.device_legacy import LegacyZNSDevice
    from repro.core.elements import SUPERBLOCK
    from repro.core.geometry import zn540

    flash, zone = zn540()
    eng = make_engine(flash, zone, SUPERBLOCK, max_active=28)

    # ---- dlwa sweep -------------------------------------------------- #
    n_ops_dlwa = 2 * n_zones * len(occupancies)
    dlwa_sweep_engine(eng, occupancies, n_zones=n_zones)  # compile
    t0 = time.perf_counter()
    for _ in range(repeats):
        eng_rows = dlwa_sweep_engine(eng, occupancies, n_zones=n_zones)
    t_eng_dlwa = (time.perf_counter() - t0) / repeats

    def legacy_sweep():
        rows = []
        for occ in occupancies:
            dev = LegacyZNSDevice(flash, zone, SUPERBLOCK, max_active=28)
            rows.append(dlwa_benchmark(dev, occupancy=occ,
                                       n_zones=n_zones))
        return rows
    legacy_sweep()  # warm the allocator jit
    t0 = time.perf_counter()
    for _ in range(repeats):
        leg_rows = legacy_sweep()
    t_leg_dlwa = (time.perf_counter() - t0) / repeats
    assert [r["dlwa"] for r in eng_rows] == [r["dlwa"] for r in leg_rows]

    # ---- interference (whole sweep in ONE padded dispatch) ------------ #
    # the per-concurrency driver compiled one run_program shape per
    # point, which is what regressed this benchmark to 0.96x pre-PR 6;
    # the batched sweep holds one run_programs shape for the whole
    # sweep, and the obs recompile counter certifies repeats are
    # compile-free
    from repro.obs.profile import RecompileCounter
    n_ops_intf = sum(3 * c for c in concurrencies)

    def engine_intf():
        return interference_sweep_engine(eng, concurrencies)

    def legacy_intf():
        out = []
        for c in concurrencies:
            dev = LegacyZNSDevice(flash, zone, SUPERBLOCK, max_active=28)
            out.append(interference_benchmark(dev, concurrency=c))
        return out
    engine_intf(); legacy_intf()  # compile both paths
    rc = RecompileCounter(run_programs=zengine.run_programs)
    warm = rc.counts()
    t0 = time.perf_counter()
    for _ in range(repeats):
        ei = engine_intf()
    t_eng_intf = (time.perf_counter() - t0) / repeats
    intf_recompiles = rc.delta(warm)["run_programs"]
    t0 = time.perf_counter()
    for _ in range(repeats):
        li = legacy_intf()
    t_leg_intf = (time.perf_counter() - t0) / repeats
    assert [r["dummy_pages"] for r in ei] == [r["dummy_pages"] for r in li]

    return {
        "dlwa_ops": float(n_ops_dlwa),
        "dlwa_legacy_s": t_leg_dlwa,
        "dlwa_engine_s": t_eng_dlwa,
        "dlwa_legacy_ops_s": n_ops_dlwa / t_leg_dlwa,
        "dlwa_engine_ops_s": n_ops_dlwa / t_eng_dlwa,
        "dlwa_speedup": t_leg_dlwa / t_eng_dlwa,
        "interference_ops": float(n_ops_intf),
        "interference_legacy_s": t_leg_intf,
        "interference_engine_s": t_eng_intf,
        "interference_legacy_ops_s": n_ops_intf / t_leg_intf,
        "interference_engine_ops_s": n_ops_intf / t_eng_intf,
        "interference_speedup": t_leg_intf / t_eng_intf,
        # dispatches per sweep and jit-cache growth across the timed
        # repeats (0 = shape-stable, the property the batched sweep
        # restores; tools/bench.py asserts it)
        "interference_dispatches": 1.0,
        "interference_recompiles": float(intf_recompiles),
    }
