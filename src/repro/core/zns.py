"""Write-order striping math (paper §3 Fig. 3b, §4).

A zone spans P LUN *columns* and ``n_segments`` stacked *segments*; pages
are striped round-robin across the P columns of the current segment, and a
segment is fully written before the write pointer advances to the next
(paper Fig. 3b).  These closed forms convert a zone write pointer ``wp``
(pages written so far) into per-block / per-element page counts -- the
quantity FINISH needs to decide dummy padding -- and into per-page LUN
streams for the timing model.

Element-slot ordering convention (used by the device mapping table):

* BLOCK       slot = seg * P + col
* VCHUNK(s)   slot = seg * (P//s) + col//s
* HCHUNK(s)   slot = (seg//s) * P + col
* SUPERBLOCK  slot = seg
* FIXED       slot = 0
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.elements import ElementKind, ElementSpec


def pages_per_block(wp: int, parallelism: int, n_segments: int,
                    pages_per_blk: int) -> np.ndarray:
    """Pages written in each (segment, column) erase block at pointer wp.

    Returns int64 array of shape (n_segments, parallelism).
    """
    P = parallelism
    seg_pages = P * pages_per_blk
    seg = np.arange(n_segments, dtype=np.int64)
    w_seg = np.clip(wp - seg * seg_pages, 0, seg_pages)  # pages in each seg
    col = np.arange(P, dtype=np.int64)
    # pages in column c of a segment with w pages striped round-robin:
    # ceil((w - c) / P) clipped to [0, pages_per_blk]
    cnt = (w_seg[:, None] - col[None, :] + P - 1) // P
    return np.clip(cnt, 0, pages_per_blk)


def element_pages(wp: int, spec: ElementSpec, parallelism: int,
                  n_segments: int, pages_per_blk: int) -> np.ndarray:
    """Pages written per element *slot* (see module docstring ordering)."""
    blk = pages_per_block(wp, parallelism, n_segments, pages_per_blk)
    P = parallelism
    if spec.kind is ElementKind.BLOCK:
        return blk.reshape(-1)
    if spec.kind is ElementKind.VCHUNK:
        s = spec.chunk
        return blk.reshape(n_segments, P // s, s).sum(axis=2).reshape(-1)
    if spec.kind is ElementKind.SUPERBLOCK:
        return blk.sum(axis=1)
    if spec.kind is ElementKind.HCHUNK:
        s = spec.chunk
        if n_segments % s:
            raise ValueError("hchunk span must divide n_segments")
        return blk.reshape(n_segments // s, s, P).sum(axis=1).reshape(-1)
    if spec.kind is ElementKind.FIXED:
        return np.asarray([blk.sum()], dtype=np.int64)
    raise ValueError(spec.kind)


def pages_per_block_jnp(wp, parallelism: int, n_segments: int,
                        pages_per_blk: int):
    """:func:`pages_per_block` with a traced ``wp`` (used inside the
    :mod:`repro.core.engine` scan).  Returns int32 (n_segments, P)."""
    P = parallelism
    seg_pages = P * pages_per_blk
    seg = jnp.arange(n_segments, dtype=jnp.int32)
    w_seg = jnp.clip(wp - seg * seg_pages, 0, seg_pages)
    col = jnp.arange(P, dtype=jnp.int32)
    cnt = (w_seg[:, None] - col[None, :] + P - 1) // P
    return jnp.clip(cnt, 0, pages_per_blk)


def element_pages_jnp(wp, spec: ElementSpec, parallelism: int,
                      n_segments: int, pages_per_blk: int):
    """:func:`element_pages` with a traced ``wp`` (spec/shape static)."""
    blk = pages_per_block_jnp(wp, parallelism, n_segments, pages_per_blk)
    P = parallelism
    if spec.kind is ElementKind.BLOCK:
        return blk.reshape(-1)
    if spec.kind is ElementKind.VCHUNK:
        s = spec.chunk
        return blk.reshape(n_segments, P // s, s).sum(axis=2).reshape(-1)
    if spec.kind is ElementKind.SUPERBLOCK:
        return blk.sum(axis=1)
    if spec.kind is ElementKind.HCHUNK:
        s = spec.chunk
        if n_segments % s:
            raise ValueError("hchunk span must divide n_segments")
        return blk.reshape(n_segments // s, s, P).sum(axis=1).reshape(-1)
    if spec.kind is ElementKind.FIXED:
        return blk.sum().reshape(1)
    raise ValueError(spec.kind)


def slot_map_jnp(slot_stride, luns_per_group, seg_span, parallelism: int,
                 n_segments: int):
    """(n_segments, P) element-slot id owning each (segment, column)
    erase-block cell, from *value-level* spec parameters::

        slot = (segment // seg_span) * slot_stride + column // luns_per_group

    with ``seg_span = pages_per_element / (luns_per_group *
    pages_per_block)`` (segments an element spans vertically).  The
    three parameters may be traced scalars -- this is how the engine's
    union path keeps the element spec a per-lane *value* -- and the map
    reproduces the per-kind closed forms of :func:`element_pages` for
    every element kind (property-tested):

    =============  ===========  ==============  ========
    kind           slot_stride  luns_per_group  seg_span
    =============  ===========  ==============  ========
    BLOCK          P            1               1
    VCHUNK(s)      P // s       s               1
    SUPERBLOCK     1            P               1
    HCHUNK(s)      P            1               s
    FIXED          1            P               n_segments
    =============  ===========  ==============  ========
    """
    seg = jnp.arange(n_segments, dtype=jnp.int32)[:, None]
    col = jnp.arange(parallelism, dtype=jnp.int32)[None, :]
    return (seg // seg_span) * slot_stride + col // luns_per_group


def n_slots(spec: ElementSpec, parallelism: int, n_segments: int) -> int:
    if spec.kind is ElementKind.BLOCK:
        return n_segments * parallelism
    if spec.kind is ElementKind.VCHUNK:
        return n_segments * (parallelism // spec.chunk)
    if spec.kind is ElementKind.SUPERBLOCK:
        return n_segments
    if spec.kind is ElementKind.HCHUNK:
        return (n_segments // spec.chunk) * parallelism
    if spec.kind is ElementKind.FIXED:
        return 1
    raise ValueError(spec.kind)


def slot_of_group_rank(spec: ElementSpec, parallelism: int, n_segments: int,
                       col_or_band: int, rank: int) -> int:
    """Map (which column/band within the zone, rank-th element taken from
    that group) -> element slot.  Rank runs over the ``take`` elements a
    group contributes, assigned to segments bottom-up."""
    P = parallelism
    if spec.kind is ElementKind.BLOCK:
        return rank * P + col_or_band          # seg=rank, col
    if spec.kind is ElementKind.VCHUNK:
        return rank * (P // spec.chunk) + col_or_band
    if spec.kind is ElementKind.SUPERBLOCK:
        return rank                             # seg=rank
    if spec.kind is ElementKind.HCHUNK:
        return rank * P + col_or_band           # seggrp=rank, col
    if spec.kind is ElementKind.FIXED:
        return 0
    raise ValueError(spec.kind)


def page_stream(wp_start: int, n_pages: int, parallelism: int,
                pages_per_blk: int, column_luns: np.ndarray,
                n_channels: int) -> tuple[np.ndarray, np.ndarray]:
    """(lun, channel) per page for a striped write of ``n_pages`` starting
    at zone pointer ``wp_start``.  ``column_luns`` maps zone column -> LUN.
    """
    p = wp_start + np.arange(n_pages, dtype=np.int64)
    seg_pages = parallelism * pages_per_blk
    col = (p % seg_pages) % parallelism
    luns = np.asarray(column_luns, dtype=np.int64)[col]
    return luns, luns % n_channels


def page_slots(pages: np.ndarray, spec: ElementSpec, parallelism: int,
               pages_per_blk: int) -> np.ndarray:
    """Element slot owning each page (vectorized page -> slot map)."""
    p = np.asarray(pages, dtype=np.int64)
    P = parallelism
    seg_pages = P * pages_per_blk
    seg = p // seg_pages
    col = (p % seg_pages) % P
    if spec.kind is ElementKind.BLOCK:
        return seg * P + col
    if spec.kind is ElementKind.VCHUNK:
        return seg * (P // spec.chunk) + col // spec.chunk
    if spec.kind is ElementKind.SUPERBLOCK:
        return seg
    if spec.kind is ElementKind.HCHUNK:
        return (seg // spec.chunk) * P + col
    if spec.kind is ElementKind.FIXED:
        return np.zeros_like(p)
    raise ValueError(spec.kind)


def pad_stream(wp: int, zone_pages: int, spec: ElementSpec,
               parallelism: int, pages_per_blk: int,
               column_luns: np.ndarray, padded_slots: np.ndarray,
               n_channels: int) -> tuple[np.ndarray, np.ndarray]:
    """(lun, channel) streams for FINISH dummy padding.

    Padding continues the zone's striped write order from ``wp`` to the end
    of the zone, restricted to pages belonging to ``padded_slots`` (the
    partially-written elements) -- released elements receive no writes.
    """
    pages = np.arange(wp, zone_pages, dtype=np.int64)
    slots = page_slots(pages, spec, parallelism, pages_per_blk)
    keep = np.isin(slots, padded_slots)
    pages = pages[keep]
    seg_pages = parallelism * pages_per_blk
    col = (pages % seg_pages) % parallelism
    luns = np.asarray(column_luns, dtype=np.int64)[col]
    return luns, luns % n_channels


def read_stream(pages: np.ndarray, parallelism: int, pages_per_blk: int,
                column_luns: np.ndarray, n_channels: int
                ) -> tuple[np.ndarray, np.ndarray]:
    """(lun, channel) for arbitrary page reads within a zone."""
    p = np.asarray(pages, dtype=np.int64)
    seg_pages = parallelism * pages_per_blk
    col = (p % seg_pages) % parallelism
    luns = np.asarray(column_luns, dtype=np.int64)[col]
    return luns, luns % n_channels
