"""The ``ZoneBackend`` protocol: the zone-command surface hosts consume.

:class:`repro.storage.zonefs.ZoneFS` (and through it the LSM simulator,
the checkpoint benchmark, and every other host-side workload) only ever
touches a device through this surface:

* geometry     -- ``zone_pages``, ``n_zones``, ``max_active``,
                  ``flash`` (for ``page_bytes`` and timing constants);
* zone state   -- ``zones[z].state`` / ``zones[z].wp``;
* commands     -- ``zone_write`` / ``zone_read`` / ``zone_finish`` /
                  ``zone_reset``;
* metrics      -- ``dlwa``, ``host_pages``, ``dummy_pages``.

Anything implementing this protocol can be mounted by a host unchanged.
Today there are two implementations: a single emulated
:class:`repro.core.device.ZNSDevice` and the multi-device
:class:`repro.array.ZNSArray` (zone-chunk striping + log-structured
parity), which is what turns every single-device workload into a
multi-device scenario for free.

Units: every page quantity (``zone_pages``, ``n_pages``, write
pointers, ``host_pages``/``dummy_pages``) counts *flash pages* of
``flash.page_bytes`` bytes -- for an array these are logical pages of
the superzone address space.  ``zones`` maps dense zone indexes to
objects exposing at least ``.state`` (EMPTY/OPEN/FULL) and ``.wp``
(pages written).  DLWA is dimensionless: (host + device-generated
pages) / host pages.
"""

from __future__ import annotations

from typing import Any, Mapping, Optional, Protocol, runtime_checkable

import numpy as np

from repro.core.geometry import FlashGeometry


@runtime_checkable
class ZoneBackend(Protocol):
    """Structural type for anything that serves ZNS zone commands."""

    flash: FlashGeometry
    max_active: int

    @property
    def zone_pages(self) -> int: ...          # host-visible pages per zone

    @property
    def n_zones(self) -> int: ...

    @property
    def zones(self) -> Mapping[int, Any]: ...  # z -> obj with .state / .wp

    @property
    def dlwa(self) -> float: ...

    @property
    def host_pages(self) -> int: ...

    @property
    def dummy_pages(self) -> int: ...

    def zone_write(self, zone_id: int, n_pages: int, *, host: bool = True,
                   trace: bool = False) -> Optional[Any]:
        """Append ``n_pages`` pages at the zone's write pointer.

        Opens (and allocates) an EMPTY zone; raises ``RuntimeError`` on
        a FULL zone, overflow, or the active-zone limit.  ``host=False``
        marks device-internal (dummy) traffic.  With ``trace`` returns
        the per-page IO stream(s) for the timing model (an ``IOTrace``,
        or ``(device, IOTrace)`` pairs from an array)."""
        ...

    def zone_read(self, zone_id: int, pages: np.ndarray) -> Any:
        """Read the given page offsets (0-based within the zone);
        returns IO stream(s) as in :meth:`zone_write`.  Arrays serve
        reads of failed members degraded, via parity reconstruction."""
        ...

    def zone_finish(self, zone_id: int, *, trace: bool = False
                    ) -> Optional[Any]:
        """Transition the zone to FULL: pad partially-written storage
        elements (counted in ``dummy_pages``) and release untouched
        ones.  No-op on FULL; with ``trace`` returns the padding
        stream(s)."""
        ...

    def zone_reset(self, zone_id: int) -> None:
        """Return the zone to EMPTY.  Physical erase is deferred to
        re-allocation (paper §5); the zone's valid elements are only
        invalidated here."""
        ...


def set_stream_class(dev: Any, name: str) -> None:
    """Announce the traffic class of the next commands to ``dev``.

    Host front-ends (the LSM simulator's WAL/flush/compaction writers,
    the checkpoint manager's ckpt/log streams, the flash cache's
    admission/hit paths) call this before issuing zone commands.  A
    backend that understands stream classes (the trace recorder in
    :mod:`repro.storage.compile`, which maps classes to tenant tags)
    implements ``set_stream_class``; every other backend ignores the
    announcement -- the call is a no-op on devices without the hook, so
    front-ends stay backend-agnostic."""
    hook = getattr(dev, "set_stream_class", None)
    if hook is not None and hook is not set_stream_class:
        hook(name)


def check_backend(obj: Any) -> None:
    """Raise ``TypeError`` if ``obj`` is missing part of the surface."""
    missing = [name for name in
               ("flash", "max_active", "zone_pages", "n_zones", "zones",
                "dlwa", "host_pages", "dummy_pages", "zone_write",
                "zone_read", "zone_finish", "zone_reset")
               if not hasattr(obj, name)]
    if missing:
        raise TypeError(
            f"{type(obj).__name__} does not implement ZoneBackend "
            f"(missing: {', '.join(missing)})")
