"""The original stateful-Python ZNS device (differential oracle).

This is the pre-engine implementation of :class:`ZNSDevice`, kept verbatim
(modulo the class rename) as the reference/oracle for the pytree
:mod:`repro.core.engine` core: the differential property tests replay
random op sequences through both and require bit-identical state, and
``tools/bench.py`` uses it as the per-op-loop baseline when measuring the
scan-compiled engine's speedup.  New code should use
:class:`repro.core.device.ZNSDevice` (the engine-backed shim) instead.
"""

from __future__ import annotations

import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import zns
from repro.core.alloc_exact import (AVAIL_ALLOCATED, AVAIL_FREE,
                                    AVAIL_INVALID, AVAIL_VALID)
from repro.core.allocator import RoundRobin, allocate, eligible_mask
from repro.core.elements import (ElementKind, ElementLayout, ElementSpec,
                                 build_layout, elements_per_zone,
                                 groups_per_zone)
from repro.core.geometry import FlashGeometry, ZoneGeometry
from repro.core.device import IOTrace, ZoneInfo, ZoneState


class LegacyZNSDevice:
    """One emulated ZNS SSD, stateful-Python edition (pre-engine)."""

    def __init__(self,
                 flash: FlashGeometry,
                 zone_geom: ZoneGeometry,
                 spec: ElementSpec,
                 *,
                 max_active: int = 14,
                 alloc_impl: str = "xla",
                 wear_aware: Optional[bool] = None):
        self.flash = flash
        self.zone_geom = zone_geom
        self.spec = spec
        self.max_active = max_active
        self.alloc_impl = alloc_impl
        # the ConfZNS++ fixed baseline ignores wear (paper §6.2)
        self.wear_aware = (spec.kind is not ElementKind.FIXED
                           if wear_aware is None else wear_aware)

        self.layout: ElementLayout = build_layout(flash, spec, zone_geom)
        self.elems_per_zone = elements_per_zone(self.layout, zone_geom)
        self.zone_groups = groups_per_zone(self.layout, zone_geom)
        self.take_per_group = self.elems_per_zone // self.zone_groups
        self.zone_pages = zone_geom.zone_pages(flash)
        self.n_zones = flash.n_blocks // zone_geom.blocks_per_zone

        n = self.layout.n_elements
        self.per_group = n // self.layout.n_groups
        self.elem_wear = np.zeros(n, dtype=np.int64)
        self.elem_avail = np.full(n, AVAIL_FREE, dtype=np.int32)
        self.elem_pages = np.zeros(n, dtype=np.int64)
        self.elem_zone = np.full(n, -1, dtype=np.int32)
        self.zones: Dict[int, ZoneInfo] = {z: ZoneInfo() for z in range(self.n_zones)}
        self.rr = RoundRobin(self.layout.n_groups, self.zone_groups)

        # counters
        self.host_pages = 0
        self.dummy_pages = 0
        self.block_erases = 0
        self.alloc_calls = 0
        self.alloc_seconds = 0.0
        self.alloc_latencies_us: List[float] = []

    # ------------------------------------------------------------------ #
    # metrics
    # ------------------------------------------------------------------ #
    @property
    def dlwa(self) -> float:
        if self.host_pages == 0:
            return 1.0
        return (self.host_pages + self.dummy_pages) / self.host_pages

    @property
    def n_active(self) -> int:
        return sum(1 for z in self.zones.values() if z.state is ZoneState.OPEN)

    def block_wear(self) -> np.ndarray:
        """Per erase-block wear (all blocks of an element share wear)."""
        wear = np.zeros(self.flash.n_blocks, dtype=np.int64)
        wear[self.layout.blocks.reshape(-1)] = np.repeat(
            self.elem_wear, self.layout.blocks_per_element)
        return wear

    def pending_erases(self) -> int:
        """Block erases implied by a=3 elements not yet re-allocated."""
        inv = self.elem_avail == AVAIL_INVALID
        return int(inv.sum()) * self.layout.blocks_per_element

    # ------------------------------------------------------------------ #
    # allocation (paper §5)
    # ------------------------------------------------------------------ #
    def _wear2d(self) -> np.ndarray:
        return self.elem_wear.reshape(self.layout.n_groups, self.per_group)

    def _avail2d(self) -> np.ndarray:
        return self.elem_avail.reshape(self.layout.n_groups, self.per_group)

    def _allocate_zone(self, zone_id: int) -> None:
        info = self.zones[zone_id]
        if self.n_active >= self.max_active:
            raise RuntimeError(
                f"open/active zone limit ({self.max_active}) reached")

        t0 = time.perf_counter()
        if self.spec.kind is ElementKind.FIXED:
            sel_ids = self._allocate_fixed()  # shape (1,): one static zone
            window_groups = np.asarray(
                [self.layout.group[int(sel_ids[0])]], dtype=np.int64)
        else:
            eligible = self.rr.next_window()
            if self.wear_aware:
                sel, feasible = allocate(self._wear2d(), self._avail2d(),
                                         eligible, self.take_per_group,
                                         impl=self.alloc_impl)
            else:
                sel, feasible = self._first_available(eligible)
            if not feasible:
                # round-robin window exhausted: activate the cheapest
                # feasible groups instead (ILP with L_min = zone_groups --
                # optimal group choice = smallest sum of take-lowest wears)
                eligible = self._cheapest_groups()
                sel, feasible = allocate(self._wear2d(), self._avail2d(),
                                         eligible, self.take_per_group,
                                         impl=self.alloc_impl)
            if not feasible:
                raise RuntimeError("no free storage elements for zone "
                                   f"{zone_id} ({self.spec.name})")
            sel2d = sel.reshape(self.layout.n_groups, self.per_group)
            window_groups = np.nonzero(sel2d.any(axis=1))[0]
            sel_ids = self._arrange(sel2d, window_groups)
        self.alloc_calls += 1
        dt = time.perf_counter() - t0
        self.alloc_seconds += dt
        self.alloc_latencies_us.append(dt * 1e6)

        flat = sel_ids.reshape(-1)
        # deferred physical erase of invalid elements (paper §5 RESET)
        invalid = flat[self.elem_avail[flat] == AVAIL_INVALID]
        if invalid.size:
            self.elem_wear[invalid] += 1
            self.block_erases += invalid.size * self.layout.blocks_per_element
        self.elem_avail[flat] = AVAIL_ALLOCATED
        self.elem_pages[flat] = 0
        self.elem_zone[flat] = zone_id

        info.elements = sel_ids
        info.column_luns = self._column_luns(window_groups)
        info.state = ZoneState.OPEN
        info.wp = 0
        info.host_wp = 0

    def _cheapest_groups(self) -> np.ndarray:
        """Pick the ``zone_groups`` groups minimizing the sum of their
        ``take`` lowest available wears (exact for the balanced ILP)."""
        wear2d = self._wear2d().astype(np.float64)
        avail2d = self._avail2d()
        ok = (avail2d == AVAIL_FREE) | (avail2d == AVAIL_INVALID)
        keyed = np.where(ok, wear2d, np.inf)
        part = np.sort(keyed, axis=1)[:, : self.take_per_group]
        cost = part.sum(axis=1)  # inf when < take available
        order = np.argsort(cost, kind="stable")[: self.zone_groups]
        mask = np.zeros(self.layout.n_groups, dtype=bool)
        mask[order] = True
        return mask

    def _first_available(self, eligible: np.ndarray
                         ) -> Tuple[np.ndarray, bool]:
        """Wear-oblivious first-fit (baseline allocation policy)."""
        avail2d = self._avail2d()
        ok = ((avail2d == AVAIL_FREE) | (avail2d == AVAIL_INVALID))
        ok &= eligible[:, None]
        idx = np.argsort(~ok, axis=1, kind="stable")  # available first
        ranks = np.argsort(idx, axis=1, kind="stable")
        sel = ok & (ranks < self.take_per_group)
        feasible = bool(np.all(np.where(
            eligible, ok.sum(axis=1) >= self.take_per_group, True)))
        return sel, feasible

    def _allocate_fixed(self) -> np.ndarray:
        ok = np.isin(self.elem_avail, (AVAIL_FREE, AVAIL_INVALID))
        ids = np.nonzero(ok)[0]
        if not ids.size:
            raise RuntimeError("no free physical zone (fixed mapping)")
        if self.wear_aware:
            e = ids[np.argmin(self.elem_wear[ids])]
        else:
            e = ids[0]
        return np.asarray([e], dtype=np.int64)

    def _arrange(self, sel2d: np.ndarray, window_groups: np.ndarray
                 ) -> np.ndarray:
        """Order selected elements into zone slots (see zns.py ordering).

        Returns (n_slots,) element ids; within each group, selected
        elements are ranked by wear and assigned to segments bottom-up.
        """
        n_slots = zns.n_slots(self.spec, self.zone_geom.parallelism,
                              self.zone_geom.n_segments)
        out = np.full(n_slots, -1, dtype=np.int64)
        for c, g in enumerate(window_groups):
            cols = np.nonzero(sel2d[g])[0]
            ids = g * self.per_group + cols
            order = np.argsort(self.elem_wear[ids], kind="stable")
            for rank, eid in enumerate(ids[order]):
                slot = zns.slot_of_group_rank(
                    self.spec, self.zone_geom.parallelism,
                    self.zone_geom.n_segments, c, rank)
                out[slot] = eid
        assert (out >= 0).all(), "zone slot assignment incomplete"
        return out

    def _column_luns(self, window_groups: np.ndarray) -> np.ndarray:
        """Zone column -> LUN id, from the groups that won the allocation.

        FIXED-zone column convention: a static physical zone is pinned to
        ``parallelism`` *adjacent* LUNs starting at ``group * parallelism``
        (its erase blocks are laid out contiguously, so the winning group
        index alone determines every column).  Dynamic elements instead
        contribute ``luns_per_group`` columns per winning group.
        """
        s = self.layout.luns_per_group
        luns = []
        for g in window_groups:
            if self.spec.kind is ElementKind.FIXED:
                base = int(g) * self.zone_geom.parallelism
                luns.extend(range(base, base + self.zone_geom.parallelism))
            else:
                luns.extend(range(int(g) * s, int(g) * s + s))
        return np.asarray(luns[: self.zone_geom.parallelism], dtype=np.int64)

    # ------------------------------------------------------------------ #
    # ZNS commands
    # ------------------------------------------------------------------ #
    def zone_write(self, zone_id: int, n_pages: int,
                   *, host: bool = True, trace: bool = False
                   ) -> Optional[IOTrace]:
        info = self.zones[zone_id]
        if info.state is ZoneState.FULL:
            raise RuntimeError(f"write to FULL zone {zone_id}")
        if info.state is ZoneState.EMPTY:
            self._allocate_zone(zone_id)
        if info.wp + n_pages > self.zone_pages:
            raise RuntimeError(
                f"zone {zone_id} overflow: wp={info.wp} + {n_pages} "
                f"> {self.zone_pages}")
        start = info.wp
        info.wp += n_pages
        if host:
            info.host_wp += n_pages
            self.host_pages += n_pages
        else:
            self.dummy_pages += n_pages
        self._refresh_element_pages(info)
        if info.wp == self.zone_pages:
            self._seal(info)
        if trace:
            luns, chans = zns.page_stream(
                start, n_pages, self.zone_geom.parallelism,
                self.flash.pages_per_block, info.column_luns,
                self.flash.n_channels)
            return IOTrace(luns, chans, "write")
        return None

    def zone_read(self, zone_id: int, pages: np.ndarray) -> IOTrace:
        info = self.zones[zone_id]
        if info.column_luns is None:
            raise RuntimeError(f"read from unmapped zone {zone_id}")
        luns, chans = zns.read_stream(
            pages, self.zone_geom.parallelism, self.flash.pages_per_block,
            info.column_luns, self.flash.n_channels)
        return IOTrace(luns, chans, "read")

    def zone_finish(self, zone_id: int, *, trace: bool = False
                    ) -> Optional[IOTrace]:
        """FINISH: pad partially-written elements, release untouched ones.

        Returns the dummy-write IOTrace when ``trace`` (for interference
        simulation).
        """
        info = self.zones[zone_id]
        if info.state is ZoneState.FULL:
            return None
        if info.state is ZoneState.EMPTY:
            info.state = ZoneState.FULL  # finishing an empty zone is a no-op
            return None
        written = zns.element_pages(
            info.wp, self.spec, self.zone_geom.parallelism,
            self.zone_geom.n_segments, self.flash.pages_per_block)
        cap = self.layout.pages_per_element
        elems = info.elements
        padded_slots: List[int] = []

        for slot, eid in enumerate(elems):
            if eid < 0:
                continue
            w = int(written[slot])
            if w == 0:
                # untouched: release back to the pool (a=1 -> a=0)
                self.elem_avail[eid] = AVAIL_FREE
                self.elem_zone[eid] = -1
                self.elem_pages[eid] = 0
                info.elements[slot] = -1
            else:
                pad = cap - w
                if pad:
                    self.dummy_pages += pad
                    padded_slots.append(slot)
                self.elem_pages[eid] = cap
                self.elem_avail[eid] = AVAIL_VALID
        wp_at_finish = info.wp
        self._seal(info)
        if trace:
            luns, chans = zns.pad_stream(
                wp_at_finish, self.zone_pages, self.spec,
                self.zone_geom.parallelism, self.flash.pages_per_block,
                info.column_luns, np.asarray(padded_slots, dtype=np.int64),
                self.flash.n_channels)
            return IOTrace(luns, chans, "write")
        return None

    def zone_reset(self, zone_id: int) -> None:
        """Partial + asynchronous RESET (paper §5): invalidate metadata,
        defer physical erase to re-allocation."""
        info = self.zones[zone_id]
        if info.elements is not None:
            for eid in info.elements:
                if eid < 0:
                    continue
                if self.elem_avail[eid] == AVAIL_VALID:
                    self.elem_avail[eid] = AVAIL_INVALID
                elif self.elem_avail[eid] == AVAIL_ALLOCATED:
                    self.elem_avail[eid] = AVAIL_FREE
                self.elem_zone[eid] = -1
                self.elem_pages[eid] = 0
        self.zones[zone_id] = ZoneInfo()

    # ------------------------------------------------------------------ #
    # helpers
    # ------------------------------------------------------------------ #
    def _seal(self, info: ZoneInfo) -> None:
        info.state = ZoneState.FULL

    def _refresh_element_pages(self, info: ZoneInfo) -> None:
        written = zns.element_pages(
            info.wp, self.spec, self.zone_geom.parallelism,
            self.zone_geom.n_segments, self.flash.pages_per_block)
        elems = info.elements
        valid = elems >= 0
        self.elem_pages[elems[valid]] = written[valid]
        # first host byte into an element transitions it a=1 -> a=2? The
        # paper marks written elements valid at WRITE time (§5 READ/WRITE).
        touched = valid & (written > 0)
        self.elem_avail[elems[touched]] = AVAIL_VALID

    def warmup_alloc(self) -> None:
        """Compile the jitted allocator paths (primary window + cheapest-
        groups fallback) on copies, so timed samples exclude compilation
        (paper Table 4 methodology)."""
        if self.spec.kind is ElementKind.FIXED:
            return  # pure-numpy selection: nothing to compile
        eligible = eligible_mask(self.layout.n_groups, 0, self.zone_groups)
        allocate(self._wear2d().copy(), self._avail2d().copy(), eligible,
                 self.take_per_group, impl=self.alloc_impl)
        allocate(self._wear2d().copy(), self._avail2d().copy(),
                 self._cheapest_groups(), self.take_per_group,
                 impl=self.alloc_impl)

    def median_alloc_latency_us(self) -> float:
        if not self.alloc_latencies_us:
            return 0.0
        return float(np.median(self.alloc_latencies_us))
