"""ZoneEngine: the device state machine as a pure pytree + scan programs.

The legacy :class:`repro.core.device_legacy.LegacyZNSDevice` executes every
WRITE/FINISH/RESET as a stateful Python call with a host->JAX round-trip
per allocation.  This module inverts that ownership: **all** device state
lives in a :class:`DeviceState` pytree of ``jnp`` arrays, and every zone
command is a pure jit-compiled transition

    apply_op(state, op_row) -> (state, OpTrace)

so an encoded ``(n_ops, 4)`` int32 *op program* runs in a single
``lax.scan`` (:func:`run_program`) with no per-op host round-trips, and a
batch of programs (e.g. a DLWA occupancy sweep) runs in one vmapped scan
(:func:`run_programs`).  Semantics are bit-exact with the legacy device --
the differential property tests in ``tests/test_engine_diff.py`` replay
random op sequences through both.

Op encoding (all int32): ``[opcode, zone, n_pages, flags]`` with flags
bit0 = host write (0 -> dummy/device-internal write).  Rows may carry
extra trailing columns (the fleet layer appends a *tenant* tag in column
4, see :mod:`repro.fleet.tenants`); the engine only reads the first four.
Illegal ops (FULL write, overflow, allocation failure, active-zone limit)
never raise: they apply exactly the partial effects the legacy device
leaves behind after its ``RuntimeError`` (e.g. an overflowing write still
opens the zone) and report ``ok=0`` in the trace.

Static configuration is a frozen hashable :class:`EngineConfig`, so the
jitted transitions are compile-cached *per device geometry/spec*, not per
engine instance.  A subset of the config -- the knobs that affect
*values* but not *array shapes* -- can additionally be overridden per
call (and per batch lane) with a traced :class:`DynConfig`: effective
zone capacity in pages, the active-zone limit, the addressable zone
count, the allocator's wear-awareness, and (since the union-config
extension) the whole *element spec*: ``n_elements`` / ``per_group`` /
``take`` / ``zone_groups`` / ``slot_stride`` / ``pages_per_element``
become per-lane values on a padded static layout built at the max
geometry of a spec set (:func:`make_union_config`).  This is what lets
a single ``run_programs`` dispatch batch a *heterogeneous* fleet:
every lane shares the padded static shapes of the largest
geometry/spec while its ``DynConfig`` selects the member's effective
element granularity, geometry and allocator (see :mod:`repro.fleet`).

Units: ``n_pages``/``zone_pages``/``wp`` count flash pages; ``wear`` and
``block_erases`` count erase-block erasures; zones and elements are
indexed densely from 0.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import zns
from repro.core.alloc_exact import (AVAIL_ALLOCATED, AVAIL_FREE,
                                    AVAIL_INVALID, AVAIL_VALID)
from repro.core.elements import (ElementKind, ElementLayout, ElementSpec,
                                 build_layout, elements_per_zone,
                                 groups_per_zone, union_grid_ids)
from repro.core.geometry import FlashGeometry, ZoneGeometry

# ----------------------------------------------------------------------- #
# op + zone-state encodings
# ----------------------------------------------------------------------- #
OP_NOP, OP_ALLOC, OP_WRITE, OP_FINISH, OP_RESET, OP_READ = range(6)
F_HOST = 1  # flags bit0: host (vs dummy) write

ZONE_EMPTY, ZONE_OPEN, ZONE_FULL = 0, 1, 2

# DynConfig.alloc_policy values: TRADITIONAL keeps the legacy fixed
# element-grid mapping (a zone's whole element set is committed at ALLOC
# time); SILENT is the paper's on-the-fly allocation -- a zone is an
# arbitrary block collection sized to the write at hand, chosen as the
# cheapest per-LUN set under a wear-leveling bound and grown on demand.
POLICY_TRADITIONAL, POLICY_SILENT = 0, 1
_POLICY_NAMES = {"traditional": POLICY_TRADITIONAL,
                 "silent": POLICY_SILENT}

_BIG = 2**30  # sentinel wear for unavailable slots (matches allocator.py)


# ----------------------------------------------------------------------- #
# static config + state pytree
# ----------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class SpecValues:
    """The value-only, spec-derived subset of :class:`EngineConfig`:
    everything one element spec contributes that a lane can shadow
    through a :class:`DynConfig` on a padded union layout.  All ints;
    ``pages_per_element`` in pages, the rest count elements / groups /
    slots."""

    n_elements: int
    per_group: int
    take: int
    zone_groups: int
    slot_stride: int
    pages_per_element: int


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Hashable static description of one device geometry/element spec.

    All fields are compile-time constants (they determine array shapes
    and loop structure).  Page-unit fields: ``pages_per_block``,
    ``zone_pages``, ``pages_per_element``; block-unit:
    ``blocks_per_element``; the rest count elements / groups / zones /
    LUN columns.  The *value-only* subset (``zone_pages``,
    ``max_active``, ``n_zones``, ``wear_aware``, plus the spec-derived
    :class:`SpecValues` fields) can be shadowed per call by a
    :class:`DynConfig`.

    ``members`` lists the element specs this config can host per lane:
    a plain :func:`make_config` has exactly its own spec; a
    :func:`make_union_config` built at the max geometry of a spec set
    has one entry per member, each carrying the member's
    :class:`SpecValues`.
    """

    kind: ElementKind
    chunk: int
    wear_aware: bool
    n_elements: int
    n_groups: int
    per_group: int
    luns_per_group: int
    take: int            # elements taken per winning group
    zone_groups: int     # winning groups per zone
    slot_stride: int     # slot = rank * slot_stride + window_position
    n_slots: int
    parallelism: int
    n_segments: int
    pages_per_block: int
    zone_pages: int
    pages_per_element: int
    blocks_per_element: int
    n_zones: int
    max_active: int
    n_channels: int
    members: Tuple[Tuple[ElementSpec, SpecValues], ...] = ()

    @property
    def spec(self) -> ElementSpec:
        return ElementSpec(self.kind, self.chunk)

    def member_values(self, spec: ElementSpec) -> SpecValues:
        """The :class:`SpecValues` of a member spec (raises
        ``ValueError`` for a spec this config was not built over)."""
        for s, v in self.members:
            if s == spec:
                return v
        raise ValueError(
            f"spec {spec.name} is not a member of this config "
            f"(members: {[s.name for s, _ in self.members]})")


class DeviceState(NamedTuple):
    """The whole device as a pytree.  Element arrays carry one trailing
    *scratch* slot (index ``n_elements``) absorbing masked scatters."""

    elem_wear: jax.Array    # (n_elements + 1,) i32
    elem_avail: jax.Array   # (n_elements + 1,) i32
    elem_pages: jax.Array   # (n_elements + 1,) i32
    elem_zone: jax.Array    # (n_elements + 1,) i32
    zone_state: jax.Array   # (n_zones,) i32
    zone_wp: jax.Array      # (n_zones,) i32
    zone_host_wp: jax.Array  # (n_zones,) i32
    zone_elems: jax.Array   # (n_zones, n_slots) i32, -1 = unmapped/released
    zone_cols: jax.Array    # (n_zones, parallelism) i32 zone column -> LUN
    rr_next: jax.Array      # () i32 round-robin window start
    n_active: jax.Array     # () i32 OPEN zone count
    host_pages: jax.Array   # () i32
    dummy_pages: jax.Array  # () i32
    block_erases: jax.Array  # () i32
    alloc_calls: jax.Array  # () i32


class OpTrace(NamedTuple):
    """Per-op trace slice: enough to rebuild IO streams host-side."""

    op: jax.Array          # () i32
    zone: jax.Array        # () i32
    ok: jax.Array          # () bool
    wp_before: jax.Array   # () i32
    wp_after: jax.Array    # () i32
    host_delta: jax.Array  # () i32
    dummy_delta: jax.Array  # () i32
    erase_delta: jax.Array  # () i32
    elems: jax.Array       # (n_slots,) i32  zone slot row *after* the op
    cols: jax.Array        # (parallelism,) i32 zone column -> LUN


class DynConfig(NamedTuple):
    """Traced (per-call / per-batch-lane) overrides of the value-only
    :class:`EngineConfig` fields.

    Every field is a rank-0 array (or, under ``run_programs``, a
    ``(n_programs,)`` vector -- one value per lane):

    * ``zone_pages``  -- () i32, effective zone capacity in *pages*.
      Must be ``<= cfg.zone_pages``; a smaller value emulates a
      shorter-zone geometry (fewer segments) on the padded static
      shapes: writes seal at the effective capacity and FINISH frees the
      never-touched tail elements, so metrics match a device built with
      the smaller geometry outright (tested).  Exact for every element kind
      whose per-element page capacity is segment-count-independent
      (BLOCK / VCHUNK / HCHUNK / SUPERBLOCK); FIXED elements *are* the
      whole static zone, so FIXED lanes must keep the full capacity.
    * ``max_active``  -- () i32, open/active-zone limit
      (``<= cfg.max_active``).
    * ``n_zones``     -- () i32, addressable zones (``<= cfg.n_zones``);
      op rows are clipped into ``[0, n_zones)``.
    * ``wear_aware``  -- () bool, allocator policy: lowest-(wear, col)
      selection when true, first-fit by column when false.

    The spec axis (all () i32, defaulting to the primary member's
    bundle -- a plain config's own spec; select another union member
    with ``make_dyn(cfg, spec=...)``):

    * ``n_elements`` / ``per_group`` -- the lane's element count and
      group width.  A lane's element ``(g, c)`` lives at union id
      ``g * cfg.per_group + c``; columns ``>= per_group`` and groups
      ``>= n_elements // per_group`` of the padded grid are never
      allocated (they are selection-masked, not state-marked, because
      every lane of a batch shares one initial state).
    * ``take`` / ``zone_groups`` / ``slot_stride`` -- the lane's zone
      composition: ``zone_groups`` winning groups each contribute
      ``take`` elements, element rank ``r`` of window position ``p``
      mapping to zone slot ``r * slot_stride + p``.
    * ``pages_per_element`` -- the FINISH padding capacity per element;
      also derives the lane's per-(segment, column) slot map and its
      ``blocks_per_element = pages_per_element // cfg.pages_per_block``
      wear increment.

    All six are *values* on the padded static shapes, which is what
    lets one ``run_programs`` dispatch mix element specs per lane --
    element-exact vs a device built with the member spec outright
    (tested in ``tests/test_union_spec.py``).

    The allocation-policy axis (the paper's SilentZNS proposal):

    * ``alloc_policy`` -- () i32, :data:`POLICY_TRADITIONAL` (default)
      or :data:`POLICY_SILENT`.  Traditional commits the zone's whole
      element grid at ALLOC time (the legacy round-robin window +
      cheapest-groups fallback).  Silent sizes the claim to the op at
      hand: ALLOC claims ``ceil(n_pages / pages_per_rank)`` element
      ranks (at least one per group -- the parallelism floor stays
      ``zone_groups`` distinct groups) from the *cheapest* groups under
      the wear bound, and a WRITE that outruns the committed ranks
      claims more on the fly before it lands.  Traditional lanes are
      bit-identical to the pre-policy allocator (property-fuzzed in
      ``tests/test_silentzns_property.py``).
    * ``wear_bound`` -- () i32, silent-policy wear-leveling bound: an
      element is claimable only while its wear is within ``wear_bound``
      erases of the least-worn free element.  Defaults to unbounded;
      ignored by traditional lanes.
    """

    zone_pages: jax.Array
    max_active: jax.Array
    n_zones: jax.Array
    wear_aware: jax.Array
    n_elements: jax.Array
    per_group: jax.Array
    take: jax.Array
    zone_groups: jax.Array
    slot_stride: jax.Array
    pages_per_element: jax.Array
    alloc_policy: jax.Array
    wear_bound: jax.Array


def make_dyn(cfg: EngineConfig, *, zone_pages: Optional[int] = None,
             max_active: Optional[int] = None, n_zones: Optional[int] = None,
             wear_aware: Optional[bool] = None,
             spec: Optional[ElementSpec] = None,
             alloc_policy=None,
             wear_bound: Optional[int] = None) -> DynConfig:
    """A :class:`DynConfig` defaulting every field to ``cfg``'s value.

    ``spec`` selects a member of ``cfg.members`` (a union config's spec
    set) and fills the spec-derived fields with that member's
    :class:`SpecValues`; without it the lane runs the *primary*
    (first) member -- for a plain single-spec config that is the
    config's own spec, and for a union config it keeps dyn-less runs
    meaningful instead of mixing cross-member maxima into a spec no
    device has.

    ``alloc_policy`` is ``"traditional"`` / ``"silent"`` (or the
    :data:`POLICY_TRADITIONAL` / :data:`POLICY_SILENT` ints);
    ``wear_bound`` is the silent policy's wear-leveling bound in erases
    (``None`` = unbounded).  See :class:`DynConfig`.

    Overrides are validated eagerly: ``zone_pages`` / ``n_zones`` /
    ``max_active`` beyond the padded static config would index past the
    padded tables (silently wrong metrics), so out-of-range values
    raise ``ValueError`` here instead.  Shrinking ``zone_pages`` on a
    FIXED-kind lane is likewise rejected: FIXED elements *are* the
    whole static zone, so there is no smaller element set for the
    override to claim (see :class:`DynConfig`).  ``alloc_policy`` /
    ``wear_bound`` get the same treatment: an unknown policy or a
    negative bound would otherwise flow into the jitted selection as a
    silently-traditional lane or an always-empty claimable set.
    """
    if spec is not None:
        sv = cfg.member_values(spec)
        kind = spec.kind
    elif cfg.members:
        spec0, sv = cfg.members[0]       # primary member
        kind = spec0.kind
    else:                                # hand-built config: own statics
        sv = SpecValues(cfg.n_elements, cfg.per_group, cfg.take,
                        cfg.zone_groups, cfg.slot_stride,
                        cfg.pages_per_element)
        kind = cfg.kind
    if zone_pages is not None:
        if not 0 < zone_pages <= cfg.zone_pages:
            raise ValueError(
                f"zone_pages override {zone_pages} out of range "
                f"(static config holds {cfg.zone_pages} pages)")
        if kind is ElementKind.FIXED and zone_pages < cfg.zone_pages:
            raise ValueError(
                "FIXED elements span the whole static zone; a "
                f"zone_pages override ({zone_pages} < {cfg.zone_pages}) "
                "cannot shrink a FIXED lane")
    if n_zones is not None and not 0 < n_zones <= cfg.n_zones:
        raise ValueError(
            f"n_zones override {n_zones} out of range "
            f"(static config holds {cfg.n_zones} zones)")
    if max_active is not None and not 0 < max_active <= cfg.max_active:
        raise ValueError(
            f"max_active override {max_active} out of range "
            f"(static config allows {cfg.max_active} active zones)")
    if alloc_policy is None:
        policy = POLICY_TRADITIONAL
    elif isinstance(alloc_policy, str):
        if alloc_policy not in _POLICY_NAMES:
            raise ValueError(
                f"alloc_policy override {alloc_policy!r} unknown "
                f"(expected one of {sorted(_POLICY_NAMES)} or the "
                f"POLICY_* ints)")
        policy = _POLICY_NAMES[alloc_policy]
    else:
        policy = int(alloc_policy)
        if policy not in (POLICY_TRADITIONAL, POLICY_SILENT):
            raise ValueError(
                f"alloc_policy override {alloc_policy!r} unknown "
                f"(expected one of {sorted(_POLICY_NAMES)} or the "
                f"POLICY_* ints)")
    if policy == POLICY_SILENT and kind is ElementKind.FIXED:
        raise ValueError(
            "alloc_policy 'silent' needs a block collection to vary; "
            "FIXED elements are the whole static zone")
    if wear_bound is not None and not 0 <= wear_bound <= _BIG:
        raise ValueError(
            f"wear_bound override {wear_bound} out of range "
            f"(must be in [0, {_BIG}])")
    i32 = jnp.int32
    return DynConfig(
        zone_pages=jnp.asarray(
            cfg.zone_pages if zone_pages is None else zone_pages, i32),
        max_active=jnp.asarray(
            cfg.max_active if max_active is None else max_active, i32),
        n_zones=jnp.asarray(
            cfg.n_zones if n_zones is None else n_zones, i32),
        wear_aware=jnp.asarray(
            cfg.wear_aware if wear_aware is None else wear_aware, bool),
        n_elements=jnp.asarray(sv.n_elements, i32),
        per_group=jnp.asarray(sv.per_group, i32),
        take=jnp.asarray(sv.take, i32),
        zone_groups=jnp.asarray(sv.zone_groups, i32),
        slot_stride=jnp.asarray(sv.slot_stride, i32),
        pages_per_element=jnp.asarray(sv.pages_per_element, i32),
        alloc_policy=jnp.asarray(policy, i32),
        wear_bound=jnp.asarray(
            _BIG if wear_bound is None else wear_bound, i32),
    )


def dyn_values(cfg: EngineConfig, dyn: Optional[DynConfig] = None,
               lane: Optional[int] = None) -> dict:
    """Host-side snapshot of the *effective* value-only configuration.

    Returns the :class:`DynConfig` fields as plain Python ints/bools
    (``cfg``'s own values when ``dyn`` is ``None``); ``lane`` selects
    one row of a stacked (:func:`stack_dyn`) DynConfig.  This is the
    bridge the static checkers in :mod:`repro.check` use to read a
    dispatch's per-lane geometry without touching traced values.
    """
    if dyn is None:
        dyn = make_dyn(cfg)
    out = {}
    for name, leaf in zip(DynConfig._fields, dyn):
        v = np.asarray(leaf)
        if lane is not None and v.ndim > 0:
            v = v[lane]
        if v.ndim != 0:
            raise ValueError(
                f"dyn field {name!r} has shape {v.shape}; pass lane= "
                f"to select one row of a stacked DynConfig")
        out[name] = bool(v) if v.dtype == np.bool_ else int(v)
    return out


def stack_dyn(dyns: Sequence[DynConfig]) -> DynConfig:
    """Stack per-lane :class:`DynConfig`\\ s along a leading batch axis
    (the shape ``run_programs`` consumes for a heterogeneous batch)."""
    dyns = list(dyns)
    if not dyns:
        raise ValueError("stack_dyn needs at least one DynConfig "
                         "(an empty fleet batch has no lanes to stack)")
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *dyns)


def _slot_stride(spec: ElementSpec, parallelism: int) -> int:
    if spec.kind is ElementKind.BLOCK:
        return parallelism
    if spec.kind is ElementKind.VCHUNK:
        return parallelism // spec.chunk
    if spec.kind is ElementKind.SUPERBLOCK:
        return 1
    if spec.kind is ElementKind.HCHUNK:
        return parallelism
    if spec.kind is ElementKind.FIXED:
        return 1
    raise ValueError(spec.kind)


def make_config(flash: FlashGeometry, zone_geom: ZoneGeometry,
                spec: ElementSpec, *, max_active: int = 14,
                wear_aware: Optional[bool] = None
                ) -> Tuple[EngineConfig, ElementLayout]:
    layout = build_layout(flash, spec, zone_geom)
    elems = elements_per_zone(layout, zone_geom)
    zgroups = groups_per_zone(layout, zone_geom)
    values = SpecValues(
        n_elements=layout.n_elements,
        per_group=layout.n_elements // layout.n_groups,
        take=elems // zgroups,
        zone_groups=zgroups,
        slot_stride=_slot_stride(spec, zone_geom.parallelism),
        pages_per_element=layout.pages_per_element,
    )
    cfg = EngineConfig(
        kind=spec.kind,
        chunk=spec.chunk,
        wear_aware=(spec.kind is not ElementKind.FIXED
                    if wear_aware is None else wear_aware),
        n_elements=values.n_elements,
        n_groups=layout.n_groups,
        per_group=values.per_group,
        luns_per_group=layout.luns_per_group,
        take=values.take,
        zone_groups=values.zone_groups,
        slot_stride=values.slot_stride,
        n_slots=zns.n_slots(spec, zone_geom.parallelism,
                            zone_geom.n_segments),
        parallelism=zone_geom.parallelism,
        n_segments=zone_geom.n_segments,
        pages_per_block=flash.pages_per_block,
        zone_pages=zone_geom.zone_pages(flash),
        pages_per_element=values.pages_per_element,
        blocks_per_element=layout.blocks_per_element,
        n_zones=flash.n_blocks // zone_geom.blocks_per_zone,
        max_active=max_active,
        n_channels=flash.n_channels,
        members=((spec, values),),
    )
    return cfg, layout


def make_union_config(flash: FlashGeometry, zone_geom: ZoneGeometry,
                      specs: Sequence[ElementSpec], *, max_active: int = 14,
                      wear_aware: Optional[bool] = None
                      ) -> Tuple[EngineConfig, dict]:
    """One :class:`EngineConfig` hosting *any* of ``specs`` per lane.

    Static shapes are padded to the max geometry across the spec set
    (``n_groups`` x ``per_group`` element grid, ``n_slots`` / ``take``
    / ``zone_groups`` maxima); the per-spec :class:`SpecValues` land in
    ``cfg.members`` and are selected per lane with
    ``make_dyn(cfg, spec=...)``.  A member's element ``(g, c)`` lives
    at union id ``g * per_group_max + c``, so for specs sharing one
    group width (BLOCK / VCHUNK / SUPERBLOCK all have
    ``per_group = blocks_per_lun``) member ids are a dense prefix of
    the union grid.  FIXED is rejected: its element *is* the static
    zone, which leaves no spec axis to vary.

    Returns ``(cfg, layouts)`` with one :class:`ElementLayout` per
    member (host-side wear/block bookkeeping).
    """
    specs = tuple(specs)
    if not specs:
        raise ValueError("make_union_config needs at least one spec")
    if len(set(specs)) != len(specs):
        raise ValueError(f"duplicate specs in union: "
                         f"{[s.name for s in specs]}")
    if any(s.kind is ElementKind.FIXED for s in specs):
        raise ValueError("FIXED elements span the whole static zone "
                         "and cannot join a per-lane spec union")
    built = [make_config(flash, zone_geom, s, max_active=max_active,
                         wear_aware=wear_aware) for s in specs]
    cfgs = [c for c, _ in built]
    layouts = {s: lay for s, (_, lay) in zip(specs, built)}
    n_groups = max(c.n_groups for c in cfgs)
    per_group = max(c.per_group for c in cfgs)
    cfg = dataclasses.replace(
        cfgs[0],
        # the padded element grid must stay rectangular for the
        # (n_groups, per_group) allocator reshape, so the static
        # element count is the full grid, not the largest member's
        n_elements=n_groups * per_group,
        n_groups=n_groups,
        per_group=per_group,
        luns_per_group=max(c.luns_per_group for c in cfgs),
        take=max(c.take for c in cfgs),
        zone_groups=max(c.zone_groups for c in cfgs),
        slot_stride=max(c.slot_stride for c in cfgs),
        n_slots=max(c.n_slots for c in cfgs),
        pages_per_element=max(c.pages_per_element for c in cfgs),
        blocks_per_element=max(c.blocks_per_element for c in cfgs),
        members=tuple((s, c.member_values(s))
                      for s, c in zip(specs, cfgs)),
    )
    return cfg, layouts


def init_state(cfg: EngineConfig) -> DeviceState:
    n = cfg.n_elements + 1  # + scratch slot
    i32 = jnp.int32
    return DeviceState(
        elem_wear=jnp.zeros(n, i32),
        elem_avail=jnp.full(n, AVAIL_FREE, i32),
        elem_pages=jnp.zeros(n, i32),
        elem_zone=jnp.full(n, -1, i32),
        zone_state=jnp.full(cfg.n_zones, ZONE_EMPTY, i32),
        zone_wp=jnp.zeros(cfg.n_zones, i32),
        zone_host_wp=jnp.zeros(cfg.n_zones, i32),
        zone_elems=jnp.full((cfg.n_zones, cfg.n_slots), -1, i32),
        zone_cols=jnp.zeros((cfg.n_zones, cfg.parallelism), i32),
        rr_next=jnp.zeros((), i32),
        n_active=jnp.zeros((), i32),
        host_pages=jnp.zeros((), i32),
        dummy_pages=jnp.zeros((), i32),
        block_erases=jnp.zeros((), i32),
        alloc_calls=jnp.zeros((), i32),
    )


# ----------------------------------------------------------------------- #
# pure selection helpers (bit-exact with allocator.py / device_legacy.py)
# ----------------------------------------------------------------------- #
def _rr_mask(cfg: EngineConfig, dyn: DynConfig, start: jax.Array
             ) -> jax.Array:
    """Round-robin eligibility window: ``dyn.zone_groups`` consecutive
    groups (mod the lane's *effective* group count) starting at
    ``start``.  Window positions past ``dyn.zone_groups`` scatter out
    of bounds and are dropped, so a union lane with fewer groups than
    the padded static ``cfg.zone_groups`` gets exactly its own
    window."""
    ng = dyn.n_elements // dyn.per_group      # effective group count
    pos = jnp.arange(cfg.zone_groups, dtype=jnp.int32)
    idx = jnp.where(pos < dyn.zone_groups, (start + pos) % ng,
                    cfg.n_groups)
    return jnp.zeros(cfg.n_groups, bool).at[idx].set(True)


def _take_lowest(cfg: EngineConfig, dyn: DynConfig, w2, a2, eligible,
                 by_wear, take_eff):
    """Per-eligible-group ``take`` lowest-(wear, col) available elements.

    One ``top_k`` over the unique composite key ``wear * per_group + col``
    reproduces the legacy stable argsort selection *and* its arrange
    order (within a group, selected elements ranked by wear then column)
    without full sorts -- the scan's hot path.  ``by_wear`` may be a
    traced () bool (the :class:`DynConfig` allocator axis); false is the
    wear-oblivious first-fit (selection key = column alone).
    ``take_eff`` (traced, ``<= dyn.take``) is how many of the selected
    elements the zone will actually claim (fewer under an effective-
    capacity override): feasibility only requires that many.  Columns
    past ``dyn.per_group`` are union-grid padding, never free.

    Returns (cols (n_groups, take) ordered ascending by (wear, col),
    feasible).  Valid only where ``eligible``; overflow-safe while wear
    stays below ``2**30 / per_group`` (far beyond any simulated churn).
    """
    free = (a2 == AVAIL_FREE) | (a2 == AVAIL_INVALID)
    col = jnp.arange(cfg.per_group, dtype=jnp.int32)[None, :]
    free = free & eligible[:, None] & (col < dyn.per_group)
    composite = w2 * cfg.per_group + col
    key = jnp.where(free, jnp.where(by_wear, composite, col), _BIG)
    negv, cols = jax.lax.top_k(-key, cfg.take)
    # the take_eff-th smallest key must be a real element
    kth = jnp.take(negv, take_eff - 1, axis=1)
    got_all = (-kth) < _BIG
    feasible = jnp.all(got_all | ~eligible)
    cols = cols.astype(jnp.int32)
    # whatever key selected the elements, the legacy ``_arrange`` ranks
    # them by (wear, col) when assigning zone slots.  On the wear-aware
    # path the top_k output is already in that order, so the reorder is
    # an identity there (and lets ``by_wear`` stay traced).  Non-free
    # filler (top_k rows with fewer than ``take`` free elements) must
    # keep sorting last, or an in-use element could be reordered into
    # the claimed take_eff prefix and stolen from its zone.
    sel_free = jnp.take_along_axis(free, cols, axis=1)
    sel_key = jnp.where(
        sel_free,
        jnp.take_along_axis(w2, cols, axis=1) * cfg.per_group + cols,
        _BIG)
    order = jnp.argsort(sel_key, axis=1, stable=True)
    cols = jnp.take_along_axis(cols, order, axis=1)
    return cols, feasible


def _cheapest_groups(cfg: EngineConfig, dyn: DynConfig, w2, a2, take_eff
                     ) -> jax.Array:
    ng = dyn.n_elements // dyn.per_group
    grow = jnp.arange(cfg.n_groups, dtype=jnp.int32)[:, None]
    col = jnp.arange(cfg.per_group, dtype=jnp.int32)[None, :]
    ok = (a2 == AVAIL_FREE) | (a2 == AVAIL_INVALID)
    ok = ok & (grow < ng) & (col < dyn.per_group)  # union-grid padding
    keyed = jnp.where(ok, w2.astype(jnp.float32), jnp.inf)
    part = -jax.lax.top_k(-keyed, cfg.take)[0]  # take smallest per row
    rank = jnp.arange(cfg.take, dtype=jnp.int32)[None, :]
    cost = jnp.where(rank < take_eff, part, 0.0).sum(axis=1)
    order = jnp.argsort(cost, stable=True)[: cfg.zone_groups]
    # cheapest dyn.zone_groups groups only (padded window tail unused)
    picked = jnp.arange(cfg.zone_groups, dtype=jnp.int32) < dyn.zone_groups
    return jnp.zeros(cfg.n_groups, bool).at[order].set(picked)


def _wear_bounded_avail(cfg: EngineConfig, dyn: DynConfig, w2, a2
                        ) -> jax.Array:
    """The silent policy's wear-leveling bound as an availability mask:
    elements worn more than ``dyn.wear_bound`` erases past the
    least-worn free element are presented busy, so neither the group
    selection nor the per-group claim can pick them.  Subtraction (not
    ``min_wear + bound``) keeps the unbounded default (``_BIG``) free of
    i32 overflow."""
    ng = dyn.n_elements // dyn.per_group
    grow = jnp.arange(cfg.n_groups, dtype=jnp.int32)[:, None]
    col = jnp.arange(cfg.per_group, dtype=jnp.int32)[None, :]
    free = (a2 == AVAIL_FREE) | (a2 == AVAIL_INVALID)
    free = free & (grow < ng) & (col < dyn.per_group)
    min_wear = jnp.min(jnp.where(free, w2, _BIG))
    in_bound = (w2 - min_wear) <= dyn.wear_bound
    return jnp.where(in_bound, a2, AVAIL_VALID)


def _where_state(pred, new: DeviceState, old: DeviceState) -> DeviceState:
    return jax.tree_util.tree_map(
        lambda a, b: jnp.where(pred, a, b), new, old)


# ----------------------------------------------------------------------- #
# transitions
# ----------------------------------------------------------------------- #
def _alloc(cfg: EngineConfig, dyn: DynConfig, state: DeviceState,
           zone: jax.Array, hint: jax.Array
           ) -> Tuple[DeviceState, jax.Array]:
    """ALLOC a zone's elements (legacy ``_allocate_zone``).  Caller guards
    on the zone being EMPTY; this applies the selection + deferred erase.

    ``hint`` is the triggering op's ``n_pages`` (0 for a bare ALLOC with
    no size).  Traditional lanes ignore it; a silent lane commits only
    ``ceil(hint / pages_per_rank)`` element ranks (the whole grid when
    the hint is 0), one element per winning group per rank, from the
    cheapest wear-bounded groups -- :func:`_grow_silent` claims the rest
    on demand when later writes outrun the commitment."""
    n = cfg.n_elements
    limit_ok = state.n_active < dyn.max_active

    if cfg.kind is ElementKind.FIXED:
        wear = state.elem_wear[:n]
        avail = state.elem_avail[:n]
        free = (avail == AVAIL_FREE) | (avail == AVAIL_INVALID)
        key = jnp.where(
            free,
            jnp.where(dyn.wear_aware, wear,
                      jnp.arange(n, dtype=jnp.int32)),
            _BIG)
        e = jnp.argmin(key).astype(jnp.int32)
        feasible = free.any()
        band = e % cfg.n_groups
        cols_row = (band * cfg.parallelism
                    + jnp.arange(cfg.parallelism, dtype=jnp.int32))
        elems_row = jnp.full((cfg.n_slots,), e, jnp.int32)
        rr_next = state.rr_next
    else:
        pg = cfg.per_group
        w2 = state.elem_wear[:n].reshape(cfg.n_groups, pg)
        a2 = state.elem_avail[:n].reshape(cfg.n_groups, pg)
        # effective-capacity override (DynConfig): a shrunk lane claims
        # only the slots its capacity can reach, so its element set --
        # and therefore wear / deferred-erase accounting -- is exactly
        # the one a device built with the smaller geometry would pick
        # (slot layouts are uniform across groups for whole-segment
        # capacities, so the per-group claim count is a single scalar)
        n_slots_eff = dyn.zone_pages // dyn.pages_per_element
        take_eff = jnp.clip(
            n_slots_eff // jnp.maximum(dyn.slot_stride, 1),
            1, dyn.take).astype(jnp.int32)

        def traditional(_):
            elig1 = _rr_mask(cfg, dyn, state.rr_next)
            cols1, f1 = _take_lowest(cfg, dyn, w2, a2, elig1,
                                     dyn.wear_aware, take_eff)

            # round-robin window exhausted: cheapest feasible groups
            # instead (the legacy fallback always uses the wear-aware
            # selection); lazily computed -- the common path pays for
            # one top_k only
            def fallback(_):
                elig2 = _cheapest_groups(cfg, dyn, w2, a2, take_eff)
                cols2, f2 = _take_lowest(cfg, dyn, w2, a2, elig2, True,
                                         take_eff)
                return cols2, f2, elig2

            cols1, f2, elig1 = jax.lax.cond(
                f1, lambda _: (cols1, f1, elig1), fallback, None)
            # legacy advances the window even when the allocation then
            # fails
            ng = dyn.n_elements // dyn.per_group
            rr = (state.rr_next + dyn.zone_groups) % ng
            return cols1, f1 | f2, elig1, rr, dyn.take

        def silent(_):
            # on-the-fly commitment: only the ranks the size hint needs
            # (>= 1, keeping the parallelism floor of one element per
            # winning group), from the cheapest wear-bounded groups;
            # the round-robin window is not consumed
            per_rank = dyn.pages_per_element * dyn.zone_groups
            ranks_hint = -(-hint // jnp.maximum(per_rank, 1))
            take_s = jnp.clip(jnp.where(hint > 0, ranks_hint, take_eff),
                              1, take_eff).astype(jnp.int32)
            a2b = _wear_bounded_avail(cfg, dyn, w2, a2)
            elig_s = _cheapest_groups(cfg, dyn, w2, a2b, take_s)
            cols_s, f_s = _take_lowest(cfg, dyn, w2, a2b, elig_s, True,
                                       take_s)
            return cols_s, f_s, elig_s, state.rr_next, take_s

        cols, feasible, elig, rr_next, rank_lim = jax.lax.cond(
            dyn.alloc_policy == POLICY_SILENT, silent, traditional, None)
        # every eligible group contributes exactly ``take`` elements, so
        # the winning groups are the eligible window itself (ascending)
        win = jnp.nonzero(elig, size=cfg.zone_groups,
                          fill_value=0)[0].astype(jnp.int32)
        eids = (win[:, None] * pg + cols[win]).astype(jnp.int32)
        ranks = jnp.arange(cfg.take, dtype=jnp.int32)[None, :]
        cpos = jnp.arange(cfg.zone_groups, dtype=jnp.int32)[:, None]
        # window positions past the lane's zone_groups are union
        # padding: their slots divert to the scratch column and their
        # elements to the scratch element.  The rank mask is an
        # identity for traditional lanes (rank_lim = dyn.take: a slot
        # below n_slots_eff already implies rank < take because
        # zone_groups <= slot_stride for every gridded kind) and is
        # what sizes a silent lane's partial commitment.
        valid = cpos < dyn.zone_groups
        raw_slots = ranks * dyn.slot_stride + cpos
        slots = jnp.where(valid, raw_slots, cfg.n_slots).reshape(-1)
        claimed = (valid & (raw_slots < n_slots_eff)
                   & (ranks < rank_lim)).reshape(-1)
        elems_row = jnp.full(cfg.n_slots + 1, -1, jnp.int32).at[
            slots].set(jnp.where(claimed, eids.reshape(-1),
                                 -1))[: cfg.n_slots]
        # zone column c -> LUN: window position c // luns_per_group
        # owns the group band, c % luns_per_group walks its LUNs
        lpg = cfg.parallelism // dyn.zone_groups
        c = jnp.arange(cfg.parallelism, dtype=jnp.int32)
        cols_row = win[c // lpg] * lpg + c % lpg

    if cfg.kind is ElementKind.FIXED:
        flat = elems_row.reshape(-1)
        claimed_flat = jnp.ones_like(flat, dtype=bool)
    else:
        # unclaimed selections scatter into the scratch slot
        flat = jnp.where(claimed, eids.reshape(-1), n)
        claimed_flat = claimed
    ok = limit_ok & feasible
    # deferred physical erase of invalid elements (paper §5 RESET)
    inv = claimed_flat & (state.elem_avail[flat] == AVAIL_INVALID)
    erase_delta = (inv.sum().astype(jnp.int32)
                   * (dyn.pages_per_element // cfg.pages_per_block))
    new = state._replace(
        elem_wear=state.elem_wear.at[flat].add(inv.astype(jnp.int32)),
        elem_avail=state.elem_avail.at[flat].set(AVAIL_ALLOCATED),
        elem_pages=state.elem_pages.at[flat].set(0),
        elem_zone=state.elem_zone.at[flat].set(zone),
        zone_state=state.zone_state.at[zone].set(ZONE_OPEN),
        zone_wp=state.zone_wp.at[zone].set(0),
        zone_host_wp=state.zone_host_wp.at[zone].set(0),
        zone_elems=state.zone_elems.at[zone].set(elems_row),
        zone_cols=state.zone_cols.at[zone].set(cols_row),
        n_active=state.n_active + 1,
        block_erases=state.block_erases + erase_delta,
        alloc_calls=state.alloc_calls + 1,
    )
    state = _where_state(ok, new, state)
    # rr advance survives an infeasible attempt (but not a limit refusal,
    # where the legacy device raises before touching the window)
    state = state._replace(
        rr_next=jnp.where(limit_ok, rr_next, state.rr_next))
    return state, ok


def _written_per_slot(cfg: EngineConfig, dyn: DynConfig, wp: jax.Array
                      ) -> jax.Array:
    """Pages written per element slot at zone pointer ``wp``, computed
    from the lane's *dynamic* spec values: every (segment, column)
    erase-block cell scatter-adds its page count into the slot

        (segment // seg_span) * slot_stride + column // luns_per_group

    which reproduces :func:`repro.core.zns.element_pages_jnp` for every
    element kind (slot-map property-tested in
    ``tests/test_union_spec.py``) while keeping the spec a value, not a
    shape."""
    blk = zns.pages_per_block_jnp(wp, cfg.parallelism, cfg.n_segments,
                                  cfg.pages_per_block)
    lpg = cfg.parallelism // dyn.zone_groups       # LUN columns / element
    seg_span = dyn.pages_per_element // (lpg * cfg.pages_per_block)
    slot = zns.slot_map_jnp(dyn.slot_stride, lpg, seg_span,
                            cfg.parallelism, cfg.n_segments)
    return jnp.zeros(cfg.n_slots, jnp.int32).at[slot.reshape(-1)].add(
        blk.reshape(-1))


def _grow_silent(cfg: EngineConfig, dyn: DynConfig, state: DeviceState,
                 zone, wp1, pred) -> Tuple[DeviceState, jax.Array]:
    """Silent-policy on-demand commitment: when a write will advance the
    zone pointer past the element ranks claimed so far, claim the
    missing ranks (cheapest wear-bounded elements of the zone's own
    winning groups, keeping the slot grid rectangular) before the write
    lands.  A no-op (ok) for traditional lanes, FULL zones, and writes
    the commitment already covers."""
    if cfg.kind is ElementKind.FIXED:
        return state, jnp.asarray(True)
    n_slots_eff = dyn.zone_pages // dyn.pages_per_element
    take_eff = jnp.clip(
        n_slots_eff // jnp.maximum(dyn.slot_stride, 1),
        1, dyn.take).astype(jnp.int32)
    per_rank = dyn.pages_per_element * dyn.zone_groups
    need = jnp.clip(-(-wp1 // jnp.maximum(per_rank, 1)),
                    1, take_eff).astype(jnp.int32)
    # committed ranks: the claim grid is rectangular (every rank spans
    # all zone_groups window positions), so the row's live-slot count
    # divides exactly
    have = ((state.zone_elems[zone] >= 0).sum()
            // jnp.maximum(dyn.zone_groups, 1)).astype(jnp.int32)
    grow = (pred & (dyn.alloc_policy == POLICY_SILENT)
            & (need > have))

    def grow_fn(s):
        n = cfg.n_elements
        pg = cfg.per_group
        w2 = s.elem_wear[:n].reshape(cfg.n_groups, pg)
        a2 = s.elem_avail[:n].reshape(cfg.n_groups, pg)
        a2b = _wear_bounded_avail(cfg, dyn, w2, a2)
        # the zone's winning groups, recovered from its column map
        # (ascending, exactly as _alloc laid them out)
        lpg = cfg.parallelism // dyn.zone_groups
        pos = jnp.arange(cfg.zone_groups, dtype=jnp.int32)
        win_g = s.zone_cols[zone][
            jnp.clip(pos * lpg, 0, cfg.parallelism - 1)] // lpg
        gidx = jnp.where(pos < dyn.zone_groups, win_g, cfg.n_groups)
        elig = jnp.zeros(cfg.n_groups, bool).at[gidx].set(True)
        k = need - have
        cols, fg = _take_lowest(cfg, dyn, w2, a2b, elig, True, k)
        win = jnp.nonzero(elig, size=cfg.zone_groups,
                          fill_value=0)[0].astype(jnp.int32)
        eids = (win[:, None] * pg + cols[win]).astype(jnp.int32)
        ranks = jnp.arange(cfg.take, dtype=jnp.int32)[None, :]
        cpos = jnp.arange(cfg.zone_groups, dtype=jnp.int32)[:, None]
        raw_slots = (have + ranks) * dyn.slot_stride + cpos
        claimed = ((cpos < dyn.zone_groups) & (ranks < k)
                   & (raw_slots < n_slots_eff))
        slots = jnp.where(claimed, raw_slots, cfg.n_slots).reshape(-1)
        claimed = claimed.reshape(-1)
        flat = jnp.where(claimed, eids.reshape(-1), n)
        row = jnp.append(s.zone_elems[zone], jnp.int32(-1))
        elems_row = row.at[slots].set(
            jnp.where(claimed, eids.reshape(-1), -1))[: cfg.n_slots]
        # deferred physical erase, exactly as at ALLOC time
        inv = claimed & (s.elem_avail[flat] == AVAIL_INVALID)
        erase_delta = (inv.sum().astype(jnp.int32)
                       * (dyn.pages_per_element // cfg.pages_per_block))
        new = s._replace(
            elem_wear=s.elem_wear.at[flat].add(inv.astype(jnp.int32)),
            elem_avail=s.elem_avail.at[flat].set(AVAIL_ALLOCATED),
            elem_pages=s.elem_pages.at[flat].set(0),
            elem_zone=s.elem_zone.at[flat].set(zone),
            zone_elems=s.zone_elems.at[zone].set(elems_row),
            block_erases=s.block_erases + erase_delta,
            alloc_calls=s.alloc_calls + 1,
        )
        return _where_state(fg, new, s), fg

    return jax.lax.cond(
        grow, grow_fn, lambda s: (s, jnp.asarray(True)), state)


def _write(cfg: EngineConfig, dyn: DynConfig, state: DeviceState,
           zone, n_pages, host) -> Tuple[DeviceState, jax.Array]:
    zst0 = state.zone_state[zone]
    state, aok = jax.lax.cond(
        zst0 == ZONE_EMPTY,
        lambda s: _alloc(cfg, dyn, s, zone, n_pages),
        lambda s: (s, jnp.asarray(True)),
        state)
    wp0 = state.zone_wp[zone]
    wp1 = wp0 + n_pages
    fits = wp1 <= dyn.zone_pages
    state, gok = _grow_silent(cfg, dyn, state, zone, wp1,
                              (zst0 != ZONE_FULL) & aok & fits)
    ok = (zst0 != ZONE_FULL) & aok & fits & gok

    written = _written_per_slot(cfg, dyn, wp1).astype(jnp.int32)
    elems = state.zone_elems[zone]
    valid = elems >= 0
    idx = jnp.where(valid, elems, cfg.n_elements)
    touched = valid & (written > 0)
    seal = wp1 == dyn.zone_pages
    new = state._replace(
        elem_pages=state.elem_pages.at[idx].set(written),
        elem_avail=state.elem_avail.at[
            jnp.where(touched, elems, cfg.n_elements)].set(AVAIL_VALID),
        zone_wp=state.zone_wp.at[zone].set(wp1),
        zone_host_wp=state.zone_host_wp.at[zone].add(
            jnp.where(host, n_pages, 0)),
        zone_state=state.zone_state.at[zone].set(
            jnp.where(seal, ZONE_FULL, ZONE_OPEN)),
        n_active=state.n_active - seal.astype(jnp.int32),
        host_pages=state.host_pages + jnp.where(host, n_pages, 0),
        dummy_pages=state.dummy_pages + jnp.where(host, 0, n_pages),
    )
    return _where_state(ok, new, state), ok


def _finish(cfg: EngineConfig, dyn: DynConfig, state: DeviceState, zone
            ) -> Tuple[DeviceState, jax.Array]:
    zst0 = state.zone_state[zone]
    is_open = zst0 == ZONE_OPEN
    wp = state.zone_wp[zone]
    written = _written_per_slot(cfg, dyn, wp).astype(jnp.int32)
    elems = state.zone_elems[zone]
    valid = elems >= 0
    untouched = valid & (written == 0) & is_open
    touched = valid & (written > 0) & is_open
    cap = dyn.pages_per_element
    pad = jnp.sum(jnp.where(touched, cap - written, 0)).astype(jnp.int32)
    n = cfg.n_elements
    u_idx = jnp.where(untouched, elems, n)
    t_idx = jnp.where(touched, elems, n)
    avail = state.elem_avail.at[u_idx].set(AVAIL_FREE)
    avail = avail.at[t_idx].set(AVAIL_VALID)
    pages = state.elem_pages.at[u_idx].set(0)
    pages = pages.at[t_idx].set(cap)
    new = state._replace(
        elem_avail=avail,
        elem_pages=pages,
        elem_zone=state.elem_zone.at[u_idx].set(-1),
        zone_elems=state.zone_elems.at[zone].set(
            jnp.where(untouched, -1, elems)),
        zone_state=state.zone_state.at[zone].set(ZONE_FULL),
        dummy_pages=state.dummy_pages + pad,
        n_active=state.n_active - is_open.astype(jnp.int32),
    )
    # FULL is a no-op; EMPTY just seals (untouched/touched masks are empty)
    return _where_state(zst0 != ZONE_FULL, new, state), jnp.asarray(True)


def _reset(cfg: EngineConfig, state: DeviceState, zone
           ) -> Tuple[DeviceState, jax.Array]:
    zst0 = state.zone_state[zone]
    elems = state.zone_elems[zone]
    valid = elems >= 0
    idx = jnp.where(valid, elems, cfg.n_elements)
    cur = state.elem_avail[idx]
    nxt = jnp.where(cur == AVAIL_VALID, AVAIL_INVALID,
                    jnp.where(cur == AVAIL_ALLOCATED, AVAIL_FREE, cur))
    new = state._replace(
        elem_avail=state.elem_avail.at[idx].set(nxt),
        elem_zone=state.elem_zone.at[idx].set(-1),
        elem_pages=state.elem_pages.at[idx].set(0),
        zone_state=state.zone_state.at[zone].set(ZONE_EMPTY),
        zone_wp=state.zone_wp.at[zone].set(0),
        zone_host_wp=state.zone_host_wp.at[zone].set(0),
        zone_elems=state.zone_elems.at[zone].set(
            jnp.full(cfg.n_slots, -1, jnp.int32)),
        zone_cols=state.zone_cols.at[zone].set(
            jnp.zeros(cfg.parallelism, jnp.int32)),
        n_active=state.n_active - (zst0 == ZONE_OPEN).astype(jnp.int32),
    )
    return new, jnp.asarray(True)


# ----------------------------------------------------------------------- #
# op dispatch + program executor
# ----------------------------------------------------------------------- #
def _apply_op_impl(cfg: EngineConfig, dyn: DynConfig, state: DeviceState,
                   row: jax.Array) -> Tuple[DeviceState, OpTrace]:
    op = row[0]
    zone = jnp.clip(row[1], 0, dyn.n_zones - 1)
    n_pages = row[2]
    host = (row[3] & F_HOST) == F_HOST

    def nop(s):
        return s, jnp.asarray(True)

    def alloc_branch(s):
        zst0 = s.zone_state[zone]
        # row[2] rides along as the silent policy's size hint
        s2, ok = _alloc(cfg, dyn, s, zone, n_pages)
        # no-op (and fine) when the zone is already mapped
        return (_where_state(zst0 == ZONE_EMPTY, s2, s),
                jnp.where(zst0 == ZONE_EMPTY, ok, True))

    state2, ok = jax.lax.switch(
        jnp.clip(op, 0, OP_READ),
        [nop,
         alloc_branch,
         lambda s: _write(cfg, dyn, s, zone, n_pages, host),
         lambda s: _finish(cfg, dyn, s, zone),
         lambda s: _reset(cfg, s, zone),
         nop],  # OP_READ: reads never change device state
        state)
    trace = OpTrace(
        op=op, zone=zone, ok=ok,
        wp_before=state.zone_wp[zone],
        wp_after=state2.zone_wp[zone],
        host_delta=state2.host_pages - state.host_pages,
        dummy_delta=state2.dummy_pages - state.dummy_pages,
        erase_delta=state2.block_erases - state.block_erases,
        elems=state2.zone_elems[zone],
        cols=state2.zone_cols[zone],
    )
    return state2, trace


@functools.partial(jax.jit, static_argnums=(0,))
def apply_op(cfg: EngineConfig, state: DeviceState, row: jax.Array,
             dyn: Optional[DynConfig] = None
             ) -> Tuple[DeviceState, OpTrace]:
    """One zone command as a pure jitted transition.  ``dyn`` (optional)
    shadows the value-only config fields -- see :class:`DynConfig`."""
    if dyn is None:
        dyn = make_dyn(cfg)
    return _apply_op_impl(cfg, dyn, state, row)


def _scan_program(cfg: EngineConfig, dyn: DynConfig, state: DeviceState,
                  program: jax.Array, obs):
    """The shared scan body of :func:`run_program` / :func:`run_programs`.

    With ``obs`` (a static ``repro.obs.recorder.ObsConfig``) the scan
    carry additionally threads a telemetry accumulator and the return
    grows a third element.  The recorder only *reads* the device state,
    so the ``DeviceState`` / ``OpTrace`` outputs are bit-identical with
    and without it (property-tested in ``tests/test_obs.py``)."""
    if obs is None:
        return jax.lax.scan(
            lambda s, r: _apply_op_impl(cfg, dyn, s, r), state, program)
    # imported lazily: repro.obs depends on repro.core, not vice versa
    from repro.obs import recorder

    n_ops = int(program.shape[0])

    def step(carry, row):
        s, tel = carry
        s2, trace = _apply_op_impl(cfg, dyn, s, row)
        tel2 = recorder.telemetry_update(obs, tel, s, s2, trace, row,
                                         max(n_ops, 1))
        return (s2, tel2), trace

    (state2, tel), trace = jax.lax.scan(
        step, (state, recorder.telemetry_init(obs)), program)
    return state2, trace, tel


@functools.partial(jax.jit, static_argnums=(0,),
                   static_argnames=("obs",))
def run_program(cfg: EngineConfig, state: DeviceState, program: jax.Array,
                dyn: Optional[DynConfig] = None, *, obs=None
                ) -> Tuple[DeviceState, OpTrace]:
    """Execute an ``(n_ops, >=4)`` int32 program in a single ``lax.scan``.
    Only the first four row columns are interpreted; extra columns (e.g.
    the fleet layer's tenant tag) ride along untouched.  ``obs`` (a
    static ``repro.obs.recorder.ObsConfig``) opts into in-scan
    telemetry: the return becomes ``(state, trace, telemetry)``."""
    if dyn is None:
        dyn = make_dyn(cfg)
    return _scan_program(cfg, dyn, state, program, obs)


@functools.partial(jax.jit, static_argnums=(0,),
                   static_argnames=("obs",))
def run_programs(cfg: EngineConfig, state: DeviceState, programs: jax.Array,
                 dyn: Optional[DynConfig] = None, *, obs=None
                 ) -> Tuple[DeviceState, OpTrace]:
    """Batch :func:`run_program` over a leading program axis (shared
    initial state) -- a whole parameter sweep in one compiled dispatch.

    ``dyn`` (optional) must hold ``(n_programs,)``-shaped leaves (see
    :func:`stack_dyn`): lane ``k`` runs ``programs[k]`` under
    ``dyn[k]``, which is how a *heterogeneous* fleet (mixed effective
    zone geometries / allocator policies, padded to the largest static
    shape) executes in one dispatch.  ``obs`` opts into per-lane
    telemetry stacks (``(n_programs, n_buckets, ...)`` leaves): the
    return becomes ``(states, traces, telemetry)``.

    Uses ``lax.map`` rather than ``jax.vmap``: the transitions are
    scatter/gather-heavy and batching them materializes every branch of
    the per-op ``switch`` for every lane, which is several times slower
    on CPU than mapping the already-tight single-device scan."""
    if dyn is None:
        return jax.lax.map(
            lambda p: _scan_program(cfg, make_dyn(cfg), state, p, obs),
            programs)
    return jax.lax.map(
        lambda pd: _scan_program(cfg, pd[1], state, pd[0], obs),
        (programs, dyn))


# ----------------------------------------------------------------------- #
# host-facing wrapper
# ----------------------------------------------------------------------- #
def encode_program(ops, width: int = 4) -> np.ndarray:
    """``[(opcode, zone, n_pages, flags[, ...]), ...]`` -> (n_ops, width)
    int32.  ``width > 4`` leaves room for engine-opaque columns (the
    fleet layer stores a tenant tag in column 4); short rows are
    zero-padded."""
    out = np.zeros((len(ops), width), dtype=np.int32)
    for i, row in enumerate(ops):
        out[i, : len(row)] = row
    return out


class ZoneEngine:
    """Pure functional core of one emulated ZNS device.

    Holds the static :class:`EngineConfig` + :class:`ElementLayout` and
    wraps the module-level jitted transitions; state is always passed
    explicitly (the engine itself is stateless and shareable).

    ``spec`` may be a single :class:`ElementSpec` or a *sequence* of
    them: a sequence builds the padded union config
    (:func:`make_union_config`), whose lanes each pick a member spec
    through ``self.dyn(spec=...)`` -- one batched ``run_programs``
    dispatch then mixes element specs per lane.  ``self.spec`` /
    ``self.layout`` refer to the first (primary) member.
    """

    def __init__(self, flash: FlashGeometry, zone_geom: ZoneGeometry,
                 spec, *, max_active: int = 14,
                 wear_aware: Optional[bool] = None):
        self.flash = flash
        self.zone_geom = zone_geom
        if isinstance(spec, ElementSpec):
            self.cfg, self.layout = make_config(
                flash, zone_geom, spec, max_active=max_active,
                wear_aware=wear_aware)
            self.layouts = {spec: self.layout}
        else:
            self.cfg, self.layouts = make_union_config(
                flash, zone_geom, spec, max_active=max_active,
                wear_aware=wear_aware)
            self.layout = self.layouts[tuple(spec)[0]]
            spec = tuple(spec)[0]
        self.spec = spec

    # -- state ---------------------------------------------------------- #
    def init_state(self) -> DeviceState:
        return init_state(self.cfg)

    @property
    def members(self) -> dict:
        """Member spec -> :class:`SpecValues` (one entry for a plain
        engine, one per union member otherwise)."""
        return dict(self.cfg.members)

    def dyn(self, **overrides) -> DynConfig:
        """Per-call :class:`DynConfig` (``zone_pages`` / ``max_active`` /
        ``n_zones`` / ``wear_aware`` / ``spec`` / ``alloc_policy`` /
        ``wear_bound`` keywords; others from ``cfg``)."""
        return make_dyn(self.cfg, **overrides)

    def member_element_ids(self, spec: ElementSpec) -> np.ndarray:
        """Dense element ids of ``spec`` -> their union-grid positions
        (``(g, c) -> g * cfg.per_group + c``); the identity for a plain
        single-spec engine."""
        v = self.cfg.member_values(spec)
        return union_grid_ids(v.n_elements, v.per_group,
                              self.cfg.per_group)

    def apply(self, state: DeviceState, row,
              dyn: Optional[DynConfig] = None
              ) -> Tuple[DeviceState, OpTrace]:
        return apply_op(self.cfg, state,
                        jnp.asarray(row, jnp.int32), dyn)

    def run(self, state: DeviceState, program: np.ndarray,
            dyn: Optional[DynConfig] = None, *, obs=None
            ) -> Tuple[DeviceState, OpTrace]:
        """One scan; ``obs`` (an ``ObsConfig``) adds a telemetry third
        return -- see :func:`run_program`."""
        return run_program(self.cfg, state,
                           jnp.asarray(program, jnp.int32), dyn, obs=obs)

    def run_batch(self, state: DeviceState, programs: np.ndarray,
                  dyn: Optional[DynConfig] = None, *, obs=None
                  ) -> Tuple[DeviceState, OpTrace]:
        """Batched :meth:`run`; ``dyn`` with ``(n_programs,)`` leaves
        (see :func:`stack_dyn`) makes the batch heterogeneous; ``obs``
        adds per-lane telemetry stacks."""
        return run_programs(self.cfg, state,
                            jnp.asarray(programs, jnp.int32), dyn,
                            obs=obs)

    def warmup(self) -> None:
        """Compile every op branch on a scratch state (one switch jit)."""
        s = self.init_state()
        for op in (OP_ALLOC, OP_WRITE, OP_FINISH, OP_RESET):
            s, _ = self.apply(s, (op, 0, 1, F_HOST))
        jax.block_until_ready(s.elem_wear)

    # -- metrics -------------------------------------------------------- #
    def metrics(self, state: DeviceState) -> dict:
        host = int(state.host_pages)
        dummy = int(state.dummy_pages)
        return {
            "host_pages": float(host),
            "dummy_pages": float(dummy),
            "dlwa": (host + dummy) / host if host else 1.0,
            "block_erases": float(int(state.block_erases)),
            "alloc_calls": float(int(state.alloc_calls)),
            "n_active": float(int(state.n_active)),
        }

    def elem_wear(self, state: DeviceState,
                  spec: Optional[ElementSpec] = None) -> np.ndarray:
        """Element wear in ``spec``'s dense id order (default: the
        primary spec; union-grid padding elements are excluded)."""
        ids = self.member_element_ids(spec or self.spec)
        return np.asarray(state.elem_wear, dtype=np.int64)[ids]

    def block_wear(self, state: DeviceState,
                   spec: Optional[ElementSpec] = None) -> np.ndarray:
        spec = spec or self.spec
        layout = self.layouts[spec]
        wear = np.zeros(self.flash.n_blocks, dtype=np.int64)
        wear[layout.blocks.reshape(-1)] = np.repeat(
            self.elem_wear(state, spec), layout.blocks_per_element)
        return wear

    # -- IO stream reconstruction (host-side, post-scan) ---------------- #
    def op_stream(self, op: int, wp_before: int, wp_after: int,
                  dummy_delta: int, elems_after: np.ndarray,
                  cols: np.ndarray):
        """Rebuild the per-page ``(luns, channels)`` stream of one traced
        op, exactly as the legacy device's ``trace=True`` path emits it.
        Returns ``None`` when the op moved no pages."""
        cfg = self.cfg
        cols = np.asarray(cols, dtype=np.int64)
        if op == OP_WRITE and wp_after > wp_before:
            return zns.page_stream(wp_before, wp_after - wp_before,
                                   cfg.parallelism, cfg.pages_per_block,
                                   cols, cfg.n_channels) + ("write",)
        if op == OP_FINISH and dummy_delta > 0:
            written = zns.element_pages(
                wp_before, self.spec, cfg.parallelism, cfg.n_segments,
                cfg.pages_per_block)
            padded = np.nonzero((np.asarray(elems_after) >= 0)
                                & (written > 0)
                                & (written < cfg.pages_per_element))[0]
            return zns.pad_stream(
                wp_before, cfg.zone_pages, self.spec, cfg.parallelism,
                cfg.pages_per_block, cols, padded.astype(np.int64),
                cfg.n_channels) + ("write",)
        return None
